"""Pure-jnp oracles for the FastFold Pallas kernels.

Every Pallas kernel in this package has a reference implementation here,
written as straight-line jnp with NO fusion tricks. pytest asserts
allclose(kernel, ref) across shape/dtype sweeps — this is the core L1
correctness signal (paper §IV.A kernels: fused softmax, fused LayerNorm,
gated attention, triangle multiplicative update, outer product mean).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def fused_softmax_ref(x, bias=None, mask=None, scale=1.0):
    """Scaled, biased, masked softmax over the last axis.

    x:    (B, H, Q, K) attention scores (or any (..., K))
    bias: (H, Q, K) pair bias, broadcast over batch (optional)
    mask: (B, K) additive mask (0 / -inf style), broadcast over H, Q (optional)
    """
    s = x.astype(jnp.float32) * scale
    if bias is not None:
        s = s + bias.astype(jnp.float32)[None]
    if mask is not None:
        s = s + mask.astype(jnp.float32)[:, None, None, :]
    m = jnp.max(s, axis=-1, keepdims=True)
    e = jnp.exp(s - m)
    out = e / jnp.sum(e, axis=-1, keepdims=True)
    return out.astype(x.dtype)


def softmax2d_ref(x, scale=1.0):
    """Plain row softmax for 2-D (rows, cols) inputs (no bias/mask)."""
    s = x.astype(jnp.float32) * scale
    m = jnp.max(s, axis=-1, keepdims=True)
    e = jnp.exp(s - m)
    return (e / jnp.sum(e, axis=-1, keepdims=True)).astype(x.dtype)


def layernorm_ref(x, gamma, beta, eps=1e-5):
    """LayerNorm over the last axis (the paper's 12-per-block op)."""
    xf = x.astype(jnp.float32)
    mean = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(xf - mean), axis=-1, keepdims=True)
    y = (xf - mean) / jnp.sqrt(var + eps)
    return (y * gamma.astype(jnp.float32) + beta.astype(jnp.float32)).astype(
        x.dtype
    )


def gated_attention_ref(q, k, v, gate, bias=None, mask=None):
    """Evoformer attention core (paper Fig 3).

    q,k,v: (B, H, Q, D) / (B, H, K, D);  gate: (B, H, Q, D) pre-sigmoid
    bias:  (H, Q, K) optional pair bias; mask: (B, K) optional additive.
    Returns sigmoid(gate) * (softmax(qk^T/sqrt(D) + bias + mask) @ v).
    """
    d = q.shape[-1]
    scores = jnp.einsum(
        "bhqd,bhkd->bhqk", q.astype(jnp.float32), k.astype(jnp.float32)
    )
    p = fused_softmax_ref(scores, bias=bias, mask=mask, scale=1.0 / jnp.sqrt(d))
    ctx = jnp.einsum(
        "bhqk,bhkd->bhqd", p.astype(jnp.float32), v.astype(jnp.float32)
    )
    out = jax.nn.sigmoid(gate.astype(jnp.float32)) * ctx
    return out.astype(q.dtype)


def triangle_mult_ref(a, b, outgoing=True):
    """Triangular multiplicative update core (paper Fig 4 MatMul part).

    a, b: (R, R, C) gated projections of the pair representation.
    outgoing: out[i,j] = sum_k a[i,k] * b[j,k]
    incoming: out[i,j] = sum_k a[k,i] * b[k,j]
    """
    af = a.astype(jnp.float32)
    bf = b.astype(jnp.float32)
    if outgoing:
        out = jnp.einsum("ikc,jkc->ijc", af, bf)
    else:
        out = jnp.einsum("kic,kjc->ijc", af, bf)
    return out.astype(a.dtype)


def outer_product_mean_ref(a, b):
    """Outer Product Mean core: einsum(sid,sje->ijde) averaged over s.

    a: (S, I, D), b: (S, J, E)  ->  (I, J, D*E)
    """
    s = a.shape[0]
    out = jnp.einsum(
        "sid,sje->ijde", a.astype(jnp.float32), b.astype(jnp.float32)
    ) / s
    i, j, d, e = out.shape
    return out.reshape(i, j, d * e).astype(a.dtype)


def naive_softmax_unfused(x, bias=None, mask=None, scale=1.0):
    """Deliberately UNFUSED softmax chain — the 'PyTorch native' baseline of
    Fig 8: separate scale, bias-add, mask-add, max, sub, exp, sum, div ops
    kept as distinct HLO-visible steps (optimization barriers stop XLA from
    collapsing the chain, mimicking eager-mode kernel-per-op execution)."""
    opt = jax.lax.optimization_barrier
    s = opt(x.astype(jnp.float32))
    s = opt(s * scale)
    if bias is not None:
        s = opt(s + bias.astype(jnp.float32)[None])
    if mask is not None:
        s = opt(s + mask.astype(jnp.float32)[:, None, None, :])
    m = opt(jnp.max(s, axis=-1, keepdims=True))
    s = opt(s - m)
    e = opt(jnp.exp(s))
    z = opt(jnp.sum(e, axis=-1, keepdims=True))
    return (e / z).astype(x.dtype)


def naive_layernorm_twopass(x, gamma, beta, eps=1e-5):
    """Deliberately UNFUSED two-pass LayerNorm — the Fig 9 baseline."""
    opt = jax.lax.optimization_barrier
    xf = opt(x.astype(jnp.float32))
    mean = opt(jnp.mean(xf, axis=-1, keepdims=True))
    centered = opt(xf - mean)
    var = opt(jnp.mean(jnp.square(centered), axis=-1, keepdims=True))
    inv = opt(1.0 / jnp.sqrt(var + eps))
    y = opt(centered * inv)
    y = opt(y * gamma.astype(jnp.float32))
    return (y + beta.astype(jnp.float32)).astype(x.dtype)
