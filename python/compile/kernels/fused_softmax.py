"""Fused scaled/biased/masked softmax as a Pallas kernel (paper §IV.A.2).

GPU→TPU adaptation (DESIGN.md §Hardware-Adaptation): the paper assigns one
CUDA *warp* per softmax row and reduces with ``__shfl_xor_sync``. On TPU the
analogue is one *grid program* per (batch, head) tile: the whole row block
lives in VMEM and the max/sum reductions are VPU vector reduces. Scaling,
pair-bias add and mask add are fused into the same kernel — one HBM pass —
exactly the fusion the CUDA kernel performs.

``interpret=True`` everywhere: the CPU PJRT plugin cannot run Mosaic
custom-calls; interpret mode lowers to plain HLO so the same artifact runs
under the rust runtime.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _softmax_body(s):
    """Numerically-stable softmax over the last axis of an f32 block."""
    m = jnp.max(s, axis=-1, keepdims=True)
    e = jnp.exp(s - m)
    return e / jnp.sum(e, axis=-1, keepdims=True)


def _kernel_plain(x_ref, o_ref, *, scale):
    s = x_ref[...].astype(jnp.float32) * scale
    o_ref[...] = _softmax_body(s).astype(o_ref.dtype)


def _kernel_bias(x_ref, b_ref, o_ref, *, scale):
    s = x_ref[...].astype(jnp.float32) * scale
    s = s + b_ref[...].astype(jnp.float32)
    o_ref[...] = _softmax_body(s).astype(o_ref.dtype)


def _kernel_bias_mask(x_ref, b_ref, m_ref, o_ref, *, scale):
    s = x_ref[...].astype(jnp.float32) * scale
    s = s + b_ref[...].astype(jnp.float32)
    s = s + m_ref[...].astype(jnp.float32)[:, None, :]
    o_ref[...] = _softmax_body(s).astype(o_ref.dtype)


def _fused_softmax_raw(x, bias=None, mask=None, scale=1.0):
    """softmax(x*scale + bias + mask) over the last axis.

    x:    (B, H, Q, K); bias: (H, Q, K) or None; mask: (B, K) or None.
    The (B, H) grid expresses the bias broadcast through BlockSpec index
    maps instead of materializing the broadcast in HBM.
    """
    b, h, q, k = x.shape
    grid = (b, h)
    x_spec = pl.BlockSpec((1, 1, q, k), lambda i, j: (i, j, 0, 0))
    out_spec = pl.BlockSpec((1, 1, q, k), lambda i, j: (i, j, 0, 0))
    out_shape = jax.ShapeDtypeStruct(x.shape, x.dtype)
    if bias is None and mask is None:
        return pl.pallas_call(
            functools.partial(_kernel_plain, scale=scale),
            grid=grid,
            in_specs=[x_spec],
            out_specs=out_spec,
            out_shape=out_shape,
            interpret=True,
        )(x)
    if bias is None:
        bias = jnp.zeros((h, q, k), x.dtype)
    b_spec = pl.BlockSpec((1, q, k), lambda i, j: (j, 0, 0))
    if mask is None:
        return pl.pallas_call(
            functools.partial(_kernel_bias, scale=scale),
            grid=grid,
            in_specs=[x_spec, b_spec],
            out_specs=out_spec,
            out_shape=out_shape,
            interpret=True,
        )(x, bias)
    m_spec = pl.BlockSpec((1, k), lambda i, j: (i, 0))
    return pl.pallas_call(
        functools.partial(_kernel_bias_mask, scale=scale),
        grid=grid,
        in_specs=[x_spec, b_spec, m_spec],
        out_specs=out_spec,
        out_shape=out_shape,
        interpret=True,
    )(x, bias, mask)


# --------------------------------------------------------------------------
# custom_vjp wrappers: pallas_call has no built-in reverse-mode rule, and the
# paper ships *fused backward kernels* anyway. The backward below is the
# analytic fused-softmax gradient (ds = p ⊙ (ct − ⟨ct, p⟩)), computed from the
# saved probabilities — one fused elementwise+reduce chain, no forward replay.
# --------------------------------------------------------------------------


def _softmax_grad(p, ct):
    pf = p.astype(jnp.float32)
    ctf = ct.astype(jnp.float32)
    return pf * (ctf - jnp.sum(ctf * pf, axis=-1, keepdims=True))


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _sm_plain(scale, x):
    return _fused_softmax_raw(x, None, None, scale)


def _sm_plain_fwd(scale, x):
    out = _fused_softmax_raw(x, None, None, scale)
    return out, out


def _sm_plain_bwd(scale, p, ct):
    return (( _softmax_grad(p, ct) * scale).astype(p.dtype),)


_sm_plain.defvjp(_sm_plain_fwd, _sm_plain_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _sm_bias(scale, x, bias):
    return _fused_softmax_raw(x, bias, None, scale)


def _sm_bias_fwd(scale, x, bias):
    out = _fused_softmax_raw(x, bias, None, scale)
    return out, out


def _sm_bias_bwd(scale, p, ct):
    ds = _softmax_grad(p, ct)
    return (ds * scale).astype(p.dtype), jnp.sum(ds, axis=0).astype(p.dtype)


_sm_bias.defvjp(_sm_bias_fwd, _sm_bias_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _sm_bias_mask(scale, x, bias, mask):
    return _fused_softmax_raw(x, bias, mask, scale)


def _sm_bias_mask_fwd(scale, x, bias, mask):
    out = _fused_softmax_raw(x, bias, mask, scale)
    return out, out


def _sm_bias_mask_bwd(scale, p, ct):
    ds = _softmax_grad(p, ct)
    return (
        (ds * scale).astype(p.dtype),
        jnp.sum(ds, axis=0).astype(p.dtype),
        jnp.sum(ds, axis=(1, 2)).astype(p.dtype),
    )


_sm_bias_mask.defvjp(_sm_bias_mask_fwd, _sm_bias_mask_bwd)


def fused_softmax(x, bias=None, mask=None, scale=1.0):
    """Differentiable fused softmax (see _fused_softmax_raw for semantics)."""
    if bias is None and mask is None:
        return _sm_plain(scale, x)
    if bias is None:
        bias = jnp.zeros((x.shape[1], x.shape[2], x.shape[3]), x.dtype)
    if mask is None:
        return _sm_bias(scale, x, bias)
    return _sm_bias_mask(scale, x, bias, mask)


def _kernel_rows(x_ref, o_ref, *, scale):
    s = x_ref[...].astype(jnp.float32) * scale
    o_ref[...] = _softmax_body(s).astype(o_ref.dtype)


@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1))
def _sm2d(scale, block_rows, x):
    return _fused_softmax2d_raw(x, scale, block_rows)


def _sm2d_fwd(scale, block_rows, x):
    out = _fused_softmax2d_raw(x, scale, block_rows)
    return out, out


def _sm2d_bwd(scale, block_rows, p, ct):
    return ((_softmax_grad(p, ct) * scale).astype(p.dtype),)


_sm2d.defvjp(_sm2d_fwd, _sm2d_bwd)


def fused_softmax2d(x, scale=1.0, block_rows=128):
    """Differentiable 2-D row softmax (Fig 8 microbenchmark shape)."""
    return _sm2d(scale, block_rows, x)


def _fused_softmax2d_raw(x, scale=1.0, block_rows=128):
    """Row softmax for 2-D (rows, cols): the Fig 8 microbenchmark shape.

    One grid program handles ``block_rows`` rows — the TPU analogue of the
    paper's one-warp-per-row mapping for many-small-rows inputs.
    """
    r, c = x.shape
    br = min(block_rows, r)
    # pad rows so the grid divides evenly (masked rows are pure garbage-in/
    # garbage-out and sliced off — softmax rows are independent).
    pad = (-r) % br
    xp = jnp.pad(x, ((0, pad), (0, 0))) if pad else x
    out = pl.pallas_call(
        functools.partial(_kernel_rows, scale=scale),
        grid=((r + pad) // br,),
        in_specs=[pl.BlockSpec((br, c), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((br, c), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(xp.shape, x.dtype),
        interpret=True,
    )(xp)
    return out[:r] if pad else out
