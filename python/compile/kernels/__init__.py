"""FastFold L1 Pallas kernels (interpret=True — CPU-PJRT runnable HLO).

Public surface:
    fused_softmax, fused_softmax2d   — §IV.A.2
    fused_layernorm                  — §IV.A.3 (chunked Welford)
    gated_attention                  — Fig 3 fused attention core
    triangle_mult                    — Fig 4 triangular update core
    outer_product_mean               — MSA→pair communication core
plus the pure-jnp oracles in kernels.ref.
"""

from .attention import gated_attention
from .fused_layernorm import fused_layernorm
from .fused_softmax import fused_softmax, fused_softmax2d
from .opm import outer_product_mean
from .triangle import triangle_mult

__all__ = [
    "gated_attention",
    "fused_layernorm",
    "fused_softmax",
    "fused_softmax2d",
    "outer_product_mean",
    "triangle_mult",
]
