"""Outer Product Mean core as a Pallas kernel (paper §III.A item 3).

einsum(sid, sje -> ijde) / S, flattened to (I, J, D*E): the MSA→pair
communication op. TPU mapping: 2-D grid over (i-block, j-block); each
program holds the (S, BI, D) left and (S, BJ, E) right tiles in VMEM and
contracts over the sequence axis s — the reduction the paper averages over
sequences. The projection GEMMs producing left/right live in model.py.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(a_ref, b_ref, o_ref):
    a = a_ref[...].astype(jnp.float32)  # (S, BI, D)
    b = b_ref[...].astype(jnp.float32)  # (S, BJ, E)
    s = a.shape[0]
    out = jnp.einsum("sid,sje->ijde", a, b) / s
    bi, bj, d, e = out.shape
    o_ref[...] = out.reshape(bi, bj, d * e).astype(o_ref.dtype)


def _outer_product_mean_raw(a, b, block=64):
    """a: (S, I, D), b: (S, J, E) -> (I, J, D*E), mean over S."""
    s, i, d = a.shape
    _, j, e = b.shape
    bi, bj = min(block, i), min(block, j)
    while i % bi:
        bi -= 1
    while j % bj:
        bj -= 1
    return pl.pallas_call(
        _kernel,
        grid=(i // bi, j // bj),
        in_specs=[
            pl.BlockSpec((s, bi, d), lambda x, y: (0, x, 0)),
            pl.BlockSpec((s, bj, e), lambda x, y: (0, y, 0)),
        ],
        out_specs=pl.BlockSpec((bi, bj, d * e), lambda x, y: (x, y, 0)),
        out_shape=jax.ShapeDtypeStruct((i, j, d * e), a.dtype),
        interpret=True,
    )(a, b)


# --------------------------------------------------------------------------
# custom_vjp: analytic OPM backward.
#   out[i,j,(d,e)] = (1/S) Σ_s a[s,i,d] b[s,j,e]
#   da[s,i,d] = (1/S) Σ_{j,e} ct[i,j,(d,e)] b[s,j,e]
#   db[s,j,e] = (1/S) Σ_{i,d} ct[i,j,(d,e)] a[s,i,d]
# --------------------------------------------------------------------------


@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def outer_product_mean(a, b, block=64):
    """Differentiable outer-product-mean contraction."""
    return _outer_product_mean_raw(a, b, block)


def _opm_fwd(a, b, block):
    return _outer_product_mean_raw(a, b, block), (a, b)


def _opm_bwd(block, res, ct):
    a, b = res
    s, i, d = a.shape
    _, j, e = b.shape
    ct4 = ct.astype(jnp.float32).reshape(i, j, d, e)
    af, bf = a.astype(jnp.float32), b.astype(jnp.float32)
    da = jnp.einsum("ijde,sje->sid", ct4, bf) / s
    db = jnp.einsum("ijde,sid->sje", ct4, af) / s
    return da.astype(a.dtype), db.astype(b.dtype)


outer_product_mean.defvjp(_opm_fwd, _opm_bwd)
