"""Fused gated attention core as a Pallas kernel (paper Fig 3).

Evoformer attention differs from vanilla attention in two ways the kernel
fuses end-to-end:
  1. *pair bias* added to the attention score before softmax;
  2. a *gating* branch: sigmoid(gate) elementwise-multiplies the context.

One grid program per (batch, head): Q/K/V/gate tiles for that head sit in
VMEM; scores → stable softmax → context → gate happen without touching HBM
in between. The QK^T and PV products are MXU-shaped matmuls (D = 32 lanes,
bf16-friendly); the merge-GEMM producing QKV+gate in a single projection
lives one level up in model.py (paper §IV.A.1 "Merge GEMM").
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _attn_core(q, k, v, g, scale):
    s = jnp.einsum("qd,kd->qk", q, k) * scale
    m = jnp.max(s, axis=-1, keepdims=True)
    e = jnp.exp(s - m)
    p = e / jnp.sum(e, axis=-1, keepdims=True)
    ctx = jnp.einsum("qk,kd->qd", p, v)
    return jax.nn.sigmoid(g) * ctx


def _kernel(q_ref, k_ref, v_ref, g_ref, o_ref, *, scale):
    q = q_ref[0, 0].astype(jnp.float32)
    k = k_ref[0, 0].astype(jnp.float32)
    v = v_ref[0, 0].astype(jnp.float32)
    g = g_ref[0, 0].astype(jnp.float32)
    o_ref[0, 0] = _attn_core(q, k, v, g, scale).astype(o_ref.dtype)


def _kernel_bias(q_ref, k_ref, v_ref, g_ref, b_ref, o_ref, *, scale):
    q = q_ref[0, 0].astype(jnp.float32)
    k = k_ref[0, 0].astype(jnp.float32)
    v = v_ref[0, 0].astype(jnp.float32)
    g = g_ref[0, 0].astype(jnp.float32)
    s = jnp.einsum("qd,kd->qk", q, k) * scale + b_ref[0].astype(jnp.float32)
    m = jnp.max(s, axis=-1, keepdims=True)
    e = jnp.exp(s - m)
    p = e / jnp.sum(e, axis=-1, keepdims=True)
    ctx = jnp.einsum("qk,kd->qd", p, v)
    o_ref[0, 0] = (jax.nn.sigmoid(g) * ctx).astype(o_ref.dtype)


def _gated_attention_raw(q, k, v, gate, bias=None):
    """sigmoid(gate) * softmax(q k^T / sqrt(D) + bias) v.

    q, gate: (B, H, Q, D); k, v: (B, H, K, D); bias: (H, Q, K) or None.
    """
    b, h, nq, d = q.shape
    nk = k.shape[2]
    scale = 1.0 / (d ** 0.5)
    grid = (b, h)
    spec_q = pl.BlockSpec((1, 1, nq, d), lambda i, j: (i, j, 0, 0))
    spec_k = pl.BlockSpec((1, 1, nk, d), lambda i, j: (i, j, 0, 0))
    out_shape = jax.ShapeDtypeStruct(q.shape, q.dtype)
    if bias is None:
        return pl.pallas_call(
            functools.partial(_kernel, scale=scale),
            grid=grid,
            in_specs=[spec_q, spec_k, spec_k, spec_q],
            out_specs=spec_q,
            out_shape=out_shape,
            interpret=True,
        )(q, k, v, gate)
    spec_b = pl.BlockSpec((1, nq, nk), lambda i, j: (j, 0, 0))
    return pl.pallas_call(
        functools.partial(_kernel_bias, scale=scale),
        grid=grid,
        in_specs=[spec_q, spec_k, spec_k, spec_q, spec_b],
        out_specs=spec_q,
        out_shape=out_shape,
        interpret=True,
    )(q, k, v, gate, bias)


# --------------------------------------------------------------------------
# custom_vjp: backward replays the reference attention under jax.vjp — this
# is exactly the *gradient checkpointing* the paper applies to attention
# (§III.B): the O(Q·K) probability tensor is never saved, only the O(Q·D)
# inputs, and is rematerialized in backward.
# --------------------------------------------------------------------------

from . import ref as _ref  # noqa: E402  (import after kernel defs)


@jax.custom_vjp
def _ga_nobias(q, k, v, gate):
    return _gated_attention_raw(q, k, v, gate, None)


def _ga_nobias_fwd(q, k, v, gate):
    return _gated_attention_raw(q, k, v, gate, None), (q, k, v, gate)


def _ga_nobias_bwd(res, ct):
    _, vjp = jax.vjp(lambda q, k, v, g: _ref.gated_attention_ref(q, k, v, g), *res)
    return vjp(ct)


_ga_nobias.defvjp(_ga_nobias_fwd, _ga_nobias_bwd)


@jax.custom_vjp
def _ga_bias(q, k, v, gate, bias):
    return _gated_attention_raw(q, k, v, gate, bias)


def _ga_bias_fwd(q, k, v, gate, bias):
    return _gated_attention_raw(q, k, v, gate, bias), (q, k, v, gate, bias)


def _ga_bias_bwd(res, ct):
    _, vjp = jax.vjp(
        lambda q, k, v, g, b: _ref.gated_attention_ref(q, k, v, g, b), *res
    )
    return vjp(ct)


_ga_bias.defvjp(_ga_bias_fwd, _ga_bias_bwd)


def gated_attention(q, k, v, gate, bias=None):
    """Differentiable fused gated attention (see _gated_attention_raw)."""
    if bias is None:
        return _ga_nobias(q, k, v, gate)
    return _ga_bias(q, k, v, gate, bias)
