"""Triangular multiplicative update MatMul core as a Pallas kernel
(paper Fig 4).

out[i,j,c] = sum_k a[i,k,c] * b[j,k,c]   (outgoing edges)
out[i,j,c] = sum_k a[k,i,c] * b[k,j,c]   (incoming edges)

This is a batch of per-channel rank-R updates. TPU mapping: 2-D grid over
(i-block, j-block); each program keeps an (BI, K, C) a-tile and (BJ, K, C)
b-tile in VMEM and contracts over k with an MXU-shaped einsum. The left/right
projection + gating merge-GEMM feeding this kernel lives in model.py.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel_out(a_ref, b_ref, o_ref):
    a = a_ref[...].astype(jnp.float32)  # (BI, K, C)
    b = b_ref[...].astype(jnp.float32)  # (BJ, K, C)
    o_ref[...] = jnp.einsum("ikc,jkc->ijc", a, b).astype(o_ref.dtype)


def _kernel_in(a_ref, b_ref, o_ref):
    a = a_ref[...].astype(jnp.float32)  # (K, BI, C)
    b = b_ref[...].astype(jnp.float32)  # (K, BJ, C)
    o_ref[...] = jnp.einsum("kic,kjc->ijc", a, b).astype(o_ref.dtype)


def _triangle_mult_raw(a, b, outgoing=True, block=64):
    """Triangle multiplicative-update contraction.

    a, b: (R, R, C) — already layer-normed, projected and gated.
    Returns (R, R, C). a and b may have different leading/contraction sizes
    only through R; C is the pair channel dim.
    """
    r1, r2, c = a.shape
    bi = min(block, r1 if outgoing else r2)
    bj = min(block, b.shape[0] if outgoing else b.shape[1])
    if outgoing:
        ni, nj = a.shape[0], b.shape[0]
        while ni % bi:
            bi -= 1
        while nj % bj:
            bj -= 1
        return pl.pallas_call(
            _kernel_out,
            grid=(ni // bi, nj // bj),
            in_specs=[
                pl.BlockSpec((bi, r2, c), lambda i, j: (i, 0, 0)),
                pl.BlockSpec((bj, r2, c), lambda i, j: (j, 0, 0)),
            ],
            out_specs=pl.BlockSpec((bi, bj, c), lambda i, j: (i, j, 0)),
            out_shape=jax.ShapeDtypeStruct((ni, nj, c), a.dtype),
            interpret=True,
        )(a, b)
    ni, nj = a.shape[1], b.shape[1]
    while ni % bi:
        bi -= 1
    while nj % bj:
        bj -= 1
    return pl.pallas_call(
        _kernel_in,
        grid=(ni // bi, nj // bj),
        in_specs=[
            pl.BlockSpec((r1, bi, c), lambda i, j: (0, i, 0)),
            pl.BlockSpec((r1, bj, c), lambda i, j: (0, j, 0)),
        ],
        out_specs=pl.BlockSpec((bi, bj, c), lambda i, j: (i, j, 0)),
        out_shape=jax.ShapeDtypeStruct((ni, nj, c), a.dtype),
        interpret=True,
    )(a, b)


# --------------------------------------------------------------------------
# custom_vjp: analytic triangle-update backward (two einsums per operand),
# the fused-bwd-kernel analogue.
#   outgoing: out[i,j] = Σ_k a[i,k] b[j,k]
#     da[i,k] = Σ_j ct[i,j] b[j,k];  db[j,k] = Σ_i ct[i,j] a[i,k]
#   incoming: out[i,j] = Σ_k a[k,i] b[k,j]
#     da[k,i] = Σ_j ct[i,j] b[k,j];  db[k,j] = Σ_i ct[i,j] a[k,i]
# --------------------------------------------------------------------------


@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3))
def triangle_mult(a, b, outgoing=True, block=64):
    """Differentiable triangle multiplicative-update contraction."""
    return _triangle_mult_raw(a, b, outgoing, block)


def _tri_fwd(a, b, outgoing, block):
    return _triangle_mult_raw(a, b, outgoing, block), (a, b)


def _tri_bwd(outgoing, block, res, ct):
    a, b = res
    af, bf = a.astype(jnp.float32), b.astype(jnp.float32)
    ctf = ct.astype(jnp.float32)
    if outgoing:
        da = jnp.einsum("ijc,jkc->ikc", ctf, bf)
        db = jnp.einsum("ijc,ikc->jkc", ctf, af)
    else:
        da = jnp.einsum("ijc,kjc->kic", ctf, bf)
        db = jnp.einsum("ijc,kic->kjc", ctf, af)
    return da.astype(a.dtype), db.astype(b.dtype)


triangle_mult.defvjp(_tri_fwd, _tri_bwd)
