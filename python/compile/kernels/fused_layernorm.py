"""Fused LayerNorm as a Pallas kernel with chunked-Welford statistics
(paper §IV.A.3).

The paper's CUDA kernel assigns one warp per row and computes mean/variance
with the *Welford* single-pass update, merged across threads via
WarpAllReduce. TPU adaptation: one grid program per row block; the row is
tiled into column chunks, per-chunk (count, mean, M2) are computed
vectorized, then merged with the Chan/Welford parallel-merge formula — a
single pass over HBM, numerically stable, and shaped exactly like the
warp-tree merge the paper implements.

scale (gamma) and bias (beta) application is fused into the same kernel —
the whole LayerNorm is one HBM read + one HBM write.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _welford_merge(count_a, mean_a, m2_a, count_b, mean_b, m2_b):
    """Chan parallel-variance merge of two (count, mean, M2) partials."""
    count = count_a + count_b
    delta = mean_b - mean_a
    mean = mean_a + delta * (count_b / count)
    m2 = m2_a + m2_b + jnp.square(delta) * (count_a * count_b / count)
    return count, mean, m2


def _kernel(x_ref, g_ref, b_ref, o_ref, *, eps, chunks):
    x = x_ref[...].astype(jnp.float32)  # (rows, C)
    rows, c = x.shape
    cs = c // chunks  # chunk size (c padded to a multiple by caller)

    # per-chunk partials, vectorized over rows: shapes (rows, chunks)
    xc = x.reshape(rows, chunks, cs)
    cnt = jnp.full((rows, chunks), float(cs), jnp.float32)
    mean = jnp.mean(xc, axis=-1)
    m2 = jnp.sum(jnp.square(xc - mean[..., None]), axis=-1)

    # sequential Welford merge across chunks (the warp-reduce analogue)
    def merge(i, carry):
        ca, ma, m2a = carry
        return _welford_merge(ca, ma, m2a, cnt[:, i], mean[:, i], m2[:, i])

    carry = (cnt[:, 0], mean[:, 0], m2[:, 0])
    ca, ma, m2a = jax.lax.fori_loop(1, chunks, merge, carry)

    var = m2a / ca
    inv = jax.lax.rsqrt(var + eps)
    y = (x - ma[:, None]) * inv[:, None]
    y = y * g_ref[...].astype(jnp.float32) + b_ref[...].astype(jnp.float32)
    o_ref[...] = y.astype(o_ref.dtype)


def _fused_layernorm_raw(x, gamma, beta, eps=1e-5, block_rows=128, chunks=None):
    """LayerNorm over the last axis of x (any leading shape).

    gamma, beta: (C,). Rows are flattened, processed ``block_rows`` per grid
    program; the feature axis is split into ``chunks`` Welford partials
    (default: one 128-lane chunk per 128 features, min 1).
    """
    orig_shape = x.shape
    c = orig_shape[-1]
    x2 = x.reshape(-1, c)
    r = x2.shape[0]
    if chunks is None:
        chunks = max(1, c // 128)
    while c % chunks != 0:
        chunks -= 1
    br = min(block_rows, r)
    pad = (-r) % br
    xp = jnp.pad(x2, ((0, pad), (0, 0))) if pad else x2
    out = pl.pallas_call(
        functools.partial(_kernel, eps=eps, chunks=chunks),
        grid=((r + pad) // br,),
        in_specs=[
            pl.BlockSpec((br, c), lambda i: (i, 0)),
            pl.BlockSpec((c,), lambda i: (0,)),
            pl.BlockSpec((c,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((br, c), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(xp.shape, x.dtype),
        interpret=True,
    )(xp, gamma, beta)
    if pad:
        out = out[:r]
    return out.reshape(orig_shape)


# --------------------------------------------------------------------------
# custom_vjp: the analytic fused LayerNorm backward (the paper ships a fused
# bwd kernel too). Residuals are (x, gamma); mean/inv-std are recomputed in
# f32 — one pass, same cost class as the CUDA bwd which re-reads x anyway.
# --------------------------------------------------------------------------


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def fused_layernorm(x, gamma, beta, eps=1e-5, block_rows=128, chunks=None):
    """Differentiable fused LayerNorm over the last axis (see module doc)."""
    return _fused_layernorm_raw(x, gamma, beta, eps, block_rows, chunks)


def _ln_fwd(x, gamma, beta, eps, block_rows, chunks):
    out = _fused_layernorm_raw(x, gamma, beta, eps, block_rows, chunks)
    return out, (x, gamma)


def _ln_bwd(eps, block_rows, chunks, res, ct):
    x, gamma = res
    xf = x.astype(jnp.float32)
    gf = gamma.astype(jnp.float32)
    ctf = ct.astype(jnp.float32)
    mean = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(xf - mean), axis=-1, keepdims=True)
    inv = jax.lax.rsqrt(var + eps)
    xhat = (xf - mean) * inv
    dbeta = jnp.sum(ctf, axis=tuple(range(ct.ndim - 1)))
    dgamma = jnp.sum(ctf * xhat, axis=tuple(range(ct.ndim - 1)))
    dxhat = ctf * gf
    dx = inv * (
        dxhat
        - jnp.mean(dxhat, axis=-1, keepdims=True)
        - xhat * jnp.mean(dxhat * xhat, axis=-1, keepdims=True)
    )
    return dx.astype(x.dtype), dgamma.astype(gamma.dtype), dbeta.astype(x.dtype)


fused_layernorm.defvjp(_ln_fwd, _ln_bwd)
