"""Dynamic Axial Parallelism: the Evoformer block decomposed into
communication-separated segments (paper §IV.B.2, Fig 6, Table III).

Sharding convention for N ranks (DESIGN.md §3):
    m  s-sharded: (s/N, r, d_msa)     axis 0        (row-attention phase)
    m  r-sharded: (s, r/N, d_msa)     axis 1        (col-attn/transition/OPM)
    z  i-sharded: (r/N, r, d_pair)    axis 0        (canonical)
    z  j-sharded: (r, r/N, d_pair)    axis 1        (triangle-attn ending)

Each *segment* is a pure JAX function ``seg(p_block, cfg, *tensors)`` whose
inputs/outputs are rank-local shards or gathered full tensors. The rust
coordinator executes the AOT-compiled segments and performs the collectives
between them; `SCHEDULE` below is the exact op list it follows (exported
verbatim into manifest.json), including the Duality-Async ``trigger`` /
``wait`` pairs that expose computation–communication overlap: a collective
is launched, independent segments run, then the consumer waits.

`simulate_dap` emulates the whole thing in-process with jnp collectives —
pytest asserts it reproduces `model.evoformer_block` bit-for-bit-ish
(float-associativity tolerance), which is the paper's §V.D validation at
block level.

Backward: every segment also exports a VJP twin (aot.py) computing
``(dparams, dinputs) = vjp(seg)(cotangents)`` with forward rematerialized
inside — gradient checkpointing at segment granularity, matching the
paper's use of activation checkpointing. The rust tape replays SCHEDULE in
reverse with transposed collectives (all_gather ↔ reduce_scatter,
all_to_all ↔ inverse all_to_all).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import model
from .configs import ModelConfig
from .kernels import outer_product_mean, triangle_mult

# --------------------------------------------------------------------------
# segments
# --------------------------------------------------------------------------


def seg_row_bias(p, cfg, z_loc):
    """(z i-shard) → pair-bias shard (i_loc, r, h_msa)."""
    act = model.layer_norm(p["row_bias"]["ln"], z_loc)
    return (model.linear_nobias(p["row_bias"]["proj"], act),)


def seg_msa_row_proj(p, cfg, m_loc):
    """(m s-shard) → merged QKV+gate projection (s_loc, r, 4·h·d)."""
    act = model.layer_norm(p["row_attn"]["ln"], m_loc)
    return (model.linear_nobias(p["row_attn"]["qkvg"], act),)


def seg_msa_row_core(p, cfg, m_loc, qkvg, bias_full):
    """(m s-shard, qkvg, gathered bias (r,r,h)) → updated m s-shard."""
    h = cfg.n_heads_msa
    bias = jnp.transpose(bias_full, (2, 0, 1))
    q, k, v, g = jnp.split(qkvg, 4, axis=-1)
    q, k, v, g = (model._split_heads(t, h) for t in (q, k, v, g))
    o = model._attention(q, k, v, g, bias, True)
    return (m_loc + model.linear(p["row_attn"]["out"], model._merge_heads(o)),)


def seg_msa_col(p, cfg, m_loc):
    """(m r-shard) → updated m r-shard; attention along s is rank-local."""
    return (m_loc + model.msa_col_attention(
        p["col_attn"], m_loc, cfg.n_heads_msa),)


def seg_msa_trans(p, cfg, m_loc):
    return (m_loc + model.transition(p["msa_trans"], m_loc),)


def seg_opm_pre(p, cfg, m_loc):
    """(m r-shard) → OPM left/right projections (s, r_loc, d_opm) each."""
    act = model.layer_norm(p["opm"]["ln"], m_loc)
    ab = model.linear_nobias(p["opm"]["ab"], act)
    a, b = jnp.split(ab, 2, axis=-1)
    return a, b


def seg_opm_post(p, cfg, z_loc, a_loc, b_full):
    """(z i-shard, local left, gathered right) → updated z i-shard.

    out[i_loc, j] = mean_s a[s, i_loc] ⊗ b[s, j]  (1 AllGather total)."""
    o = outer_product_mean(a_loc, b_full)
    return (z_loc + model.linear(p["opm"]["out"], o),)


def _tri_projections(p, act):
    pg = model.linear_nobias(p["pg"], act)
    a, b, ga, gb = jnp.split(pg, 4, axis=-1)
    return a * jax.nn.sigmoid(ga), b * jax.nn.sigmoid(gb)


def seg_tri_out_pre(p, cfg, z_loc):
    """(z i-shard) → (ln(z) shard, gated left a, gated right b)."""
    act = model.layer_norm(p["tri_out"]["ln"], z_loc)
    a, b = _tri_projections(p["tri_out"], act)
    return act, a, b


def seg_tri_out_post(p, cfg, z_loc, act, a_loc, b_full):
    """out[i_loc, j] = Σ_k a[i_loc,k]·b_full[j,k]  (1 AllGather)."""
    o = triangle_mult(a_loc, b_full, True)
    o = model.layer_norm(p["tri_out"]["ln_out"], o)
    g = jax.nn.sigmoid(model.linear_nobias(p["tri_out"]["gate"], act))
    return (z_loc + g * model.linear(p["tri_out"]["out"], o),)


def seg_tri_in_pre(p, cfg, z_loc):
    """(z i-shard) → (ln(z) shard, FULL partial sum over local k).

    partial[i,j] = Σ_{k∈local} a[k,i]·b[k,j] — reduce-scattered along i
    (avoids the double gather; 1 ReduceScatter, DESIGN.md §3)."""
    act = model.layer_norm(p["tri_in"]["ln"], z_loc)
    a, b = _tri_projections(p["tri_in"], act)
    partial = triangle_mult(a, b, False)
    return act, partial


def seg_tri_in_post(p, cfg, z_loc, act, part_loc):
    o = model.layer_norm(p["tri_in"]["ln_out"], part_loc)
    g = jax.nn.sigmoid(model.linear_nobias(p["tri_in"]["gate"], act))
    return (z_loc + g * model.linear(p["tri_in"]["out"], o),)


def seg_tri_start_bias(p, cfg, z_loc):
    act = model.layer_norm(p["start_bias"]["ln"], z_loc)
    return (model.linear_nobias(p["start_bias"]["proj"], act),)


def seg_tri_start_proj(p, cfg, z_loc):
    act = model.layer_norm(p["tri_start"]["ln"], z_loc)
    return (model.linear_nobias(p["tri_start"]["qkvg"], act),)


def seg_tri_start_core(p, cfg, z_loc, qkvg, bias_full):
    h = cfg.n_heads_pair
    bias = jnp.transpose(bias_full, (2, 0, 1))
    q, k, v, g = jnp.split(qkvg, 4, axis=-1)
    q, k, v, g = (model._split_heads(t, h) for t in (q, k, v, g))
    o = model._attention(q, k, v, g, bias, True)
    return (z_loc + model.linear(p["tri_start"]["out"], model._merge_heads(o)),)


def seg_tri_end_bias(p, cfg, z_loc):
    """z is j-sharded (r, j_loc, c); ending-node = starting-node on z^T."""
    zt = jnp.transpose(z_loc, (1, 0, 2))  # (j_loc, r, c)
    act = model.layer_norm(p["end_bias"]["ln"], zt)
    return (model.linear_nobias(p["end_bias"]["proj"], act),)


def seg_tri_end_proj(p, cfg, z_loc):
    zt = jnp.transpose(z_loc, (1, 0, 2))
    act = model.layer_norm(p["tri_end"]["ln"], zt)
    return (model.linear_nobias(p["tri_end"]["qkvg"], act),)


def seg_tri_end_core(p, cfg, z_loc, qkvg, bias_full):
    h = cfg.n_heads_pair
    bias = jnp.transpose(bias_full, (2, 0, 1))
    q, k, v, g = jnp.split(qkvg, 4, axis=-1)
    q, k, v, g = (model._split_heads(t, h) for t in (q, k, v, g))
    o = model._attention(q, k, v, g, bias, True)
    o = model.linear(p["tri_end"]["out"], model._merge_heads(o))
    return (z_loc + jnp.transpose(o, (1, 0, 2)),)


def seg_pair_trans(p, cfg, z_loc):
    return (z_loc + model.transition(p["pair_trans"], z_loc),)


SEGMENTS = {
    "row_bias": seg_row_bias,
    "msa_row_proj": seg_msa_row_proj,
    "msa_row_core": seg_msa_row_core,
    "msa_col": seg_msa_col,
    "msa_trans": seg_msa_trans,
    "opm_pre": seg_opm_pre,
    "opm_post": seg_opm_post,
    "tri_out_pre": seg_tri_out_pre,
    "tri_out_post": seg_tri_out_post,
    "tri_in_pre": seg_tri_in_pre,
    "tri_in_post": seg_tri_in_post,
    "tri_start_bias": seg_tri_start_bias,
    "tri_start_proj": seg_tri_start_proj,
    "tri_start_core": seg_tri_start_core,
    "tri_end_bias": seg_tri_end_bias,
    "tri_end_proj": seg_tri_end_proj,
    "tri_end_core": seg_tri_end_core,
    "pair_trans": seg_pair_trans,
}

# --------------------------------------------------------------------------
# schedule: the exact op sequence the rust DAP coordinator runs per block.
# ops:
#   exec:     run segment, reading/writing named state slots
#   gather:   all_gather IN along AXIS -> OUT          (async-capable)
#   scatter:  reduce_scatter IN along AXIS -> OUT (sum)
#   a2a:      all_to_all IN (split SPLIT, concat CONCAT) -> OUT
# async collectives carry an "id"; "wait" joins them. A collective without
# trigger/wait semantics is synchronous. Comm-op counts per fwd block:
# 5 gather + 1 scatter + 4 a2a (vs paper Table III: 3 AllGather + 6 A2A —
# delta documented in DESIGN.md §3).
# --------------------------------------------------------------------------

SCHEDULE = [
    {"op": "exec", "seg": "row_bias", "in": ["z"], "out": ["t_bias"]},
    {"op": "gather", "in": "t_bias", "out": "t_bias_f", "axis": 0,
     "id": "ag_bias"},
    {"op": "exec", "seg": "msa_row_proj", "in": ["m"], "out": ["t_qkvg"]},
    {"op": "wait", "id": "ag_bias"},
    {"op": "exec", "seg": "msa_row_core",
     "in": ["m", "t_qkvg", "t_bias_f"], "out": ["m"]},
    {"op": "a2a", "in": "m", "out": "m", "split": 1, "concat": 0},
    {"op": "exec", "seg": "msa_col", "in": ["m"], "out": ["m"]},
    {"op": "exec", "seg": "msa_trans", "in": ["m"], "out": ["m"]},
    {"op": "exec", "seg": "opm_pre", "in": ["m"], "out": ["t_a", "t_b"]},
    {"op": "gather", "in": "t_b", "out": "t_b_f", "axis": 1, "id": "ag_opm"},
    # m returns to s-shard for the NEXT block; overlaps the entire pair stack
    {"op": "a2a", "in": "m", "out": "m", "split": 0, "concat": 1,
     "id": "a2a_m"},
    {"op": "wait", "id": "ag_opm"},
    {"op": "exec", "seg": "opm_post", "in": ["z", "t_a", "t_b_f"],
     "out": ["z"]},
    {"op": "exec", "seg": "tri_out_pre", "in": ["z"],
     "out": ["t_act", "t_ta", "t_tb"]},
    {"op": "gather", "in": "t_tb", "out": "t_tb_f", "axis": 0,
     "id": "ag_tri"},
    {"op": "wait", "id": "ag_tri"},
    {"op": "exec", "seg": "tri_out_post",
     "in": ["z", "t_act", "t_ta", "t_tb_f"], "out": ["z"]},
    {"op": "exec", "seg": "tri_in_pre", "in": ["z"],
     "out": ["t_act2", "t_part"]},
    {"op": "scatter", "in": "t_part", "out": "t_part_l", "axis": 0,
     "id": "rs_tri"},
    {"op": "wait", "id": "rs_tri"},
    {"op": "exec", "seg": "tri_in_post", "in": ["z", "t_act2", "t_part_l"],
     "out": ["z"]},
    {"op": "exec", "seg": "tri_start_bias", "in": ["z"], "out": ["t_sb"]},
    {"op": "gather", "in": "t_sb", "out": "t_sb_f", "axis": 0,
     "id": "ag_sb"},
    {"op": "exec", "seg": "tri_start_proj", "in": ["z"], "out": ["t_sq"]},
    {"op": "wait", "id": "ag_sb"},
    {"op": "exec", "seg": "tri_start_core", "in": ["z", "t_sq", "t_sb_f"],
     "out": ["z"]},
    {"op": "a2a", "in": "z", "out": "z", "split": 1, "concat": 0},
    {"op": "exec", "seg": "tri_end_bias", "in": ["z"], "out": ["t_eb"]},
    {"op": "gather", "in": "t_eb", "out": "t_eb_f", "axis": 0,
     "id": "ag_eb"},
    {"op": "exec", "seg": "tri_end_proj", "in": ["z"], "out": ["t_eq"]},
    {"op": "wait", "id": "ag_eb"},
    {"op": "exec", "seg": "tri_end_core", "in": ["z", "t_eq", "t_eb_f"],
     "out": ["z"]},
    {"op": "a2a", "in": "z", "out": "z", "split": 0, "concat": 1},
    {"op": "exec", "seg": "pair_trans", "in": ["z"], "out": ["z"]},
    {"op": "wait", "id": "a2a_m"},
]


def comm_counts(schedule=SCHEDULE):
    """Measured per-block-forward collective counts — the Table III repro."""
    out = {"gather": 0, "scatter": 0, "a2a": 0}
    for op in schedule:
        if op["op"] in out:
            out[op["op"]] += 1
    return out


# --------------------------------------------------------------------------
# in-python DAP simulator (jnp collectives) — the correctness oracle the
# rust coordinator is validated against, and itself validated against
# model.evoformer_block.
# --------------------------------------------------------------------------


def shard(x, n, axis):
    return [jnp.take(x, jnp.arange(i * (x.shape[axis] // n),
                                   (i + 1) * (x.shape[axis] // n)), axis=axis)
            for i in range(n)]


def _all_gather(xs, axis):
    full = jnp.concatenate(xs, axis=axis)
    return [full for _ in xs]


def _reduce_scatter(xs, axis):
    total = sum(xs[1:], xs[0])
    return shard(total, len(xs), axis)


def _all_to_all(xs, split, concat):
    n = len(xs)
    parts = [jnp.split(x, n, axis=split) for x in xs]  # parts[src][dst]
    return [jnp.concatenate([parts[src][dst] for src in range(n)],
                            axis=concat) for dst in range(n)]


def simulate_dap_block(p_block, cfg: ModelConfig, m, z, n):
    """Run one Evoformer block under N-way DAP, emulating collectives.

    m: (s, r, d_msa), z: (r, r, d_pair) full tensors. Returns full (m', z').
    """
    state = {
        "m": shard(m, n, 0),   # s-sharded at block entry
        "z": shard(z, n, 0),   # i-sharded at block entry
    }
    pending = {}
    for op in SCHEDULE:
        kind = op["op"]
        if kind == "exec":
            fn = SEGMENTS[op["seg"]]
            outs = [fn(p_block, cfg, *[state[s][r] for s in op["in"]])
                    for r in range(n)]
            for k, slot in enumerate(op["out"]):
                state[slot] = [outs[r][k] for r in range(n)]
        elif kind == "gather":
            pending[op.get("id", "_sync")] = (
                op["out"], _all_gather(state[op["in"]], op["axis"]))
            if "id" not in op:
                slot, val = pending.pop("_sync")
                state[slot] = val
        elif kind == "scatter":
            pending[op.get("id", "_sync")] = (
                op["out"], _reduce_scatter(state[op["in"]], op["axis"]))
            if "id" not in op:
                slot, val = pending.pop("_sync")
                state[slot] = val
        elif kind == "a2a":
            res = _all_to_all(state[op["in"]], op["split"], op["concat"])
            if "id" in op:
                pending[op["id"]] = (op["out"], res)
            else:
                state[op["out"]] = res
        elif kind == "wait":
            slot, val = pending.pop(op["id"])
            state[slot] = val
        else:  # pragma: no cover
            raise ValueError(f"unknown op {kind}")
    assert not pending, f"unjoined collectives: {list(pending)}"
    m_out = jnp.concatenate(state["m"], axis=0)
    z_out = jnp.concatenate(state["z"], axis=0)
    return m_out, z_out


# --------------------------------------------------------------------------
# backward twins: for each segment S, vjp_S(p, *inputs, *cotangents) →
# (flat param-grads for the block params S touches, *input-cotangents).
# Forward is rematerialized inside (segment-level checkpointing).
# --------------------------------------------------------------------------


def make_segment_vjp(name):
    fn = SEGMENTS[name]

    def vjp_fn(p, cfg, inputs, cotangents):
        def wrapped(p_, *ins):
            return fn(p_, cfg, *ins)

        _, pullback = jax.vjp(wrapped, p, *inputs)
        grads = pullback(tuple(cotangents))
        return grads[0], grads[1:]  # (dparams pytree, dinput tuple)

    return vjp_fn
