"""Model configuration presets (mirrors rust/src/config presets 1:1).

`initial_training` / `finetune` are the exact paper Table I settings and
feed the analytic perf/memory models; `tiny` drives the test suite and
`small` the end-to-end CPU training example (the 1-core substitute for the
~100 M-param run — see DESIGN.md §7).
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    n_res: int          # N_r — residues (second MSA axis, both pair axes)
    n_seq: int          # N_s — sequences in the MSA stack
    d_msa: int = 256    # H_m
    d_pair: int = 128   # H_z
    n_heads_msa: int = 8
    n_heads_pair: int = 4
    d_head: int = 32    # per-head hidden
    d_opm: int = 32     # outer-product-mean projection dim
    n_blocks: int = 48
    transition_factor: int = 4
    msa_vocab: int = 23       # 20 aa + X + gap + mask token
    n_dist_bins: int = 64
    relpos_clip: int = 32

    @property
    def mask_token(self) -> int:
        return self.msa_vocab - 1


TINY = ModelConfig(
    name="tiny", n_res=16, n_seq=8, d_msa=32, d_pair=16,
    n_heads_msa=4, n_heads_pair=2, d_head=8, d_opm=8, n_blocks=2,
    transition_factor=2, n_dist_bins=16, relpos_clip=8,
)

SMALL = ModelConfig(
    name="small", n_res=64, n_seq=16, d_msa=64, d_pair=32,
    n_heads_msa=4, n_heads_pair=4, d_head=16, d_opm=16, n_blocks=4,
    transition_factor=4, n_dist_bins=32, relpos_clip=16,
)

# paper Table I — exact AlphaFold settings (analytic models only)
INITIAL_TRAINING = ModelConfig(name="initial_training", n_res=256, n_seq=128)
FINETUNE = ModelConfig(name="finetune", n_res=384, n_seq=512)

PRESETS = {c.name: c for c in (TINY, SMALL, INITIAL_TRAINING, FINETUNE)}


def config_dict(cfg: ModelConfig) -> dict:
    return dataclasses.asdict(cfg)
