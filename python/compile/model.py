"""L2: the FastFold Evoformer / mini-AlphaFold model in JAX.

Faithful to the paper's description of AlphaFold's trunk (Fig 1, §II–III):

  Embedding  →  N × Evoformer block  →  heads (masked-MSA + distogram)

Each Evoformer block (AlphaFold2 ordering):
  MSA stack:    row-attention (pair bias, gated) → column-attention (gated)
                → transition
  Communication: outer product mean (MSA → pair)
  Pair stack:   triangle-mult outgoing → triangle-mult incoming
                → triangle-attention starting → triangle-attention ending
                → transition

Every hot op calls the L1 Pallas kernels (``use_kernels=True``) or the
unfused reference chain (``use_kernels=False`` — the Fig 8/9/12 baseline).
The Merge-GEMM optimization of §IV.A.1 is structural here: Q,K,V and the
gate are produced by ONE projection matrix, and the triangle left/right
projections + gates by one matrix.

Params are nested dicts of jnp arrays; ``init_params`` builds them,
``param_spec``/``flatten_params`` define the canonical flatten order that
the rust runtime relies on (manifest.json).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .configs import ModelConfig
from .kernels import (
    fused_layernorm,
    gated_attention,
    outer_product_mean,
    triangle_mult,
)
from .kernels import ref as kref

# --------------------------------------------------------------------------
# primitives
# --------------------------------------------------------------------------


def layer_norm(p, x, use_kernels=True):
    if use_kernels:
        return fused_layernorm(x, p["g"], p["b"])
    return kref.naive_layernorm_twopass(x, p["g"], p["b"])


def linear(p, x):
    return jnp.einsum("...i,io->...o", x, p["w"]) + p["b"]


def linear_nobias(p, x):
    return jnp.einsum("...i,io->...o", x, p["w"])


def _attention(q, k, v, gate, bias, use_kernels):
    """(B,H,Q,D) gated attention, fused or reference path."""
    if use_kernels:
        return gated_attention(q, k, v, gate, bias)
    d = q.shape[-1]
    s = jnp.einsum("bhqd,bhkd->bhqk", q, k)
    p = kref.naive_softmax_unfused(s, bias=bias, scale=1.0 / np.sqrt(d))
    ctx = jnp.einsum("bhqk,bhkd->bhqd", p, v)
    return jax.nn.sigmoid(gate) * ctx


def _split_heads(x, h):
    """(..., L, H*D) -> (..., H, L, D)"""
    *lead, l, hd = x.shape
    x = x.reshape(*lead, l, h, hd // h)
    return jnp.moveaxis(x, -2, -3)


def _merge_heads(x):
    """(..., H, L, D) -> (..., L, H*D)"""
    x = jnp.moveaxis(x, -3, -2)
    *lead, l, h, d = x.shape
    return x.reshape(*lead, l, h * d)


# --------------------------------------------------------------------------
# Evoformer sub-modules. Shapes: m (s, r, d_msa); z (r, r, d_pair).
# --------------------------------------------------------------------------


def pair_bias(p, z, use_kernels=True):
    """Project LayerNormed pair rep to per-head attention bias (h, r, r)."""
    act = layer_norm(p["ln"], z, use_kernels)
    return jnp.transpose(linear_nobias(p["proj"], act), (2, 0, 1))


def msa_row_attention(p, m, bias, h, use_kernels=True):
    """MSA row-wise gated self-attention with pair bias (batch axis = s)."""
    act = layer_norm(p["ln"], m, use_kernels)
    qkvg = linear_nobias(p["qkvg"], act)  # merge-GEMM: (s, r, 4*h*d)
    q, k, v, g = jnp.split(qkvg, 4, axis=-1)
    q, k, v, g = (_split_heads(t, h) for t in (q, k, v, g))
    o = _attention(q, k, v, g, bias, use_kernels)
    return linear(p["out"], _merge_heads(o))


def msa_col_attention(p, m, h, use_kernels=True):
    """MSA column-wise gated self-attention (no bias; batch axis = r)."""
    act = layer_norm(p["ln"], m, use_kernels)
    act_t = jnp.swapaxes(act, 0, 1)  # (r, s, d)
    qkvg = linear_nobias(p["qkvg"], act_t)
    q, k, v, g = jnp.split(qkvg, 4, axis=-1)
    q, k, v, g = (_split_heads(t, h) for t in (q, k, v, g))
    o = _attention(q, k, v, g, None, use_kernels)
    return jnp.swapaxes(linear(p["out"], _merge_heads(o)), 0, 1)


def transition(p, x, use_kernels=True):
    """2-layer MLP (paper: Transition = 2 MLP layers, ×4 widening)."""
    act = layer_norm(p["ln"], x, use_kernels)
    return linear(p["l2"], jax.nn.relu(linear(p["l1"], act)))


def outer_product_mean_module(p, m, use_kernels=True):
    """MSA → pair communication: einsum(bsid,bsje->bijde) mean over s."""
    act = layer_norm(p["ln"], m, use_kernels)
    ab = linear_nobias(p["ab"], act)  # merge-GEMM: (s, r, 2*d_opm)
    a, b = jnp.split(ab, 2, axis=-1)
    if use_kernels:
        o = outer_product_mean(a, b)
    else:
        o = kref.outer_product_mean_ref(a, b)
    return linear(p["out"], o)


def triangle_mult_module(p, z, outgoing, use_kernels=True):
    """Triangular multiplicative update (Fig 4), merge-GEMM proj+gates."""
    act = layer_norm(p["ln"], z, use_kernels)
    pg = linear_nobias(p["pg"], act)  # (r, r, 4*c): a, b, gate_a, gate_b
    a, b, ga, gb = jnp.split(pg, 4, axis=-1)
    a = a * jax.nn.sigmoid(ga)
    b = b * jax.nn.sigmoid(gb)
    if use_kernels:
        o = triangle_mult(a, b, outgoing)
    else:
        o = kref.triangle_mult_ref(a, b, outgoing)
    o = layer_norm(p["ln_out"], o, use_kernels)
    g = jax.nn.sigmoid(linear_nobias(p["gate"], act))
    return g * linear(p["out"], o)


def triangle_attention_module(p, z, bias, starting, h, use_kernels=True):
    """Triangle self-attention (start/end node). Ending-node attention is
    starting-node attention on the transposed pair rep (OpenFold trick)."""
    zt = z if starting else jnp.swapaxes(z, 0, 1)
    act = layer_norm(p["ln"], zt, use_kernels)
    qkvg = linear_nobias(p["qkvg"], act)
    q, k, v, g = jnp.split(qkvg, 4, axis=-1)
    q, k, v, g = (_split_heads(t, h) for t in (q, k, v, g))
    o = _attention(q, k, v, g, bias, use_kernels)
    o = linear(p["out"], _merge_heads(o))
    return o if starting else jnp.swapaxes(o, 0, 1)


def tri_attn_bias(p, z, starting, use_kernels=True):
    """Bias for triangle attention: (h, r, r) from the (maybe transposed) z."""
    zt = z if starting else jnp.swapaxes(z, 0, 1)
    act = layer_norm(p["ln"], zt, use_kernels)
    return jnp.transpose(linear_nobias(p["proj"], act), (2, 0, 1))


# --------------------------------------------------------------------------
# Evoformer block + full model
# --------------------------------------------------------------------------


def evoformer_block(p, m, z, cfg: ModelConfig, use_kernels=True):
    hm, hp = cfg.n_heads_msa, cfg.n_heads_pair
    bias = pair_bias(p["row_bias"], z, use_kernels)
    m = m + msa_row_attention(p["row_attn"], m, bias, hm, use_kernels)
    m = m + msa_col_attention(p["col_attn"], m, hm, use_kernels)
    m = m + transition(p["msa_trans"], m, use_kernels)
    z = z + outer_product_mean_module(p["opm"], m, use_kernels)
    z = z + triangle_mult_module(p["tri_out"], z, True, use_kernels)
    z = z + triangle_mult_module(p["tri_in"], z, False, use_kernels)
    b_start = tri_attn_bias(p["start_bias"], z, True, use_kernels)
    z = z + triangle_attention_module(p["tri_start"], z, b_start, True, hp, use_kernels)
    b_end = tri_attn_bias(p["end_bias"], z, False, use_kernels)
    z = z + triangle_attention_module(p["tri_end"], z, b_end, False, hp, use_kernels)
    z = z + transition(p["pair_trans"], z, use_kernels)
    return m, z


def embedder(p, cfg: ModelConfig, msa_tokens, use_kernels=True):
    """Input embedding (paper Fig 1 'Embedding'):

    msa_tokens: (s, r) int32 (already masked for the BERT-style objective).
    target = first MSA row. Pair init = outer sum of target projections +
    clipped relative-position embedding.
    """
    msa_feat = jax.nn.one_hot(msa_tokens, cfg.msa_vocab, dtype=jnp.float32)
    target_feat = msa_feat[0]  # (r, vocab)
    m = linear(p["msa_proj"], msa_feat) + linear(p["target_m"], target_feat)[None]
    zi = linear(p["target_zi"], target_feat)
    zj = linear(p["target_zj"], target_feat)
    z = zi[:, None, :] + zj[None, :, :]
    # relative position: clip(i-j, ±clip) one-hot → linear
    pos = jnp.arange(cfg.n_res)
    rel = jnp.clip(pos[:, None] - pos[None, :], -cfg.relpos_clip, cfg.relpos_clip)
    rel_oh = jax.nn.one_hot(rel + cfg.relpos_clip, 2 * cfg.relpos_clip + 1,
                            dtype=jnp.float32)
    z = z + linear(p["relpos"], rel_oh)
    return m, z


def heads(p, m, z, use_kernels=True):
    """Masked-MSA logits (s,r,vocab) and symmetrized distogram logits
    (r,r,bins)."""
    msa_logits = linear(p["masked_msa"], layer_norm(p["ln_m"], m, use_kernels))
    zs = z + jnp.swapaxes(z, 0, 1)  # symmetrize
    dist_logits = linear(p["distogram"], layer_norm(p["ln_z"], zs, use_kernels))
    return msa_logits, dist_logits


def forward(params, cfg: ModelConfig, msa_tokens, use_kernels=True):
    m, z = embedder(params["embedder"], cfg, msa_tokens, use_kernels)
    for bp in params["blocks"]:
        m, z = evoformer_block(bp, m, z, cfg, use_kernels)
    return heads(params["heads"], m, z, use_kernels)


def _xent(logits, labels, mask):
    logp = jax.nn.log_softmax(logits, axis=-1)
    ll = jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    return -jnp.sum(ll * mask) / (jnp.sum(mask) + 1e-8)


def trunk_losses(msa_logits, dist_logits, batch):
    """BERT-style masked-MSA loss + 0.3-weighted distogram loss — the ONE
    definition of the training objective, shared by ``loss_fn`` (the
    monolithic grad_step export) and ``loss_from_heads`` (the hybrid
    trainer's heads/loss VJP export) so the two paths cannot diverge."""
    msa_loss = _xent(msa_logits, batch["msa_labels"], batch["msa_mask"])
    dist_loss = _xent(
        dist_logits, batch["dist_bins"],
        jnp.ones_like(batch["dist_bins"], jnp.float32),
    )
    return msa_loss + 0.3 * dist_loss


def loss_from_heads(hp, m, z, batch, use_kernels=True):
    """Trunk losses given head params and the trunk outputs (m, z) — the
    tail the hybrid DP×DAP trainer differentiates at the trunk boundary
    (exported as ``loss_head_grad``)."""
    msa_logits, dist_logits = heads(hp, m, z, use_kernels)
    return trunk_losses(msa_logits, dist_logits, batch)


def loss_fn(params, cfg: ModelConfig, batch, use_kernels=True):
    """Full-model training loss (the trunk losses the paper's training
    pipeline optimizes; structure-module FAPE is out of the Evoformer
    scope this paper targets)."""
    msa_logits, dist_logits = forward(
        params, cfg, batch["msa_tokens"], use_kernels
    )
    return trunk_losses(msa_logits, dist_logits, batch)


# --------------------------------------------------------------------------
# init + canonical flatten order
# --------------------------------------------------------------------------


def _lin_init(key, d_in, d_out, scale=1.0):
    w = jax.random.normal(key, (d_in, d_out), jnp.float32)
    return {"w": w * (scale / np.sqrt(d_in)), "b": jnp.zeros((d_out,))}


def _lin_nb_init(key, d_in, d_out, scale=1.0):
    w = jax.random.normal(key, (d_in, d_out), jnp.float32)
    return {"w": w * (scale / np.sqrt(d_in))}


def _ln_init(d):
    return {"g": jnp.ones((d,)), "b": jnp.zeros((d,))}


def _attn_init(key, d_model, heads, d_head, d_bias=None):
    ks = jax.random.split(key, 3)
    return {
        "ln": _ln_init(d_model),
        "qkvg": _lin_nb_init(ks[0], d_model, 4 * heads * d_head),
        "out": _lin_init(ks[1], heads * d_head, d_model, scale=0.5),
    }


def _bias_init(key, d_pair, heads):
    return {"ln": _ln_init(d_pair), "proj": _lin_nb_init(key, d_pair, heads)}


def _trans_init(key, d, factor):
    k1, k2 = jax.random.split(key)
    return {
        "ln": _ln_init(d),
        "l1": _lin_init(k1, d, factor * d),
        "l2": _lin_init(k2, factor * d, d, scale=0.5),
    }


def _tri_mult_init(key, d_pair):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "ln": _ln_init(d_pair),
        "pg": _lin_nb_init(k1, d_pair, 4 * d_pair),
        "ln_out": _ln_init(d_pair),
        "gate": _lin_nb_init(k2, d_pair, d_pair),
        "out": _lin_init(k3, d_pair, d_pair, scale=0.5),
    }


def _opm_init(key, d_msa, d_opm, d_pair):
    k1, k2 = jax.random.split(key)
    return {
        "ln": _ln_init(d_msa),
        "ab": _lin_nb_init(k1, d_msa, 2 * d_opm),
        "out": _lin_init(k2, d_opm * d_opm, d_pair, scale=0.5),
    }


def init_block(key, cfg: ModelConfig):
    ks = jax.random.split(key, 11)
    return {
        "row_bias": _bias_init(ks[0], cfg.d_pair, cfg.n_heads_msa),
        "row_attn": _attn_init(ks[1], cfg.d_msa, cfg.n_heads_msa, cfg.d_head),
        "col_attn": _attn_init(ks[2], cfg.d_msa, cfg.n_heads_msa, cfg.d_head),
        "msa_trans": _trans_init(ks[3], cfg.d_msa, cfg.transition_factor),
        "opm": _opm_init(ks[4], cfg.d_msa, cfg.d_opm, cfg.d_pair),
        "tri_out": _tri_mult_init(ks[5], cfg.d_pair),
        "tri_in": _tri_mult_init(ks[6], cfg.d_pair),
        "start_bias": _bias_init(ks[7], cfg.d_pair, cfg.n_heads_pair),
        "tri_start": _attn_init(ks[8], cfg.d_pair, cfg.n_heads_pair, cfg.d_head),
        "end_bias": _bias_init(ks[9], cfg.d_pair, cfg.n_heads_pair),
        "tri_end": _attn_init(ks[10], cfg.d_pair, cfg.n_heads_pair, cfg.d_head),
        "pair_trans": _trans_init(
            jax.random.fold_in(key, 99), cfg.d_pair, cfg.transition_factor
        ),
    }


def init_params(key, cfg: ModelConfig):
    ke, kh, *kb = jax.random.split(key, 2 + cfg.n_blocks)
    kes = jax.random.split(ke, 6)
    nrel = 2 * cfg.relpos_clip + 1
    embed = {
        "msa_proj": _lin_init(kes[0], cfg.msa_vocab, cfg.d_msa),
        "target_m": _lin_init(kes[1], cfg.msa_vocab, cfg.d_msa),
        "target_zi": _lin_init(kes[2], cfg.msa_vocab, cfg.d_pair),
        "target_zj": _lin_init(kes[3], cfg.msa_vocab, cfg.d_pair),
        "relpos": _lin_init(kes[4], nrel, cfg.d_pair),
    }
    khs = jax.random.split(kh, 2)
    head = {
        "ln_m": _ln_init(cfg.d_msa),
        "masked_msa": _lin_init(khs[0], cfg.d_msa, cfg.msa_vocab),
        "ln_z": _ln_init(cfg.d_pair),
        "distogram": _lin_init(khs[1], cfg.d_pair, cfg.n_dist_bins),
    }
    return {
        "embedder": embed,
        "blocks": [init_block(k, cfg) for k in kb],
        "heads": head,
    }


def flatten_params(params):
    """Canonical (path, leaf) list — the order manifest.json / params.bin
    use. jax's own tree flatten order (sorted dict keys) is the contract."""
    leaves = []

    def walk(prefix, node):
        if isinstance(node, dict):
            for k in sorted(node.keys()):
                walk(f"{prefix}/{k}" if prefix else k, node[k])
        elif isinstance(node, list):
            for i, v in enumerate(node):
                walk(f"{prefix}/{i}", v)
        else:
            leaves.append((prefix, node))

    walk("", params)
    return leaves


def count_params(params):
    return int(sum(np.prod(leaf.shape) for _, leaf in flatten_params(params)))


# --------------------------------------------------------------------------
# synthetic data (mirrors rust/src/train/data.rs — same recipe, both sides
# produce structurally identical batches; seeds differ)
# --------------------------------------------------------------------------


def make_synthetic_batch(key, cfg: ModelConfig, mask_frac=0.15):
    """Synthetic co-evolution batch: a random 'ancestral' sequence, MSA rows
    are noisy copies (mutations), distance bins from a toy 1-D chain fold so
    the distogram target correlates with |i-j| and sequence content."""
    k1, k2, k3, k4 = jax.random.split(key, 4)
    aa = 20
    ancestor = jax.random.randint(k1, (cfg.n_res,), 0, aa)
    mut = jax.random.bernoulli(k2, 0.15, (cfg.n_seq, cfg.n_res))
    rand_aa = jax.random.randint(k3, (cfg.n_seq, cfg.n_res), 0, aa)
    msa = jnp.where(mut, rand_aa, ancestor[None, :])
    msa = msa.at[0].set(ancestor)  # row 0 is the target sequence
    # toy fold: positions on a noisy helix; distance -> bins
    t = jnp.arange(cfg.n_res, dtype=jnp.float32)
    coords = jnp.stack(
        [jnp.cos(t * 0.6) * 4, jnp.sin(t * 0.6) * 4, t * 1.5], axis=-1
    )
    coords = coords + 0.3 * jax.random.normal(k4, coords.shape)
    d = jnp.linalg.norm(coords[:, None] - coords[None, :], axis=-1)
    dist_bins = jnp.clip(
        (d / (d.max() / cfg.n_dist_bins)).astype(jnp.int32),
        0, cfg.n_dist_bins - 1,
    )
    # BERT masking
    kmask = jax.random.fold_in(key, 7)
    mask = jax.random.bernoulli(kmask, mask_frac, (cfg.n_seq, cfg.n_res))
    tokens = jnp.where(mask, cfg.mask_token, msa)
    return {
        "msa_tokens": tokens.astype(jnp.int32),
        "msa_labels": msa.astype(jnp.int32),
        "msa_mask": mask.astype(jnp.float32),
        "dist_bins": dist_bins.astype(jnp.int32),
    }


BATCH_KEYS = ["msa_tokens", "msa_labels", "msa_mask", "dist_bins"]
