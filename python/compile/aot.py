"""AOT exporter: lower every L2 computation to HLO *text* + manifest.json.

HLO text (NOT serialized HloModuleProto) is the interchange format: jax
≥0.5 emits protos with 64-bit instruction ids that the xla crate's
xla_extension 0.5.1 rejects; the text parser reassigns ids (see
/opt/xla-example/README.md). Lowering uses ``return_tuple=True``; the rust
side unwraps the tuple.

Exported artifact families (→ DESIGN.md §5):
  model_fwd / embed / heads / block_fwd     single-device inference pieces
  grad_step / adam_update / train_step      training (DP splits grad+adam
                                            around the host all-reduce)
  loss_head_grad / embed_bwd                hybrid DP×DAP trunk-boundary
                                            VJPs (heads+loss and embedder)
  dap{N}/<segment>[, _bwd]                  DAP coordinator executables
  fig8_* / fig9_*                           kernel microbench pairs
All artifact input/output names+shapes+dtypes, the canonical parameter
flatten order, initial params binary, and the DAP schedule are recorded in
artifacts/manifest.json — the single contract the rust runtime consumes.

Python runs ONCE (`make artifacts`); nothing here is on the request path.
"""

from __future__ import annotations

import argparse
import json
import os
import sys

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import dap, model
from .configs import PRESETS, config_dict
from .kernels import fused_layernorm, fused_softmax2d
from .kernels import ref as kref

# ---------------------------------------------------------------- lowering


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _path_str(path) -> str:
    out = []
    for p in path:
        if hasattr(p, "key"):
            out.append(str(p.key))
        elif hasattr(p, "idx"):
            out.append(str(p.idx))
        else:
            out.append(str(p))
    return "/".join(out)


def _spec_of(x):
    return {"shape": list(x.shape), "dtype": str(x.dtype)}


class Exporter:
    def __init__(self, out_dir):
        self.out_dir = out_dir
        self.manifest = {"artifacts": {}, "configs": {}, "params": {},
                         "batch_spec": {}, "dap_schedule": dap.SCHEDULE}
        os.makedirs(out_dir, exist_ok=True)
        # incremental export: merge onto an existing manifest so partial
        # re-exports (--only / --configs) do not drop other entries
        prev = os.path.join(out_dir, "manifest.json")
        if os.path.exists(prev):
            with open(prev) as f:
                old = json.load(f)
            for key in ("artifacts", "configs", "params", "batch_spec"):
                merged = old.get(key, {})
                merged.update(self.manifest[key])
                self.manifest[key] = merged

    def export(self, name, fn, example_args):
        """Lower fn(*example_args) (arbitrary pytrees of ShapeDtypeStructs)
        to <name>.hlo.txt; record flat input/output specs in the manifest."""
        flat, treedef = jax.tree_util.tree_flatten(example_args)

        def flat_fn(*leaves):
            args = jax.tree_util.tree_unflatten(treedef, leaves)
            out = fn(*args)
            return tuple(jax.tree_util.tree_flatten(out)[0])

        path_leaves = jax.tree_util.tree_flatten_with_path(example_args)[0]
        in_specs = [
            {"name": _path_str(p), **_spec_of(l)} for p, l in path_leaves
        ]
        out_shape = jax.eval_shape(fn, *example_args)
        out_leaves = jax.tree_util.tree_flatten_with_path(out_shape)[0]
        out_specs = [
            {"name": _path_str(p), **_spec_of(l)} for p, l in out_leaves
        ]
        specs = [jax.ShapeDtypeStruct(l.shape, l.dtype) for l in flat]
        # keep_unused: segments receive the FULL block-param leaf list
        # (uniform calling convention for the rust coordinator) even when
        # a segment touches only a few leaves
        text = to_hlo_text(jax.jit(flat_fn, keep_unused=True).lower(*specs))
        fname = f"{name}.hlo.txt"
        fpath = os.path.join(self.out_dir, fname)
        os.makedirs(os.path.dirname(fpath), exist_ok=True)
        with open(fpath, "w") as f:
            f.write(text)
        self.manifest["artifacts"][name] = {
            "file": fname, "inputs": in_specs, "outputs": out_specs,
        }
        print(f"  exported {name}  ({len(in_specs)} in, {len(out_specs)} out,"
              f" {len(text) // 1024} KiB)")

    def save_manifest(self):
        with open(os.path.join(self.out_dir, "manifest.json"), "w") as f:
            json.dump(self.manifest, f, indent=1)


# ------------------------------------------------------------ adam optimizer


def adam_update(params, grads, m, v, step, lr, b1=0.9, b2=0.999, eps=1e-8):
    """Adam on a params pytree; step is the 1-based f32 step counter."""
    def upd(p, g, m_, v_):
        m2 = b1 * m_ + (1 - b1) * g
        v2 = b2 * v_ + (1 - b2) * jnp.square(g)
        mhat = m2 / (1 - b1 ** step)
        vhat = v2 / (1 - b2 ** step)
        return p - lr * mhat / (jnp.sqrt(vhat) + eps), m2, v2

    flat_p, td = jax.tree_util.tree_flatten(params)
    flat_g = jax.tree_util.tree_flatten(grads)[0]
    flat_m = jax.tree_util.tree_flatten(m)[0]
    flat_v = jax.tree_util.tree_flatten(v)[0]
    out = [upd(p, g, m_, v_) for p, g, m_, v_ in
           zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree_util.tree_unflatten(td, [o[0] for o in out])
    new_m = jax.tree_util.tree_unflatten(td, [o[1] for o in out])
    new_v = jax.tree_util.tree_unflatten(td, [o[2] for o in out])
    return new_p, new_m, new_v


# ------------------------------------------------------------ batch specs


def batch_spec(cfg):
    return {
        "msa_tokens": jax.ShapeDtypeStruct((cfg.n_seq, cfg.n_res), jnp.int32),
        "msa_labels": jax.ShapeDtypeStruct((cfg.n_seq, cfg.n_res), jnp.int32),
        "msa_mask": jax.ShapeDtypeStruct((cfg.n_seq, cfg.n_res), jnp.float32),
        "dist_bins": jax.ShapeDtypeStruct((cfg.n_res, cfg.n_res), jnp.int32),
    }


def params_spec(cfg):
    return jax.eval_shape(
        lambda: model.init_params(jax.random.PRNGKey(0), cfg)
    )


def _f32_like(tree):
    return jax.tree_util.tree_map(
        lambda l: jax.ShapeDtypeStruct(l.shape, jnp.float32), tree
    )


# ------------------------------------------------------------ export drivers


def export_core(ex: Exporter, cfg, train=True):
    """Single-device model + training artifacts for one config preset."""
    name = cfg.name
    pspec = params_spec(cfg)
    bspec = batch_spec(cfg)
    tok = bspec["msa_tokens"]
    scalar = jax.ShapeDtypeStruct((), jnp.float32)
    ex.manifest["configs"][name] = config_dict(cfg)
    ex.manifest["batch_spec"][name] = {
        k: _spec_of(v) for k, v in bspec.items()
    }

    ex.export(f"{name}/model_fwd",
              lambda p, t: model.forward(p, cfg, t), (pspec, tok))
    ex.export(f"{name}/model_fwd_naive",
              lambda p, t: model.forward(p, cfg, t, use_kernels=False),
              (pspec, tok))
    ex.export(f"{name}/embed",
              lambda p, t: model.embedder(p, cfg, t),
              (pspec["embedder"], tok))
    m_spec = jax.ShapeDtypeStruct((cfg.n_seq, cfg.n_res, cfg.d_msa),
                                  jnp.float32)
    z_spec = jax.ShapeDtypeStruct((cfg.n_res, cfg.n_res, cfg.d_pair),
                                  jnp.float32)
    ex.export(f"{name}/heads",
              lambda p, m, z: model.heads(p, m, z),
              (pspec["heads"], m_spec, z_spec))
    ex.export(f"{name}/block_fwd",
              lambda p, m, z: model.evoformer_block(p, m, z, cfg),
              (pspec["blocks"][0], m_spec, z_spec))
    ex.export(f"{name}/block_fwd_naive",
              lambda p, m, z: model.evoformer_block(p, m, z, cfg,
                                                    use_kernels=False),
              (pspec["blocks"][0], m_spec, z_spec))

    def block_grad(p, m, z, ct_m, ct_z):
        # reference VJP of one block: validates the rust DAP backward tape
        def f(p_, m_, z_):
            return model.evoformer_block(p_, m_, z_, cfg)

        _, pullback = jax.vjp(f, p, m, z)
        dp, dm, dz = pullback((ct_m, ct_z))
        return dp, dm, dz

    ex.export(f"{name}/block_grad", block_grad,
              (pspec["blocks"][0], m_spec, z_spec, m_spec, z_spec))
    if train:
        ex.export(f"{name}/grad_step",
                  lambda p, b: jax.value_and_grad(
                      lambda p_: model.loss_fn(p_, cfg, b))(p),
                  (pspec, bspec))
        ex.export(f"{name}/adam_update", adam_update,
                  (pspec, _f32_like(pspec), _f32_like(pspec),
                   _f32_like(pspec), scalar, scalar))

        def train_step(p, m, v, step, lr, b):
            loss, g = jax.value_and_grad(
                lambda p_: model.loss_fn(p_, cfg, b))(p)
            p2, m2, v2 = adam_update(p, g, m, v, step, lr)
            return loss, p2, m2, v2

        ex.export(f"{name}/train_step", train_step,
                  (pspec, _f32_like(pspec), _f32_like(pspec), scalar,
                   scalar, bspec))

        # hybrid DP×DAP training boundary VJPs: the rust trainer runs the
        # trunk through the DAP coordinator + tape; these close the loop
        # at the trunk edges — (heads + losses) w.r.t. (head params, m, z)
        # and the embedder w.r.t. its params given (d_m, d_z). The loss
        # itself is model.trunk_losses, shared with loss_fn/grad_step.
        def loss_head_grad(hp, m, z, b):
            loss, pull = jax.vjp(
                lambda hp_, m_, z_: model.loss_from_heads(hp_, m_, z_, b),
                hp, m, z)
            dhp, dm, dz = pull(jnp.ones((), jnp.float32))
            return loss, dhp, dm, dz

        ex.export(f"{name}/loss_head_grad", loss_head_grad,
                  (pspec["heads"], m_spec, z_spec, bspec))

        def embed_bwd(ep, t, dm, dz):
            _, pull = jax.vjp(
                lambda ep_: model.embedder(ep_, cfg, t), ep)
            (dep,) = pull((dm, dz))
            return dep

        ex.export(f"{name}/embed_bwd", embed_bwd,
                  (pspec["embedder"], tok, m_spec, z_spec))

    # initial params binary (canonical jax tree_flatten order)
    params = model.init_params(jax.random.PRNGKey(42), cfg)
    leaves = jax.tree_util.tree_flatten_with_path(params)[0]
    offset = 0
    entries = []
    with open(os.path.join(ex.out_dir, f"{name}_params.bin"), "wb") as f:
        for path, leaf in leaves:
            arr = np.asarray(leaf, np.float32)
            f.write(arr.tobytes())
            entries.append({"name": _path_str(path),
                            "shape": list(arr.shape), "offset": offset})
            offset += arr.size
    ex.manifest["params"][name] = {
        "file": f"{name}_params.bin",
        "total": offset, "leaves": entries,
        "count": model.count_params(params),
    }


# segment input slot → shape builder, given cfg and dap size n
def _seg_specs(cfg, n):
    s, r = cfg.n_seq, cfg.n_res
    sl, rl = s // n, r // n
    dm, dz = cfg.d_msa, cfg.d_pair
    hm, hp, dh, do = (cfg.n_heads_msa, cfg.n_heads_pair, cfg.d_head,
                      cfg.d_opm)
    f = lambda *sh: jax.ShapeDtypeStruct(sh, jnp.float32)
    return {
        "row_bias": [f(rl, r, dz)],
        "msa_row_proj": [f(sl, r, dm)],
        "msa_row_core": [f(sl, r, dm), f(sl, r, 4 * hm * dh), f(r, r, hm)],
        "msa_col": [f(s, rl, dm)],
        "msa_trans": [f(s, rl, dm)],
        "opm_pre": [f(s, rl, dm)],
        "opm_post": [f(rl, r, dz), f(s, rl, do), f(s, r, do)],
        "tri_out_pre": [f(rl, r, dz)],
        "tri_out_post": [f(rl, r, dz), f(rl, r, dz), f(rl, r, dz),
                         f(r, r, dz)],
        "tri_in_pre": [f(rl, r, dz)],
        "tri_in_post": [f(rl, r, dz), f(rl, r, dz), f(rl, r, dz)],
        "tri_start_bias": [f(rl, r, dz)],
        "tri_start_proj": [f(rl, r, dz)],
        "tri_start_core": [f(rl, r, dz), f(rl, r, 4 * hp * dh), f(r, r, hp)],
        "tri_end_bias": [f(r, rl, dz)],
        "tri_end_proj": [f(r, rl, dz)],
        "tri_end_core": [f(r, rl, dz), f(rl, r, 4 * hp * dh), f(r, r, hp)],
        "pair_trans": [f(rl, r, dz)],
    }


def export_dap(ex: Exporter, cfg, n, backward=True):
    """All DAP segment executables (fwd + vjp) for dap_size n."""
    pspec = params_spec(cfg)["blocks"][0]
    specs = _seg_specs(cfg, n)
    for seg_name, in_specs in specs.items():
        fn = dap.SEGMENTS[seg_name]
        ex.export(f"{cfg.name}/dap{n}/{seg_name}",
                  lambda p, *t, _fn=fn: _fn(p, cfg, *t),
                  (pspec, *in_specs))
        if backward:
            out_shape = jax.eval_shape(
                lambda p, *t, _fn=fn: _fn(p, cfg, *t), pspec, *in_specs)
            ct_specs = tuple(jax.tree_util.tree_flatten(out_shape)[0])
            vjp_fn = dap.make_segment_vjp(seg_name)
            ex.export(
                f"{cfg.name}/dap{n}/{seg_name}_bwd",
                lambda p, ins, cts, _v=vjp_fn: _v(p, cfg, ins, cts),
                (pspec, tuple(in_specs), ct_specs),
            )


def export_kernel_benches(ex: Exporter):
    """Fig 8 / Fig 9 microbench pairs: fused kernel vs deliberately-unfused
    baseline vs (LN only) an 'apex-like' single-fusion baseline — identical
    math, same backend, so the delta isolates kernel structure."""
    f32 = jnp.float32
    for rows, cols in [(1024, 32), (1024, 64), (1024, 128), (1024, 256),
                       (4096, 64), (4096, 128)]:
        x = jax.ShapeDtypeStruct((rows, cols), f32)
        # §Perf-L1 iteration 1: block_rows=1024 (vs default 128) — fewer,
        # fatter grid programs amortize the interpret-mode grid loop and
        # map to better VMEM streaming on TPU (rows*cols*4B <= 1 MiB/blk).
        ex.export(f"bench/fig8_fused_{rows}x{cols}",
                  lambda x: fused_softmax2d(x, 0.125, block_rows=1024), (x,))
        ex.export(f"bench/fig8_naive_{rows}x{cols}",
                  lambda x: kref.naive_softmax_unfused(x, scale=0.125), (x,))
        g = jax.ShapeDtypeStruct((cols,), f32)
        ex.export(f"bench/fig9_fused_{rows}x{cols}",
                  lambda x, g, b: fused_layernorm(x, g, b), (x, g, g))
        ex.export(f"bench/fig9_naive_{rows}x{cols}",
                  lambda x, g, b: kref.naive_layernorm_twopass(x, g, b),
                  (x, g, g))
        ex.export(f"bench/fig9_apexlike_{rows}x{cols}",
                  lambda x, g, b: kref.layernorm_ref(x, g, b), (x, g, g))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--configs", default="tiny,small")
    ap.add_argument("--dap", default="1,2,4")
    ap.add_argument("--only", default=None,
                    help="comma list: core,dap,bench (default all)")
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else {"core", "dap", "bench"}
    ex = Exporter(args.out)
    for cname in args.configs.split(","):
        cfg = PRESETS[cname]
        if "core" in only:
            print(f"[aot] core artifacts for '{cname}'")
            export_core(ex, cfg)
        if "dap" in only:
            for n in (int(x) for x in args.dap.split(",")):
                if cfg.n_seq % n or cfg.n_res % n:
                    print(f"  skip dap{n} for {cname} (indivisible)")
                    continue
                print(f"[aot] dap{n} segments for '{cname}' "
                      f"(bwd={cname == 'tiny'})")
                export_dap(ex, cfg, n, backward=(cname == "tiny"))
    if "bench" in only:
        print("[aot] kernel bench artifacts")
        export_kernel_benches(ex)
    ex.save_manifest()
    print(f"[aot] manifest with {len(ex.manifest['artifacts'])} artifacts")


if __name__ == "__main__":
    sys.exit(main())
