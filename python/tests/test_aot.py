"""AOT exporter contract tests: manifest consistency, HLO text parses back
through xla_client, params binary layout, adam semantics."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, configs, model

jax.config.update("jax_platform_name", "cpu")

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def test_to_hlo_text_structure():
    """The HLO text we emit must be well-formed entry-computation text with
    a tuple root — the contract the rust loader
    (`HloModuleProto::from_text_file`) relies on; the actual load+execute
    round-trip is covered by rust `integration_runtime`."""

    def fn(x, y):
        return (jnp.matmul(x, y) + 1.0,)

    spec = jax.ShapeDtypeStruct((2, 2), jnp.float32)
    text = aot.to_hlo_text(jax.jit(fn, keep_unused=True).lower(spec, spec))
    assert "ENTRY" in text
    assert "f32[2,2]" in text
    # return_tuple=True: the root instruction is a tuple
    assert "ROOT tuple" in text
    # parameters preserved in order
    assert text.count("parameter(") >= 2


def test_adam_update_semantics():
    params = {"w": jnp.array([1.0, 2.0])}
    grads = {"w": jnp.array([0.1, -0.2])}
    zeros = {"w": jnp.zeros(2)}
    p2, m2, v2 = aot.adam_update(params, grads, zeros, zeros,
                                 jnp.asarray(1.0), jnp.asarray(0.1))
    # step 1 with zero state: mhat = g, vhat = g² → p - lr·sign-ish(g)
    want = params["w"] - 0.1 * grads["w"] / (jnp.abs(grads["w"]) + 1e-8)
    np.testing.assert_allclose(np.asarray(p2["w"]), np.asarray(want),
                               rtol=1e-4)
    assert float(m2["w"][0]) == pytest.approx(0.01, rel=1e-5)
    assert float(v2["w"][0]) == pytest.approx(1e-5, rel=1e-4)


def test_adam_descends_on_quadratic():
    p = {"w": jnp.array([5.0])}
    m = {"w": jnp.zeros(1)}
    v = {"w": jnp.zeros(1)}
    for step in range(1, 200):
        g = {"w": 2.0 * p["w"]}
        p, m, v = aot.adam_update(p, g, m, v, jnp.asarray(float(step)),
                                  jnp.asarray(0.1))
    assert abs(float(p["w"][0])) < 0.5


def test_seg_specs_cover_all_segments():
    from compile import dap

    for n in (1, 2, 4):
        specs = aot._seg_specs(configs.TINY, n)
        assert set(specs) == set(dap.SEGMENTS)
        # every schedule exec references an exported segment
        for op in dap.SCHEDULE:
            if op["op"] == "exec":
                assert op["seg"] in specs


def test_seg_specs_shapes_match_segment_eval():
    """Exported input shapes must be consumable by the segment functions
    (shape errors here would break the rust coordinator)."""
    from compile import dap

    cfg = configs.TINY
    params = model.init_params(jax.random.PRNGKey(0), cfg)["blocks"][0]
    for n in (1, 2):
        for name, specs in aot._seg_specs(cfg, n).items():
            ins = tuple(jnp.zeros(s.shape, s.dtype) for s in specs)
            outs = dap.SEGMENTS[name](params, cfg, *ins)
            assert all(np.isfinite(np.asarray(o)).all() for o in outs), name


@pytest.mark.skipif(
    not os.path.exists(os.path.join(ART, "manifest.json")),
    reason="artifacts not built (run `make artifacts`)",
)
class TestManifestOnDisk:
    @pytest.fixture(scope="class")
    def manifest(self):
        with open(os.path.join(ART, "manifest.json")) as f:
            return json.load(f)

    def test_artifact_files_exist(self, manifest):
        for name, spec in manifest["artifacts"].items():
            path = os.path.join(ART, spec["file"])
            assert os.path.exists(path), name
            assert os.path.getsize(path) > 0, name

    def test_params_bin_layout(self, manifest):
        for preset, ps in manifest["params"].items():
            path = os.path.join(ART, ps["file"])
            assert os.path.getsize(path) == ps["total"] * 4
            # offsets ascending, contiguous
            off = 0
            for leaf in ps["leaves"]:
                assert leaf["offset"] == off
                off += int(np.prod(leaf["shape"])) if leaf["shape"] else 1
            assert off == ps["total"]

    def test_param_count_matches_model(self, manifest):
        for preset, ps in manifest["params"].items():
            cfg = configs.PRESETS[preset]
            params = model.init_params(jax.random.PRNGKey(0), cfg)
            assert ps["count"] == model.count_params(params)

    def test_schedule_embedded(self, manifest):
        from compile import dap

        assert manifest["dap_schedule"] == dap.SCHEDULE
