"""L1 correctness: every Pallas kernel vs its pure-jnp oracle.

hypothesis sweeps shapes and dtypes (f32 + bf16) per the paper's problem
ranges (small hidden dims, many rows — §III.B). Tolerances are dtype-aware:
bf16 has ~8 mantissa bits so comparisons happen against the f32 oracle
output downcast to bf16.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import (
    fused_layernorm,
    fused_softmax,
    fused_softmax2d,
    gated_attention,
    outer_product_mean,
    triangle_mult,
)
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")

DTYPES = [jnp.float32, jnp.bfloat16]


def tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else dict(
        rtol=1e-5, atol=1e-5
    )


def rand(key, shape, dtype, scale=1.0):
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def keys(seed, n):
    return jax.random.split(jax.random.PRNGKey(seed), n)


# ---------------------------------------------------------------- softmax


@settings(max_examples=10, deadline=None)
@given(
    b=st.integers(1, 4),
    h=st.integers(1, 8),
    q=st.integers(1, 33),
    k=st.integers(1, 65),
    dt=st.sampled_from(DTYPES),
    scale=st.floats(0.1, 2.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_fused_softmax_bias_mask(b, h, q, k, dt, scale, seed):
    k1, k2, k3 = keys(seed, 3)
    x = rand(k1, (b, h, q, k), dt, 3.0)
    bias = rand(k2, (h, q, k), dt)
    mask = jnp.where(
        jax.random.bernoulli(k3, 0.9, (b, k)), 0.0, -1e9
    ).astype(dt)
    # guarantee at least one unmasked col per row so softmax is well defined
    mask = mask.at[:, 0].set(0.0)
    got = fused_softmax(x, bias, mask, scale)
    want = ref.fused_softmax_ref(x, bias, mask, scale)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), **tol(dt)
    )


@settings(max_examples=6, deadline=None)
@given(
    b=st.integers(1, 3),
    h=st.integers(1, 4),
    q=st.integers(1, 17),
    k=st.integers(1, 40),
    dt=st.sampled_from(DTYPES),
    seed=st.integers(0, 2**31 - 1),
)
def test_fused_softmax_plain(b, h, q, k, dt, seed):
    (k1,) = keys(seed, 1)
    x = rand(k1, (b, h, q, k), dt, 2.0)
    got = fused_softmax(x)
    want = ref.fused_softmax_ref(x)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), **tol(dt)
    )


@settings(max_examples=6, deadline=None)
@given(
    r=st.integers(1, 300),
    c=st.integers(1, 130),
    br=st.sampled_from([1, 7, 32, 128]),
    dt=st.sampled_from(DTYPES),
    seed=st.integers(0, 2**31 - 1),
)
def test_fused_softmax2d(r, c, br, dt, seed):
    (k1,) = keys(seed, 1)
    x = rand(k1, (r, c), dt, 2.0)
    got = fused_softmax2d(x, 0.7, block_rows=br)
    want = ref.softmax2d_ref(x, 0.7)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), **tol(dt)
    )


def test_softmax_rows_sum_to_one():
    x = rand(jax.random.PRNGKey(0), (4, 2, 9, 31), jnp.float32, 5.0)
    got = np.asarray(fused_softmax(x, scale=0.3), np.float32)
    np.testing.assert_allclose(got.sum(-1), np.ones(got.shape[:-1]), rtol=1e-5)


def test_softmax_translation_invariance():
    # softmax(x + c) == softmax(x): the max-subtraction stability property
    x = rand(jax.random.PRNGKey(1), (2, 2, 4, 16), jnp.float32)
    a = fused_softmax(x)
    b = fused_softmax(x + 100.0)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-6)


def test_softmax_extreme_values_stable():
    x = jnp.full((1, 1, 2, 8), 1e4, jnp.float32)
    got = np.asarray(fused_softmax(x))
    assert np.isfinite(got).all()
    np.testing.assert_allclose(got.sum(-1), 1.0, rtol=1e-5)


# --------------------------------------------------------------- layernorm


@settings(max_examples=10, deadline=None)
@given(
    rows=st.integers(1, 200),
    c=st.sampled_from([8, 32, 64, 128, 129, 256, 384]),
    br=st.sampled_from([1, 16, 128]),
    dt=st.sampled_from(DTYPES),
    seed=st.integers(0, 2**31 - 1),
    shift=st.floats(-50.0, 50.0),
)
def test_fused_layernorm(rows, c, br, dt, seed, shift):
    k1, k2, k3 = keys(seed, 3)
    x = rand(k1, (rows, c), dt, 2.0) + jnp.asarray(shift, dt)
    g = rand(k2, (c,), dt)
    b = rand(k3, (c,), dt)
    got = fused_layernorm(x, g, b, block_rows=br)
    want = ref.layernorm_ref(x, g, b)
    # chunked-Welford and two-pass differ in summation order; shifted
    # inputs amplify the f32 difference slightly (both are valid LNs)
    t = tol(dt)
    t["atol"] = max(t["atol"], 2e-4 * (1.0 + abs(shift) / 10.0))
    t["rtol"] = max(t["rtol"], 1e-4)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), **t
    )


def test_layernorm_nd_leading_shape():
    k1, k2, k3 = keys(0, 3)
    x = rand(k1, (3, 5, 7, 64), jnp.float32)
    g, b = rand(k2, (64,), jnp.float32), rand(k3, (64,), jnp.float32)
    got = fused_layernorm(x, g, b)
    want = ref.layernorm_ref(x, g, b)
    assert got.shape == x.shape
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


def test_layernorm_welford_large_mean_stability():
    # one-pass mean(x^2)-mean(x)^2 catastrophically cancels at mean≫std;
    # the Welford merge must not (paper §IV.A.3 rationale).
    c = 256
    x = rand(jax.random.PRNGKey(9), (64, c), jnp.float32, 1.0) + 1e4
    g = jnp.ones((c,), jnp.float32)
    b = jnp.zeros((c,), jnp.float32)
    got = np.asarray(fused_layernorm(x, g, b))
    assert np.isfinite(got).all()
    # compare against float64 ground truth: the chunked-Welford kernel must
    # be at least as accurate as the two-pass f32 reference at huge means.
    x64 = np.asarray(x, np.float64)
    m = x64.mean(-1, keepdims=True)
    v = ((x64 - m) ** 2).mean(-1, keepdims=True)
    truth = (x64 - m) / np.sqrt(v + 1e-5)
    ref_err = np.abs(np.asarray(ref.layernorm_ref(x, g, b)) - truth).max()
    ker_err = np.abs(got - truth).max()
    assert ker_err <= max(ref_err * 1.5, 1e-3), (ker_err, ref_err)


def test_layernorm_output_statistics():
    c = 128
    x = rand(jax.random.PRNGKey(3), (32, c), jnp.float32, 4.0)
    got = np.asarray(
        fused_layernorm(x, jnp.ones((c,)), jnp.zeros((c,)))
    )
    np.testing.assert_allclose(got.mean(-1), 0.0, atol=1e-5)
    np.testing.assert_allclose(got.std(-1), 1.0, atol=1e-2)


# --------------------------------------------------------------- attention


@settings(max_examples=8, deadline=None)
@given(
    b=st.integers(1, 3),
    h=st.sampled_from([1, 4, 8]),
    q=st.integers(1, 24),
    k=st.integers(1, 24),
    d=st.sampled_from([8, 16, 32]),
    with_bias=st.booleans(),
    dt=st.sampled_from(DTYPES),
    seed=st.integers(0, 2**31 - 1),
)
def test_gated_attention(b, h, q, k, d, with_bias, dt, seed):
    k1, k2, k3, k4, k5 = keys(seed, 5)
    qq = rand(k1, (b, h, q, d), dt)
    kk = rand(k2, (b, h, k, d), dt)
    vv = rand(k3, (b, h, k, d), dt)
    gg = rand(k4, (b, h, q, d), dt)
    bias = rand(k5, (h, q, k), dt) if with_bias else None
    got = gated_attention(qq, kk, vv, gg, bias)
    want = ref.gated_attention_ref(qq, kk, vv, gg, bias)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), **tol(dt)
    )


def test_gated_attention_zero_gate_zeroes_output():
    k1, k2 = keys(11, 2)
    q = rand(k1, (1, 2, 4, 8), jnp.float32)
    kv = rand(k2, (1, 2, 4, 8), jnp.float32)
    gate = jnp.full((1, 2, 4, 8), -1e9, jnp.float32)  # sigmoid -> 0
    got = np.asarray(gated_attention(q, kv, kv, gate))
    np.testing.assert_allclose(got, 0.0, atol=1e-30)


# ---------------------------------------------------------------- triangle


@settings(max_examples=8, deadline=None)
@given(
    r=st.integers(2, 48),
    c=st.sampled_from([4, 16, 32]),
    outgoing=st.booleans(),
    block=st.sampled_from([1, 8, 64]),
    dt=st.sampled_from(DTYPES),
    seed=st.integers(0, 2**31 - 1),
)
def test_triangle_mult(r, c, outgoing, block, dt, seed):
    k1, k2 = keys(seed, 2)
    a = rand(k1, (r, r, c), dt)
    b = rand(k2, (r, r, c), dt)
    got = triangle_mult(a, b, outgoing, block=block)
    want = ref.triangle_mult_ref(a, b, outgoing)
    np.testing.assert_allclose(
        np.asarray(got, np.float32),
        np.asarray(want, np.float32),
        rtol=3e-2 if dt == jnp.bfloat16 else 1e-4,
        atol=3e-1 if dt == jnp.bfloat16 else 1e-4,
    )


def test_triangle_outgoing_incoming_transpose_relation():
    # out_outgoing(a, b) == out_incoming(a^T, b^T) where ^T swaps (i,j)
    k1, k2 = keys(21, 2)
    a = rand(k1, (12, 12, 8), jnp.float32)
    b = rand(k2, (12, 12, 8), jnp.float32)
    out1 = np.asarray(triangle_mult(a, b, outgoing=True))
    out2 = np.asarray(
        triangle_mult(a.transpose(1, 0, 2), b.transpose(1, 0, 2), outgoing=False)
    )
    np.testing.assert_allclose(out1, out2, rtol=1e-4, atol=1e-4)


# --------------------------------------------------------------------- OPM


@settings(max_examples=8, deadline=None)
@given(
    s=st.integers(1, 24),
    i=st.integers(1, 24),
    j=st.integers(1, 24),
    d=st.sampled_from([4, 8, 16]),
    e=st.sampled_from([4, 8, 16]),
    block=st.sampled_from([1, 8, 64]),
    dt=st.sampled_from(DTYPES),
    seed=st.integers(0, 2**31 - 1),
)
def test_outer_product_mean(s, i, j, d, e, block, dt, seed):
    k1, k2 = keys(seed, 2)
    a = rand(k1, (s, i, d), dt)
    b = rand(k2, (s, j, e), dt)
    got = outer_product_mean(a, b, block=block)
    want = ref.outer_product_mean_ref(a, b)
    assert got.shape == (i, j, d * e)
    np.testing.assert_allclose(
        np.asarray(got, np.float32),
        np.asarray(want, np.float32),
        rtol=3e-2 if dt == jnp.bfloat16 else 1e-4,
        atol=3e-2 if dt == jnp.bfloat16 else 1e-5,
    )


def test_opm_mean_property():
    # identical rows along s: mean over s equals the single-row outer product
    k1, k2 = keys(31, 2)
    a1 = rand(k1, (1, 6, 4), jnp.float32)
    b1 = rand(k2, (1, 7, 5), jnp.float32)
    a = jnp.tile(a1, (9, 1, 1))
    b = jnp.tile(b1, (9, 1, 1))
    got = np.asarray(outer_product_mean(a, b))
    want = np.asarray(outer_product_mean(a1, b1))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)


# ----------------------------------------------------- naive baselines agree


def test_naive_baselines_match_refs():
    # Fig 8/9 baselines must compute the same math, just unfused.
    k1, k2, k3 = keys(41, 3)
    x = rand(k1, (2, 3, 5, 33), jnp.float32, 2.0)
    bias = rand(k2, (3, 5, 33), jnp.float32)
    np.testing.assert_allclose(
        np.asarray(ref.naive_softmax_unfused(x, bias, scale=0.5)),
        np.asarray(ref.fused_softmax_ref(x, bias, scale=0.5)),
        rtol=1e-5,
        atol=1e-6,
    )
    xl = rand(k1, (16, 128), jnp.float32, 3.0)
    g, b = rand(k2, (128,), jnp.float32), rand(k3, (128,), jnp.float32)
    np.testing.assert_allclose(
        np.asarray(ref.naive_layernorm_twopass(xl, g, b)),
        np.asarray(ref.layernorm_ref(xl, g, b)),
        rtol=1e-5,
        atol=1e-5,
    )


# --------------------------------------------------------- differentiability


def _grads_match(f_kernel, f_ref, args, argnums, rtol=1e-4, atol=1e-5):
    gk = jax.grad(lambda *a: jnp.sum(jnp.sin(f_kernel(*a))), argnums)(*args)
    gr = jax.grad(lambda *a: jnp.sum(jnp.sin(f_ref(*a))), argnums)(*args)
    for a, b in zip(gk, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=rtol, atol=atol)


def test_softmax_grads_match_ref():
    k1, k2, k3 = keys(51, 3)
    x = rand(k1, (2, 3, 4, 9), jnp.float32, 2.0)
    bias = rand(k2, (3, 4, 9), jnp.float32)
    mask = jnp.zeros((2, 9), jnp.float32)
    _grads_match(
        lambda x, b, m: fused_softmax(x, b, m, 0.6),
        lambda x, b, m: ref.fused_softmax_ref(x, b, m, 0.6),
        (x, bias, mask), (0, 1, 2),
    )
    x2 = rand(k3, (11, 17), jnp.float32)
    _grads_match(
        lambda x: fused_softmax2d(x, 0.8, block_rows=4),
        lambda x: ref.softmax2d_ref(x, 0.8),
        (x2,), (0,),
    )


def test_layernorm_grads_match_ref():
    k1, k2, k3 = keys(52, 3)
    x = rand(k1, (3, 5, 64), jnp.float32, 2.0)
    g, b = rand(k2, (64,), jnp.float32), rand(k3, (64,), jnp.float32)
    _grads_match(fused_layernorm, ref.layernorm_ref, (x, g, b), (0, 1, 2))


def test_attention_grads_match_ref():
    k1, k2, k3, k4, k5 = keys(53, 5)
    q = rand(k1, (1, 2, 4, 8), jnp.float32)
    kk = rand(k2, (1, 2, 6, 8), jnp.float32)
    v = rand(k3, (1, 2, 6, 8), jnp.float32)
    gate = rand(k4, (1, 2, 4, 8), jnp.float32)
    bias = rand(k5, (2, 4, 6), jnp.float32)
    _grads_match(
        gated_attention, ref.gated_attention_ref, (q, kk, v, gate, bias),
        (0, 1, 2, 3, 4),
    )
    _grads_match(
        gated_attention, ref.gated_attention_ref, (q, kk, v, gate), (0, 1, 2, 3)
    )


def test_triangle_grads_match_ref():
    k1, k2 = keys(54, 2)
    a = rand(k1, (8, 8, 4), jnp.float32)
    b = rand(k2, (8, 8, 4), jnp.float32)
    for og in (True, False):
        _grads_match(
            lambda a, b: triangle_mult(a, b, og),
            lambda a, b: ref.triangle_mult_ref(a, b, og),
            (a, b), (0, 1), rtol=1e-4, atol=1e-4,
        )


def test_opm_grads_match_ref():
    k1, k2 = keys(55, 2)
    a = rand(k1, (5, 6, 4), jnp.float32)
    b = rand(k2, (5, 7, 3), jnp.float32)
    _grads_match(outer_product_mean, ref.outer_product_mean_ref, (a, b), (0, 1))
