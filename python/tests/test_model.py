"""L2 model correctness: shapes, kernel-vs-naive equivalence, gradients,
loss behaviour, parameter accounting against the paper's Table II."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import configs, model

jax.config.update("jax_platform_name", "cpu")

CFG = configs.TINY


@pytest.fixture(scope="module")
def params():
    return model.init_params(jax.random.PRNGKey(0), CFG)


@pytest.fixture(scope="module")
def batch():
    return model.make_synthetic_batch(jax.random.PRNGKey(1), CFG)


def test_forward_shapes(params, batch):
    msa_logits, dist_logits = model.forward(params, CFG, batch["msa_tokens"])
    assert msa_logits.shape == (CFG.n_seq, CFG.n_res, CFG.msa_vocab)
    assert dist_logits.shape == (CFG.n_res, CFG.n_res, CFG.n_dist_bins)


def test_kernel_and_naive_paths_agree(params, batch):
    """The fused-kernel path and the unfused reference path are the same
    math — paper §V.D validation at model level."""
    a = model.forward(params, CFG, batch["msa_tokens"], use_kernels=True)
    b = model.forward(params, CFG, batch["msa_tokens"], use_kernels=False)
    for x, y in zip(a, b):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y), rtol=1e-4,
                                   atol=1e-4)


def test_block_residual_structure(params):
    """Zeroed-out block params (gates closed) ≈ identity via residuals."""
    m = jax.random.normal(jax.random.PRNGKey(2),
                          (CFG.n_seq, CFG.n_res, CFG.d_msa))
    z = jax.random.normal(jax.random.PRNGKey(3),
                          (CFG.n_res, CFG.n_res, CFG.d_pair))
    m2, z2 = model.evoformer_block(params["blocks"][0], m, z, CFG)
    assert m2.shape == m.shape and z2.shape == z.shape
    # block must actually transform the input
    assert float(jnp.abs(m2 - m).max()) > 1e-3
    assert float(jnp.abs(z2 - z).max()) > 1e-3


def test_distogram_logits_symmetric_input(params, batch):
    """heads() symmetrizes z: logits(i,j) == logits(j,i)."""
    m = jnp.zeros((CFG.n_seq, CFG.n_res, CFG.d_msa))
    z = jax.random.normal(jax.random.PRNGKey(4),
                          (CFG.n_res, CFG.n_res, CFG.d_pair))
    _, dist = model.heads(params["heads"], m, z)
    np.testing.assert_allclose(np.asarray(dist),
                               np.asarray(jnp.swapaxes(dist, 0, 1)),
                               rtol=1e-5, atol=1e-5)


def test_loss_finite_and_positive(params, batch):
    loss = model.loss_fn(params, CFG, batch)
    assert np.isfinite(float(loss))
    assert float(loss) > 0


def test_loss_decreases_under_sgd(params, batch):
    """A few SGD steps on one batch must reduce the loss — end-to-end
    differentiability of embed→blocks(kernels)→heads→loss."""
    p = params
    lf = jax.jit(lambda p: model.loss_fn(p, CFG, batch))
    gf = jax.jit(jax.grad(lambda p: model.loss_fn(p, CFG, batch)))
    l0 = float(lf(p))
    for _ in range(5):
        g = gf(p)
        p = jax.tree_util.tree_map(lambda a, b: a - 0.05 * b, p, g)
    l1 = float(lf(p))
    assert l1 < l0, f"{l0} -> {l1}"


def test_masked_positions_use_mask_token(batch):
    mask = np.asarray(batch["msa_mask"])
    toks = np.asarray(batch["msa_tokens"])
    assert (toks[mask > 0.5] == CFG.mask_token).all()


def test_param_count_matches_paper():
    """Paper Table II: ~1.8 M params per Evoformer layer, ~93 M total
    (ours lacks the structure module/template stack → slightly lower)."""
    cfg = configs.ModelConfig(name="paper", n_res=8, n_seq=4)
    params = model.init_params(jax.random.PRNGKey(0), cfg)
    per_block = model.count_params({"b": [params["blocks"][0]]})
    assert 1.7e6 < per_block < 1.95e6
    total = model.count_params(params)
    assert 80e6 < total < 100e6


def test_flatten_order_is_jax_canonical(params):
    ours = [name for name, _ in model.flatten_params(params)]
    theirs = [
        "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
        for path, _ in jax.tree_util.tree_flatten_with_path(params)[0]
    ]
    assert ours == theirs


def test_embedder_relpos_translation():
    """Relative-position embedding depends only on i-j (clipped)."""
    cfg = CFG
    p = model.init_params(jax.random.PRNGKey(5), cfg)["embedder"]
    toks = jnp.zeros((cfg.n_seq, cfg.n_res), jnp.int32)
    _, z = model.embedder(p, cfg, toks)
    # identical residues everywhere → z[i,j] depends only on clip(i-j)
    za = np.asarray(z)
    assert np.allclose(za[0, 1], za[1, 2], atol=1e-5)
    assert np.allclose(za[2, 0], za[3, 1], atol=1e-5)
