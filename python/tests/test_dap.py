"""DAP segment-decomposition correctness — the key L2 validation.

`simulate_dap_block` (jnp-emulated collectives over N logical ranks) must
reproduce `evoformer_block` exactly for every N, and the per-segment VJPs
must compose to the block gradient. The rust coordinator's integration
tests mirror these against the AOT artifacts."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import configs, dap, model

jax.config.update("jax_platform_name", "cpu")

CFG = configs.TINY


@pytest.fixture(scope="module")
def setup():
    params = model.init_params(jax.random.PRNGKey(0), CFG)
    m = jax.random.normal(jax.random.PRNGKey(1),
                          (CFG.n_seq, CFG.n_res, CFG.d_msa))
    z = jax.random.normal(jax.random.PRNGKey(2),
                          (CFG.n_res, CFG.n_res, CFG.d_pair))
    return params["blocks"][0], m, z


@pytest.mark.parametrize("n", [1, 2, 4])
def test_dap_matches_block(setup, n):
    p, m, z = setup
    m_ref, z_ref = model.evoformer_block(p, m, z, CFG)
    m_dap, z_dap = dap.simulate_dap_block(p, CFG, m, z, n)
    np.testing.assert_allclose(np.asarray(m_dap), np.asarray(m_ref),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(z_dap), np.asarray(z_ref),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("n", [2, 4])
def test_dap_two_blocks_chained(setup, n):
    """Block-exit layout must be valid block-entry layout (schedule returns
    m to s-shard, z to i-shard)."""
    p, m, z = setup
    m1, z1 = model.evoformer_block(p, m, z, CFG)
    m_ref, z_ref = model.evoformer_block(p, m1, z1, CFG)
    ma, za = dap.simulate_dap_block(p, CFG, m, z, n)
    mb, zb = dap.simulate_dap_block(p, CFG, ma, za, n)
    np.testing.assert_allclose(np.asarray(mb), np.asarray(m_ref),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(zb), np.asarray(z_ref),
                               rtol=1e-4, atol=1e-4)


def test_comm_counts_match_design():
    """DESIGN.md §3 (Table III repro): 5 gathers, 1 reduce-scatter, 4
    all-to-alls per block forward."""
    counts = dap.comm_counts()
    assert counts == {"gather": 5, "scatter": 1, "a2a": 4}


def test_schedule_waits_every_async_op():
    ids = set()
    waited = set()
    for op in dap.SCHEDULE:
        if op["op"] == "wait":
            waited.add(op["id"])
        elif "id" in op:
            ids.add(op["id"])
    assert ids == waited


def test_schedule_slots_defined_before_use():
    defined = {"m", "z"}
    pending = {}
    for op in dap.SCHEDULE:
        if op["op"] == "exec":
            for s in op["in"]:
                assert s in defined, f"slot {s} used before def in {op}"
            defined.update(op["out"])
        elif op["op"] == "wait":
            defined.add(pending.pop(op["id"]))
        elif "id" in op:
            assert op["in"] in defined
            pending[op["id"]] = op["out"]
        else:
            assert op["in"] in defined
            defined.add(op["out"])


def test_collective_emulators():
    xs = [jnp.arange(6.0).reshape(2, 3) + 10 * i for i in range(3)]
    full = dap._all_gather(xs, 0)
    assert full[0].shape == (6, 3)
    np.testing.assert_allclose(np.asarray(full[0]), np.asarray(full[2]))
    rs = dap._reduce_scatter([jnp.ones((6, 2)) * (i + 1) for i in range(3)], 0)
    assert rs[0].shape == (2, 2)
    np.testing.assert_allclose(np.asarray(rs[1]), 6.0)
    # a2a inverse property (axis sizes divisible by n=3)
    ys = dap._all_to_all(xs, 1, 0)
    assert ys[0].shape == (6, 1)
    back = dap._all_to_all(ys, 0, 1)
    for a, b in zip(back, xs):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("seg_name", ["msa_row_core", "tri_out_post",
                                      "opm_post", "pair_trans"])
def test_segment_vjp_matches_autodiff(setup, seg_name):
    """The exported VJP twins must equal jax.grad through the segment."""
    p, m, z = setup
    n = 2
    from compile.aot import _seg_specs
    specs = _seg_specs(CFG, n)[seg_name]
    keys = jax.random.split(jax.random.PRNGKey(7), len(specs) + 1)
    inputs = tuple(jax.random.normal(k, s.shape) for k, s in
                   zip(keys[:-1], specs))
    fn = dap.SEGMENTS[seg_name]
    outs = fn(p, CFG, *inputs)
    cts = tuple(jnp.ones_like(o) for o in outs)

    vjp_fn = dap.make_segment_vjp(seg_name)
    dp, dins = vjp_fn(p, CFG, inputs, cts)

    def scalar(p_, *ins):
        return sum(jnp.sum(o) for o in fn(p_, CFG, *ins))

    want = jax.grad(scalar, argnums=tuple(range(len(inputs) + 1)))(p, *inputs)
    for a, b in zip(jax.tree_util.tree_leaves(dp),
                    jax.tree_util.tree_leaves(want[0])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-4)
    for a, b in zip(dins, want[1:]):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-4)


def test_dap_block_gradient_matches_reference(setup):
    """End-to-end: gradients composed through the simulated DAP schedule
    (via jax.grad over simulate_dap_block) == gradients of the block."""
    p, m, z = setup

    def loss_ref(p_, m_, z_):
        mo, zo = model.evoformer_block(p_, m_, z_, CFG)
        return jnp.sum(jnp.sin(mo)) + jnp.sum(jnp.sin(zo))

    def loss_dap(p_, m_, z_):
        mo, zo = dap.simulate_dap_block(p_, CFG, m_, z_, 2)
        return jnp.sum(jnp.sin(mo)) + jnp.sum(jnp.sin(zo))

    g_ref = jax.grad(loss_ref, argnums=(0, 1, 2))(p, m, z)
    g_dap = jax.grad(loss_dap, argnums=(0, 1, 2))(p, m, z)
    for a, b in zip(jax.tree_util.tree_leaves(g_dap),
                    jax.tree_util.tree_leaves(g_ref)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-4, atol=2e-4)
