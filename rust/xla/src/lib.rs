//! Offline stand-in for the `xla-rs` PJRT bindings.
//!
//! The build environment has no network and no XLA/PJRT shared libraries,
//! so this crate supplies the exact API surface the coordinator uses with
//! two behaviours:
//!
//! * **Data path ([`Literal`], [`ArrayShape`]) — fully functional.** Host
//!   tensors round-trip through literals losslessly; shape/reshape
//!   arithmetic is real. Everything the pure-model code path touches works.
//! * **Execution path ([`PjRtClient`], [`PjRtLoadedExecutable`]) — gated.**
//!   `compile` succeeds (it records the HLO text length for diagnostics),
//!   but `execute` returns [`Error`] explaining that a real PJRT backend is
//!   required. Integration tests already skip when `artifacts/` is absent,
//!   so the gate is only reachable by explicitly pointing the CLI at
//!   artifacts without a real backend.
//!
//! Swapping the real bindings back in is a one-line change in
//! `rust/Cargo.toml` (point the `xla` dependency at the real crate); no
//! coordinator code changes, because this stub mirrors its signatures.

use std::fmt;
use std::path::Path;
use std::sync::Arc;

/// Error type mirroring `xla::Error`: a message, nothing more.
#[derive(Debug, Clone)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

/// Crate-local result alias.
pub type Result<T> = std::result::Result<T, Error>;

fn err(msg: impl Into<String>) -> Error {
    Error(msg.into())
}

// ------------------------------------------------------------------ literal

/// Element storage for an array literal (f32 and i32 are the only dtypes
/// the artifact contract uses). Storage is `Arc`-shared: cloning a
/// literal (reshape, tuple decomposition, `to_literal_sync`) bumps a
/// refcount instead of deep-copying elements, and the coordinator's
/// `HostTensor` shares the same buffers through
/// [`Literal::from_shared`] / [`Literal::to_shared`].
#[derive(Debug, Clone, PartialEq)]
pub enum LiteralData {
    /// 32-bit float elements (shared storage).
    F32(Arc<Vec<f32>>),
    /// 32-bit signed integer elements (shared storage).
    I32(Arc<Vec<i32>>),
}

impl LiteralData {
    fn len(&self) -> usize {
        match self {
            LiteralData::F32(v) => v.len(),
            LiteralData::I32(v) => v.len(),
        }
    }
}

/// Element types a [`Literal`] can hold.
pub trait NativeType: Copy {
    /// Wrap a host vector into typed literal storage.
    fn wrap(v: Vec<Self>) -> LiteralData;
    /// Wrap an already-shared buffer into typed literal storage
    /// (zero-copy).
    fn wrap_shared(v: Arc<Vec<Self>>) -> LiteralData;
    /// Extract a host vector if the storage matches `Self` (copies).
    fn unwrap(d: &LiteralData) -> Option<Vec<Self>>;
    /// Share the storage buffer if it matches `Self` (zero-copy).
    fn unwrap_shared(d: &LiteralData) -> Option<Arc<Vec<Self>>>;
}

impl NativeType for f32 {
    fn wrap(v: Vec<Self>) -> LiteralData {
        LiteralData::F32(Arc::new(v))
    }
    fn wrap_shared(v: Arc<Vec<Self>>) -> LiteralData {
        LiteralData::F32(v)
    }
    fn unwrap(d: &LiteralData) -> Option<Vec<Self>> {
        match d {
            LiteralData::F32(v) => Some(v.as_ref().clone()),
            _ => None,
        }
    }
    fn unwrap_shared(d: &LiteralData) -> Option<Arc<Vec<Self>>> {
        match d {
            LiteralData::F32(v) => Some(Arc::clone(v)),
            _ => None,
        }
    }
}

impl NativeType for i32 {
    fn wrap(v: Vec<Self>) -> LiteralData {
        LiteralData::I32(Arc::new(v))
    }
    fn wrap_shared(v: Arc<Vec<Self>>) -> LiteralData {
        LiteralData::I32(v)
    }
    fn unwrap(d: &LiteralData) -> Option<Vec<Self>> {
        match d {
            LiteralData::I32(v) => Some(v.as_ref().clone()),
            _ => None,
        }
    }
    fn unwrap_shared(d: &LiteralData) -> Option<Arc<Vec<Self>>> {
        match d {
            LiteralData::I32(v) => Some(Arc::clone(v)),
            _ => None,
        }
    }
}

/// Dimensions of an array literal.
#[derive(Debug, Clone, PartialEq)]
pub struct ArrayShape {
    dims: Vec<i64>,
}

impl ArrayShape {
    /// The dimension sizes, outermost first (row-major).
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }
}

/// A host-side XLA literal: an nd-array of f32/i32, or a tuple of literals.
#[derive(Debug, Clone, PartialEq)]
pub enum Literal {
    /// A dense row-major array.
    Array {
        /// Element storage.
        data: LiteralData,
        /// Dimension sizes, outermost first.
        dims: Vec<i64>,
    },
    /// A tuple of literals (executable outputs are lowered as one tuple).
    Tuple(Vec<Literal>),
}

impl Literal {
    /// Build a rank-1 literal from a host slice.
    pub fn vec1<T: NativeType>(data: &[T]) -> Literal {
        Literal::Array {
            dims: vec![data.len() as i64],
            data: T::wrap(data.to_vec()),
        }
    }

    /// Build a literal sharing an existing storage buffer (zero-copy);
    /// element count must match `dims`.
    pub fn from_shared<T: NativeType>(data: Arc<Vec<T>>, dims: &[i64]) -> Result<Literal> {
        let want: i64 = dims.iter().product();
        if want < 0 || want as usize != data.len() {
            return Err(err(format!(
                "from_shared to {:?} wants {} elements, buffer has {}",
                dims,
                want,
                data.len()
            )));
        }
        Ok(Literal::Array { data: T::wrap_shared(data), dims: dims.to_vec() })
    }

    /// Share the element storage (zero-copy counterpart of
    /// [`Literal::to_vec`]).
    pub fn to_shared<T: NativeType>(&self) -> Result<Arc<Vec<T>>> {
        match self {
            Literal::Array { data, .. } => T::unwrap_shared(data)
                .ok_or_else(|| err("literal element type mismatch")),
            Literal::Tuple(_) => Err(err("cannot read elements of a tuple literal")),
        }
    }

    /// Reshape to `dims` (element count must match).
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        match self {
            Literal::Array { data, .. } => {
                let want: i64 = dims.iter().product();
                if want < 0 || want as usize != data.len() {
                    return Err(err(format!(
                        "reshape to {:?} wants {} elements, literal has {}",
                        dims,
                        want,
                        data.len()
                    )));
                }
                Ok(Literal::Array { data: data.clone(), dims: dims.to_vec() })
            }
            Literal::Tuple(_) => Err(err("cannot reshape a tuple literal")),
        }
    }

    /// The array shape (error on tuples).
    pub fn array_shape(&self) -> Result<ArrayShape> {
        match self {
            Literal::Array { dims, .. } => Ok(ArrayShape { dims: dims.clone() }),
            Literal::Tuple(_) => Err(err("tuple literal has no array shape")),
        }
    }

    /// Copy the elements out as a host vector of `T`.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        match self {
            Literal::Array { data, .. } => T::unwrap(data)
                .ok_or_else(|| err("literal element type mismatch")),
            Literal::Tuple(_) => Err(err("cannot read elements of a tuple literal")),
        }
    }

    /// Decompose a tuple literal into its parts.
    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        match self {
            Literal::Tuple(parts) => Ok(parts.clone()),
            Literal::Array { .. } => Err(err("literal is not a tuple")),
        }
    }
}

/// Values accepted by [`PjRtLoadedExecutable::execute`]: owned or borrowed
/// literals (mirrors `xla-rs`'s `BorrowLiteral`).
pub trait BorrowLiteral {
    /// Borrow the underlying literal.
    fn borrow_literal(&self) -> &Literal;
}

impl BorrowLiteral for Literal {
    fn borrow_literal(&self) -> &Literal {
        self
    }
}

impl BorrowLiteral for &Literal {
    fn borrow_literal(&self) -> &Literal {
        self
    }
}

// ------------------------------------------------------------------ compile

/// Parsed HLO module (here: the raw text, held for diagnostics).
#[derive(Debug, Clone)]
pub struct HloModuleProto {
    text_len: usize,
}

impl HloModuleProto {
    /// Read an HLO-text artifact from disk.
    pub fn from_text_file(path: impl AsRef<Path>) -> Result<Self> {
        let text = std::fs::read_to_string(path.as_ref())
            .map_err(|e| err(format!("read {}: {e}", path.as_ref().display())))?;
        Ok(HloModuleProto { text_len: text.len() })
    }
}

/// An XLA computation built from an HLO module.
#[derive(Debug, Clone)]
pub struct XlaComputation {
    text_len: usize,
}

impl XlaComputation {
    /// Wrap a parsed HLO module.
    pub fn from_proto(proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { text_len: proto.text_len }
    }
}

// ------------------------------------------------------------------ runtime

/// PJRT client handle. The stub "cpu" client exists so pure-model code and
/// manifest plumbing run; only `execute` is gated.
#[derive(Debug)]
pub struct PjRtClient {
    platform: &'static str,
}

impl PjRtClient {
    /// Create the (stub) CPU client.
    pub fn cpu() -> Result<Self> {
        Ok(PjRtClient { platform: "cpu-stub" })
    }

    /// Platform name, e.g. `"cpu-stub"`.
    pub fn platform_name(&self) -> String {
        self.platform.to_string()
    }

    /// "Compile" a computation. Succeeds so callers can cache executables;
    /// the gate sits on `execute`.
    pub fn compile(&self, comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Ok(PjRtLoadedExecutable { hlo_text_len: comp.text_len })
    }
}

/// Device buffer returned by an execution (unreachable through the stub,
/// but part of the API shape).
#[derive(Debug, Clone)]
pub struct PjRtBuffer {
    literal: Literal,
}

impl PjRtBuffer {
    /// Copy the buffer back to a host literal.
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Ok(self.literal.clone())
    }
}

/// A compiled executable. `execute` is the offline gate: it returns an
/// error explaining that a real PJRT backend is required.
#[derive(Debug)]
pub struct PjRtLoadedExecutable {
    hlo_text_len: usize,
}

impl PjRtLoadedExecutable {
    /// Execute with the given argument literals.
    ///
    /// Always errors in this stub build: there is no XLA runtime to run
    /// the HLO. The error names the fix so the failure is actionable.
    pub fn execute<L: BorrowLiteral>(&self, args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        let _ = args;
        Err(err(format!(
            "PJRT execution unavailable: this build uses the offline xla stub \
             (artifact HLO text: {} bytes, {} args supplied). Rebuild with the \
             real xla-rs bindings to execute artifacts.",
            self.hlo_text_len,
            args.len()
        )))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_f32() {
        let lit = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let lit = lit.reshape(&[2, 3]).unwrap();
        assert_eq!(lit.array_shape().unwrap().dims(), &[2, 3]);
        assert_eq!(lit.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
    }

    #[test]
    fn literal_roundtrip_i32() {
        let lit = Literal::vec1(&[7i32, 8, 9]);
        assert_eq!(lit.to_vec::<i32>().unwrap(), vec![7, 8, 9]);
        assert!(lit.to_vec::<f32>().is_err());
    }

    #[test]
    fn reshape_validates_count() {
        let lit = Literal::vec1(&[1.0f32, 2.0]);
        assert!(lit.reshape(&[3]).is_err());
        assert!(lit.reshape(&[2, 1]).is_ok());
    }

    #[test]
    fn tuple_access() {
        let t = Literal::Tuple(vec![Literal::vec1(&[1.0f32]), Literal::vec1(&[2i32])]);
        let parts = t.to_tuple().unwrap();
        assert_eq!(parts.len(), 2);
        assert!(t.array_shape().is_err());
        assert!(Literal::vec1(&[1.0f32]).to_tuple().is_err());
    }

    #[test]
    fn shared_storage_roundtrip_is_zero_copy() {
        let buf = Arc::new(vec![1.0f32, 2.0, 3.0, 4.0]);
        let lit = Literal::from_shared(Arc::clone(&buf), &[2, 2]).unwrap();
        let back = lit.to_shared::<f32>().unwrap();
        assert!(Arc::ptr_eq(&buf, &back), "no element copy on the data path");
        // reshape clones only the Arc, not the elements
        let re = lit.reshape(&[4]).unwrap();
        assert!(Arc::ptr_eq(&buf, &re.to_shared::<f32>().unwrap()));
        assert!(Literal::from_shared(buf, &[3]).is_err());
        assert!(Literal::vec1(&[1i32]).to_shared::<f32>().is_err());
    }

    #[test]
    fn execute_is_gated() {
        let client = PjRtClient::cpu().unwrap();
        assert_eq!(client.platform_name(), "cpu-stub");
        let comp = XlaComputation::from_proto(&HloModuleProto { text_len: 0 });
        let exe = client.compile(&comp).unwrap();
        let lits = vec![Literal::vec1(&[1.0f32])];
        let e = exe.execute::<Literal>(&lits).unwrap_err();
        assert!(e.to_string().contains("stub"));
    }
}
