//! Fig 11 reproduction: data-parallel scaling.
//!
//!  * EXECUTED — real DP trainer (grad_step → ring all-reduce → adam) at
//!    dp ∈ {1,2,4} on tiny; reports measured per-rank step economics and
//!    actual ring wire bytes.
//!  * MODEL — DP efficiency to 128 nodes at paper scale (90.1% claim).

use fastfold::config::{ModelConfig, TrainConfig};
use fastfold::metrics::Table;
use fastfold::perfmodel::gpu::ImplProfile;
use fastfold::perfmodel::scaling::{MpMethod, ScalingModel};
use fastfold::runtime::Runtime;
use fastfold::train::Trainer;

fn main() {
    println!("\nFig 11 — data-parallel scaling\n");

    let rt = Runtime::new("artifacts").expect("run `make artifacts`");
    let auto = fastfold::dap::default_threads();
    println!(
        "EXECUTED (tiny preset, 6 steps per point; rank executor at 1 \
         thread = sequential vs {auto} = auto):"
    );
    let mut t = Table::new(&[
        "DP ranks", "threads", "wall/step (ms)", "speedup vs seq",
        "ring wire/step (KiB)",
    ]);
    for dp in [1usize, 2, 4] {
        let cfg = TrainConfig {
            steps: 6,
            lr: 1e-3,
            warmup_steps: 0,
            log_every: 1000,
            checkpoint_every: 10_000,
            seed: 3,
            ..TrainConfig::default()
        };
        let mut wall_seq = 0.0f64;
        let mut thread_opts = vec![1usize];
        if auto > 1 {
            thread_opts.push(auto);
        }
        for &threads in &thread_opts {
            let mut tr = Trainer::new(&rt, "tiny", dp, cfg.clone())
                .unwrap()
                .with_threads(threads);
            let rep = tr.run().unwrap();
            let wall_step = rep.seconds / rep.steps as f64;
            if threads == 1 {
                wall_seq = wall_step;
            }
            t.row(&[
                dp.to_string(),
                threads.to_string(),
                format!("{:.1}", wall_step * 1e3),
                format!("{:.2}x", wall_seq / wall_step.max(1e-12)),
                format!("{:.1}", rep.wire_bytes as f64 / 1024.0 / rep.steps as f64),
            ]);
        }
    }
    t.print();

    let m = ScalingModel::default();
    let p = ImplProfile::fastfold();
    for (label, cfg, dap) in [
        ("Initial Training, DAP=2 (paper)", ModelConfig::initial_training(), 2usize),
        ("Fine-tuning, DAP=4 (paper)", ModelConfig::finetune(), 4),
    ] {
        println!("\nMODEL — {label}:");
        let mp = m.train_step(&cfg, &p, MpMethod::Dap, dap, true).total();
        let mut t = Table::new(&["DP ranks", "step (s)", "efficiency"]);
        for n in [1usize, 2, 8, 32, 64, 128] {
            let s = m.dp_step(&cfg, mp, n);
            t.row(&[n.to_string(), format!("{s:.3}"), format!("{:.1}%", 100.0 * mp / s)]);
        }
        t.print();
    }
    println!("\n(paper: near-linear scaling, 90.1% efficiency at 128-node fine-tuning.)");
}
