//! Serving-layer throughput bench: drain a synthetic mixed fleet of
//! requests through the engine's `plan_batch` pipeline (placement +
//! admission + schedule + lane simulation), FIFO vs SJF, at several lane
//! counts — modeled makespan, mean modeled turnaround, and aggregate
//! modeled PFLOP/s per configuration (the paper's aggregate throughput
//! framing at serving granularity).
//!
//! Pure cost-model run: no artifacts needed (`cargo bench` builds it; run
//! the binary directly for the tables).

use fastfold::config::RunConfig;
use fastfold::inference::engine::{plan_batch, InferRequest, PlacementPlanner, SchedPolicy};
use fastfold::metrics::{fmt_secs, Table};

/// The synthetic fleet: a heterogeneous request mix — mostly short
/// sequences, a band of chunkable long ones, a few DAP-worthy monsters —
/// roughly the shape ParaFold reports for batch AlphaFold serving.
fn fleet() -> Vec<InferRequest> {
    let mut reqs = Vec::new();
    let lens: [usize; 12] = [
        256, 384, 512, 640, 768, 1024, 1536, 2048, 2560, 3072, 3584, 4096,
    ];
    for round in 0..3u64 {
        for (k, &len) in lens.iter().enumerate() {
            let mut r = InferRequest::new(&format!("r{round}-{len}"), "tiny");
            r.model_len = Some(len);
            r.seed = 100 + round * 31 + k as u64;
            reqs.push(r);
        }
    }
    reqs
}

fn main() {
    println!("\nbench_serve — request-driven serving throughput (modeled)\n");
    let run_cfg = RunConfig::default();
    let planner = PlacementPlanner::from_run_config(&run_cfg).expect("planner");
    let requests = fleet();

    // placements are policy/lane-invariant: take them from one base plan
    let base = plan_batch(
        &planner,
        SchedPolicy::Fifo,
        run_cfg.serve.max_bypass,
        1,
        &requests,
    );
    let stats = base.stats(&requests);
    println!(
        "{} requests ({} admitted, {} rejected); backend mix: {}\n",
        requests.len(),
        base.order.len(),
        requests.len() - base.order.len(),
        stats.backend_mix()
    );

    let mut t = Table::new(&[
        "policy", "lanes", "modeled makespan", "mean turnaround", "aggregate PFLOP/s",
    ]);
    for policy in [SchedPolicy::Fifo, SchedPolicy::Sjf] {
        for lanes in [1usize, 2, 4, 8] {
            let plan = plan_batch(&planner, policy, run_cfg.serve.max_bypass, lanes, &requests);
            let lats: Vec<f64> = plan
                .order
                .iter()
                .map(|&i| {
                    plan.placements[i]
                        .as_ref()
                        .map(|p| p.modeled_latency)
                        .unwrap_or(0.0)
                })
                .collect();
            let turnaround: f64 = plan
                .modeled_starts
                .iter()
                .zip(lats.iter())
                .map(|(s, l)| s + l)
                .sum::<f64>()
                / lats.len().max(1) as f64;
            t.row(&[
                policy.name().into(),
                lanes.to_string(),
                fmt_secs(plan.modeled_makespan),
                fmt_secs(turnaround),
                format!("{:.2}", stats.aggregate_pflops(plan.modeled_makespan)),
            ]);
        }
    }
    t.print();
    println!(
        "\n(SJF lowers mean turnaround at equal makespan — the long DAP jobs\n\
         stop blocking the short-sequence traffic; the starvation guard\n\
         bounds how long they wait. Makespan is policy-invariant at 1 lane.)"
    );
}
