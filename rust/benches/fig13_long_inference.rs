//! Fig 13 reproduction: long-sequence distributed inference.
//!
//!  * EXECUTED — DAP full-model inference at N ∈ {1,2,4} on the small
//!    preset: dual-stream simulated step time + numerics check.
//!  * MODEL — paper-scale latency table 1k–2.5k and the 7.5–9.5× band.

use fastfold::config::ModelConfig;
use fastfold::dap::DapCoordinator;
use fastfold::inference::autochunk;
use fastfold::metrics::Table;
use fastfold::perfmodel::gpu::ImplProfile;
use fastfold::perfmodel::scaling::{MpMethod, ScalingModel};
use fastfold::perfmodel::{GpuSpec, MemoryModel};
use fastfold::runtime::Runtime;
use fastfold::train::DataGen;

fn main() {
    let rt = Runtime::new("artifacts").expect("run `make artifacts`");
    println!("\nFig 13 — long-sequence inference (distributed DAP)\n");

    println!("EXECUTED (small preset, full model):");
    let cfg = ModelConfig::small();
    let params = rt.manifest.load_params("small").unwrap();
    let mut gen = DataGen::new(cfg, 13);
    let batch = gen.next_batch();
    let mut t = Table::new(&["DAP", "sim latency (ms)", "speedup vs DAP=1"]);
    let mut base = 0.0f64;
    for n in [1usize, 2, 4] {
        // warmup + measure via timeline
        let co = DapCoordinator::new(&rt, "small", n, true).unwrap();
        co.model_forward(&params, &batch.msa_tokens).unwrap();
        let co = DapCoordinator::new(&rt, "small", n, true).unwrap();
        co.model_forward(&params, &batch.msa_tokens).unwrap();
        let sim = co.timeline.lock().unwrap().elapsed();
        if n == 1 {
            base = sim;
        }
        t.row(&[
            n.to_string(),
            format!("{:.1}", sim * 1e3),
            format!("{:.2}x", base / sim),
        ]);
    }
    t.print();

    let m = ScalingModel::default();
    println!("\nMODEL (paper scale, recycling=4):");
    let mut t = Table::new(&[
        "Length", "AlphaFold (s)", "OpenFold (s)", "FF 4 GPU (s)", "FF 8 GPU (s)",
        "FF8 vs OpenFold",
    ]);
    for &len in &[1024usize, 1536, 2048, 2560] {
        let af = m.inference_latency(len, &ImplProfile::alphafold_jax_gpu(), MpMethod::Dap, 1, true);
        let of = m.inference_latency(len, &ImplProfile::openfold(), MpMethod::Dap, 1, true);
        let f4 = m.inference_latency(len, &ImplProfile::fastfold(), MpMethod::Dap, 4, false);
        let f8 = m.inference_latency(len, &ImplProfile::fastfold(), MpMethod::Dap, 8, false);
        t.row(&[
            len.to_string(),
            format!("{af:.0}"),
            format!("{of:.0}"),
            format!("{f4:.0}"),
            format!("{f8:.0}"),
            format!("{:.1}x", of / f8),
        ]);
    }
    t.print();
    println!("\n(paper: 7.5–9.5x vs OpenFold, 9.3–11.6x vs AlphaFold.)");

    // AutoChunk planner: what the single-device baseline must do to fit
    // each length (and where it stops fitting entirely — the Table V OOM
    // handoff to DAP)
    let mem = MemoryModel::default();
    let gpu = GpuSpec::a100_40g();
    println!("\nAutoChunk strategies backing the baseline rows above:");
    for &len in &[1024usize, 1536, 2048, 2560, 3072] {
        match autochunk::plan(&ModelConfig::inference(len), &mem, &gpu, 1) {
            Ok(plan) => println!("  {}", plan.summary()),
            Err(e) => println!("  autochunk[infer_{len} dap=1]: {e}"),
        }
    }
}
