//! Fig 8 reproduction: fused softmax kernel vs the unfused "native" chain.
//!
//! Both variants are AOT HLO artifacts executing identical math on the same
//! PJRT CPU backend — the measured delta isolates the kernel *structure*
//! (one fused pass vs an 8-op chain with optimization barriers), which is
//! exactly what the paper's CUDA comparison isolates. Paper: 1.77–3.32×.

use fastfold::metrics::{median, Table};
use fastfold::rng::Rng;
use fastfold::runtime::Runtime;
use fastfold::tensor::HostTensor;

const SIZES: [(usize, usize); 6] =
    [(1024, 32), (1024, 64), (1024, 128), (1024, 256), (4096, 64), (4096, 128)];
const ITERS: usize = 30;

fn bench_exe(rt: &Runtime, name: &str, inputs: &[HostTensor]) -> f64 {
    let exe = rt.load(name).expect(name);
    for _ in 0..3 {
        exe.run_f32(inputs).unwrap();
    }
    let times: Vec<f64> = (0..ITERS)
        .map(|_| {
            let t0 = std::time::Instant::now();
            exe.run_f32(inputs).unwrap();
            t0.elapsed().as_secs_f64()
        })
        .collect();
    median(times)
}

fn main() {
    let rt = Runtime::new("artifacts").expect("run `make artifacts` first");
    let mut rng = Rng::new(8);
    println!("\nFig 8 — Fused Softmax (paper speedup band: 1.77–3.32x)\n");
    let mut t = Table::new(&[
        "size (rows x cols)", "naive (µs)", "fused (µs)", "cpu ratio",
        "HBM-pass model",
    ]);
    for (rows, cols) in SIZES {
        let x = HostTensor::new(vec![rows, cols], rng.normal_vec(rows * cols, 2.0)).unwrap();
        let naive = bench_exe(&rt, &format!("bench/fig8_naive_{rows}x{cols}"), &[x.clone()]);
        let fused = bench_exe(&rt, &format!("bench/fig8_fused_{rows}x{cols}"), &[x]);
        // bandwidth-bound model: the unfused chain makes 8 read+write passes
        // over the tensor (scale, max, sub, exp, sum, div + barriers); the
        // fused kernel makes 1 read + 1 write. On an HBM-bound GPU the
        // speedup approaches this ratio derated by launch overheads — the
        // paper measures 1.77–3.32x inside this envelope.
        let model = 8.0f64 / 2.0;
        t.row(&[
            format!("{rows} x {cols}"),
            format!("{:.1}", naive * 1e6),
            format!("{:.1}", fused * 1e6),
            format!("{:.2}x", naive / fused),
            format!("{model:.1}x bound"),
        ]);
    }
    t.print();
    println!();
    println!("NOTE: cpu ratio is interpret-mode Pallas vs vectorized XLA on one");
    println!("CPU core — NOT a TPU/GPU wallclock proxy (grid loop overhead");
    println!("dominates). The kernel's fusion structure (1 HBM pass vs 8) is the");
    println!("quantity that transfers; see EXPERIMENTS.md §Fig8 and DESIGN.md §6.");
}
