//! Fig 8 reproduction: fused softmax kernel vs the unfused "native" chain.
//!
//! Two modes, both printed when available:
//!
//! * **Native host mode (always runs — no artifacts, no device):** the
//!   fused host kernel (`fastfold::kernels::softmax`) vs the naive
//!   6-op chain (scale, max, sub, exp, sum, div — one traversal per op,
//!   temporaries from the scratch pool). Outputs are bit-for-bit equal;
//!   the measured delta isolates memory passes, which is what the
//!   paper's CUDA comparison isolates. Paper band: 1.77–3.32×.
//! * **Artifact mode (when `artifacts/` exists with real PJRT):** both
//!   variants as AOT HLO executing on the same backend — the original
//!   fig8 comparison, kept intact.

use fastfold::bench::bench_med;
use fastfold::kernels::{softmax, ScratchPool};
use fastfold::metrics::{median, Table};
use fastfold::rng::Rng;
use fastfold::runtime::Runtime;
use fastfold::tensor::HostTensor;

const SIZES: [(usize, usize); 6] =
    [(1024, 32), (1024, 64), (1024, 128), (1024, 256), (4096, 64), (4096, 128)];
const ITERS: usize = 30;

fn native_mode() {
    let mut rng = Rng::new(8);
    let pool = ScratchPool::new();
    println!("\nFig 8 — Fused Softmax, native host kernels (paper band: 1.77–3.32x)\n");
    let mut t = Table::new(&[
        "size (rows x cols)", "naive (µs)", "fused (µs)", "host ratio",
        "HBM-pass model",
    ]);
    for (rows, cols) in SIZES {
        let x = rng.normal_vec(rows * cols, 2.0);
        let scale = 1.0 / (cols as f32).sqrt();
        let mut out = vec![0.0f32; x.len()];
        let fused = bench_med(3, ITERS, || {
            softmax::softmax_rows(&x, cols, scale, &mut out);
            std::hint::black_box(out[0]);
        });
        let naive = bench_med(3, ITERS, || {
            softmax::softmax_rows_naive(&x, cols, scale, &pool, &mut out);
            std::hint::black_box(out[0]);
        });
        // bandwidth-bound model: the unfused chain makes ~8 read+write
        // passes over the tensor (scale, max, sub, exp, sum, div +
        // barriers); the fused kernel makes 1 read + 1 write. On an
        // HBM-bound GPU the speedup approaches this ratio derated by
        // launch overheads — the paper measures 1.77–3.32x inside it.
        let model = 8.0f64 / 2.0;
        t.row(&[
            format!("{rows} x {cols}"),
            format!("{:.1}", naive * 1e6),
            format!("{:.1}", fused * 1e6),
            format!("{:.2}x", naive / fused),
            format!("{model:.1}x bound"),
        ]);
    }
    t.print();
    println!();
    println!("Native mode: fused and naive are bit-for-bit equal (pinned by the");
    println!("kernels::softmax test); the ratio above measures memory passes on");
    println!("one CPU core. `fastfold bench --json` records it in BENCH_host.json.");
}

fn bench_exe(rt: &Runtime, name: &str, inputs: &[HostTensor]) -> f64 {
    let exe = rt.load(name).expect(name);
    for _ in 0..3 {
        exe.run_f32(inputs).unwrap();
    }
    let times: Vec<f64> = (0..ITERS)
        .map(|_| {
            let t0 = std::time::Instant::now();
            exe.run_f32(inputs).unwrap();
            t0.elapsed().as_secs_f64()
        })
        .collect();
    median(times)
}

fn artifact_mode(rt: &Runtime) {
    let mut rng = Rng::new(8);
    println!("\nFig 8 — HLO artifact comparison (same math, AOT Pallas vs XLA chain)\n");
    let mut t = Table::new(&[
        "size (rows x cols)", "naive (µs)", "fused (µs)", "cpu ratio",
    ]);
    for (rows, cols) in SIZES {
        let x = HostTensor::new(vec![rows, cols], rng.normal_vec(rows * cols, 2.0)).unwrap();
        let naive = bench_exe(rt, &format!("bench/fig8_naive_{rows}x{cols}"), &[x.clone()]);
        let fused = bench_exe(rt, &format!("bench/fig8_fused_{rows}x{cols}"), &[x]);
        t.row(&[
            format!("{rows} x {cols}"),
            format!("{:.1}", naive * 1e6),
            format!("{:.1}", fused * 1e6),
            format!("{:.2}x", naive / fused),
        ]);
    }
    t.print();
    println!();
    println!("NOTE: cpu ratio is interpret-mode Pallas vs vectorized XLA on one");
    println!("CPU core — NOT a TPU/GPU wallclock proxy; see EXPERIMENTS.md §Fig8.");
}

fn main() {
    native_mode();
    match Runtime::new("artifacts") {
        Ok(rt) => artifact_mode(&rt),
        Err(_) => {
            println!("\n(artifacts/ absent — HLO artifact comparison skipped; the");
            println!(" native host mode above runs everywhere, including CI)");
        }
    }
}
