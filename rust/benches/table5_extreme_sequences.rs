//! Table V reproduction: extremely-long-sequence inference — latency for
//! the configurations that fit, sim-OOM verdicts for those that don't
//! (memory model), matching the paper's OOM pattern exactly.

use fastfold::config::ModelConfig;
use fastfold::inference::{autochunk, chunking};
use fastfold::metrics::Table;
use fastfold::perfmodel::gpu::ImplProfile;
use fastfold::perfmodel::scaling::{MpMethod, ScalingModel};
use fastfold::perfmodel::{GpuSpec, MemoryModel};

fn main() {
    let m = ScalingModel::default();
    let mem = MemoryModel::default();
    let gpu = GpuSpec::a100_40g();
    println!("\nTable V — extremely long sequences (memory + scaling models)\n");
    let mut t = Table::new(&[
        "Length", "AlphaFold", "OpenFold", "FastFold (8 GPU)", "FastFold (4 GPU)",
        "paper (FF8 / FF4)",
    ]);
    let paper = [
        (2560usize, "133 / 154"),
        (3072, "202 / 239"),
        (3584, "389 / 414"),
        (4096, "548 / OOM"),
    ];
    for (len, paper_cell) in paper {
        let cfg = ModelConfig::inference(len);
        let base = |p: ImplProfile| match chunking::plan_chunks(&cfg, &mem, &gpu) {
            Some(plan) => format!(
                "{:.0} s",
                m.inference_latency(len, &p, MpMethod::Dap, 1, plan.chunks > 1)
            ),
            None => "OOM".into(),
        };
        let ff = |n: usize| match mem.check(&cfg, n, 1, gpu.memory) {
            Ok(_) => format!(
                "{:.0} s",
                m.inference_latency(len, &ImplProfile::fastfold(), MpMethod::Dap, n, false)
            ),
            Err(_) => "OOM".into(),
        };
        t.row(&[
            len.to_string(),
            base(ImplProfile::alphafold_jax_gpu()),
            base(ImplProfile::openfold()),
            ff(8),
            ff(4),
            paper_cell.into(),
        ]);
    }
    t.print();
    println!("\nmemory detail (peak decimal GB on one device):");
    let mut t = Table::new(&["Length", "single+chunk", "DAP=4", "DAP=8", "capacity"]);
    for &len in &[2560usize, 3072, 3584, 4096] {
        let cfg = ModelConfig::inference(len);
        let chunked = chunking::plan_chunks(&cfg, &mem, &gpu)
            .map(|p| format!("{:.1}", p.peak_bytes / 1e9))
            .unwrap_or_else(|| ">40 (OOM)".into());
        t.row(&[
            len.to_string(),
            chunked,
            format!("{:.1}", mem.inference_peak(&cfg, 4, 1) / 1e9),
            format!("{:.1}", mem.inference_peak(&cfg, 8, 1) / 1e9),
            format!("{:.0}", gpu.memory / 1e9),
        ]);
    }
    t.print();

    println!("\nAutoChunk planner (per-module strategies, single device + min DAP):");
    let mut t = Table::new(&[
        "Length", "1-GPU verdict", "peak (GB)", "saves vs naive", "latency",
        "min DAP that fits",
    ]);
    for &len in &[2560usize, 3072, 3584, 4096] {
        let cfg = ModelConfig::inference(len);
        let (verdict, peak, saves, lat) = match autochunk::plan(&cfg, &mem, &gpu, 1) {
            Ok(p) => (
                "fits".to_string(),
                format!("{:.1}", p.peak_bytes / 1e9),
                format!("{:.1}%", 100.0 * p.savings_frac()),
                format!("x{:.2}", p.latency_factor),
            ),
            Err(_) => ("OOM".into(), "-".into(), "-".into(), "-".into()),
        };
        let min_dap =
            autochunk::min_dap_degree(&cfg, &mem, &gpu, 64, autochunk::CHUNK_HEADROOM)
                .map(|(n, _)| n.to_string())
                .unwrap_or_else(|| ">64".into());
        t.row(&[len.to_string(), verdict, peak, saves, lat, min_dap]);
    }
    t.print();
    println!("\n(paper OOM pattern: baselines die at 3072; FastFold-4 dies only at 4096 —");
    println!(" reproduced by the activation-memory model and the planner above.)");
}
