//! Fig 9 reproduction: fused (chunked-Welford) LayerNorm vs the unfused
//! two-pass chain vs an "Apex-like" single-fusion baseline.
//! Paper: 5.53–8.65× vs PyTorch-native, 1.20–1.62× vs Apex.
//!
//! Two modes, both printed when available:
//!
//! * **Native host mode (always runs — no artifacts, no device):** the
//!   chunked-Welford fused kernel (`fastfold::kernels::layernorm`)
//!   vs the Apex-like 3-pass single fusion vs the naive 6-op chain with
//!   scratch-pool temporaries. The ratio isolates memory passes.
//! * **Artifact mode (when `artifacts/` exists with real PJRT):** the
//!   original AOT HLO comparison, kept intact.

use fastfold::bench::bench_med;
use fastfold::kernels::{layernorm, ScratchPool};
use fastfold::metrics::{median, Table};
use fastfold::rng::Rng;
use fastfold::runtime::Runtime;
use fastfold::tensor::HostTensor;

const SIZES: [(usize, usize); 6] =
    [(1024, 32), (1024, 64), (1024, 128), (1024, 256), (4096, 64), (4096, 128)];
const ITERS: usize = 30;
const EPS: f32 = 1e-5;

fn native_mode() {
    let mut rng = Rng::new(9);
    let pool = ScratchPool::new();
    println!(
        "\nFig 9 — Fused LayerNorm, native host kernels (paper: 5.53–8.65x vs \
         native, 1.20–1.62x vs Apex)\n"
    );
    let mut t = Table::new(&[
        "size", "naive 6-op (µs)", "apex-like (µs)", "fused (µs)",
        "vs naive", "vs apex",
    ]);
    for (rows, cols) in SIZES {
        let x = rng.normal_vec(rows * cols, 2.0);
        let g = rng.normal_vec(cols, 1.0);
        let b = rng.normal_vec(cols, 1.0);
        let mut out = vec![0.0f32; x.len()];
        let fused = bench_med(3, ITERS, || {
            layernorm::layernorm_rows(&x, cols, &g, &b, EPS, &mut out);
            std::hint::black_box(out[0]);
        });
        let apex = bench_med(3, ITERS, || {
            layernorm::layernorm_rows_apex(&x, cols, &g, &b, EPS, &mut out);
            std::hint::black_box(out[0]);
        });
        let naive = bench_med(3, ITERS, || {
            layernorm::layernorm_rows_naive(&x, cols, &g, &b, EPS, &pool, &mut out);
            std::hint::black_box(out[0]);
        });
        t.row(&[
            format!("{rows} x {cols}"),
            format!("{:.1}", naive * 1e6),
            format!("{:.1}", apex * 1e6),
            format!("{:.1}", fused * 1e6),
            format!("{:.2}x", naive / fused),
            format!("{:.2}x", apex / fused),
        ]);
    }
    t.print();
    println!();
    println!("HBM-pass model: naive chain = 6+ read+write passes; apex-like single");
    println!("fusion = 3 (two reduce passes + apply); chunked-Welford fused = 2.");
    println!("`fastfold bench --json` records the 4096x128 point in BENCH_host.json.");
}

fn bench_exe(rt: &Runtime, name: &str, inputs: &[HostTensor]) -> f64 {
    let exe = rt.load(name).expect(name);
    for _ in 0..3 {
        exe.run_f32(inputs).unwrap();
    }
    let times: Vec<f64> = (0..ITERS)
        .map(|_| {
            let t0 = std::time::Instant::now();
            exe.run_f32(inputs).unwrap();
            t0.elapsed().as_secs_f64()
        })
        .collect();
    median(times)
}

fn artifact_mode(rt: &Runtime) {
    let mut rng = Rng::new(9);
    println!("\nFig 9 — HLO artifact comparison (AOT Pallas vs XLA chains)\n");
    let mut t = Table::new(&[
        "size", "naive 2-pass (µs)", "apex-like (µs)", "fused (µs)",
        "vs naive", "vs apex",
    ]);
    for (rows, cols) in SIZES {
        let x = HostTensor::new(vec![rows, cols], rng.normal_vec(rows * cols, 2.0)).unwrap();
        let g = HostTensor::new(vec![cols], rng.normal_vec(cols, 1.0)).unwrap();
        let b = HostTensor::new(vec![cols], rng.normal_vec(cols, 1.0)).unwrap();
        let args = [x, g, b];
        let naive = bench_exe(rt, &format!("bench/fig9_naive_{rows}x{cols}"), &args);
        let apex = bench_exe(rt, &format!("bench/fig9_apexlike_{rows}x{cols}"), &args);
        let fused = bench_exe(rt, &format!("bench/fig9_fused_{rows}x{cols}"), &args);
        t.row(&[
            format!("{rows} x {cols}"),
            format!("{:.1}", naive * 1e6),
            format!("{:.1}", apex * 1e6),
            format!("{:.1}", fused * 1e6),
            format!("{:.2}x", naive / fused),
            format!("{:.2}x", apex / fused),
        ]);
    }
    t.print();
    println!();
    println!("CPU wallclock here is interpret-mode Pallas — not a device proxy;");
    println!("see EXPERIMENTS.md §Fig9.");
}

fn main() {
    native_mode();
    match Runtime::new("artifacts") {
        Ok(rt) => artifact_mode(&rt),
        Err(_) => {
            println!("\n(artifacts/ absent — HLO artifact comparison skipped; the");
            println!(" native host mode above runs everywhere, including CI)");
        }
    }
}
