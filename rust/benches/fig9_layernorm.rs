//! Fig 9 reproduction: fused (chunked-Welford) LayerNorm vs the unfused
//! two-pass chain vs an "Apex-like" single-fusion baseline (XLA-fused
//! reference LN — the analogue of NVIDIA Apex's hand-fused kernel).
//! Paper: 5.53–8.65× vs PyTorch-native, 1.20–1.62× vs Apex.

use fastfold::metrics::{median, Table};
use fastfold::rng::Rng;
use fastfold::runtime::Runtime;
use fastfold::tensor::HostTensor;

const SIZES: [(usize, usize); 6] =
    [(1024, 32), (1024, 64), (1024, 128), (1024, 256), (4096, 64), (4096, 128)];
const ITERS: usize = 30;

fn bench_exe(rt: &Runtime, name: &str, inputs: &[HostTensor]) -> f64 {
    let exe = rt.load(name).expect(name);
    for _ in 0..3 {
        exe.run_f32(inputs).unwrap();
    }
    let times: Vec<f64> = (0..ITERS)
        .map(|_| {
            let t0 = std::time::Instant::now();
            exe.run_f32(inputs).unwrap();
            t0.elapsed().as_secs_f64()
        })
        .collect();
    median(times)
}

fn main() {
    let rt = Runtime::new("artifacts").expect("run `make artifacts` first");
    let mut rng = Rng::new(9);
    println!("\nFig 9 — Fused LayerNorm (paper: 5.53–8.65x vs native, 1.20–1.62x vs Apex)\n");
    let mut t = Table::new(&[
        "size", "naive 2-pass (µs)", "apex-like (µs)", "fused (µs)",
        "vs naive", "vs apex",
    ]);
    for (rows, cols) in SIZES {
        let x = HostTensor::new(vec![rows, cols], rng.normal_vec(rows * cols, 2.0)).unwrap();
        let g = HostTensor::new(vec![cols], rng.normal_vec(cols, 1.0)).unwrap();
        let b = HostTensor::new(vec![cols], rng.normal_vec(cols, 1.0)).unwrap();
        let args = [x, g, b];
        let naive = bench_exe(&rt, &format!("bench/fig9_naive_{rows}x{cols}"), &args);
        let apex = bench_exe(&rt, &format!("bench/fig9_apexlike_{rows}x{cols}"), &args);
        let fused = bench_exe(&rt, &format!("bench/fig9_fused_{rows}x{cols}"), &args);
        t.row(&[
            format!("{rows} x {cols}"),
            format!("{:.1}", naive * 1e6),
            format!("{:.1}", apex * 1e6),
            format!("{:.1}", fused * 1e6),
            format!("{:.2}x", naive / fused),
            format!("{:.2}x", apex / fused),
        ]);
    }
    t.print();
    println!();
    println!("HBM-pass model: naive two-pass chain = 7 read+write passes; apex-like");
    println!("single-fusion = 3 (two reduce passes + apply); chunked-Welford fused =");
    println!("2 (one read, one write). Bound: 3.5x vs native, 1.5x vs apex — the");
    println!("paper measures 5.53–8.65x / 1.20–1.62x (their native baseline also");
    println!("pays per-op launch overhead). CPU wallclock above is interpret-mode");
    println!("Pallas — not a device proxy; see EXPERIMENTS.md §Fig9.");
}
