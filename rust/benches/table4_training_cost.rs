//! Table IV reproduction: end-to-end training time/economics.
//!
//!  * EXECUTED — measured hybrid train-step wall time on this testbed
//!    (tiny/small presets) across (dp, dap, accum) layouts, demonstrating
//!    the pipeline the cost model extrapolates.
//!  * MODEL — the paper's Table IV rows via the hybrid DP×DAP step model
//!    (`ScalingModel::hybrid_step` / `two_stage_hours`): the 11 days →
//!    67 hours headline, 6.02 aggregate PFLOP/s, 90.1% DP efficiency.

use fastfold::config::{ModelConfig, TrainConfig};
use fastfold::metrics::Table;
use fastfold::perfmodel::gpu::ImplProfile;
use fastfold::perfmodel::scaling::ScalingModel;
use fastfold::runtime::Runtime;
use fastfold::train::{ParallelPlan, Trainer};

fn main() {
    println!("\nTable IV — training resource & time cost\n");

    // executed step timing (artifact-gated)
    match Runtime::new("artifacts") {
        Ok(rt) => {
            println!("EXECUTED (this testbed):");
            let mut t =
                Table::new(&["preset", "dp", "dap", "accum", "steps", "s/step (measured)"]);
            for (preset, dp, dap, accum, steps) in [
                ("tiny", 1usize, 1usize, 1usize, 6usize),
                ("tiny", 2, 1, 1, 4),
                ("tiny", 2, 1, 2, 2),
                ("tiny", 1, 2, 1, 2),
                ("small", 1, 1, 1, 2),
            ] {
                if !rt.manifest.artifacts.contains_key(&format!("{preset}/grad_step")) {
                    continue;
                }
                if dap > 1
                    && !rt
                        .manifest
                        .artifacts
                        .contains_key(&format!("{preset}/loss_head_grad"))
                {
                    continue;
                }
                let cfg = TrainConfig {
                    steps,
                    log_every: 10_000,
                    checkpoint_every: 10_000,
                    ..TrainConfig::default()
                };
                let plan = ParallelPlan::new(dp, dap, accum).with_threads(0);
                let mut tr = Trainer::hybrid(&rt, preset, plan, true, cfg).unwrap();
                let rep = tr.run().unwrap();
                t.row(&[
                    preset.into(),
                    dp.to_string(),
                    dap.to_string(),
                    accum.to_string(),
                    rep.steps.to_string(),
                    format!("{:.3}", rep.seconds / rep.steps.max(1) as f64),
                ]);
            }
            t.print();
        }
        Err(_) => println!("EXECUTED: skipped (run `make artifacts`)"),
    }

    // model extrapolation (paper scale)
    let m = ScalingModel::default();
    println!("\nMODEL (paper scale; samples: 10M initial + 1.5M finetune, batch 128):");
    let mut t = Table::new(&[
        "Implementation", "phase", "hardware", "step (s)", "paper (s)",
        "agg PFLOP/s", "DP eff", "total", "paper total",
    ]);
    let rows: [(&str, ImplProfile, usize, usize, &str, &str, &str); 2] = [
        ("OpenFold", ImplProfile::openfold(), 1, 1, "6.186", "20.657", "8.39 days"),
        ("FastFold", ImplProfile::fastfold(), 2, 4, "2.487", "4.153", "67 h"),
    ];
    for (name, p, dap_i, dap_f, paper_i, paper_f, paper_total) in rows {
        let hi = m.hybrid_step(&ModelConfig::initial_training(), &p, dap_i, 128, true);
        let hf = m.hybrid_step(&ModelConfig::finetune(), &p, dap_f, 128, true);
        let (ti, tf) = m.two_stage_hours(&p, (dap_i, 128), (dap_f, 128));
        t.row(&[
            name.into(),
            "initial".into(),
            format!("{} x A100", hi.gpus()),
            format!("{:.2}", hi.step_secs),
            paper_i.into(),
            format!("{:.2}", hi.aggregate_pflops),
            format!("{:.1}%", 100.0 * hi.dp_efficiency),
            format!("{:.1} h", ti + tf),
            paper_total.into(),
        ]);
        t.row(&[
            "".into(),
            "finetune".into(),
            format!("{} x A100", hf.gpus()),
            format!("{:.2}", hf.step_secs),
            paper_f.into(),
            format!("{:.2}", hf.aggregate_pflops),
            format!("{:.1}%", 100.0 * hf.dp_efficiency),
            "".into(),
            "".into(),
        ]);
    }
    t.print();

    let head = m.hybrid_step(
        &ModelConfig::finetune(),
        &ImplProfile::fastfold(),
        4,
        128,
        true,
    );
    let (hi, hf) =
        m.two_stage_hours(&ImplProfile::fastfold(), (2, 128), (4, 128));
    println!(
        "\nheadline: {:.1} h total (paper: 67 h); {:.2} PFLOP/s aggregate at \
         512 x A100 (paper: 6.02); {:.1}% DP efficiency (paper: 90.1%)",
        hi + hf,
        head.aggregate_pflops,
        100.0 * head.dp_efficiency
    );
    println!("AlphaFold baseline: 11 days on 128 TPUv3 (paper) — our model only");
    println!("covers the A100 implementations it can calibrate.");
    println!("(`fastfold scale --gpus 512` prints the same sweep from the CLI.)");
}
