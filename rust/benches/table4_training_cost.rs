//! Table IV reproduction: end-to-end training time/economics.
//!
//!  * EXECUTED — measured train-step wall time on this testbed (small
//!    preset) for the fused and DP paths, demonstrating the pipeline that
//!    the cost model extrapolates.
//!  * MODEL — the paper's Table IV rows (11 days → 67 hours headline).

use fastfold::config::{ModelConfig, TrainConfig};
use fastfold::metrics::Table;
use fastfold::perfmodel::flops::train_step_flops;
use fastfold::perfmodel::gpu::ImplProfile;
use fastfold::perfmodel::scaling::{MpMethod, ScalingModel};
use fastfold::runtime::Runtime;
use fastfold::train::Trainer;

fn main() {
    println!("\nTable IV — training resource & time cost\n");

    // executed step timing
    let rt = Runtime::new("artifacts").expect("run `make artifacts`");
    println!("EXECUTED (this testbed):");
    let mut t = Table::new(&["preset", "dp", "steps", "s/step (measured)"]);
    for (preset, dp, steps) in [("tiny", 1usize, 6usize), ("tiny", 2, 4), ("small", 1, 2)] {
        if !rt.manifest.artifacts.contains_key(&format!("{preset}/grad_step")) {
            continue;
        }
        let cfg = TrainConfig {
            steps,
            log_every: 10_000,
            checkpoint_every: 10_000,
            ..TrainConfig::default()
        };
        let mut tr = Trainer::new(&rt, preset, dp, cfg).unwrap();
        let rep = tr.run().unwrap();
        t.row(&[
            preset.into(),
            dp.to_string(),
            steps.to_string(),
            format!("{:.3}", rep.seconds / steps as f64),
        ]);
    }
    t.print();

    // model extrapolation (paper scale)
    let m = ScalingModel::default();
    println!("\nMODEL (paper scale; samples: 10M initial + 1.5M finetune, batch 128):");
    let mut t = Table::new(&[
        "Implementation", "phase", "hardware", "step (s)", "paper (s)", "total days", "paper days",
    ]);
    let init_steps = 10.0e6 / 128.0;
    let ft_steps = 1.5e6 / 128.0;
    let rows: [(&str, ImplProfile, usize, usize, &str, &str, &str); 2] = [
        ("OpenFold", ImplProfile::openfold(), 1, 1, "6.186", "20.657", "8.39"),
        ("FastFold", ImplProfile::fastfold(), 2, 4, "2.487", "4.153", "2.81"),
    ];
    for (name, p, dap_i, dap_f, paper_i, paper_f, paper_days) in rows {
        let cfg_i = ModelConfig::initial_training();
        let cfg_f = ModelConfig::finetune();
        let si = m.dp_step(&cfg_i, m.train_step(&cfg_i, &p, MpMethod::Dap, dap_i, true).total(), 128);
        let sf = m.dp_step(&cfg_f, m.train_step(&cfg_f, &p, MpMethod::Dap, dap_f, true).total(), 128);
        let days = (si * init_steps + sf * ft_steps) / 86400.0;
        t.row(&[
            name.into(), "initial".into(), format!("{} x A100", 128 * dap_i),
            format!("{si:.2}"), paper_i.into(), format!("{days:.2}"), paper_days.into(),
        ]);
        t.row(&[
            "".into(), "finetune".into(), format!("{} x A100", 128 * dap_f),
            format!("{sf:.2}"), paper_f.into(), "".into(), "".into(),
        ]);
    }
    t.print();

    // headline aggregate PFLOPs
    let cfg = ModelConfig::finetune();
    let p = ImplProfile::fastfold();
    let mp = m.train_step(&cfg, &p, MpMethod::Dap, 4, true).total();
    let step = m.dp_step(&cfg, mp, 128);
    let flops = train_step_flops(&cfg, 2.5) * 128.0;
    println!(
        "\nheadline: {:.2} PFLOPs aggregate at 512 x A100 (paper: 6.02), \
         {:.1}% DP efficiency (paper: 90.1%)",
        flops / step / 1e15,
        100.0 * mp / step
    );
    println!("AlphaFold baseline: 11 days on 128 TPUv3 (paper) — our model only");
    println!("covers the A100 implementations it can calibrate.");
}
