//! Fig 10 reproduction: model-parallel scaling intra-node, TP vs DAP.
//!
//! Two series per training setting:
//!  * EXECUTED — the real DAP coordinator at N ∈ {1,2,4} on the tiny
//!    preset; per-rank simulated step time from the dual-stream timeline
//!    (measured per-rank compute + α–β comm) — paper Fig 7/10 semantics.
//!  * MODEL — calibrated A100 model at the paper's exact Table I settings.

use fastfold::config::ModelConfig;
use fastfold::dap::DapCoordinator;
use fastfold::metrics::Table;
use fastfold::perfmodel::gpu::ImplProfile;
use fastfold::perfmodel::scaling::{MpMethod, ScalingModel};
use fastfold::rng::Rng;
use fastfold::runtime::Runtime;
use fastfold::tensor::HostTensor;

fn main() {
    println!("\nFig 10 — model parallelism scaling (DAP vs TP)\n");

    // --- executed series (tiny preset, real coordinator)
    let rt = Runtime::new("artifacts").expect("run `make artifacts`");
    let cfg = ModelConfig::tiny();
    let params = rt.manifest.load_params("tiny").unwrap();
    let idx = rt.manifest.block_leaf_indices("tiny", 0).unwrap();
    let bp: Vec<HostTensor> = idx.iter().map(|&i| params[i].clone()).collect();
    let mut rng = Rng::new(10);
    let m = HostTensor::new(
        vec![cfg.n_seq, cfg.n_res, cfg.d_msa],
        rng.normal_vec(cfg.n_seq * cfg.n_res * cfg.d_msa, 1.0),
    )
    .unwrap();
    let z = HostTensor::new(
        vec![cfg.n_res, cfg.n_res, cfg.d_pair],
        rng.normal_vec(cfg.n_res * cfg.n_res * cfg.d_pair, 1.0),
    )
    .unwrap();

    println!("EXECUTED (tiny preset, dual-stream simulated step; block fwd):");
    let mut t = Table::new(&["DAP ranks", "sim step (ms)", "efficiency", "exposed comm (ms)"]);
    let mut t1 = 0.0f64;
    for n in [1usize, 2, 4] {
        let co = DapCoordinator::new(&rt, "tiny", n, true).unwrap();
        // warmup (compile + first-run effects)
        let mut st = co.shard_inputs(&m, &z).unwrap();
        co.block_forward(&bp, &mut st).unwrap();
        // measured
        let co = DapCoordinator::new(&rt, "tiny", n, true).unwrap();
        let mut best = f64::INFINITY;
        let mut exposed = 0.0;
        for _ in 0..3 {
            let co2 = DapCoordinator::new(&rt, "tiny", n, true).unwrap();
            let mut st = co2.shard_inputs(&m, &z).unwrap();
            co2.block_forward(&bp, &mut st).unwrap();
            let tl = co2.timeline.borrow();
            if tl.elapsed() < best {
                best = tl.elapsed();
                exposed = tl.exposed_comm_seconds;
            }
        }
        drop(co);
        if n == 1 {
            t1 = best;
        }
        t.row(&[
            n.to_string(),
            format!("{:.2}", best * 1e3),
            format!("{:.1}%", 100.0 * t1 / (n as f64 * best)),
            format!("{:.3}", exposed * 1e3),
        ]);
    }
    t.print();

    // --- model series at paper scale
    let mdl = ScalingModel::default();
    let p = ImplProfile::fastfold();
    for (label, cfg) in [
        ("Initial Training (paper Table I)", ModelConfig::initial_training()),
        ("Fine-tuning (paper Table I)", ModelConfig::finetune()),
    ] {
        println!("\nMODEL — {label}:");
        let mut t = Table::new(&["GPUs", "DAP step (s)", "DAP eff", "TP step (s)", "TP eff"]);
        let t1 = mdl.train_step(&cfg, &p, MpMethod::Dap, 1, true).total();
        for n in [1usize, 2, 4] {
            let d = mdl.train_step(&cfg, &p, MpMethod::Dap, n, true).total();
            let tp = mdl.train_step(&cfg, &p, MpMethod::TensorParallel, n, true).total();
            t.row(&[
                n.to_string(),
                format!("{d:.3}"),
                format!("{:.1}%", 100.0 * t1 / (n as f64 * d)),
                format!("{tp:.3}"),
                format!("{:.1}%", 100.0 * t1 / (n as f64 * tp)),
            ]);
        }
        t.print();
    }
    println!("\n(paper shape: DAP > TP everywhere; fine-tuning scales better than");
    println!(" initial training. Both hold in the executed and model series.)");
}
