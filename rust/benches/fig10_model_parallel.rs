//! Fig 10 reproduction: model-parallel scaling intra-node, TP vs DAP.
//!
//! Three series per training setting:
//!  * EXECUTED — the real DAP coordinator at N ∈ {1,2,4} on the tiny
//!    preset; per-rank simulated step time from the dual-stream timeline
//!    (measured per-rank compute + α–β comm) — paper Fig 7/10 semantics.
//!  * THREADED — the same block forward with the rank executor fanned out
//!    over host worker threads and Duality-Async collectives on the comm
//!    worker: *wall-clock* step time, threaded vs `--threads 1`
//!    sequential baseline, plus the measured-vs-modeled exposed-comm
//!    comparison with overlap on vs off.
//!  * MODEL — calibrated A100 model at the paper's exact Table I settings.

use fastfold::config::ModelConfig;
use fastfold::dap::{default_threads, DapCoordinator};
use fastfold::metrics::Table;
use fastfold::perfmodel::gpu::ImplProfile;
use fastfold::perfmodel::scaling::{MpMethod, ScalingModel};
use fastfold::rng::Rng;
use fastfold::runtime::Runtime;
use fastfold::tensor::HostTensor;
use std::time::Instant;

struct Measured {
    wall: f64,
    sim: f64,
    measured_exposed: f64,
    measured_comm: f64,
    modeled_exposed: f64,
}

/// Best-of-3 block forward at (n, threads, overlap): wall clock + the two
/// exposed-comm ledgers (real and α–β).
fn run_point(
    rt: &Runtime,
    bp: &[HostTensor],
    m: &HostTensor,
    z: &HostTensor,
    n: usize,
    threads: usize,
    overlap: bool,
) -> Measured {
    let mut best = Measured {
        wall: f64::INFINITY,
        sim: 0.0,
        measured_exposed: 0.0,
        measured_comm: 0.0,
        modeled_exposed: 0.0,
    };
    for _ in 0..3 {
        let co = DapCoordinator::new(rt, "tiny", n, overlap)
            .unwrap()
            .with_threads(threads);
        let mut st = co.shard_inputs(m, z).unwrap();
        let t0 = Instant::now();
        co.block_forward(bp, &mut st).unwrap();
        let wall = t0.elapsed().as_secs_f64();
        if wall < best.wall {
            let tl = co.timeline.lock().unwrap();
            let ms = co.measured.lock().unwrap();
            best = Measured {
                wall,
                sim: tl.elapsed(),
                measured_exposed: ms.exposed_comm_seconds,
                measured_comm: ms.comm_seconds,
                modeled_exposed: tl.exposed_comm_seconds,
            };
        }
    }
    best
}

fn main() {
    println!("\nFig 10 — model parallelism scaling (DAP vs TP)\n");

    // --- executed series (tiny preset, real coordinator)
    let rt = Runtime::new("artifacts").expect("run `make artifacts`");
    let cfg = ModelConfig::tiny();
    let params = rt.manifest.load_params("tiny").unwrap();
    let idx = rt.manifest.block_leaf_indices("tiny", 0).unwrap();
    let bp: Vec<HostTensor> = idx.iter().map(|&i| params[i].clone()).collect();
    let mut rng = Rng::new(10);
    let m = HostTensor::new(
        vec![cfg.n_seq, cfg.n_res, cfg.d_msa],
        rng.normal_vec(cfg.n_seq * cfg.n_res * cfg.d_msa, 1.0),
    )
    .unwrap();
    let z = HostTensor::new(
        vec![cfg.n_res, cfg.n_res, cfg.d_pair],
        rng.normal_vec(cfg.n_res * cfg.n_res * cfg.d_pair, 1.0),
    )
    .unwrap();

    // warmup (compile + first-run effects)
    {
        let co = DapCoordinator::new(&rt, "tiny", 1, true).unwrap();
        let mut st = co.shard_inputs(&m, &z).unwrap();
        co.block_forward(&bp, &mut st).unwrap();
    }

    let auto = default_threads();
    println!(
        "EXECUTED (tiny preset, block fwd; host threads: 1 = sequential, \
         {auto} = auto):"
    );
    let mut t = Table::new(&[
        "DAP ranks", "threads", "overlap", "wall (ms)", "sim step (ms)",
        "meas comm (ms)", "meas exposed (ms)", "model exposed (ms)",
    ]);
    let mut wall_seq_dap4 = 0.0f64;
    let mut wall_thr_dap4 = 0.0f64;
    let mut share_on = 0.0f64;
    let mut share_off = 0.0f64;
    for n in [1usize, 2, 4] {
        let mut thread_opts = vec![1usize];
        if auto > 1 {
            thread_opts.push(auto);
        }
        for &threads in &thread_opts {
            for overlap in [true, false] {
                let p = run_point(&rt, &bp, &m, &z, n, threads, overlap);
                if n == 4 && overlap {
                    if threads == 1 {
                        wall_seq_dap4 = p.wall;
                    } else {
                        wall_thr_dap4 = p.wall;
                    }
                }
                if n == 4 && threads == auto.max(1) {
                    let share = p.measured_exposed / p.wall.max(1e-12);
                    if overlap {
                        share_on = share;
                    } else {
                        share_off = share;
                    }
                }
                t.row(&[
                    n.to_string(),
                    threads.to_string(),
                    (if overlap { "on" } else { "off" }).to_string(),
                    format!("{:.2}", p.wall * 1e3),
                    format!("{:.2}", p.sim * 1e3),
                    format!("{:.3}", p.measured_comm * 1e3),
                    format!("{:.3}", p.measured_exposed * 1e3),
                    format!("{:.3}", p.modeled_exposed * 1e3),
                ]);
            }
        }
    }
    t.print();
    if wall_thr_dap4 > 0.0 {
        println!(
            "\nthreaded speedup at dap=4 (overlap on): {:.2}x \
             (sequential {:.2} ms -> {} threads {:.2} ms)",
            wall_seq_dap4 / wall_thr_dap4.max(1e-12),
            wall_seq_dap4 * 1e3,
            auto,
            wall_thr_dap4 * 1e3,
        );
        println!(
            "measured exposed-comm share at dap=4, {auto} threads: \
             overlap on {:.1}% vs off {:.1}%",
            100.0 * share_on,
            100.0 * share_off,
        );
    } else {
        println!("\n(single host core: threaded series skipped; run with ≥2 cores)");
    }

    // --- model series at paper scale
    let mdl = ScalingModel::default();
    let p = ImplProfile::fastfold();
    for (label, cfg) in [
        ("Initial Training (paper Table I)", ModelConfig::initial_training()),
        ("Fine-tuning (paper Table I)", ModelConfig::finetune()),
    ] {
        println!("\nMODEL — {label}:");
        let mut t = Table::new(&["GPUs", "DAP step (s)", "DAP eff", "TP step (s)", "TP eff"]);
        let t1 = mdl.train_step(&cfg, &p, MpMethod::Dap, 1, true).total();
        for n in [1usize, 2, 4] {
            let d = mdl.train_step(&cfg, &p, MpMethod::Dap, n, true).total();
            let tp = mdl.train_step(&cfg, &p, MpMethod::TensorParallel, n, true).total();
            t.row(&[
                n.to_string(),
                format!("{d:.3}"),
                format!("{:.1}%", 100.0 * t1 / (n as f64 * d)),
                format!("{tp:.3}"),
                format!("{:.1}%", 100.0 * t1 / (n as f64 * tp)),
            ]);
        }
        t.print();
    }
    println!("\n(paper shape: DAP > TP everywhere; fine-tuning scales better than");
    println!(" initial training. Both hold in the executed and model series.)");
}
