//! Fig 12 reproduction: short-sequence single-device inference latency,
//! FastFold fused kernels vs the unfused "PyTorch-native"-style baseline —
//! both full-model AOT artifacts on the same PJRT backend.
//! Paper: 1.25–2.11× vs OpenFold, 2.01–4.05× vs AlphaFold-JAX.

use fastfold::config::ModelConfig;
use fastfold::inference::single_device_forward;
use fastfold::metrics::{median, Table};
use fastfold::runtime::Runtime;
use fastfold::train::DataGen;

fn main() {
    let rt = Runtime::new("artifacts").expect("run `make artifacts`");
    println!("\nFig 12 — short-sequence inference (fused vs unfused kernels)\n");
    let mut t = Table::new(&[
        "preset", "N_res", "naive (ms)", "fused (ms)", "kernel speedup",
    ]);
    for preset in ["tiny", "small"] {
        if !rt.manifest.artifacts.contains_key(&format!("{preset}/model_fwd")) {
            continue;
        }
        let cfg = ModelConfig::preset(preset).unwrap();
        let params = rt.manifest.load_params(preset).unwrap();
        let mut gen = DataGen::new(cfg.clone(), 12);
        let batch = gen.next_batch();
        let mut run = |naive: bool| -> f64 {
            let _ = single_device_forward(&rt, preset, &params, &batch.msa_tokens, naive);
            let times: Vec<f64> = (0..5)
                .map(|_| {
                    let t0 = std::time::Instant::now();
                    single_device_forward(&rt, preset, &params, &batch.msa_tokens, naive)
                        .unwrap();
                    t0.elapsed().as_secs_f64()
                })
                .collect();
            median(times)
        };
        let naive = run(true);
        let fused = run(false);
        t.row(&[
            preset.into(),
            cfg.n_res.to_string(),
            format!("{:.1}", naive * 1e3),
            format!("{:.1}", fused * 1e3),
            format!("{:.2}x", naive / fused),
        ]);
    }
    t.print();
    println!("\n(the fused-vs-naive delta is the kernel contribution the paper");
    println!(" measures against OpenFold; the 2.01–4.05x AlphaFold-JAX gap adds");
    println!(" framework overhead our single-backend setup deliberately excludes.)");
}
