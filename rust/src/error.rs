//! Crate-wide error type. Everything funnels into [`Error`]; `Result<T>` is
//! the crate-default result alias.

use thiserror::Error;

#[derive(Error, Debug)]
pub enum Error {
    #[error("xla error: {0}")]
    Xla(#[from] xla::Error),

    #[error("io error: {0}")]
    Io(#[from] std::io::Error),

    #[error("manifest error: {0}")]
    Manifest(String),

    #[error("json parse error: {0}")]
    Json(String),

    #[error("config error: {0}")]
    Config(String),

    #[error("shape mismatch: {0}")]
    Shape(String),

    #[error("communicator error: {0}")]
    Comm(String),

    #[error("scheduler error: {0}")]
    Schedule(String),

    #[error("out of (simulated) device memory: need {need_gib:.2} GiB, capacity {cap_gib:.2} GiB")]
    SimOom { need_gib: f64, cap_gib: f64 },

    #[error("{0}")]
    Msg(String),
}

impl Error {
    pub fn msg(s: impl Into<String>) -> Self {
        Error::Msg(s.into())
    }
}

pub type Result<T> = std::result::Result<T, Error>;
