//! Crate-wide error type. Everything funnels into [`Error`]; `Result<T>` is
//! the crate-default result alias. Hand-rolled `Display`/`From` impls (the
//! offline build has no `thiserror`).

use std::fmt;

/// All failure modes of the coordinator, runtime, and models.
#[derive(Debug)]
pub enum Error {
    /// Error from the XLA/PJRT layer.
    Xla(xla::Error),
    /// Filesystem / IO failure.
    Io(std::io::Error),
    /// Artifact-manifest contract violation.
    Manifest(String),
    /// JSON parse failure.
    Json(String),
    /// Bad configuration (preset, TOML, CLI flag).
    Config(String),
    /// Tensor shape mismatch.
    Shape(String),
    /// Collective-communication misuse.
    Comm(String),
    /// DAP schedule violation.
    Schedule(String),
    /// The memory model says this plan exceeds device capacity
    /// (the paper's Table V OOM verdict).
    SimOom {
        /// Required memory in decimal GB.
        need_gb: f64,
        /// Device capacity in decimal GB.
        cap_gb: f64,
    },
    /// A bounded collective wait expired: the comm worker (or an
    /// injected stall) failed to deliver within the configured
    /// `[comm] wait_timeout_ms`, so the waiter surfaces a structured
    /// timeout instead of joining forever.
    CommTimeout {
        /// Collective op that stalled (e.g. `gather[n=4]`).
        op: String,
        /// Rank that was blocked waiting on the result.
        rank: usize,
        /// How long the waiter was prepared to wait, milliseconds.
        waited_ms: u64,
    },
    /// A DP rank is permanently lost (heartbeat plane declared it dead);
    /// the trainer recovers by rollback + dp-shrink re-plan.
    RankLost {
        /// The dead rank.
        rank: usize,
        /// 1-based optimizer step at which the loss was detected.
        step: usize,
    },
    /// Free-form error message.
    Msg(String),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Xla(e) => write!(f, "xla error: {e}"),
            Error::Io(e) => write!(f, "io error: {e}"),
            Error::Manifest(s) => write!(f, "manifest error: {s}"),
            Error::Json(s) => write!(f, "json parse error: {s}"),
            Error::Config(s) => write!(f, "config error: {s}"),
            Error::Shape(s) => write!(f, "shape mismatch: {s}"),
            Error::Comm(s) => write!(f, "communicator error: {s}"),
            Error::Schedule(s) => write!(f, "scheduler error: {s}"),
            Error::SimOom { need_gb, cap_gb } => write!(
                f,
                "out of (simulated) device memory: need {need_gb:.2} GB, \
                 capacity {cap_gb:.2} GB"
            ),
            Error::CommTimeout { op, rank, waited_ms } => write!(
                f,
                "collective timeout: rank {rank} waited {waited_ms} ms for \
                 '{op}' with no reply"
            ),
            Error::RankLost { rank, step } => write!(
                f,
                "rank {rank} lost at step {step} (heartbeat declared dead)"
            ),
            Error::Msg(s) => write!(f, "{s}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Xla(e) => Some(e),
            Error::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e)
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

impl Error {
    /// Build a free-form [`Error::Msg`].
    pub fn msg(s: impl Into<String>) -> Self {
        Error::Msg(s.into())
    }
}

/// Crate-default result alias.
pub type Result<T> = std::result::Result<T, Error>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_matches_legacy_format() {
        let e = Error::SimOom { need_gb: 43.5, cap_gb: 40.0 };
        let s = e.to_string();
        assert!(s.contains("43.50") && s.contains("40.00"), "{s}");
        assert!(Error::Config("x".into()).to_string().starts_with("config error"));
    }

    #[test]
    fn io_conversion_keeps_source() {
        let ioe = std::io::Error::new(std::io::ErrorKind::NotFound, "gone");
        let e: Error = ioe.into();
        assert!(std::error::Error::source(&e).is_some());
        assert!(e.to_string().contains("gone"));
    }
}
