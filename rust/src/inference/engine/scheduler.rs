//! Multi-request scheduler: deterministic queue ordering (FIFO or
//! shortest-job-first by modeled latency, both priority-aware) plus the
//! lane simulation that turns a schedule into a modeled makespan.
//!
//! Determinism is the contract: the order depends only on the entries
//! (priority, modeled latency, arrival index) — never on thread timing —
//! so the same request set produces the same schedule, the same backend
//! choices, and (results landing slot-indexed in the engine's
//! work-conserving drain loop) bit-for-bit the same outputs at any
//! `--threads` budget.
//!
//! SJF carries a **starvation guard**: once `max_bypass` later arrivals
//! have overtaken a waiting request, it runs next (oldest starved first,
//! regardless of priority). Pure SJF pushes the one long DAP request to
//! the back of every batch; the guard bounds that displacement.

use crate::error::{Error, Result};

/// Queue discipline for the serving layer.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SchedPolicy {
    /// Arrival order within priority classes.
    Fifo,
    /// Shortest modeled latency first within priority classes, with the
    /// aging starvation guard.
    Sjf,
}

impl SchedPolicy {
    /// Parse a config/CLI policy name (`fifo`, `sjf`).
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "fifo" => Ok(SchedPolicy::Fifo),
            "sjf" => Ok(SchedPolicy::Sjf),
            other => Err(Error::Config(format!(
                "unknown scheduling policy '{other}' (known: fifo, sjf)"
            ))),
        }
    }

    /// Stable display name.
    pub fn name(self) -> &'static str {
        match self {
            SchedPolicy::Fifo => "fifo",
            SchedPolicy::Sjf => "sjf",
        }
    }
}

/// One schedulable request as the scheduler sees it.
#[derive(Clone, Copy, Debug)]
pub struct SchedEntry {
    /// Submission index (FIFO key, SJF tie-break, starvation age).
    pub arrival: usize,
    /// Smaller runs sooner; requests default to 0.
    pub priority: u32,
    /// The placement planner's modeled latency (SJF key).
    pub modeled_latency: f64,
}

/// Deterministic execution order over `entries`: returns indices into
/// `entries`. `max_bypass` is the SJF starvation bound (ignored by FIFO);
/// `0` degenerates to pure arrival order.
pub fn schedule_order(
    policy: SchedPolicy,
    entries: &[SchedEntry],
    max_bypass: usize,
) -> Vec<usize> {
    let n = entries.len();
    match policy {
        SchedPolicy::Fifo => {
            let mut idx: Vec<usize> = (0..n).collect();
            idx.sort_by_key(|&i| (entries[i].priority, entries[i].arrival));
            idx
        }
        SchedPolicy::Sjf => {
            let mut scheduled = vec![false; n];
            let mut overtaken = vec![0usize; n];
            let mut order = Vec::with_capacity(n);
            for _ in 0..n {
                // aged request? oldest one runs next, whatever its length
                let starved = (0..n)
                    .filter(|&i| !scheduled[i] && overtaken[i] >= max_bypass)
                    .min_by_key(|&i| entries[i].arrival);
                let pick = match starved {
                    Some(i) => i,
                    None => (0..n)
                        .filter(|&i| !scheduled[i])
                        .min_by(|&a, &b| {
                            entries[a]
                                .priority
                                .cmp(&entries[b].priority)
                                .then(
                                    entries[a]
                                        .modeled_latency
                                        .total_cmp(&entries[b].modeled_latency),
                                )
                                .then(entries[a].arrival.cmp(&entries[b].arrival))
                        })
                        .expect("schedule_order: empty candidate set"),
                };
                scheduled[pick] = true;
                for (i, &done) in scheduled.iter().enumerate() {
                    if !done && entries[i].arrival < entries[pick].arrival {
                        overtaken[i] += 1;
                    }
                }
                order.push(pick);
            }
            order
        }
    }
}

/// Incremental form of [`schedule_order`] for the daemon's event loop:
/// pick the next entry to dispatch from a live `queue` of
/// `(entry, overtaken_count)` pairs. Returns an index into `queue`, or
/// `None` when the queue is empty.
///
/// The starvation guard applies to *both* policies here (the one-shot
/// batch FIFO never needs it — it always picks the oldest — but a live
/// FIFO queue with priority classes can starve a low-priority request,
/// so the daemon ages it the same way): any entry overtaken by
/// `max_bypass` or more younger dispatches runs next, oldest first.
/// Otherwise FIFO picks min `(priority, arrival)` and SJF picks min
/// `(priority, modeled_latency, arrival)` — exactly the batch keys, so
/// an all-arrived-at-once daemon replays the batch order bit-for-bit.
/// The caller owns the bookkeeping: after a dispatch, bump `overtaken`
/// on every remaining entry with an older `arrival`.
pub fn pick_next(
    policy: SchedPolicy,
    queue: &[(SchedEntry, usize)],
    max_bypass: usize,
) -> Option<usize> {
    if queue.is_empty() {
        return None;
    }
    let starved = (0..queue.len())
        .filter(|&i| queue[i].1 >= max_bypass)
        .min_by_key(|&i| queue[i].0.arrival);
    if let Some(i) = starved {
        return Some(i);
    }
    (0..queue.len()).min_by(|&a, &b| {
        let (ea, eb) = (&queue[a].0, &queue[b].0);
        let by_class = ea.priority.cmp(&eb.priority);
        let by_len = match policy {
            SchedPolicy::Fifo => std::cmp::Ordering::Equal,
            SchedPolicy::Sjf => ea.modeled_latency.total_cmp(&eb.modeled_latency),
        };
        by_class.then(by_len).then(ea.arrival.cmp(&eb.arrival))
    })
}

/// Greedy lane assignment of latencies in schedule order: each job starts
/// on the earliest-free of `lanes` lanes (ties → lowest lane index).
/// Returns the modeled start time per scheduled slot and the makespan —
/// the denominator of the aggregate modeled PFLOP/s figure.
pub fn simulate_lanes(latencies_in_order: &[f64], lanes: usize) -> (Vec<f64>, f64) {
    let lanes = lanes.max(1);
    let mut free = vec![0.0f64; lanes];
    let mut starts = Vec::with_capacity(latencies_in_order.len());
    for &lat in latencies_in_order {
        let mut best = 0usize;
        for k in 1..lanes {
            if free[k] < free[best] {
                best = k;
            }
        }
        starts.push(free[best]);
        free[best] += lat.max(0.0);
    }
    let makespan = free.into_iter().fold(0.0, f64::max);
    (starts, makespan)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn entries(lats: &[f64]) -> Vec<SchedEntry> {
        lats.iter()
            .enumerate()
            .map(|(i, &l)| SchedEntry { arrival: i, priority: 0, modeled_latency: l })
            .collect()
    }

    #[test]
    fn fifo_is_arrival_order_within_priority() {
        let mut es = entries(&[5.0, 1.0, 3.0]);
        assert_eq!(schedule_order(SchedPolicy::Fifo, &es, 4), vec![0, 1, 2]);
        es[2].priority = 0;
        es[0].priority = 1; // demote the first arrival
        assert_eq!(schedule_order(SchedPolicy::Fifo, &es, 4), vec![1, 2, 0]);
    }

    #[test]
    fn sjf_orders_by_modeled_latency() {
        let es = entries(&[5.0, 1.0, 3.0, 1.0]);
        // ties broken by arrival: both 1.0s keep their relative order
        assert_eq!(schedule_order(SchedPolicy::Sjf, &es, 100), vec![1, 3, 2, 0]);
    }

    #[test]
    fn sjf_starvation_guard_bounds_displacement() {
        // one long job arrives first, nine short ones after it
        let mut lats = vec![100.0];
        lats.extend(vec![1.0; 9]);
        let es = entries(&lats);
        // unguarded: the long job is dead last
        let loose = schedule_order(SchedPolicy::Sjf, &es, 100);
        assert_eq!(loose.iter().position(|&i| i == 0), Some(9));
        // guarded: at most 3 shorter jobs may overtake it
        let tight = schedule_order(SchedPolicy::Sjf, &es, 3);
        assert_eq!(tight.iter().position(|&i| i == 0), Some(3));
        // every job still runs exactly once
        let mut seen = tight.clone();
        seen.sort_unstable();
        assert_eq!(seen, (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn max_bypass_zero_is_arrival_order() {
        let es = entries(&[5.0, 1.0, 3.0]);
        assert_eq!(schedule_order(SchedPolicy::Sjf, &es, 0), vec![0, 1, 2]);
    }

    #[test]
    fn schedule_is_deterministic() {
        let es = entries(&[4.0, 4.0, 2.0, 8.0, 2.0]);
        for policy in [SchedPolicy::Fifo, SchedPolicy::Sjf] {
            let a = schedule_order(policy, &es, 2);
            let b = schedule_order(policy, &es, 2);
            assert_eq!(a, b, "{}", policy.name());
        }
    }

    #[test]
    fn lanes_pack_greedily() {
        let (starts, makespan) = simulate_lanes(&[3.0, 1.0, 1.0, 1.0], 2);
        // lane0: [3], lane1: [1,1,1] → makespan 3
        assert_eq!(starts, vec![0.0, 0.0, 1.0, 2.0]);
        assert!((makespan - 3.0).abs() < 1e-12);
        let (_, serial) = simulate_lanes(&[3.0, 1.0, 1.0, 1.0], 1);
        assert!((serial - 6.0).abs() < 1e-12);
        let (s, m) = simulate_lanes(&[], 4);
        assert!(s.is_empty() && m == 0.0);
    }

    /// Drain a queue through `pick_next` with the documented overtaken
    /// bookkeeping and return the dispatch order as entry indices.
    fn drain_incremental(
        policy: SchedPolicy,
        es: &[SchedEntry],
        max_bypass: usize,
    ) -> Vec<usize> {
        let mut queue: Vec<(usize, SchedEntry, usize)> =
            es.iter().enumerate().map(|(i, &e)| (i, e, 0)).collect();
        let mut order = Vec::with_capacity(es.len());
        while !queue.is_empty() {
            let view: Vec<(SchedEntry, usize)> =
                queue.iter().map(|&(_, e, o)| (e, o)).collect();
            let k = pick_next(policy, &view, max_bypass).expect("non-empty");
            let (idx, picked, _) = queue.remove(k);
            for item in &mut queue {
                if item.1.arrival < picked.arrival {
                    item.2 += 1;
                }
            }
            order.push(idx);
        }
        order
    }

    #[test]
    fn pick_next_matches_batch_sjf() {
        let mut es = entries(&[100.0, 1.0, 3.0, 1.0, 8.0, 2.0, 50.0, 4.0]);
        es[4].priority = 2;
        es[1].priority = 1;
        for max_bypass in [0, 1, 3, 100] {
            assert_eq!(
                drain_incremental(SchedPolicy::Sjf, &es, max_bypass),
                schedule_order(SchedPolicy::Sjf, &es, max_bypass),
                "max_bypass={max_bypass}"
            );
        }
    }

    #[test]
    fn pick_next_matches_batch_fifo_uniform_priority() {
        // with one priority class FIFO always dispatches the oldest, so
        // the guard never fires and incremental == batch at any bound
        let es = entries(&[5.0, 1.0, 3.0, 9.0, 2.0]);
        for max_bypass in [1, 4, 100] {
            assert_eq!(
                drain_incremental(SchedPolicy::Fifo, &es, max_bypass),
                schedule_order(SchedPolicy::Fifo, &es, max_bypass),
            );
        }
    }

    #[test]
    fn pick_next_ages_starved_fifo_priorities() {
        // live FIFO: priority-1 oldest entry is bypassed by younger
        // priority-0 arrivals until the guard promotes it
        let mut es = entries(&[5.0, 1.0, 1.0, 1.0, 1.0]);
        es[0].priority = 1;
        let order = drain_incremental(SchedPolicy::Fifo, &es, 2);
        assert_eq!(order.iter().position(|&i| i == 0), Some(2));
        assert_eq!(pick_next(SchedPolicy::Fifo, &[], 2), None);
    }

    #[test]
    fn policy_names_roundtrip() {
        for p in [SchedPolicy::Fifo, SchedPolicy::Sjf] {
            assert_eq!(SchedPolicy::parse(p.name()).unwrap(), p);
        }
        assert!(SchedPolicy::parse("lifo").is_err());
    }
}
