//! Placement planner: the cost-model-driven "which backend serves this
//! request" decision.
//!
//! For every [`InferRequest`] the planner prices the request's modeled
//! shape against the perf models — FLOPs ([`model_flops`]), memory (the
//! AutoChunk planner over [`MemoryModel`]), latency ([`ScalingModel`]) —
//! and walks the decision tree:
//!
//! 1. fits unchunked on one device → [`BackendKind::SingleDevice`];
//! 2. fits with per-module chunking → [`BackendKind::Chunked`] (latency
//!    scaled by the plan's chunk overhead);
//! 3. sim-OOM on one device → smallest DAP degree ≤ `max_dap` whose plan
//!    fits → [`BackendKind::Dap`];
//! 4. nothing fits → the request is **rejected at admission** with the
//!    same [`Error::SimOom`] verdict the memory guard raises (Table V's
//!    OOM rows) — the engine reports it instead of thrashing.
//!
//! This is ScaleFold's observation (arXiv 2404.11068) applied to serving:
//! strategy selection is a cost-model query, not a launch flag.

use crate::config::{ModelConfig, RunConfig};
use crate::error::{Error, Result};
use crate::inference::autochunk::{self, AutoChunkPlan};
use crate::perfmodel::flops::model_flops;
use crate::perfmodel::gpu::ImplProfile;
use crate::perfmodel::scaling::{MpMethod, ScalingModel, INFER_RECYCLES};
use crate::perfmodel::{GpuSpec, MemoryModel};

use super::InferRequest;

/// Which execution strategy a request is placed on.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum BackendKind {
    /// Single-device trunk execution (short sequences, Fig 12).
    SingleDevice,
    /// Single device with the AutoChunk plan applied (long sequences that
    /// still fit one device, paper §IV).
    Chunked,
    /// Dynamic Axial Parallelism at the given degree (Fig 13 / Table V).
    Dap(usize),
}

impl BackendKind {
    /// Stable display name: `single`, `chunked`, `dap<N>`.
    pub fn name(&self) -> String {
        match self {
            BackendKind::SingleDevice => "single".into(),
            BackendKind::Chunked => "chunked".into(),
            BackendKind::Dap(n) => format!("dap{n}"),
        }
    }

    /// DAP degree this backend occupies (1 for the single-device paths).
    pub fn dap_degree(&self) -> usize {
        match self {
            BackendKind::Dap(n) => (*n).max(1),
            _ => 1,
        }
    }

    /// Inverse of [`BackendKind::name`] (request files name backends).
    /// Degree-1 "DAP" is not a distinct strategy — `dap1`/`dap0` are
    /// rejected, matching the request-file `dap` key (degree ≥ 2 pins).
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "single" | "single_device" => Ok(BackendKind::SingleDevice),
            "chunked" => Ok(BackendKind::Chunked),
            other => other
                .strip_prefix("dap")
                .and_then(|n| n.parse::<usize>().ok())
                .filter(|&n| n >= 2)
                .map(BackendKind::Dap)
                .ok_or_else(|| {
                    Error::Config(format!(
                        "unknown backend '{other}' (known: single, chunked, dap<N> with N >= 2)"
                    ))
                }),
        }
    }
}

/// The planner's verdict for one admitted request.
#[derive(Clone, Debug)]
pub struct Placement {
    /// Chosen execution strategy.
    pub backend: BackendKind,
    /// The AutoChunk plan backing the decision (None with the guard off).
    pub plan: Option<AutoChunkPlan>,
    /// Modeled end-to-end latency at paper scale (seconds) — the SJF key.
    pub modeled_latency: f64,
    /// Modeled forward FLOPs for the whole request (all recycles).
    pub modeled_flops: f64,
    /// Modeled peak device memory under the placement (decimal GB).
    pub modeled_peak_gb: f64,
}

impl Placement {
    /// Modeled device-aggregate throughput of this request's placement.
    pub fn modeled_pflops(&self) -> f64 {
        if self.modeled_latency > 0.0 {
            self.modeled_flops / self.modeled_latency / 1e15
        } else {
            0.0
        }
    }
}

/// The pure (no [`crate::runtime::Runtime`] needed) placement policy: cost
/// models + admission bounds. Fields are public so deployments can swap a
/// tuned [`MemoryModel`] or a different device/profile.
#[derive(Clone, Debug)]
pub struct PlacementPlanner {
    /// Memory model the AutoChunk planner budgets against.
    pub mem: MemoryModel,
    /// Device every backend is priced on.
    pub gpu: GpuSpec,
    /// AutoChunk headroom fraction (see `[autochunk] headroom`).
    pub headroom: f64,
    /// Consult the memory guard at all (`[autochunk] enabled`); with the
    /// guard off every unforced request places on `SingleDevice`.
    pub guard: bool,
    /// Largest DAP degree the fleet offers (admission bound).
    pub max_dap: usize,
    /// Latency model for the SJF key and throughput accounting.
    pub scaling: ScalingModel,
    /// Kernel-quality profile requests execute with.
    pub profile: ImplProfile,
    /// Mandatory admission verification: every DAP placement first proves
    /// its schedule hazard-free ([`crate::analysis::admit`]). `false` is
    /// the `--unsafe-skip-verify` escape hatch for benchmarking the
    /// verifier's own cost.
    pub verify: bool,
}

impl PlacementPlanner {
    /// Build the planner from a launcher config (`[autochunk]` + `[serve]`).
    pub fn from_run_config(cfg: &RunConfig) -> Result<Self> {
        Ok(PlacementPlanner {
            mem: MemoryModel::default(),
            gpu: GpuSpec::by_name(&cfg.autochunk.gpu)?,
            headroom: cfg.autochunk.headroom,
            guard: cfg.autochunk.enabled,
            max_dap: cfg.serve.max_dap,
            scaling: ScalingModel::default(),
            // price requests at the configured device backend — the
            // planner never names a concrete backend, the profile map does
            profile: ImplProfile::for_device_backend(&cfg.device.backend),
            verify: true,
        })
    }

    /// The config the cost models price the request at: the executable
    /// preset's own shape, or the paper-scale inference shape when the
    /// request carries a modeled length (`len` in the request file). The
    /// preset is validated either way — placement must not outlive a typo.
    pub fn plan_cfg(&self, req: &InferRequest) -> Result<ModelConfig> {
        let preset_cfg = ModelConfig::preset(&req.preset)?;
        Ok(match req.model_len {
            Some(len) => ModelConfig::inference(len),
            None => preset_cfg,
        })
    }

    /// Modeled end-to-end latency of `cfg` itself — priced on the same
    /// architecture as `modeled_flops`, so modeled PFLOP/s is a real
    /// ratio for preset-shaped requests too. (For `inference(len)` shapes
    /// this equals [`ScalingModel::inference_latency`] at `chunked =
    /// false`, times the plan's chunk factor.)
    fn latency(&self, cfg: &ModelConfig, dap: usize, chunk_factor: f64) -> f64 {
        let t = self.scaling.mp_block_time(
            cfg, &self.profile, MpMethod::Dap, dap.max(1), false, true,
        );
        cfg.n_blocks as f64 * self.scaling.pipeline_mult * t.total() * INFER_RECYCLES
            * chunk_factor
    }

    /// Place one request, or reject it ([`Error::SimOom`]) when no fleet
    /// strategy up to `max_dap` can hold it. A DAP placement is admitted
    /// only after the static schedule verifier proves its program
    /// hazard-free — "crashes mid-run" becomes "rejected at admission".
    pub fn place(&self, req: &InferRequest) -> Result<Placement> {
        let placement = self.place_unverified(req)?;
        if self.verify {
            if let BackendKind::Dap(n) = placement.backend {
                let cfg = self.plan_cfg(req)?;
                crate::analysis::admit("engine", &cfg, n)?;
            }
        }
        Ok(placement)
    }

    fn place_unverified(&self, req: &InferRequest) -> Result<Placement> {
        let cfg = self.plan_cfg(req)?;
        let flops = model_flops(&cfg) * INFER_RECYCLES;

        // forced backend (legacy CLI paths: `--dap N`): the guard still
        // vets the degree when enabled, exactly as the old entry points
        // did — and the fleet bound applies to pinned degrees too
        if let Some(force) = &req.force {
            let degree = force.dap_degree();
            if degree > self.max_dap {
                return Err(Error::Config(format!(
                    "request pins dap{degree} but the fleet serves at most \
                     dap{} ([serve] max_dap)",
                    self.max_dap
                )));
            }
            let plan = if self.guard {
                Some(autochunk::plan_with_headroom(
                    &cfg, &self.mem, &self.gpu, degree, self.headroom,
                )?)
            } else {
                None
            };
            let chunk_factor = match (force, &plan) {
                (BackendKind::Chunked, Some(p)) => p.latency_factor,
                (BackendKind::Chunked, None) => 1.3, // α–β chunk penalty, no plan
                _ => 1.0,
            };
            let peak = plan
                .as_ref()
                .map(|p| p.peak_bytes)
                .unwrap_or_else(|| self.mem.unchunked_peak_bytes(&cfg, degree));
            return Ok(Placement {
                backend: force.clone(),
                modeled_latency: self.latency(&cfg, degree, chunk_factor),
                modeled_flops: flops,
                modeled_peak_gb: peak / 1e9,
                plan,
            });
        }

        if !self.guard {
            return Ok(Placement {
                backend: BackendKind::SingleDevice,
                plan: None,
                modeled_latency: self.latency(&cfg, 1, 1.0),
                modeled_flops: flops,
                modeled_peak_gb: self.mem.unchunked_peak_bytes(&cfg, 1) / 1e9,
            });
        }

        match autochunk::plan_with_headroom(&cfg, &self.mem, &self.gpu, 1, self.headroom) {
            Ok(plan) => {
                let backend = if plan.is_chunked() {
                    BackendKind::Chunked
                } else {
                    BackendKind::SingleDevice
                };
                Ok(Placement {
                    backend,
                    modeled_latency: self.latency(&cfg, 1, plan.latency_factor),
                    modeled_flops: flops,
                    modeled_peak_gb: plan.peak_bytes / 1e9,
                    plan: Some(plan),
                })
            }
            Err(oom @ Error::SimOom { .. }) => {
                // degree 1 just failed, so the fallback search starts at 2
                // (power-of-two degrees, like autochunk::min_dap_degree)
                let mut found = None;
                let mut n = 2usize;
                while n <= self.max_dap {
                    if let Ok(p) = autochunk::plan_with_headroom(
                        &cfg, &self.mem, &self.gpu, n, self.headroom,
                    ) {
                        found = Some((n, p));
                        break;
                    }
                    n *= 2;
                }
                match found {
                    Some((n, plan)) => Ok(Placement {
                        backend: BackendKind::Dap(n),
                        modeled_latency: self.latency(&cfg, n, plan.latency_factor),
                        modeled_flops: flops,
                        modeled_peak_gb: plan.peak_bytes / 1e9,
                        plan: Some(plan),
                    }),
                    // admission control: nothing in the fleet fits
                    None => Err(oom),
                }
            }
            Err(e) => Err(e),
        }
    }
}

/// A memoized planner verdict: placements are shared via `Arc`;
/// rejections are stored in a reconstructable form because [`Error`]
/// is not `Clone` ([`Error::SimOom`] keeps its fields so admission
/// rejections replay with their exact verdict, anything else replays
/// as a message-preserving [`Error::Msg`]).
enum MemoVerdict {
    Placed(std::sync::Arc<Placement>),
    SimOom {
        need_gb: f64,
        cap_gb: f64,
    },
    Rejected(String),
}

impl MemoVerdict {
    fn to_result(&self) -> Result<std::sync::Arc<Placement>> {
        match self {
            MemoVerdict::Placed(p) => Ok(std::sync::Arc::clone(p)),
            MemoVerdict::SimOom { need_gb, cap_gb } => {
                Err(Error::SimOom { need_gb: *need_gb, cap_gb: *cap_gb })
            }
            MemoVerdict::Rejected(msg) => Err(Error::msg(msg.clone())),
        }
    }
}

/// Memoizing view over a [`PlacementPlanner`] for the daemon's event
/// loop: placement depends only on the request's modeled shape
/// (`preset`, `len`) and pinned backend, so a million-request trace with
/// a handful of distinct shapes prices each shape once. Rejections are
/// memoized too — admission control must not get cheaper on repeat
/// offenders.
pub struct MemoPlanner<'p> {
    planner: &'p PlacementPlanner,
    memo: std::collections::BTreeMap<String, MemoVerdict>,
    hits: u64,
    misses: u64,
}

impl<'p> MemoPlanner<'p> {
    /// A fresh memo over `planner`.
    pub fn new(planner: &'p PlacementPlanner) -> Self {
        MemoPlanner { planner, memo: std::collections::BTreeMap::new(), hits: 0, misses: 0 }
    }

    /// The fields [`PlacementPlanner::place`] actually reads.
    fn memo_key(req: &InferRequest) -> String {
        format!(
            "{}|{}|{}",
            req.preset,
            req.model_len.map_or_else(|| "-".into(), |l| l.to_string()),
            req.force.as_ref().map_or_else(|| "-".into(), BackendKind::name),
        )
    }

    /// Place `req`, consulting the memo first. Cached placements come
    /// back as clones of one shared `Arc`.
    pub fn place(&mut self, req: &InferRequest) -> Result<std::sync::Arc<Placement>> {
        let key = Self::memo_key(req);
        if let Some(v) = self.memo.get(&key) {
            self.hits += 1;
            return v.to_result();
        }
        self.misses += 1;
        let verdict = match self.planner.place(req) {
            Ok(p) => MemoVerdict::Placed(std::sync::Arc::new(p)),
            Err(Error::SimOom { need_gb, cap_gb }) => MemoVerdict::SimOom { need_gb, cap_gb },
            Err(e) => MemoVerdict::Rejected(e.to_string()),
        };
        let out = verdict.to_result();
        self.memo.insert(key, verdict);
        out
    }

    /// Memo hits so far.
    pub fn hits(&self) -> u64 {
        self.hits
    }

    /// Memo misses (distinct shapes priced) so far.
    pub fn misses(&self) -> u64 {
        self.misses
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn planner() -> PlacementPlanner {
        PlacementPlanner {
            mem: MemoryModel::default(),
            gpu: GpuSpec::a100_40g(),
            headroom: autochunk::CHUNK_HEADROOM,
            guard: true,
            max_dap: 8,
            scaling: ScalingModel::default(),
            profile: ImplProfile::fastfold(),
            verify: true,
        }
    }

    fn req(len: usize) -> InferRequest {
        InferRequest { model_len: Some(len), ..InferRequest::new("r", "tiny") }
    }

    #[test]
    fn run_config_backend_prices_the_profile() {
        let mut cfg = RunConfig::default();
        let p = PlacementPlanner::from_run_config(&cfg).unwrap();
        assert_eq!(p.profile.name, "FastFold");
        cfg.device.backend = "scalar".into();
        let p = PlacementPlanner::from_run_config(&cfg).unwrap();
        assert_eq!(p.profile.name, "ScalarHost");
    }

    #[test]
    fn decision_tree_follows_table5() {
        let p = planner();
        // short: unchunked single device
        let short = p.place(&req(512)).unwrap();
        assert_eq!(short.backend, BackendKind::SingleDevice);
        // long but fits one device with chunking
        let long = p.place(&req(2048)).unwrap();
        assert_eq!(long.backend, BackendKind::Chunked);
        assert!(long.plan.as_ref().unwrap().is_chunked());
        // past the single-device boundary: smallest fitting DAP degree
        let dist = p.place(&req(4096)).unwrap();
        assert_eq!(dist.backend, BackendKind::Dap(8));
        assert!(dist.modeled_peak_gb <= p.gpu.memory / 1e9);
    }

    #[test]
    fn dap_admission_gate_is_transparent_for_hazard_free_schedules() {
        // the shipping schedule proves hazard-free, so the mandatory
        // static-verify step must not change any placement verdict —
        // and the --unsafe-skip-verify hatch must agree with it
        let mut p = planner();
        let r = req(4096);
        let verified = p.place(&r).unwrap();
        assert_eq!(verified.backend, BackendKind::Dap(8));
        p.verify = false;
        let skipped = p.place(&r).unwrap();
        assert_eq!(verified.backend, skipped.backend);
    }

    #[test]
    fn admission_rejects_beyond_fleet() {
        let mut p = planner();
        p.max_dap = 4; // 4096 needs DAP-8 (Table V)
        let e = p.place(&req(4096)).unwrap_err();
        assert!(matches!(e, Error::SimOom { .. }), "{e}");
    }

    #[test]
    fn guard_off_places_single_unconditionally() {
        let mut p = planner();
        p.guard = false;
        let pl = p.place(&req(4096)).unwrap();
        assert_eq!(pl.backend, BackendKind::SingleDevice);
        assert!(pl.plan.is_none());
    }

    #[test]
    fn forced_backend_honored_and_vetted() {
        let p = planner();
        let mut r = req(2048);
        r.force = Some(BackendKind::Dap(4));
        let pl = p.place(&r).unwrap();
        assert_eq!(pl.backend, BackendKind::Dap(4));
        assert!(pl.plan.is_some());
        // a forced degree the guard refuses propagates the verdict
        let mut r = req(4096);
        r.force = Some(BackendKind::Dap(4));
        assert!(matches!(p.place(&r).unwrap_err(), Error::SimOom { .. }));
        // the fleet bound applies to pinned degrees too
        let mut r = req(512);
        r.force = Some(BackendKind::Dap(16));
        assert!(matches!(p.place(&r).unwrap_err(), Error::Config(_)));
    }

    #[test]
    fn modeled_latency_orders_by_length() {
        let p = planner();
        let a = p.place(&req(512)).unwrap().modeled_latency;
        let b = p.place(&req(2048)).unwrap().modeled_latency;
        assert!(b > a, "{b} vs {a}");
        assert!(p.place(&req(512)).unwrap().modeled_pflops() > 0.0);
        // a preset-shaped request is priced on its own architecture for
        // both flops AND latency — the tiny preset is far cheaper than
        // any paper-scale shape
        let tiny = p.place(&InferRequest::new("t", "tiny")).unwrap();
        assert!(tiny.modeled_latency < a, "{} vs {a}", tiny.modeled_latency);
        assert!(tiny.modeled_pflops() > 0.0);
    }

    #[test]
    fn len_requests_match_inference_latency_model() {
        // for inference(len) shapes the placement latency must agree with
        // the ScalingModel's headline inference_latency (unchunked case)
        let p = planner();
        let pl = p.place(&req(512)).unwrap();
        assert!(!pl.plan.as_ref().unwrap().is_chunked());
        let want = p
            .scaling
            .inference_latency(512, &p.profile, MpMethod::Dap, 1, false);
        assert!(
            (pl.modeled_latency - want).abs() <= 1e-9 * want,
            "{} vs {want}",
            pl.modeled_latency
        );
    }

    #[test]
    fn backend_kind_names_roundtrip() {
        for k in [BackendKind::SingleDevice, BackendKind::Chunked, BackendKind::Dap(4)] {
            assert_eq!(BackendKind::parse(&k.name()).unwrap(), k);
        }
        assert!(BackendKind::parse("dap0").is_err());
        assert!(BackendKind::parse("dap1").is_err(), "degree-1 DAP is 'single'");
        assert!(BackendKind::parse("gpu").is_err());
    }

    #[test]
    fn unknown_preset_rejected_even_with_model_len() {
        let p = planner();
        let r = InferRequest { model_len: Some(512), ..InferRequest::new("r", "nope") };
        assert!(p.place(&r).is_err());
    }

    #[test]
    fn memo_planner_shares_placements_and_replays_verdicts() {
        let p = planner();
        let mut memo = MemoPlanner::new(&p);
        let a = memo.place(&req(2048)).unwrap();
        // different id/priority/seed, same shape → same shared placement
        let mut dup = req(2048);
        dup.id = "other".into();
        dup.priority = 3;
        let b = memo.place(&dup).unwrap();
        assert!(std::sync::Arc::ptr_eq(&a, &b));
        assert_eq!((memo.hits(), memo.misses()), (1, 1));
        assert_eq!(a.backend, p.place(&req(2048)).unwrap().backend);

        // admission rejections replay with their SimOom verdict intact
        let mut bounded = p.clone();
        bounded.max_dap = 4;
        let mut memo = MemoPlanner::new(&bounded);
        let first = memo.place(&req(4096)).unwrap_err();
        let again = memo.place(&req(4096)).unwrap_err();
        match (first, again) {
            (
                Error::SimOom { need_gb: n1, cap_gb: c1 },
                Error::SimOom { need_gb: n2, cap_gb: c2 },
            ) => assert_eq!((n1, c1), (n2, c2)),
            other => panic!("expected SimOom twice, got {other:?}"),
        }
        assert_eq!(memo.hits(), 1);
    }
}
