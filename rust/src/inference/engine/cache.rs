//! Sequence-hash result cache: identical requests short-circuit to a
//! stored result instead of re-folding (ParaFold's observation that
//! production batches are full of repeated proteins).
//!
//! The index key is an FNV-1a hash of the request's *content string*
//! (every field except the caller-visible id — preset, modeled length,
//! priority, kernel variant, input seed, pinned backend), and every
//! entry stores that full content string: a lookup verifies exact
//! content equality, so two distinct requests that collide in the hash
//! can never serve each other's bits — a collision is just a miss.
//!
//! Eviction is LRU under a byte budget. Entries are priced by the
//! caller (the daemon prices them at the modeled output size of the
//! request shape; the executed path prices real tensor bytes), and an
//! insert evicts least-recently-used entries until the new entry fits.
//! An entry larger than the whole budget is not admitted at all.
//!
//! Entries carry a `ready_at` virtual time: a result inserted by a
//! request that *finishes* at t=100 is not servable to a duplicate
//! dispatched at t=50 — on the daemon's virtual clock the bits do not
//! exist yet, so that lookup is a miss and the duplicate recomputes.

use std::collections::BTreeMap;

/// Aggregate cache counters for reports and the `BENCH_serve.json`
/// ledger.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct CacheStats {
    /// Lookups answered from a stored, ready entry.
    pub hits: u64,
    /// Lookups that found nothing servable (absent, colliding, or not
    /// ready at the lookup's virtual time).
    pub misses: u64,
    /// Entries evicted by the byte budget.
    pub evictions: u64,
    /// Entries admitted into the cache.
    pub insertions: u64,
    /// Bytes currently held.
    pub used_bytes: usize,
    /// High-water mark of held bytes over the cache's lifetime.
    pub peak_bytes: usize,
    /// Entries currently held.
    pub entries: usize,
}

struct Entry<V> {
    key: String,
    value: V,
    bytes: usize,
    ready_at: f64,
    tick: u64,
}

/// LRU result cache with a byte budget and exact-content verification.
/// `V` is whatever the caller wants to memoize — the modeled daemon
/// stores the source request's trace index; the executed path stores
/// the output tensors (Arc-backed, so a clone is O(1)).
pub struct ResultCache<V> {
    budget: usize,
    used: usize,
    peak: usize,
    tick: u64,
    entries: BTreeMap<u64, Entry<V>>,
    /// recency index: monotonic tick -> entry hash (lowest tick = LRU).
    recency: BTreeMap<u64, u64>,
    hits: u64,
    misses: u64,
    evictions: u64,
    insertions: u64,
}

/// FNV-1a over the content string — the "sequence hash" of the cache's
/// name. 64-bit, deterministic, dependency-free.
pub fn content_hash(key: &str) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for b in key.as_bytes() {
        h ^= u64::from(*b);
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

impl<V> ResultCache<V> {
    /// An empty cache holding at most `budget_bytes` (0 disables every
    /// insert, so all lookups miss).
    pub fn new(budget_bytes: usize) -> Self {
        ResultCache {
            budget: budget_bytes,
            used: 0,
            peak: 0,
            tick: 0,
            entries: BTreeMap::new(),
            recency: BTreeMap::new(),
            hits: 0,
            misses: 0,
            evictions: 0,
            insertions: 0,
        }
    }

    /// The configured byte budget.
    pub fn budget_bytes(&self) -> usize {
        self.budget
    }

    /// Bytes currently held (always <= the budget).
    pub fn used_bytes(&self) -> usize {
        self.used
    }

    /// Entries currently held.
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    /// Whether the cache holds no entries.
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Counter snapshot for reports.
    pub fn stats(&self) -> CacheStats {
        CacheStats {
            hits: self.hits,
            misses: self.misses,
            evictions: self.evictions,
            insertions: self.insertions,
            used_bytes: self.used,
            peak_bytes: self.peak,
            entries: self.entries.len(),
        }
    }

    /// Look up `key` at virtual time `now`. A hit requires an entry
    /// whose stored content string equals `key` exactly (hash collisions
    /// are misses) and whose `ready_at` is not in the future. Hits
    /// refresh the entry's recency.
    pub fn lookup(&mut self, key: &str, now: f64) -> Option<V>
    where
        V: Clone,
    {
        let hash = content_hash(key);
        let servable = match self.entries.get(&hash) {
            Some(e) => e.key == key && e.ready_at <= now,
            None => false,
        };
        if !servable {
            self.misses += 1;
            return None;
        }
        self.hits += 1;
        self.tick += 1;
        let tick = self.tick;
        let e = self.entries.get_mut(&hash).expect("checked above");
        self.recency.remove(&e.tick);
        e.tick = tick;
        self.recency.insert(tick, hash);
        Some(e.value.clone())
    }

    /// Insert a result that becomes servable at `ready_at`. Evicts LRU
    /// entries until `bytes` fits the budget; an entry that alone
    /// exceeds the budget is not admitted. Re-inserting an existing
    /// content key replaces the entry; a hash-colliding *different* key
    /// leaves the resident entry in place (first wins — verification
    /// keeps lookups correct either way).
    pub fn insert(&mut self, key: &str, value: V, bytes: usize, ready_at: f64) {
        self.insert_hashed(content_hash(key), key, value, bytes, ready_at);
    }

    fn insert_hashed(&mut self, hash: u64, key: &str, value: V, bytes: usize, ready_at: f64) {
        if bytes > self.budget {
            return;
        }
        if let Some(e) = self.entries.get(&hash) {
            if e.key != key {
                return; // colliding foreign entry stays resident
            }
            let old = self.entries.remove(&hash).expect("present");
            self.recency.remove(&old.tick);
            self.used -= old.bytes;
        }
        while self.used + bytes > self.budget {
            let (&lru_tick, &lru_hash) =
                self.recency.iter().next().expect("used > 0 implies entries");
            self.recency.remove(&lru_tick);
            let victim = self.entries.remove(&lru_hash).expect("indexed");
            self.used -= victim.bytes;
            self.evictions += 1;
        }
        self.tick += 1;
        self.recency.insert(self.tick, hash);
        self.entries.insert(
            hash,
            Entry { key: key.to_string(), value, bytes, ready_at, tick: self.tick },
        );
        self.used += bytes;
        self.peak = self.peak.max(self.used);
        self.insertions += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hit_requires_exact_key_and_readiness() {
        let mut c: ResultCache<u32> = ResultCache::new(1000);
        assert_eq!(c.lookup("a", 0.0), None);
        c.insert("a", 7, 10, 5.0);
        // not ready yet at t=4.9 — the producing request finishes at 5.0
        assert_eq!(c.lookup("a", 4.9), None);
        assert_eq!(c.lookup("a", 5.0), Some(7));
        assert_eq!(c.lookup("a", 100.0), Some(7));
        let s = c.stats();
        assert_eq!((s.hits, s.misses, s.insertions), (2, 2, 1));
    }

    #[test]
    fn eviction_respects_byte_budget_exactly() {
        let mut c: ResultCache<u32> = ResultCache::new(100);
        c.insert("a", 1, 40, 0.0);
        c.insert("b", 2, 40, 0.0);
        assert_eq!(c.used_bytes(), 80);
        // 30 more bytes exceed 100 → evict exactly one LRU entry ("a")
        c.insert("c", 3, 30, 0.0);
        assert_eq!(c.used_bytes(), 70);
        assert_eq!(c.lookup("a", 0.0), None, "LRU evicted");
        assert_eq!(c.lookup("b", 0.0), Some(2));
        assert_eq!(c.lookup("c", 0.0), Some(3));
        assert_eq!(c.stats().evictions, 1);
        assert!(c.stats().peak_bytes <= 100, "never over budget");
        // a 90-byte insert needs both residents gone (70 + 90 > 100):
        // "b" (older recency after the lookups above) goes first, then "c"
        c.insert("d", 4, 90, 0.0);
        assert_eq!(c.lookup("d", 0.0), Some(4));
        assert_eq!(c.used_bytes(), 90);
        assert_eq!(c.len(), 1);
    }

    #[test]
    fn oversize_entry_not_admitted() {
        let mut c: ResultCache<u32> = ResultCache::new(50);
        c.insert("big", 1, 51, 0.0);
        assert!(c.is_empty());
        assert_eq!(c.lookup("big", 0.0), None);
        // zero-budget cache admits nothing
        let mut z: ResultCache<u32> = ResultCache::new(0);
        z.insert("a", 1, 1, 0.0);
        assert!(z.is_empty());
    }

    #[test]
    fn hash_collision_is_a_miss_not_a_wrong_answer() {
        let mut c: ResultCache<u32> = ResultCache::new(1000);
        // force two different content strings onto one hash bucket
        c.insert_hashed(42, "protein-A", 1, 10, 0.0);
        c.insert_hashed(42, "protein-B", 2, 10, 0.0);
        // resident entry untouched; the collider was not admitted
        assert_eq!(c.len(), 1);
        assert_eq!(c.used_bytes(), 10);
        // a lookup that hashes to the bucket but differs in content
        // must miss — verified against the stored content string
        let e = c.entries.get(&42).expect("resident");
        assert_eq!(e.key, "protein-A");
        assert_eq!(e.value, 1);
    }

    #[test]
    fn reinsert_same_key_replaces() {
        let mut c: ResultCache<u32> = ResultCache::new(100);
        c.insert("a", 1, 60, 10.0);
        c.insert("a", 2, 30, 5.0);
        assert_eq!(c.len(), 1);
        assert_eq!(c.used_bytes(), 30);
        assert_eq!(c.lookup("a", 5.0), Some(2));
    }

    #[test]
    fn content_hash_is_stable_and_spreads() {
        assert_eq!(content_hash(""), 0xcbf29ce484222325);
        assert_ne!(content_hash("tiny|512"), content_hash("tiny|513"));
        assert_eq!(content_hash("x"), content_hash("x"));
    }
}
