//! Continuous-batching serve daemon: the engine's one-shot batch drain
//! becomes a long-running service loop over *modeled time*.
//!
//! Requests arrive from a [`TraceEvent`] stream (JSONL with arrival
//! timestamps, priority class, deadline, and optional cancellation),
//! and the existing planner/scheduler runs continuously instead of
//! draining once: a discrete-event loop on a virtual clock ingests
//! arrivals, applies admission control ([`MemoPlanner`] — one
//! pricing per distinct shape), sheds load when the wait queue
//! saturates (`queue_cap` backpressure), expires requests whose
//! deadline passes before dispatch, honors cancellations, and picks
//! the next dispatch with [`pick_next`] — the incremental
//! form of the batch policy, starvation guard included. A
//! [`ResultCache`] keyed on the request content hash
//! short-circuits identical proteins to a cached result (ParaFold's
//! redundancy observation), with virtual-time readiness so a duplicate
//! dispatched before its producer finishes still recomputes.
//!
//! The whole lifecycle is simulated single-threaded and deterministic
//! ([`simulate`]); the executed path ([`Engine::serve_trace`]) replays
//! the simulation's dispatch decisions through the real backends with
//! the slot-indexed pull loop, so outputs are bit-for-bit identical at
//! any `--threads` budget — and cancelled/expired/shed requests never
//! construct a backend at all.

use crate::config::RunConfig;
use crate::error::{Error, Result};
use crate::json::Json;
use crate::metrics::{fmt_secs, ServeRecord, ServeStats};
use crate::tensor::HostTensor;
use crate::train::DataGen;
use std::collections::BTreeMap;
use std::sync::Arc;
use std::time::Instant; // lint:allow(wallclock) — executed-replay wall clock, never in the ledger

use super::cache::{CacheStats, ResultCache};
use super::planner::{BackendKind, MemoPlanner, Placement, PlacementPlanner};
use super::scheduler::{pick_next, SchedEntry, SchedPolicy};
use super::{BackendFactory, Engine, InferRequest};

/// Modeled lane occupancy of a cache hit (seconds): a hit still
/// transits the daemon (lookup, result copy-out), it just skips the
/// fold.
pub const CACHE_HIT_LATENCY: f64 = 0.05;

/// Default dispatch attempts per request before it fails permanently.
pub const DEFAULT_MAX_RETRIES: usize = 3;
/// Default consecutive injected failures that open the circuit breaker.
pub const DEFAULT_BREAKER_THRESHOLD: usize = 4;
/// Default virtual seconds the breaker sheds arrivals once tripped.
pub const DEFAULT_BREAKER_COOLDOWN: f64 = 1.0;
/// Default base backoff (virtual seconds) before a retry.
pub const DEFAULT_BACKOFF_BASE: f64 = 0.1;
/// Default modeled lane occupancy of a failed dispatch attempt — the
/// time to *detect* the failure (seconds).
pub const FAULT_DETECT_LATENCY: f64 = 0.05;

/// One timed request in a serve trace: the request itself plus its
/// arrival-process metadata.
#[derive(Clone, Debug)]
pub struct TraceEvent {
    /// The request (everything `fastfold serve` accepts).
    pub req: InferRequest,
    /// Virtual arrival second (trace files are sorted on this).
    pub arrival: f64,
    /// Deadline in seconds *after arrival*; a request still queued at
    /// its deadline expires undispatched, one finishing late completes
    /// with `deadline_missed`.
    pub deadline: Option<f64>,
    /// Absolute virtual second the caller cancels at; a request still
    /// queued then is withdrawn and never reaches a backend.
    pub cancel_at: Option<f64>,
}

impl TraceEvent {
    /// An event with no deadline or cancellation.
    pub fn at(arrival: f64, req: InferRequest) -> Self {
        TraceEvent { req, arrival, deadline: None, cancel_at: None }
    }

    /// Parse one trace object: the request keys of
    /// [`InferRequest::from_json`] plus `arrival` (default 0 — a plain
    /// request file is a valid all-at-once trace), `deadline`,
    /// `cancel_at`. Unknown keys stay loud errors.
    pub fn from_json(j: &Json, index: usize) -> Result<Self> {
        let mut rest = j.as_obj()?.clone();
        let arrival = match rest.remove("arrival") {
            Some(v) => v.as_f64()?,
            None => 0.0,
        };
        let deadline = match rest.remove("deadline") {
            Some(v) => Some(v.as_f64()?),
            None => None,
        };
        let cancel_at = match rest.remove("cancel_at") {
            Some(v) => Some(v.as_f64()?),
            None => None,
        };
        if !arrival.is_finite() || arrival < 0.0 {
            return Err(Error::Config(format!(
                "trace event {index}: arrival must be a finite second >= 0, got {arrival}"
            )));
        }
        if deadline.is_some_and(|d| !d.is_finite() || d <= 0.0) {
            return Err(Error::Config(format!(
                "trace event {index}: deadline must be a finite second > 0"
            )));
        }
        if cancel_at.is_some_and(|c| !c.is_finite() || c < 0.0) {
            return Err(Error::Config(format!(
                "trace event {index}: cancel_at must be a finite second >= 0"
            )));
        }
        let req = InferRequest::from_json(&Json::Obj(rest), index)?;
        Ok(TraceEvent { req, arrival, deadline, cancel_at })
    }

    /// The event as one JSONL object (inverse of [`TraceEvent::from_json`];
    /// request fields at their defaults are omitted).
    pub fn to_json(&self) -> Json {
        let mut m = BTreeMap::new();
        m.insert("id".to_string(), Json::Str(self.req.id.clone()));
        m.insert("preset".to_string(), Json::Str(self.req.preset.clone()));
        m.insert("arrival".to_string(), Json::Num(self.arrival));
        if let Some(len) = self.req.model_len {
            m.insert("len".to_string(), Json::Num(len as f64));
        }
        if self.req.priority != 0 {
            m.insert("priority".to_string(), Json::Num(f64::from(self.req.priority)));
        }
        if self.req.naive {
            m.insert("naive".to_string(), Json::Bool(true));
        }
        if self.req.seed != super::DEFAULT_SEED {
            m.insert("seed".to_string(), Json::Num(self.req.seed as f64));
        }
        if let Some(force) = &self.req.force {
            m.insert("backend".to_string(), Json::Str(force.name()));
        }
        if let Some(d) = self.deadline {
            m.insert("deadline".to_string(), Json::Num(d));
        }
        if let Some(c) = self.cancel_at {
            m.insert("cancel_at".to_string(), Json::Num(c));
        }
        Json::Obj(m)
    }

    /// Parse a JSONL trace (one object per non-blank, non-`#` line) and
    /// stable-sort it by arrival — ties keep file order, which is the
    /// tiebreak seniority the scheduler sees.
    pub fn parse_jsonl(src: &str) -> Result<Vec<TraceEvent>> {
        let mut events = Vec::new();
        for line in src.lines() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let j = Json::parse(line)?;
            events.push(TraceEvent::from_json(&j, events.len())?);
        }
        sort_by_arrival(&mut events);
        Ok(events)
    }

    /// Render a trace as JSONL, one event per line.
    pub fn to_jsonl(trace: &[TraceEvent]) -> String {
        let mut out = String::new();
        for ev in trace {
            out.push_str(&ev.to_json().to_string());
            out.push('\n');
        }
        out
    }
}

/// Stable-sort a trace by arrival time (ties keep their order).
pub fn sort_by_arrival(trace: &mut [TraceEvent]) {
    trace.sort_by(|a, b| a.arrival.total_cmp(&b.arrival));
}

/// The same trace re-timed `dt` seconds later: arrivals and (absolute)
/// cancellations shift, relative deadlines don't. This is how a warm
/// replay follows a cold one on a shared virtual clock — the cache's
/// `ready_at` stamps from the first pass stay in the past.
pub fn shift_trace(trace: &[TraceEvent], dt: f64) -> Vec<TraceEvent> {
    trace
        .iter()
        .map(|ev| TraceEvent {
            req: ev.req.clone(),
            arrival: ev.arrival + dt,
            deadline: ev.deadline,
            cancel_at: ev.cancel_at.map(|c| c + dt),
        })
        .collect()
}

/// Daemon service parameters (the `[serve]` config plus the modeled
/// lane count).
#[derive(Clone, Debug)]
pub struct DaemonConfig {
    /// Queue discipline (both policies run starvation-guarded here).
    pub policy: SchedPolicy,
    /// Starvation bound: no queued request is bypassed by more than
    /// this many younger dispatches.
    pub max_bypass: usize,
    /// Modeled worker-lane count the virtual clock packs onto.
    pub lanes: usize,
    /// Backpressure bound: arrivals finding this many requests already
    /// waiting are shed (0 = unbounded).
    pub queue_cap: usize,
    /// Result-cache byte budget (0 disables the cache).
    pub cache_bytes: usize,
    /// Modeled lane occupancy of a cache hit (seconds).
    pub cache_hit_latency: f64,
    /// Fault schedule whose serve events fail dispatch attempts
    /// (`None` = no injection; the loop is byte-identical to pre-fault
    /// behavior when unset).
    pub faults: Option<crate::faults::FaultSchedule>,
    /// Dispatch attempts per request before [`Disposition::Failed`].
    pub max_retries: usize,
    /// Consecutive failed attempts that open the circuit breaker.
    pub breaker_threshold: usize,
    /// Virtual seconds the breaker sheds arrivals once tripped.
    pub breaker_cooldown: f64,
    /// Base backoff (virtual seconds) before a retry; attempt `k` waits
    /// `base · 2^(k−1)`.
    pub backoff_base: f64,
    /// Modeled lane occupancy of a failed attempt (detection), seconds.
    pub fault_detect_latency: f64,
}

impl DaemonConfig {
    /// Build from a launcher config (`[serve]`) with `lanes` modeled
    /// lanes.
    pub fn from_run_config(cfg: &RunConfig, lanes: usize) -> Self {
        DaemonConfig {
            policy: cfg.serve.policy,
            max_bypass: cfg.serve.max_bypass,
            lanes: lanes.max(1),
            queue_cap: cfg.serve.queue_cap,
            cache_bytes: (cfg.serve.cache_gb * 1e9).round() as usize,
            cache_hit_latency: CACHE_HIT_LATENCY,
            faults: None,
            max_retries: DEFAULT_MAX_RETRIES,
            breaker_threshold: DEFAULT_BREAKER_THRESHOLD,
            breaker_cooldown: DEFAULT_BREAKER_COOLDOWN,
            backoff_base: DEFAULT_BACKOFF_BASE,
            fault_detect_latency: FAULT_DETECT_LATENCY,
        }
    }
}

/// How one traced request left the daemon.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Disposition {
    /// Dispatched and finished (possibly from cache, possibly past its
    /// deadline — both recorded).
    Completed {
        /// Served from the result cache instead of a backend.
        cached: bool,
        /// Finished after its absolute deadline.
        deadline_missed: bool,
    },
    /// Refused at admission (sim-OOM, unknown preset, fleet bound).
    Rejected,
    /// Shed by queue backpressure on arrival.
    Shed,
    /// Deadline passed while still queued; never dispatched.
    Expired,
    /// Cancelled while still queued (or before admission).
    Cancelled,
    /// Exhausted its dispatch retries against failing backends.
    Failed,
}

impl Disposition {
    /// Stable display name (`completed`, `rejected`, `shed`, `expired`,
    /// `cancelled`, `failed`).
    pub fn name(&self) -> &'static str {
        match self {
            Disposition::Completed { .. } => "completed",
            Disposition::Rejected => "rejected",
            Disposition::Shed => "shed",
            Disposition::Expired => "expired",
            Disposition::Cancelled => "cancelled",
            Disposition::Failed => "failed",
        }
    }
}

/// One traced request's simulated lifecycle.
#[derive(Clone, Debug)]
pub struct SimOutcome {
    /// Index into the (arrival-sorted) trace.
    pub trace_idx: usize,
    /// Request id.
    pub id: String,
    /// Virtual arrival second.
    pub arrival: f64,
    /// Absolute deadline second, if the event carried one.
    pub deadline: Option<f64>,
    /// Final lifecycle state.
    pub disposition: Disposition,
    /// Virtual dispatch second (None if never dispatched).
    pub dispatch: Option<f64>,
    /// Virtual completion second (None if never dispatched).
    pub finish: Option<f64>,
    /// Younger dispatches that overtook this request while it waited.
    pub bypassed: usize,
    /// Rejection/shed detail, if any.
    pub error: Option<String>,
    /// The placement (shared via the planner memo); None when the
    /// request was rejected or cancelled before admission.
    pub placement: Option<Arc<Placement>>,
    /// For cache hits: trace index of the request whose result served
    /// this one.
    pub cache_source: Option<usize>,
}

impl SimOutcome {
    /// Modeled sojourn (arrival → finish) for completed requests.
    pub fn sojourn(&self) -> Option<f64> {
        self.finish.map(|f| f - self.arrival)
    }

    fn terminal(
        trace_idx: usize,
        ev: &TraceEvent,
        disposition: Disposition,
        error: Option<String>,
        placement: Option<Arc<Placement>>,
    ) -> Self {
        SimOutcome {
            trace_idx,
            id: ev.req.id.clone(),
            arrival: ev.arrival,
            deadline: ev.deadline.map(|d| ev.arrival + d),
            disposition,
            dispatch: None,
            finish: None,
            bypassed: 0,
            error,
            placement,
            cache_source: None,
        }
    }
}

/// The simulated service run: per-request lifecycles plus the daemon's
/// aggregate view.
#[derive(Debug)]
pub struct DaemonReport {
    /// One outcome per trace event, trace order.
    pub outcomes: Vec<SimOutcome>,
    /// Trace indices in dispatch order (completed requests only,
    /// cache hits included) — the schedule the executed path replays.
    pub dispatch_order: Vec<usize>,
    /// Virtual second the last dispatch finished.
    pub makespan: f64,
    /// Result-cache counters at end of run.
    pub cache: CacheStats,
    /// Largest wait-queue depth observed.
    pub peak_queue: usize,
    /// Dispatch attempts retried after an injected backend failure.
    pub retries: usize,
    /// Retries that moved to a smaller placement (DAP degree shed or
    /// chunked fallback).
    pub fallbacks: usize,
    /// Arrivals shed because the circuit breaker was open.
    pub breaker_shed: usize,
}

impl DaemonReport {
    fn count(&self, f: impl Fn(&Disposition) -> bool) -> usize {
        self.outcomes.iter().filter(|o| f(&o.disposition)).count()
    }

    /// Requests that finished (cache hits included).
    pub fn completed(&self) -> usize {
        self.count(|d| matches!(d, Disposition::Completed { .. }))
    }

    /// Completed requests served from the cache.
    pub fn cache_hits(&self) -> usize {
        self.count(|d| matches!(d, Disposition::Completed { cached: true, .. }))
    }

    /// Requests refused at admission.
    pub fn rejected(&self) -> usize {
        self.count(|d| *d == Disposition::Rejected)
    }

    /// Requests shed by queue backpressure.
    pub fn shed(&self) -> usize {
        self.count(|d| *d == Disposition::Shed)
    }

    /// Requests whose deadline expired before dispatch.
    pub fn expired(&self) -> usize {
        self.count(|d| *d == Disposition::Expired)
    }

    /// Requests cancelled before dispatch.
    pub fn cancelled(&self) -> usize {
        self.count(|d| *d == Disposition::Cancelled)
    }

    /// Requests that exhausted their dispatch retries.
    pub fn failed(&self) -> usize {
        self.count(|d| *d == Disposition::Failed)
    }

    /// Completed requests that finished past their deadline.
    pub fn completed_late(&self) -> usize {
        self.count(|d| matches!(d, Disposition::Completed { deadline_missed: true, .. }))
    }

    /// Deadline misses overall: expired in queue plus completed late,
    /// over requests that carried a deadline and were not cancelled,
    /// shed, or rejected (those never contracted a deadline the daemon
    /// could miss). NaN-free: returns 0 when no request qualifies.
    pub fn deadline_miss_rate(&self) -> f64 {
        let eligible = self
            .outcomes
            .iter()
            .filter(|o| {
                o.deadline.is_some()
                    && matches!(
                        o.disposition,
                        Disposition::Completed { .. } | Disposition::Expired
                    )
            })
            .count();
        if eligible == 0 {
            return 0.0;
        }
        (self.expired() + self.completed_late()) as f64 / eligible as f64
    }

    /// Modeled sojourn times (arrival → finish) of completed requests.
    pub fn sojourns(&self) -> Vec<f64> {
        self.outcomes.iter().filter_map(SimOutcome::sojourn).collect()
    }

    /// Metrics ledger for the simulated run. Completed requests carry
    /// their placement's modeled figures (cache hits flagged so the
    /// FLOP numerator excludes them); terminal lifecycles carry zeros —
    /// they did no compute. Degraded-mode counters ride along.
    pub fn stats(&self) -> ServeStats {
        let mut stats = ServeStats {
            degraded: crate::metrics::DegradedStats {
                retries: self.retries,
                fallbacks: self.fallbacks,
                breaker_shed: self.breaker_shed,
                failed: self.failed(),
            },
            ..ServeStats::default()
        };
        for o in &self.outcomes {
            let completed = matches!(o.disposition, Disposition::Completed { .. });
            let backend = match (&o.disposition, &o.placement) {
                (Disposition::Completed { .. }, Some(p)) => p.backend.name(),
                (d, _) => d.name().to_string(),
            };
            let (lat, flops) = match (&o.placement, completed) {
                (Some(p), true) => (p.modeled_latency, p.modeled_flops),
                _ => (0.0, 0.0),
            };
            stats.push(ServeRecord {
                id: o.id.clone(),
                backend,
                modeled_latency: lat,
                modeled_flops: flops,
                wall_seconds: 0.0,
                ok: completed,
                cached: matches!(o.disposition, Disposition::Completed { cached: true, .. }),
            });
        }
        stats
    }

    /// One-line aggregate summary for logs; degraded-mode counters
    /// appear only when the run absorbed faults.
    pub fn summary(&self) -> String {
        let mut s = format!(
            "daemon: {} events -> {} completed ({} cached, {} late), \
             {} rejected, {} shed, {} expired, {} cancelled; makespan {}; \
             peak queue {}; miss rate {:.3}",
            self.outcomes.len(),
            self.completed(),
            self.cache_hits(),
            self.completed_late(),
            self.rejected(),
            self.shed(),
            self.expired(),
            self.cancelled(),
            fmt_secs(self.makespan),
            self.peak_queue,
            self.deadline_miss_rate(),
        );
        let degraded =
            self.failed() + self.retries + self.fallbacks + self.breaker_shed;
        if degraded > 0 {
            s.push_str(&format!(
                "; degraded: {} failed, {} retries, {} fallbacks, \
                 {} breaker-shed",
                self.failed(),
                self.retries,
                self.fallbacks,
                self.breaker_shed
            ));
        }
        s
    }
}

/// One waiting request inside the event loop.
struct QueueItem {
    trace_idx: usize,
    /// Seniority: position in the arrival-sorted trace.
    seq: usize,
    arrival: f64,
    deadline_abs: Option<f64>,
    cancel_at: Option<f64>,
    priority: u32,
    latency: f64,
    key: String,
    bytes: usize,
    overtaken: usize,
    placement: Arc<Placement>,
    /// Dispatch attempts already consumed by injected failures.
    attempts: usize,
    /// Retry backoff: not eligible for dispatch before this second.
    not_before: f64,
}

/// The next placement to try after a failed dispatch: DAP sheds degree
/// (`dap n` → `dap n/2` down to 2), then falls to the chunked
/// single-device schedule; a failed single-device attempt falls to
/// `chunked`; `chunked` is the floor.
fn fallback_backend(failed: &BackendKind) -> Option<BackendKind> {
    match failed {
        BackendKind::Dap(n) if n / 2 >= 2 => Some(BackendKind::Dap(n / 2)),
        BackendKind::Dap(_) | BackendKind::SingleDevice => Some(BackendKind::Chunked),
        BackendKind::Chunked => None,
    }
}

/// Draw down one serve fault event if this construction attempt is
/// named by the schedule. Attempts are numbered across the whole run in
/// dispatch order (cache hits excluded — they construct no backend),
/// mirroring [`super::backend::ChaosFactory`]'s numbering.
fn take_serve_fault(
    cfg: &DaemonConfig,
    attempt_seq: &mut usize,
    spent: &mut [usize],
) -> bool {
    let Some(s) = &cfg.faults else {
        return false;
    };
    let seq = *attempt_seq;
    *attempt_seq += 1;
    for (i, e) in s.serve.iter().enumerate() {
        if seq >= e.at && seq < e.at + e.count && spent[i] < e.count {
            spent[i] += 1;
            return true;
        }
    }
    false
}

/// Modeled byte size of a request's result (the cache's price for an
/// entry): the two output tensors at the *modeled* shape, f32.
fn modeled_result_bytes(planner: &PlacementPlanner, req: &InferRequest) -> usize {
    match planner.plan_cfg(req) {
        Ok(cfg) => {
            4 * (cfg.n_seq * cfg.n_res * cfg.msa_vocab + cfg.n_res * cfg.n_res * cfg.n_dist_bins)
        }
        Err(_) => 0,
    }
}

/// Simulate the daemon over `trace` with a fresh cache sized by
/// `cfg.cache_bytes`.
pub fn simulate(
    planner: &PlacementPlanner,
    cfg: &DaemonConfig,
    trace: &[TraceEvent],
) -> DaemonReport {
    let mut cache = ResultCache::new(cfg.cache_bytes);
    simulate_with_cache(planner, cfg, trace, &mut cache)
}

/// Simulate the daemon over `trace`, reusing `cache` across calls (a
/// warm replay hands back the cold run's cache together with a
/// [`shift_trace`]-retimed trace, so readiness stamps stay coherent).
///
/// The loop is a pure single-threaded discrete-event simulation — no
/// wall clock, no thread timing — so the outcome is a deterministic
/// function of (planner, cfg, trace, cache state). Each iteration:
///
/// 1. pick the earliest-free lane (ties → lowest index, matching
///    [`super::simulate_lanes`]) and advance `now` to when that lane
///    and at least one request are both present;
/// 2. ingest every arrival up to `now` — pre-arrival cancellations,
///    admission rejections, and backpressure shedding resolve here;
/// 3. purge waiting requests whose cancellation or deadline has passed
///    (they never reach a backend);
/// 4. dispatch one request chosen by [`pick_next`] among those already
///    arrived, consulting the result cache first.
pub fn simulate_with_cache(
    planner: &PlacementPlanner,
    cfg: &DaemonConfig,
    trace: &[TraceEvent],
    cache: &mut ResultCache<usize>,
) -> DaemonReport {
    let n = trace.len();
    let lanes = cfg.lanes.max(1);
    let mut memo = MemoPlanner::new(planner);
    // process in arrival order whatever order the caller handed us
    let mut sorted: Vec<usize> = (0..n).collect();
    sorted.sort_by(|&a, &b| trace[a].arrival.total_cmp(&trace[b].arrival));

    let mut outcomes: Vec<Option<SimOutcome>> = (0..n).map(|_| None).collect();
    let mut dispatch_order = Vec::new();
    let mut free = vec![0.0f64; lanes];
    let mut queue: Vec<QueueItem> = Vec::new();
    let mut next = 0usize; // cursor into `sorted`
    let mut makespan = 0.0f64;
    let mut peak_queue = 0usize;
    // degraded-mode state (all inert when `cfg.faults` is None)
    let mut attempt_seq = 0usize;
    let mut fault_spent =
        vec![0usize; cfg.faults.as_ref().map_or(0, |s| s.serve.len())];
    let mut consecutive_failures = 0usize;
    let mut breaker_until = f64::NEG_INFINITY;
    let mut retries = 0usize;
    let mut fallbacks = 0usize;
    let mut breaker_shed = 0usize;

    while next < n || !queue.is_empty() {
        // 1. earliest-free lane, ties to the lowest index
        let mut lane = 0usize;
        for k in 1..lanes {
            if free[k] < free[lane] {
                lane = k;
            }
        }
        // a requeued request is "present" only once its backoff expires
        let earliest_present = queue.iter().map(|q| q.arrival.max(q.not_before)).fold(
            if next < n { trace[sorted[next]].arrival } else { f64::INFINITY },
            f64::min,
        );
        let now = free[lane].max(earliest_present);

        // 2. ingest arrivals up to `now`
        while next < n && trace[sorted[next]].arrival <= now {
            let idx = sorted[next];
            let seq = next;
            next += 1;
            let ev = &trace[idx];
            if ev.cancel_at.is_some_and(|c| c <= ev.arrival) {
                outcomes[idx] =
                    Some(SimOutcome::terminal(idx, ev, Disposition::Cancelled, None, None));
                continue;
            }
            match memo.place(&ev.req) {
                Err(e) => {
                    outcomes[idx] = Some(SimOutcome::terminal(
                        idx,
                        ev,
                        Disposition::Rejected,
                        Some(e.to_string()),
                        None,
                    ));
                }
                Ok(placement) => {
                    if ev.arrival < breaker_until {
                        breaker_shed += 1;
                        outcomes[idx] = Some(SimOutcome::terminal(
                            idx,
                            ev,
                            Disposition::Shed,
                            Some(format!(
                                "circuit breaker open until t={breaker_until:.3}"
                            )),
                            Some(placement),
                        ));
                        continue;
                    }
                    if cfg.queue_cap > 0 && queue.len() >= cfg.queue_cap {
                        outcomes[idx] = Some(SimOutcome::terminal(
                            idx,
                            ev,
                            Disposition::Shed,
                            Some(format!(
                                "queue full ({} waiting, cap {})",
                                queue.len(),
                                cfg.queue_cap
                            )),
                            Some(placement),
                        ));
                        continue;
                    }
                    queue.push(QueueItem {
                        trace_idx: idx,
                        seq,
                        arrival: ev.arrival,
                        deadline_abs: ev.deadline.map(|d| ev.arrival + d),
                        cancel_at: ev.cancel_at,
                        priority: ev.req.priority,
                        latency: placement.modeled_latency,
                        key: ev.req.content_key(),
                        bytes: modeled_result_bytes(planner, &ev.req),
                        overtaken: 0,
                        placement,
                        attempts: 0,
                        not_before: 0.0,
                    });
                    peak_queue = peak_queue.max(queue.len());
                }
            }
        }

        // 3. purge cancelled/expired waiters — they never dispatch
        let mut k = 0usize;
        while k < queue.len() {
            let cancelled = queue[k].cancel_at.is_some_and(|c| c <= now);
            let expired = !cancelled && queue[k].deadline_abs.is_some_and(|d| d <= now);
            if !(cancelled || expired) {
                k += 1;
                continue;
            }
            let item = queue.remove(k);
            let ev = &trace[item.trace_idx];
            let disposition =
                if cancelled { Disposition::Cancelled } else { Disposition::Expired };
            let mut out =
                SimOutcome::terminal(item.trace_idx, ev, disposition, None, Some(item.placement));
            out.bypassed = item.overtaken;
            outcomes[item.trace_idx] = Some(out);
        }

        // 4. dispatch one request among those already arrived (and past
        // any retry backoff)
        let eligible: Vec<usize> = (0..queue.len())
            .filter(|&i| queue[i].arrival.max(queue[i].not_before) <= now)
            .collect();
        if eligible.is_empty() {
            continue; // progress came from ingestion/purging above
        }
        let view: Vec<(SchedEntry, usize)> = eligible
            .iter()
            .map(|&i| {
                let q = &queue[i];
                (
                    SchedEntry {
                        arrival: q.seq,
                        priority: q.priority,
                        modeled_latency: q.latency,
                    },
                    q.overtaken,
                )
            })
            .collect();
        // invariant: `eligible` was checked non-empty above
        let pick = pick_next(cfg.policy, &view, cfg.max_bypass)
            .expect("eligible is non-empty"); // lint:allow(panic)
        let mut item = queue.remove(eligible[pick]);

        // cache hits never construct a backend, so they are not
        // failure-injection attempts
        let cache_hit =
            if cfg.cache_bytes > 0 { cache.lookup(&item.key, now) } else { None };
        if cache_hit.is_none()
            && take_serve_fault(cfg, &mut attempt_seq, &mut fault_spent)
        {
            // injected backend failure: the lane burns the detection
            // latency; the request retries with exponential backoff on
            // a (possibly) smaller placement, or fails permanently
            let detect = cfg.fault_detect_latency.max(0.0);
            free[lane] = now + detect;
            item.attempts += 1;
            consecutive_failures += 1;
            if consecutive_failures >= cfg.breaker_threshold.max(1) {
                breaker_until = now + detect + cfg.breaker_cooldown.max(0.0);
                consecutive_failures = 0;
            }
            if item.attempts > cfg.max_retries {
                let seq = item.seq;
                let ev = &trace[item.trace_idx];
                let mut out = SimOutcome::terminal(
                    item.trace_idx,
                    ev,
                    Disposition::Failed,
                    Some(format!(
                        "backend failed all {} dispatch attempts",
                        item.attempts
                    )),
                    Some(item.placement),
                );
                out.dispatch = Some(now);
                out.bypassed = item.overtaken;
                outcomes[item.trace_idx] = Some(out);
                for q in &mut queue {
                    if q.seq < seq {
                        q.overtaken += 1;
                    }
                }
            } else {
                retries += 1;
                // placement fallback: a failing device sheds DAP degree,
                // then falls to the chunked single-device schedule
                if let Some(kind) = fallback_backend(&item.placement.backend) {
                    let mut r2 = trace[item.trace_idx].req.clone();
                    r2.force = Some(kind);
                    if let Ok(p2) = memo.place(&r2) {
                        if p2.backend != item.placement.backend {
                            fallbacks += 1;
                            item.latency = p2.modeled_latency;
                            item.placement = p2;
                        }
                    }
                }
                item.not_before = now
                    + detect
                    + crate::faults::backoff_secs(
                        cfg.backoff_base.max(0.0),
                        item.attempts,
                    );
                queue.push(item);
            }
            continue;
        }

        let (finish, cached, cache_source) = match cache_hit {
            Some(src) => (now + cfg.cache_hit_latency.max(0.0), true, Some(src)),
            None => {
                let f = now + item.latency.max(0.0);
                if cfg.cache_bytes > 0 {
                    cache.insert(&item.key, item.trace_idx, item.bytes, f);
                }
                (f, false, None)
            }
        };
        if !cached {
            // a completed construction closes any failure streak
            consecutive_failures = 0;
        }
        for q in &mut queue {
            if q.seq < item.seq {
                q.overtaken += 1;
            }
        }
        free[lane] = finish;
        makespan = makespan.max(finish);
        let deadline_missed = item.deadline_abs.is_some_and(|d| finish > d);
        outcomes[item.trace_idx] = Some(SimOutcome {
            trace_idx: item.trace_idx,
            id: trace[item.trace_idx].req.id.clone(),
            arrival: item.arrival,
            deadline: item.deadline_abs,
            disposition: Disposition::Completed { cached, deadline_missed },
            dispatch: Some(now),
            finish: Some(finish),
            bypassed: item.overtaken,
            error: None,
            placement: Some(item.placement),
            cache_source,
        });
        dispatch_order.push(item.trace_idx);
    }

    DaemonReport {
        outcomes: outcomes
            .into_iter()
            // invariant: the loop above terminates every trace event
            .map(|o| o.expect("every trace event reaches a terminal state")) // lint:allow(panic)
            .collect(),
        dispatch_order,
        makespan,
        cache: cache.stats(),
        peak_queue,
        retries,
        fallbacks,
        breaker_shed,
    }
}

/// The executed daemon run: the simulation's decisions plus real
/// backend outputs.
#[derive(Debug)]
pub struct TraceServeReport {
    /// The deterministic lifecycle simulation the execution replayed.
    pub sim: DaemonReport,
    /// Per-trace-event output (trace order): `None` for requests that
    /// never dispatched; cache hits carry a bit-identical clone of
    /// their source's output.
    pub outputs: Vec<Option<Result<(HostTensor, HostTensor)>>>,
    /// Backend execution notes, aligned with `outputs`.
    pub notes: Vec<Option<String>>,
    /// Worker lanes the execution used.
    pub threads: usize,
    /// Measured wall seconds for the whole replay.
    pub wall_seconds: f64,
    /// Metrics ledger (wall times measured, cache hits flagged).
    pub stats: ServeStats,
}

impl Engine<'_> {
    /// Execute a trace through the daemon with the production backends.
    pub fn serve_trace(
        &self,
        cfg: &DaemonConfig,
        trace: &[TraceEvent],
    ) -> Result<TraceServeReport> {
        self.serve_trace_with(cfg, trace, self)
    }

    /// Execute a trace through the daemon with an injected backend
    /// factory (the test seam). The lifecycle — admission, shedding,
    /// expiry, cancellation, dispatch order, cache hits — comes from
    /// the single-threaded [`simulate`]; only completed non-cached
    /// requests are executed, pulled work-conservingly in dispatch
    /// order with slot-indexed results, so outputs are bit-for-bit
    /// identical at any thread budget and cancelled/expired/shed
    /// requests never construct a backend.
    pub fn serve_trace_with(
        &self,
        cfg: &DaemonConfig,
        trace: &[TraceEvent],
        factory: &dyn BackendFactory,
    ) -> Result<TraceServeReport> {
        let t0 = Instant::now();
        let sim = simulate(&self.planner, cfg, trace);

        let to_execute: Vec<usize> = sim
            .dispatch_order
            .iter()
            .copied()
            .filter(|&i| {
                matches!(
                    sim.outcomes[i].disposition,
                    Disposition::Completed { cached: false, .. }
                )
            })
            .collect();
        let concurrent = to_execute.len().clamp(1, self.threads.max(1));
        let rank_threads = (self.threads / concurrent).max(1);
        let executed: Vec<(Result<super::InferOutput>, f64)> =
            super::pull_map(self.threads, to_execute.len(), |slot| {
                let i = to_execute[slot];
                let req = &trace[i].req;
                let placement = sim.outcomes[i]
                    .placement
                    .as_ref()
                    // invariant: completed outcomes always carry one
                    .expect("dispatched request must be placed"); // lint:allow(panic)
                let t = Instant::now();
                let out = (|| {
                    let be = factory.make(req, placement, rank_threads)?;
                    let exec_cfg = crate::config::ModelConfig::preset(&req.preset)?;
                    let mut gen = DataGen::new(exec_cfg, req.seed);
                    be.infer(&gen.next_batch().msa_tokens)
                })();
                (out, t.elapsed().as_secs_f64())
            });

        let mut outputs: Vec<Option<Result<(HostTensor, HostTensor)>>> =
            (0..trace.len()).map(|_| None).collect();
        let mut notes: Vec<Option<String>> = vec![None; trace.len()];
        let mut walls = vec![0.0f64; trace.len()];
        for (slot, (out, wall)) in executed.into_iter().enumerate() {
            let i = to_execute[slot];
            walls[i] = wall;
            match out {
                Ok(super::InferOutput { msa_logits, dist_logits, note }) => {
                    outputs[i] = Some(Ok((msa_logits, dist_logits)));
                    notes[i] = note;
                }
                Err(e) => outputs[i] = Some(Err(e)),
            }
        }
        // cache hits clone their source's bits (the cache stores the
        // producing request's output; Error is not Clone, so a failed
        // producer propagates as a message-preserving error)
        for o in &sim.outcomes {
            if let (Disposition::Completed { cached: true, .. }, Some(src)) =
                (&o.disposition, o.cache_source)
            {
                let cloned = match &outputs[src] {
                    Some(Ok((m, z))) => Ok((m.clone(), z.clone())),
                    Some(Err(e)) => Err(Error::msg(e.to_string())),
                    None => Err(Error::msg("cache source was not executed")),
                };
                outputs[o.trace_idx] = Some(cloned);
                notes[o.trace_idx] = Some(format!("cache hit (source {})", trace[src].req.id));
            }
        }

        let mut stats = ServeStats {
            degraded: crate::metrics::DegradedStats {
                retries: sim.retries,
                fallbacks: sim.fallbacks,
                breaker_shed: sim.breaker_shed,
                failed: sim.failed(),
            },
            ..ServeStats::default()
        };
        for (i, o) in sim.outcomes.iter().enumerate() {
            let completed = matches!(o.disposition, Disposition::Completed { .. });
            let cached = matches!(o.disposition, Disposition::Completed { cached: true, .. });
            let backend = match (&o.disposition, &o.placement) {
                (Disposition::Completed { .. }, Some(p)) => p.backend.name(),
                (d, _) => d.name().to_string(),
            };
            let (lat, flops) = match (&o.placement, completed) {
                (Some(p), true) => (p.modeled_latency, p.modeled_flops),
                _ => (0.0, 0.0),
            };
            stats.push(ServeRecord {
                id: o.id.clone(),
                backend,
                modeled_latency: lat,
                modeled_flops: flops,
                wall_seconds: walls[i],
                ok: matches!(outputs[i], Some(Ok(_))),
                cached,
            });
        }

        Ok(TraceServeReport {
            sim,
            outputs,
            notes,
            threads: self.threads,
            wall_seconds: t0.elapsed().as_secs_f64(),
            stats,
        })
    }
}
