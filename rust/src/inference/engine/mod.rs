//! The unified inference engine: one request-driven serving layer over
//! the single-device, chunked, and DAP execution paths.
//!
//! Before this subsystem each strategy was its own entry point serving
//! exactly one request (`inference::single`, the DAP coordinator, CLI
//! glue). ParaFold (arXiv 2111.06340) frames real AlphaFold deployments
//! as throughput problems over many heterogeneous sequences; here the
//! [`Engine`] owns the [`Runtime`] (compile-once executable cache) and a
//! per-preset parameter cache once, accepts a queue of [`InferRequest`]s,
//! and for each request:
//!
//! 1. **places** it via [`planner::PlacementPlanner`] — cost-model-driven
//!    backend choice with sim-OOM admission control;
//! 2. **schedules** the admitted batch ([`scheduler`]) — FIFO or SJF by
//!    modeled latency, starvation-guarded, deterministic;
//! 3. **executes** up to `threads` requests concurrently — worker lanes
//!    pull scheduled requests work-conservingly, results land
//!    slot-indexed so outputs are bit-for-bit identical at any thread
//!    budget, and each request's DAP backend still runs on PR 2's rank
//!    executor with its share of the budget;
//! 4. **accounts** per-request latency and aggregate modeled PFLOP/s
//!    through [`crate::metrics::ServeStats`].
//!
//! `fastfold serve --requests <jsonl>` drives this from the CLI;
//! `fastfold infer` is now a one-request special case of the same path.

pub mod backend;
pub mod cache;
pub mod daemon;
pub mod loadgen;
pub mod planner;
pub mod scheduler;

pub use backend::{
    BackendFactory, ChaosFactory, DapBackend, InferBackend, InferOutput, TrunkBackend,
};
pub use cache::{CacheStats, ResultCache};
pub use daemon::{
    simulate, simulate_with_cache, DaemonConfig, DaemonReport, Disposition, SimOutcome,
    TraceEvent, TraceServeReport,
};
pub use loadgen::LoadgenSpec;
pub use planner::{BackendKind, MemoPlanner, Placement, PlacementPlanner};
pub use scheduler::{pick_next, schedule_order, simulate_lanes, SchedEntry, SchedPolicy};

use crate::config::{ModelConfig, RunConfig};
use crate::error::{Error, Result};
use crate::json::Json;
use crate::metrics::{fmt_secs, ServeRecord, ServeStats, Table};
use crate::runtime::Runtime;
use crate::tensor::HostTensor;
use crate::train::DataGen;
use std::collections::BTreeMap;
use std::sync::{Arc, Mutex};
use std::time::Instant; // lint:allow(wallclock) — per-request wall-latency measurement

/// Default input-stream seed — matches the legacy `fastfold infer` data
/// stream, so engine outputs are bit-for-bit comparable to the old path.
pub const DEFAULT_SEED: u64 = 7;

/// One inference request as the serving layer sees it.
#[derive(Clone, Debug)]
pub struct InferRequest {
    /// Caller-visible request id (reports key on it).
    pub id: String,
    /// Preset whose artifacts execute the request.
    pub preset: String,
    /// Residue count the cost models price the request at (None = the
    /// preset's own shape). This is the "short vs long vs DAP-worthy"
    /// knob: executed semantics stay at preset scale on this testbed
    /// while placement sees the deployment-scale sequence.
    pub model_len: Option<usize>,
    /// Smaller runs sooner (deadline classes); defaults to 0.
    pub priority: u32,
    /// Run the unfused-kernel baseline variant.
    pub naive: bool,
    /// Synthetic input-stream seed.
    pub seed: u64,
    /// Pin the backend instead of consulting the planner (legacy
    /// `--dap N` paths); the memory guard still vets a forced choice.
    pub force: Option<BackendKind>,
}

impl InferRequest {
    /// A request with defaults (no modeled length, priority 0, fused
    /// kernels, the legacy input seed, planner-chosen backend).
    pub fn new(id: &str, preset: &str) -> Self {
        InferRequest {
            id: id.to_string(),
            preset: preset.to_string(),
            model_len: None,
            priority: 0,
            naive: false,
            seed: DEFAULT_SEED,
            force: None,
        }
    }

    /// Parse one request object. Recognized keys (all optional except
    /// none): `id` (default `req<index>`), `preset` (default `tiny`),
    /// `len`, `priority`, `naive`, `seed`, `backend`
    /// (`single`/`chunked`/`dap<N>`), `dap` (degree ≥ 2 pins `dap<N>`).
    pub fn from_json(j: &Json, index: usize) -> Result<Self> {
        // a bare scalar/array line must error, not become a default
        // request — and a misspelled key must not silently drop a setting
        const KNOWN: [&str; 8] =
            ["id", "preset", "len", "priority", "naive", "seed", "backend", "dap"];
        for key in j.as_obj()?.keys() {
            if !KNOWN.contains(&key.as_str()) {
                return Err(Error::Config(format!(
                    "request {index}: unknown key '{key}' (known: {})",
                    KNOWN.join(", ")
                )));
            }
        }
        let mut req = InferRequest::new(&format!("req{index}"), "tiny");
        if let Some(v) = j.opt("id") {
            req.id = v.as_str()?.to_string();
        }
        if let Some(v) = j.opt("preset") {
            req.preset = v.as_str()?.to_string();
        }
        if let Some(v) = j.opt("len") {
            req.model_len = Some(v.as_usize()?);
        }
        if let Some(v) = j.opt("priority") {
            req.priority = v.as_usize()? as u32;
        }
        if let Some(v) = j.opt("naive") {
            req.naive = v.as_bool()?;
        }
        if let Some(v) = j.opt("seed") {
            req.seed = v.as_usize()? as u64;
        }
        if j.opt("backend").is_some() && j.opt("dap").is_some() {
            return Err(Error::Config(format!(
                "request {index}: 'backend' and 'dap' are both backend \
                 pins — give one"
            )));
        }
        if let Some(v) = j.opt("backend") {
            req.force = Some(BackendKind::parse(v.as_str()?)?);
        } else if let Some(v) = j.opt("dap") {
            let n = v.as_usize()?;
            if n >= 2 {
                req.force = Some(BackendKind::Dap(n));
            }
        }
        Ok(req)
    }

    /// The request's content identity for the result cache: every field
    /// except the caller-visible `id`. Two requests with equal keys are
    /// guaranteed to produce bit-identical outputs (same preset
    /// artifacts, same modeled shape, same input seed, same kernel
    /// variant, same pinned backend — and conservatively the priority
    /// class, which costs duplicate hits nothing in practice since
    /// duplicates copy the full request).
    pub fn content_key(&self) -> String {
        format!(
            "{}|{}|p{}|n{}|s{}|{}",
            self.preset,
            self.model_len.map_or_else(|| "-".into(), |l| l.to_string()),
            self.priority,
            u8::from(self.naive),
            self.seed,
            self.force.as_ref().map_or_else(|| "-".into(), BackendKind::name),
        )
    }

    /// Parse a JSONL request file (one JSON object per non-blank line).
    pub fn parse_jsonl(src: &str) -> Result<Vec<InferRequest>> {
        let mut reqs = Vec::new();
        for line in src.lines() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let j = Json::parse(line)?;
            reqs.push(InferRequest::from_json(&j, reqs.len())?);
        }
        Ok(reqs)
    }
}

/// Work-conserving slot map: up to `threads` scoped workers pull the next
/// unclaimed slot index and run `f` on it — a free lane always takes the
/// next scheduled job, matching [`simulate_lanes`]' earliest-free-lane
/// model (static round-robin striping would let a lane idle behind a long
/// job). Results land slot-indexed, so outputs are deterministic however
/// the pulls interleave.
fn pull_map<T: Send>(threads: usize, n: usize, f: impl Fn(usize) -> T + Sync) -> Vec<T> {
    if threads <= 1 || n <= 1 {
        return (0..n).map(f).collect();
    }
    let next = std::sync::atomic::AtomicUsize::new(0);
    let slots: Vec<Mutex<Option<T>>> = (0..n).map(|_| Mutex::new(None)).collect();
    std::thread::scope(|s| {
        for _ in 0..threads.min(n) {
            s.spawn(|| loop {
                let slot = next.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                if slot >= n {
                    break;
                }
                *slots[slot].lock().unwrap() = Some(f(slot));
            });
        }
    });
    slots
        .into_iter()
        .map(|m| {
            m.into_inner()
                .expect("slot mutex poisoned")
                .expect("every slot is filled before the scope joins")
        })
        .collect()
}

/// The plan-only front half of a drain: placements in submission order,
/// the executed schedule, and the modeled lane economics — computable
/// without a [`Runtime`] (dry-run, benches, examples).
#[derive(Debug)]
pub struct BatchPlan {
    /// Per-request placement (or admission rejection), submission order.
    pub placements: Vec<Result<Placement>>,
    /// Executed schedule: submission indices in run order (admitted only).
    pub order: Vec<usize>,
    /// Modeled start second of each scheduled slot (aligned with `order`).
    pub modeled_starts: Vec<f64>,
    /// Modeled makespan of the schedule over the lanes (seconds).
    pub modeled_makespan: f64,
}

/// Place, admit, schedule, and lane-simulate a request batch — the single
/// implementation behind [`Engine::serve`], `fastfold serve --dry-run`,
/// `bench_serve`, and the examples, so schedule semantics cannot drift
/// between the executed and preview paths.
pub fn plan_batch(
    planner: &PlacementPlanner,
    policy: SchedPolicy,
    max_bypass: usize,
    lanes: usize,
    requests: &[InferRequest],
) -> BatchPlan {
    let placements: Vec<Result<Placement>> =
        requests.iter().map(|r| planner.place(r)).collect();
    let admitted: Vec<usize> = placements
        .iter()
        .enumerate()
        .filter(|(_, p)| p.is_ok())
        .map(|(i, _)| i)
        .collect();
    let latency_of = |i: usize| -> f64 {
        placements[i].as_ref().map(|p| p.modeled_latency).unwrap_or(0.0)
    };
    let entries: Vec<SchedEntry> = admitted
        .iter()
        .map(|&i| SchedEntry {
            arrival: i,
            priority: requests[i].priority,
            modeled_latency: latency_of(i),
        })
        .collect();
    let order: Vec<usize> = schedule_order(policy, &entries, max_bypass)
        .into_iter()
        .map(|k| admitted[k])
        .collect();
    let lats: Vec<f64> = order.iter().map(|&i| latency_of(i)).collect();
    let (modeled_starts, modeled_makespan) = simulate_lanes(&lats, lanes);
    BatchPlan { placements, order, modeled_starts, modeled_makespan }
}

impl BatchPlan {
    /// Metrics ledger for the planned (not executed) batch: wall fields
    /// are zero, rejected requests carry zero flops. `requests` must be
    /// the batch this plan was built from.
    pub fn stats(&self, requests: &[InferRequest]) -> ServeStats {
        let mut stats = ServeStats::default();
        for (req, pl) in requests.iter().zip(self.placements.iter()) {
            stats.push(match pl {
                Ok(p) => ServeRecord {
                    id: req.id.clone(),
                    backend: p.backend.name(),
                    modeled_latency: p.modeled_latency,
                    modeled_flops: p.modeled_flops,
                    wall_seconds: 0.0,
                    ok: true,
                    cached: false,
                },
                Err(_) => ServeRecord {
                    id: req.id.clone(),
                    backend: "rejected".into(),
                    modeled_latency: 0.0,
                    modeled_flops: 0.0,
                    wall_seconds: 0.0,
                    ok: false,
                    cached: false,
                },
            });
        }
        stats
    }

    /// Placement preview table — the one rendering the dry-run CLI and
    /// the examples share.
    pub fn table(&self, requests: &[InferRequest]) -> Table {
        let mut t = Table::new(&[
            "id", "preset", "len", "backend", "modeled lat", "peak GB",
            "modeled PFLOP/s", "status",
        ]);
        for (req, pl) in requests.iter().zip(self.placements.iter()) {
            let len = req
                .model_len
                .map(|l| l.to_string())
                .unwrap_or_else(|| "preset".into());
            match pl {
                Ok(p) => t.row(&[
                    req.id.clone(),
                    req.preset.clone(),
                    len,
                    p.backend.name(),
                    fmt_secs(p.modeled_latency),
                    format!("{:.1}", p.modeled_peak_gb),
                    format!("{:.2}", p.modeled_pflops()),
                    "admitted".into(),
                ]),
                Err(_) => t.row(&[
                    req.id.clone(),
                    req.preset.clone(),
                    len,
                    "-".into(),
                    "-".into(),
                    "-".into(),
                    "-".into(),
                    "rejected".into(),
                ]),
            }
        }
        t
    }

    /// Rejection detail lines (`id: error`) for printing under the table.
    pub fn rejections(&self, requests: &[InferRequest]) -> Vec<String> {
        requests
            .iter()
            .zip(self.placements.iter())
            .filter_map(|(req, pl)| {
                pl.as_ref().err().map(|e| format!("{}: {e}", req.id))
            })
            .collect()
    }
}

/// One request's final disposition, in submission order inside a
/// [`ServeReport`].
#[derive(Debug)]
pub struct RequestOutcome {
    /// Request id.
    pub id: String,
    /// Preset the request named.
    pub preset: String,
    /// The planner's placement (None = rejected at admission).
    pub placement: Option<Placement>,
    /// The logits, or the rejection/execution error.
    pub output: Result<(HostTensor, HostTensor)>,
    /// Backend execution note (plan summary, overlap report).
    pub note: Option<String>,
    /// Measured wall seconds for this request's execution.
    pub wall_seconds: f64,
}

/// The drained batch: outcomes, the executed schedule, and the metrics
/// ledger.
#[derive(Debug)]
pub struct ServeReport {
    /// Per-request outcomes in submission order.
    pub outcomes: Vec<RequestOutcome>,
    /// Executed schedule: submission indices in run order (admitted only).
    pub order: Vec<usize>,
    /// Request-level worker lanes the drain used.
    pub threads: usize,
    /// Measured wall seconds for the whole drain.
    pub wall_seconds: f64,
    /// Modeled makespan of the schedule over `threads` lanes (seconds).
    pub modeled_makespan: f64,
    /// Per-request metrics ledger (see [`ServeStats`]).
    pub stats: ServeStats,
}

impl ServeReport {
    /// Requests that produced output.
    pub fn completed(&self) -> usize {
        self.stats.completed()
    }

    /// Aggregate modeled throughput of the drained batch: total modeled
    /// FLOPs over the modeled makespan (the paper's aggregate-PFLOP/s
    /// framing).
    pub fn aggregate_pflops(&self) -> f64 {
        self.stats.aggregate_pflops(self.modeled_makespan)
    }

    /// Per-request report table.
    pub fn table(&self) -> Table {
        let mut t = Table::new(&[
            "id", "preset", "backend", "modeled lat", "modeled PFLOP/s", "wall", "status",
        ]);
        for o in &self.outcomes {
            let (backend, lat, pf) = match &o.placement {
                Some(p) => (
                    p.backend.name(),
                    fmt_secs(p.modeled_latency),
                    format!("{:.2}", p.modeled_pflops()),
                ),
                None => ("-".into(), "-".into(), "-".into()),
            };
            // no placement = never admitted (sim-OOM, bad preset, fleet
            // bound) → "rejected"; placed but errored = "failed" — keyed
            // on admission so the table agrees with backend_mix()
            let status = match (&o.output, &o.placement) {
                (Ok(_), _) => "ok".to_string(),
                (Err(_), None) => "rejected".into(),
                (Err(_), Some(_)) => "failed".into(),
            };
            t.row(&[
                o.id.clone(),
                o.preset.clone(),
                backend,
                lat,
                pf,
                fmt_secs(o.wall_seconds),
                status,
            ]);
        }
        t
    }

    /// One-line aggregate summary for logs.
    pub fn summary(&self) -> String {
        format!(
            "served {}/{} requests in {} (threads={}, mean wall {}); \
             backends: {}; modeled makespan {} -> aggregate {:.2} PFLOP/s \
             (modeled)",
            self.completed(),
            self.outcomes.len(),
            fmt_secs(self.wall_seconds),
            self.threads,
            fmt_secs(self.stats.mean_wall_seconds()),
            self.stats.backend_mix(),
            fmt_secs(self.modeled_makespan),
            self.aggregate_pflops(),
        )
    }
}

/// Lazily-loaded, shareable parameter leaves for one preset: the outer
/// map lock is held only to find the slot; the per-slot lock is held
/// across the disk load, so one preset's load never blocks another's.
type ParamSlot = Arc<Mutex<Option<Arc<Vec<HostTensor>>>>>;

/// The serving engine: owns the runtime + parameter caches once, drains
/// request batches through place → schedule → execute → account.
pub struct Engine<'rt> {
    rt: &'rt Runtime,
    /// Placement policy (public so deployments can swap cost models).
    pub planner: PlacementPlanner,
    /// Queue discipline for [`Engine::serve`].
    pub policy: SchedPolicy,
    /// SJF starvation bound (see [`scheduler::schedule_order`]).
    pub max_bypass: usize,
    /// Request-level worker-lane budget (also the modeled lane count).
    pub threads: usize,
    /// Duality-Async overlap for DAP placements.
    pub overlap: bool,
    params: Mutex<BTreeMap<String, ParamSlot>>,
}

impl<'rt> Engine<'rt> {
    /// Build an engine from a launcher config (`[parallel]`, `[autochunk]`,
    /// `[serve]`).
    pub fn new(rt: &'rt Runtime, cfg: &RunConfig) -> Result<Self> {
        Ok(Engine {
            rt,
            planner: PlacementPlanner::from_run_config(cfg)?,
            policy: cfg.serve.policy,
            max_bypass: cfg.serve.max_bypass,
            threads: cfg.parallel.resolve_threads(),
            overlap: cfg.parallel.overlap,
            params: Mutex::new(BTreeMap::new()),
        })
    }

    /// The runtime this engine serves from.
    pub fn runtime(&self) -> &'rt Runtime {
        self.rt
    }

    /// Canonical parameter leaves for `preset`, loaded once and shared
    /// across every request that names the preset. Concurrent lanes
    /// racing on the *same* preset wait for one read (the per-preset
    /// slot lock spans the load); lanes on *different* presets load in
    /// parallel (the map lock is only held to find the slot). A failed
    /// load leaves the slot empty, so a later request retries.
    pub fn params_for(&self, preset: &str) -> Result<Arc<Vec<HostTensor>>> {
        let slot: ParamSlot = self
            .params
            .lock()
            .unwrap()
            .entry(preset.to_string())
            .or_default()
            .clone();
        let mut guard = slot.lock().unwrap();
        if let Some(p) = &*guard {
            return Ok(p.clone());
        }
        let loaded = Arc::new(self.rt.manifest.load_params(preset)?);
        *guard = Some(loaded.clone());
        Ok(loaded)
    }

    /// Place one request without executing it (the `--dry-run` path).
    pub fn place(&self, req: &InferRequest) -> Result<Placement> {
        self.planner.place(req)
    }

    /// Drain a batch with the production backends.
    pub fn serve(&self, requests: &[InferRequest]) -> Result<ServeReport> {
        self.serve_with(requests, self)
    }

    /// Drain a batch with an injected [`BackendFactory`] (the test seam —
    /// scheduling, admission, and accounting are identical to
    /// [`Engine::serve`]).
    pub fn serve_with(
        &self,
        requests: &[InferRequest],
        factory: &dyn BackendFactory,
    ) -> Result<ServeReport> {
        let t0 = Instant::now();

        // 1.–3. place + admit + schedule + lane-simulate (deterministic,
        // shared with the dry-run/bench preview paths)
        let BatchPlan { placements, order, modeled_makespan, .. } =
            plan_batch(&self.planner, self.policy, self.max_bypass, self.threads, requests);

        // 4. execute: worker lanes pull scheduled requests work-conservingly
        // ([`pull_map`], mirroring the lane model); results land
        // slot-indexed, so outputs cannot depend on the thread budget
        // (rank_threads never changes numerics either — PR 2's bit-for-bit
        // guarantee). The budget splits across concurrent requests with no
        // oversubscription: a lone request keeps all of it (legacy
        // single-request behavior), a full batch gets one lane each.
        let concurrent = order.len().clamp(1, self.threads.max(1));
        let rank_threads = (self.threads / concurrent).max(1);
        let executed: Vec<(usize, Result<InferOutput>, f64)> =
            pull_map(self.threads, order.len(), |slot| {
                let i = order[slot];
                let req = &requests[i];
                let placement = placements[i]
                    .as_ref()
                    .expect("scheduled request must be admitted");
                let t = Instant::now();
                let out = (|| {
                    let be = factory.make(req, placement, rank_threads)?;
                    let exec_cfg = ModelConfig::preset(&req.preset)?;
                    let mut gen = DataGen::new(exec_cfg, req.seed);
                    be.infer(&gen.next_batch().msa_tokens)
                })();
                (i, out, t.elapsed().as_secs_f64())
            });

        // 5. assemble outcomes in submission order + the metrics ledger
        let mut exec_map: BTreeMap<usize, (Result<InferOutput>, f64)> = executed
            .into_iter()
            .map(|(i, out, wall)| (i, (out, wall)))
            .collect();
        let mut outcomes = Vec::with_capacity(requests.len());
        for (i, (req, pl)) in requests.iter().zip(placements.into_iter()).enumerate() {
            let outcome = match pl {
                Err(e) => RequestOutcome {
                    id: req.id.clone(),
                    preset: req.preset.clone(),
                    placement: None,
                    output: Err(e),
                    note: None,
                    wall_seconds: 0.0,
                },
                Ok(p) => {
                    let (out, wall) = exec_map
                        .remove(&i)
                        .unwrap_or((Err(Error::msg("request was not executed")), 0.0));
                    let (output, note) = match out {
                        Ok(InferOutput { msa_logits, dist_logits, note }) => {
                            (Ok((msa_logits, dist_logits)), note)
                        }
                        Err(e) => (Err(e), None),
                    };
                    RequestOutcome {
                        id: req.id.clone(),
                        preset: req.preset.clone(),
                        placement: Some(p),
                        output,
                        note,
                        wall_seconds: wall,
                    }
                }
            };
            outcomes.push(outcome);
        }

        let mut stats = ServeStats::default();
        for o in &outcomes {
            stats.push(ServeRecord {
                id: o.id.clone(),
                backend: o
                    .placement
                    .as_ref()
                    .map(|p| p.backend.name())
                    .unwrap_or_else(|| "rejected".into()),
                modeled_latency: o.placement.as_ref().map(|p| p.modeled_latency).unwrap_or(0.0),
                modeled_flops: o.placement.as_ref().map(|p| p.modeled_flops).unwrap_or(0.0),
                wall_seconds: o.wall_seconds,
                ok: o.output.is_ok(),
                cached: false,
            });
        }

        Ok(ServeReport {
            outcomes,
            order,
            threads: self.threads,
            wall_seconds: t0.elapsed().as_secs_f64(),
            modeled_makespan,
            stats,
        })
    }
}

impl BackendFactory for Engine<'_> {
    fn make<'a>(
        &'a self,
        req: &InferRequest,
        placement: &Placement,
        rank_threads: usize,
    ) -> Result<Box<dyn InferBackend + 'a>> {
        let params = self.params_for(&req.preset)?;
        Ok(match &placement.backend {
            BackendKind::SingleDevice | BackendKind::Chunked => Box::new(TrunkBackend {
                rt: self.rt,
                preset: req.preset.clone(),
                params,
                naive: req.naive,
                plan: placement.plan.clone(),
                chunked: placement.backend == BackendKind::Chunked,
            }),
            BackendKind::Dap(n) => Box::new(DapBackend {
                rt: self.rt,
                preset: req.preset.clone(),
                params,
                n: *n,
                overlap: self.overlap,
                rank_threads,
                plan: placement.plan.clone(),
            }),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn jsonl_parsing_defaults_and_overrides() {
        let src = r#"
            {"id": "a", "preset": "small", "len": 2048, "priority": 2}
            # comment line
            {"seed": 11, "naive": true}
            {"id": "d", "dap": 4}
            {"id": "s", "backend": "chunked"}
        "#;
        let reqs = InferRequest::parse_jsonl(src).unwrap();
        assert_eq!(reqs.len(), 4);
        assert_eq!(reqs[0].id, "a");
        assert_eq!(reqs[0].preset, "small");
        assert_eq!(reqs[0].model_len, Some(2048));
        assert_eq!(reqs[0].priority, 2);
        assert_eq!(reqs[1].id, "req1");
        assert_eq!(reqs[1].preset, "tiny");
        assert_eq!(reqs[1].seed, 11);
        assert!(reqs[1].naive);
        assert_eq!(reqs[2].force, Some(BackendKind::Dap(4)));
        assert_eq!(reqs[3].force, Some(BackendKind::Chunked));
        assert!(InferRequest::parse_jsonl("{\"backend\": \"warp\"}").is_err());
        assert!(InferRequest::parse_jsonl("not json").is_err());
        // bare non-object JSON lines error instead of becoming defaults
        assert!(InferRequest::parse_jsonl("42").is_err());
        assert!(InferRequest::parse_jsonl("[{\"id\": \"a\"}]").is_err());
        // a misspelled key is a loud error, not a silently dropped setting
        assert!(InferRequest::parse_jsonl("{\"lenght\": 4096}").is_err());
        // so are conflicting backend pins
        assert!(
            InferRequest::parse_jsonl(r#"{"backend": "chunked", "dap": 4}"#).is_err()
        );
    }

    #[test]
    fn dap_one_is_not_a_forced_backend() {
        let reqs = InferRequest::parse_jsonl(r#"{"dap": 1}"#).unwrap();
        assert_eq!(reqs[0].force, None);
    }

    #[test]
    fn content_key_ignores_id_only() {
        let a = InferRequest::new("a", "tiny");
        let b = InferRequest::new("b", "tiny");
        assert_eq!(a.content_key(), b.content_key());
        let tweaks: [fn(&mut InferRequest); 6] = [
            |r| r.preset = "small".into(),
            |r| r.model_len = Some(512),
            |r| r.priority = 1,
            |r| r.naive = true,
            |r| r.seed = 99,
            |r| r.force = Some(BackendKind::Chunked),
        ];
        for tweak in tweaks {
            let mut t = InferRequest::new("a", "tiny");
            tweak(&mut t);
            assert_ne!(a.content_key(), t.content_key(), "{}", t.content_key());
        }
    }

    #[test]
    fn pull_map_matches_sequential_at_any_width() {
        let want: Vec<usize> = (0..37).map(|i| i * 3 + 1).collect();
        for threads in [1usize, 2, 4, 8] {
            let got = super::pull_map(threads, 37, |i| i * 3 + 1);
            assert_eq!(got, want, "threads={threads}");
        }
        assert!(super::pull_map(4, 0, |i| i).is_empty());
        assert_eq!(super::pull_map(4, 1, |i| i), vec![0]);
    }
}
