//! Deterministic load generator + replay harness for the serve daemon.
//!
//! `fastfold loadgen` synthesizes a large request trace from a seeded
//! distribution (shape mix skewed short, Poisson-like arrivals scaled
//! to a target lane utilization, recency-biased duplicates for the
//! result cache, per-request deadlines and cancellations), replays it
//! through [`daemon::simulate`], and writes the service-quality ledger
//! — p50/p99 modeled latency, throughput, deadline-miss rate, and the
//! cache-hit curve — into `BENCH_serve.json`.
//!
//! Everything downstream of the seed is pure arithmetic on the virtual
//! clock: the same seed produces a byte-identical trace file and a
//! byte-identical ledger at any `--threads` budget, which is what lets
//! CI gate on the numbers instead of eyeballing them.

use crate::bench::{num, obj};
use crate::json::Json;
use crate::metrics::percentile;
use crate::rng::Rng;

use super::daemon::{self, DaemonConfig, DaemonReport, Disposition, TraceEvent};
use super::planner::{MemoPlanner, PlacementPlanner};
use super::InferRequest;

/// Trace-synthesis parameters. Every field feeds the seeded generator,
/// so two equal specs produce byte-identical traces.
#[derive(Clone, Debug)]
pub struct LoadgenSpec {
    /// Number of requests to synthesize.
    pub requests: usize,
    /// Generator seed.
    pub seed: u64,
    /// Modeled worker lanes the arrival rate is scaled against.
    pub lanes: usize,
    /// Target lane utilization the arrival rate aims for (0, 1).
    pub util: f64,
    /// Fraction of requests that duplicate a recent request's content
    /// (the cache's workload).
    pub dup_frac: f64,
    /// Fraction of requests that carry a deadline.
    pub deadline_frac: f64,
    /// Fraction of requests that carry a cancellation time.
    pub cancel_frac: f64,
    /// Trailing window duplicates draw their source from.
    pub window: usize,
}

impl LoadgenSpec {
    /// A spec with the default workload shape at `requests` requests.
    pub fn new(requests: usize, seed: u64) -> Self {
        LoadgenSpec {
            requests,
            seed,
            lanes: 4,
            util: 0.7,
            dup_frac: 0.35,
            deadline_frac: 0.5,
            cancel_frac: 0.05,
            window: 256,
        }
    }

    /// The tier-1 quick trace: 100k requests (the CI serve-smoke and
    /// the full-trace integration test both replay this in seconds).
    pub fn quick(seed: u64) -> Self {
        LoadgenSpec::new(100_000, seed)
    }
}

impl Default for LoadgenSpec {
    /// The headline workload: a million-request trace.
    fn default() -> Self {
        LoadgenSpec::new(1_000_000, 17)
    }
}

/// The shape mix the generator draws from: `(modeled len, weight)`,
/// skewed short the way folding queues are, with a thin tail of
/// fleet-rejected 8k monsters to exercise admission control.
/// `None` is the executable tiny-preset shape.
const SHAPE_MIX: [(Option<usize>, f64); 8] = [
    (None, 0.25),
    (Some(256), 0.15),
    (Some(512), 0.20),
    (Some(1024), 0.15),
    (Some(2048), 0.12),
    (Some(3072), 0.07),
    (Some(4096), 0.05),
    (Some(8192), 0.01),
];

/// Round a virtual second to whole microseconds — keeps trace files
/// human-readable without losing round-trip fidelity.
fn round_us(t: f64) -> f64 {
    (t * 1e6).round() / 1e6
}

/// Synthesize a deterministic trace: shape mix per [`SHAPE_MIX`],
/// exponential arrival gaps scaled so the admitted work targets
/// `util` across `lanes`, duplicates drawn recency-biased from the
/// trailing `window`, deadlines proportional to the request's own
/// modeled latency, cancellations shortly after arrival. The returned
/// trace is arrival-sorted.
pub fn synthesize(planner: &PlacementPlanner, spec: &LoadgenSpec) -> Vec<TraceEvent> {
    let mut rng = Rng::new(spec.seed);
    let mut memo = MemoPlanner::new(planner);

    // price each distinct shape once: latency feeds both the arrival
    // scale and the deadline draw (0 for admission-rejected shapes)
    let shape_latency: Vec<f64> = SHAPE_MIX
        .iter()
        .map(|(len, _)| {
            let mut probe = InferRequest::new("probe", "tiny");
            probe.model_len = *len;
            memo.place(&probe).map(|p| p.modeled_latency).unwrap_or(0.0)
        })
        .collect();
    let mean_latency: f64 = SHAPE_MIX
        .iter()
        .zip(shape_latency.iter())
        .map(|((_, w), lat)| w * lat)
        .sum();
    // offered load = mean_latency / (gap * lanes) => gap for target util
    let mean_gap = mean_latency / (spec.lanes.max(1) as f64 * spec.util.clamp(0.05, 0.99));

    let mut trace: Vec<TraceEvent> = Vec::with_capacity(spec.requests);
    let mut latencies: Vec<f64> = Vec::with_capacity(spec.requests);
    let mut clock = 0.0f64;
    for i in 0..spec.requests {
        clock += -(1.0 - rng.uniform()).ln() * mean_gap;
        let arrival = round_us(clock);

        let (req, lat) = if !trace.is_empty() && rng.bernoulli(spec.dup_frac) {
            // duplicate a recent request's full content (new id) — the
            // cache keys on content, so this is a prospective hit
            let span = trace.len().min(spec.window.max(1));
            let src = trace.len() - 1 - rng.below(span);
            let mut req = trace[src].req.clone();
            req.id = format!("r{i}");
            (req, latencies[src])
        } else {
            let mut acc = 0.0;
            let draw = rng.uniform();
            let mut shape = 0usize;
            for (k, (_, w)) in SHAPE_MIX.iter().enumerate() {
                acc += w;
                if draw < acc {
                    shape = k;
                    break;
                }
            }
            let mut req = InferRequest::new(&format!("r{i}"), "tiny");
            req.model_len = SHAPE_MIX[shape].0;
            req.seed = rng.below(1_000_000) as u64;
            let p = rng.uniform();
            req.priority = if p < 0.7 {
                0
            } else if p < 0.9 {
                1
            } else {
                2
            };
            (req, shape_latency[shape])
        };

        let deadline = if lat > 0.0 && rng.bernoulli(spec.deadline_frac) {
            // 1.5x–8x the request's own service time: tight enough to
            // miss under queueing, loose enough that most make it
            Some(round_us(lat * (1.5 + 6.5 * rng.uniform())))
        } else {
            None
        };
        let cancel_at = if rng.bernoulli(spec.cancel_frac) {
            // within ~2 service times of arrival: some fire while the
            // request still queues (cancelled), the rest after it
            // finished (no-ops)
            Some(round_us(arrival + 2.0 * lat.max(0.1) * rng.uniform()))
        } else {
            None
        };

        latencies.push(lat);
        trace.push(TraceEvent { req, arrival, deadline, cancel_at });
    }
    trace
}

/// Per-decile cache-hit fraction over the trace (completed requests
/// only): decile `d` covers trace indices `[d*n/10, (d+1)*n/10)`. The
/// curve climbs as the cache warms — flat zero means the cache never
/// engaged.
pub fn hit_curve(report: &DaemonReport) -> Vec<f64> {
    let n = report.outcomes.len();
    let mut curve = Vec::with_capacity(10);
    for d in 0..10usize {
        let (lo, hi) = (d * n / 10, (d + 1) * n / 10);
        let mut completed = 0usize;
        let mut hits = 0usize;
        for o in &report.outcomes[lo..hi] {
            if let Disposition::Completed { cached, .. } = o.disposition {
                completed += 1;
                hits += usize::from(cached);
            }
        }
        curve.push(if completed > 0 { hits as f64 / completed as f64 } else { 0.0 });
    }
    curve
}

fn pct_or_zero(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        percentile(xs.to_vec(), p)
    }
}

/// The `BENCH_serve.json` ledger for one replay: daemon config echo,
/// lifecycle counts, the p50/p90/p99 modeled-sojourn ledger,
/// throughput, deadline-miss rate, and the cache section with its
/// per-decile hit curve. Pure arithmetic on the report — byte-identical
/// across runs and thread counts for the same trace. (`fastfold
/// daemon` replaying a foreign trace emits this directly; `fastfold
/// loadgen` adds its spec echo via [`bench_doc`].)
pub fn report_doc(cfg: &DaemonConfig, report: &DaemonReport) -> Json {
    let sojourns = report.sojourns();
    let mean = if sojourns.is_empty() {
        0.0
    } else {
        sojourns.iter().sum::<f64>() / sojourns.len() as f64
    };
    let max = sojourns.iter().fold(0.0f64, |a, &b| a.max(b));
    let completed = report.completed();
    let throughput = if report.makespan > 0.0 {
        completed as f64 / report.makespan
    } else {
        0.0
    };
    let hit_rate = if completed > 0 {
        report.cache_hits() as f64 / completed as f64
    } else {
        0.0
    };
    obj(vec![
        ("kind", Json::Str("serve".into())),
        (
            "daemon",
            obj(vec![
                ("policy", Json::Str(cfg.policy.name().into())),
                ("max_bypass", num(cfg.max_bypass as f64)),
                ("lanes", num(cfg.lanes as f64)),
                ("queue_cap", num(cfg.queue_cap as f64)),
                ("cache_bytes", num(cfg.cache_bytes as f64)),
                ("cache_hit_latency_s", num(cfg.cache_hit_latency)),
            ]),
        ),
        (
            "outcomes",
            obj(vec![
                ("events", num(report.outcomes.len() as f64)),
                ("completed", num(completed as f64)),
                ("cache_hits", num(report.cache_hits() as f64)),
                ("completed_late", num(report.completed_late() as f64)),
                ("rejected", num(report.rejected() as f64)),
                ("shed", num(report.shed() as f64)),
                ("expired", num(report.expired() as f64)),
                ("cancelled", num(report.cancelled() as f64)),
                ("failed", num(report.failed() as f64)),
                ("retries", num(report.retries as f64)),
                ("fallbacks", num(report.fallbacks as f64)),
                ("breaker_shed", num(report.breaker_shed as f64)),
                ("peak_queue", num(report.peak_queue as f64)),
            ]),
        ),
        (
            "latency",
            obj(vec![
                ("p50_s", num(pct_or_zero(&sojourns, 50.0))),
                ("p90_s", num(pct_or_zero(&sojourns, 90.0))),
                ("p99_s", num(pct_or_zero(&sojourns, 99.0))),
                ("mean_s", num(mean)),
                ("max_s", num(max)),
            ]),
        ),
        ("throughput_rps", num(throughput)),
        ("deadline_miss_rate", num(report.deadline_miss_rate())),
        (
            "cache",
            obj(vec![
                ("hit_rate", num(hit_rate)),
                ("evictions", num(report.cache.evictions as f64)),
                ("insertions", num(report.cache.insertions as f64)),
                ("peak_bytes", num(report.cache.peak_bytes as f64)),
                ("used_bytes", num(report.cache.used_bytes as f64)),
                ("hit_curve", Json::Arr(hit_curve(report).into_iter().map(num).collect())),
            ]),
        ),
        ("makespan_s", num(report.makespan)),
        ("aggregate_pflops", num(report.stats().aggregate_pflops(report.makespan))),
    ])
}

/// [`report_doc`] plus the loadgen spec echo — the full
/// `BENCH_serve.json` written by `fastfold loadgen`.
pub fn bench_doc(spec: &LoadgenSpec, cfg: &DaemonConfig, report: &DaemonReport) -> Json {
    let mut doc = report_doc(cfg, report);
    if let Json::Obj(map) = &mut doc {
        map.insert(
            "spec".into(),
            obj(vec![
                ("requests", num(spec.requests as f64)),
                ("seed", num(spec.seed as f64)),
                ("lanes", num(spec.lanes as f64)),
                ("util", num(spec.util)),
                ("dup_frac", num(spec.dup_frac)),
                ("deadline_frac", num(spec.deadline_frac)),
                ("cancel_frac", num(spec.cancel_frac)),
                ("window", num(spec.window as f64)),
            ]),
        );
    }
    doc
}

/// Synthesize `spec`'s trace and replay it through the daemon: the one
/// call behind `fastfold loadgen` and the CI serve-smoke.
pub fn generate_and_replay(
    planner: &PlacementPlanner,
    spec: &LoadgenSpec,
    cfg: &DaemonConfig,
) -> (Vec<TraceEvent>, DaemonReport) {
    let trace = synthesize(planner, spec);
    let report = daemon::simulate(planner, cfg, &trace);
    (trace, report)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::RunConfig;

    fn planner() -> PlacementPlanner {
        PlacementPlanner::from_run_config(&RunConfig::default()).expect("default planner")
    }

    fn small_spec() -> LoadgenSpec {
        let mut spec = LoadgenSpec::new(400, 5);
        spec.window = 64;
        spec
    }

    #[test]
    fn synthesis_is_deterministic_and_sorted() {
        let p = planner();
        let a = synthesize(&p, &small_spec());
        let b = synthesize(&p, &small_spec());
        assert_eq!(TraceEvent::to_jsonl(&a), TraceEvent::to_jsonl(&b));
        assert!(a.windows(2).all(|w| w[0].arrival <= w[1].arrival), "arrival-sorted");
        // a different seed moves the workload
        let mut other = small_spec();
        other.seed = 6;
        let c = synthesize(&p, &other);
        assert_ne!(TraceEvent::to_jsonl(&a), TraceEvent::to_jsonl(&c));
    }

    #[test]
    fn trace_roundtrips_through_jsonl() {
        let p = planner();
        let a = synthesize(&p, &small_spec());
        let parsed = TraceEvent::parse_jsonl(&TraceEvent::to_jsonl(&a)).unwrap();
        assert_eq!(TraceEvent::to_jsonl(&parsed), TraceEvent::to_jsonl(&a));
    }

    #[test]
    fn replay_ledger_is_deterministic_and_complete() {
        let p = planner();
        let spec = small_spec();
        let cfg = DaemonConfig::from_run_config(&RunConfig::default(), spec.lanes);
        let (trace, report) = generate_and_replay(&p, &spec, &cfg);
        assert_eq!(report.outcomes.len(), trace.len());
        // every request reaches exactly one terminal state
        let accounted = report.completed()
            + report.rejected()
            + report.shed()
            + report.expired()
            + report.cancelled();
        assert_eq!(accounted, trace.len());
        assert!(report.cache_hits() > 0, "dup_frac must produce hits");
        let doc_a = bench_doc(&spec, &cfg, &report).to_string();
        let (_, report_b) = generate_and_replay(&p, &spec, &cfg);
        let doc_b = bench_doc(&spec, &cfg, &report_b).to_string();
        assert_eq!(doc_a, doc_b, "ledger must be byte-identical across runs");
        for key in [
            "\"p50_s\"",
            "\"p99_s\"",
            "\"throughput_rps\"",
            "\"deadline_miss_rate\"",
            "\"hit_curve\"",
        ] {
            assert!(doc_a.contains(key), "missing {key} in {doc_a}");
        }
    }

    #[test]
    fn hit_curve_warms_up() {
        let p = planner();
        let spec = small_spec();
        let cfg = DaemonConfig::from_run_config(&RunConfig::default(), spec.lanes);
        let (_, report) = generate_and_replay(&p, &spec, &cfg);
        let curve = hit_curve(&report);
        assert_eq!(curve.len(), 10);
        assert!(curve.iter().all(|&h| (0.0..=1.0).contains(&h)));
        // the tail of the trace should hit at least as often as the
        // cold first decile (the cache warms)
        let tail: f64 = curve[5..].iter().sum();
        assert!(tail >= curve[0], "curve should not decay below cold start");
    }
}
