//! The [`InferBackend`] trait and the production backends the placement
//! planner chooses among. Each backend wraps an existing execution path —
//! the refactor moves *selection* into the engine, not the math:
//!
//! * [`TrunkBackend`] → [`single_device_forward`] (Fig 12 path), serving
//!   both the `single` and `chunked` placements (chunking is a memory
//!   schedule on this testbed, not a numeric change — same outputs,
//!   latency/peak priced by the plan);
//! * [`DapBackend`] → [`DapCoordinator::model_forward`] (Fig 13 path),
//!   one coordinator per request so the tape/comm state stays private.
//!
//! [`BackendFactory`] is the construction seam (the same idea as
//! [`crate::dap::executor::SegmentRunner`]): the engine is the production
//! factory; tests inject pure-host fakes to exercise the scheduler and
//! drain loop without artifacts.

use crate::dap::DapCoordinator;
use crate::error::Result;
use crate::inference::autochunk::AutoChunkPlan;
use crate::inference::single_device_forward;
use crate::runtime::Runtime;
use crate::tensor::{HostTensor, IntTensor};
use std::sync::Arc;

use super::planner::Placement;
use super::InferRequest;

/// What a backend returns for one request.
#[derive(Clone, Debug)]
pub struct InferOutput {
    /// BERT-head logits over the MSA, `(n_seq, n_res, vocab)`.
    pub msa_logits: HostTensor,
    /// Distogram logits, `(n_res, n_res, n_dist_bins)`.
    pub dist_logits: HostTensor,
    /// One-line execution note for logs (plan summary, overlap report).
    pub note: Option<String>,
}

impl InferOutput {
    /// Bytes this output occupies — the price the result cache charges
    /// an entry against its byte budget (tensor payloads plus the note).
    pub fn size_bytes(&self) -> usize {
        self.msa_logits.size_bytes()
            + self.dist_logits.size_bytes()
            + self.note.as_ref().map_or(0, String::len)
    }
}

/// One execution strategy behind the engine. Implementations need not be
/// `Sync` — the engine constructs a backend inside the worker thread that
/// runs the request.
pub trait InferBackend {
    /// Stable display name (`single`, `chunked`, `dap4`).
    fn name(&self) -> String;
    /// Execute one request's forward pass on this strategy.
    fn infer(&self, tokens: &IntTensor) -> Result<InferOutput>;
}

/// Builds the backend for a placed request. `rank_threads` is the
/// intra-request rank-executor budget (the engine hands each request one
/// lane when several run concurrently).
pub trait BackendFactory: Sync {
    /// Construct the backend `placement` calls for.
    fn make<'a>(
        &'a self,
        req: &InferRequest,
        placement: &Placement,
        rank_threads: usize,
    ) -> Result<Box<dyn InferBackend + 'a>>;
}

/// Single-device trunk execution (the Fig 12 measurement path), serving
/// both the `single` and `chunked` placements: on this testbed an
/// AutoChunk plan is a memory *schedule*, not a numeric change, so both
/// run [`single_device_forward`] — `chunked` carries the plan it
/// executes under as its note, `single` carries the guard's advisory.
pub struct TrunkBackend<'rt> {
    /// Artifact runtime (shared executable cache).
    pub rt: &'rt Runtime,
    /// Preset whose artifacts execute.
    pub preset: String,
    /// Full canonical parameter leaves (engine-cached).
    pub params: Arc<Vec<HostTensor>>,
    /// Unfused-kernel baseline variant.
    pub naive: bool,
    /// The placement's AutoChunk plan (None with the guard off).
    pub plan: Option<AutoChunkPlan>,
    /// Whether this placement executes under the chunk plan (`chunked`)
    /// or unchunked with the plan as advisory (`single`).
    pub chunked: bool,
}

impl InferBackend for TrunkBackend<'_> {
    fn name(&self) -> String {
        if self.chunked { "chunked" } else { "single" }.into()
    }

    fn infer(&self, tokens: &IntTensor) -> Result<InferOutput> {
        let (m, z) =
            single_device_forward(self.rt, &self.preset, &self.params, tokens, self.naive)?;
        let note = self.plan.as_ref().map(|p| {
            if self.chunked {
                p.summary()
            } else {
                format!("memory guard (advisory): {}", p.summary())
            }
        });
        Ok(InferOutput { msa_logits: m, dist_logits: z, note })
    }
}

/// Dynamic Axial Parallelism at degree `n`, wrapping the existing
/// coordinator (threaded rank executor + Duality-Async comm worker).
pub struct DapBackend<'rt> {
    /// Artifact runtime (shared executable cache).
    pub rt: &'rt Runtime,
    /// Preset whose artifacts execute.
    pub preset: String,
    /// Full canonical parameter leaves (engine-cached).
    pub params: Arc<Vec<HostTensor>>,
    /// DAP degree (logical ranks).
    pub n: usize,
    /// Duality-Async overlap on/off.
    pub overlap: bool,
    /// Intra-request rank-executor thread budget.
    pub rank_threads: usize,
    /// Advisory chunked-fallback plan from the memory guard.
    pub plan: Option<AutoChunkPlan>,
}

impl InferBackend for DapBackend<'_> {
    fn name(&self) -> String {
        format!("dap{}", self.n)
    }

    fn infer(&self, tokens: &IntTensor) -> Result<InferOutput> {
        let co = DapCoordinator::new(self.rt, &self.preset, self.n, self.overlap)?
            .with_threads(self.rank_threads);
        let (m, z) = co.model_forward(&self.params, tokens)?;
        let overlap = format!("overlap: {}", co.overlap_report());
        let note = match &self.plan {
            Some(p) => format!("memory guard (advisory): {} | {overlap}", p.summary()),
            None => overlap,
        };
        Ok(InferOutput { msa_logits: m, dist_logits: z, note: Some(note) })
    }
}

/// Fault-injection seam over any [`BackendFactory`]: construction
/// attempts are numbered in call order, and attempts named by the wrapped
/// [`crate::faults::FaultSchedule`]'s serve events fail with a simulated
/// device failure instead of building a backend. The executed drain path
/// and its tests use this to exercise mid-batch backend errors without
/// touching the production factories.
pub struct ChaosFactory<'f> {
    inner: &'f dyn BackendFactory,
    schedule: crate::faults::FaultSchedule,
    // (next attempt number, per-event consumed budget) — a Mutex because
    // `make` takes `&self` from concurrent drain workers
    state: std::sync::Mutex<(usize, Vec<usize>)>,
}

impl<'f> ChaosFactory<'f> {
    /// Wrap `inner`, failing the construction attempts `schedule.serve`
    /// names (attempt `at`, `count` consecutive failures).
    pub fn new(
        inner: &'f dyn BackendFactory,
        schedule: crate::faults::FaultSchedule,
    ) -> Self {
        let spent = vec![0; schedule.serve.len()];
        ChaosFactory {
            inner,
            schedule,
            state: std::sync::Mutex::new((0, spent)),
        }
    }

    /// Attempts injected as failures so far.
    pub fn injected(&self) -> usize {
        match self.state.lock() {
            Ok(s) => s.1.iter().sum(),
            Err(_) => 0,
        }
    }
}

impl BackendFactory for ChaosFactory<'_> {
    fn make<'a>(
        &'a self,
        req: &InferRequest,
        placement: &Placement,
        rank_threads: usize,
    ) -> Result<Box<dyn InferBackend + 'a>> {
        let mut fail = None;
        if let Ok(mut s) = self.state.lock() {
            let seq = s.0;
            s.0 += 1;
            for (i, e) in self.schedule.serve.iter().enumerate() {
                if seq >= e.at && seq < e.at + e.count && s.1[i] < e.count {
                    s.1[i] += 1;
                    fail = Some(seq);
                    break;
                }
            }
        }
        if let Some(seq) = fail {
            return Err(crate::error::Error::msg(format!(
                "injected backend failure for '{}' (chaos attempt {seq})",
                req.id
            )));
        }
        self.inner.make(req, placement, rank_threads)
    }
}
