//! Inference drivers (paper §V.C–§IV):
//!
//! * [`single`] — single-device trunk execution (`block_fwd` per block),
//!   the short-sequence path (Fig 12), with the naive-kernel variant as the
//!   baseline, plus the AutoChunk memory guard for long sequences.
//! * [`chunking`] — the baselines' *uniform* long-sequence strategy: one
//!   power-of-two chunk factor over the attention batch axis (trades speed
//!   for memory; paper §V.C). Kept as the comparison baseline.
//! * [`autochunk`] — the cost-model-driven planner (paper §IV): per-module
//!   chunk strategies searched against the fine-grained memory model, with
//!   a latency-aware objective. The primary long-sequence path.
//! * distributed inference = [`crate::dap::DapCoordinator::model_forward`]
//!   (Fig 13 / Table V FastFold path), with
//!   [`crate::dap::DapCoordinator::autochunk_fallback`] planning the
//!   chunked fallback when a DAP degree alone is not enough.
//! * [`engine`] — the request-driven serving layer over all of the above:
//!   cost-model placement with admission control, a deterministic
//!   FIFO/SJF scheduler, and a `--threads`-bounded drain loop
//!   (`fastfold serve`).

pub mod autochunk;
pub mod chunking;
pub mod engine;
pub mod single;

pub use autochunk::AutoChunkPlan;
pub use engine::{Engine, InferRequest};
pub use single::single_device_forward;
