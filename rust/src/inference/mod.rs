//! Inference drivers (paper §V.C):
//!
//! * [`single`] — single-device trunk execution (`block_fwd` per block),
//!   the short-sequence path (Fig 12), with the naive-kernel variant as the
//!   baseline.
//! * [`chunking`] — the baselines' long-sequence strategy: split the
//!   attention batch axis into chunks executed sequentially (trades speed
//!   for memory; paper §V.C).
//! * distributed inference = [`crate::dap::DapCoordinator::model_forward`]
//!   (Fig 13 / Table V FastFold path).

pub mod chunking;
pub mod single;

pub use single::single_device_forward;
