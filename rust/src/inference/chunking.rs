//! Uniform chunked inference — the baselines' long-sequence strategy
//! (paper §V.C): split the attention batch axis into chunks computed
//! sequentially, trading latency for peak-transient memory. Chunking does
//! NOT shrink the resident representations, which is why single-device
//! inference still OOMs past ~3k residues (Table V) while DAP keeps
//! scaling.
//!
//! This module is the *legacy baseline*: one global power-of-two factor
//! against the coarse memory model. The cost-model-driven planner that
//! supersedes it — per-module strategies, non-power-of-two counts,
//! latency-aware objective — lives in [`crate::inference::autochunk`].
//! Agreement with this baseline is property-tested (`proptests.rs`): the
//! planner is feasible exactly where this heuristic is, and on those
//! cases never streams a larger MSA-row transient than the heuristic's
//! power-of-two choice.
//!
//! In this runtime, executed chunking reuses the DAP segment decomposition
//! with the shards run *sequentially on one device* (sum of shard times,
//! not max) — the same compute decomposition, minus the parallelism.

use crate::config::ModelConfig;
use crate::error::Result;
use crate::perfmodel::{GpuSpec, MemoryModel};

/// A uniform chunking plan: how finely the attention batch axis must be
/// split for the working set to fit device capacity.
///
/// ```
/// use fastfold::config::ModelConfig;
/// use fastfold::inference::chunking::plan_chunks;
/// use fastfold::perfmodel::{GpuSpec, MemoryModel};
///
/// // 512 residues fit unchunked; 2048 need chunking; 3072 cannot fit at all
/// let at = |n| plan_chunks(&ModelConfig::inference(n), &MemoryModel::default(),
///                          &GpuSpec::a100_40g());
/// assert_eq!(at(512).unwrap().chunks, 1);
/// assert!(at(2048).unwrap().chunks > 1);
/// assert!(at(3072).is_none());
/// ```
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ChunkPlan {
    /// Power-of-two chunk count over the attention batch axis.
    pub chunks: usize,
    /// Modeled peak bytes under this chunk count.
    pub peak_bytes: f64,
    /// latency multiplier vs unchunked (launch + re-read overhead per
    /// chunk; calibrated to the paper's "to a certain extent reduces
    /// performance" ≈ 1.2–1.4× at deep chunking)
    pub latency_factor: f64,
}

/// Find the smallest power-of-two chunk count that fits `gpu` memory, or
/// None if even the deepest chunking cannot fit (resident reps too large —
/// the paper's OOM rows).
pub fn plan_chunks(cfg: &ModelConfig, mem: &MemoryModel, gpu: &GpuSpec) -> Option<ChunkPlan> {
    let mut chunks = 1usize;
    while chunks <= 256 {
        let peak = mem.inference_peak(cfg, 1, chunks);
        if peak <= gpu.memory {
            let latency_factor = 1.0 + 0.02 * (chunks as f64).log2().max(0.0) * 2.0;
            return Some(ChunkPlan { chunks, peak_bytes: peak, latency_factor });
        }
        chunks *= 2;
    }
    None
}

/// Chunked-vs-DAP memory check used by Table V: returns per-configuration
/// verdicts (Ok(peak) or SimOom).
///
/// ```
/// use fastfold::inference::chunking::memory_verdict;
/// use fastfold::perfmodel::{GpuSpec, MemoryModel};
///
/// let mem = MemoryModel::default();
/// let gpu = GpuSpec::a100_40g();
/// // Table V: 4096 residues fit under DAP-8 but OOM under DAP-4
/// assert!(memory_verdict(4096, 8, 1, &mem, &gpu).is_ok());
/// assert!(memory_verdict(4096, 4, 1, &mem, &gpu).is_err());
/// ```
pub fn memory_verdict(
    n_res: usize,
    dap: usize,
    chunks: usize,
    mem: &MemoryModel,
    gpu: &GpuSpec,
) -> Result<f64> {
    let cfg = ModelConfig::inference(n_res);
    mem.check(&cfg, dap, chunks, gpu.memory)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn short_sequences_need_no_chunking() {
        let plan = plan_chunks(
            &ModelConfig::inference(512),
            &MemoryModel::default(),
            &GpuSpec::a100_40g(),
        )
        .unwrap();
        assert_eq!(plan.chunks, 1);
        assert!((plan.latency_factor - 1.0).abs() < 1e-9);
    }

    #[test]
    fn long_sequences_need_chunking() {
        let plan = plan_chunks(
            &ModelConfig::inference(2048),
            &MemoryModel::default(),
            &GpuSpec::a100_40g(),
        )
        .unwrap();
        assert!(plan.chunks > 1, "chunks {}", plan.chunks);
        assert!(plan.latency_factor > 1.0);
    }

    #[test]
    fn extreme_sequences_oom_even_chunked() {
        // Table V: 3072+ OOMs on a single device regardless of chunking
        let plan = plan_chunks(
            &ModelConfig::inference(3072),
            &MemoryModel::default(),
            &GpuSpec::a100_40g(),
        );
        assert!(plan.is_none());
    }

    #[test]
    fn chunk_monotonic_in_length() {
        let mem = MemoryModel::default();
        let gpu = GpuSpec::a100_40g();
        let c1 = plan_chunks(&ModelConfig::inference(1024), &mem, &gpu)
            .unwrap()
            .chunks;
        let c2 = plan_chunks(&ModelConfig::inference(2048), &mem, &gpu)
            .unwrap()
            .chunks;
        assert!(c2 >= c1);
    }
}
