//! Single-device trunk inference: embed → N × block_fwd → heads, composing
//! the per-block executable (the fused-kernel or naive variant) — the
//! Fig 12 measurement path. For long sequences, [`memory_guard`] consults
//! the AutoChunk planner before execution so an over-capacity request
//! fails fast with a sim-OOM verdict (and a plan summary when it fits)
//! instead of thrashing.

use super::autochunk::{self, AutoChunkPlan};
use crate::config::ModelConfig;
use crate::error::Result;
use crate::perfmodel::{GpuSpec, MemoryModel};
use crate::runtime::{Runtime, Value};
use crate::tensor::{HostTensor, IntTensor};

/// Plan-or-refuse gate for the single-device path: returns the AutoChunk
/// plan for `cfg` on `gpu` at the given headroom (see
/// [`autochunk::CHUNK_HEADROOM`] for the default policy, or pass the
/// deployment's `[autochunk] headroom`), or the
/// [`crate::error::Error::SimOom`] verdict when no per-module strategy
/// fits (Table V's 3072+ boundary).
pub fn memory_guard(
    cfg: &ModelConfig,
    mem: &MemoryModel,
    gpu: &GpuSpec,
    headroom: f64,
) -> Result<AutoChunkPlan> {
    autochunk::plan_with_headroom(cfg, mem, gpu, 1, headroom)
}

/// [`single_device_forward`] behind [`memory_guard`]: plans first, refuses
/// on sim-OOM, then runs and returns the plan alongside the logits. The
/// guard budgets against the *caller's* `mem` — a deployment's tuned
/// [`MemoryModel`] must change the verdict, not be silently swapped for
/// the default.
#[allow(clippy::too_many_arguments)] // mirrors the execution contract 1:1
pub fn single_device_forward_guarded(
    rt: &Runtime,
    preset: &str,
    params: &[HostTensor],
    tokens: &IntTensor,
    naive: bool,
    mem: &MemoryModel,
    gpu: &GpuSpec,
    headroom: f64,
) -> Result<(HostTensor, HostTensor, AutoChunkPlan)> {
    let cfg = ModelConfig::preset(preset)?;
    let plan = memory_guard(&cfg, mem, gpu, headroom)?;
    let (m, z) = single_device_forward(rt, preset, params, tokens, naive)?;
    Ok((m, z, plan))
}

/// Run the full model on one device. `naive` selects the unfused-kernel
/// block variant (the "PyTorch-native" baseline of Fig 12).
pub fn single_device_forward(
    rt: &Runtime,
    preset: &str,
    params: &[HostTensor],
    tokens: &IntTensor,
    naive: bool,
) -> Result<(HostTensor, HostTensor)> {
    let man = &rt.manifest;
    let embed = rt.load(&format!("{preset}/embed"))?;
    let block = rt.load(&format!(
        "{preset}/block_fwd{}",
        if naive { "_naive" } else { "" }
    ))?;
    let heads = rt.load(&format!("{preset}/heads"))?;

    let mut args: Vec<Value> = man
        .pick_params(preset, "embedder/", params)?
        .into_iter()
        .map(Into::into)
        .collect();
    args.push(tokens.clone().into());
    let out = embed.run(&args)?;
    let (mut m, mut z) = (out[0].clone(), out[1].clone());

    let n_blocks = man
        .configs
        .get(preset)
        .and_then(|c| c.opt("n_blocks"))
        .and_then(|v| v.as_usize().ok())
        .unwrap_or(1);
    for b in 0..n_blocks {
        let idx = man.block_leaf_indices(preset, b)?;
        let mut bargs: Vec<HostTensor> =
            idx.iter().map(|&i| params[i].clone()).collect();
        bargs.push(m);
        bargs.push(z);
        let out = block.run_f32(&bargs)?;
        m = out[0].clone();
        z = out[1].clone();
    }

    let mut hargs: Vec<Value> = man
        .pick_params(preset, "heads/", params)?
        .into_iter()
        .map(Into::into)
        .collect();
    hargs.push(m.into());
    hargs.push(z.into());
    let out = heads.run(&hargs)?;
    Ok((out[0].clone(), out[1].clone()))
}
