//! Single-device trunk inference: embed → N × block_fwd → heads, composing
//! the per-block executable (the fused-kernel or naive variant) — the
//! Fig 12 measurement path.

use crate::error::Result;
use crate::runtime::{Runtime, Value};
use crate::tensor::{HostTensor, IntTensor};

/// Run the full model on one device. `naive` selects the unfused-kernel
/// block variant (the "PyTorch-native" baseline of Fig 12).
pub fn single_device_forward(
    rt: &Runtime,
    preset: &str,
    params: &[HostTensor],
    tokens: &IntTensor,
    naive: bool,
) -> Result<(HostTensor, HostTensor)> {
    let man = &rt.manifest;
    let ps = man
        .params
        .get(preset)
        .ok_or_else(|| crate::Error::Manifest(format!("no params for '{preset}'")))?;
    let pick = |prefix: &str| -> Vec<HostTensor> {
        ps.leaves
            .iter()
            .enumerate()
            .filter(|(_, l)| l.name.starts_with(prefix))
            .map(|(i, _)| params[i].clone())
            .collect()
    };

    let embed = rt.load(&format!("{preset}/embed"))?;
    let block = rt.load(&format!(
        "{preset}/block_fwd{}",
        if naive { "_naive" } else { "" }
    ))?;
    let heads = rt.load(&format!("{preset}/heads"))?;

    let mut args: Vec<Value> = pick("embedder/").into_iter().map(Into::into).collect();
    args.push(tokens.clone().into());
    let out = embed.run(&args)?;
    let (mut m, mut z) = (out[0].clone(), out[1].clone());

    let n_blocks = man
        .configs
        .get(preset)
        .and_then(|c| c.opt("n_blocks"))
        .and_then(|v| v.as_usize().ok())
        .unwrap_or(1);
    for b in 0..n_blocks {
        let idx = man.block_leaf_indices(preset, b)?;
        let mut bargs: Vec<HostTensor> =
            idx.iter().map(|&i| params[i].clone()).collect();
        bargs.push(m);
        bargs.push(z);
        let out = block.run_f32(&bargs)?;
        m = out[0].clone();
        z = out[1].clone();
    }

    let mut hargs: Vec<Value> = pick("heads/").into_iter().map(Into::into).collect();
    hargs.push(m.into());
    hargs.push(z.into());
    let out = heads.run(&hargs)?;
    Ok((out[0].clone(), out[1].clone()))
}
