//! AutoChunk: cost-model-driven per-module chunk planning (paper §IV).
//!
//! The baselines' uniform chunking ([`crate::inference::chunking`]) picks
//! one power-of-two factor for the streamed attention transient. This
//! planner instead searches a **per-module strategy** over the fine-grained
//! memory model ([`BlockModule`]): every chunkable Evoformer sub-module
//! gets its own (not necessarily power-of-two) chunk count, attention
//! transients and triangle intermediates are planned separately, and the
//! objective is latency-aware — the cheapest plan that fits
//! [`GpuSpec::memory`] wins, with per-module chunk overhead weighted by
//! that module's share of block FLOPs.
//!
//! Planning rules:
//!
//! * The transient budget is `capacity − overhead − resident`. Chunkable
//!   modules are planned against `(1 − CHUNK_HEADROOM)` of that budget —
//!   the reservation absorbs allocator fragmentation and workspace spikes,
//!   and costs little because chunk overhead is amortized over large row
//!   blocks.
//! * The triangle-multiplicative working set is irreducible on one device
//!   (the `ikc,jkc->ijc` contraction needs the full `k` axis), so it is
//!   checked against the full budget: when it alone exceeds the budget the
//!   planner reports sim-OOM — reproducing the Table V 3072+ single-device
//!   boundary no strategy can escape.
//! * Each module takes the smallest chunk count that fits its limit
//!   (fewest chunks = least launch/re-read overhead = latency-minimal).
//!
//! The result is a serializable [`AutoChunkPlan`] consumed by the CLI
//! (`fastfold autochunk`), the single-device memory guard
//! ([`crate::inference::single`]), the DAP coordinator's chunked fallback,
//! and the Fig 13 / Table V benches.

use crate::config::ModelConfig;
use crate::error::{Error, Result};
use crate::json::Json;
use crate::perfmodel::memory::BlockModule;
use crate::perfmodel::{GpuSpec, MemoryModel};
use std::collections::BTreeMap;

/// Fraction of the transient budget the planner leaves free when choosing
/// chunk counts (fragmentation / workspace reservation). Feasibility of
/// the irreducible triangle working set still uses the full budget.
pub const CHUNK_HEADROOM: f64 = 0.5;

/// Relative latency cost per `ln(chunks)` of a module's runtime share —
/// calibrated so deep chunking lands in the paper's "to a certain extent
/// reduces performance" band (≈1.2–1.4×).
pub const CHUNK_LATENCY_COEF: f64 = 0.2;

/// Validate a headroom fraction — the single range check shared by the
/// `[autochunk]` config parser and [`plan_with_headroom`].
pub fn validate_headroom(headroom: f64) -> Result<()> {
    if (0.0..1.0).contains(&headroom) {
        Ok(())
    } else {
        Err(Error::Config(format!(
            "autochunk headroom must be in [0, 1), got {headroom}"
        )))
    }
}

/// One module's planned strategy inside an [`AutoChunkPlan`].
#[derive(Clone, Debug, PartialEq)]
pub struct ModuleStrategy {
    /// Which Evoformer sub-module this strategy covers.
    pub module: BlockModule,
    /// Chunk count along the module's chunk axis (1 = unchunked).
    pub chunks: usize,
    /// Peak transient bytes this module materializes under the strategy.
    pub transient_bytes: f64,
    /// This module's share of block forward FLOPs (latency weight).
    pub flops_weight: f64,
}

/// A complete per-block chunk plan: one strategy per module plus the
/// modeled memory/latency outcome.
///
/// ```
/// use fastfold::config::ModelConfig;
/// use fastfold::inference::autochunk;
/// use fastfold::perfmodel::{GpuSpec, MemoryModel};
///
/// let mem = MemoryModel::default();
/// let gpu = GpuSpec::a100_40g();
/// // 2048 residues: the planner fits a 40 GB device and cuts modeled peak
/// // memory by over 80% vs the naive unchunked execution (paper §IV).
/// let plan = autochunk::plan(&ModelConfig::inference(2048), &mem, &gpu, 1).unwrap();
/// assert!(plan.peak_bytes <= gpu.memory);
/// assert!(plan.savings_frac() >= 0.80);
/// // 3072+ still sim-OOMs on one device no matter the strategy (Table V):
/// // the triangle-mult working set is irreducible.
/// assert!(autochunk::plan(&ModelConfig::inference(3072), &mem, &gpu, 1).is_err());
/// ```
#[derive(Clone, Debug, PartialEq)]
pub struct AutoChunkPlan {
    /// Model config name the plan was built for (e.g. `infer_2048`).
    pub config: String,
    /// Residue count.
    pub n_res: usize,
    /// MSA row count.
    pub n_seq: usize,
    /// DAP degree the plan assumes (1 = single device).
    pub dap: usize,
    /// Device name the plan targets.
    pub gpu: String,
    /// Device memory capacity in bytes.
    pub capacity_bytes: f64,
    /// Resident representation bytes per device.
    pub resident_bytes: f64,
    /// Modeled peak bytes under this plan (resident + worst transient +
    /// overhead).
    pub peak_bytes: f64,
    /// Modeled peak bytes of the naive unchunked execution — the savings
    /// baseline.
    pub unchunked_peak_bytes: f64,
    /// Modeled latency multiplier vs unchunked (≥ 1.0).
    pub latency_factor: f64,
    /// Per-module strategies, in [`BlockModule::ALL`] order.
    pub modules: Vec<ModuleStrategy>,
}

impl AutoChunkPlan {
    /// Chunk count assigned to `module` (1 if absent).
    pub fn chunks_for(&self, module: BlockModule) -> usize {
        self.modules
            .iter()
            .find(|s| s.module == module)
            .map(|s| s.chunks)
            .unwrap_or(1)
    }

    /// Largest per-module transient under the plan, in bytes.
    pub fn transient_peak_bytes(&self) -> f64 {
        self.modules
            .iter()
            .map(|s| s.transient_bytes)
            .fold(0.0, f64::max)
    }

    /// Fraction of the naive unchunked peak this plan saves
    /// (`1 − peak/unchunked`).
    pub fn savings_frac(&self) -> f64 {
        if self.unchunked_peak_bytes <= 0.0 {
            0.0
        } else {
            1.0 - self.peak_bytes / self.unchunked_peak_bytes
        }
    }

    /// Whether any module is actually chunked.
    pub fn is_chunked(&self) -> bool {
        self.modules.iter().any(|s| s.chunks > 1)
    }

    /// Whether the plan fits device capacity.
    pub fn fits(&self) -> bool {
        self.peak_bytes <= self.capacity_bytes
    }

    /// The per-block module assignment as `(module, chunks)` pairs (the
    /// form [`MemoryModel::planned_peak_bytes`] consumes).
    pub fn assignment(&self) -> Vec<(BlockModule, usize)> {
        self.modules.iter().map(|s| (s.module, s.chunks)).collect()
    }

    /// One-line human summary for logs.
    pub fn summary(&self) -> String {
        let chunked: Vec<String> = self
            .modules
            .iter()
            .filter(|s| s.chunks > 1)
            .map(|s| format!("{}x{}", s.module.name(), s.chunks))
            .collect();
        format!(
            "autochunk[{} dap={}]: peak {:.1} GB / cap {:.0} GB, \
             saves {:.1}% vs unchunked, latency x{:.2}, strategies: {}",
            self.config,
            self.dap,
            self.peak_bytes / 1e9,
            self.capacity_bytes / 1e9,
            100.0 * self.savings_frac(),
            self.latency_factor,
            if chunked.is_empty() { "none needed".into() } else { chunked.join(" ") }
        )
    }

    /// Serialize through the crate JSON codec.
    pub fn to_json(&self) -> Json {
        let mut o = BTreeMap::new();
        o.insert("version".into(), Json::Num(1.0));
        o.insert("config".into(), Json::Str(self.config.clone()));
        o.insert("n_res".into(), Json::Num(self.n_res as f64));
        o.insert("n_seq".into(), Json::Num(self.n_seq as f64));
        o.insert("dap".into(), Json::Num(self.dap as f64));
        o.insert("gpu".into(), Json::Str(self.gpu.clone()));
        o.insert("capacity_bytes".into(), Json::Num(self.capacity_bytes));
        o.insert("resident_bytes".into(), Json::Num(self.resident_bytes));
        o.insert("peak_bytes".into(), Json::Num(self.peak_bytes));
        o.insert(
            "unchunked_peak_bytes".into(),
            Json::Num(self.unchunked_peak_bytes),
        );
        o.insert("latency_factor".into(), Json::Num(self.latency_factor));
        o.insert(
            "modules".into(),
            Json::Arr(
                self.modules
                    .iter()
                    .map(|s| {
                        let mut m = BTreeMap::new();
                        m.insert("module".into(), Json::Str(s.module.name().into()));
                        m.insert("chunks".into(), Json::Num(s.chunks as f64));
                        m.insert(
                            "transient_bytes".into(),
                            Json::Num(s.transient_bytes),
                        );
                        m.insert("flops_weight".into(), Json::Num(s.flops_weight));
                        Json::Obj(m)
                    })
                    .collect(),
            ),
        );
        Json::Obj(o)
    }

    /// Deserialize a plan produced by [`AutoChunkPlan::to_json`].
    pub fn from_json(j: &Json) -> Result<Self> {
        let modules = j
            .get("modules")?
            .as_arr()?
            .iter()
            .map(|m| {
                Ok(ModuleStrategy {
                    module: BlockModule::parse(m.get("module")?.as_str()?)?,
                    chunks: m.get("chunks")?.as_usize()?.max(1),
                    transient_bytes: m.get("transient_bytes")?.as_f64()?,
                    flops_weight: m.get("flops_weight")?.as_f64()?,
                })
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(AutoChunkPlan {
            config: j.get("config")?.as_str()?.to_string(),
            n_res: j.get("n_res")?.as_usize()?,
            n_seq: j.get("n_seq")?.as_usize()?,
            dap: j.get("dap")?.as_usize()?.max(1),
            gpu: j.get("gpu")?.as_str()?.to_string(),
            capacity_bytes: j.get("capacity_bytes")?.as_f64()?,
            resident_bytes: j.get("resident_bytes")?.as_f64()?,
            peak_bytes: j.get("peak_bytes")?.as_f64()?,
            unchunked_peak_bytes: j.get("unchunked_peak_bytes")?.as_f64()?,
            latency_factor: j.get("latency_factor")?.as_f64()?,
            modules,
        })
    }
}

/// Per-module forward FLOPs — lives next to `block_flops` in
/// [`crate::perfmodel::flops`] so the two stay in one place.
pub use crate::perfmodel::flops::module_flops;

// ----------------------------------------------------------------- planner

/// Smallest chunk count in `[1, axis]` whose transient fits `limit_elems`
/// (binary search over the monotone transient curve), or `None`.
fn min_chunks(
    mem: &MemoryModel,
    cfg: &ModelConfig,
    module: BlockModule,
    dap: usize,
    limit_elems: f64,
) -> Option<usize> {
    let axis = module.chunk_axis_len(cfg, dap);
    if mem.module_transient_elems(cfg, module, dap, 1) <= limit_elems {
        return Some(1);
    }
    if mem.module_transient_elems(cfg, module, dap, axis) > limit_elems {
        return None;
    }
    let (mut lo, mut hi) = (1usize, axis); // f(lo) > limit, f(hi) <= limit
    while hi - lo > 1 {
        let mid = lo + (hi - lo) / 2;
        if mem.module_transient_elems(cfg, module, dap, mid) <= limit_elems {
            hi = mid;
        } else {
            lo = mid;
        }
    }
    Some(hi)
}

/// Plan per-module chunk strategies for `cfg` on `gpu` at DAP degree `dap`,
/// with the default [`CHUNK_HEADROOM`]. Errors with [`Error::SimOom`] when
/// no strategy fits (the Table V OOM verdict).
pub fn plan(
    cfg: &ModelConfig,
    mem: &MemoryModel,
    gpu: &GpuSpec,
    dap: usize,
) -> Result<AutoChunkPlan> {
    plan_with_headroom(cfg, mem, gpu, dap, CHUNK_HEADROOM)
}

/// [`plan`] with an explicit headroom fraction in `[0, 1)` (the same
/// range `[autochunk] headroom` accepts in config files; anything else is
/// an [`Error::Config`], never silently clamped).
pub fn plan_with_headroom(
    cfg: &ModelConfig,
    mem: &MemoryModel,
    gpu: &GpuSpec,
    dap: usize,
    headroom: f64,
) -> Result<AutoChunkPlan> {
    let dap = dap.max(1);
    validate_headroom(headroom)?;
    let resident = mem.resident_elems(cfg, dap);
    let budget = (gpu.memory - mem.fixed_overhead) / mem.elem_bytes - resident;
    let target = budget * (1.0 - headroom);

    let oom = |mem: &MemoryModel| -> Error {
        // best-effort floor: every chunkable module fully chunked
        let full: Vec<(BlockModule, usize)> = BlockModule::ALL
            .into_iter()
            .map(|m| (m, m.chunk_axis_len(cfg, dap).max(1)))
            .collect();
        Error::SimOom {
            need_gb: mem.planned_peak_bytes(cfg, dap, &full) / 1e9,
            cap_gb: gpu.memory / 1e9,
        }
    };

    if budget <= 0.0 {
        return Err(oom(mem));
    }

    let total_flops: f64 = BlockModule::ALL
        .into_iter()
        .map(|m| module_flops(cfg, m))
        .sum();

    let mut modules = Vec::with_capacity(BlockModule::ALL.len());
    let mut latency = 1.0f64;
    for module in BlockModule::ALL {
        let chunks = if module.chunk_axis_len(cfg, dap) <= 1 {
            // irreducible transient (triangle mult): feasibility only,
            // against the full budget
            if mem.module_transient_elems(cfg, module, dap, 1) > budget {
                return Err(oom(mem));
            }
            1
        } else {
            match min_chunks(mem, cfg, module, dap, target) {
                Some(c) => c,
                None => return Err(oom(mem)),
            }
        };
        let weight = if total_flops > 0.0 {
            module_flops(cfg, module) / total_flops
        } else {
            0.0
        };
        latency += weight * CHUNK_LATENCY_COEF * (chunks as f64).ln();
        modules.push(ModuleStrategy {
            module,
            chunks,
            transient_bytes: mem.elem_bytes
                * mem.module_transient_elems(cfg, module, dap, chunks),
            flops_weight: weight,
        });
    }

    let assignment: Vec<(BlockModule, usize)> =
        modules.iter().map(|s| (s.module, s.chunks)).collect();
    let peak = mem.planned_peak_bytes(cfg, dap, &assignment);
    if peak > gpu.memory {
        return Err(oom(mem));
    }
    Ok(AutoChunkPlan {
        config: cfg.name.clone(),
        n_res: cfg.n_res,
        n_seq: cfg.n_seq,
        dap,
        gpu: gpu.name.to_string(),
        capacity_bytes: gpu.memory,
        resident_bytes: mem.elem_bytes * resident,
        peak_bytes: peak,
        unchunked_peak_bytes: mem.unchunked_peak_bytes(cfg, dap),
        latency_factor: latency,
        modules,
    })
}

/// Smallest power-of-two DAP degree (up to `max_dap`) whose plan fits at
/// the given headroom, with the plan — the "how many GPUs do I need"
/// answer for a length. Pass [`CHUNK_HEADROOM`] for the default policy;
/// use the same headroom as the verdict you are explaining, or the
/// suggested degree may not fit under the caller's policy.
pub fn min_dap_degree(
    cfg: &ModelConfig,
    mem: &MemoryModel,
    gpu: &GpuSpec,
    max_dap: usize,
    headroom: f64,
) -> Option<(usize, AutoChunkPlan)> {
    let mut dap = 1usize;
    while dap <= max_dap {
        if let Ok(p) = plan_with_headroom(cfg, mem, gpu, dap, headroom) {
            return Some((dap, p));
        }
        dap *= 2;
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::inference::chunking;

    fn mem() -> MemoryModel {
        MemoryModel::default()
    }

    fn gpu() -> GpuSpec {
        GpuSpec::a100_40g()
    }

    #[test]
    fn saves_over_80_percent_at_2048() {
        // the §IV acceptance claim: ≥80% modeled peak reduction vs the
        // naive unchunked baseline at 2048 residues on an A100-40G
        let plan = plan(&ModelConfig::inference(2048), &mem(), &gpu(), 1).unwrap();
        assert!(plan.fits());
        assert!(plan.peak_bytes <= gpu().memory);
        assert!(
            plan.savings_frac() >= 0.80,
            "savings {:.3}",
            plan.savings_frac()
        );
        assert!(plan.is_chunked());
        assert!(plan.latency_factor > 1.0 && plan.latency_factor < 1.6);
    }

    #[test]
    fn non_power_of_two_strategies_chosen() {
        // with the default headroom the 2048-residue plan needs 10-way
        // triangle-attention chunking and 3-way MSA-row chunking — neither
        // a power of two (the legacy heuristic could not express either)
        let plan = plan(&ModelConfig::inference(2048), &mem(), &gpu(), 1).unwrap();
        let tri = plan.chunks_for(BlockModule::TriangleAttnStart);
        let row = plan.chunks_for(BlockModule::MsaRowAttn);
        assert_eq!(tri, 10, "tri-attn chunks");
        assert_eq!(row, 3, "msa-row chunks");
        assert!(!tri.is_power_of_two() && !row.is_power_of_two());
        // attention transients and triangle intermediates get separate
        // strategies: triangle mult stays unchunked (irreducible)
        assert_eq!(plan.chunks_for(BlockModule::TriangleMult), 1);
    }

    #[test]
    fn table5_single_device_boundary() {
        // Table V: 2560 fits one device with chunking; 3072+ sim-OOM
        assert!(plan(&ModelConfig::inference(2560), &mem(), &gpu(), 1).is_ok());
        for len in [3072usize, 3584, 4096] {
            let e = plan(&ModelConfig::inference(len), &mem(), &gpu(), 1)
                .unwrap_err();
            assert!(
                matches!(e, Error::SimOom { .. }),
                "len {len}: {e}"
            );
        }
    }

    #[test]
    fn table5_dap_boundary() {
        // Table V: 3584 fits DAP-4; 4096 needs DAP-8
        assert!(plan(&ModelConfig::inference(3584), &mem(), &gpu(), 4).is_ok());
        assert!(plan(&ModelConfig::inference(4096), &mem(), &gpu(), 4).is_err());
        assert!(plan(&ModelConfig::inference(4096), &mem(), &gpu(), 8).is_ok());
        let (dap, p) = min_dap_degree(
            &ModelConfig::inference(4096), &mem(), &gpu(), 64, CHUNK_HEADROOM,
        )
        .unwrap();
        assert_eq!(dap, 8);
        assert!(p.fits());
    }

    #[test]
    fn short_sequences_need_no_chunking() {
        for len in [256usize, 512, 1024] {
            let p = plan(&ModelConfig::inference(len), &mem(), &gpu(), 1).unwrap();
            assert!(!p.is_chunked(), "len {len}: {}", p.summary());
            assert!((p.latency_factor - 1.0).abs() < 1e-12);
        }
    }

    #[test]
    fn headroom_out_of_range_rejected() {
        let cfg = ModelConfig::inference(1024);
        for bad in [-0.1, 1.0, 1.5] {
            let e = plan_with_headroom(&cfg, &mem(), &gpu(), 1, bad).unwrap_err();
            assert!(matches!(e, Error::Config(_)), "headroom {bad}: {e}");
        }
        assert!(plan_with_headroom(&cfg, &mem(), &gpu(), 1, 0.0).is_ok());
    }

    #[test]
    fn plan_gpu_name_resolves_back_to_spec() {
        // the serialized plan's `gpu` field must round-trip through
        // GpuSpec::by_name so consumers can rebuild the spec
        let p = plan(&ModelConfig::inference(1024), &mem(), &gpu(), 1).unwrap();
        let spec = GpuSpec::by_name(&p.gpu).unwrap();
        assert_eq!(spec.memory, p.capacity_bytes);
    }

    #[test]
    fn json_roundtrip() {
        let p = plan(&ModelConfig::inference(2048), &mem(), &gpu(), 1).unwrap();
        let j = p.to_json();
        let back = AutoChunkPlan::from_json(&Json::parse(&j.to_string()).unwrap())
            .unwrap();
        assert_eq!(back, p);
    }

    #[test]
    fn planner_at_least_as_memory_tight_as_legacy() {
        // wherever the legacy pow2 heuristic finds a plan, the full
        // planner's MSA-row strategy (the one axis both can chunk) streams
        // at most as much transient as the legacy choice — the planner
        // never regresses on the legacy heuristic's own cases
        for len in [512usize, 1024, 1536, 2048, 2560] {
            let cfg = ModelConfig::inference(len);
            let legacy = chunking::plan_chunks(&cfg, &mem(), &gpu()).unwrap();
            let p = plan(&cfg, &mem(), &gpu(), 1).unwrap();
            let legacy_msa_bytes = mem().elem_bytes
                * mem().module_transient_elems(
                    &cfg,
                    BlockModule::MsaRowAttn,
                    1,
                    legacy.chunks,
                );
            let new_msa = p
                .modules
                .iter()
                .find(|s| s.module == BlockModule::MsaRowAttn)
                .unwrap();
            assert!(
                new_msa.transient_bytes <= legacy_msa_bytes + 1.0,
                "len {len}: planner {} vs legacy {} (chunks {} vs {})",
                new_msa.transient_bytes,
                legacy_msa_bytes,
                new_msa.chunks,
                legacy.chunks
            );
        }
    }

    #[test]
    fn weights_sum_to_one() {
        let p = plan(&ModelConfig::inference(2048), &mem(), &gpu(), 1).unwrap();
        let sum: f64 = p.modules.iter().map(|s| s.flops_weight).sum();
        assert!((sum - 1.0).abs() < 1e-9, "{sum}");
    }

    #[test]
    fn dap_relieves_chunking_pressure() {
        let c1 = plan(&ModelConfig::inference(2560), &mem(), &gpu(), 1).unwrap();
        let c4 = plan(&ModelConfig::inference(2560), &mem(), &gpu(), 4).unwrap();
        assert!(c4.peak_bytes < c1.peak_bytes);
        for m in BlockModule::ALL {
            assert!(
                c4.chunks_for(m) <= c1.chunks_for(m),
                "{}: dap4 {} vs dap1 {}",
                m.name(),
                c4.chunks_for(m),
                c1.chunks_for(m)
            );
        }
    }
}
