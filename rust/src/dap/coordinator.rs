//! The DAP executor: runs the manifest schedule per Evoformer block across
//! N logical ranks, records the tape for backward, drives the timeline.

use super::tape::{Tape, TapeOp};
use super::timeline::{CommCost, Timeline};
use crate::comm::Collectives;
use crate::config::ModelConfig;
use crate::error::{Error, Result};
use crate::manifest::ScheduleOp;
use crate::runtime::{Executable, Runtime};
use crate::tensor::{HostTensor, IntTensor};
use std::cell::RefCell;
use std::collections::BTreeMap;
use std::rc::Rc;
use std::time::Instant;

/// Per-slot, per-rank tensor state threaded through the schedule.
pub type State = BTreeMap<String, Vec<HostTensor>>;

pub struct DapCoordinator<'rt> {
    pub rt: &'rt Runtime,
    pub cfg: ModelConfig,
    pub preset: String,
    pub n: usize,
    pub comm: Collectives,
    pub timeline: RefCell<Timeline>,
    segs: BTreeMap<String, Rc<Executable>>,
    segs_bwd: BTreeMap<String, Rc<Executable>>,
    /// record a tape during forward (enable for training)
    pub record: RefCell<bool>,
    pub tape: RefCell<Tape>,
}

impl<'rt> DapCoordinator<'rt> {
    /// Load all fwd (and, if exported, bwd) segment executables for
    /// `preset` at DAP degree `n`.
    pub fn new(rt: &'rt Runtime, preset: &str, n: usize, overlap: bool) -> Result<Self> {
        let cfg = ModelConfig::preset(preset)?;
        if cfg.n_seq % n != 0 || cfg.n_res % n != 0 {
            return Err(Error::Schedule(format!(
                "dap_size {n} does not divide (n_seq={}, n_res={})",
                cfg.n_seq, cfg.n_res
            )));
        }
        let mut segs = BTreeMap::new();
        let mut segs_bwd = BTreeMap::new();
        let seg_names: Vec<String> = rt
            .manifest
            .schedule
            .iter()
            .filter_map(|op| match op {
                ScheduleOp::Exec { seg, .. } => Some(seg.clone()),
                _ => None,
            })
            .collect();
        for seg in &seg_names {
            let key = format!("{preset}/dap{n}/{seg}");
            segs.insert(seg.clone(), rt.load(&key)?);
            let bwd_key = format!("{preset}/dap{n}/{seg}_bwd");
            if rt.manifest.artifacts.contains_key(&bwd_key) {
                segs_bwd.insert(seg.clone(), rt.load(&bwd_key)?);
            }
        }
        Ok(DapCoordinator {
            rt,
            cfg,
            preset: preset.to_string(),
            n,
            comm: Collectives::new(n),
            timeline: RefCell::new(Timeline::new(n, CommCost::cpu_calibrated(), overlap)),
            segs,
            segs_bwd,
            record: RefCell::new(false),
            tape: RefCell::new(Tape::default()),
        })
    }

    pub fn has_backward(&self) -> bool {
        !self.segs_bwd.is_empty()
    }

    /// Chunked fallback for this coordinator's DAP degree: ask the
    /// AutoChunk planner for per-module chunk strategies at degree
    /// `self.n` on `gpu` with the given headroom (the deployment's
    /// `[autochunk] headroom`, or `autochunk::CHUNK_HEADROOM` for the
    /// default policy). `Ok(plan)` means the sharded working set fits
    /// (possibly by chunking transients on top of the sharding);
    /// `Err(SimOom)` means this degree cannot hold the model no matter the
    /// strategy and the caller must raise the DAP degree (Table V's
    /// FastFold-4 → OOM at 4096 verdict).
    pub fn autochunk_fallback(
        &self,
        mem: &crate::perfmodel::MemoryModel,
        gpu: &crate::perfmodel::GpuSpec,
        headroom: f64,
    ) -> Result<crate::inference::autochunk::AutoChunkPlan> {
        crate::inference::autochunk::plan_with_headroom(
            &self.cfg, mem, gpu, self.n, headroom,
        )
    }

    /// Shard full (m, z) into the canonical block-entry layout
    /// (m s-sharded, z i-sharded).
    pub fn shard_inputs(&self, m: &HostTensor, z: &HostTensor) -> Result<State> {
        let mut state = State::new();
        state.insert("m".into(), m.split_axis(0, self.n)?);
        state.insert("z".into(), z.split_axis(0, self.n)?);
        Ok(state)
    }

    /// Reassemble full (m, z) from block-exit state.
    pub fn unshard(&self, state: &State) -> Result<(HostTensor, HostTensor)> {
        let m = HostTensor::concat(&state["m"], 0)?;
        let z = HostTensor::concat(&state["z"], 0)?;
        Ok((m, z))
    }

    /// Run one Evoformer block forward under the DAP schedule.
    /// `block_params`: the block's 63 parameter leaves in canonical order
    /// (identical on every rank — DAP replicates parameters).
    pub fn block_forward(&self, block_params: &[HostTensor], state: &mut State) -> Result<()> {
        // §Perf-L3: convert parameter leaves to literals ONCE per block —
        // they are reused by all 18 segment executions × N ranks.
        // (FASTFOLD_NO_LITCACHE=1 restores the naive per-exec conversion,
        // kept for the EXPERIMENTS.md §Perf A/B measurement.)
        let lit_cache = std::env::var_os("FASTFOLD_NO_LITCACHE").is_none();
        let param_lits: Vec<xla::Literal> = if lit_cache {
            block_params.iter().map(|t| t.to_literal()).collect::<Result<_>>()?
        } else {
            Vec::new()
        };
        let schedule = self.rt.manifest.schedule.clone();
        // async collectives whose results are not yet visible in `state`
        let mut inflight: BTreeMap<String, (String, Vec<HostTensor>)> = BTreeMap::new();
        let recording = *self.record.borrow();

        for op in &schedule {
            match op {
                ScheduleOp::Exec { seg, inputs, outputs } => {
                    let exe = self
                        .segs
                        .get(seg)
                        .ok_or_else(|| Error::Schedule(format!("no segment '{seg}'")))?;
                    let mut per_rank_outs: Vec<Vec<HostTensor>> = Vec::with_capacity(self.n);
                    let t0 = Instant::now();
                    for r in 0..self.n {
                        let mut rest: Vec<HostTensor> = Vec::with_capacity(inputs.len());
                        for slot in inputs {
                            let shards = state.get(slot).ok_or_else(|| {
                                Error::Schedule(format!("slot '{slot}' unset for '{seg}'"))
                            })?;
                            rest.push(shards[r].clone());
                        }
                        if lit_cache {
                            per_rank_outs.push(exe.run_with_params(&param_lits, &rest)?);
                        } else {
                            let mut args = block_params.to_vec();
                            args.extend(rest);
                            per_rank_outs.push(exe.run_f32(&args)?);
                        }
                    }
                    let secs = t0.elapsed().as_secs_f64() / self.n as f64;
                    self.timeline.borrow_mut().exec(secs);
                    if recording {
                        let snap: Vec<Vec<HostTensor>> = inputs
                            .iter()
                            .map(|slot| state[slot].clone())
                            .collect();
                        self.tape.borrow_mut().push(TapeOp::Exec {
                            seg: seg.clone(),
                            in_slots: inputs.clone(),
                            out_slots: outputs.clone(),
                            inputs: snap,
                        });
                    }
                    for (k, slot) in outputs.iter().enumerate() {
                        let shards: Vec<HostTensor> =
                            (0..self.n).map(|r| per_rank_outs[r][k].clone()).collect();
                        state.insert(slot.clone(), shards);
                    }
                }
                ScheduleOp::Gather { input, output, axis, id } => {
                    let parts = &state[input];
                    let bytes = parts[0].size_bytes() * (self.n - 1);
                    let res = self.comm.all_gather(parts, *axis)?;
                    if recording {
                        self.tape.borrow_mut().push(TapeOp::Gather {
                            in_slot: input.clone(), out_slot: output.clone(), axis: *axis });
                    }
                    self.land(state, &mut inflight, id, output, res, bytes);
                }
                ScheduleOp::Scatter { input, output, axis, id } => {
                    let parts = &state[input];
                    let bytes = parts[0].size_bytes() * (self.n - 1) / self.n;
                    let res = self.comm.reduce_scatter(parts, *axis)?;
                    if recording {
                        self.tape.borrow_mut().push(TapeOp::Scatter {
                            in_slot: input.clone(), out_slot: output.clone(), axis: *axis });
                    }
                    self.land(state, &mut inflight, id, output, res, bytes);
                }
                ScheduleOp::AllToAll { input, output, split, concat, id } => {
                    let parts = &state[input];
                    let bytes = parts[0].size_bytes() * (self.n - 1) / self.n;
                    let res = self.comm.all_to_all(parts, *split, *concat)?;
                    if recording {
                        self.tape.borrow_mut().push(TapeOp::AllToAll {
                            in_slot: input.clone(), out_slot: output.clone(),
                            split: *split, concat: *concat });
                    }
                    self.land(state, &mut inflight, id, output, res, bytes);
                }
                ScheduleOp::Wait { id } => {
                    self.timeline.borrow_mut().wait(id);
                    if let Some((slot, val)) = inflight.remove(id) {
                        state.insert(slot, val);
                    }
                }
            }
        }
        if !inflight.is_empty() {
            return Err(Error::Schedule(format!(
                "unjoined collectives at block end: {:?}",
                inflight.keys().collect::<Vec<_>>()
            )));
        }
        Ok(())
    }

    fn land(
        &self,
        state: &mut State,
        inflight: &mut BTreeMap<String, (String, Vec<HostTensor>)>,
        id: &Option<String>,
        output: &str,
        res: Vec<HostTensor>,
        bytes: usize,
    ) {
        match id {
            Some(id) => {
                self.timeline.borrow_mut().collective_async(id, bytes);
                inflight.insert(id.clone(), (output.to_string(), res));
            }
            None => {
                self.timeline.borrow_mut().collective_sync(bytes);
                state.insert(output.to_string(), res);
            }
        }
    }

    /// Backward through one recorded block: consumes the tape, returns
    /// (param grads, d_m shards, d_z shards). `d_state` carries the
    /// cotangents of the block outputs and is updated in place to the
    /// cotangents of the block inputs.
    pub fn block_backward(&self, block_params: &[HostTensor], d_state: &mut State) -> Result<super::tape::BlockGrads> {
        let tape = std::mem::take(&mut *self.tape.borrow_mut());
        super::tape::run_backward(self, block_params, tape, d_state)
    }

    pub(crate) fn bwd_exe(&self, seg: &str) -> Result<&Rc<Executable>> {
        self.segs_bwd
            .get(seg)
            .ok_or_else(|| Error::Schedule(format!("no backward executable for '{seg}' (export with aot --configs tiny)")))
    }

    pub(crate) fn fwd_exe(&self, seg: &str) -> Result<&Rc<Executable>> {
        self.segs
            .get(seg)
            .ok_or_else(|| Error::Schedule(format!("no segment '{seg}'")))
    }

    /// Full-trunk forward for inference: embed (replicated on rank 0) →
    /// shard → N_blocks × DAP block → unshard → heads. `all_params` are the
    /// full model leaves in canonical order.
    pub fn model_forward(
        &self,
        all_params: &[HostTensor],
        tokens: &IntTensor,
    ) -> Result<(HostTensor, HostTensor)> {
        let man = &self.rt.manifest;
        let embed = self.rt.load(&format!("{}/embed", self.preset))?;
        let heads = self.rt.load(&format!("{}/heads", self.preset))?;
        let ps = man
            .params
            .get(&self.preset)
            .ok_or_else(|| Error::Manifest(format!("no params for '{}'", self.preset)))?;

        let pick = |prefix: &str| -> Vec<HostTensor> {
            ps.leaves
                .iter()
                .enumerate()
                .filter(|(_, l)| l.name.starts_with(prefix))
                .map(|(i, _)| all_params[i].clone())
                .collect()
        };

        // embed
        let mut embed_in: Vec<crate::runtime::executable::Value> = pick("embedder/")
            .into_iter()
            .map(Into::into)
            .collect();
        embed_in.push(tokens.clone().into());
        let embed_out = embed.run(&embed_in)?;
        let (m0, z0) = (embed_out[0].clone(), embed_out[1].clone());

        // trunk under DAP
        let mut state = self.shard_inputs(&m0, &z0)?;
        for b in 0..self.cfg.n_blocks {
            let idx = man.block_leaf_indices(&self.preset, b)?;
            let bp: Vec<HostTensor> = idx.iter().map(|&i| all_params[i].clone()).collect();
            self.block_forward(&bp, &mut state)?;
        }
        let (m, z) = self.unshard(&state)?;

        // heads
        let mut head_in: Vec<crate::runtime::executable::Value> =
            pick("heads/").into_iter().map(Into::into).collect();
        head_in.push(m.into());
        head_in.push(z.into());
        let out = heads.run(&head_in)?;
        Ok((out[0].clone(), out[1].clone()))
    }
}
