//! The DAP coordinator: owns the PJRT segment executables, the comm
//! substrate, and the dual-stream clocks, and drives the threaded
//! schedule executor ([`super::executor`]) per Evoformer block across N
//! logical ranks — recording the tape for backward.

use super::executor::{default_threads, run_schedule, MeasuredComm, SegmentRunner};
use super::tape::Tape;
use super::timeline::{CommCost, Timeline};
use crate::comm::worker::CommWorker;
use crate::comm::Collectives;
use crate::config::ModelConfig;
use crate::error::{Error, Result};
use crate::manifest::ScheduleOp;
use crate::runtime::{Executable, Runtime};
use crate::tensor::{HostTensor, IntTensor};
use std::cell::RefCell;
use std::collections::BTreeMap;
use std::sync::{Arc, Mutex};

pub use super::executor::State;

pub struct DapCoordinator<'rt> {
    pub rt: &'rt Runtime,
    pub cfg: ModelConfig,
    pub preset: String,
    pub n: usize,
    /// rank-executor thread budget (1 = the exact sequential path);
    /// defaults to [`default_threads`], override with [`Self::with_threads`]
    pub threads: usize,
    pub comm: Collectives,
    pub timeline: Mutex<Timeline>,
    /// real-clock comm ledger (measured counterpart of the timeline)
    pub measured: Mutex<MeasuredComm>,
    segs: BTreeMap<String, Arc<Executable>>,
    segs_bwd: BTreeMap<String, Arc<Executable>>,
    /// the long-lived Duality-Async comm worker, spawned lazily on the
    /// first overlapped block so every block forward reuses one thread
    comm_worker: RefCell<Option<CommWorker>>,
    /// record a tape during forward (enable for training)
    pub record: RefCell<bool>,
    pub tape: RefCell<Tape>,
}

/// PJRT-backed segment runner: the production implementation of the
/// executor's [`SegmentRunner`] seam. Ranks are SPMD (same executable on
/// equal shards), so `rank` only selects the input shards.
struct PjrtSegmentRunner<'a> {
    segs: &'a BTreeMap<String, Arc<Executable>>,
    block_params: &'a [HostTensor],
    param_lits: &'a [xla::Literal],
    lit_cache: bool,
}

impl SegmentRunner for PjrtSegmentRunner<'_> {
    fn run_segment(
        &self,
        seg: &str,
        _rank: usize,
        inputs: &[HostTensor],
    ) -> Result<Vec<HostTensor>> {
        let exe = self
            .segs
            .get(seg)
            .ok_or_else(|| Error::Schedule(format!("no segment '{seg}'")))?;
        if self.lit_cache {
            exe.run_with_params(self.param_lits, inputs)
        } else {
            let mut args = self.block_params.to_vec();
            args.extend_from_slice(inputs);
            exe.run_f32(&args)
        }
    }
}

impl<'rt> DapCoordinator<'rt> {
    /// Load all fwd (and, if exported, bwd) segment executables for
    /// `preset` at DAP degree `n`.
    pub fn new(rt: &'rt Runtime, preset: &str, n: usize, overlap: bool) -> Result<Self> {
        let cfg = ModelConfig::preset(preset)?;
        if cfg.n_seq % n != 0 || cfg.n_res % n != 0 {
            return Err(Error::Schedule(format!(
                "dap_size {n} does not divide (n_seq={}, n_res={})",
                cfg.n_seq, cfg.n_res
            )));
        }
        let mut segs = BTreeMap::new();
        let mut segs_bwd = BTreeMap::new();
        let seg_names: Vec<String> = rt
            .manifest
            .schedule
            .iter()
            .filter_map(|op| match op {
                ScheduleOp::Exec { seg, .. } => Some(seg.clone()),
                _ => None,
            })
            .collect();
        for seg in &seg_names {
            let key = format!("{preset}/dap{n}/{seg}");
            segs.insert(seg.clone(), rt.load(&key)?);
            let bwd_key = format!("{preset}/dap{n}/{seg}_bwd");
            if rt.manifest.artifacts.contains_key(&bwd_key) {
                segs_bwd.insert(seg.clone(), rt.load(&bwd_key)?);
            }
        }
        Ok(DapCoordinator {
            rt,
            cfg,
            preset: preset.to_string(),
            n,
            threads: default_threads(),
            comm: Collectives::new(n),
            timeline: Mutex::new(Timeline::new(n, CommCost::cpu_calibrated(), overlap)),
            measured: Mutex::new(MeasuredComm::default()),
            segs,
            segs_bwd,
            comm_worker: RefCell::new(None),
            record: RefCell::new(false),
            tape: RefCell::new(Tape::default()),
        })
    }

    /// Builder-style override of the rank-executor thread budget
    /// (`--threads` on the CLI): 1 restores the sequential path, 0 means
    /// auto ([`default_threads`]), consistent with the CLI/TOML/env knobs.
    pub fn with_threads(mut self, threads: usize) -> Self {
        self.threads = if threads == 0 { default_threads() } else { threads };
        self
    }

    pub fn has_backward(&self) -> bool {
        !self.segs_bwd.is_empty()
    }

    /// Chunked fallback for this coordinator's DAP degree: ask the
    /// AutoChunk planner for per-module chunk strategies at degree
    /// `self.n` on `gpu` with the given headroom (the deployment's
    /// `[autochunk] headroom`, or `autochunk::CHUNK_HEADROOM` for the
    /// default policy). `Ok(plan)` means the sharded working set fits
    /// (possibly by chunking transients on top of the sharding);
    /// `Err(SimOom)` means this degree cannot hold the model no matter the
    /// strategy and the caller must raise the DAP degree (Table V's
    /// FastFold-4 → OOM at 4096 verdict).
    pub fn autochunk_fallback(
        &self,
        mem: &crate::perfmodel::MemoryModel,
        gpu: &crate::perfmodel::GpuSpec,
        headroom: f64,
    ) -> Result<crate::inference::autochunk::AutoChunkPlan> {
        crate::inference::autochunk::plan_with_headroom(
            &self.cfg, mem, gpu, self.n, headroom,
        )
    }

    /// Shard full (m, z) into the canonical block-entry layout
    /// (m s-sharded, z i-sharded).
    pub fn shard_inputs(&self, m: &HostTensor, z: &HostTensor) -> Result<State> {
        let mut state = State::new();
        state.insert("m".into(), m.split_axis(0, self.n)?);
        state.insert("z".into(), z.split_axis(0, self.n)?);
        Ok(state)
    }

    /// Reassemble full (m, z) from block-exit state.
    pub fn unshard(&self, state: &State) -> Result<(HostTensor, HostTensor)> {
        let m = HostTensor::concat(&state["m"], 0)?;
        let z = HostTensor::concat(&state["z"], 0)?;
        Ok((m, z))
    }

    /// Run one Evoformer block forward under the DAP schedule: rank
    /// executions fan out over `self.threads` worker threads, async
    /// collectives run on the comm worker and join at `Wait` (real
    /// Duality-Async overlap; see [`super::executor`]).
    /// `block_params`: the block's 63 parameter leaves in canonical order
    /// (identical on every rank — DAP replicates parameters).
    pub fn block_forward(&self, block_params: &[HostTensor], state: &mut State) -> Result<()> {
        // §Perf-L3: convert parameter leaves to literals ONCE per block —
        // they are reused by all 18 segment executions × N ranks.
        // (FASTFOLD_NO_LITCACHE=1 restores the naive per-exec conversion,
        // kept for the EXPERIMENTS.md §Perf A/B measurement.)
        let lit_cache = std::env::var_os("FASTFOLD_NO_LITCACHE").is_none();
        let param_lits: Vec<xla::Literal> = if lit_cache {
            block_params.iter().map(|t| t.to_literal()).collect::<Result<_>>()?
        } else {
            Vec::new()
        };
        let runner = PjrtSegmentRunner {
            segs: &self.segs,
            block_params,
            param_lits: &param_lits,
            lit_cache,
        };
        let schedule = &self.rt.manifest.schedule;
        // spawn the comm worker once, on the first block that can overlap
        if self.threads > 1
            && self.timeline.lock().unwrap().overlap
            && self.comm_worker.borrow().is_none()
        {
            *self.comm_worker.borrow_mut() = Some(CommWorker::spawn(self.comm.clone()));
        }
        let worker_guard = self.comm_worker.borrow();
        let worker = worker_guard.as_ref();
        if *self.record.borrow() {
            let mut tape = self.tape.borrow_mut();
            run_schedule(
                schedule, self.n, self.threads, &runner, &self.comm,
                &self.timeline, &self.measured, worker, state, Some(&mut *tape),
            )
        } else {
            run_schedule(
                schedule, self.n, self.threads, &runner, &self.comm,
                &self.timeline, &self.measured, worker, state, None,
            )
        }
    }

    /// One-line measured-vs-modeled overlap report: real wall/comm/exposed
    /// seconds from [`MeasuredComm`] next to the α–β timeline prediction.
    pub fn overlap_report(&self) -> String {
        let tl = self.timeline.lock().unwrap();
        let m = *self.measured.lock().unwrap();
        format!(
            "measured: wall {:.1} ms, comm {:.1} ms, exposed {:.1} ms \
             ({:.1}% of wall) | modeled (α–β): elapsed {:.1} ms, comm {:.1} ms, \
             exposed {:.1} ms [threads={}, overlap={}]",
            m.wall_seconds * 1e3,
            m.comm_seconds * 1e3,
            m.exposed_comm_seconds * 1e3,
            100.0 * m.exposed_share(),
            tl.elapsed() * 1e3,
            tl.comm_seconds * 1e3,
            tl.exposed_comm_seconds * 1e3,
            self.threads,
            tl.overlap,
        )
    }

    /// Backward through one recorded block: consumes the tape, returns
    /// (param grads, d_m shards, d_z shards). `d_state` carries the
    /// cotangents of the block outputs and is updated in place to the
    /// cotangents of the block inputs.
    pub fn block_backward(&self, block_params: &[HostTensor], d_state: &mut State) -> Result<super::tape::BlockGrads> {
        let tape = std::mem::take(&mut *self.tape.borrow_mut());
        super::tape::run_backward(self, block_params, tape, d_state)
    }

    /// Backward through an explicitly supplied tape. The hybrid trainer
    /// records one tape per Evoformer block during the trunk forward and
    /// replays them in reverse block order — this entry point lets it own
    /// that per-block tape stack instead of the coordinator's single
    /// [`Self::tape`] slot.
    pub fn block_backward_with(
        &self,
        tape: super::tape::Tape,
        block_params: &[HostTensor],
        d_state: &mut State,
    ) -> Result<super::tape::BlockGrads> {
        super::tape::run_backward(self, block_params, tape, d_state)
    }

    pub(crate) fn bwd_exe(&self, seg: &str) -> Result<&Arc<Executable>> {
        self.segs_bwd
            .get(seg)
            .ok_or_else(|| Error::Schedule(format!("no backward executable for '{seg}' (export with aot --configs tiny)")))
    }

    pub(crate) fn fwd_exe(&self, seg: &str) -> Result<&Arc<Executable>> {
        self.segs
            .get(seg)
            .ok_or_else(|| Error::Schedule(format!("no segment '{seg}'")))
    }

    /// Full-trunk forward for inference: embed (replicated on rank 0) →
    /// shard → N_blocks × DAP block → unshard → heads. `all_params` are the
    /// full model leaves in canonical order.
    pub fn model_forward(
        &self,
        all_params: &[HostTensor],
        tokens: &IntTensor,
    ) -> Result<(HostTensor, HostTensor)> {
        let man = &self.rt.manifest;
        let embed = self.rt.load(&format!("{}/embed", self.preset))?;
        let heads = self.rt.load(&format!("{}/heads", self.preset))?;

        // embed
        let mut embed_in: Vec<crate::runtime::executable::Value> = man
            .pick_params(&self.preset, "embedder/", all_params)?
            .into_iter()
            .map(Into::into)
            .collect();
        embed_in.push(tokens.clone().into());
        let embed_out = embed.run(&embed_in)?;
        let (m0, z0) = (embed_out[0].clone(), embed_out[1].clone());

        // trunk under DAP
        let mut state = self.shard_inputs(&m0, &z0)?;
        for b in 0..self.cfg.n_blocks {
            let idx = man.block_leaf_indices(&self.preset, b)?;
            let bp: Vec<HostTensor> = idx.iter().map(|&i| all_params[i].clone()).collect();
            self.block_forward(&bp, &mut state)?;
        }
        let (m, z) = self.unshard(&state)?;

        // heads
        let mut head_in: Vec<crate::runtime::executable::Value> = man
            .pick_params(&self.preset, "heads/", all_params)?
            .into_iter()
            .map(Into::into)
            .collect();
        head_in.push(m.into());
        head_in.push(z.into());
        let out = heads.run(&head_in)?;
        Ok((out[0].clone(), out[1].clone()))
    }
}
