//! Dual-stream simulated clock: per-rank *compute stream* plus a shared
//! *communication stream*, the substitution for CUDA streams on this
//! testbed (DESIGN.md §2).
//!
//! * `exec(secs)` advances every rank's compute stream (ranks execute the
//!   same segment on equal shards — SPMD).
//! * `collective_sync(bytes)` synchronizes ranks, then blocks compute for
//!   the collective's duration.
//! * `collective_async(id, bytes)` is the *Duality Async* trigger: the
//!   collective runs on the comm stream starting when all ranks arrive;
//!   `wait(id)` joins — compute done in between is overlapped for free.
//!
//! Durations come from [`CommCost`], an α–β (latency + bytes/bandwidth)
//! model with presets for NVLink-class and IB-class links and a CPU-
//! calibrated preset used when mixing with measured CPU compute times.

use crate::error::{Error, Result};
use std::collections::BTreeMap;

/// α–β communication cost model: time = α + bytes / β.
#[derive(Clone, Copy, Debug)]
pub struct CommCost {
    /// per-collective launch latency (seconds)
    pub alpha: f64,
    /// link bandwidth (bytes/second)
    pub beta: f64,
}

impl CommCost {
    /// NVLink 3 (A100 intra-node): 600 GB/s nominal; *effective* collective
    /// bandwidth for ring/gather patterns at Evoformer message sizes is far
    /// lower (NCCL achieves ~80 GB/s busbw here), 15 µs launch latency.
    pub fn nvlink() -> Self {
        CommCost { alpha: 15e-6, beta: 80e9 }
    }

    /// HDR InfiniBand (inter-node): 25 GB/s, 12 µs latency.
    pub fn infiniband() -> Self {
        CommCost { alpha: 12e-6, beta: 25e9 }
    }

    /// CPU-testbed calibration: host memcpy-class bandwidth so that
    /// comm:compute ratios on the 1-core simulator resemble the
    /// NVLink:A100 ratio (both ~2 orders below compute throughput).
    pub fn cpu_calibrated() -> Self {
        CommCost { alpha: 5e-6, beta: 4e9 }
    }

    pub fn time(&self, bytes: usize) -> f64 {
        if bytes == 0 {
            0.0
        } else {
            self.alpha + bytes as f64 / self.beta
        }
    }
}

#[derive(Clone, Debug)]
pub struct Timeline {
    /// per-rank compute-stream clock (seconds)
    pub compute: Vec<f64>,
    /// comm stream is busy until this instant
    comm_free: f64,
    /// in-flight async collectives: id -> completion time
    pending: BTreeMap<String, f64>,
    pub cost: CommCost,
    /// Duality Async on/off (off = every collective is synchronous)
    pub overlap: bool,
    /// accounting
    pub comm_seconds: f64,
    pub exposed_comm_seconds: f64,
}

impl Timeline {
    pub fn new(n: usize, cost: CommCost, overlap: bool) -> Self {
        Timeline {
            compute: vec![0.0; n],
            comm_free: 0.0,
            pending: BTreeMap::new(),
            cost,
            overlap,
            comm_seconds: 0.0,
            exposed_comm_seconds: 0.0,
        }
    }

    fn now(&self) -> f64 {
        self.compute.iter().cloned().fold(0.0, f64::max)
    }

    /// All ranks run a segment taking `secs` of compute.
    pub fn exec(&mut self, secs: f64) {
        for c in self.compute.iter_mut() {
            *c += secs;
        }
    }

    /// Synchronous collective: ranks align, then block for the duration.
    pub fn collective_sync(&mut self, bytes: usize) {
        let arrive = self.now().max(self.comm_free);
        let d = self.cost.time(bytes);
        self.comm_seconds += d;
        self.exposed_comm_seconds += d;
        let done = arrive + d;
        self.comm_free = done;
        for c in self.compute.iter_mut() {
            *c = done;
        }
    }

    /// Duality Async trigger: launch on the comm stream, don't block.
    pub fn collective_async(&mut self, id: &str, bytes: usize) {
        if !self.overlap {
            self.collective_sync(bytes);
            self.pending.insert(id.to_string(), self.now());
            return;
        }
        let start = self.now().max(self.comm_free);
        let d = self.cost.time(bytes);
        self.comm_seconds += d;
        let done = start + d;
        self.comm_free = done;
        self.pending.insert(id.to_string(), done);
    }

    /// Duality Async wait: join the collective; any time the compute
    /// stream still has to wait is *exposed* (non-overlapped) comm.
    ///
    /// Waiting on an id that was never scheduled (or was already joined)
    /// is a schedule bug — a typo'd `wait` used to no-op silently, hiding
    /// both the error and the un-joined collective's cost.
    pub fn wait(&mut self, id: &str) -> Result<()> {
        let done = self.pending.remove(id).ok_or_else(|| {
            Error::Schedule(format!(
                "wait on unknown async collective id '{id}' \
                 (never scheduled, or already joined)"
            ))
        })?;
        let now = self.now();
        if done > now {
            self.exposed_comm_seconds += done - now;
            for c in self.compute.iter_mut() {
                *c = (*c).max(done);
            }
        }
        Ok(())
    }

    /// Simulated elapsed wall time. Un-joined in-flight collectives count:
    /// the wall clock cannot stop before the comm stream drains. (The old
    /// `now().max(comm_free.min(now()))` was a tautology that always
    /// returned `now()`, silently dropping comm time past the last wait.)
    pub fn elapsed(&self) -> f64 {
        let comm_tail = self.pending.values().fold(0.0f64, |a, &b| a.max(b));
        self.now().max(comm_tail)
    }

    pub fn in_flight(&self) -> usize {
        self.pending.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn overlap_hides_comm() {
        let cost = CommCost { alpha: 0.0, beta: 1e6 }; // 1 MB/s
        // overlapped: 1s compute || 0.5s comm -> 2s total with 1s more compute
        let mut t = Timeline::new(2, cost, true);
        t.exec(1.0);
        t.collective_async("x", 500_000); // 0.5 s
        t.exec(1.0); // overlaps
        t.wait("x").unwrap();
        assert!((t.elapsed() - 2.0).abs() < 1e-9, "{}", t.elapsed());
        assert!(t.exposed_comm_seconds < 1e-9);

        // sync: same ops cost 2.5s
        let mut t = Timeline::new(2, cost, false);
        t.exec(1.0);
        t.collective_async("x", 500_000);
        t.exec(1.0);
        t.wait("x").unwrap();
        assert!((t.elapsed() - 2.5).abs() < 1e-9, "{}", t.elapsed());
        assert!((t.exposed_comm_seconds - 0.5).abs() < 1e-9);
    }

    #[test]
    fn partially_exposed_comm() {
        let cost = CommCost { alpha: 0.0, beta: 1e6 };
        let mut t = Timeline::new(1, cost, true);
        t.collective_async("x", 1_000_000); // 1 s
        t.exec(0.25); // only 0.25 s to hide behind
        t.wait("x").unwrap();
        assert!((t.elapsed() - 1.0).abs() < 1e-9);
        assert!((t.exposed_comm_seconds - 0.75).abs() < 1e-9);
    }

    #[test]
    fn comm_stream_serializes() {
        let cost = CommCost { alpha: 0.0, beta: 1e6 };
        let mut t = Timeline::new(1, cost, true);
        t.collective_async("a", 1_000_000);
        t.collective_async("b", 1_000_000); // queues behind a
        t.wait("a").unwrap();
        t.wait("b").unwrap();
        assert!((t.elapsed() - 2.0).abs() < 1e-9);
    }

    #[test]
    fn elapsed_counts_unjoined_comm() {
        // regression: elapsed() was `now().max(comm_free.min(now()))`,
        // a tautology that dropped comm time past the last wait
        let cost = CommCost { alpha: 0.0, beta: 1e6 };
        let mut t = Timeline::new(2, cost, true);
        t.exec(0.25);
        t.collective_async("tail", 1_000_000); // 1 s, never joined
        assert_eq!(t.in_flight(), 1);
        assert!(
            (t.elapsed() - 1.25).abs() < 1e-9,
            "elapsed {} must include the un-joined collective",
            t.elapsed()
        );
        // joining it folds the time into compute; elapsed is unchanged
        t.wait("tail").unwrap();
        assert!((t.elapsed() - 1.25).abs() < 1e-9);
        assert!((t.exposed_comm_seconds - 1.0).abs() < 1e-9);
    }

    #[test]
    fn wait_unknown_id_errors() {
        // regression: a typo'd wait used to succeed silently
        let mut t = Timeline::new(1, CommCost::cpu_calibrated(), true);
        let err = t.wait("never-scheduled").unwrap_err();
        assert!(err.to_string().contains("never-scheduled"), "{err}");
        // double-join is the same bug
        t.collective_async("x", 100);
        t.wait("x").unwrap();
        assert!(t.wait("x").is_err());
    }

    #[test]
    fn alpha_beta_model() {
        let c = CommCost { alpha: 1e-5, beta: 1e9 };
        assert_eq!(c.time(0), 0.0);
        assert!((c.time(1_000_000) - (1e-5 + 1e-3)).abs() < 1e-12);
    }
}
