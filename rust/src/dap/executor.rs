//! Threaded schedule executor: runs a DAP `ScheduleOp` program over N
//! logical ranks on real host worker threads, with genuinely deferred
//! Duality-Async collectives.
//!
//! This replaces the old coordinator inner loop, where every "rank" ran
//! sequentially on one thread and overlap existed only in the simulated
//! [`Timeline`] clock. Here:
//!
//! * Each `Exec` fans the N rank executions out over up to `threads`
//!   scoped worker threads ([`parallel_ranks`]); results are joined in
//!   rank order, so the parallel path is bit-for-bit identical to the
//!   sequential one.
//! * An async collective (`id: Some(..)`) is submitted to the dedicated
//!   [`CommWorker`] thread at its trigger point and joined at `Wait` —
//!   compute issued in between genuinely overlaps it on the wall clock,
//!   not just on the simulated one. The collective math runs the same
//!   [`Collectives`] code either way (same reduction order), so deferral
//!   never changes numerics.
//! * A [`MeasuredComm`] ledger tracks *real* seconds — total collective
//!   execution time and the part that blocked the compute path — next to
//!   the α–β-modeled numbers the timeline keeps, so overlap can be
//!   reported measured-vs-modeled.
//!
//! Schedule safety (the silent failure modes this module closes):
//! reading a slot whose pending async write has not been waited on is a
//! schedule error (stale-read hazard), writing such a slot is one too
//! (the joined result would clobber the newer write), waiting on an
//! unknown id is a schedule error, reusing an in-flight id is a schedule
//! error, and finishing the schedule with un-joined collectives remains
//! one.

use super::tape::{Tape, TapeOp};
use super::timeline::Timeline;
use crate::comm::worker::{CommJob, CommTicket, CommWorker};
use crate::comm::Collectives;
use crate::error::{Error, Result};
use crate::manifest::ScheduleOp;
use crate::tensor::HostTensor;
use std::collections::BTreeMap;
use std::sync::Mutex;
use std::time::Instant; // lint:allow(wallclock) — measured wall/comm overlap ledger (MeasuredComm)

/// Per-slot, per-rank tensor state threaded through the schedule.
pub type State = BTreeMap<String, Vec<HostTensor>>;

/// How a segment is actually executed for one rank. The coordinator backs
/// this with PJRT executables; tests back it with pure host math, so the
/// threading/overlap machinery is exercised without artifacts.
pub trait SegmentRunner: Sync {
    /// Run segment `seg` for `rank` on that rank's input shards; returns
    /// one output tensor per schedule output slot.
    fn run_segment(
        &self,
        seg: &str,
        rank: usize,
        inputs: &[HostTensor],
    ) -> Result<Vec<HostTensor>>;
}

/// Rank-executor thread count: `FASTFOLD_THREADS` if set (≥1), else the
/// host's available parallelism. `1` selects the exact sequential path.
pub fn default_threads() -> usize {
    match std::env::var("FASTFOLD_THREADS")
        .ok()
        .and_then(|s| s.parse::<usize>().ok())
    {
        Some(t) if t >= 1 => t,
        _ => std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1),
    }
}

/// Real-clock communication ledger, the measured counterpart of the
/// timeline's α–β accounting.
#[derive(Default, Clone, Copy, Debug)]
pub struct MeasuredComm {
    /// wall seconds spent inside `run_schedule` calls
    pub wall_seconds: f64,
    /// seconds spent executing collectives (worker or inline)
    pub comm_seconds: f64,
    /// seconds the compute path was blocked on comm (inline collectives
    /// plus time blocked joining tickets at `Wait`)
    pub exposed_comm_seconds: f64,
}

impl MeasuredComm {
    /// Exposed-comm share of wall time (0 when nothing ran).
    pub fn exposed_share(&self) -> f64 {
        if self.wall_seconds > 0.0 {
            self.exposed_comm_seconds / self.wall_seconds
        } else {
            0.0
        }
    }
}

/// Run `f(rank)` for every rank, fanning out over up to `threads` scoped
/// worker threads (worker w takes ranks w, w+W, …). Results come back in
/// rank order and the first error (by rank order) wins, so callers cannot
/// observe whether the map ran sequentially or in parallel.
pub fn parallel_ranks<T: Send>(
    threads: usize,
    n: usize,
    f: impl Fn(usize) -> Result<T> + Sync,
) -> Result<Vec<T>> {
    if threads <= 1 || n <= 1 {
        return (0..n).map(f).collect();
    }
    let workers = threads.min(n);
    let mut results: Vec<(usize, Result<T>)> = Vec::with_capacity(n);
    std::thread::scope(|s| {
        let handles: Vec<_> = (0..workers)
            .map(|w| {
                let f = &f;
                s.spawn(move || {
                    let mut out = Vec::new();
                    let mut r = w;
                    while r < n {
                        out.push((r, f(r)));
                        r += workers;
                    }
                    out
                })
            })
            .collect();
        for h in handles {
            results.extend(h.join().expect("rank worker thread panicked"));
        }
    });
    results.sort_by_key(|(r, _)| *r);
    results.into_iter().map(|(_, res)| res).collect()
}

/// [`parallel_ranks`] with heartbeat-based failure detection: each rank
/// ticks its beat on the shared [`crate::faults::Heartbeats`] plane as
/// it takes work, and a rank already declared dead surfaces a structured
/// [`crate::Error::RankLost`] (tagged with 1-based `step`) instead of
/// being executed — the sweep fails fast rather than waiting on a rank
/// that will never report. Results and error precedence are otherwise
/// identical to [`parallel_ranks`] (first error by rank order wins), so
/// with an all-alive plane this is bitwise the plain sweep.
pub fn parallel_ranks_with_heartbeat<T: Send>(
    threads: usize,
    n: usize,
    hb: &crate::faults::Heartbeats,
    step: usize,
    f: impl Fn(usize) -> Result<T> + Sync,
) -> Result<Vec<T>> {
    parallel_ranks(threads, n, |r| {
        if hb.is_dead(r) {
            return Err(Error::RankLost { rank: r, step });
        }
        hb.tick(r);
        f(r)
    })
}

/// One un-joined async collective: where its result will land, and either
/// the already-computed value (inline mode) or the comm-worker ticket.
enum InflightVal {
    Ready(Vec<HostTensor>),
    Pending(CommTicket),
}

struct Inflight {
    slot: String,
    val: InflightVal,
}

/// Error if `slot` is the destination of an un-joined async write: a
/// reader would see the stale pre-collective shards, and a writer would
/// be clobbered when the async result lands at `Wait`.
fn check_no_inflight_write(
    inflight: &BTreeMap<String, Inflight>,
    slot: &str,
    who: &str,
    access: SlotAccess,
) -> Result<()> {
    for (id, inf) in inflight {
        if inf.slot == slot {
            return Err(Error::Schedule(match access {
                SlotAccess::Read => format!(
                    "stale read: {who} reads slot '{slot}' while async \
                     collective '{id}' has an in-flight write to it — the \
                     schedule must wait on '{id}' first"
                ),
                SlotAccess::Write => format!(
                    "write-after-write: {who} writes slot '{slot}' while \
                     async collective '{id}' has an in-flight write to it \
                     (joining '{id}' would clobber the newer value) — the \
                     schedule must wait on '{id}' first"
                ),
            }));
        }
    }
    Ok(())
}

#[derive(Clone, Copy)]
enum SlotAccess {
    Read,
    Write,
}

/// Execute `schedule` over `n` ranks. `threads` bounds the rank-executor
/// fan-out (1 = sequential); async collectives are deferred to a comm
/// worker when the timeline has overlap on *and* threads > 1, otherwise
/// they execute inline at the trigger (visibility still deferred to
/// `Wait`, preserving schedule semantics). Pass `worker` to reuse a
/// long-lived [`CommWorker`] across calls (the coordinator does, so the
/// spawn cost is paid once, not per block); with `worker: None` a local
/// one is spawned for this call when deferral applies. `tape`, when
/// present, records forward ops for the backward replay.
#[allow(clippy::too_many_arguments)] // this IS the narrow waist of dap/
pub fn run_schedule<R: SegmentRunner + ?Sized>(
    schedule: &[ScheduleOp],
    n: usize,
    threads: usize,
    runner: &R,
    comm: &Collectives,
    timeline: &Mutex<Timeline>,
    measured: &Mutex<MeasuredComm>,
    worker: Option<&CommWorker>,
    state: &mut State,
    mut tape: Option<&mut Tape>,
) -> Result<()> {
    let wall0 = Instant::now();
    let overlap = timeline.lock().unwrap().overlap;
    let spawned: Option<CommWorker>;
    let worker: Option<&CommWorker> = if overlap && threads > 1 {
        match worker {
            Some(w) => Some(w),
            None => {
                spawned = Some(CommWorker::spawn(comm.clone()));
                spawned.as_ref()
            }
        }
    } else {
        None
    };
    let mut inflight: BTreeMap<String, Inflight> = BTreeMap::new();

    // run one collective inline (blocking the compute path) and account it
    let run_inline = |job: CommJob| -> Result<Vec<HostTensor>> {
        let t0 = Instant::now();
        let res = job.run(comm);
        let secs = t0.elapsed().as_secs_f64();
        let mut m = measured.lock().unwrap();
        m.comm_seconds += secs;
        m.exposed_comm_seconds += secs;
        res
    };

    for op in schedule {
        match op {
            ScheduleOp::Exec { seg, inputs, outputs } => {
                for slot in inputs {
                    check_no_inflight_write(
                        &inflight, slot, &format!("'{seg}'"), SlotAccess::Read,
                    )?;
                    if !state.contains_key(slot) {
                        return Err(Error::Schedule(format!(
                            "slot '{slot}' unset for '{seg}'"
                        )));
                    }
                }
                for slot in outputs {
                    check_no_inflight_write(
                        &inflight, slot, &format!("'{seg}'"), SlotAccess::Write,
                    )?;
                }
                let per_rank: Vec<(Vec<HostTensor>, f64)> =
                    parallel_ranks(threads, n, |r| {
                        let ins: Vec<HostTensor> =
                            inputs.iter().map(|slot| state[slot][r].clone()).collect();
                        let t0 = Instant::now();
                        let out = runner.run_segment(seg, r, &ins)?;
                        if out.len() != outputs.len() {
                            return Err(Error::Schedule(format!(
                                "segment '{seg}' returned {} outputs, schedule \
                                 expects {}",
                                out.len(),
                                outputs.len()
                            )));
                        }
                        Ok((out, t0.elapsed().as_secs_f64()))
                    })?;
                // the simulated clock wants per-rank compute seconds; the
                // mean of the per-rank measurements equals the old
                // wall/n in sequential mode and stays honest under
                // contention in threaded mode
                let secs = per_rank.iter().map(|(_, s)| s).sum::<f64>() / n as f64;
                timeline.lock().unwrap().exec(secs);
                if let Some(t) = tape.as_deref_mut() {
                    let snap: Vec<Vec<HostTensor>> =
                        inputs.iter().map(|slot| state[slot].clone()).collect();
                    t.push(TapeOp::Exec {
                        seg: seg.clone(),
                        in_slots: inputs.clone(),
                        out_slots: outputs.clone(),
                        inputs: snap,
                    });
                }
                for (k, slot) in outputs.iter().enumerate() {
                    let shards: Vec<HostTensor> =
                        per_rank.iter().map(|(o, _)| o[k].clone()).collect();
                    state.insert(slot.clone(), shards);
                }
            }
            ScheduleOp::Gather { input, output, axis, id } => {
                check_no_inflight_write(&inflight, input, "gather", SlotAccess::Read)?;
                let parts = state.get(input).ok_or_else(|| {
                    Error::Schedule(format!("slot '{input}' unset for gather"))
                })?;
                let bytes = parts[0].size_bytes() * (n - 1);
                if let Some(t) = tape.as_deref_mut() {
                    t.push(TapeOp::Gather {
                        in_slot: input.clone(),
                        out_slot: output.clone(),
                        axis: *axis,
                    });
                }
                let job = CommJob::Gather { parts: parts.clone(), axis: *axis };
                land(
                    job, id, output, bytes, worker, &run_inline, timeline, state,
                    &mut inflight,
                )?;
            }
            ScheduleOp::Scatter { input, output, axis, id } => {
                check_no_inflight_write(&inflight, input, "scatter", SlotAccess::Read)?;
                let parts = state.get(input).ok_or_else(|| {
                    Error::Schedule(format!("slot '{input}' unset for scatter"))
                })?;
                let bytes = parts[0].size_bytes() * (n - 1) / n;
                if let Some(t) = tape.as_deref_mut() {
                    t.push(TapeOp::Scatter {
                        in_slot: input.clone(),
                        out_slot: output.clone(),
                        axis: *axis,
                    });
                }
                let job = CommJob::Scatter { parts: parts.clone(), axis: *axis };
                land(
                    job, id, output, bytes, worker, &run_inline, timeline, state,
                    &mut inflight,
                )?;
            }
            ScheduleOp::AllToAll { input, output, split, concat, id } => {
                check_no_inflight_write(&inflight, input, "all_to_all", SlotAccess::Read)?;
                let parts = state.get(input).ok_or_else(|| {
                    Error::Schedule(format!("slot '{input}' unset for all_to_all"))
                })?;
                let bytes = parts[0].size_bytes() * (n - 1) / n;
                if let Some(t) = tape.as_deref_mut() {
                    t.push(TapeOp::AllToAll {
                        in_slot: input.clone(),
                        out_slot: output.clone(),
                        split: *split,
                        concat: *concat,
                    });
                }
                let job = CommJob::AllToAll {
                    parts: parts.clone(),
                    split: *split,
                    concat: *concat,
                };
                land(
                    job, id, output, bytes, worker, &run_inline, timeline, state,
                    &mut inflight,
                )?;
            }
            ScheduleOp::Wait { id } => {
                // the timeline is the authority on unknown/double-joined
                // ids; `land` keeps its pending set and `inflight` in
                // lockstep, so a miss here is an executor invariant break
                timeline.lock().unwrap().wait(id)?;
                let inf = inflight.remove(id).ok_or_else(|| {
                    Error::Schedule(format!(
                        "internal: timeline and executor in-flight sets \
                         diverged for id '{id}'"
                    ))
                })?;
                let res = match inf.val {
                    InflightVal::Ready(v) => v,
                    InflightVal::Pending(ticket) => {
                        let t0 = Instant::now();
                        let (v, exec_secs) = ticket.join()?;
                        let blocked = t0.elapsed().as_secs_f64();
                        let mut m = measured.lock().unwrap();
                        m.comm_seconds += exec_secs;
                        // only the join stall was exposed; the rest of the
                        // collective ran under compute
                        m.exposed_comm_seconds += blocked;
                        v
                    }
                };
                state.insert(inf.slot, res);
            }
        }
    }
    if !inflight.is_empty() {
        return Err(Error::Schedule(format!(
            "unjoined collectives at block end: {:?}",
            inflight.keys().collect::<Vec<_>>()
        )));
    }
    measured.lock().unwrap().wall_seconds += wall0.elapsed().as_secs_f64();
    Ok(())
}

/// Land one collective: async ids go to the comm worker (or execute
/// inline with deferred visibility when no worker runs); sync collectives
/// execute inline and land immediately.
#[allow(clippy::too_many_arguments)]
fn land(
    job: CommJob,
    id: &Option<String>,
    output: &str,
    bytes: usize,
    worker: Option<&CommWorker>,
    run_inline: &dyn Fn(CommJob) -> Result<Vec<HostTensor>>,
    timeline: &Mutex<Timeline>,
    state: &mut State,
    inflight: &mut BTreeMap<String, Inflight>,
) -> Result<()> {
    // landing (now or at the future Wait) must not clobber a slot another
    // in-flight collective is still due to write
    check_no_inflight_write(inflight, output, "a collective", SlotAccess::Write)?;
    match id {
        Some(id) => {
            if inflight.contains_key(id) {
                return Err(Error::Schedule(format!(
                    "async collective id '{id}' reused while still in flight"
                )));
            }
            timeline.lock().unwrap().collective_async(id, bytes);
            let val = match worker {
                Some(w) => InflightVal::Pending(w.submit(job)),
                None => InflightVal::Ready(run_inline(job)?),
            };
            inflight.insert(id.clone(), Inflight { slot: output.to_string(), val });
        }
        None => {
            timeline.lock().unwrap().collective_sync(bytes);
            let res = run_inline(job)?;
            state.insert(output.to_string(), res);
        }
    }
    Ok(())
}
