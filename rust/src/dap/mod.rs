//! Dynamic Axial Parallelism coordinator (paper §IV.B.2) + Duality-Async
//! overlap (§IV.C).
//!
//! Executes the segment schedule exported in `manifest.json` across N
//! logical ranks: PJRT segment executions interleaved with host-tensor
//! collectives. A [`timeline::Timeline`] prices the run on a dual-stream
//! (compute + communication) simulated clock — the Duality Async Operation
//! trigger/wait pairs map to comm-stream launches that overlap compute,
//! exactly the paper's Fig 7 semantics (see DESIGN.md §2 for why simulated
//! streams replace CUDA streams on this testbed).
//!
//! Backward ([`tape`]) replays the schedule in reverse with transposed
//! collectives (all_gather ↔ reduce_scatter, all_to_all ↔ inverse
//! all_to_all) and per-segment VJP executables that rematerialize forward
//! internally — segment-granular gradient checkpointing, as the paper uses.

mod coordinator;
mod tape;
mod timeline;

pub use coordinator::{DapCoordinator, State};
pub use tape::BlockGrads;
pub use timeline::{CommCost, Timeline};
