//! Dynamic Axial Parallelism coordinator (paper §IV.B.2) + Duality-Async
//! overlap (§IV.C).
//!
//! Executes the segment schedule exported in `manifest.json` across N
//! logical ranks: PJRT segment executions interleaved with host-tensor
//! collectives. A [`timeline::Timeline`] prices the run on a dual-stream
//! (compute + communication) simulated clock — the Duality Async Operation
//! trigger/wait pairs map to comm-stream launches that overlap compute,
//! exactly the paper's Fig 7 semantics (see DESIGN.md §2 for why simulated
//! streams replace CUDA streams on this testbed).
//!
//! Since the threaded-executor refactor, parallelism is *real* as well as
//! simulated: [`executor`] fans rank executions out over host worker
//! threads and defers async collectives to a dedicated comm worker thread
//! ([`crate::comm::worker`]), joined at `Wait` — so Duality-Async overlap
//! is measured on the wall clock ([`executor::MeasuredComm`]) next to the
//! α–β model. Parallel execution is bit-for-bit equal to sequential
//! (`threads = 1`): ranks join in order and the collective math is the
//! same code either way.
//!
//! Backward ([`tape`]) replays the schedule in reverse with transposed
//! collectives (all_gather ↔ reduce_scatter, all_to_all ↔ inverse
//! all_to_all) and per-segment VJP executables that rematerialize forward
//! internally — segment-granular gradient checkpointing, as the paper uses.

mod coordinator;
pub mod executor;
mod tape;
mod timeline;

pub use coordinator::DapCoordinator;
pub use executor::{default_threads, MeasuredComm, SegmentRunner, State};
pub use tape::{BlockGrads, Tape, TapeOp};
pub use timeline::{CommCost, Timeline};
