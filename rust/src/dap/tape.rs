//! Reverse-mode replay of the DAP schedule.
//!
//! Forward records a [`Tape`] of dataflow ops with per-slot *versions*
//! (slots like `z` are read by several segments before being overwritten —
//! cotangents must accumulate per version, not per name). Backward walks
//! the tape in reverse:
//!
//! * `Exec` → the segment's VJP executable (rematerializes forward inside;
//!   segment-granular activation checkpointing). Parameter gradients sum
//!   over ranks — DAP replicates parameters, so the true gradient is the
//!   sum of every rank's contribution.
//! * `Gather(axis)`   → `reduce_scatter(d_out, axis)`
//! * `Scatter(axis)`  → `all_gather(d_out, axis)`
//! * `AllToAll(s, c)` → `all_to_all(d_out, split=c, concat=s)` (inverse)
//!
//! The hybrid trainer records one tape per Evoformer block during the
//! trunk forward and replays them in reverse block order
//! ([`DapCoordinator::block_backward_with`]), threading the cotangent
//! state from the heads/loss VJP back to the embedder.

use super::coordinator::{DapCoordinator, State};
use crate::error::{Error, Result};
use crate::tensor::HostTensor;
use std::collections::BTreeMap;

#[derive(Clone, Debug)]
pub enum TapeOp {
    Exec {
        seg: String,
        in_slots: Vec<String>,
        out_slots: Vec<String>,
        /// forward input shards, per input per rank (VJP rematerializes
        /// forward from these)
        inputs: Vec<Vec<HostTensor>>,
    },
    Gather { in_slot: String, out_slot: String, axis: usize },
    Scatter { in_slot: String, out_slot: String, axis: usize },
    AllToAll { in_slot: String, out_slot: String, split: usize, concat: usize },
}

#[derive(Default, Debug)]
pub struct Tape {
    pub ops: Vec<TapeOp>,
}

impl Tape {
    pub fn push(&mut self, op: TapeOp) {
        self.ops.push(op);
    }

    pub fn len(&self) -> usize {
        self.ops.len()
    }

    pub fn is_empty(&self) -> bool {
        self.ops.is_empty()
    }
}

/// Accumulated parameter gradients for one block (canonical leaf order).
pub type BlockGrads = Vec<HostTensor>;

type Key = (String, usize);

/// version bookkeeping: assign (slot, version) keys to every tape op's
/// reads and writes by replaying the dataflow forward.
fn assign_versions(tape: &Tape) -> Vec<(Vec<Key>, Vec<Key>)> {
    let mut cur: BTreeMap<String, usize> = BTreeMap::new();
    let mut out = Vec::with_capacity(tape.ops.len());
    let mut read = |cur: &BTreeMap<String, usize>, s: &str| -> Key {
        (s.to_string(), *cur.get(s).unwrap_or(&0))
    };
    for op in &tape.ops {
        let (ins, outs): (Vec<&str>, Vec<&str>) = match op {
            TapeOp::Exec { in_slots, out_slots, .. } => (
                in_slots.iter().map(|s| s.as_str()).collect(),
                out_slots.iter().map(|s| s.as_str()).collect(),
            ),
            TapeOp::Gather { in_slot, out_slot, .. }
            | TapeOp::Scatter { in_slot, out_slot, .. }
            | TapeOp::AllToAll { in_slot, out_slot, .. } => {
                (vec![in_slot.as_str()], vec![out_slot.as_str()])
            }
        };
        let in_keys: Vec<Key> = ins.iter().map(|s| read(&cur, s)).collect();
        let out_keys: Vec<Key> = outs
            .iter()
            .map(|s| {
                let v = cur.get(*s).copied().unwrap_or(0) + 1;
                cur.insert((*s).to_string(), v);
                ((*s).to_string(), v)
            })
            .collect();
        out.push((in_keys, out_keys));
    }
    out
}

pub fn run_backward(
    co: &DapCoordinator,
    block_params: &[HostTensor],
    tape: Tape,
    d_state: &mut State,
) -> Result<BlockGrads> {
    let n = co.n;
    let versions = assign_versions(&tape);

    // final versions of m and z carry the incoming output cotangents
    let mut final_v: BTreeMap<String, usize> = BTreeMap::new();
    for (_, outs) in &versions {
        for (s, v) in outs {
            final_v.insert(s.clone(), *v);
        }
    }
    let mut cts: BTreeMap<Key, Vec<HostTensor>> = BTreeMap::new();
    for slot in ["m", "z"] {
        let v = *final_v
            .get(slot)
            .ok_or_else(|| Error::Schedule(format!("tape never wrote '{slot}'")))?;
        let d = d_state
            .get(slot)
            .ok_or_else(|| Error::Schedule(format!("missing d_state['{slot}']")))?;
        cts.insert((slot.to_string(), v), d.clone());
    }

    let mut param_grads: Option<BlockGrads> = None;
    // §Perf-L3: one literal conversion for the whole backward pass
    let param_lits: Vec<xla::Literal> = block_params
        .iter()
        .map(|t| t.to_literal())
        .collect::<Result<_>>()?;

    let add_ct = |cts: &mut BTreeMap<Key, Vec<HostTensor>>,
                  key: Key,
                  val: Vec<HostTensor>|
     -> Result<()> {
        match cts.get_mut(&key) {
            Some(existing) => {
                for (e, v) in existing.iter_mut().zip(val.iter()) {
                    e.add_assign(v)?;
                }
            }
            None => {
                cts.insert(key, val);
            }
        }
        Ok(())
    };

    for (op, (in_keys, out_keys)) in
        tape.ops.iter().rev().zip(versions.iter().rev())
    {
        match op {
            TapeOp::Exec { seg, inputs, .. } => {
                let fwd = co.fwd_exe(seg)?;
                let bwd = co.bwd_exe(seg)?;
                // cotangents of outputs (zero if this output never fed
                // anything downstream — allowed, e.g. unused residuals)
                let out_specs = &fwd.spec.outputs;
                let mut ct_per_out: Vec<Vec<HostTensor>> = Vec::new();
                for (k, key) in out_keys.iter().enumerate() {
                    let shards = match cts.remove(key) {
                        Some(s) => s,
                        None => (0..n)
                            .map(|_| HostTensor::zeros(&out_specs[k].shape))
                            .collect(),
                    };
                    ct_per_out.push(shards);
                }
                // run VJP per rank — fanned out over the coordinator's
                // thread budget like forward Exec ops; results come back
                // in rank order, so the gradient accumulation below keeps
                // the sequential summation order bit-for-bit
                let n_params = block_params.len();
                let per_rank: Vec<Vec<HostTensor>> =
                    super::executor::parallel_ranks(co.threads, n, |r| {
                        let mut rest: Vec<HostTensor> = Vec::new();
                        for inp in inputs {
                            rest.push(inp[r].clone());
                        }
                        for ct in &ct_per_out {
                            rest.push(ct[r].clone());
                        }
                        bwd.run_with_params(&param_lits, &rest)
                    })?;
                // param grads sum over ranks (DAP replicates parameters)
                let mut d_ins: Vec<Vec<HostTensor>> =
                    vec![Vec::with_capacity(n); in_keys.len()];
                for outs in &per_rank {
                    let (pg, di) = outs.split_at(n_params);
                    match &mut param_grads {
                        Some(acc) => {
                            for (a, g) in acc.iter_mut().zip(pg.iter()) {
                                a.add_assign(g)?;
                            }
                        }
                        None => param_grads = Some(pg.to_vec()),
                    }
                    for (slot_i, d) in di.iter().enumerate() {
                        d_ins[slot_i].push(d.clone());
                    }
                }
                for (key, d) in in_keys.iter().zip(d_ins.into_iter()) {
                    add_ct(&mut cts, key.clone(), d)?;
                }
            }
            TapeOp::Gather { axis, .. } => {
                if let Some(d_out) = cts.remove(&out_keys[0]) {
                    let d_in = co.comm.reduce_scatter(&d_out, *axis)?;
                    add_ct(&mut cts, in_keys[0].clone(), d_in)?;
                }
            }
            TapeOp::Scatter { axis, .. } => {
                if let Some(d_out) = cts.remove(&out_keys[0]) {
                    let d_in = co.comm.all_gather(&d_out, *axis)?;
                    add_ct(&mut cts, in_keys[0].clone(), d_in)?;
                }
            }
            TapeOp::AllToAll { split, concat, .. } => {
                if let Some(d_out) = cts.remove(&out_keys[0]) {
                    let d_in = co.comm.all_to_all(&d_out, *concat, *split)?;
                    add_ct(&mut cts, in_keys[0].clone(), d_in)?;
                }
            }
        }
    }

    // cotangents of the block inputs live at version 0
    for slot in ["m", "z"] {
        let key = (slot.to_string(), 0usize);
        let d = cts.remove(&key).ok_or_else(|| {
            Error::Schedule(format!("backward produced no d{slot}"))
        })?;
        d_state.insert(slot.to_string(), d);
    }

    param_grads.ok_or_else(|| Error::Schedule("empty tape".into()))
}
