//! Fused Adam update: the exported `adam_update` executable's formula
//! (`python/compile/aot.py`) as a single host traversal.
//!
//! [`adam_step`] updates `p`, `m`, `v` in one pass — the fused form the
//! ScaleFold-style host path wants. [`adam_step_naive`] is the unfused
//! op chain (m-update, v-update, bias-corrections, denominator, apply —
//! six traversals, three temporaries), kept as the measurable baseline.
//! Both execute identical per-element op sequences, so they are
//! **bit-for-bit equal** (pinned by test), and both match the legacy
//! host Adam loop exactly — the hybrid trainer's bit-for-bit resume and
//! equivalence suites see no numeric change from the fusion.

use super::scratch::ScratchPool;

/// Adam β₁ (first-moment decay), matching the exported executable.
pub const BETA1: f32 = 0.9;
/// Adam β₂ (second-moment decay).
pub const BETA2: f32 = 0.999;
/// Adam ε (denominator stabilizer).
pub const EPS: f32 = 1e-8;

/// One fused Adam update at (1-based) `step` with learning rate `lr`:
/// updates `p`, `m`, `v` in place in a single traversal. Slice lengths
/// must agree (panics otherwise — callers own shape checks).
pub fn adam_step(step: usize, lr: f32, p: &mut [f32], g: &[f32], m: &mut [f32], v: &mut [f32]) {
    assert!(
        p.len() == g.len() && p.len() == m.len() && p.len() == v.len(),
        "adam: length mismatch (p={}, g={}, m={}, v={})",
        p.len(),
        g.len(),
        m.len(),
        v.len()
    );
    let t = step as f32;
    let bc1 = 1.0 - BETA1.powf(t);
    let bc2 = 1.0 - BETA2.powf(t);
    for (((pi, &gi), mi), vi) in
        p.iter_mut().zip(g).zip(m.iter_mut()).zip(v.iter_mut())
    {
        *mi = BETA1 * *mi + (1.0 - BETA1) * gi;
        *vi = BETA2 * *vi + (1.0 - BETA2) * gi * gi;
        let mhat = *mi / bc1;
        let vhat = *vi / bc2;
        *pi -= lr * mhat / (vhat.sqrt() + EPS);
    }
}

/// The naive unfused Adam chain: one traversal per op with temporaries
/// from `pool` — the memory-traffic baseline. Bit-for-bit equal to
/// [`adam_step`].
pub fn adam_step_naive(
    step: usize,
    lr: f32,
    p: &mut [f32],
    g: &[f32],
    m: &mut [f32],
    v: &mut [f32],
    pool: &ScratchPool,
) {
    assert!(
        p.len() == g.len() && p.len() == m.len() && p.len() == v.len(),
        "adam: length mismatch (p={}, g={}, m={}, v={})",
        p.len(),
        g.len(),
        m.len(),
        v.len()
    );
    let t = step as f32;
    let bc1 = 1.0 - BETA1.powf(t);
    let bc2 = 1.0 - BETA2.powf(t);
    // op 1: first moment
    for (mi, &gi) in m.iter_mut().zip(g) {
        *mi = BETA1 * *mi + (1.0 - BETA1) * gi;
    }
    // op 2: second moment
    for (vi, &gi) in v.iter_mut().zip(g) {
        *vi = BETA2 * *vi + (1.0 - BETA2) * gi * gi;
    }
    // op 3: bias-corrected first moment
    let mut mhat = pool.take(p.len());
    for (o, &mi) in mhat.iter_mut().zip(m.iter()) {
        *o = mi / bc1;
    }
    // op 4: bias-corrected second moment
    let mut vhat = pool.take(p.len());
    for (o, &vi) in vhat.iter_mut().zip(v.iter()) {
        *o = vi / bc2;
    }
    // op 5: denominator
    let mut denom = pool.take(p.len());
    for (o, &vh) in denom.iter_mut().zip(vhat.iter()) {
        *o = vh.sqrt() + EPS;
    }
    // op 6: apply
    for ((pi, &mh), &dn) in p.iter_mut().zip(mhat.iter()).zip(denom.iter()) {
        *pi -= lr * mh / dn;
    }
    pool.give(denom);
    pool.give(vhat);
    pool.give(mhat);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    #[test]
    fn fused_equals_naive_bitwise() {
        let mut rng = Rng::new(71);
        let pool = ScratchPool::new();
        for step in [1usize, 2, 10, 1000] {
            let n = 257;
            let p0 = rng.normal_vec(n, 1.0);
            let g = rng.normal_vec(n, 0.5);
            let m0 = rng.normal_vec(n, 0.1);
            let v0: Vec<f32> = rng.normal_vec(n, 0.1).iter().map(|x| x * x).collect();
            let (mut pa, mut ma, mut va) = (p0.clone(), m0.clone(), v0.clone());
            let (mut pb, mut mb, mut vb) = (p0, m0, v0);
            adam_step(step, 1e-3, &mut pa, &g, &mut ma, &mut va);
            adam_step_naive(step, 1e-3, &mut pb, &g, &mut mb, &mut vb, &pool);
            for i in 0..n {
                assert_eq!(pa[i].to_bits(), pb[i].to_bits(), "p[{i}] step {step}");
                assert_eq!(ma[i].to_bits(), mb[i].to_bits(), "m[{i}] step {step}");
                assert_eq!(va[i].to_bits(), vb[i].to_bits(), "v[{i}] step {step}");
            }
        }
    }

    #[test]
    fn moves_against_gradient() {
        let mut p = vec![1.0f32; 4];
        let g = vec![0.5f32; 4];
        let mut m = vec![0.0f32; 4];
        let mut v = vec![0.0f32; 4];
        adam_step(1, 0.1, &mut p, &g, &mut m, &mut v);
        assert!(p[0] < 1.0);
        assert!(m[0] > 0.0);
        assert!(v[0] > 0.0);
    }

    #[test]
    #[should_panic(expected = "length mismatch")]
    fn length_mismatch_panics() {
        let mut p = vec![0.0f32; 2];
        let mut m = vec![0.0f32; 2];
        let mut v = vec![0.0f32; 2];
        adam_step(1, 0.1, &mut p, &[0.0; 3], &mut m, &mut v);
    }
}
