//! Fused softmax (paper Fig 8): `softmax(x · scale)` over the last axis.
//!
//! The unfused chain is 6 ops — scale, row-max, subtract, exp, row-sum,
//! divide — each a full memory traversal with a temporary
//! ([`softmax_rows_naive`]). The fused kernel ([`softmax_rows`]) makes
//! one read pass for the max, one read+write pass computing
//! `exp(x·scale − max)` while accumulating the row sum, and one in-place
//! normalize pass — no temporaries at all. Both execute the identical
//! per-element op sequence in the identical fold order, so outputs are
//! **bit-for-bit equal** (pinned by test); only the memory traffic
//! differs, which is exactly the quantity the paper's 1.77–3.32× Fig 8
//! band measures.
//!
//! Both paths exponentiate through the kernel-plane polynomial
//! [`super::math::exp32`] rather than libm, which is what lets the f32x8
//! lane backend reproduce this kernel bit-for-bit (see
//! [`crate::device`]) and keeps the results platform-deterministic.

use super::math::exp32;
use super::scratch::ScratchPool;

/// Fused row softmax: `out[r] = softmax(x[r] · scale)` for each
/// `cols`-length row. `x.len()` must be a multiple of `cols` and
/// `out.len() == x.len()` (panics otherwise — callers own shape checks).
pub fn softmax_rows(x: &[f32], cols: usize, scale: f32, out: &mut [f32]) {
    assert!(cols > 0, "softmax over 0 columns");
    assert_eq!(x.len() % cols, 0, "input not a whole number of rows");
    assert_eq!(out.len(), x.len(), "output length mismatch");
    for (orow, xrow) in out.chunks_exact_mut(cols).zip(x.chunks_exact(cols)) {
        let mut mx = f32::NEG_INFINITY;
        for &xv in xrow {
            mx = mx.max(xv * scale);
        }
        let mut sum = 0.0f32;
        for (o, &xv) in orow.iter_mut().zip(xrow) {
            let e = exp32(xv * scale - mx);
            *o = e;
            sum += e;
        }
        for o in orow.iter_mut() {
            *o /= sum;
        }
    }
}

/// The naive unfused chain: one full traversal per op (scale → row-max →
/// subtract → exp → row-sum → divide), temporaries from `pool` — the
/// memory-traffic baseline the fused kernel is measured against.
/// Bit-for-bit equal to [`softmax_rows`].
pub fn softmax_rows_naive(
    x: &[f32],
    cols: usize,
    scale: f32,
    pool: &ScratchPool,
    out: &mut [f32],
) {
    assert!(cols > 0, "softmax over 0 columns");
    assert_eq!(x.len() % cols, 0, "input not a whole number of rows");
    assert_eq!(out.len(), x.len(), "output length mismatch");
    let rows = x.len() / cols;

    // op 1: scale
    let mut scaled = pool.take(x.len());
    for (o, &xv) in scaled.iter_mut().zip(x) {
        *o = xv * scale;
    }
    // op 2: row max
    let mut rowmax = pool.take(rows);
    for (o, row) in rowmax.iter_mut().zip(scaled.chunks_exact(cols)) {
        *o = row.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
    }
    // op 3: subtract the row max
    let mut sub = pool.take(x.len());
    for ((orow, srow), &mx) in sub
        .chunks_exact_mut(cols)
        .zip(scaled.chunks_exact(cols))
        .zip(rowmax.iter())
    {
        for (o, &s) in orow.iter_mut().zip(srow) {
            *o = s - mx;
        }
    }
    // op 4: exp
    let mut ex = pool.take(x.len());
    for (o, &s) in ex.iter_mut().zip(sub.iter()) {
        *o = exp32(s);
    }
    // op 5: row sum
    let mut rowsum = pool.take(rows);
    for (o, row) in rowsum.iter_mut().zip(ex.chunks_exact(cols)) {
        *o = row.iter().sum();
    }
    // op 6: divide
    for ((orow, erow), &s) in out
        .chunks_exact_mut(cols)
        .zip(ex.chunks_exact(cols))
        .zip(rowsum.iter())
    {
        for (o, &e) in orow.iter_mut().zip(erow) {
            *o = e / s;
        }
    }
    pool.give(rowsum);
    pool.give(ex);
    pool.give(sub);
    pool.give(rowmax);
    pool.give(scaled);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    #[test]
    fn fused_equals_naive_bitwise() {
        let mut rng = Rng::new(81);
        let pool = ScratchPool::new();
        for &(rows, cols) in &[(1usize, 1usize), (3, 7), (16, 64), (5, 33)] {
            let x = rng.normal_vec(rows * cols, 2.0);
            for &scale in &[1.0f32, 0.176_776_7] {
                let mut fused = vec![0.0f32; x.len()];
                let mut naive = vec![0.0f32; x.len()];
                softmax_rows(&x, cols, scale, &mut fused);
                softmax_rows_naive(&x, cols, scale, &pool, &mut naive);
                for (a, b) in fused.iter().zip(naive.iter()) {
                    assert_eq!(a.to_bits(), b.to_bits(), "rows={rows} cols={cols}");
                }
            }
        }
    }

    #[test]
    fn rows_normalize_and_order_preserved() {
        let x = vec![1.0f32, 2.0, 3.0, -1.0, 0.0, 1.0];
        let mut out = vec![0.0f32; 6];
        softmax_rows(&x, 3, 1.0, &mut out);
        for row in out.chunks_exact(3) {
            let s: f32 = row.iter().sum();
            assert!((s - 1.0).abs() < 1e-6);
            assert!(row[0] < row[1] && row[1] < row[2], "monotone in logits");
            assert!(row.iter().all(|&p| p > 0.0));
        }
    }

    #[test]
    fn large_logits_are_stable() {
        // the max-subtraction is what keeps exp() finite
        let x = vec![1000.0f32, 1001.0, 999.0];
        let mut out = vec![0.0f32; 3];
        softmax_rows(&x, 3, 1.0, &mut out);
        assert!(out.iter().all(|p| p.is_finite()));
        assert!((out.iter().sum::<f32>() - 1.0).abs() < 1e-6);
    }

    #[test]
    #[should_panic(expected = "whole number of rows")]
    fn ragged_input_panics() {
        let mut out = vec![0.0f32; 5];
        softmax_rows(&[0.0; 5], 3, 1.0, &mut out);
    }
}
