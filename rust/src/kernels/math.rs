//! Deterministic scalar math for the kernel plane.
//!
//! [`exp32`] is a Cephes-style polynomial `exp` used by **every** device
//! backend (scalar oracle and f32x8 lane path alike). Routing both
//! through one shared polynomial — instead of libm's `f32::exp` — is
//! what makes the softmax backends bit-for-bit comparable: the lane path
//! evaluates the identical branch-free op sequence per element, so
//! vectorization changes throughput, never bits. It also removes the
//! last libm call from the fused kernels, making their results
//! platform-deterministic (libm `expf` is not pinned across targets).
//!
//! The implementation is the classic range-reduction scheme: clamp,
//! split `x = n·ln2 + r` with a two-constant Cody–Waite reduction
//! (round-to-nearest via the 1.5·2²³ magic-number trick — branch-free
//! and SSE2-vectorizable, unlike `f32::floor`, which lowers to a libm
//! call on pre-SSE4.1 targets), evaluate a degree-6 polynomial on
//! `|r| ≤ ln2/2`, and scale by `2^n` through exponent-bit assembly.

/// Saturation threshold: inputs above this clamp to it before range
/// reduction (`exp32(88) ≈ 1.65e38` is still finite in f32, and keeps
/// the biased exponent `n + 127` strictly below the infinity encoding).
pub const EXP_HI: f32 = 88.0;

/// Flush threshold: inputs below this return exactly `0.0`
/// (`exp(-87) ≈ 1.6e-38` is the last comfortably normal result).
pub const EXP_LO: f32 = -87.0;

/// Polynomial `exp(x)` for f32, deterministic across platforms and
/// identical whether evaluated one element at a time or eight lanes at
/// a time (branch-free selects, no libm, no FMA contraction).
///
/// Edge behavior: `exp32(NaN)` is NaN, `exp32(-inf) == 0.0`,
/// `exp32(+inf)` saturates to `exp32(EXP_HI)` (finite), and denormal
/// inputs round to `1.0` like any tiny argument. Accuracy is a few ulp
/// over the reduced range — well inside the kernel-plane tolerances.
#[inline(always)]
pub fn exp32(x: f32) -> f32 {
    const LOG2E: f32 = std::f32::consts::LOG2_E;
    // Cody–Waite split of ln2 (exact bit patterns — LN2_HI has a short
    // mantissa so n·LN2_HI is exact for |n| ≤ 127, keeping the
    // reduction error in LN2_LO): 0.693359375 and -2.1219444e-4.
    const LN2_HI: f32 = f32::from_bits(0x3F31_8000);
    const LN2_LO: f32 = f32::from_bits(0xB95E_8083);
    // 1.5·2²³: adding then subtracting rounds to the nearest integer in
    // f32 arithmetic (exact for |z| < 2²²) without calling `floor`.
    const ROUND: f32 = 12_582_912.0;
    // Cephes expf minimax coefficients, highest degree first:
    // 1.9875691e-4, 1.3981999e-3, 8.333452e-3, 4.1665796e-2,
    // 1.6666666e-1, 0.5 — pinned by bit pattern so the constants are
    // exactly the intended f32 values on every host.
    const P0: f32 = f32::from_bits(0x3950_6967);
    const P1: f32 = f32::from_bits(0x3AB7_43CE);
    const P2: f32 = f32::from_bits(0x3C08_8908);
    const P3: f32 = f32::from_bits(0x3D2A_A9C1);
    const P4: f32 = f32::from_bits(0x3E2A_AAAA);
    const P5: f32 = f32::from_bits(0x3F00_0000);

    // `x > EXP_HI` is false for NaN, so NaN flows through untouched.
    let xc = if x > EXP_HI { EXP_HI } else { x };
    let nf = (xc * LOG2E + ROUND) - ROUND;
    let r = xc - nf * LN2_HI - nf * LN2_LO;

    let p = P0;
    let p = p * r + P1;
    let p = p * r + P2;
    let p = p * r + P3;
    let p = p * r + P4;
    let p = p * r + P5;
    let y = p * r * r + r + 1.0;

    // 2^n via exponent bits. `nf as i32` saturates (NaN → 0), and for
    // out-of-range inputs the garbage scale is masked by the select
    // below, which also pins `exp32(-inf)` to exactly 0.
    let n = nf as i32;
    let scale = f32::from_bits(((n + 127) << 23) as u32);
    if x < EXP_LO {
        0.0
    } else {
        y * scale
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matches_libm_to_a_few_ulp() {
        // sweep the softmax-relevant range (arguments are ≤ 0 after
        // max-subtraction) plus a positive band
        let mut worst = 0.0f64;
        let mut t = -86.5f32;
        while t < 86.5 {
            let got = exp32(t) as f64;
            let want = (t as f64).exp();
            let rel = ((got - want) / want).abs();
            worst = worst.max(rel);
            t += 0.037;
        }
        assert!(worst < 1e-6, "worst relative error {worst:e}");
    }

    #[test]
    fn exact_anchor_points() {
        assert_eq!(exp32(0.0), 1.0);
        assert_eq!(exp32(-0.0), 1.0);
        assert_eq!(exp32(f32::NEG_INFINITY), 0.0);
        assert_eq!(exp32(-1.0e30), 0.0);
        assert!(exp32(f32::NAN).is_nan());
        let sat = exp32(f32::INFINITY);
        assert!(sat.is_finite() && sat > 1.0e38);
        assert_eq!(sat.to_bits(), exp32(EXP_HI).to_bits());
    }

    #[test]
    fn denormals_and_flush_band() {
        assert_eq!(exp32(1.0e-40), 1.0, "denormal argument rounds to 1");
        assert_eq!(exp32(EXP_LO - 1.0), 0.0);
        let lo = exp32(EXP_LO);
        assert!(lo > 0.0 && lo.is_normal(), "flush threshold stays normal");
    }

    #[test]
    fn monotone_on_a_grid() {
        let mut prev = exp32(-20.0);
        let mut t = -20.0f32 + 0.01;
        while t < 20.0 {
            let cur = exp32(t);
            assert!(cur >= prev, "exp32 not monotone at {t}");
            prev = cur;
            t += 0.01;
        }
    }
}
