//! Native fused host kernels (paper Figs 8–9 brought on-host).
//!
//! The paper's kernel pillar fuses multi-op chains — softmax's
//! scale/max/sub/exp/sum/div, LayerNorm's two reduction passes plus the
//! affine apply — into single kernels that traverse memory once or twice
//! instead of once per op. This module implements those fused kernels as
//! plain-slice host functions, next to their **naive op-chain
//! counterparts** (one full traversal per op, temporaries from a
//! [`ScratchPool`]) so the fused-vs-naive delta is measurable everywhere
//! (`fastfold bench`, the fig8/fig9 benches' native mode) without
//! artifacts or a device.
//!
//! Contracts:
//!
//! * `softmax` fused vs naive is **bit-for-bit identical** (same
//!   per-element op sequence, same fold order) — fusion changes memory
//!   traffic, never numerics.
//! * `adam` fused vs naive is bit-for-bit identical, and both match the
//!   exported `adam_update` executable's formula exactly (the
//!   [`crate::train`] host Adam path runs on the fused kernel).
//! * `layernorm`'s fused kernel uses chunked Welford accumulation —
//!   numerically *better* than the naive two-pass chain but not
//!   bit-identical to it; equivalence is validated to tolerance, like
//!   the paper's Fig 14 numerics check.
//!
//! Kernels operate on raw `&[f32]` rows so this module stays a leaf
//! (usable from [`crate::tensor`] without cycles of responsibility).
//! Exponentials go through the kernel-plane polynomial
//! [`math::exp32`] — shared by the scalar oracle and the f32x8 lane
//! backend, which is what makes the two bit-comparable.
//!
//! Callers outside the device plane do not invoke these functions
//! directly: dispatch goes through [`crate::device`], which selects the
//! scalar oracle, the SIMD fast path, or the xla stub at runtime (the
//! `backend-bypass` lint enforces this).

pub mod adam;
pub mod bf16;
pub mod layernorm;
pub mod math;
pub mod scratch;
pub mod softmax;

pub use scratch::ScratchPool;

/// Elementwise `dst += src` (the reduction primitive behind
/// [`crate::tensor::HostTensor::add_assign`]).
pub fn add_assign(dst: &mut [f32], src: &[f32]) {
    debug_assert_eq!(dst.len(), src.len());
    for (a, &b) in dst.iter_mut().zip(src) {
        *a += b;
    }
}

/// Elementwise `dst *= s` (behind [`crate::tensor::HostTensor::scale`]).
pub fn scale(dst: &mut [f32], s: f32) {
    for a in dst.iter_mut() {
        *a *= s;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn elementwise_helpers() {
        let mut a = vec![1.0f32, 2.0, 3.0];
        add_assign(&mut a, &[0.5, 0.5, 0.5]);
        assert_eq!(a, vec![1.5, 2.5, 3.5]);
        scale(&mut a, 2.0);
        assert_eq!(a, vec![3.0, 5.0, 7.0]);
    }
}
