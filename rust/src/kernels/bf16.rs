//! bf16 storage emulation for the mixed-precision training plane.
//!
//! The offline build has no device bf16 unit, so "bf16" here means the
//! *storage format*: a value is bf16-representable iff its low 16
//! mantissa bits are zero. [`pack`] rounds an f32 to the nearest
//! bf16-representable value (round-to-nearest-even — the hardware cast
//! semantics on every bf16-capable accelerator) and keeps the top 16
//! bits; [`unpack`] widens back by appending a zero mantissa half,
//! which is exact. Gradients stored/reduced in bf16 therefore lose
//! precision exactly where real hardware would, while the f32 master
//! weights in the fused Adam keep the optimizer trajectory stable
//! (paper-adjacent ScaleFold recipe, arXiv:2404.11068).
//!
//! Like the other kernels this module is a leaf: callers dispatch
//! through [`crate::device`], never call these directly.

/// Round an f32 to bf16 (round-to-nearest-even) and keep the packed
/// top-16-bit form. NaNs are quieted (mantissa MSB forced on) so a NaN
/// payload can never round to infinity.
#[inline(always)]
pub fn pack(x: f32) -> u16 {
    let bits = x.to_bits();
    if x.is_nan() {
        return ((bits >> 16) as u16) | 0x0040;
    }
    // RNE: add 0x7FFF plus the round bit that makes ties go to even
    let round = 0x7FFF + ((bits >> 16) & 1);
    (bits.wrapping_add(round) >> 16) as u16
}

/// Widen a packed bf16 half back to f32 (exact — bf16 values are a
/// subset of f32).
#[inline(always)]
pub fn unpack(b: u16) -> f32 {
    f32::from_bits((b as u32) << 16)
}

/// The nearest bf16-representable value of `x`, as f32 (cast
/// round-trip: pack then widen).
#[inline(always)]
pub fn round_f32(x: f32) -> f32 {
    unpack(pack(x))
}

/// In-place cast of every element to its nearest bf16-representable
/// value (f32 storage, bf16 value grid).
pub fn round_slice(dst: &mut [f32]) {
    for d in dst.iter_mut() {
        *d = round_f32(*d);
    }
}

/// Pack f32s into bf16 wire halves (RNE per element).
pub fn pack_slice(src: &[f32], dst: &mut [u16]) {
    debug_assert_eq!(src.len(), dst.len());
    for (d, &s) in dst.iter_mut().zip(src) {
        *d = pack(s);
    }
}

/// Unpack bf16 wire halves back into f32s (exact).
pub fn unpack_slice(src: &[u16], dst: &mut [f32]) {
    debug_assert_eq!(src.len(), dst.len());
    for (d, &s) in dst.iter_mut().zip(src) {
        *d = unpack(s);
    }
}

/// bf16-accumulate: `dst += widen(src)` with f32 accumulation — the
/// reduction primitive of the bf16 ring all-reduce (values travel in
/// half the bytes; the accumulator keeps f32 precision).
pub fn add_assign_bf16(dst: &mut [f32], src: &[u16]) {
    debug_assert_eq!(dst.len(), src.len());
    for (d, &s) in dst.iter_mut().zip(src) {
        *d += unpack(s);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_is_exact_on_bf16_grid() {
        for v in [0.0f32, -0.0, 1.0, -1.5, 0.25, 3.0e38, 1.0e-38] {
            let r = round_f32(v);
            // a second cast is a fixed point
            assert_eq!(round_f32(r).to_bits(), r.to_bits(), "v={v}");
        }
        // values already on the grid pass through untouched
        assert_eq!(round_f32(1.0).to_bits(), 1.0f32.to_bits());
        assert_eq!(round_f32(-2.5).to_bits(), (-2.5f32).to_bits());
    }

    #[test]
    fn rounds_to_nearest_even() {
        // 1.0 + 2^-9 sits exactly between bf16 neighbours 1.0 and
        // 1.0078125; RNE picks the even mantissa (1.0)
        let tie = f32::from_bits(0x3F80_8000);
        assert_eq!(round_f32(tie), 1.0);
        // one ulp above the tie rounds up
        let above = f32::from_bits(0x3F80_8001);
        assert_eq!(round_f32(above), f32::from_bits(0x3F81_0000));
        // and the next tie (between 1.0078125 and 1.015625) rounds to
        // the even neighbour above
        let tie2 = f32::from_bits(0x3F81_8000);
        assert_eq!(round_f32(tie2), f32::from_bits(0x3F82_0000));
    }

    #[test]
    fn nan_and_inf_preserved() {
        assert!(round_f32(f32::NAN).is_nan());
        assert_eq!(round_f32(f32::INFINITY), f32::INFINITY);
        assert_eq!(round_f32(f32::NEG_INFINITY), f32::NEG_INFINITY);
        // overflow to infinity at the top of the range is RNE-correct
        assert_eq!(round_f32(f32::MAX), f32::INFINITY);
    }

    #[test]
    fn slice_helpers_agree_with_scalar() {
        let xs: Vec<f32> = (0..1000).map(|i| (i as f32 - 500.0) * 0.0137).collect();
        let mut packed = vec![0u16; xs.len()];
        pack_slice(&xs, &mut packed);
        let mut widened = vec![0f32; xs.len()];
        unpack_slice(&packed, &mut widened);
        let mut rounded = xs.clone();
        round_slice(&mut rounded);
        for ((&x, &w), &r) in xs.iter().zip(&widened).zip(&rounded) {
            assert_eq!(w.to_bits(), round_f32(x).to_bits());
            assert_eq!(r.to_bits(), w.to_bits());
            assert!((w - x).abs() <= x.abs() * 0.0040, "x={x} w={w}");
        }
        let mut acc = vec![1.0f32; xs.len()];
        add_assign_bf16(&mut acc, &packed);
        for (a, &w) in acc.iter().zip(&widened) {
            assert_eq!(a.to_bits(), (1.0 + w).to_bits());
        }
    }
}
