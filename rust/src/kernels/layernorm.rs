//! Fused LayerNorm (paper Fig 9): normalize each row to zero mean / unit
//! variance, then apply the `gamma`/`beta` affine.
//!
//! Three implementations, mirroring the paper's three baselines:
//!
//! * [`layernorm_rows`] — **fused, chunked Welford**: one read pass
//!   accumulates mean and M2 over [`LANES`] interleaved accumulator
//!   lanes (merged with the parallel-Welford combine), one read+write
//!   pass applies the normalize+affine. 2 memory passes, no
//!   temporaries. This is the paper's chunked-Welford kernel at host
//!   scale — and the chunking matters on a CPU too: textbook
//!   single-accumulator Welford puts a division on the loop-carried
//!   dependency chain (serial ~15–20 cycles/element), which can lose to
//!   the naive chain's vectorizable passes; separate lanes plus
//!   precomputed running-mean reciprocals keep the single pass
//!   pipelined.
//! * [`layernorm_rows_apex`] — "Apex-like" single fusion: separate mean
//!   and variance reduction passes, then one fused apply. 3 passes, no
//!   temporaries.
//! * [`layernorm_rows_naive`] — the unfused op chain: mean, subtract,
//!   square, variance, normalize, affine — 6 traversals with
//!   temporaries from the [`ScratchPool`].
//!
//! Welford changes the *summation order*, so fused vs naive is validated
//! to tolerance (like the paper's Fig 14 numerics check), not bitwise;
//! apex vs naive share the two-pass statistics and differ only in fusion.

use super::scratch::ScratchPool;

/// Interleaved Welford accumulator lanes in [`layernorm_rows`] — the
/// "chunk" count of the chunked-Welford statistics pass.
pub const LANES: usize = 4;

/// Fused chunked-Welford LayerNorm over `cols`-length rows.
/// `gamma`/`beta` are length-`cols`; `out.len() == x.len()` (panics on
/// mismatch — callers own shape checks).
pub fn layernorm_rows(
    x: &[f32],
    cols: usize,
    gamma: &[f32],
    beta: &[f32],
    eps: f32,
    out: &mut [f32],
) {
    check(x, cols, gamma, beta, out.len());
    // running-mean reciprocals 1/(k+1), shared by every row and lane —
    // keeps the hot loop division-free (float division would otherwise
    // bound the pass's throughput)
    let max_cnt = (cols + LANES - 1) / LANES;
    let recip: Vec<f32> = (0..max_cnt).map(|k| 1.0 / (k as f32 + 1.0)).collect();
    for (orow, xrow) in out.chunks_exact_mut(cols).zip(x.chunks_exact(cols)) {
        // pass 1: chunked Welford — LANES independent accumulators over
        // interleaved elements (separate dependency chains), merged by
        // the parallel-Welford combination
        let mut mean = [0.0f32; LANES];
        let mut m2 = [0.0f32; LANES];
        let mut cnt = [0usize; LANES];
        for chunk in xrow.chunks(LANES) {
            for (l, &xv) in chunk.iter().enumerate() {
                let delta = xv - mean[l];
                mean[l] += delta * recip[cnt[l]];
                m2[l] += delta * (xv - mean[l]);
                cnt[l] += 1;
            }
        }
        let mut n_acc = cnt[0] as f32;
        let mut mean_acc = mean[0];
        let mut m2_acc = m2[0];
        for l in 1..LANES {
            if cnt[l] == 0 {
                continue;
            }
            let nb = cnt[l] as f32;
            let delta = mean[l] - mean_acc;
            let n = n_acc + nb;
            m2_acc += m2[l] + delta * delta * n_acc * nb / n;
            mean_acc += delta * nb / n;
            n_acc = n;
        }
        let var = m2_acc / cols as f32;
        let rstd = 1.0 / (var + eps).sqrt();
        // pass 2: fused normalize + affine
        for ((o, &xv), (&g, &b)) in
            orow.iter_mut().zip(xrow).zip(gamma.iter().zip(beta.iter()))
        {
            *o = (xv - mean_acc) * rstd * g + b;
        }
    }
}

/// "Apex-like" single-fusion baseline: two-pass statistics (mean pass,
/// variance pass) + one fused normalize/affine pass — 3 traversals, no
/// temporaries.
pub fn layernorm_rows_apex(
    x: &[f32],
    cols: usize,
    gamma: &[f32],
    beta: &[f32],
    eps: f32,
    out: &mut [f32],
) {
    check(x, cols, gamma, beta, out.len());
    for (orow, xrow) in out.chunks_exact_mut(cols).zip(x.chunks_exact(cols)) {
        let mean = xrow.iter().sum::<f32>() / cols as f32;
        let mut acc = 0.0f32;
        for &xv in xrow {
            let d = xv - mean;
            acc += d * d;
        }
        let var = acc / cols as f32;
        let rstd = 1.0 / (var + eps).sqrt();
        for ((o, &xv), (&g, &b)) in
            orow.iter_mut().zip(xrow).zip(gamma.iter().zip(beta.iter()))
        {
            *o = (xv - mean) * rstd * g + b;
        }
    }
}

/// The naive unfused chain: one traversal per op (mean → subtract →
/// square → variance → normalize → affine) with temporaries from `pool`
/// — the memory-traffic baseline.
pub fn layernorm_rows_naive(
    x: &[f32],
    cols: usize,
    gamma: &[f32],
    beta: &[f32],
    eps: f32,
    pool: &ScratchPool,
    out: &mut [f32],
) {
    check(x, cols, gamma, beta, out.len());
    let rows = x.len() / cols;

    // op 1: row means
    let mut means = pool.take(rows);
    for (o, row) in means.iter_mut().zip(x.chunks_exact(cols)) {
        *o = row.iter().sum::<f32>() / cols as f32;
    }
    // op 2: center
    let mut centered = pool.take(x.len());
    for ((orow, xrow), &mean) in centered
        .chunks_exact_mut(cols)
        .zip(x.chunks_exact(cols))
        .zip(means.iter())
    {
        for (o, &xv) in orow.iter_mut().zip(xrow) {
            *o = xv - mean;
        }
    }
    // op 3: square
    let mut sq = pool.take(x.len());
    for (o, &c) in sq.iter_mut().zip(centered.iter()) {
        *o = c * c;
    }
    // op 4: row variances
    let mut vars = pool.take(rows);
    for (o, row) in vars.iter_mut().zip(sq.chunks_exact(cols)) {
        *o = row.iter().sum::<f32>() / cols as f32;
    }
    // op 5: normalize
    let mut norm = pool.take(x.len());
    for ((orow, crow), &var) in norm
        .chunks_exact_mut(cols)
        .zip(centered.chunks_exact(cols))
        .zip(vars.iter())
    {
        let rstd = 1.0 / (var + eps).sqrt();
        for (o, &c) in orow.iter_mut().zip(crow) {
            *o = c * rstd;
        }
    }
    // op 6: affine
    for (orow, nrow) in out.chunks_exact_mut(cols).zip(norm.chunks_exact(cols)) {
        for ((o, &nv), (&g, &b)) in
            orow.iter_mut().zip(nrow).zip(gamma.iter().zip(beta.iter()))
        {
            *o = nv * g + b;
        }
    }
    pool.give(norm);
    pool.give(vars);
    pool.give(sq);
    pool.give(centered);
    pool.give(means);
}

fn check(x: &[f32], cols: usize, gamma: &[f32], beta: &[f32], out_len: usize) {
    assert!(cols > 0, "layernorm over 0 columns");
    assert_eq!(x.len() % cols, 0, "input not a whole number of rows");
    assert_eq!(gamma.len(), cols, "gamma length mismatch");
    assert_eq!(beta.len(), cols, "beta length mismatch");
    assert_eq!(out_len, x.len(), "output length mismatch");
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    const EPS: f32 = 1e-5;

    #[test]
    fn fused_matches_naive_and_apex_to_tolerance() {
        let mut rng = Rng::new(91);
        let pool = ScratchPool::new();
        for &(rows, cols) in &[(1usize, 4usize), (8, 32), (16, 128), (3, 65)] {
            let x = rng.normal_vec(rows * cols, 2.0);
            let g = rng.normal_vec(cols, 1.0);
            let b = rng.normal_vec(cols, 1.0);
            let mut fused = vec![0.0f32; x.len()];
            let mut apex = vec![0.0f32; x.len()];
            let mut naive = vec![0.0f32; x.len()];
            layernorm_rows(&x, cols, &g, &b, EPS, &mut fused);
            layernorm_rows_apex(&x, cols, &g, &b, EPS, &mut apex);
            layernorm_rows_naive(&x, cols, &g, &b, EPS, &pool, &mut naive);
            for i in 0..x.len() {
                assert!(
                    (fused[i] - naive[i]).abs() < 1e-4,
                    "fused vs naive at {i}: {} vs {}",
                    fused[i],
                    naive[i]
                );
                assert!(
                    (apex[i] - naive[i]).abs() < 1e-5,
                    "apex vs naive at {i}: {} vs {}",
                    apex[i],
                    naive[i]
                );
            }
        }
    }

    #[test]
    fn normalizes_rows() {
        let mut rng = Rng::new(92);
        let (rows, cols) = (4usize, 64usize);
        let x = rng.normal_vec(rows * cols, 3.0);
        let g = vec![1.0f32; cols];
        let b = vec![0.0f32; cols];
        let mut out = vec![0.0f32; x.len()];
        layernorm_rows(&x, cols, &g, &b, EPS, &mut out);
        for row in out.chunks_exact(cols) {
            let mean: f32 = row.iter().sum::<f32>() / cols as f32;
            let var: f32 =
                row.iter().map(|&v| (v - mean) * (v - mean)).sum::<f32>() / cols as f32;
            assert!(mean.abs() < 1e-4, "mean {mean}");
            assert!((var - 1.0).abs() < 1e-2, "var {var}");
        }
    }

    #[test]
    fn affine_applies() {
        let x = vec![1.0f32, -1.0];
        let mut out = vec![0.0f32; 2];
        layernorm_rows(&x, 2, &[2.0, 2.0], &[10.0, 10.0], EPS, &mut out);
        // normalized row is ±1 (up to eps), so out ≈ 10 ± 2
        assert!((out[0] - 12.0).abs() < 1e-2, "{}", out[0]);
        assert!((out[1] - 8.0).abs() < 1e-2, "{}", out[1]);
    }

    #[test]
    fn deterministic() {
        let mut rng = Rng::new(93);
        let x = rng.normal_vec(256, 1.0);
        let g = vec![1.0f32; 64];
        let b = vec![0.0f32; 64];
        let mut a = vec![0.0f32; 256];
        let mut c = vec![0.0f32; 256];
        layernorm_rows(&x, 64, &g, &b, EPS, &mut a);
        layernorm_rows(&x, 64, &g, &b, EPS, &mut c);
        for (p, q) in a.iter().zip(c.iter()) {
            assert_eq!(p.to_bits(), q.to_bits());
        }
    }
}
