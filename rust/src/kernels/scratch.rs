//! [`ScratchPool`]: a reusable buffer pool for kernel temporaries.
//!
//! The naive op-chain kernels materialize one temporary per op — exactly
//! the allocation traffic the fused kernels eliminate. The pool lets the
//! chains (and any other per-step temporary consumer, e.g. the ring
//! all-reduce's per-step snapshot) pay the allocation once and reuse it
//! across iterations, so benches compare *memory passes*, not allocator
//! throughput.

/// A LIFO free-list of `Vec<f32>` buffers. `take` hands out a zeroed
/// buffer of the requested length, reusing the most recently returned
/// allocation (LIFO — callers with a fixed take/give pattern, like the
/// naive kernel chains, get their own allocations back and reallocate
/// nothing in steady state); `give` returns a buffer for reuse.
#[derive(Debug, Default)]
pub struct ScratchPool {
    free: Vec<Vec<f32>>,
}

impl ScratchPool {
    /// An empty pool.
    pub fn new() -> Self {
        Self::default()
    }

    /// Take a buffer of exactly `len` zeros (reuses a retained allocation
    /// when one exists; its capacity is kept, so steady-state `take`s
    /// allocate nothing once the pool is warm).
    pub fn take(&mut self, len: usize) -> Vec<f32> {
        let mut b = self.free.pop().unwrap_or_default();
        b.clear();
        b.resize(len, 0.0);
        b
    }

    /// Return a buffer to the pool for reuse.
    pub fn give(&mut self, b: Vec<f32>) {
        self.free.push(b);
    }

    /// Number of buffers currently retained for reuse.
    pub fn retained(&self) -> usize {
        self.free.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn take_reuses_capacity() {
        let mut pool = ScratchPool::new();
        let mut b = pool.take(128);
        assert_eq!(b.len(), 128);
        assert!(b.iter().all(|&x| x == 0.0));
        b[0] = 7.0;
        let cap = b.capacity();
        let ptr = b.as_ptr();
        pool.give(b);
        assert_eq!(pool.retained(), 1);
        let c = pool.take(64);
        assert_eq!(c.len(), 64);
        assert!(c.iter().all(|&x| x == 0.0), "reused buffers come back zeroed");
        assert_eq!(c.as_ptr(), ptr, "allocation reused");
        assert_eq!(c.capacity(), cap);
        assert_eq!(pool.retained(), 0);
    }

    #[test]
    fn empty_pool_allocates() {
        let mut pool = ScratchPool::new();
        assert_eq!(pool.retained(), 0);
        let b = pool.take(8);
        assert_eq!(b.len(), 8);
    }
}
