//! [`ScratchPool`]: a reusable buffer pool for kernel temporaries.
//!
//! The naive op-chain kernels materialize one temporary per op — exactly
//! the allocation traffic the fused kernels eliminate. The pool lets the
//! chains (and any other per-step temporary consumer, e.g. the ring
//! all-reduce's per-step snapshot) pay the allocation once and reuse it
//! across iterations, so benches compare *memory passes*, not allocator
//! throughput.
//!
//! The free-list sits behind a `Mutex`, so one pool can be shared by the
//! within-op worker threads the SIMD backend spawns (see
//! [`crate::device`]): `take`/`give` are checked checkouts — each worker
//! owns its buffers outright between the two calls, and the lock is held
//! only for the free-list push/pop, never across a kernel pass.

use std::sync::Mutex;

/// A LIFO free-list of `Vec<f32>` buffers. `take` hands out a zeroed
/// buffer of the requested length, reusing the most recently returned
/// allocation (LIFO — callers with a fixed take/give pattern, like the
/// naive kernel chains, get their own allocations back and reallocate
/// nothing in steady state); `give` returns a buffer for reuse. All
/// methods take `&self`, so a single pool is sharable across worker
/// threads (`Sync` via the interior lock).
#[derive(Debug, Default)]
pub struct ScratchPool {
    free: Mutex<Vec<Vec<f32>>>,
}

impl ScratchPool {
    /// An empty pool.
    pub fn new() -> Self {
        Self::default()
    }

    /// Take a buffer of exactly `len` zeros (reuses a retained allocation
    /// when one exists; its capacity is kept, so steady-state `take`s
    /// allocate nothing once the pool is warm). The buffer is owned by
    /// the caller until `give`n back — no lock is held while it is used.
    pub fn take(&self, len: usize) -> Vec<f32> {
        let popped = self.free.lock().expect("scratch pool lock poisoned").pop();
        let mut b = popped.unwrap_or_default();
        b.clear();
        b.resize(len, 0.0);
        b
    }

    /// Return a buffer to the pool for reuse.
    pub fn give(&self, b: Vec<f32>) {
        self.free.lock().expect("scratch pool lock poisoned").push(b);
    }

    /// Number of buffers currently retained for reuse.
    pub fn retained(&self) -> usize {
        self.free.lock().expect("scratch pool lock poisoned").len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn take_reuses_capacity() {
        let pool = ScratchPool::new();
        let mut b = pool.take(128);
        assert_eq!(b.len(), 128);
        assert!(b.iter().all(|&x| x == 0.0));
        b[0] = 7.0;
        let cap = b.capacity();
        let ptr = b.as_ptr();
        pool.give(b);
        assert_eq!(pool.retained(), 1);
        let c = pool.take(64);
        assert_eq!(c.len(), 64);
        assert!(c.iter().all(|&x| x == 0.0), "reused buffers come back zeroed");
        assert_eq!(c.as_ptr(), ptr, "allocation reused");
        assert_eq!(c.capacity(), cap);
        assert_eq!(pool.retained(), 0);
    }

    #[test]
    fn empty_pool_allocates() {
        let pool = ScratchPool::new();
        assert_eq!(pool.retained(), 0);
        let b = pool.take(8);
        assert_eq!(b.len(), 8);
    }

    #[test]
    fn shared_across_threads_checkouts_are_distinct() {
        // 4 workers × many iterations hammer one pool concurrently; every
        // checkout must be a distinct zeroed buffer (a worker scribbles a
        // tag, yields, and re-checks — aliased buffers would clash), and
        // the free-list must end bounded by the peak outstanding count.
        let pool = ScratchPool::new();
        std::thread::scope(|s| {
            for t in 0..4usize {
                let pool = &pool;
                s.spawn(move || {
                    for i in 0..200usize {
                        let mut a = pool.take(64);
                        let mut b = pool.take(32);
                        assert!(a.iter().all(|&x| x == 0.0));
                        assert!(b.iter().all(|&x| x == 0.0));
                        let tag = (t * 1000 + i) as f32;
                        a[0] = tag;
                        b[0] = -tag;
                        std::thread::yield_now();
                        assert_eq!(a[0], tag, "buffer aliased across threads");
                        assert_eq!(b[0], -tag, "buffer aliased across threads");
                        pool.give(b);
                        pool.give(a);
                    }
                });
            }
        });
        assert!(
            pool.retained() <= 8,
            "free-list exceeds peak outstanding buffers: {}",
            pool.retained()
        );
    }
}
