//! [`HostTensor`]: the host-side nd-array the coordinator moves between
//! PJRT executions and collectives.
//!
//! **Storage model (the zero-copy host data plane).** Every tensor is a
//! *view* into shared `Arc<Vec<f32>>` storage: `(buf, offset, shape)`,
//! always contiguous in row-major order. Consequences:
//!
//! * `clone()` is O(1) — an `Arc` bump plus a shape copy. The DAP
//!   executor's shard moves and the tape's forward snapshots no longer
//!   deep-copy activations.
//! * `slice_axis`/`split_axis` along a leading axis (the DAP shard axis)
//!   are O(1) metadata ops; `concat` of adjacent views of one buffer
//!   (the shard → unshard roundtrip) reassembles the parent view without
//!   touching element data.
//! * Mutation goes through [`HostTensor::data_mut`], which is
//!   **copy-on-write**: a uniquely-owned full-buffer tensor mutates in
//!   place, a shared or sub-view tensor first materializes its own
//!   buffer. No caller can observe another view's mutation.
//! * Literal conversion shares storage with the `xla` stub
//!   ([`xla::Literal::from_shared`] / `to_shared`), so the Runtime hot
//!   path moves `Arc`s, not element copies.
//!
//! Views are deliberately restricted to *contiguous* runs (no general
//! strides): the hot paths — axis-0 sharding, executor slot moves, tape
//! snapshots, literal conversion — are all contiguous, and a strided
//! `transpose01` view would only defer the same copy to the next literal
//! conversion while making every consumer stride-aware. The copying
//! reference implementations ([`HostTensor::slice_axis_copy`],
//! [`HostTensor::concat_copy`]) are kept for the equivalence property
//! suite and the `fastfold bench` shard-move comparison.

use crate::error::{Error, Result};
use std::sync::Arc;

/// Row-major f32 nd-array over shared, view-based storage (see the
/// module docs for the zero-copy semantics).
#[derive(Clone, Debug)]
pub struct HostTensor {
    /// Logical dimensions, outermost first (row-major).
    pub shape: Vec<usize>,
    buf: Arc<Vec<f32>>,
    offset: usize,
}

impl PartialEq for HostTensor {
    fn eq(&self, other: &Self) -> bool {
        self.shape == other.shape && self.data() == other.data()
    }
}

impl HostTensor {
    /// Build a tensor owning `data` (element count must match `shape`).
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Result<Self> {
        let n: usize = shape.iter().product();
        if n != data.len() {
            return Err(Error::Shape(format!(
                "shape {:?} wants {} elems, got {}",
                shape,
                n,
                data.len()
            )));
        }
        Ok(HostTensor { shape, buf: Arc::new(data), offset: 0 })
    }

    /// Build a tensor sharing an existing storage buffer (zero-copy; the
    /// literal round-trip uses this).
    pub fn from_shared(shape: Vec<usize>, buf: Arc<Vec<f32>>) -> Result<Self> {
        let n: usize = shape.iter().product();
        if n != buf.len() {
            return Err(Error::Shape(format!(
                "shape {:?} wants {} elems, shared buffer has {}",
                shape,
                n,
                buf.len()
            )));
        }
        Ok(HostTensor { shape, buf, offset: 0 })
    }

    /// All-zeros tensor.
    pub fn zeros(shape: &[usize]) -> Self {
        let n = shape.iter().product();
        HostTensor { shape: shape.to_vec(), buf: Arc::new(vec![0.0; n]), offset: 0 }
    }

    /// Rank-0 scalar.
    pub fn scalar(v: f32) -> Self {
        HostTensor { shape: vec![], buf: Arc::new(vec![v]), offset: 0 }
    }

    /// Constant-filled tensor.
    pub fn full(shape: &[usize], v: f32) -> Self {
        let n = shape.iter().product();
        HostTensor { shape: shape.to_vec(), buf: Arc::new(vec![v; n]), offset: 0 }
    }

    /// Element count of the view.
    pub fn len(&self) -> usize {
        self.shape.iter().product()
    }

    /// True when the view holds no elements (some dimension is 0).
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Number of dimensions.
    pub fn ndim(&self) -> usize {
        self.shape.len()
    }

    /// Byte volume of the view's elements.
    pub fn size_bytes(&self) -> usize {
        self.len() * 4
    }

    /// The view's elements in logical (row-major) order. O(1): views are
    /// always contiguous, so this is a plain sub-slice of the shared
    /// buffer.
    pub fn data(&self) -> &[f32] {
        &self.buf[self.offset..self.offset + self.len()]
    }

    /// Copy the view's elements out as an owned vector.
    pub fn to_vec(&self) -> Vec<f32> {
        self.data().to_vec()
    }

    /// Mutable access with **copy-on-write** semantics: if this tensor
    /// uniquely owns its full buffer it mutates in place; otherwise (the
    /// storage is shared with other views, or this is a sub-view) it
    /// first materializes a private copy of its elements. Either way the
    /// returned slice is this tensor's elements in logical order and no
    /// other view observes the mutation.
    pub fn data_mut(&mut self) -> &mut [f32] {
        let n = self.len();
        let unique_full = self.offset == 0
            && self.buf.len() == n
            && Arc::get_mut(&mut self.buf).is_some();
        if !unique_full {
            let copied = self.data().to_vec();
            self.buf = Arc::new(copied);
            self.offset = 0;
        }
        Arc::get_mut(&mut self.buf)
            .expect("unique after copy-on-write")
            .as_mut_slice()
    }

    /// True when `self` and `other` share one storage buffer (views of
    /// the same allocation). Test/diagnostic helper for the zero-copy
    /// contracts.
    pub fn shares_storage(&self, other: &Self) -> bool {
        Arc::ptr_eq(&self.buf, &other.buf)
    }

    /// True when this tensor is a proper view (does not span its whole
    /// storage buffer).
    pub fn is_view(&self) -> bool {
        self.offset != 0 || self.buf.len() != self.len()
    }

    /// Shared validation for the slice family: bounds-check the request
    /// and return `(outer, inner, d)` — the dims both implementations
    /// gather with (one checker, so the paths cannot diverge).
    fn slice_dims(&self, axis: usize, start: usize, len: usize) -> Result<(usize, usize, usize)> {
        if axis >= self.shape.len() || start + len > self.shape[axis] {
            return Err(Error::Shape(format!(
                "slice axis {axis} [{start}+{len}) of {:?}",
                self.shape
            )));
        }
        let outer = self.shape[..axis].iter().product();
        let inner = self.shape[axis + 1..].iter().product();
        Ok((outer, inner, self.shape[axis]))
    }

    /// Slice `[start, start+len)` along `axis`. O(1) when the selected
    /// elements form one contiguous run — `axis` is the leading
    /// non-trivial dimension (the DAP shard axis) or the slice is the
    /// identity — otherwise a gather-copy.
    pub fn slice_axis(&self, axis: usize, start: usize, len: usize) -> Result<Self> {
        let (outer, inner, d) = self.slice_dims(axis, start, len)?;
        if outer == 1 || len == d {
            // one contiguous run: offset arithmetic only (len == d forces
            // start == 0 — the identity slice — at any axis)
            let mut shape = self.shape.clone();
            shape[axis] = len;
            return Ok(HostTensor {
                shape,
                buf: Arc::clone(&self.buf),
                offset: self.offset + start * inner,
            });
        }
        // non-contiguous selection: one gather algorithm, shared with the
        // reference so the two paths cannot diverge
        self.slice_axis_copy(axis, start, len)
    }

    /// Reference copying `slice_axis` (the pre-view implementation) —
    /// kept for the equivalence property suite and the shard-move bench.
    pub fn slice_axis_copy(&self, axis: usize, start: usize, len: usize) -> Result<Self> {
        let (outer, inner, d) = self.slice_dims(axis, start, len)?;
        let src = self.data();
        let mut out = Vec::with_capacity(outer * len * inner);
        for o in 0..outer {
            let base = o * d * inner + start * inner;
            out.extend_from_slice(&src[base..base + len * inner]);
        }
        let mut shape = self.shape.clone();
        shape[axis] = len;
        HostTensor::new(shape, out)
    }

    /// Split into `n` equal parts along `axis` (O(1) views on the leading
    /// axis).
    pub fn split_axis(&self, axis: usize, n: usize) -> Result<Vec<Self>> {
        if axis >= self.shape.len() || n == 0 || self.shape[axis] % n != 0 {
            return Err(Error::Shape(format!(
                "split axis {axis} of {:?} into {n}",
                self.shape
            )));
        }
        let part = self.shape[axis] / n;
        (0..n).map(|i| self.slice_axis(axis, i * part, part)).collect()
    }

    /// Shared validation for the concat family: rank/shape compatibility
    /// plus the result geometry `(outer, inner, concatenated shape)` —
    /// one checker, so the view and copy paths cannot diverge.
    fn concat_dims(parts: &[Self], axis: usize) -> Result<(usize, usize, Vec<usize>)> {
        let first = parts.first().ok_or_else(|| Error::Shape("concat of 0 tensors".into()))?;
        let nd = first.shape.len();
        if axis >= nd {
            return Err(Error::Shape(format!("concat axis {axis} of {nd}-d")));
        }
        for p in parts {
            if p.shape.len() != nd
                || p.shape[..axis] != first.shape[..axis]
                || p.shape[axis + 1..] != first.shape[axis + 1..]
            {
                return Err(Error::Shape(format!(
                    "concat mismatch {:?} vs {:?}",
                    p.shape, first.shape
                )));
            }
        }
        let outer = first.shape[..axis].iter().product();
        let inner = first.shape[axis + 1..].iter().product();
        let total_axis: usize = parts.iter().map(|p| p.shape[axis]).sum();
        let mut shape = first.shape.clone();
        shape[axis] = total_axis;
        Ok((outer, inner, shape))
    }

    /// Concatenate along `axis`. When `parts` are adjacent views of one
    /// buffer in order (the shard → unshard roundtrip), the parent view
    /// is reassembled without copying; otherwise a gather-copy.
    pub fn concat(parts: &[Self], axis: usize) -> Result<Self> {
        let (outer, _inner, shape) = Self::concat_dims(parts, axis)?;
        if outer == 1 {
            // zero-copy reassembly of adjacent in-order views
            let first = &parts[0];
            let mut off = first.offset;
            let mut adjacent = true;
            for p in parts {
                if !Arc::ptr_eq(&p.buf, &first.buf) || p.offset != off {
                    adjacent = false;
                    break;
                }
                off += p.len();
            }
            if adjacent {
                return Ok(HostTensor {
                    shape,
                    buf: Arc::clone(&first.buf),
                    offset: first.offset,
                });
            }
        }
        // one gather algorithm, shared with the reference so the two
        // paths cannot diverge
        Self::concat_copy(parts, axis)
    }

    /// Reference copying `concat` (always materializes) — kept for the
    /// equivalence property suite and the shard-move bench.
    pub fn concat_copy(parts: &[Self], axis: usize) -> Result<Self> {
        let (outer, inner, shape) = Self::concat_dims(parts, axis)?;
        let mut out = Vec::with_capacity(shape.iter().product());
        for o in 0..outer {
            for p in parts {
                let d = p.shape[axis];
                let base = o * d * inner;
                out.extend_from_slice(&p.data()[base..base + d * inner]);
            }
        }
        HostTensor::new(shape, out)
    }

    /// Elementwise in-place add (for reductions); copy-on-write if the
    /// storage is shared. Dispatches through the active
    /// [`crate::device`] backend.
    pub fn add_assign(&mut self, other: &Self) -> Result<()> {
        if self.shape != other.shape {
            return Err(Error::Shape(format!(
                "add {:?} vs {:?}",
                self.shape, other.shape
            )));
        }
        crate::device::add_assign_tensor(self, other);
        Ok(())
    }

    /// In-place scalar multiply; copy-on-write if the storage is shared.
    /// Dispatches through the active [`crate::device`] backend.
    pub fn scale(&mut self, s: f32) {
        crate::device::scale_tensor(self, s);
    }

    /// Swap the first two axes (needed by inference drivers for z^T
    /// views). Materializes: a transposed run is not contiguous, and its
    /// consumers (literal conversion, kernels) need contiguous data
    /// anyway.
    pub fn transpose01(&self) -> Result<Self> {
        if self.shape.len() < 2 {
            return Err(Error::Shape("transpose01 needs ndim>=2".into()));
        }
        let (d0, d1) = (self.shape[0], self.shape[1]);
        let inner: usize = self.shape[2..].iter().product();
        let src = self.data();
        let mut out = vec![0.0f32; src.len()];
        for i in 0..d0 {
            for j in 0..d1 {
                let s = (i * d1 + j) * inner;
                let d = (j * d0 + i) * inner;
                out[d..d + inner].copy_from_slice(&src[s..s + inner]);
            }
        }
        let mut shape = self.shape.clone();
        shape.swap(0, 1);
        HostTensor::new(shape, out)
    }

    /// Largest elementwise absolute difference vs `other`.
    pub fn max_abs_diff(&self, other: &Self) -> f32 {
        self.data()
            .iter()
            .zip(other.data().iter())
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }

    // ----------------------------------------------------------- kernels

    /// Fused softmax over the last axis (`exp(x·scale − rowmax)`
    /// normalized per row), dispatched through the active
    /// [`crate::device`] backend.
    pub fn softmax_last_axis(&self, scale: f32) -> Result<Self> {
        let cols = *self
            .shape
            .last()
            .ok_or_else(|| Error::Shape("softmax needs ndim >= 1".into()))?;
        if cols == 0 {
            return Err(Error::Shape("softmax over an empty axis".into()));
        }
        let mut out = vec![0.0f32; self.len()];
        crate::device::current().softmax_rows(self.data(), cols, scale, &mut out);
        HostTensor::new(self.shape.clone(), out)
    }

    /// Fused (chunked-Welford) LayerNorm over the last axis, dispatched
    /// through the active [`crate::device`] backend. `gamma`/`beta`
    /// must be rank-1 of the last-axis length.
    pub fn layernorm_last_axis(
        &self,
        gamma: &HostTensor,
        beta: &HostTensor,
        eps: f32,
    ) -> Result<Self> {
        let cols = *self
            .shape
            .last()
            .ok_or_else(|| Error::Shape("layernorm needs ndim >= 1".into()))?;
        if cols == 0 {
            return Err(Error::Shape("layernorm over an empty axis".into()));
        }
        if gamma.shape != [cols] || beta.shape != [cols] {
            return Err(Error::Shape(format!(
                "layernorm gamma {:?} / beta {:?} must be [{cols}]",
                gamma.shape, beta.shape
            )));
        }
        let mut out = vec![0.0f32; self.len()];
        crate::device::current().layernorm_rows(
            self.data(),
            cols,
            gamma.data(),
            beta.data(),
            eps,
            &mut out,
        );
        HostTensor::new(self.shape.clone(), out)
    }

    // ---------------------------------------------------------- literals

    /// Convert to an `xla` literal. Zero-copy (shared `Arc`) when this
    /// tensor spans its whole buffer; a sub-view materializes once.
    pub fn to_literal(&self) -> Result<xla::Literal> {
        let dims: Vec<i64> = self.shape.iter().map(|&d| d as i64).collect();
        if !self.is_view() {
            return Ok(xla::Literal::from_shared(Arc::clone(&self.buf), &dims)?);
        }
        let lit = xla::Literal::vec1(self.data());
        Ok(lit.reshape(&dims)?)
    }

    /// Build from an `xla` literal, sharing its storage (zero-copy).
    pub fn from_literal(lit: &xla::Literal) -> Result<Self> {
        let shape = lit.array_shape()?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        let buf = lit.to_shared::<f32>()?;
        HostTensor::from_shared(dims, buf)
    }
}

/// Integer tensor (token ids, bin labels) — converted to S32 literals.
#[derive(Clone, Debug, PartialEq)]
pub struct IntTensor {
    /// Logical dimensions, outermost first (row-major).
    pub shape: Vec<usize>,
    /// Elements in row-major order.
    pub data: Vec<i32>,
}

impl IntTensor {
    /// Build a tensor owning `data` (element count must match `shape`).
    pub fn new(shape: Vec<usize>, data: Vec<i32>) -> Result<Self> {
        let n: usize = shape.iter().product();
        if n != data.len() {
            return Err(Error::Shape(format!(
                "shape {:?} wants {} elems, got {}",
                shape,
                n,
                data.len()
            )));
        }
        Ok(IntTensor { shape, data })
    }

    /// Convert to an S32 `xla` literal.
    pub fn to_literal(&self) -> Result<xla::Literal> {
        let dims: Vec<i64> = self.shape.iter().map(|&d| d as i64).collect();
        let lit = xla::Literal::vec1(&self.data);
        Ok(lit.reshape(&dims)?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t(shape: &[usize]) -> HostTensor {
        let n: usize = shape.iter().product();
        HostTensor::new(shape.to_vec(), (0..n).map(|i| i as f32).collect()).unwrap()
    }

    #[test]
    fn slice_concat_roundtrip() {
        let x = t(&[4, 6, 3]);
        for axis in 0..3 {
            let n = if axis == 2 { 3 } else { 2 };
            let parts = x.split_axis(axis, n).unwrap();
            let back = HostTensor::concat(&parts, axis).unwrap();
            assert_eq!(back, x, "axis {axis}");
        }
    }

    #[test]
    fn slice_values() {
        let x = t(&[2, 3]);
        let s = x.slice_axis(1, 1, 2).unwrap();
        assert_eq!(s.shape, vec![2, 2]);
        assert_eq!(s.data(), &[1.0, 2.0, 4.0, 5.0][..]);
    }

    #[test]
    fn axis0_slice_is_a_view_and_inner_slice_copies() {
        let x = t(&[4, 3]);
        let v = x.slice_axis(0, 1, 2).unwrap();
        assert!(v.shares_storage(&x), "leading-axis slice must be O(1)");
        assert!(v.is_view());
        assert_eq!(v.data(), &[3.0, 4.0, 5.0, 6.0, 7.0, 8.0][..]);
        let c = x.slice_axis(1, 0, 2).unwrap();
        assert!(!c.shares_storage(&x), "inner slice gathers");
        assert_eq!(c.data(), &[0.0, 1.0, 3.0, 4.0, 6.0, 7.0][..]);
        // identity slice at any axis is a view
        let id = x.slice_axis(1, 0, 3).unwrap();
        assert!(id.shares_storage(&x));
        assert_eq!(id, x);
    }

    #[test]
    fn shard_unshard_roundtrip_is_zero_copy() {
        let x = t(&[8, 5]);
        let parts = x.split_axis(0, 4).unwrap();
        assert!(parts.iter().all(|p| p.shares_storage(&x)));
        let back = HostTensor::concat(&parts, 0).unwrap();
        assert!(back.shares_storage(&x), "adjacent views reassemble free");
        assert_eq!(back, x);
        // out-of-order parts must fall back to the copy path, correctly
        let swapped = vec![parts[1].clone(), parts[0].clone()];
        let y = HostTensor::concat(&swapped, 0).unwrap();
        assert!(!y.shares_storage(&x));
        assert_eq!(y.data()[0], 10.0);
    }

    #[test]
    fn copy_on_write_isolates_views() {
        let x = t(&[4, 2]);
        let mut v = x.slice_axis(0, 0, 2).unwrap();
        assert!(v.shares_storage(&x));
        // lint:allow(backend) — pins the CoW contract itself, not kernel math
        v.data_mut()[0] = 99.0;
        assert!(!v.shares_storage(&x), "mutation must detach the view");
        assert_eq!(x.data()[0], 0.0, "parent unchanged");
        assert_eq!(v.data()[0], 99.0);
        // a uniquely-owned full tensor mutates in place (no realloc)
        let mut u = t(&[3]);
        let before = u.data().as_ptr();
        // lint:allow(backend) — pins the CoW contract itself, not kernel math
        u.data_mut()[1] = 5.0;
        assert_eq!(u.data().as_ptr(), before);
        assert_eq!(u.data(), &[0.0, 5.0, 2.0][..]);
    }

    #[test]
    fn clone_shares_until_mutated() {
        let x = t(&[2, 2]);
        let mut y = x.clone();
        assert!(y.shares_storage(&x));
        y.scale(2.0);
        assert!(!y.shares_storage(&x));
        assert_eq!(x.data(), &[0.0, 1.0, 2.0, 3.0][..]);
        assert_eq!(y.data(), &[0.0, 2.0, 4.0, 6.0][..]);
    }

    #[test]
    fn transpose01_roundtrip() {
        let x = t(&[3, 5, 2]);
        let tt = x.transpose01().unwrap().transpose01().unwrap();
        assert_eq!(tt, x);
        let y = x.transpose01().unwrap();
        assert_eq!(y.shape, vec![5, 3, 2]);
        // spot check element [i=1, j=2] -> [2, 1]
        assert_eq!(y.data()[(2 * 3 + 1) * 2], x.data()[(1 * 5 + 2) * 2]);
    }

    #[test]
    fn add_and_scale() {
        let mut a = t(&[2, 2]);
        let b = t(&[2, 2]);
        a.add_assign(&b).unwrap();
        assert_eq!(a.data(), &[0.0, 2.0, 4.0, 6.0][..]);
        a.scale(0.5);
        assert_eq!(a.data(), &[0.0, 1.0, 2.0, 3.0][..]);
    }

    #[test]
    fn add_assign_on_shared_storage_is_safe() {
        let x = t(&[4]);
        let mut a = x.clone();
        let b = x.clone();
        a.add_assign(&b).unwrap();
        assert_eq!(a.data(), &[0.0, 2.0, 4.0, 6.0][..]);
        assert_eq!(x.data(), &[0.0, 1.0, 2.0, 3.0][..], "source untouched");
    }

    #[test]
    fn shape_errors() {
        let x = t(&[2, 2]);
        assert!(x.slice_axis(2, 0, 1).is_err());
        assert!(x.slice_axis(0, 1, 2).is_err());
        assert!(x.split_axis(0, 3).is_err());
        let y = t(&[3, 2]);
        assert!(HostTensor::concat(&[x.clone(), y], 1).is_err());
        let mut a = t(&[2, 2]);
        assert!(a.add_assign(&t(&[4])).is_err());
    }

    #[test]
    fn literal_roundtrip_shares_storage() {
        let x = t(&[2, 3]);
        let lit = x.to_literal().unwrap();
        let back = HostTensor::from_literal(&lit).unwrap();
        assert_eq!(back, x);
        assert!(back.shares_storage(&x), "full-buffer literal path is zero-copy");
        // a sub-view materializes exactly once on the way in
        let v = x.slice_axis(0, 1, 1).unwrap();
        let lit = v.to_literal().unwrap();
        let back = HostTensor::from_literal(&lit).unwrap();
        assert_eq!(back, v);
    }

    #[test]
    fn kernel_wrappers_normalize() {
        let x = t(&[2, 4]);
        let sm = x.softmax_last_axis(1.0).unwrap();
        for row in sm.data().chunks_exact(4) {
            let s: f32 = row.iter().sum();
            assert!((s - 1.0).abs() < 1e-6, "row sums to {s}");
        }
        let g = HostTensor::full(&[4], 1.0);
        let b = HostTensor::zeros(&[4]);
        let ln = x.layernorm_last_axis(&g, &b, 1e-5).unwrap();
        for row in ln.data().chunks_exact(4) {
            let mean: f32 = row.iter().sum::<f32>() / 4.0;
            assert!(mean.abs() < 1e-5, "row mean {mean}");
        }
        assert!(x.layernorm_last_axis(&HostTensor::zeros(&[3]), &b, 1e-5).is_err());
        assert!(HostTensor::scalar(1.0).softmax_last_axis(1.0).is_err());
    }
}
