//! [`HostTensor`]: the host-side nd-array the coordinator moves between
//! PJRT executions and collectives.
//!
//! Deliberately minimal — row-major f32 (plus an i32 variant for token
//! batches), with exactly the ops the DAP/TP coordinators need: slicing and
//! concatenation along an axis (shard / all_gather / all_to_all), axis
//! splitting, elementwise add (reduce), and (de)serialization to
//! [`xla::Literal`].

use crate::error::{Error, Result};

#[derive(Clone, Debug, PartialEq)]
pub struct HostTensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl HostTensor {
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Result<Self> {
        let n: usize = shape.iter().product();
        if n != data.len() {
            return Err(Error::Shape(format!(
                "shape {:?} wants {} elems, got {}",
                shape,
                n,
                data.len()
            )));
        }
        Ok(HostTensor { shape, data })
    }

    pub fn zeros(shape: &[usize]) -> Self {
        let n = shape.iter().product();
        HostTensor { shape: shape.to_vec(), data: vec![0.0; n] }
    }

    pub fn scalar(v: f32) -> Self {
        HostTensor { shape: vec![], data: vec![v] }
    }

    pub fn full(shape: &[usize], v: f32) -> Self {
        let n = shape.iter().product();
        HostTensor { shape: shape.to_vec(), data: vec![v; n] }
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn ndim(&self) -> usize {
        self.shape.len()
    }

    pub fn size_bytes(&self) -> usize {
        self.data.len() * 4
    }

    /// Row-major strides.
    fn strides(&self) -> Vec<usize> {
        let mut s = vec![1usize; self.shape.len()];
        for i in (0..self.shape.len().saturating_sub(1)).rev() {
            s[i] = s[i + 1] * self.shape[i + 1];
        }
        s
    }

    /// Slice `[start, start+len)` along `axis` (copies).
    pub fn slice_axis(&self, axis: usize, start: usize, len: usize) -> Result<Self> {
        if axis >= self.shape.len() || start + len > self.shape[axis] {
            return Err(Error::Shape(format!(
                "slice axis {axis} [{start}+{len}) of {:?}",
                self.shape
            )));
        }
        let outer: usize = self.shape[..axis].iter().product();
        let inner: usize = self.shape[axis + 1..].iter().product();
        let d = self.shape[axis];
        let mut out = Vec::with_capacity(outer * len * inner);
        for o in 0..outer {
            let base = o * d * inner + start * inner;
            out.extend_from_slice(&self.data[base..base + len * inner]);
        }
        let mut shape = self.shape.clone();
        shape[axis] = len;
        HostTensor::new(shape, out)
    }

    /// Split into `n` equal parts along `axis`.
    pub fn split_axis(&self, axis: usize, n: usize) -> Result<Vec<Self>> {
        if axis >= self.shape.len() || self.shape[axis] % n != 0 {
            return Err(Error::Shape(format!(
                "split axis {axis} of {:?} into {n}",
                self.shape
            )));
        }
        let part = self.shape[axis] / n;
        (0..n).map(|i| self.slice_axis(axis, i * part, part)).collect()
    }

    /// Concatenate along `axis`.
    pub fn concat(parts: &[Self], axis: usize) -> Result<Self> {
        let first = parts.first().ok_or_else(|| Error::Shape("concat of 0 tensors".into()))?;
        let nd = first.shape.len();
        if axis >= nd {
            return Err(Error::Shape(format!("concat axis {axis} of {nd}-d")));
        }
        for p in parts {
            if p.shape.len() != nd
                || p.shape[..axis] != first.shape[..axis]
                || p.shape[axis + 1..] != first.shape[axis + 1..]
            {
                return Err(Error::Shape(format!(
                    "concat mismatch {:?} vs {:?}",
                    p.shape, first.shape
                )));
            }
        }
        let outer: usize = first.shape[..axis].iter().product();
        let inner: usize = first.shape[axis + 1..].iter().product();
        let total_axis: usize = parts.iter().map(|p| p.shape[axis]).sum();
        let mut out = Vec::with_capacity(outer * total_axis * inner);
        for o in 0..outer {
            for p in parts {
                let d = p.shape[axis];
                let base = o * d * inner;
                out.extend_from_slice(&p.data[base..base + d * inner]);
            }
        }
        let mut shape = first.shape.clone();
        shape[axis] = total_axis;
        HostTensor::new(shape, out)
    }

    /// Elementwise in-place add (for reductions).
    pub fn add_assign(&mut self, other: &Self) -> Result<()> {
        if self.shape != other.shape {
            return Err(Error::Shape(format!(
                "add {:?} vs {:?}",
                self.shape, other.shape
            )));
        }
        for (a, b) in self.data.iter_mut().zip(other.data.iter()) {
            *a += b;
        }
        Ok(())
    }

    pub fn scale(&mut self, s: f32) {
        for a in self.data.iter_mut() {
            *a *= s;
        }
    }

    /// Swap the first two axes (needed by inference drivers for z^T views).
    pub fn transpose01(&self) -> Result<Self> {
        if self.shape.len() < 2 {
            return Err(Error::Shape("transpose01 needs ndim>=2".into()));
        }
        let (d0, d1) = (self.shape[0], self.shape[1]);
        let inner: usize = self.shape[2..].iter().product();
        let mut out = vec![0.0f32; self.data.len()];
        for i in 0..d0 {
            for j in 0..d1 {
                let src = (i * d1 + j) * inner;
                let dst = (j * d0 + i) * inner;
                out[dst..dst + inner].copy_from_slice(&self.data[src..src + inner]);
            }
        }
        let mut shape = self.shape.clone();
        shape.swap(0, 1);
        HostTensor::new(shape, out)
    }

    pub fn max_abs_diff(&self, other: &Self) -> f32 {
        self.data
            .iter()
            .zip(other.data.iter())
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }

    // ---------------------------------------------------------- literals

    pub fn to_literal(&self) -> Result<xla::Literal> {
        let dims: Vec<i64> = self.shape.iter().map(|&d| d as i64).collect();
        let lit = xla::Literal::vec1(&self.data);
        Ok(lit.reshape(&dims)?)
    }

    pub fn from_literal(lit: &xla::Literal) -> Result<Self> {
        let shape = lit.array_shape()?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        let data = lit.to_vec::<f32>()?;
        HostTensor::new(dims, data)
    }

    fn _strides_doc() {
        // strides() kept private; exposed ops cover coordinator needs.
        let _ = HostTensor::zeros(&[1]).strides();
    }
}

/// Integer tensor (token ids, bin labels) — converted to S32 literals.
#[derive(Clone, Debug, PartialEq)]
pub struct IntTensor {
    pub shape: Vec<usize>,
    pub data: Vec<i32>,
}

impl IntTensor {
    pub fn new(shape: Vec<usize>, data: Vec<i32>) -> Result<Self> {
        let n: usize = shape.iter().product();
        if n != data.len() {
            return Err(Error::Shape(format!(
                "shape {:?} wants {} elems, got {}",
                shape,
                n,
                data.len()
            )));
        }
        Ok(IntTensor { shape, data })
    }

    pub fn to_literal(&self) -> Result<xla::Literal> {
        let dims: Vec<i64> = self.shape.iter().map(|&d| d as i64).collect();
        let lit = xla::Literal::vec1(&self.data);
        Ok(lit.reshape(&dims)?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t(shape: &[usize]) -> HostTensor {
        let n: usize = shape.iter().product();
        HostTensor::new(shape.to_vec(), (0..n).map(|i| i as f32).collect()).unwrap()
    }

    #[test]
    fn slice_concat_roundtrip() {
        let x = t(&[4, 6, 3]);
        for axis in 0..3 {
            let n = if axis == 2 { 3 } else { 2 };
            let parts = x.split_axis(axis, n).unwrap();
            let back = HostTensor::concat(&parts, axis).unwrap();
            assert_eq!(back, x, "axis {axis}");
        }
    }

    #[test]
    fn slice_values() {
        let x = t(&[2, 3]);
        let s = x.slice_axis(1, 1, 2).unwrap();
        assert_eq!(s.shape, vec![2, 2]);
        assert_eq!(s.data, vec![1.0, 2.0, 4.0, 5.0]);
    }

    #[test]
    fn transpose01_roundtrip() {
        let x = t(&[3, 5, 2]);
        let tt = x.transpose01().unwrap().transpose01().unwrap();
        assert_eq!(tt, x);
        let y = x.transpose01().unwrap();
        assert_eq!(y.shape, vec![5, 3, 2]);
        // spot check element [i=1, j=2] -> [2, 1]
        assert_eq!(y.data[(2 * 3 + 1) * 2], x.data[(1 * 5 + 2) * 2]);
    }

    #[test]
    fn add_and_scale() {
        let mut a = t(&[2, 2]);
        let b = t(&[2, 2]);
        a.add_assign(&b).unwrap();
        assert_eq!(a.data, vec![0.0, 2.0, 4.0, 6.0]);
        a.scale(0.5);
        assert_eq!(a.data, vec![0.0, 1.0, 2.0, 3.0]);
    }

    #[test]
    fn shape_errors() {
        let x = t(&[2, 2]);
        assert!(x.slice_axis(2, 0, 1).is_err());
        assert!(x.slice_axis(0, 1, 2).is_err());
        assert!(x.split_axis(0, 3).is_err());
        let y = t(&[3, 2]);
        assert!(HostTensor::concat(&[x.clone(), y], 1).is_err());
        let mut a = t(&[2, 2]);
        assert!(a.add_assign(&t(&[4])).is_err());
    }
}
