//! Collective communication substrate.
//!
//! The paper's testbed runs NCCL over NVLink/IB; here N ranks are *logical
//! devices* of a single-process simulation (DESIGN.md §2 substitution), so
//! collectives are exact host-tensor operations over `Vec<HostTensor>`
//! (index = rank). Every call is logged with op kind + per-rank byte volume
//! so (a) Table III comm counts are measured, not asserted, and (b) the
//! α–β performance model can price any recorded timeline.
//!
//! `ring` contains a real ring all-reduce (2(N−1) chunk steps) — the
//! algorithm the DP gradient reduction models — validated against the
//! naive sum.
//!
//! Threading: the log is shared behind `Arc<Mutex<…>>`, so a `Collectives`
//! clone can be handed to rank worker threads and to the dedicated
//! [`worker::CommWorker`] thread that runs Duality-Async collectives off
//! the compute path.

pub mod log;
pub mod ring;
pub mod worker;

use crate::error::{Error, Result};
use crate::tensor::HostTensor;
pub use log::{CommKind, CommLog, CommRecord};
use std::sync::{Arc, Mutex};

/// Collective engine over logical ranks. Cheap to clone (shared log);
/// `Send + Sync`, so clones may issue collectives from any thread.
#[derive(Clone)]
pub struct Collectives {
    pub n: usize,
    pub log: Arc<Mutex<CommLog>>,
}

impl Collectives {
    pub fn new(n: usize) -> Self {
        Collectives { n, log: Arc::new(Mutex::new(CommLog::default())) }
    }

    fn check(&self, parts: &[HostTensor], what: &str) -> Result<()> {
        if parts.len() != self.n {
            return Err(Error::Comm(format!(
                "{what}: {} shards for {} ranks",
                parts.len(),
                self.n
            )));
        }
        Ok(())
    }

    /// Each rank contributes its shard; all ranks receive the concatenation
    /// along `axis`. Per-rank send volume: own shard to N−1 peers (ring:
    /// (N−1)/N of the full tensor transits each link).
    pub fn all_gather(&self, parts: &[HostTensor], axis: usize) -> Result<Vec<HostTensor>> {
        self.check(parts, "all_gather")?;
        let full = HostTensor::concat(parts, axis)?;
        let bytes = full.size_bytes() * (self.n - 1) / self.n.max(1);
        self.log.lock().unwrap().record(CommKind::AllGather, bytes, full.size_bytes());
        Ok(vec![full; self.n])
    }

    /// Each rank contributes a FULL partial tensor; rank k receives the
    /// k-th slice (along `axis`) of the elementwise sum.
    pub fn reduce_scatter(&self, parts: &[HostTensor], axis: usize) -> Result<Vec<HostTensor>> {
        self.check(parts, "reduce_scatter")?;
        let mut total = parts[0].clone();
        for p in &parts[1..] {
            total.add_assign(p)?;
        }
        let bytes = total.size_bytes() * (self.n - 1) / self.n.max(1);
        self.log.lock().unwrap().record(CommKind::ReduceScatter, bytes, total.size_bytes());
        total.split_axis(axis, self.n)
    }

    /// Each rank splits its local tensor along `split`, sends part p to
    /// rank p, and concatenates what it receives along `concat`.
    ///
    /// The wire volume is priced per rank from the (validated-uniform)
    /// local shard size: pricing from `parts[0]` alone would silently
    /// mis-account a ragged input, so non-uniform shard shapes are an
    /// error here even when concat could geometrically absorb them.
    pub fn all_to_all(
        &self,
        parts: &[HostTensor],
        split: usize,
        concat: usize,
    ) -> Result<Vec<HostTensor>> {
        self.check(parts, "all_to_all")?;
        if let Some(bad) = parts.iter().position(|p| p.shape != parts[0].shape) {
            return Err(Error::Comm(format!(
                "all_to_all: non-uniform shard shapes: rank 0 has {:?} but \
                 rank {bad} has {:?}",
                parts[0].shape, parts[bad].shape
            )));
        }
        let mut split_parts: Vec<Vec<HostTensor>> = Vec::with_capacity(self.n);
        for p in parts {
            split_parts.push(p.split_axis(split, self.n)?);
        }
        let mut out = Vec::with_capacity(self.n);
        for dst in 0..self.n {
            let recv: Vec<HostTensor> =
                (0..self.n).map(|src| split_parts[src][dst].clone()).collect();
            out.push(HostTensor::concat(&recv, concat)?);
        }
        // per-rank volume: local tensor minus the self-part stays put
        let local = parts[0].size_bytes();
        let bytes = local * (self.n - 1) / self.n.max(1);
        self.log.lock().unwrap().record(CommKind::AllToAll, bytes, local);
        Ok(out)
    }

    /// Sum across ranks; every rank receives the full sum (ring volume:
    /// 2(N−1)/N of the tensor per rank).
    pub fn all_reduce(&self, parts: &[HostTensor]) -> Result<Vec<HostTensor>> {
        self.check(parts, "all_reduce")?;
        let mut total = parts[0].clone();
        for p in &parts[1..] {
            total.add_assign(p)?;
        }
        let bytes = total.size_bytes() * 2 * (self.n - 1) / self.n.max(1);
        self.log.lock().unwrap().record(CommKind::AllReduce, bytes, total.size_bytes());
        Ok(vec![total; self.n])
    }

    /// Rank `root`'s tensor to everyone.
    pub fn broadcast(&self, parts: &[HostTensor], root: usize) -> Result<Vec<HostTensor>> {
        self.check(parts, "broadcast")?;
        let t = parts[root].clone();
        let bytes = t.size_bytes();
        self.log.lock().unwrap().record(CommKind::Broadcast, bytes, bytes);
        Ok(vec![t; self.n])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn shards(n: usize, per: usize) -> Vec<HostTensor> {
        (0..n)
            .map(|r| {
                HostTensor::new(
                    vec![per, 2],
                    (0..per * 2).map(|i| (r * 100 + i) as f32).collect(),
                )
                .unwrap()
            })
            .collect()
    }

    #[test]
    fn all_gather_concats() {
        let c = Collectives::new(3);
        let out = c.all_gather(&shards(3, 2), 0).unwrap();
        assert_eq!(out.len(), 3);
        assert_eq!(out[0].shape, vec![6, 2]);
        assert_eq!(out[0], out[2]);
        assert_eq!(out[0].data()[0], 0.0);
        assert_eq!(out[0].data()[4], 100.0);
    }

    #[test]
    fn reduce_scatter_sums_and_splits() {
        let c = Collectives::new(2);
        let full: Vec<HostTensor> = (0..2)
            .map(|r| HostTensor::full(&[4, 2], (r + 1) as f32))
            .collect();
        let out = c.reduce_scatter(&full, 0).unwrap();
        assert_eq!(out[0].shape, vec![2, 2]);
        assert!(out.iter().all(|t| t.data().iter().all(|&x| x == 3.0)));
    }

    #[test]
    fn all_to_all_inverse() {
        let c = Collectives::new(4);
        let parts: Vec<HostTensor> = (0..4)
            .map(|r| {
                HostTensor::new(
                    vec![2, 8],
                    (0..16).map(|i| (r * 16 + i) as f32).collect(),
                )
                .unwrap()
            })
            .collect();
        let fwd = c.all_to_all(&parts, 1, 0).unwrap(); // (2,8)->(8,2)
        assert_eq!(fwd[0].shape, vec![8, 2]);
        let back = c.all_to_all(&fwd, 0, 1).unwrap();
        for (a, b) in back.iter().zip(parts.iter()) {
            assert_eq!(a, b);
        }
    }

    #[test]
    fn all_reduce_matches_sum() {
        let c = Collectives::new(3);
        let parts = shards(3, 2);
        let out = c.all_reduce(&parts).unwrap();
        let want: Vec<f32> = (0..4)
            .map(|i| (0..3).map(|r| (r * 100 + i) as f32).sum())
            .collect();
        assert_eq!(out[1].data(), want.as_slice());
    }

    #[test]
    fn log_records_volume() {
        let c = Collectives::new(2);
        c.all_gather(&shards(2, 2), 0).unwrap();
        c.all_reduce(&shards(2, 2)).unwrap();
        let log = c.log.lock().unwrap();
        assert_eq!(log.count(CommKind::AllGather), 1);
        assert_eq!(log.count(CommKind::AllReduce), 1);
        assert!(log.total_bytes() > 0);
    }

    #[test]
    fn all_to_all_rejects_nonuniform_shards() {
        // wire volume is priced from the local shard size, so a ragged
        // input must be an error, not a silently mispriced transfer
        let c = Collectives::new(2);
        let parts = vec![
            HostTensor::full(&[2, 4], 1.0),
            HostTensor::full(&[2, 6], 1.0),
        ];
        let err = c.all_to_all(&parts, 1, 0).unwrap_err();
        assert!(err.to_string().contains("non-uniform"), "{err}");
        // and nothing was logged for the failed collective
        assert_eq!(c.log.lock().unwrap().len(), 0);
    }

    #[test]
    fn rank_count_enforced() {
        let c = Collectives::new(3);
        assert!(c.all_gather(&shards(2, 2), 0).is_err());
    }
}
