//! Explicit ring all-reduce: the algorithm behind the data-parallel
//! gradient reduction whose cost model drives Fig 11.
//!
//! Phase 1 (reduce-scatter): N−1 steps; in step s, rank r sends chunk
//! (r−s) mod N to rank r+1 and accumulates what it receives.
//! Phase 2 (all-gather): N−1 steps circulating the finished chunks.
//! Per-rank wire volume: ≈ 2(N−1)/N × size — the constant the α–β model
//! uses — measured here *exactly* per rank, because with a non-divisible
//! length the remainder-absorbing last chunk makes ranks unequal: over the
//! 2(N−1) steps, rank r sends every chunk except (r+1) mod N in phase 1
//! and every chunk except (r+2) mod N in phase 2, so ranks that skip the
//! big chunk move fewer bytes than ranks that skip a base chunk.
//!
//! Allocation behavior: each of the 2(N−1) steps needs a snapshot of the
//! chunks in flight (the exchange is simultaneous, so in-place
//! accumulation without a snapshot would let rank r's update feed rank
//! r+1 within the same step). The snapshot lives in **one reusable
//! scratch buffer** (N × max-chunk elements) allocated once per call —
//! the old implementation allocated N fresh `Vec`s per step, 2N(N−1)
//! allocations per reduction, on the trainer's per-step hot path.

use crate::error::{Error, Result};

/// Run ring all-reduce over per-rank flat vectors (in place, returns sums).
/// Also returns the wire bytes actually sent by each rank, so tests can
/// verify the 2(N−1)/N volume formula the perf model assumes and callers
/// can account the critical-path (max) rank honestly. The old truncating
/// `total / n` average hid the per-rank skew at non-divisible lengths.
pub fn ring_all_reduce(mut ranks: Vec<Vec<f32>>) -> Result<(Vec<Vec<f32>>, Vec<usize>)> {
    let n = ranks.len();
    if n == 0 {
        return Err(Error::Comm("ring over 0 ranks".into()));
    }
    let len = ranks[0].len();
    if ranks.iter().any(|r| r.len() != len) {
        return Err(Error::Comm("ring shards differ in length".into()));
    }
    if n == 1 {
        return Ok((ranks, vec![0]));
    }
    // chunk boundaries (last chunk absorbs the remainder)
    let base = len / n;
    let bounds: Vec<(usize, usize)> = (0..n)
        .map(|c| (c * base, if c == n - 1 { len } else { (c + 1) * base }))
        .collect();
    let max_chunk = bounds.iter().map(|&(lo, hi)| hi - lo).max().unwrap_or(0);
    let mut wire = vec![0usize; n];
    // one scratch for all 2(N−1) per-step snapshots: lane r holds the
    // chunk rank r sends this step
    let mut scratch = vec![0.0f32; n * max_chunk];

    // phase 1: reduce-scatter
    for s in 0..n - 1 {
        // snapshot the chunks being sent this step (simultaneous exchange)
        for r in 0..n {
            let c = (r + n - s) % n;
            let (lo, hi) = bounds[c];
            scratch[r * max_chunk..r * max_chunk + (hi - lo)]
                .copy_from_slice(&ranks[r][lo..hi]);
        }
        for r in 0..n {
            let dst = (r + 1) % n;
            let c = (r + n - s) % n;
            let (lo, hi) = bounds[c];
            let sent = &scratch[r * max_chunk..r * max_chunk + (hi - lo)];
            // the accumulate is the collective's kernel entry point:
            // dispatch through the device plane (bit-for-bit on every
            // backend — elementwise add)
            crate::device::current().add_assign(&mut ranks[dst][lo..hi], sent);
            wire[r] += (hi - lo) * 4;
        }
    }
    // phase 2: all-gather of finished chunks
    for s in 0..n - 1 {
        for r in 0..n {
            let c = (r + 1 + n - s) % n;
            let (lo, hi) = bounds[c];
            scratch[r * max_chunk..r * max_chunk + (hi - lo)]
                .copy_from_slice(&ranks[r][lo..hi]);
        }
        for r in 0..n {
            let dst = (r + 1) % n;
            let c = (r + 1 + n - s) % n;
            let (lo, hi) = bounds[c];
            ranks[dst][lo..hi]
                .copy_from_slice(&scratch[r * max_chunk..r * max_chunk + (hi - lo)]);
            wire[r] += (hi - lo) * 4;
        }
    }
    Ok((ranks, wire))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    /// The pre-scratch reference implementation (per-step `Vec`
    /// snapshots), kept verbatim so the scratch-buffer rewrite is pinned
    /// against it — values *and* per-rank wire accounting.
    fn ring_all_reduce_ref(
        mut ranks: Vec<Vec<f32>>,
    ) -> Result<(Vec<Vec<f32>>, Vec<usize>)> {
        let n = ranks.len();
        if n == 0 {
            return Err(Error::Comm("ring over 0 ranks".into()));
        }
        let len = ranks[0].len();
        if n == 1 {
            return Ok((ranks, vec![0]));
        }
        let base = len / n;
        let bounds: Vec<(usize, usize)> = (0..n)
            .map(|c| (c * base, if c == n - 1 { len } else { (c + 1) * base }))
            .collect();
        let mut wire = vec![0usize; n];
        for s in 0..n - 1 {
            let sends: Vec<(usize, Vec<f32>)> = (0..n)
                .map(|r| {
                    let c = (r + n - s) % n;
                    let (lo, hi) = bounds[c];
                    (c, ranks[r][lo..hi].to_vec())
                })
                .collect();
            for r in 0..n {
                let dst = (r + 1) % n;
                let (c, ref chunk) = sends[r];
                let (lo, _hi) = bounds[c];
                for (i, v) in chunk.iter().enumerate() {
                    ranks[dst][lo + i] += v;
                }
                wire[r] += chunk.len() * 4;
            }
        }
        for s in 0..n - 1 {
            let sends: Vec<(usize, Vec<f32>)> = (0..n)
                .map(|r| {
                    let c = (r + 1 + n - s) % n;
                    let (lo, hi) = bounds[c];
                    (c, ranks[r][lo..hi].to_vec())
                })
                .collect();
            for r in 0..n {
                let dst = (r + 1) % n;
                let (c, ref chunk) = sends[r];
                let (lo, _hi) = bounds[c];
                ranks[dst][lo..lo + chunk.len()].copy_from_slice(chunk);
                wire[r] += chunk.len() * 4;
            }
        }
        Ok((ranks, wire))
    }

    #[test]
    fn matches_naive_sum() {
        let mut rng = Rng::new(5);
        for &(n, len) in &[(2usize, 8usize), (3, 10), (4, 64), (5, 7), (8, 33)] {
            let ranks: Vec<Vec<f32>> = (0..n)
                .map(|_| rng.normal_vec(len, 1.0))
                .collect();
            let want: Vec<f32> = (0..len)
                .map(|i| ranks.iter().map(|r| r[i]).sum::<f32>())
                .collect();
            let (got, _) = ring_all_reduce(ranks).unwrap();
            for r in &got {
                for (a, b) in r.iter().zip(want.iter()) {
                    assert!((a - b).abs() < 1e-4, "n={n} len={len}");
                }
            }
        }
    }

    #[test]
    fn scratch_rewrite_is_bitwise_the_reference() {
        // the scratch-buffer rewrite must not change a single bit of the
        // result or a single byte of the per-rank wire accounting —
        // including the non-divisible-length skew cases
        let mut rng = Rng::new(55);
        for &(n, len) in &[
            (2usize, 8usize),
            (3, 10),
            (4, 64),
            (5, 7),
            (8, 33),
            (7, 1),
            (6, 6),
        ] {
            let ranks: Vec<Vec<f32>> = (0..n)
                .map(|_| rng.normal_vec(len, 1.0))
                .collect();
            let (got, wire) = ring_all_reduce(ranks.clone()).unwrap();
            let (want, wire_ref) = ring_all_reduce_ref(ranks).unwrap();
            assert_eq!(wire, wire_ref, "wire skew changed: n={n} len={len}");
            for (a, b) in got.iter().zip(want.iter()) {
                for (x, y) in a.iter().zip(b.iter()) {
                    assert_eq!(x.to_bits(), y.to_bits(), "n={n} len={len}");
                }
            }
        }
    }

    #[test]
    fn wire_volume_formula() {
        // divisible length: every rank sends exactly 2(N−1)/N × size_bytes
        let n = 4;
        let len = 1024;
        let ranks: Vec<Vec<f32>> = (0..n).map(|_| vec![1.0; len]).collect();
        let (_, wire) = ring_all_reduce(ranks).unwrap();
        let expect = 2 * (n - 1) * len * 4 / n;
        assert_eq!(wire, vec![expect; n]);
    }

    #[test]
    fn wire_volume_exact_at_non_divisible_length() {
        // len=33, n=8: base chunk 4 elems, last chunk 5. The old
        // accounting truncated total/n to a single flat 231 B/rank; the
        // true per-rank volumes are skewed by which chunk a rank skips.
        let (n, len) = (8usize, 33usize);
        let ranks: Vec<Vec<f32>> = (0..n).map(|_| vec![1.0; len]).collect();
        let (_, wire) = ring_all_reduce(ranks).unwrap();
        let base = len / n;
        let chunk_bytes =
            |c: usize| 4 * if c == n - 1 { len - (n - 1) * base } else { base };
        // rank r skips chunk (r+1)%n in phase 1 and (r+2)%n in phase 2
        let expect: Vec<usize> = (0..n)
            .map(|r| {
                2 * len * 4 - chunk_bytes((r + 1) % n) - chunk_bytes((r + 2) % n)
            })
            .collect();
        assert_eq!(wire, expect);
        // totals conserved: every chunk crosses every link once per phase
        assert_eq!(wire.iter().sum::<usize>(), 2 * (n - 1) * len * 4);
        // the skew the old `total / n` average hid
        assert!(wire.iter().any(|&w| w != wire[0]));
    }

    #[test]
    fn single_rank_noop() {
        let (out, wire) = ring_all_reduce(vec![vec![3.0, 4.0]]).unwrap();
        assert_eq!(out[0], vec![3.0, 4.0]);
        assert_eq!(wire, vec![0]);
    }

    #[test]
    fn mismatched_lengths_rejected() {
        assert!(ring_all_reduce(vec![vec![1.0], vec![1.0, 2.0]]).is_err());
    }
}
