//! Explicit ring all-reduce: the algorithm behind the data-parallel
//! gradient reduction whose cost model drives Fig 11.
//!
//! Phase 1 (reduce-scatter): N−1 steps; in step s, rank r sends chunk
//! (r−s) mod N to rank r+1 and accumulates what it receives.
//! Phase 2 (all-gather): N−1 steps circulating the finished chunks.
//! Per-rank wire volume: ≈ 2(N−1)/N × size — the constant the α–β model
//! uses — measured here *exactly* per rank, because with a non-divisible
//! length the remainder-absorbing last chunk makes ranks unequal: over the
//! 2(N−1) steps, rank r sends every chunk except (r+1) mod N in phase 1
//! and every chunk except (r+2) mod N in phase 2, so ranks that skip the
//! big chunk move fewer bytes than ranks that skip a base chunk.
//!
//! Allocation behavior: each of the 2(N−1) steps needs a snapshot of the
//! chunks in flight (the exchange is simultaneous, so in-place
//! accumulation without a snapshot would let rank r's update feed rank
//! r+1 within the same step). The snapshot lives in a [`RingScratch`]
//! buffer (N × max-chunk elements). [`ring_all_reduce`] allocates one per
//! call; the bucketed-overlap trainer instead owns a single `RingScratch`
//! and calls [`ring_all_reduce_with_scratch`] so **every bucket of every
//! step reuses one allocation** (asserted by the train bench via
//! [`RingScratch::allocs`]).
//!
//! Wire precision: [`ring_all_reduce_bf16_with_scratch`] emulates a
//! bf16-on-the-wire reduction — every chunk crosses a link as packed
//! `u16` bf16 halves (2 B/elem, half the f32 wire), receivers accumulate
//! into f32, and finished chunks are rounded to the bf16 grid before the
//! all-gather phase so every rank ends bit-for-bit identical.

use crate::error::{Error, Result};

/// Reusable snapshot buffers for the ring reductions.
///
/// Grows monotonically to the largest request and never shrinks, so a
/// trainer that reduces many gradient buckets per step pays for at most
/// one f32 (and, under bf16, one u16) allocation over its whole run —
/// `allocs()` counts the grows so benches can assert exactly that.
#[derive(Debug, Default)]
pub struct RingScratch {
    f32_buf: Vec<f32>,
    u16_buf: Vec<u16>,
    allocs: usize,
}

impl RingScratch {
    /// Empty scratch; buffers are grown on first use.
    pub fn new() -> Self {
        Self::default()
    }

    /// How many times a buffer had to be (re)allocated. A warm scratch
    /// sized by its largest bucket stays constant across further calls.
    pub fn allocs(&self) -> usize {
        self.allocs
    }

    fn f32_lanes(&mut self, elems: usize) -> &mut [f32] {
        if self.f32_buf.len() < elems {
            self.f32_buf = vec![0.0; elems];
            self.allocs += 1;
        }
        &mut self.f32_buf[..elems]
    }

    fn u16_lanes(&mut self, elems: usize) -> &mut [u16] {
        if self.u16_buf.len() < elems {
            self.u16_buf = vec![0; elems];
            self.allocs += 1;
        }
        &mut self.u16_buf[..elems]
    }
}

/// Chunk boundaries for a length-`len` vector over `n` ranks (the last
/// chunk absorbs the remainder). Returns `(bounds, max_chunk)`.
fn chunk_bounds(len: usize, n: usize) -> (Vec<(usize, usize)>, usize) {
    let base = len / n;
    let bounds: Vec<(usize, usize)> = (0..n)
        .map(|c| (c * base, if c == n - 1 { len } else { (c + 1) * base }))
        .collect();
    let max_chunk = bounds.iter().map(|&(lo, hi)| hi - lo).max().unwrap_or(0);
    (bounds, max_chunk)
}

fn check_ranks(ranks: &[Vec<f32>]) -> Result<usize> {
    if ranks.is_empty() {
        return Err(Error::Comm("ring over 0 ranks".into()));
    }
    let len = ranks[0].len();
    if ranks.iter().any(|r| r.len() != len) {
        return Err(Error::Comm("ring shards differ in length".into()));
    }
    Ok(len)
}

/// CRC-32 of one rank's flat wire payload (little-endian f32 bytes) —
/// the checksum the fault-tolerant DP reduce stamps on each rank's
/// gradient payload before it enters the ring. A receiver that computes
/// a different CRC over what arrived discards the transfer and asks for
/// a retransmit instead of folding corrupted bytes into every replica
/// (see the trainer's corrupt-payload handling and
/// [`crate::faults::FaultKind::CorruptPayload`]).
pub fn payload_crc32(part: &[f32]) -> u32 {
    crate::faults::crc32_f32(part)
}

/// Run ring all-reduce over per-rank flat vectors (in place, returns sums).
/// Also returns the wire bytes actually sent by each rank, so tests can
/// verify the 2(N−1)/N volume formula the perf model assumes and callers
/// can account the critical-path (max) rank honestly. The old truncating
/// `total / n` average hid the per-rank skew at non-divisible lengths.
///
/// Allocates a fresh [`RingScratch`] per call; hot paths that reduce many
/// buckets should hold one and call [`ring_all_reduce_with_scratch`].
pub fn ring_all_reduce(ranks: Vec<Vec<f32>>) -> Result<(Vec<Vec<f32>>, Vec<usize>)> {
    let mut scratch = RingScratch::new();
    ring_all_reduce_with_scratch(ranks, &mut scratch)
}

/// [`ring_all_reduce`] against a caller-owned [`RingScratch`] — bitwise
/// the same result and wire accounting, zero allocations once the
/// scratch has warmed to the largest reduction it has seen.
pub fn ring_all_reduce_with_scratch(
    mut ranks: Vec<Vec<f32>>,
    scratch: &mut RingScratch,
) -> Result<(Vec<Vec<f32>>, Vec<usize>)> {
    let n = ranks.len();
    let len = check_ranks(&ranks)?;
    if n == 1 {
        return Ok((ranks, vec![0]));
    }
    let (bounds, max_chunk) = chunk_bounds(len, n);
    let mut wire = vec![0usize; n];
    // one scratch for all 2(N−1) per-step snapshots: lane r holds the
    // chunk rank r sends this step
    let lanes = scratch.f32_lanes(n * max_chunk);

    // phase 1: reduce-scatter
    for s in 0..n - 1 {
        // snapshot the chunks being sent this step (simultaneous exchange)
        for r in 0..n {
            let c = (r + n - s) % n;
            let (lo, hi) = bounds[c];
            lanes[r * max_chunk..r * max_chunk + (hi - lo)]
                .copy_from_slice(&ranks[r][lo..hi]);
        }
        for r in 0..n {
            let dst = (r + 1) % n;
            let c = (r + n - s) % n;
            let (lo, hi) = bounds[c];
            let sent = &lanes[r * max_chunk..r * max_chunk + (hi - lo)];
            // the accumulate is the collective's kernel entry point:
            // dispatch through the device plane (bit-for-bit on every
            // backend — elementwise add)
            crate::device::current().add_assign(&mut ranks[dst][lo..hi], sent);
            wire[r] += (hi - lo) * 4;
        }
    }
    // phase 2: all-gather of finished chunks
    for s in 0..n - 1 {
        for r in 0..n {
            let c = (r + 1 + n - s) % n;
            let (lo, hi) = bounds[c];
            lanes[r * max_chunk..r * max_chunk + (hi - lo)]
                .copy_from_slice(&ranks[r][lo..hi]);
        }
        for r in 0..n {
            let dst = (r + 1) % n;
            let c = (r + 1 + n - s) % n;
            let (lo, hi) = bounds[c];
            ranks[dst][lo..hi]
                .copy_from_slice(&lanes[r * max_chunk..r * max_chunk + (hi - lo)]);
            wire[r] += (hi - lo) * 4;
        }
    }
    Ok((ranks, wire))
}

/// Ring all-reduce with **bf16 wire emulation**: the same 2(N−1)-step
/// schedule, but every chunk crosses a link as packed bf16 halves
/// (2 B/elem — wire bytes are exactly half the f32 path's), receivers
/// accumulate `f32 += unpack(bf16)` through the device plane, and each
/// rank rounds its finished chunk to the bf16 grid before the all-gather
/// circulates it (pack → unpack of on-grid values is exact), so all
/// ranks end bitwise identical.
///
/// Like real bf16 collectives, intermediate partial sums are rounded at
/// every hop — the result is deterministic but not the f32 sum; callers
/// opt in via `--precision bf16` and compare losses to f32 by tolerance.
///
/// With a single rank the values are still rounded to the bf16 grid, so
/// dp=1 bf16 runs see the same storage precision as dp>1.
pub fn ring_all_reduce_bf16_with_scratch(
    mut ranks: Vec<Vec<f32>>,
    scratch: &mut RingScratch,
) -> Result<(Vec<Vec<f32>>, Vec<usize>)> {
    let n = ranks.len();
    let len = check_ranks(&ranks)?;
    let dev = crate::device::current();
    if n == 1 {
        dev.bf16_round(&mut ranks[0]);
        return Ok((ranks, vec![0]));
    }
    let (bounds, max_chunk) = chunk_bounds(len, n);
    let mut wire = vec![0usize; n];
    // lane r holds the packed bf16 chunk rank r sends this step
    let lanes = scratch.u16_lanes(n * max_chunk);

    // phase 1: reduce-scatter over a bf16 wire, f32 accumulators
    for s in 0..n - 1 {
        for r in 0..n {
            let c = (r + n - s) % n;
            let (lo, hi) = bounds[c];
            dev.bf16_pack(
                &ranks[r][lo..hi],
                &mut lanes[r * max_chunk..r * max_chunk + (hi - lo)],
            );
        }
        for r in 0..n {
            let dst = (r + 1) % n;
            let c = (r + n - s) % n;
            let (lo, hi) = bounds[c];
            let sent = &lanes[r * max_chunk..r * max_chunk + (hi - lo)];
            dev.add_assign_bf16(&mut ranks[dst][lo..hi], sent);
            wire[r] += (hi - lo) * 2;
        }
    }
    // after reduce-scatter, rank r owns the fully-reduced chunk (r+1)%n;
    // round it to the bf16 grid so the gather below is exact and every
    // rank lands on identical bits
    for (r, rank) in ranks.iter_mut().enumerate() {
        let (lo, hi) = bounds[(r + 1) % n];
        dev.bf16_round(&mut rank[lo..hi]);
    }
    // phase 2: all-gather of finished (on-grid) chunks over the bf16 wire
    for s in 0..n - 1 {
        for r in 0..n {
            let c = (r + 1 + n - s) % n;
            let (lo, hi) = bounds[c];
            dev.bf16_pack(
                &ranks[r][lo..hi],
                &mut lanes[r * max_chunk..r * max_chunk + (hi - lo)],
            );
        }
        for r in 0..n {
            let dst = (r + 1) % n;
            let c = (r + 1 + n - s) % n;
            let (lo, hi) = bounds[c];
            dev.bf16_unpack(
                &lanes[r * max_chunk..r * max_chunk + (hi - lo)],
                &mut ranks[dst][lo..hi],
            );
            wire[r] += (hi - lo) * 2;
        }
    }
    Ok((ranks, wire))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    /// The pre-scratch reference implementation (per-step `Vec`
    /// snapshots), kept verbatim so the scratch-buffer rewrite is pinned
    /// against it — values *and* per-rank wire accounting.
    fn ring_all_reduce_ref(
        mut ranks: Vec<Vec<f32>>,
    ) -> Result<(Vec<Vec<f32>>, Vec<usize>)> {
        let n = ranks.len();
        if n == 0 {
            return Err(Error::Comm("ring over 0 ranks".into()));
        }
        let len = ranks[0].len();
        if n == 1 {
            return Ok((ranks, vec![0]));
        }
        let base = len / n;
        let bounds: Vec<(usize, usize)> = (0..n)
            .map(|c| (c * base, if c == n - 1 { len } else { (c + 1) * base }))
            .collect();
        let mut wire = vec![0usize; n];
        for s in 0..n - 1 {
            let sends: Vec<(usize, Vec<f32>)> = (0..n)
                .map(|r| {
                    let c = (r + n - s) % n;
                    let (lo, hi) = bounds[c];
                    (c, ranks[r][lo..hi].to_vec())
                })
                .collect();
            for r in 0..n {
                let dst = (r + 1) % n;
                let (c, ref chunk) = sends[r];
                let (lo, _hi) = bounds[c];
                for (i, v) in chunk.iter().enumerate() {
                    ranks[dst][lo + i] += v;
                }
                wire[r] += chunk.len() * 4;
            }
        }
        for s in 0..n - 1 {
            let sends: Vec<(usize, Vec<f32>)> = (0..n)
                .map(|r| {
                    let c = (r + 1 + n - s) % n;
                    let (lo, hi) = bounds[c];
                    (c, ranks[r][lo..hi].to_vec())
                })
                .collect();
            for r in 0..n {
                let dst = (r + 1) % n;
                let (c, ref chunk) = sends[r];
                let (lo, _hi) = bounds[c];
                ranks[dst][lo..lo + chunk.len()].copy_from_slice(chunk);
                wire[r] += chunk.len() * 4;
            }
        }
        Ok((ranks, wire))
    }

    /// Naive per-hop bf16 reference: same schedule as the scratch
    /// implementation but with per-step `Vec` snapshots and explicit
    /// pack/unpack round-trips through the device plane.
    fn ring_all_reduce_bf16_ref(
        mut ranks: Vec<Vec<f32>>,
    ) -> Result<(Vec<Vec<f32>>, Vec<usize>)> {
        let dev = crate::device::current();
        let n = ranks.len();
        let len = ranks[0].len();
        if n == 1 {
            dev.bf16_round(&mut ranks[0]);
            return Ok((ranks, vec![0]));
        }
        let (bounds, _) = chunk_bounds(len, n);
        let mut wire = vec![0usize; n];
        for s in 0..n - 1 {
            let sends: Vec<(usize, Vec<u16>)> = (0..n)
                .map(|r| {
                    let c = (r + n - s) % n;
                    let (lo, hi) = bounds[c];
                    let mut packed = vec![0u16; hi - lo];
                    dev.bf16_pack(&ranks[r][lo..hi], &mut packed);
                    (c, packed)
                })
                .collect();
            for r in 0..n {
                let dst = (r + 1) % n;
                let (c, ref chunk) = sends[r];
                let (lo, _hi) = bounds[c];
                dev.add_assign_bf16(&mut ranks[dst][lo..lo + chunk.len()], chunk);
                wire[r] += chunk.len() * 2;
            }
        }
        for (r, rank) in ranks.iter_mut().enumerate() {
            let (lo, hi) = bounds[(r + 1) % n];
            dev.bf16_round(&mut rank[lo..hi]);
        }
        for s in 0..n - 1 {
            let sends: Vec<(usize, Vec<u16>)> = (0..n)
                .map(|r| {
                    let c = (r + 1 + n - s) % n;
                    let (lo, hi) = bounds[c];
                    let mut packed = vec![0u16; hi - lo];
                    dev.bf16_pack(&ranks[r][lo..hi], &mut packed);
                    (c, packed)
                })
                .collect();
            for r in 0..n {
                let dst = (r + 1) % n;
                let (c, ref chunk) = sends[r];
                let (lo, _hi) = bounds[c];
                dev.bf16_unpack(chunk, &mut ranks[dst][lo..lo + chunk.len()]);
                wire[r] += chunk.len() * 2;
            }
        }
        Ok((ranks, wire))
    }

    #[test]
    fn matches_naive_sum() {
        let mut rng = Rng::new(5);
        for &(n, len) in &[(2usize, 8usize), (3, 10), (4, 64), (5, 7), (8, 33)] {
            let ranks: Vec<Vec<f32>> = (0..n)
                .map(|_| rng.normal_vec(len, 1.0))
                .collect();
            let want: Vec<f32> = (0..len)
                .map(|i| ranks.iter().map(|r| r[i]).sum::<f32>())
                .collect();
            let (got, _) = ring_all_reduce(ranks).unwrap();
            for r in &got {
                for (a, b) in r.iter().zip(want.iter()) {
                    assert!((a - b).abs() < 1e-4, "n={n} len={len}");
                }
            }
        }
    }

    #[test]
    fn scratch_rewrite_is_bitwise_the_reference() {
        // the scratch-buffer rewrite must not change a single bit of the
        // result or a single byte of the per-rank wire accounting —
        // including the non-divisible-length skew cases
        let mut rng = Rng::new(55);
        for &(n, len) in &[
            (2usize, 8usize),
            (3, 10),
            (4, 64),
            (5, 7),
            (8, 33),
            (7, 1),
            (6, 6),
        ] {
            let ranks: Vec<Vec<f32>> = (0..n)
                .map(|_| rng.normal_vec(len, 1.0))
                .collect();
            let (got, wire) = ring_all_reduce(ranks.clone()).unwrap();
            let (want, wire_ref) = ring_all_reduce_ref(ranks).unwrap();
            assert_eq!(wire, wire_ref, "wire skew changed: n={n} len={len}");
            for (a, b) in got.iter().zip(want.iter()) {
                for (x, y) in a.iter().zip(b.iter()) {
                    assert_eq!(x.to_bits(), y.to_bits(), "n={n} len={len}");
                }
            }
        }
    }

    #[test]
    fn shared_scratch_allocates_once_across_buckets() {
        // a trainer reducing many buckets per step reuses ONE allocation:
        // warm the scratch on the largest bucket, then every further
        // reduction — any smaller or equal size, f32 or bf16 — is
        // allocation-free
        let mut rng = Rng::new(7);
        let mut scratch = RingScratch::new();
        let mk = |rng: &mut Rng, n: usize, len: usize| -> Vec<Vec<f32>> {
            (0..n).map(|_| rng.normal_vec(len, 1.0)).collect()
        };
        ring_all_reduce_with_scratch(mk(&mut rng, 4, 256), &mut scratch).unwrap();
        ring_all_reduce_bf16_with_scratch(mk(&mut rng, 4, 256), &mut scratch)
            .unwrap();
        let warm = scratch.allocs();
        assert_eq!(warm, 2, "one f32 grow + one u16 grow");
        for _ in 0..10 {
            for &len in &[256usize, 100, 33, 7] {
                ring_all_reduce_with_scratch(mk(&mut rng, 4, len), &mut scratch)
                    .unwrap();
                ring_all_reduce_bf16_with_scratch(mk(&mut rng, 4, len), &mut scratch)
                    .unwrap();
            }
        }
        assert_eq!(scratch.allocs(), warm, "warm scratch must not reallocate");
    }

    #[test]
    fn bf16_ring_matches_reference_bitwise() {
        let mut rng = Rng::new(91);
        let mut scratch = RingScratch::new();
        for &(n, len) in &[(2usize, 8usize), (3, 10), (4, 64), (5, 7), (8, 33)] {
            let ranks: Vec<Vec<f32>> = (0..n)
                .map(|_| rng.normal_vec(len, 1.0))
                .collect();
            let (got, wire) =
                ring_all_reduce_bf16_with_scratch(ranks.clone(), &mut scratch)
                    .unwrap();
            let (want, wire_ref) = ring_all_reduce_bf16_ref(ranks).unwrap();
            assert_eq!(wire, wire_ref, "n={n} len={len}");
            for (a, b) in got.iter().zip(want.iter()) {
                for (x, y) in a.iter().zip(b.iter()) {
                    assert_eq!(x.to_bits(), y.to_bits(), "n={n} len={len}");
                }
            }
        }
    }

    #[test]
    fn bf16_ring_all_ranks_identical_and_near_f32_sum() {
        let mut rng = Rng::new(17);
        let mut scratch = RingScratch::new();
        for &(n, len) in &[(2usize, 16usize), (4, 33), (8, 64)] {
            let ranks: Vec<Vec<f32>> = (0..n)
                .map(|_| rng.normal_vec(len, 1.0))
                .collect();
            let want: Vec<f32> = (0..len)
                .map(|i| ranks.iter().map(|r| r[i]).sum::<f32>())
                .collect();
            let (got, _) =
                ring_all_reduce_bf16_with_scratch(ranks, &mut scratch).unwrap();
            for r in &got[1..] {
                for (x, y) in r.iter().zip(got[0].iter()) {
                    assert_eq!(x.to_bits(), y.to_bits(), "ranks diverged");
                }
            }
            for (a, b) in got[0].iter().zip(want.iter()) {
                // bf16 has ~2-3 decimal digits; hop-rounded sums of O(n)
                // unit normals stay well within a coarse tolerance
                assert!(
                    (a - b).abs() <= 0.05 * (n as f32) + 0.05,
                    "n={n} len={len}: bf16 {a} vs f32 {b}"
                );
            }
        }
    }

    #[test]
    fn bf16_wire_is_exactly_half_the_f32_wire() {
        let mut scratch = RingScratch::new();
        for &(n, len) in &[(2usize, 8usize), (4, 64), (8, 33)] {
            let ranks: Vec<Vec<f32>> = (0..n).map(|_| vec![1.0; len]).collect();
            let (_, wire_f32) =
                ring_all_reduce_with_scratch(ranks.clone(), &mut scratch).unwrap();
            let (_, wire_bf16) =
                ring_all_reduce_bf16_with_scratch(ranks, &mut scratch).unwrap();
            for (w16, w32) in wire_bf16.iter().zip(wire_f32.iter()) {
                assert_eq!(*w16 * 2, *w32, "n={n} len={len}");
            }
        }
    }

    #[test]
    fn bf16_single_rank_rounds_to_grid() {
        // dp=1 bf16 must see the same storage precision as dp>1
        let mut scratch = RingScratch::new();
        let (out, wire) =
            ring_all_reduce_bf16_with_scratch(vec![vec![1.0 + 1.0e-4, 2.5]], &mut scratch)
                .unwrap();
        assert_eq!(wire, vec![0]);
        assert_eq!(out[0][0].to_bits(), 1.0f32.to_bits(), "rounded to bf16 grid");
        assert_eq!(out[0][1], 2.5, "on-grid value untouched");
    }

    #[test]
    fn wire_volume_formula() {
        // divisible length: every rank sends exactly 2(N−1)/N × size_bytes
        let n = 4;
        let len = 1024;
        let ranks: Vec<Vec<f32>> = (0..n).map(|_| vec![1.0; len]).collect();
        let (_, wire) = ring_all_reduce(ranks).unwrap();
        let expect = 2 * (n - 1) * len * 4 / n;
        assert_eq!(wire, vec![expect; n]);
    }

    #[test]
    fn wire_volume_exact_at_non_divisible_length() {
        // len=33, n=8: base chunk 4 elems, last chunk 5. The old
        // accounting truncated total/n to a single flat 231 B/rank; the
        // true per-rank volumes are skewed by which chunk a rank skips.
        let (n, len) = (8usize, 33usize);
        let ranks: Vec<Vec<f32>> = (0..n).map(|_| vec![1.0; len]).collect();
        let (_, wire) = ring_all_reduce(ranks).unwrap();
        let base = len / n;
        let chunk_bytes =
            |c: usize| 4 * if c == n - 1 { len - (n - 1) * base } else { base };
        // rank r skips chunk (r+1)%n in phase 1 and (r+2)%n in phase 2
        let expect: Vec<usize> = (0..n)
            .map(|r| {
                2 * len * 4 - chunk_bytes((r + 1) % n) - chunk_bytes((r + 2) % n)
            })
            .collect();
        assert_eq!(wire, expect);
        // totals conserved: every chunk crosses every link once per phase
        assert_eq!(wire.iter().sum::<usize>(), 2 * (n - 1) * len * 4);
        // the skew the old `total / n` average hid
        assert!(wire.iter().any(|&w| w != wire[0]));
    }

    #[test]
    fn payload_crc_detects_wire_corruption() {
        let mut rng = Rng::new(23);
        let part: Vec<f32> = rng.normal_vec(257, 1.0);
        let crc = payload_crc32(&part);
        assert_eq!(crc, payload_crc32(&part), "checksum is pure");
        // any single-bit flip anywhere in the payload is detected
        for idx in [0usize, 128, 256] {
            let mut hit = part.clone();
            hit[idx] = f32::from_bits(hit[idx].to_bits() ^ 0x0001_0000);
            assert_ne!(payload_crc32(&hit), crc, "flip at {idx} undetected");
        }
        assert_eq!(payload_crc32(&[]), 0);
    }

    #[test]
    fn single_rank_noop() {
        let (out, wire) = ring_all_reduce(vec![vec![3.0, 4.0]]).unwrap();
        assert_eq!(out[0], vec![3.0, 4.0]);
        assert_eq!(wire, vec![0]);
    }

    #[test]
    fn mismatched_lengths_rejected() {
        assert!(ring_all_reduce(vec![vec![1.0], vec![1.0, 2.0]]).is_err());
    }
}
