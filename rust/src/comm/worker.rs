//! Dedicated communication worker thread: the *real-clock* analogue of the
//! simulated comm stream in [`crate::dap::Timeline`].
//!
//! Duality Async Operations hide collective latency behind compute. The
//! simulated timeline has always modeled that; this worker makes it true
//! on the host as well: the schedule executor submits an async collective
//! here at its trigger point and keeps running rank compute, then joins
//! the [`CommTicket`] at the schedule's `wait`. Jobs execute FIFO on one
//! thread — exactly the single comm stream the α–β model prices — and the
//! collective math is the same [`Collectives`] code the synchronous path
//! runs, so deferred execution is bit-for-bit identical to inline
//! execution.

use super::Collectives;
use crate::error::{Error, Result};
use crate::tensor::HostTensor;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::thread::JoinHandle;
use std::time::Instant; // lint:allow(wallclock) — real-clock comm-job measurement (MeasuredComm)

/// Default bounded-wait for joining a collective, milliseconds.
pub const DEFAULT_WAIT_TIMEOUT_MS: u64 = 30_000;

/// Process-wide join timeout (ms; 0 = wait forever). A process-wide
/// setting, like `device::configure`: the executor spawns workers deep in
/// the schedule path where no `RunConfig` is threaded, so the CLI applies
/// `[comm] wait_timeout_ms` once at startup.
static WAIT_TIMEOUT_MS: AtomicU64 = AtomicU64::new(DEFAULT_WAIT_TIMEOUT_MS);

/// Set the collective join timeout (`[comm] wait_timeout_ms`; 0 disables
/// the bound and restores the legacy block-forever join).
pub fn set_wait_timeout_ms(ms: u64) {
    WAIT_TIMEOUT_MS.store(ms, Ordering::Relaxed);
}

/// Current collective join timeout in milliseconds (0 = unbounded).
pub fn wait_timeout_ms() -> u64 {
    WAIT_TIMEOUT_MS.load(Ordering::Relaxed)
}

/// One deferred collective: the op kind plus the input shards captured at
/// the schedule's trigger point (issue-time snapshot semantics).
///
/// [`CommJob::run`] is the single dispatch point — the worker loop and the
/// executor's inline path both go through it, so deferred and inline
/// execution cannot drift apart.
pub enum CommJob {
    /// `all_gather(parts, axis)`
    Gather {
        /// per-rank input shards
        parts: Vec<HostTensor>,
        /// concat axis
        axis: usize,
    },
    /// `reduce_scatter(parts, axis)`
    Scatter {
        /// per-rank full partial tensors
        parts: Vec<HostTensor>,
        /// split axis
        axis: usize,
    },
    /// `all_to_all(parts, split, concat)`
    AllToAll {
        /// per-rank local tensors
        parts: Vec<HostTensor>,
        /// axis each rank splits along
        split: usize,
        /// axis each rank concatenates along
        concat: usize,
    },
}

impl CommJob {
    /// Op label with group-size context (e.g. `gather[n=4]`) — what a
    /// [`crate::Error::CommTimeout`] reports as the stalled op.
    pub fn label(&self) -> String {
        match self {
            CommJob::Gather { parts, .. } => format!("gather[n={}]", parts.len()),
            CommJob::Scatter { parts, .. } => {
                format!("scatter[n={}]", parts.len())
            }
            CommJob::AllToAll { parts, .. } => {
                format!("all_to_all[n={}]", parts.len())
            }
        }
    }

    /// Execute the collective against `comm`.
    pub fn run(self, comm: &Collectives) -> Result<Vec<HostTensor>> {
        match self {
            CommJob::Gather { parts, axis } => comm.all_gather(&parts, axis),
            CommJob::Scatter { parts, axis } => comm.reduce_scatter(&parts, axis),
            CommJob::AllToAll { parts, split, concat } => {
                comm.all_to_all(&parts, split, concat)
            }
        }
    }
}

struct CommDone {
    result: Result<Vec<HostTensor>>,
    exec_seconds: f64,
}

/// Handle for one in-flight collective; joining blocks until the worker
/// has finished the job — but never forever: the wait is bounded by the
/// `[comm] wait_timeout_ms` stamped at submit time.
pub struct CommTicket {
    rx: Receiver<CommDone>,
    op: String,
    timeout_ms: u64,
}

impl CommTicket {
    /// Block until the collective completes; returns the per-rank results
    /// and the seconds the worker spent executing it (measured comm time,
    /// whether or not it was exposed to the compute path). A worker that
    /// stalls past the configured timeout surfaces a structured
    /// [`crate::Error::CommTimeout`] with the op label instead of hanging
    /// the schedule's `Wait` — the fault-tolerant retry path upstream
    /// decides whether to re-issue.
    pub fn join(self) -> Result<(Vec<HostTensor>, f64)> {
        let done = if self.timeout_ms == 0 {
            self.rx.recv().map_err(|_| closed_queue_error())?
        } else {
            match self
                .rx
                .recv_timeout(std::time::Duration::from_millis(self.timeout_ms))
            {
                Ok(done) => done,
                Err(RecvTimeoutError::Timeout) => {
                    return Err(Error::CommTimeout {
                        op: self.op,
                        rank: 0,
                        waited_ms: self.timeout_ms,
                    })
                }
                Err(RecvTimeoutError::Disconnected) => {
                    return Err(closed_queue_error())
                }
            }
        };
        Ok((done.result?, done.exec_seconds))
    }
}

fn closed_queue_error() -> Error {
    Error::Comm("comm worker exited before completing a collective".into())
}

/// The comm worker thread. Dropping it closes the job queue and joins the
/// thread; outstanding tickets then fail with a descriptive error.
pub struct CommWorker {
    tx: Option<Sender<(CommJob, Sender<CommDone>)>>,
    handle: Option<JoinHandle<()>>,
}

impl CommWorker {
    /// Spawn the worker over a clone of the comm substrate (the log is
    /// shared, so collectives run here are recorded like any other).
    pub fn spawn(comm: Collectives) -> Self {
        let (tx, rx) = channel::<(CommJob, Sender<CommDone>)>();
        let handle = std::thread::Builder::new()
            .name("fastfold-comm".into())
            .spawn(move || {
                for (job, reply) in rx {
                    let t0 = Instant::now();
                    let result = job.run(&comm);
                    // a dropped ticket (executor bailed early) is fine
                    let _ = reply.send(CommDone {
                        result,
                        exec_seconds: t0.elapsed().as_secs_f64(),
                    });
                }
            })
            .expect("spawn fastfold-comm worker thread");
        CommWorker { tx: Some(tx), handle: Some(handle) }
    }

    /// Enqueue a collective; returns immediately with its join ticket
    /// (stamped with the current wait timeout and the op label).
    pub fn submit(&self, job: CommJob) -> CommTicket {
        let op = job.label();
        let (reply_tx, reply_rx) = channel();
        self.tx
            .as_ref()
            .expect("comm worker queue open while worker alive")
            .send((job, reply_tx))
            .expect("comm worker thread alive");
        CommTicket { rx: reply_rx, op, timeout_ms: wait_timeout_ms() }
    }
}

impl Drop for CommWorker {
    fn drop(&mut self) {
        drop(self.tx.take()); // close the queue → worker loop ends
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deferred_equals_inline() {
        let comm = Collectives::new(2);
        let parts = vec![
            HostTensor::full(&[2, 3], 1.0),
            HostTensor::full(&[2, 3], 2.0),
        ];
        let inline = comm.all_gather(&parts, 0).unwrap();
        let worker = CommWorker::spawn(comm.clone());
        let ticket =
            worker.submit(CommJob::Gather { parts: parts.clone(), axis: 0 });
        let (deferred, secs) = ticket.join().unwrap();
        assert_eq!(inline, deferred);
        assert!(secs >= 0.0);
        // both executions hit the shared log
        assert_eq!(comm.log.lock().unwrap().len(), 2);
    }

    #[test]
    fn worker_propagates_collective_errors() {
        let comm = Collectives::new(3); // 3 ranks, 2 shards -> error
        let worker = CommWorker::spawn(comm);
        let parts = vec![HostTensor::full(&[2], 0.0), HostTensor::full(&[2], 0.0)];
        let ticket = worker.submit(CommJob::Scatter { parts, axis: 0 });
        assert!(ticket.join().is_err());
    }

    #[test]
    fn stalled_join_times_out_with_op_context() {
        // a ticket whose worker never replies must not hang the process:
        // the bounded join surfaces CommTimeout with the op label
        let (_tx, rx) = channel::<CommDone>();
        let ticket =
            CommTicket { rx, op: "gather[n=2]".into(), timeout_ms: 10 };
        match ticket.join() {
            Err(Error::CommTimeout { op, rank, waited_ms }) => {
                assert_eq!(op, "gather[n=2]");
                assert_eq!(rank, 0);
                assert_eq!(waited_ms, 10);
            }
            other => panic!("expected CommTimeout, got {other:?}"),
        }
    }

    #[test]
    fn timeout_config_is_process_wide() {
        assert!(wait_timeout_ms() > 0, "bounded by default");
        // a healthy worker completes well inside the default bound, and
        // tickets are stamped with the op label at submit time
        let comm = Collectives::new(2);
        let worker = CommWorker::spawn(comm);
        let parts =
            vec![HostTensor::full(&[2], 1.0), HostTensor::full(&[2], 2.0)];
        let ticket = worker.submit(CommJob::Gather { parts, axis: 0 });
        assert_eq!(ticket.op, "gather[n=2]");
        assert_eq!(ticket.timeout_ms, wait_timeout_ms());
        assert!(ticket.join().is_ok());
    }

    #[test]
    fn fifo_order_many_jobs() {
        let comm = Collectives::new(2);
        let worker = CommWorker::spawn(comm);
        let tickets: Vec<CommTicket> = (0..8)
            .map(|i| {
                let parts = vec![
                    HostTensor::full(&[4], i as f32),
                    HostTensor::full(&[4], -(i as f32)),
                ];
                worker.submit(CommJob::Scatter { parts, axis: 0 })
            })
            .collect();
        for ticket in tickets {
            let (out, _) = ticket.join().unwrap();
            // reduce_scatter of x and -x sums to zero everywhere
            assert!(out.iter().all(|t| t.data().iter().all(|&v| v == 0.0)));
        }
    }
}
