//! Communication log: every collective issued by any coordinator is
//! recorded here (kind, per-rank wire bytes, logical tensor bytes). The
//! Table III reproduction and the α–β timing model both read this.

#[derive(Copy, Clone, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum CommKind {
    AllGather,
    ReduceScatter,
    AllToAll,
    AllReduce,
    Broadcast,
}

impl CommKind {
    pub fn name(&self) -> &'static str {
        match self {
            CommKind::AllGather => "AllGather",
            CommKind::ReduceScatter => "ReduceScatter",
            CommKind::AllToAll => "All_to_All",
            CommKind::AllReduce => "AllReduce",
            CommKind::Broadcast => "Broadcast",
        }
    }
}

#[derive(Clone, Debug)]
pub struct CommRecord {
    pub kind: CommKind,
    /// bytes crossing the wire per rank (ring-algorithm accounting)
    pub wire_bytes: usize,
    /// logical size of the full tensor being communicated
    pub tensor_bytes: usize,
}

#[derive(Default, Clone, Debug)]
pub struct CommLog {
    pub records: Vec<CommRecord>,
}

impl CommLog {
    pub fn record(&mut self, kind: CommKind, wire_bytes: usize, tensor_bytes: usize) {
        self.records.push(CommRecord { kind, wire_bytes, tensor_bytes });
    }

    pub fn count(&self, kind: CommKind) -> usize {
        self.records.iter().filter(|r| r.kind == kind).count()
    }

    pub fn total_bytes(&self) -> usize {
        self.records.iter().map(|r| r.wire_bytes).sum()
    }

    pub fn bytes_of(&self, kind: CommKind) -> usize {
        self.records
            .iter()
            .filter(|r| r.kind == kind)
            .map(|r| r.wire_bytes)
            .sum()
    }

    pub fn clear(&mut self) {
        self.records.clear();
    }

    pub fn len(&self) -> usize {
        self.records.len()
    }

    pub fn is_empty(&self) -> bool {
        self.records.is_empty()
    }

    /// "12 × AllReduce (3.2 MiB)"-style summary lines, sorted by kind.
    pub fn summary(&self) -> Vec<String> {
        use CommKind::*;
        [AllGather, ReduceScatter, AllToAll, AllReduce, Broadcast]
            .iter()
            .filter(|k| self.count(**k) > 0)
            .map(|k| {
                format!(
                    "{:3} x {:<14} {:>10.2} KiB wire/rank",
                    self.count(*k),
                    k.name(),
                    self.bytes_of(*k) as f64 / 1024.0
                )
            })
            .collect()
    }
}
