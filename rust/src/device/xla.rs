//! [`XlaStubHost`]: the device plane for the stub `xla` crate.
//!
//! This offline build links stub PJRT bindings (see the crate docs), so
//! there is no real device to hand kernels to; until real bindings are
//! linked, every kernel call lowers to the host fused path by
//! delegating to the scalar oracle. That keeps `--device-backend
//! xla-stub` runnable end-to-end (and bit-identical to `scalar`), while
//! giving a real device plane a ready-made seam: implement these five
//! methods against PJRT and the rest of the tree never changes.

use super::{DeviceBackend, ScalarHost};

/// The stub xla device plane (backend name `"xla-stub"`).
#[derive(Clone, Copy, Debug, Default)]
pub struct XlaStubHost;

impl DeviceBackend for XlaStubHost {
    fn name(&self) -> &'static str {
        "xla-stub"
    }

    fn softmax_rows(&self, x: &[f32], cols: usize, scale: f32, out: &mut [f32]) {
        ScalarHost.softmax_rows(x, cols, scale, out);
    }

    fn layernorm_rows(
        &self,
        x: &[f32],
        cols: usize,
        gamma: &[f32],
        beta: &[f32],
        eps: f32,
        out: &mut [f32],
    ) {
        ScalarHost.layernorm_rows(x, cols, gamma, beta, eps, out);
    }

    fn adam_step(
        &self,
        step: usize,
        lr: f32,
        p: &mut [f32],
        g: &[f32],
        m: &mut [f32],
        v: &mut [f32],
    ) {
        ScalarHost.adam_step(step, lr, p, g, m, v);
    }

    fn add_assign(&self, dst: &mut [f32], src: &[f32]) {
        ScalarHost.add_assign(dst, src);
    }

    fn scale(&self, dst: &mut [f32], s: f32) {
        ScalarHost.scale(dst, s);
    }

    fn bf16_round(&self, dst: &mut [f32]) {
        ScalarHost.bf16_round(dst);
    }

    fn bf16_pack(&self, src: &[f32], dst: &mut [u16]) {
        ScalarHost.bf16_pack(src, dst);
    }

    fn bf16_unpack(&self, src: &[u16], dst: &mut [f32]) {
        ScalarHost.bf16_unpack(src, dst);
    }

    fn add_assign_bf16(&self, dst: &mut [f32], src: &[u16]) {
        ScalarHost.add_assign_bf16(dst, src);
    }
}
