//! [`ScalarHost`]: the bit-exact oracle backend.
//!
//! Delegates every op, unchanged, to the PR 5 fused host kernels —
//! strictly sequential, one element at a time. Every other backend is
//! validated against this one (`tests/kernel_backends.rs`), so its
//! numerics are frozen: bit-for-bit with the fused kernel plane by
//! construction.

use super::DeviceBackend;
// lint:allow(backend) — ScalarHost is the sanctioned oracle over the kernel plane
use crate::kernels::{adam, layernorm, softmax};
// lint:allow(backend) — elementwise helpers live at the kernel-plane root
use crate::kernels::{add_assign as add_assign_slices, scale as scale_slices};
// lint:allow(backend) — the bf16 storage-emulation kernels are oracle-owned
use crate::kernels::bf16;

/// The scalar oracle (backend name `"scalar"`).
#[derive(Clone, Copy, Debug, Default)]
pub struct ScalarHost;

impl DeviceBackend for ScalarHost {
    fn name(&self) -> &'static str {
        "scalar"
    }

    fn softmax_rows(&self, x: &[f32], cols: usize, scale: f32, out: &mut [f32]) {
        softmax::softmax_rows(x, cols, scale, out);
    }

    fn layernorm_rows(
        &self,
        x: &[f32],
        cols: usize,
        gamma: &[f32],
        beta: &[f32],
        eps: f32,
        out: &mut [f32],
    ) {
        layernorm::layernorm_rows(x, cols, gamma, beta, eps, out);
    }

    fn adam_step(
        &self,
        step: usize,
        lr: f32,
        p: &mut [f32],
        g: &[f32],
        m: &mut [f32],
        v: &mut [f32],
    ) {
        adam::adam_step(step, lr, p, g, m, v);
    }

    fn add_assign(&self, dst: &mut [f32], src: &[f32]) {
        add_assign_slices(dst, src);
    }

    fn scale(&self, dst: &mut [f32], s: f32) {
        scale_slices(dst, s);
    }

    fn bf16_round(&self, dst: &mut [f32]) {
        bf16::round_slice(dst);
    }

    fn bf16_pack(&self, src: &[f32], dst: &mut [u16]) {
        bf16::pack_slice(src, dst);
    }

    fn bf16_unpack(&self, src: &[u16], dst: &mut [f32]) {
        bf16::unpack_slice(src, dst);
    }

    fn add_assign_bf16(&self, dst: &mut [f32], src: &[u16]) {
        bf16::add_assign_bf16(dst, src);
    }
}
