//! [`SimdHost`]: explicit f32x8 lanes + within-op row threading.
//!
//! The fast path of the device plane. Each kernel is restructured into
//! lane-parallel passes over [`F32x8`] — a plain `[f32; 8]` wrapper
//! whose ops are fixed 8-wide unrolled loops that LLVM lowers to vector
//! instructions on any SSE2+ target (portable SIMD on stable Rust, no
//! intrinsics) — with a scalar tail for the last `len % 8` elements,
//! and banded across rows over scoped worker threads up to the
//! rank-executor budget installed by [`super::configure`].
//!
//! Equivalence vs the scalar oracle ([`super::ScalarHost`]), pinned by
//! `tests/kernel_backends.rs` at every thread count:
//!
//! * **softmax — bit-for-bit.** Both backends exponentiate through the
//!   shared polynomial [`exp32`]; the lane max-reduction can differ
//!   from the sequential fold only in the sign of a ±0 maximum, which
//!   provably never changes an output bit; the row sum is folded in
//!   scalar element order over the stored numerators; the divide is
//!   elementwise. Rows are independent, so banding is invariant too.
//! * **Adam / add_assign / scale — bit-for-bit.** Purely elementwise
//!   (IEEE mul/add/div/sqrt are exact per element, vectorized or not),
//!   so lane width and band boundaries cannot show up in the bits.
//! * **LayerNorm — tolerance.** Eight Welford lanes instead of the
//!   oracle's four change the summation order; validated to tolerance
//!   like every other Welford-order change in the kernel plane.

use super::DeviceBackend;
// lint:allow(backend) — the lane path shares the oracle's Adam constants
use crate::kernels::adam::{BETA1, BETA2, EPS};
// lint:allow(backend) — shared polynomial exp keeps scalar/simd bit-identical
use crate::kernels::math::exp32;
// lint:allow(backend) — bf16 casts share the oracle's bit-manipulation kernels
use crate::kernels::bf16;
use std::ops::{Add, AddAssign, Div, Mul, Sub};

/// Lane width of [`F32x8`].
pub const F32X8_LANES: usize = 8;
const LANES: usize = F32X8_LANES;

/// Below this many rows per candidate worker, row-banded kernels stay
/// sequential (thread spawn latency would dominate the pass).
const MIN_ROWS_PER_WORKER: usize = 64;
/// Below this many elements per candidate worker, elementwise kernels
/// stay sequential.
const MIN_ELEMS_PER_WORKER: usize = 1 << 16;

/// Eight f32 lanes as a plain array wrapper. Every op is a fixed
/// 8-iteration `array::from_fn`, which the loop/SLP vectorizers turn
/// into vector instructions; semantics are exactly the per-lane scalar
/// op, so lane code is bit-comparable to scalar code by construction.
#[derive(Clone, Copy, Debug)]
pub struct F32x8([f32; 8]);

impl F32x8 {
    /// All lanes set to `v`.
    #[inline(always)]
    pub fn splat(v: f32) -> Self {
        Self([v; 8])
    }

    /// Load lanes from the first 8 elements of `s`.
    #[inline(always)]
    pub fn load(s: &[f32]) -> Self {
        Self(std::array::from_fn(|i| s[i]))
    }

    /// Store lanes into the first 8 elements of `out`.
    #[inline(always)]
    pub fn store(self, out: &mut [f32]) {
        out[..8].copy_from_slice(&self.0);
    }

    /// Per-lane greater-of select with `f32::max`'s NaN behavior for a
    /// non-NaN accumulator: a NaN in `rhs` keeps the `self` lane.
    #[inline(always)]
    pub fn max(self, rhs: Self) -> Self {
        Self(std::array::from_fn(|i| {
            if rhs.0[i] > self.0[i] {
                rhs.0[i]
            } else {
                self.0[i]
            }
        }))
    }

    /// Per-lane square root (IEEE-exact, identical scalar or vector).
    #[inline(always)]
    pub fn sqrt(self) -> Self {
        Self(std::array::from_fn(|i| self.0[i].sqrt()))
    }

    /// Per-lane [`exp32`] — literally the scalar polynomial per lane,
    /// so lane and scalar exponentials are the same bits.
    #[inline(always)]
    pub fn exp32(self) -> Self {
        Self(std::array::from_fn(|i| exp32(self.0[i])))
    }

    /// Greater-of fold across the lanes (lane 0 first; same ±0/NaN
    /// semantics as [`F32x8::max`]).
    #[inline(always)]
    pub fn reduce_max(self) -> f32 {
        let mut mx = self.0[0];
        for &v in &self.0[1..] {
            if v > mx {
                mx = v;
            }
        }
        mx
    }

    /// The lanes as a plain array (Welford lane merges).
    #[inline(always)]
    pub fn to_array(self) -> [f32; 8] {
        self.0
    }
}

impl Add for F32x8 {
    type Output = Self;
    #[inline(always)]
    fn add(self, rhs: Self) -> Self {
        Self(std::array::from_fn(|i| self.0[i] + rhs.0[i]))
    }
}

impl Sub for F32x8 {
    type Output = Self;
    #[inline(always)]
    fn sub(self, rhs: Self) -> Self {
        Self(std::array::from_fn(|i| self.0[i] - rhs.0[i]))
    }
}

impl Mul for F32x8 {
    type Output = Self;
    #[inline(always)]
    fn mul(self, rhs: Self) -> Self {
        Self(std::array::from_fn(|i| self.0[i] * rhs.0[i]))
    }
}

impl Div for F32x8 {
    type Output = Self;
    #[inline(always)]
    fn div(self, rhs: Self) -> Self {
        Self(std::array::from_fn(|i| self.0[i] / rhs.0[i]))
    }
}

impl AddAssign for F32x8 {
    #[inline(always)]
    fn add_assign(&mut self, rhs: Self) {
        *self = *self + rhs;
    }
}

/// The f32x8 fast path (backend name `"simd"`).
///
/// `threads: None` — the form the global dispatch uses — reads the
/// process-wide budget installed by [`super::configure`] at each call;
/// [`SimdHost::with_threads`] pins an exact worker count (bench ratio
/// and scaling probes, property tests).
#[derive(Clone, Copy, Debug)]
pub struct SimdHost {
    threads: Option<usize>,
}

impl SimdHost {
    /// Budget follows [`super::configure`] (the static instance behind
    /// [`super::current`]).
    pub const fn auto() -> Self {
        SimdHost { threads: None }
    }

    /// Budget pinned to exactly `threads` within-op workers.
    pub const fn with_threads(threads: usize) -> Self {
        SimdHost { threads: Some(threads) }
    }

    fn budget(&self) -> usize {
        match self.threads {
            Some(t) => t.max(1),
            None => super::active_threads(),
        }
    }
}

/// Workers actually worth spawning: the budget, capped so each worker
/// keeps at least `min_units` of `units` (and never zero workers).
fn worker_count(budget: usize, units: usize, min_units: usize) -> usize {
    budget.min((units / min_units).max(1))
}

impl DeviceBackend for SimdHost {
    fn name(&self) -> &'static str {
        "simd"
    }

    fn softmax_rows(&self, x: &[f32], cols: usize, scale: f32, out: &mut [f32]) {
        assert!(cols > 0, "softmax over 0 columns");
        assert_eq!(x.len() % cols, 0, "input not a whole number of rows");
        assert_eq!(out.len(), x.len(), "output length mismatch");
        let rows = x.len() / cols;
        let workers = worker_count(self.budget(), rows, MIN_ROWS_PER_WORKER);
        if workers <= 1 {
            softmax_band(x, cols, scale, out);
            return;
        }
        // whole rows per band — rows are independent, so banding cannot
        // change any output bit
        let band = ((rows + workers - 1) / workers) * cols;
        std::thread::scope(|s| {
            for (xc, oc) in x.chunks(band).zip(out.chunks_mut(band)) {
                s.spawn(move || softmax_band(xc, cols, scale, oc));
            }
        });
    }

    fn layernorm_rows(
        &self,
        x: &[f32],
        cols: usize,
        gamma: &[f32],
        beta: &[f32],
        eps: f32,
        out: &mut [f32],
    ) {
        assert!(cols > 0, "layernorm over 0 columns");
        assert_eq!(x.len() % cols, 0, "input not a whole number of rows");
        assert_eq!(gamma.len(), cols, "gamma length mismatch");
        assert_eq!(beta.len(), cols, "beta length mismatch");
        assert_eq!(out.len(), x.len(), "output length mismatch");
        let rows = x.len() / cols;
        let workers = worker_count(self.budget(), rows, MIN_ROWS_PER_WORKER);
        if workers <= 1 {
            layernorm_band(x, cols, gamma, beta, eps, out);
            return;
        }
        let band = ((rows + workers - 1) / workers) * cols;
        std::thread::scope(|s| {
            for (xc, oc) in x.chunks(band).zip(out.chunks_mut(band)) {
                s.spawn(move || layernorm_band(xc, cols, gamma, beta, eps, oc));
            }
        });
    }

    fn adam_step(
        &self,
        step: usize,
        lr: f32,
        p: &mut [f32],
        g: &[f32],
        m: &mut [f32],
        v: &mut [f32],
    ) {
        assert!(
            p.len() == g.len() && p.len() == m.len() && p.len() == v.len(),
            "adam: length mismatch (p={}, g={}, m={}, v={})",
            p.len(),
            g.len(),
            m.len(),
            v.len()
        );
        let n = p.len();
        let workers = worker_count(self.budget(), n, MIN_ELEMS_PER_WORKER);
        if workers <= 1 {
            adam_band(step, lr, p, g, m, v);
            return;
        }
        // purely elementwise: any banding is bit-invariant
        let band = (n + workers - 1) / workers;
        std::thread::scope(|s| {
            let bands = p
                .chunks_mut(band)
                .zip(g.chunks(band))
                .zip(m.chunks_mut(band))
                .zip(v.chunks_mut(band));
            for (((pc, gc), mc), vc) in bands {
                s.spawn(move || adam_band(step, lr, pc, gc, mc, vc));
            }
        });
    }

    fn add_assign(&self, dst: &mut [f32], src: &[f32]) {
        debug_assert_eq!(dst.len(), src.len());
        let workers = worker_count(self.budget(), dst.len(), MIN_ELEMS_PER_WORKER);
        if workers <= 1 {
            add_band(dst, src);
            return;
        }
        let band = (dst.len() + workers - 1) / workers;
        std::thread::scope(|s| {
            for (dc, sc) in dst.chunks_mut(band).zip(src.chunks(band)) {
                s.spawn(move || add_band(dc, sc));
            }
        });
    }

    fn scale(&self, dst: &mut [f32], s: f32) {
        let workers = worker_count(self.budget(), dst.len(), MIN_ELEMS_PER_WORKER);
        if workers <= 1 {
            scale_band(dst, s);
            return;
        }
        let band = (dst.len() + workers - 1) / workers;
        std::thread::scope(|sc| {
            for dc in dst.chunks_mut(band) {
                sc.spawn(move || scale_band(dc, s));
            }
        });
    }

    // The bf16 conversions are integer bit manipulation (no f32 lane op
    // expresses an RNE mantissa round), so each band runs the oracle's
    // per-element kernels; the win here is the banding — conversions on
    // the trainer's gradient leaves thread like any elementwise pass,
    // and all four are bit-invariant to band boundaries by construction.

    fn bf16_round(&self, dst: &mut [f32]) {
        let workers = worker_count(self.budget(), dst.len(), MIN_ELEMS_PER_WORKER);
        if workers <= 1 {
            bf16::round_slice(dst);
            return;
        }
        let band = (dst.len() + workers - 1) / workers;
        std::thread::scope(|s| {
            for dc in dst.chunks_mut(band) {
                s.spawn(move || bf16::round_slice(dc));
            }
        });
    }

    fn bf16_pack(&self, src: &[f32], dst: &mut [u16]) {
        debug_assert_eq!(src.len(), dst.len());
        let workers = worker_count(self.budget(), dst.len(), MIN_ELEMS_PER_WORKER);
        if workers <= 1 {
            bf16::pack_slice(src, dst);
            return;
        }
        let band = (dst.len() + workers - 1) / workers;
        std::thread::scope(|s| {
            for (sc, dc) in src.chunks(band).zip(dst.chunks_mut(band)) {
                s.spawn(move || bf16::pack_slice(sc, dc));
            }
        });
    }

    fn bf16_unpack(&self, src: &[u16], dst: &mut [f32]) {
        debug_assert_eq!(src.len(), dst.len());
        let workers = worker_count(self.budget(), dst.len(), MIN_ELEMS_PER_WORKER);
        if workers <= 1 {
            bf16::unpack_slice(src, dst);
            return;
        }
        let band = (dst.len() + workers - 1) / workers;
        std::thread::scope(|s| {
            for (sc, dc) in src.chunks(band).zip(dst.chunks_mut(band)) {
                s.spawn(move || bf16::unpack_slice(sc, dc));
            }
        });
    }

    fn add_assign_bf16(&self, dst: &mut [f32], src: &[u16]) {
        debug_assert_eq!(dst.len(), src.len());
        let workers = worker_count(self.budget(), dst.len(), MIN_ELEMS_PER_WORKER);
        if workers <= 1 {
            bf16::add_assign_bf16(dst, src);
            return;
        }
        let band = (dst.len() + workers - 1) / workers;
        std::thread::scope(|s| {
            for (dc, sc) in dst.chunks_mut(band).zip(src.chunks(band)) {
                s.spawn(move || bf16::add_assign_bf16(dc, sc));
            }
        });
    }
}

/// Lane softmax over one band of whole rows. Pass structure (vs the
/// oracle's fused exp+sum loop): lane max → lane exp store → **scalar
/// element-order sum over the stored numerators** (the same fold the
/// oracle runs, so the sum bits match) → lane divide.
fn softmax_band(x: &[f32], cols: usize, scale: f32, out: &mut [f32]) {
    let head = cols - cols % LANES;
    let scale8 = F32x8::splat(scale);
    for (orow, xrow) in out.chunks_exact_mut(cols).zip(x.chunks_exact(cols)) {
        let mut mx = f32::NEG_INFINITY;
        if head > 0 {
            let mut mx8 = F32x8::splat(f32::NEG_INFINITY);
            for c in xrow[..head].chunks_exact(LANES) {
                mx8 = mx8.max(F32x8::load(c) * scale8);
            }
            mx = mx8.reduce_max();
        }
        for &xv in &xrow[head..] {
            let sv = xv * scale;
            if sv > mx {
                mx = sv;
            }
        }
        let mx8 = F32x8::splat(mx);
        for (oc, xc) in orow[..head]
            .chunks_exact_mut(LANES)
            .zip(xrow[..head].chunks_exact(LANES))
        {
            let e = (F32x8::load(xc) * scale8 - mx8).exp32();
            e.store(oc);
        }
        for (o, &xv) in orow[head..].iter_mut().zip(&xrow[head..]) {
            *o = exp32(xv * scale - mx);
        }
        let sum: f32 = orow.iter().sum();
        let sum8 = F32x8::splat(sum);
        for oc in orow[..head].chunks_exact_mut(LANES) {
            let q = F32x8::load(oc) / sum8;
            q.store(oc);
        }
        for o in orow[head..].iter_mut() {
            *o /= sum;
        }
    }
}

/// Lane LayerNorm over one band of whole rows: 8 interleaved Welford
/// lanes (the oracle uses 4) + a scalar-Welford tail, merged with the
/// parallel-Welford combine, then a lane normalize+affine pass.
fn layernorm_band(x: &[f32], cols: usize, gamma: &[f32], beta: &[f32], eps: f32, out: &mut [f32]) {
    let head = cols - cols % LANES;
    let chunks = head / LANES;
    // running-mean reciprocals 1/(k+1), shared by every row and lane
    let recip: Vec<f32> = (0..chunks).map(|k| 1.0 / (k as f32 + 1.0)).collect();
    for (orow, xrow) in out.chunks_exact_mut(cols).zip(x.chunks_exact(cols)) {
        let mut mean_acc = 0.0f32;
        let mut m2_acc = 0.0f32;
        let mut n_acc = 0.0f32;
        if head > 0 {
            let mut mean8 = F32x8::splat(0.0);
            let mut m28 = F32x8::splat(0.0);
            for (k, c) in xrow[..head].chunks_exact(LANES).enumerate() {
                let xv = F32x8::load(c);
                let delta = xv - mean8;
                mean8 += delta * F32x8::splat(recip[k]);
                m28 += delta * (xv - mean8);
            }
            let meanl = mean8.to_array();
            let m2l = m28.to_array();
            let per_lane = chunks as f32;
            mean_acc = meanl[0];
            m2_acc = m2l[0];
            n_acc = per_lane;
            for l in 1..LANES {
                let delta = meanl[l] - mean_acc;
                let n = n_acc + per_lane;
                m2_acc += m2l[l] + delta * delta * n_acc * per_lane / n;
                mean_acc += delta * per_lane / n;
                n_acc = n;
            }
        }
        if head < cols {
            let mut mean_t = 0.0f32;
            let mut m2_t = 0.0f32;
            let mut cnt_t = 0.0f32;
            for &xv in &xrow[head..] {
                cnt_t += 1.0;
                let delta = xv - mean_t;
                mean_t += delta / cnt_t;
                m2_t += delta * (xv - mean_t);
            }
            let delta = mean_t - mean_acc;
            let n = n_acc + cnt_t;
            m2_acc += m2_t + delta * delta * n_acc * cnt_t / n;
            mean_acc += delta * cnt_t / n;
        }
        let var = m2_acc / cols as f32;
        let rstd = 1.0 / (var + eps).sqrt();
        let mean8 = F32x8::splat(mean_acc);
        let rstd8 = F32x8::splat(rstd);
        let gb = gamma[..head]
            .chunks_exact(LANES)
            .zip(beta[..head].chunks_exact(LANES));
        for ((oc, xc), (gc, bc)) in orow[..head]
            .chunks_exact_mut(LANES)
            .zip(xrow[..head].chunks_exact(LANES))
            .zip(gb)
        {
            let nv = (F32x8::load(xc) - mean8) * rstd8 * F32x8::load(gc) + F32x8::load(bc);
            nv.store(oc);
        }
        for ((o, &xv), (&g, &b)) in orow[head..]
            .iter_mut()
            .zip(&xrow[head..])
            .zip(gamma[head..].iter().zip(beta[head..].iter()))
        {
            *o = (xv - mean_acc) * rstd * g + b;
        }
    }
}

/// Lane Adam over one band: identical per-element op sequence as the
/// oracle (same constant folds, same evaluation order), 8 elements at a
/// time plus a scalar tail — bit-for-bit by construction.
fn adam_band(step: usize, lr: f32, p: &mut [f32], g: &[f32], m: &mut [f32], v: &mut [f32]) {
    let t = step as f32;
    let bc1 = 1.0 - BETA1.powf(t);
    let bc2 = 1.0 - BETA2.powf(t);
    let head = p.len() - p.len() % LANES;
    let (ph, pt) = p.split_at_mut(head);
    let (gh, gt) = g.split_at(head);
    let (mh, mt) = m.split_at_mut(head);
    let (vh, vt) = v.split_at_mut(head);
    let b1 = F32x8::splat(BETA1);
    let ob1 = F32x8::splat(1.0 - BETA1);
    let b2 = F32x8::splat(BETA2);
    let ob2 = F32x8::splat(1.0 - BETA2);
    let bc1v = F32x8::splat(bc1);
    let bc2v = F32x8::splat(bc2);
    let lr8 = F32x8::splat(lr);
    let eps8 = F32x8::splat(EPS);
    let lanes = ph
        .chunks_exact_mut(LANES)
        .zip(gh.chunks_exact(LANES))
        .zip(mh.chunks_exact_mut(LANES))
        .zip(vh.chunks_exact_mut(LANES));
    for (((pc, gc), mc), vc) in lanes {
        let gv = F32x8::load(gc);
        let mv = b1 * F32x8::load(mc) + ob1 * gv;
        let vv = b2 * F32x8::load(vc) + ob2 * gv * gv;
        mv.store(mc);
        vv.store(vc);
        let mhat = mv / bc1v;
        let vhat = vv / bc2v;
        let upd = lr8 * mhat / (vhat.sqrt() + eps8);
        let pv = F32x8::load(pc) - upd;
        pv.store(pc);
    }
    for (((pi, &gi), mi), vi) in pt.iter_mut().zip(gt).zip(mt.iter_mut()).zip(vt.iter_mut()) {
        *mi = BETA1 * *mi + (1.0 - BETA1) * gi;
        *vi = BETA2 * *vi + (1.0 - BETA2) * gi * gi;
        let mhat = *mi / bc1;
        let vhat = *vi / bc2;
        *pi -= lr * mhat / (vhat.sqrt() + EPS);
    }
}

/// Lane `dst += src` over one band (elementwise — bit-invariant to
/// banding and lane width).
fn add_band(dst: &mut [f32], src: &[f32]) {
    let head = dst.len() - dst.len() % LANES;
    for (dc, sc) in dst[..head]
        .chunks_exact_mut(LANES)
        .zip(src[..head].chunks_exact(LANES))
    {
        let sv = F32x8::load(dc) + F32x8::load(sc);
        sv.store(dc);
    }
    for (d, &s) in dst[head..].iter_mut().zip(&src[head..]) {
        *d += s;
    }
}

/// Lane `dst *= s` over one band.
fn scale_band(dst: &mut [f32], s: f32) {
    let head = dst.len() - dst.len() % LANES;
    let s8 = F32x8::splat(s);
    for dc in dst[..head].chunks_exact_mut(LANES) {
        let sv = F32x8::load(dc) * s8;
        sv.store(dc);
    }
    for d in dst[head..].iter_mut() {
        *d *= s;
    }
}
