//! Pluggable device backends for the kernel plane.
//!
//! Everything numerical that PR 5 brought on-host — fused softmax,
//! chunked-Welford LayerNorm, fused Adam, and the elementwise helpers
//! behind tensor `add_assign`/`scale` and the ring all-reduce — now
//! dispatches through the [`DeviceBackend`] trait instead of naming a
//! kernel function. Three implementations ship:
//!
//! * [`ScalarHost`] (`"scalar"`) — the PR 5 kernels unchanged, kept as
//!   the **bit-exact oracle** every other backend is validated against.
//! * [`SimdHost`] (`"simd"`, the default) — explicit f32x8 lanes with a
//!   scalar tail, plus within-op row threading on the rank-executor
//!   thread budget. Softmax, Adam, and the elementwise helpers are
//!   **bit-for-bit equal** to the oracle at any thread count (shared
//!   polynomial exp, order-preserving reductions); LayerNorm uses wider
//!   Welford lanes and matches to tolerance.
//! * [`XlaStubHost`] (`"xla-stub"`) — the device plane for the stub
//!   `xla` crate: until real PJRT device kernels are linked it lowers
//!   every call to the host fused path.
//!
//! Selection precedence: `--device-backend` flag > `FASTFOLD_BACKEND`
//! env > `[device] backend` config > the `"simd"` default. The planner,
//! engine, daemon, and trainer only ever call [`current`] (or the
//! tensor-level helpers below) — the `backend-bypass` lint keeps direct
//! kernel calls out of the rest of the tree.

mod scalar;
#[cfg(feature = "simd")]
mod simd;
mod xla;

pub use scalar::ScalarHost;
#[cfg(feature = "simd")]
pub use simd::{SimdHost, F32X8_LANES};
pub use xla::XlaStubHost;

use crate::error::{Error, Result};
use crate::tensor::HostTensor;
use std::sync::atomic::{AtomicU8, AtomicUsize, Ordering};

/// The kernel-plane contract every backend implements. Slice-level ops
/// mirror the [`crate::kernels`] signatures (including their panic
/// contracts on shape mismatch); backends may differ in throughput and
/// thread use but must stay within the equivalence guarantees spelled
/// out on [`crate::device`] (bit-for-bit for softmax/Adam/elementwise,
/// tolerance for LayerNorm).
pub trait DeviceBackend: Send + Sync {
    /// Stable short name (`"scalar"`, `"simd"`, `"xla-stub"`).
    fn name(&self) -> &'static str;

    /// Fused row softmax: `out[r] = softmax(x[r] · scale)` per
    /// `cols`-length row.
    fn softmax_rows(&self, x: &[f32], cols: usize, scale: f32, out: &mut [f32]);

    /// Fused LayerNorm over `cols`-length rows with the `gamma`/`beta`
    /// affine.
    fn layernorm_rows(
        &self,
        x: &[f32],
        cols: usize,
        gamma: &[f32],
        beta: &[f32],
        eps: f32,
        out: &mut [f32],
    );

    /// One fused Adam update at (1-based) `step`, updating `p`, `m`,
    /// `v` in place.
    fn adam_step(
        &self,
        step: usize,
        lr: f32,
        p: &mut [f32],
        g: &[f32],
        m: &mut [f32],
        v: &mut [f32],
    );

    /// Elementwise `dst += src` (tensor reductions, ring all-reduce
    /// accumulate).
    fn add_assign(&self, dst: &mut [f32], src: &[f32]);

    /// Elementwise `dst *= s`.
    fn scale(&self, dst: &mut [f32], s: f32);

    /// Cast every element to its nearest bf16-representable value
    /// (round-to-nearest-even), keeping f32 storage — the mixed-precision
    /// plane's gradient cast.
    fn bf16_round(&self, dst: &mut [f32]);

    /// Pack f32s into bf16 wire halves (RNE per element) — what a bf16
    /// collective puts on the wire at 2 bytes/element.
    fn bf16_pack(&self, src: &[f32], dst: &mut [u16]);

    /// Widen bf16 wire halves back to f32 (exact).
    fn bf16_unpack(&self, src: &[u16], dst: &mut [f32]);

    /// bf16-accumulate: `dst += widen(src)` with f32 accumulation (the
    /// bf16 ring all-reduce's reduction primitive).
    fn add_assign_bf16(&self, dst: &mut [f32], src: &[u16]);
}

/// Backend selector — the parsed form of the `[device] backend` config
/// string / `--device-backend` flag / `FASTFOLD_BACKEND` env value.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DeviceKind {
    /// The bit-exact scalar oracle.
    Scalar,
    /// The f32x8 lane fast path (process default).
    Simd,
    /// The stub xla device plane.
    XlaStub,
}

impl DeviceKind {
    /// Parse a backend name; rejects unknown names with a `Config`
    /// error listing the valid set.
    pub fn parse(name: &str) -> Result<Self> {
        match name {
            "scalar" => Ok(DeviceKind::Scalar),
            "simd" => Ok(DeviceKind::Simd),
            "xla-stub" => Ok(DeviceKind::XlaStub),
            other => Err(Error::Config(format!(
                "unknown device backend {other:?} (expected scalar, simd, or xla-stub)"
            ))),
        }
    }

    /// The canonical name [`parse`](Self::parse) accepts.
    pub fn name(self) -> &'static str {
        match self {
            DeviceKind::Scalar => "scalar",
            DeviceKind::Simd => "simd",
            DeviceKind::XlaStub => "xla-stub",
        }
    }

    fn code(self) -> u8 {
        match self {
            DeviceKind::Scalar => 1,
            DeviceKind::Simd => 2,
            DeviceKind::XlaStub => 3,
        }
    }
}

static SCALAR: ScalarHost = ScalarHost;
#[cfg(feature = "simd")]
static SIMD: SimdHost = SimdHost::auto();
static XLA: XlaStubHost = XlaStubHost;

/// 0 = not yet resolved (first [`active_kind`] read consults
/// `FASTFOLD_BACKEND`); otherwise a [`DeviceKind::code`].
static ACTIVE_KIND: AtomicU8 = AtomicU8::new(0);
/// Within-op worker budget for the auto-configured SIMD backend. Stays
/// 1 (sequential) until [`configure`] installs the rank-executor
/// budget — library consumers and tests never spawn surprise threads.
static ACTIVE_THREADS: AtomicUsize = AtomicUsize::new(1);

/// Resolve a backend from the selection chain: explicit CLI `flag`
/// first, then `FASTFOLD_BACKEND`, then the `config` string (whose
/// default is `"simd"`). Unknown names error at whichever layer named
/// them.
pub fn resolve_kind(flag: Option<&str>, config: &str) -> Result<DeviceKind> {
    if let Some(name) = flag {
        return DeviceKind::parse(name);
    }
    if let Ok(name) = std::env::var("FASTFOLD_BACKEND") {
        if !name.is_empty() {
            return DeviceKind::parse(&name);
        }
    }
    DeviceKind::parse(config)
}

/// Install `kind` as the process-wide dispatch target and `threads` as
/// the within-op worker budget (callers pass the rank executor's
/// resolved budget so one rank's kernel call can saturate the cores
/// the run was granted).
pub fn configure(kind: DeviceKind, threads: usize) {
    ACTIVE_THREADS.store(threads.max(1), Ordering::Relaxed);
    ACTIVE_KIND.store(kind.code(), Ordering::Relaxed);
}

/// The currently selected backend kind. Before any [`configure`] call
/// this resolves once from `FASTFOLD_BACKEND` (falling back to the
/// `"simd"` default), so library consumers honor the env contract
/// without CLI involvement.
pub fn active_kind() -> DeviceKind {
    match ACTIVE_KIND.load(Ordering::Relaxed) {
        1 => DeviceKind::Scalar,
        2 => DeviceKind::Simd,
        3 => DeviceKind::XlaStub,
        _ => {
            let kind = std::env::var("FASTFOLD_BACKEND")
                .ok()
                .and_then(|s| DeviceKind::parse(&s).ok())
                .unwrap_or(DeviceKind::Simd);
            ACTIVE_KIND.store(kind.code(), Ordering::Relaxed);
            kind
        }
    }
}

/// The installed within-op worker budget (see [`configure`]).
pub fn active_threads() -> usize {
    ACTIVE_THREADS.load(Ordering::Relaxed).max(1)
}

/// The static backend instance for `kind`. Without the `simd` cargo
/// feature the SIMD selection portably falls back to the scalar oracle.
pub fn backend_for(kind: DeviceKind) -> &'static dyn DeviceBackend {
    match kind {
        DeviceKind::Scalar => &SCALAR,
        #[cfg(feature = "simd")]
        DeviceKind::Simd => &SIMD,
        #[cfg(not(feature = "simd"))]
        DeviceKind::Simd => &SCALAR,
        DeviceKind::XlaStub => &XLA,
    }
}

/// The active backend — the only entry point the planner, engine,
/// daemon, trainer, and tensor wrappers use.
pub fn current() -> &'static dyn DeviceBackend {
    backend_for(active_kind())
}

/// A SIMD backend pinned to exactly `threads` within-op workers, for
/// bench ratio/scaling probes.
#[cfg(feature = "simd")]
pub fn simd_backend_with_threads(threads: usize) -> Box<dyn DeviceBackend> {
    Box::new(SimdHost::with_threads(threads))
}

/// Without the `simd` cargo feature the pinned-thread probe falls back
/// to the scalar oracle, so bench harnesses keep their shape either way.
#[cfg(not(feature = "simd"))]
pub fn simd_backend_with_threads(_threads: usize) -> Box<dyn DeviceBackend> {
    Box::new(ScalarHost)
}

// ---------------------------------------------------------------- tensors
//
// Tensor-level plumbing: the only place outside the backend impls that
// touches raw mutable views. Keeping it here means the rest of the tree
// (tensor wrappers, trainer) never pairs `data_mut` with math — which is
// exactly what the backend-bypass lint checks.

/// Elementwise `dst += src` through the active backend (copy-on-write
/// if `dst`'s storage is shared). Shape checks stay with the caller
/// ([`HostTensor::add_assign`]).
pub fn add_assign_tensor(dst: &mut HostTensor, src: &HostTensor) {
    // lint:allow(backend) — device-plane plumbing owns the raw views
    current().add_assign(dst.data_mut(), src.data());
}

/// Elementwise `dst *= s` through the active backend (copy-on-write if
/// `dst`'s storage is shared).
pub fn scale_tensor(dst: &mut HostTensor, s: f32) {
    // lint:allow(backend) — device-plane plumbing owns the raw views
    current().scale(dst.data_mut(), s);
}

/// Round every element of `dst` to the nearest bf16-representable value
/// through the active backend (copy-on-write if storage is shared).
/// Used by the mixed-precision trainer to emulate bf16 gradient storage
/// without leaving the device plane.
pub fn bf16_round_tensor(dst: &mut HostTensor) {
    // lint:allow(backend) — device-plane plumbing owns the raw views
    current().bf16_round(dst.data_mut());
}

/// One fused Adam update on tensor state through the active backend.
/// Length mismatches panic with the kernel-plane message (callers own
/// shape checks, as with the slice-level kernels).
pub fn adam_update_tensors(
    step: usize,
    lr: f32,
    p: &mut HostTensor,
    g: &HostTensor,
    m: &mut HostTensor,
    v: &mut HostTensor,
) {
    // lint:allow(backend) — device-plane plumbing owns the raw views
    current().adam_step(step, lr, p.data_mut(), g.data(), m.data_mut(), v.data_mut());
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kind_parse_roundtrip() {
        for kind in [DeviceKind::Scalar, DeviceKind::Simd, DeviceKind::XlaStub] {
            assert_eq!(DeviceKind::parse(kind.name()).unwrap(), kind);
        }
        assert!(DeviceKind::parse("cuda").is_err());
        assert!(DeviceKind::parse("").is_err());
    }

    #[test]
    fn flag_beats_config() {
        // the env leg is process-global, so only the flag/config legs are
        // pinned here; resolve_kind's env handling is covered by the CI
        // backend matrix
        assert_eq!(resolve_kind(Some("scalar"), "simd").unwrap(), DeviceKind::Scalar);
        assert!(resolve_kind(Some("cuda"), "simd").is_err());
    }

    #[test]
    fn backends_report_their_names() {
        assert_eq!(backend_for(DeviceKind::Scalar).name(), "scalar");
        assert_eq!(backend_for(DeviceKind::XlaStub).name(), "xla-stub");
        #[cfg(feature = "simd")]
        assert_eq!(backend_for(DeviceKind::Simd).name(), "simd");
    }

    #[test]
    fn bf16_paths_agree_across_backends() {
        let xs: Vec<f32> = (0..300).map(|i| (i as f32 - 150.0) * 0.917).collect();
        let oracle = &SCALAR;
        let mut want_round = xs.clone();
        oracle.bf16_round(&mut want_round);
        let mut want_packed = vec![0u16; xs.len()];
        oracle.bf16_pack(&xs, &mut want_packed);
        for kind in [DeviceKind::Scalar, DeviceKind::Simd, DeviceKind::XlaStub] {
            let be = backend_for(kind);
            let mut r = xs.clone();
            be.bf16_round(&mut r);
            assert_eq!(r, want_round, "{}", be.name());
            let mut p = vec![0u16; xs.len()];
            be.bf16_pack(&xs, &mut p);
            assert_eq!(p, want_packed, "{}", be.name());
            let mut w = vec![0f32; xs.len()];
            be.bf16_unpack(&p, &mut w);
            assert_eq!(w, want_round, "{}", be.name());
            let mut acc = vec![0.5f32; xs.len()];
            be.add_assign_bf16(&mut acc, &p);
            for (a, &r) in acc.iter().zip(&want_round) {
                assert_eq!(a.to_bits(), (0.5 + r).to_bits(), "{}", be.name());
            }
        }
    }

    #[test]
    fn tensor_helpers_dispatch() {
        let mut a = HostTensor::new(vec![2, 2], vec![1.0, 2.0, 3.0, 4.0]).unwrap();
        let b = HostTensor::new(vec![2, 2], vec![0.5; 4]).unwrap();
        add_assign_tensor(&mut a, &b);
        assert_eq!(a.data(), &[1.5, 2.5, 3.5, 4.5]);
        scale_tensor(&mut a, 2.0);
        assert_eq!(a.data(), &[3.0, 5.0, 7.0, 9.0]);
    }
}
