//! Minimal recursive-descent JSON parser + writer.
//!
//! The build environment is fully offline (no serde available), and the
//! only JSON we consume is our own `artifacts/manifest.json`, so a small,
//! strict RFC-8259-subset parser is the right tool: objects, arrays,
//! strings (with escapes), numbers, booleans, null.

use crate::error::{Error, Result};
use std::collections::BTreeMap;
use std::fmt::Write as _;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(s: &str) -> Result<Json> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(Error::Json(format!("trailing data at byte {}", p.i)));
        }
        Ok(v)
    }

    // --- typed accessors -------------------------------------------------

    pub fn get(&self, key: &str) -> Result<&Json> {
        match self {
            Json::Obj(m) => m
                .get(key)
                .ok_or_else(|| Error::Json(format!("missing key '{key}'"))),
            _ => Err(Error::Json(format!("not an object (want key '{key}')"))),
        }
    }

    pub fn opt(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => Err(Error::Json(format!("not a string: {self:?}"))),
        }
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Json::Bool(b) => Ok(*b),
            _ => Err(Error::Json(format!("not a bool: {self:?}"))),
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(n) => Ok(*n),
            _ => Err(Error::Json(format!("not a number: {self:?}"))),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        Ok(self.as_f64()? as usize)
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(a) => Ok(a),
            _ => Err(Error::Json(format!("not an array: {self:?}"))),
        }
    }

    pub fn as_obj(&self) -> Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Ok(m),
            _ => Err(Error::Json(format!("not an object: {self:?}"))),
        }
    }

    // --- writer ----------------------------------------------------------
    // serialization goes through `Display` (so `.to_string()` comes from
    // the std blanket impl instead of an inherent method clippy rejects)

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\t' => out.push_str("\\t"),
                        '\r' => out.push_str("\\r"),
                        c if (c as u32) < 0x20 => {
                            let _ = write!(out, "\\u{:04x}", c as u32);
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

impl std::fmt::Display for Json {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let mut s = String::new();
        self.write(&mut s);
        f.write_str(&s)
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl Parser<'_> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b
            .get(self.i)
            .copied()
            .ok_or_else(|| Error::Json("unexpected end of input".into()))
    }

    fn expect(&mut self, c: u8) -> Result<()> {
        if self.peek()? != c {
            return Err(Error::Json(format!(
                "expected '{}' at byte {}, found '{}'",
                c as char, self.i, self.b[self.i] as char
            )));
        }
        self.i += 1;
        Ok(())
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            _ => self.number(),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(Error::Json(format!("bad literal at byte {}", self.i)))
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                c => {
                    return Err(Error::Json(format!(
                        "expected ',' or '}}' at byte {}, found '{}'",
                        self.i, c as char
                    )))
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut a = Vec::new();
        self.ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(a));
        }
        loop {
            self.ws();
            a.push(self.value()?);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(a));
                }
                c => {
                    return Err(Error::Json(format!(
                        "expected ',' or ']' at byte {}, found '{}'",
                        self.i, c as char
                    )))
                }
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                return Err(Error::Json("bad \\u escape".into()));
                            }
                            let hex =
                                std::str::from_utf8(&self.b[self.i..self.i + 4])
                                    .map_err(|_| Error::Json("bad \\u".into()))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| Error::Json("bad \\u".into()))?;
                            self.i += 4;
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                        }
                        _ => return Err(Error::Json("bad escape".into())),
                    }
                }
                c => {
                    // re-decode UTF-8 continuation bytes faithfully
                    if c < 0x80 {
                        s.push(c as char);
                    } else {
                        let start = self.i - 1;
                        let len = if c >= 0xF0 {
                            4
                        } else if c >= 0xE0 {
                            3
                        } else {
                            2
                        };
                        let chunk = self
                            .b
                            .get(start..start + len)
                            .ok_or_else(|| Error::Json("bad utf8".into()))?;
                        let st = std::str::from_utf8(chunk)
                            .map_err(|_| Error::Json("bad utf8".into()))?;
                        s.push_str(st);
                        self.i = start + len;
                    }
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i])
            .map_err(|_| Error::Json("bad number".into()))?;
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| Error::Json(format!("bad number '{s}' at byte {start}")))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let src = r#"{"a": [1, 2.5, -3e2], "b": {"c": "x\"y\n", "d": true}, "e": null}"#;
        let v = Json::parse(src).unwrap();
        let v2 = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
        assert_eq!(v.get("a").unwrap().as_arr().unwrap()[1].as_f64().unwrap(), 2.5);
        assert_eq!(
            v.get("b").unwrap().get("c").unwrap().as_str().unwrap(),
            "x\"y\n"
        );
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("nulll").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn unicode() {
        let v = Json::parse(r#""é café — ok""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "é café — ok");
    }

    #[test]
    fn bool_accessor() {
        let v = Json::parse(r#"{"a": true, "b": 1}"#).unwrap();
        assert!(v.get("a").unwrap().as_bool().unwrap());
        assert!(v.get("b").unwrap().as_bool().is_err());
    }

    #[test]
    fn display_matches_writer() {
        let v = Json::parse(r#"{"a": [1, "x"], "b": false}"#).unwrap();
        assert_eq!(format!("{v}"), v.to_string());
        assert_eq!(Json::parse(&format!("{v}")).unwrap(), v);
    }

    #[test]
    fn empty_containers() {
        assert_eq!(Json::parse("{}").unwrap(), Json::Obj(Default::default()));
        assert_eq!(Json::parse("[]").unwrap(), Json::Arr(vec![]));
    }
}
