//! `fastfold` — the L3 launcher/CLI.
//!
//! ```text
//! fastfold train     [--preset tiny] [--steps N] [--dp N] [--dap N]
//!                    [--accum N] [--threads N] [--backend synthetic]
//!                    [--precision f32|bf16] [--prefetch] [--bucket-mb F]
//!                    [--checkpoint-dir D] [--resume] [--faults f.jsonl]
//!                    [--config f.toml]
//! fastfold scale     [--gpus N] [--dap N] [--gpu a100_40g]
//! fastfold infer     [--preset tiny] [--len N] [--dap N] [--threads N]
//!                    [--naive] [--gpu a100_40g] [--no-guard] [--config f.toml]
//! fastfold serve     --requests reqs.jsonl [--policy fifo|sjf] [--threads N]
//!                    [--gpu a100_40g] [--max-dap N] [--dry-run] [--config f.toml]
//! fastfold daemon    --trace trace.jsonl [--modeled] [--lanes N] [--queue-cap N]
//!                    [--cache-gb F] [--policy fifo|sjf] [--threads N]
//!                    [--faults f.jsonl] [--bench-out FILE] [--config f.toml]
//! fastfold loadgen   [--requests N] [--seed S] [--quick] [--lanes N]
//!                    [--out trace.jsonl] [--no-replay] [--queue-cap N]
//!                    [--cache-gb F] [--faults f.jsonl]
//!                    [--bench-out BENCH_serve.json] [--json]
//! fastfold chaos     [--seed S] [--steps N] [--dp N] [--transients N]
//!                    [--serve-events N] [--out faults.jsonl] [--base-hours H]
//! fastfold autochunk [--len N] [--seq N] [--dap N] [--gpu a100_40g]
//!                    [--headroom F] [--json] [--config f.toml]
//! fastfold bench     [--json] [--out BENCH_host.json] [--quick]
//!                    [--train] [--train-out BENCH_train.json]
//! fastfold verify    [--preset P] [--dap N] [--all] [--json FILE]
//! fastfold lint      [--src DIR]
//! fastfold report    <table2|table3|table4|table5|fig10|fig11|fig13|validate>
//! fastfold info
//! ```
//!
//! `verify` runs the static schedule verifier (the same pass the planner,
//! trainer, and daemon run as a mandatory admission gate; skip it at your
//! own risk with `--unsafe-skip-verify` on those commands); `lint` scans
//! the source tree for banned nondeterminism patterns; `chaos` synthesizes
//! a seeded fault schedule for `--faults` and projects the modeled
//! wall-clock inflation of the paper's 67-hour run under a finite MTBF.
//!
//! The `report` subcommands print console reproductions of every paper
//! table/figure that is model-driven; the executed benches live under
//! `cargo bench` (see rust/benches/).

use fastfold::config::{ModelConfig, RunConfig};
use fastfold::dap::DapCoordinator;
use fastfold::error::Result;
use fastfold::faults::FaultSchedule;
use fastfold::inference::engine::{
    daemon, loadgen, plan_batch, BackendKind, DaemonConfig, Engine, InferRequest, LoadgenSpec,
    PlacementPlanner, SchedPolicy, TraceEvent,
};
use fastfold::inference::{autochunk, chunking};
use fastfold::metrics::{fmt_bytes, fmt_secs, Table};
use fastfold::perfmodel::gpu::ImplProfile;
use fastfold::perfmodel::scaling::{MpMethod, ScalingModel, INFER_RECYCLES};
use fastfold::perfmodel::{GpuSpec, MemoryModel};
use fastfold::runtime::Runtime;
use fastfold::tp::TpCoordinator;
use fastfold::train::{DataGen, ParallelPlan, SyntheticBackend, TrainBackend, Trainer};
use std::collections::BTreeMap;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = run(&args) {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

fn parse_flags(args: &[String]) -> (Vec<String>, BTreeMap<String, String>) {
    let mut pos = Vec::new();
    let mut flags = BTreeMap::new();
    let mut i = 0;
    while i < args.len() {
        if let Some(name) = args[i].strip_prefix("--") {
            if i + 1 < args.len() && !args[i + 1].starts_with("--") {
                flags.insert(name.to_string(), args[i + 1].clone());
                i += 2;
            } else {
                flags.insert(name.to_string(), "true".to_string());
                i += 1;
            }
        } else {
            pos.push(args[i].clone());
            i += 1;
        }
    }
    (pos, flags)
}

fn run(args: &[String]) -> Result<()> {
    let (pos, flags) = parse_flags(args);
    let cmd = pos.first().map(|s| s.as_str()).unwrap_or("help");
    match cmd {
        "train" => cmd_train(&pos, &flags),
        "scale" => cmd_scale(&flags),
        "infer" => cmd_infer(&flags),
        "serve" => cmd_serve(&flags),
        "daemon" => cmd_daemon(&flags),
        "loadgen" => cmd_loadgen(&flags),
        "autochunk" => cmd_autochunk(&flags),
        "chaos" => cmd_chaos(&flags),
        "bench" => cmd_bench(&flags),
        "verify" => cmd_verify(&flags),
        "lint" => cmd_lint(&flags),
        "report" => cmd_report(&pos, &flags),
        "info" => cmd_info(&flags),
        _ => {
            println!(
                "fastfold — FastFold reproduction (see README.md)\n\n\
                 usage:\n  fastfold train  [--preset P] [--steps N] [--dp N] [--dap N] \
                 [--accum N] [--threads N]\n                  [--backend synthetic] \
                 [--precision f32|bf16] [--prefetch] [--bucket-mb F]\n                  \
                 [--checkpoint-dir D] [--resume] [--faults f.jsonl] \
                 [--config f.toml]\n                  \
                 [--device-backend scalar|simd|xla-stub]\n  \
                 fastfold scale  [--gpus N] [--dap N] [--gpu G]\n  \
                 fastfold infer  [--preset P] [--len N] [--dap N] [--threads N] [--naive] \
                 [--gpu G] [--no-guard]\n                  [--device-backend B] \
                 [--config f.toml]\n  \
                 fastfold serve  --requests reqs.jsonl [--policy fifo|sjf] [--threads N] \
                 [--gpu G] [--max-dap N] [--dry-run] [--config f.toml]\n  \
                 fastfold daemon --trace trace.jsonl [--modeled] [--lanes N] \
                 [--queue-cap N] [--cache-gb F]\n                  [--policy fifo|sjf] \
                 [--threads N] [--faults f.jsonl] [--bench-out FILE] \
                 [--config f.toml]\n  \
                 fastfold loadgen [--requests N] [--seed S] [--quick] [--lanes N] \
                 [--out trace.jsonl]\n                  [--no-replay] [--queue-cap N] \
                 [--cache-gb F] [--faults f.jsonl] [--bench-out BENCH_serve.json] \
                 [--json]\n  \
                 fastfold chaos  [--seed S] [--steps N] [--dp N] [--transients N] \
                 [--serve-events N]\n                  [--out faults.jsonl] \
                 [--base-hours H]\n  \
                 fastfold autochunk [--len N] [--seq N] [--dap N] [--gpu G] \
                 [--headroom F] [--json] [--config f.toml]\n  \
                 fastfold bench  [--json] [--out BENCH_host.json] [--quick] \
                 [--device-backend B]\n                  \
                 [--train] [--train-out BENCH_train.json]\n  \
                 fastfold verify [--preset P] [--dap N] [--all] [--json FILE]\n  \
                 fastfold lint   [--src DIR]\n  \
                 fastfold report <table2|table3|table4|table5|fig10|fig11|fig13|validate>\n  \
                 fastfold info   [--artifacts DIR]"
            );
            Ok(())
        }
    }
}

fn artifacts_dir(flags: &BTreeMap<String, String>) -> String {
    flags.get("artifacts").cloned().unwrap_or_else(|| "artifacts".into())
}

/// Resolve the device backend (`--device-backend` > `FASTFOLD_BACKEND`
/// env > `[device] backend` config > default) and install it as the
/// process-wide kernel dispatch target, with the within-op thread budget
/// taken from the resolved `[parallel] threads`. The canonical name is
/// written back into the config so downstream consumers (placement
/// planner, perf model) price the backend that actually runs.
fn apply_device_backend(
    run_cfg: &mut RunConfig,
    flags: &BTreeMap<String, String>,
) -> Result<()> {
    let kind = fastfold::device::resolve_kind(
        flags.get("device-backend").map(|s| s.as_str()),
        &run_cfg.device.backend,
    )?;
    run_cfg.device.backend = kind.name().to_string();
    fastfold::device::configure(kind, run_cfg.parallel.resolve_threads());
    Ok(())
}

/// Install the `[comm]` bounded-wait budget as the process-wide comm
/// worker timeout before any collective is scheduled (0 = unbounded).
fn apply_comm_config(run_cfg: &RunConfig) {
    fastfold::comm::worker::set_wait_timeout_ms(run_cfg.comm.wait_timeout_ms);
}

// ---------------------------------------------------------------- train

fn cmd_train(_pos: &[String], flags: &BTreeMap<String, String>) -> Result<()> {
    let mut run_cfg = match flags.get("config") {
        Some(path) => RunConfig::from_toml_file(path)?,
        None => RunConfig::default(),
    };
    if let Some(p) = flags.get("preset") {
        run_cfg.preset = p.clone();
    }
    run_cfg.train.steps = num_flag(flags, "steps", run_cfg.train.steps)?;
    run_cfg.parallel.dp_size = num_flag(flags, "dp", run_cfg.parallel.dp_size)?;
    run_cfg.parallel.dap_size = num_flag(flags, "dap", run_cfg.parallel.dap_size)?;
    run_cfg.parallel.accum = num_flag(flags, "accum", run_cfg.parallel.accum)?;
    if let Some(t) = flags.get("threads") {
        run_cfg.parallel.threads = t
            .parse()
            .map_err(|_| fastfold::Error::Config(format!("--threads: invalid value '{t}'")))?;
    }
    if flags.contains_key("no-overlap") {
        run_cfg.parallel.overlap = false;
    }
    if let Some(dir) = flags.get("checkpoint-dir") {
        run_cfg.train.checkpoint_dir = Some(dir.clone());
    }
    run_cfg.train.checkpoint_every =
        num_flag(flags, "checkpoint-every", run_cfg.train.checkpoint_every)?;
    if let Some(p) = flags.get("precision") {
        run_cfg.train.precision = fastfold::config::Precision::parse(p)?;
    }
    if flags.contains_key("prefetch") {
        run_cfg.train.prefetch = true;
    }
    if let Some(mb) = flags.get("bucket-mb") {
        let mb: f64 = mb.parse().map_err(|_| {
            fastfold::Error::Config(format!("--bucket-mb: invalid value '{mb}'"))
        })?;
        if !(mb > 0.0) {
            return Err(fastfold::Error::Config(
                "--bucket-mb must be > 0".into(),
            ));
        }
        run_cfg.train.bucket_mb = Some(mb);
    }
    apply_device_backend(&mut run_cfg, flags)?;
    apply_comm_config(&run_cfg);

    let plan = ParallelPlan::from_config(&run_cfg.parallel);
    let model_cfg = ModelConfig::preset(&run_cfg.preset)?;
    plan.validate(&model_cfg)?;
    // mandatory admission: prove the DAP program (fwd + bwd) hazard-free
    // before any executable is loaded
    if flags.contains_key("unsafe-skip-verify") {
        eprintln!(
            "[fastfold] warning: --unsafe-skip-verify: static schedule \
             admission skipped"
        );
    } else {
        let us = plan.admit_schedule(&model_cfg)?;
        if plan.dap > 1 {
            println!(
                "[fastfold] schedule admission: canonical DAP program \
                 (fwd+bwd) proven hazard-free at dap={} in {us} us",
                plan.dap
            );
        }
    }
    // modeled memory-fit advisory against the configured device (the host
    // testbed executes regardless — the verdict is what a fleet would hit)
    let gpu = GpuSpec::by_name(&run_cfg.autochunk.gpu)?;
    if let Err(e) = plan.check_memory(&model_cfg, &MemoryModel::default(), &gpu) {
        println!("[fastfold] warning: modeled training memory: {e}");
    }

    let synthetic = match flags.get("backend").map(|s| s.as_str()) {
        None | Some("pjrt") => false,
        Some("synthetic") => true,
        Some(other) => {
            return Err(fastfold::Error::Config(format!(
                "--backend: unknown value '{other}' (pjrt|synthetic)"
            )))
        }
    };
    if synthetic {
        // artifact-free pipeline smoke: host-math backend, same
        // orchestration (plan, accumulation, ring, Adam, checkpoints)
        let params = SyntheticBackend::init_params(&model_cfg);
        let backend: Box<dyn TrainBackend> =
            Box::new(SyntheticBackend::new(plan.dap));
        let mut trainer = Trainer::with_backend(
            &run_cfg.preset,
            model_cfg,
            params,
            backend,
            plan,
            run_cfg.train.clone(),
        )?;
        drive_train(&mut trainer, &run_cfg, flags, "host-synthetic")
    } else {
        let rt = Runtime::new(&artifacts_dir(flags))?;
        let platform = rt.platform();
        let mut trainer = Trainer::hybrid(
            &rt,
            &run_cfg.preset,
            plan,
            run_cfg.parallel.overlap,
            run_cfg.train.clone(),
        )?;
        drive_train(&mut trainer, &run_cfg, flags, &platform)
    }
}

/// Shared train driver: optional checkpoint resume, the run itself, and
/// the report line (actual executed steps, applied LR, DP vs DAP wire).
fn drive_train(
    trainer: &mut Trainer<'_>,
    run_cfg: &RunConfig,
    flags: &BTreeMap<String, String>,
    platform: &str,
) -> Result<()> {
    use fastfold::train::checkpoint;
    if flags.contains_key("resume") {
        let dir = run_cfg.train.checkpoint_dir.as_ref().ok_or_else(|| {
            fastfold::Error::Config(
                "--resume needs --checkpoint-dir (or [train] checkpoint_dir)".into(),
            )
        })?;
        match checkpoint::latest_step(dir, trainer.preset())? {
            Some(step) => {
                let state = checkpoint::load_full(dir, trainer.preset(), step)?;
                trainer.restore(state)?;
                println!(
                    "[fastfold] resumed from '{dir}' at step {step} \
                     (stage {}, {} steps into it)",
                    trainer.stage, trainer.steps_in_stage
                );
            }
            None => println!(
                "[fastfold] --resume: no checkpoint for '{}' in '{dir}', \
                 starting fresh",
                trainer.preset()
            ),
        }
    }
    if let Some(path) = flags.get("faults") {
        let src = std::fs::read_to_string(path).map_err(|e| {
            fastfold::Error::Config(format!(
                "--faults: cannot read '{path}': {e}"
            ))
        })?;
        let schedule = FaultSchedule::from_jsonl(&src)?;
        println!(
            "[fastfold] chaos armed: {} train fault event(s) from '{path}' \
             (seed {})",
            schedule.train.len(),
            schedule.seed
        );
        trainer.with_faults(schedule)?;
    }
    println!(
        "[fastfold] training preset='{}' [{}] backend={} steps={} \
         precision={} prefetch={} buckets={} on {}",
        trainer.preset(),
        trainer.plan,
        trainer.backend_name(),
        run_cfg.train.steps,
        run_cfg.train.precision.name(),
        run_cfg.train.prefetch,
        match run_cfg.train.bucket_mb {
            Some(mb) => format!("{mb} MB"),
            None => "off".into(),
        },
        platform,
    );
    let report = trainer.run()?;
    if report.steps == 0 {
        println!(
            "[fastfold] nothing to do: training already at step {} \
             (configured total: {} steps) — raise --steps to continue",
            trainer.step, run_cfg.train.steps
        );
        return Ok(());
    }
    println!(
        "[fastfold] done: loss {:.4} -> {:.4}, {} steps in {} \
         ({:.2} steps/s, final lr {:.2e}; wire: DP {} / DAP {})",
        report.initial_loss,
        report.final_loss,
        report.steps,
        fmt_secs(report.seconds),
        report.steps_per_sec,
        report.final_lr,
        fmt_bytes(report.wire_bytes),
        fmt_bytes(report.wire_dap_bytes),
    );
    if report.comm_seconds > 0.0 || report.prefetch_stall_seconds > 0.0 {
        println!(
            "[fastfold] overlap: {:.1}% of {} DP comm hidden ({} exposed); \
             prefetch stall {}; precision {} ({} skipped steps)",
            100.0 * report.overlap_fraction,
            fmt_secs(report.comm_seconds),
            fmt_secs(report.exposed_comm_seconds),
            fmt_secs(report.prefetch_stall_seconds),
            report.precision,
            report.skipped_steps,
        );
    }
    let rec = &report.recovery;
    if rec.any() {
        println!(
            "[fastfold] recovery: {} retries, {} retransmits, {} comm \
             timeouts, {} stragglers, {} rank crash(es), {} lost steps \
             re-run, {} absorbed",
            rec.retries,
            rec.retransmits,
            rec.comm_timeouts,
            rec.stragglers,
            rec.rank_crashes,
            rec.lost_steps,
            fmt_secs(rec.recovery_seconds),
        );
    }
    // the recovery acceptance line: a faulted run must converge to the
    // same digest as its fault-free twin (CI compares these lines)
    println!(
        "[fastfold] final param crc32 0x{:08x}",
        trainer.params_crc32()
    );
    Ok(())
}

// ---------------------------------------------------------------- scale

/// `fastfold scale --gpus N` — the modeled hybrid DP×DAP scale-out: a
/// sweep of the fleet size up to N with aggregate PFLOP/s and
/// efficiencies, plus the two-stage 67-hour headline at the paper layout.
fn cmd_scale(flags: &BTreeMap<String, String>) -> Result<()> {
    let gpus: usize = num_flag(flags, "gpus", 512)?;
    let dap_ft: usize = num_flag(flags, "dap", 4)?;
    let dap_init: usize = num_flag(flags, "dap-init", 2)?;
    if gpus == 0 || dap_ft == 0 || dap_init == 0 {
        return Err(fastfold::Error::Config("scale: --gpus/--dap must be >= 1".into()));
    }
    if gpus % dap_ft != 0 {
        return Err(fastfold::Error::Config(format!(
            "scale: --gpus {gpus} not divisible by --dap {dap_ft}"
        )));
    }
    if gpus % dap_init != 0 {
        return Err(fastfold::Error::Config(format!(
            "scale: --gpus {gpus} not divisible by --dap-init {dap_init}"
        )));
    }
    let gpu_name = flags.get("gpu").cloned().unwrap_or_else(|| "a100_40g".into());
    let gpu = GpuSpec::by_name(&gpu_name)?;
    let mem = MemoryModel::default();
    let m = ScalingModel::default();
    let p = ImplProfile::fastfold();
    let cfg_ft = ModelConfig::finetune();
    let cfg_init = ModelConfig::initial_training();

    // plan validation per stage: geometry + rank budget + memory fit
    let plan = ParallelPlan::new(gpus / dap_ft, dap_ft, 1);
    plan.validate_for(std::slice::from_ref(&cfg_ft), &mem, &gpu, gpus)?;
    ParallelPlan::new(gpus / dap_init, dap_init, 1).validate_for(
        std::slice::from_ref(&cfg_init),
        &mem,
        &gpu,
        gpus,
    )?;
    let need = plan.train_bytes_per_device(&cfg_ft, &mem);
    println!(
        "fastfold scale — hybrid DP x DAP fine-tuning on up to {gpus} x {} \
         ({:.0} GB)\nplan {plan}: {:.1} GB/device modeled training \
         working set (fits)\n",
        gpu.name,
        gpu.memory / 1e9,
        need / 1e9,
    );

    let mut t = Table::new(&[
        "GPUs", "dap", "dp", "step (s)", "samples/s", "agg PFLOP/s", "DP eff",
        "E2E eff",
    ]);
    let mut n = dap_ft;
    loop {
        let h = m.hybrid_step(&cfg_ft, &p, dap_ft, n / dap_ft, true);
        t.row(&[
            n.to_string(),
            h.dap.to_string(),
            h.dp.to_string(),
            format!("{:.2}", h.step_secs),
            format!("{:.1}", h.samples_per_sec),
            format!("{:.2}", h.aggregate_pflops),
            format!("{:.1}%", 100.0 * h.dp_efficiency),
            format!("{:.1}%", 100.0 * h.end_to_end_efficiency),
        ]);
        if n >= gpus {
            break;
        }
        n = (n * 4).min(gpus);
    }
    t.print();

    // the paper's two-stage layout: replica count capped at 128 nodes
    let dp_init = (gpus / dap_init).min(128);
    let dp_ft = (gpus / dap_ft).min(128);
    let (hi, hf) = m.two_stage_hours(&p, (dap_init, dp_init), (dap_ft, dp_ft));
    let head = m.hybrid_step(&cfg_ft, &p, dap_ft, dp_ft, true);
    let init_head = m.hybrid_step(&cfg_init, &p, dap_init, dp_init, true);
    println!(
        "\ntwo-stage recipe: initial {:.1} h on {} GPUs (dap={dap_init} x \
         dp={dp_init}) + finetune {:.1} h on {} GPUs (dap={dap_ft} x \
         dp={dp_ft})",
        hi,
        dap_init * dp_init,
        hf,
        dap_ft * dp_ft,
    );
    println!(
        "total {:.1} h (paper: 67 h) | finetune aggregate {:.2} PFLOP/s \
         (paper: 6.02) | DP efficiency {:.1}% (paper: 90.1%) | initial-stage \
         DP efficiency {:.1}%",
        hi + hf,
        head.aggregate_pflops,
        100.0 * head.dp_efficiency,
        100.0 * init_head.dp_efficiency,
    );
    Ok(())
}

// ---------------------------------------------------------------- infer

/// Fold the shared infer/serve flag overrides into the run config.
fn apply_engine_flags(
    run_cfg: &mut RunConfig,
    flags: &BTreeMap<String, String>,
) -> Result<()> {
    if let Some(t) = flags.get("threads") {
        run_cfg.parallel.threads = t
            .parse()
            .map_err(|_| fastfold::Error::Config(format!("--threads: invalid value '{t}'")))?;
    }
    if flags.contains_key("no-guard") {
        run_cfg.autochunk.enabled = false;
    }
    if flags.contains_key("no-overlap") {
        run_cfg.parallel.overlap = false;
    }
    if let Some(g) = flags.get("gpu") {
        run_cfg.autochunk.gpu = g.clone();
    }
    if let Some(p) = flags.get("policy") {
        run_cfg.serve.policy = SchedPolicy::parse(p)?;
    }
    if let Some(n) = flags.get("max-dap") {
        let n: usize = n
            .parse()
            .map_err(|_| fastfold::Error::Config(format!("--max-dap: invalid value '{n}'")))?;
        if n == 0 {
            return Err(fastfold::Error::Config("--max-dap must be >= 1".into()));
        }
        run_cfg.serve.max_dap = n;
    }
    apply_device_backend(run_cfg, flags)?;
    apply_comm_config(run_cfg);
    Ok(())
}

/// The `--unsafe-skip-verify` escape hatch (for benchmarking the
/// verifier's own cost): disable the mandatory static schedule admission
/// the planner runs before every DAP placement.
fn apply_verify_flag(
    planner: &mut PlacementPlanner,
    flags: &BTreeMap<String, String>,
) {
    if flags.contains_key("unsafe-skip-verify") {
        eprintln!(
            "[fastfold] warning: --unsafe-skip-verify: static schedule \
             admission disabled"
        );
        planner.verify = false;
    }
}

/// `fastfold infer` — a one-request special case of the serving engine:
/// the placement planner picks (or `--dap N` pins) the backend, the
/// engine executes it, and the legacy advisory/overlap notes print from
/// the outcome.
fn cmd_infer(flags: &BTreeMap<String, String>) -> Result<()> {
    let mut run_cfg = match flags.get("config") {
        Some(path) => RunConfig::from_toml_file(path)?,
        None => RunConfig::default(),
    };
    apply_engine_flags(&mut run_cfg, flags)?;
    let preset = flags.get("preset").cloned().unwrap_or_else(|| "tiny".into());
    let dap: usize = num_flag(flags, "dap", 1)?;

    let mut req = InferRequest::new("cli", &preset);
    req.naive = flags.contains_key("naive");
    req.model_len = match flags.get("len") {
        Some(s) => Some(s.parse().map_err(|_| {
            fastfold::Error::Config(format!("--len: invalid value '{s}'"))
        })?),
        None => None,
    };
    if dap > 1 {
        req.force = Some(BackendKind::Dap(dap));
        // a single-request CLI `--dap N` is an explicit ask, not a fleet
        // placement — keep the legacy behavior of honoring any degree
        run_cfg.serve.max_dap = run_cfg.serve.max_dap.max(dap);
    }

    let rt = Runtime::new(&artifacts_dir(flags))?;
    let mut engine = Engine::new(&rt, &run_cfg)?;
    apply_verify_flag(&mut engine.planner, flags);
    let report = engine.serve(std::slice::from_ref(&req))?;
    let outcome = report
        .outcomes
        .into_iter()
        .next()
        .expect("one request in, one outcome out");
    if let Some(note) = &outcome.note {
        println!("[fastfold] {note}");
    }
    let backend = outcome
        .placement
        .as_ref()
        .map(|p| p.backend.name())
        .unwrap_or_else(|| "-".into());
    let (msa_logits, dist_logits) = outcome.output?;
    println!(
        "[fastfold] inference preset='{preset}' backend={backend} naive={}: \
         msa_logits {:?}, dist_logits {:?} in {}",
        req.naive,
        msa_logits.shape,
        dist_logits.shape,
        fmt_secs(outcome.wall_seconds)
    );
    Ok(())
}

// ---------------------------------------------------------------- serve

/// `fastfold serve --requests <jsonl>` — drain a request batch through
/// the engine: cost-model placement per request, FIFO/SJF scheduling,
/// `--threads`-bounded concurrent execution, per-request + aggregate
/// metrics. `--dry-run` plans and schedules without artifacts.
fn cmd_serve(flags: &BTreeMap<String, String>) -> Result<()> {
    let mut run_cfg = match flags.get("config") {
        Some(path) => RunConfig::from_toml_file(path)?,
        None => RunConfig::default(),
    };
    apply_engine_flags(&mut run_cfg, flags)?;
    let path = flags.get("requests").ok_or_else(|| {
        fastfold::Error::Config("serve: --requests <file.jsonl> is required".into())
    })?;
    let src = std::fs::read_to_string(path).map_err(|e| {
        fastfold::Error::Config(format!("serve: cannot read requests file '{path}': {e}"))
    })?;
    let requests = InferRequest::parse_jsonl(&src)?;
    if requests.is_empty() {
        return Err(fastfold::Error::Config(format!(
            "serve: no requests in '{path}'"
        )));
    }

    if flags.contains_key("dry-run") {
        return serve_dry_run(&run_cfg, &requests, flags);
    }

    let rt = Runtime::new(&artifacts_dir(flags))?;
    let mut engine = Engine::new(&rt, &run_cfg)?;
    apply_verify_flag(&mut engine.planner, flags);
    println!(
        "[fastfold] serving {} requests (policy={}, threads={}, gpu={}, max_dap={})\n",
        requests.len(),
        engine.policy.name(),
        engine.threads,
        engine.planner.gpu.name,
        engine.planner.max_dap,
    );
    let report = engine.serve(&requests)?;
    report.table().print();
    println!();
    for o in &report.outcomes {
        match (&o.output, &o.note) {
            (Err(e), _) => println!("  {}: {e}", o.id),
            (Ok(_), Some(n)) => println!("  {}: {n}", o.id),
            _ => {}
        }
    }
    println!("\n[fastfold] {}", report.summary());
    Ok(())
}

/// Placement + schedule preview (no artifacts, no execution): what the
/// engine *would* do with the batch — backend per request, schedule
/// order, modeled makespan, aggregate modeled PFLOP/s. Runs the same
/// `plan_batch` pipeline as `Engine::serve`, so the preview cannot drift
/// from the executed schedule.
fn serve_dry_run(
    run_cfg: &RunConfig,
    requests: &[InferRequest],
    flags: &BTreeMap<String, String>,
) -> Result<()> {
    let mut planner = PlacementPlanner::from_run_config(run_cfg)?;
    apply_verify_flag(&mut planner, flags);
    let threads = run_cfg.parallel.resolve_threads();
    println!(
        "[fastfold] serve dry-run: {} requests (policy={}, lanes={}, gpu={}, max_dap={})\n",
        requests.len(),
        run_cfg.serve.policy.name(),
        threads,
        planner.gpu.name,
        planner.max_dap,
    );
    let plan = plan_batch(
        &planner,
        run_cfg.serve.policy,
        run_cfg.serve.max_bypass,
        threads,
        requests,
    );
    let stats = plan.stats(requests);
    plan.table(requests).print();
    for line in plan.rejections(requests) {
        println!("  {line}");
    }

    let ids: Vec<&str> = plan.order.iter().map(|&i| requests[i].id.as_str()).collect();
    println!("\nschedule ({}): {}", run_cfg.serve.policy.name(), ids.join(" -> "));
    println!(
        "modeled makespan {} on {} lanes -> aggregate {:.2} PFLOP/s (modeled); backends: {}",
        fmt_secs(plan.modeled_makespan),
        threads,
        stats.aggregate_pflops(plan.modeled_makespan),
        stats.backend_mix(),
    );
    Ok(())
}

// ------------------------------------------------------ daemon / loadgen

/// Shared daemon-knob parsing for `daemon`/`loadgen`: `--queue-cap`
/// and `--cache-gb` override the `[serve]` config before it is folded
/// into a [`DaemonConfig`].
fn apply_daemon_flags(run_cfg: &mut RunConfig, flags: &BTreeMap<String, String>) -> Result<()> {
    run_cfg.serve.queue_cap = num_flag(flags, "queue-cap", run_cfg.serve.queue_cap)?;
    run_cfg.serve.cache_gb = num_flag(flags, "cache-gb", run_cfg.serve.cache_gb)?;
    if !(0.0..=1024.0).contains(&run_cfg.serve.cache_gb) {
        return Err(fastfold::Error::Config(format!(
            "--cache-gb: must be in [0, 1024], got {}",
            run_cfg.serve.cache_gb
        )));
    }
    Ok(())
}

/// `--faults <file.jsonl>`: arm the daemon's deterministic serve-fault
/// schedule — injected backend failures at numbered dispatch attempts,
/// absorbed by retry/fallback/breaker and tallied in the ledger.
fn apply_faults_flag(
    dcfg: &mut DaemonConfig,
    flags: &BTreeMap<String, String>,
) -> Result<()> {
    if let Some(path) = flags.get("faults") {
        let src = std::fs::read_to_string(path).map_err(|e| {
            fastfold::Error::Config(format!(
                "--faults: cannot read '{path}': {e}"
            ))
        })?;
        let schedule = FaultSchedule::from_jsonl(&src)?;
        println!(
            "[fastfold] chaos armed: {} serve fault event(s) from '{path}' \
             (seed {})",
            schedule.serve.len(),
            schedule.seed
        );
        dcfg.faults = Some(schedule);
    }
    Ok(())
}

/// `fastfold daemon --trace <jsonl>` — replay an arrival-timed trace
/// through the continuous-batching daemon: admission, backpressure
/// shedding, deadline expiry, cancellation, starvation-guarded
/// scheduling, and the content-hash result cache all run on the virtual
/// clock. `--modeled` simulates without artifacts; otherwise completed
/// non-cached requests execute on real backends. `--lanes` sets the
/// modeled lane count (default 4, independent of `--threads` so the
/// ledger is thread-invariant); `--bench-out` writes the serve ledger.
fn cmd_daemon(flags: &BTreeMap<String, String>) -> Result<()> {
    let mut run_cfg = match flags.get("config") {
        Some(path) => RunConfig::from_toml_file(path)?,
        None => RunConfig::default(),
    };
    apply_engine_flags(&mut run_cfg, flags)?;
    apply_daemon_flags(&mut run_cfg, flags)?;
    let path = flags.get("trace").ok_or_else(|| {
        fastfold::Error::Config("daemon: --trace <file.jsonl> is required".into())
    })?;
    let src = std::fs::read_to_string(path).map_err(|e| {
        fastfold::Error::Config(format!("daemon: cannot read trace file '{path}': {e}"))
    })?;
    let trace = TraceEvent::parse_jsonl(&src)?;
    if trace.is_empty() {
        return Err(fastfold::Error::Config(format!("daemon: no events in '{path}'")));
    }
    let lanes: usize = num_flag(flags, "lanes", 4)?;
    let mut dcfg = DaemonConfig::from_run_config(&run_cfg, lanes);
    apply_faults_flag(&mut dcfg, flags)?;

    if flags.contains_key("modeled") {
        let mut planner = PlacementPlanner::from_run_config(&run_cfg)?;
        apply_verify_flag(&mut planner, flags);
        println!(
            "[fastfold] daemon (modeled): {} events (policy={}, lanes={}, queue_cap={}, \
             cache={})",
            trace.len(),
            dcfg.policy.name(),
            dcfg.lanes,
            dcfg.queue_cap,
            fmt_bytes(dcfg.cache_bytes),
        );
        let report = daemon::simulate(&planner, &dcfg, &trace);
        println!("[fastfold] {}", report.summary());
        write_serve_ledger(flags, &dcfg, &report, None)?;
        return Ok(());
    }

    let rt = Runtime::new(&artifacts_dir(flags))?;
    let mut engine = Engine::new(&rt, &run_cfg)?;
    apply_verify_flag(&mut engine.planner, flags);
    println!(
        "[fastfold] daemon: {} events (policy={}, lanes={}, threads={}, queue_cap={}, \
         cache={})",
        trace.len(),
        dcfg.policy.name(),
        dcfg.lanes,
        engine.threads,
        dcfg.queue_cap,
        fmt_bytes(dcfg.cache_bytes),
    );
    let report = engine.serve_trace(&dcfg, &trace)?;
    for (i, out) in report.outputs.iter().enumerate() {
        if let Some(Err(e)) = out {
            println!("  {}: {e}", report.sim.outcomes[i].id);
        }
    }
    println!("[fastfold] {}", report.sim.summary());
    println!(
        "[fastfold] executed in {} on {} worker threads",
        fmt_secs(report.wall_seconds),
        report.threads
    );
    write_serve_ledger(flags, &dcfg, &report.sim, None)?;
    Ok(())
}

/// `fastfold loadgen` — synthesize a seeded request trace (1M requests
/// by default, 100k with `--quick`), optionally dump it (`--out`), and
/// replay it through the modeled daemon into `BENCH_serve.json`. The
/// whole path is pure virtual-clock arithmetic: the same seed yields a
/// byte-identical trace and ledger at any `--threads` budget.
fn cmd_loadgen(flags: &BTreeMap<String, String>) -> Result<()> {
    let mut run_cfg = match flags.get("config") {
        Some(path) => RunConfig::from_toml_file(path)?,
        None => RunConfig::default(),
    };
    apply_engine_flags(&mut run_cfg, flags)?;
    apply_daemon_flags(&mut run_cfg, flags)?;
    let seed: u64 = num_flag(flags, "seed", 17)?;
    let mut spec = if flags.contains_key("quick") {
        LoadgenSpec::quick(seed)
    } else {
        LoadgenSpec::new(num_flag(flags, "requests", 1_000_000)?, seed)
    };
    spec.lanes = num_flag(flags, "lanes", spec.lanes)?;
    // the replay packs onto the spec's modeled lanes, NOT --threads:
    // that keeps the ledger a pure function of (config, spec)
    let mut dcfg = DaemonConfig::from_run_config(&run_cfg, spec.lanes);
    apply_faults_flag(&mut dcfg, flags)?;
    let mut planner = PlacementPlanner::from_run_config(&run_cfg)?;
    apply_verify_flag(&mut planner, flags);

    println!(
        "[fastfold] loadgen: synthesizing {} requests (seed {}, lanes {}, policy {}, \
         queue_cap {}, cache {})",
        spec.requests,
        spec.seed,
        spec.lanes,
        dcfg.policy.name(),
        dcfg.queue_cap,
        fmt_bytes(dcfg.cache_bytes),
    );
    let trace = loadgen::synthesize(&planner, &spec);
    if let Some(out) = flags.get("out") {
        std::fs::write(out, TraceEvent::to_jsonl(&trace))?;
        eprintln!("[fastfold] wrote {out} ({} events)", trace.len());
    }
    if flags.contains_key("no-replay") {
        return Ok(());
    }
    let report = daemon::simulate(&planner, &dcfg, &trace);
    println!("[fastfold] {}", report.summary());
    write_serve_ledger(flags, &dcfg, &report, Some(&spec))?;
    Ok(())
}

/// Write the serve ledger (`--bench-out`, default `BENCH_serve.json`
/// for loadgen; opt-in for daemon) and echo it with `--json`.
fn write_serve_ledger(
    flags: &BTreeMap<String, String>,
    dcfg: &DaemonConfig,
    report: &daemon::DaemonReport,
    spec: Option<&LoadgenSpec>,
) -> Result<()> {
    let out = match (flags.get("bench-out"), spec) {
        (Some(path), _) => path.clone(),
        // loadgen always writes its ledger; daemon only on request
        (None, Some(_)) => "BENCH_serve.json".to_string(),
        (None, None) => return Ok(()),
    };
    let doc = match spec {
        Some(spec) => loadgen::bench_doc(spec, dcfg, report),
        None => loadgen::report_doc(dcfg, report),
    };
    std::fs::write(&out, format!("{doc}\n"))?;
    if flags.contains_key("json") {
        println!("{doc}");
    }
    eprintln!("[fastfold] wrote {out}");
    Ok(())
}

// ------------------------------------------------------------- autochunk

/// Parse a numeric flag strictly: absent → default, malformed → error
/// (a planner invoked with a typo'd length must not plan a default one).
fn num_flag<T: std::str::FromStr>(
    flags: &BTreeMap<String, String>,
    name: &str,
    default: T,
) -> Result<T> {
    match flags.get(name) {
        None => Ok(default),
        Some(s) => s.parse().map_err(|_| {
            fastfold::Error::Config(format!("--{name}: invalid value '{s}'"))
        }),
    }
}

/// `fastfold autochunk` — run the planner for a sequence length and print
/// (or emit as JSON) the per-module chunk strategy.
fn cmd_autochunk(flags: &BTreeMap<String, String>) -> Result<()> {
    // config-file defaults, overridable by flags
    let run_cfg = match flags.get("config") {
        Some(path) => RunConfig::from_toml_file(path)?,
        None => RunConfig::default(),
    };
    let len: usize = num_flag(flags, "len", 2048)?;
    let seq: usize = num_flag(flags, "seq", 256)?;
    let dap: usize = num_flag(flags, "dap", 1)?;
    let gpu_name = flags
        .get("gpu")
        .cloned()
        .unwrap_or_else(|| run_cfg.autochunk.gpu.clone());
    let headroom: f64 = num_flag(flags, "headroom", run_cfg.autochunk.headroom)?;
    let gpu = GpuSpec::by_name(&gpu_name)?;
    let mem = MemoryModel::default();
    let mut cfg = ModelConfig::inference(len);
    cfg.n_seq = seq;

    match autochunk::plan_with_headroom(&cfg, &mem, &gpu, dap, headroom) {
        Ok(plan) => {
            if flags.contains_key("json") {
                println!("{}", plan.to_json().to_string());
                return Ok(());
            }
            println!(
                "AutoChunk plan — {} residues x {} MSA rows, dap={dap}, {} \
                 ({:.0} GB), headroom {:.0}%\n",
                len, seq, gpu.name, gpu.memory / 1e9, 100.0 * headroom
            );
            let mut t = Table::new(&[
                "module", "chunks", "transient (GB)", "flops share",
            ]);
            for s in &plan.modules {
                t.row(&[
                    s.module.name().into(),
                    s.chunks.to_string(),
                    format!("{:.2}", s.transient_bytes / 1e9),
                    format!("{:.1}%", 100.0 * s.flops_weight),
                ]);
            }
            t.print();
            println!(
                "\nresident {:.2} GB + worst transient {:.2} GB + overhead \
                 {:.2} GB = peak {:.2} GB (fits {:.0} GB)",
                plan.resident_bytes / 1e9,
                plan.transient_peak_bytes() / 1e9,
                mem.fixed_overhead / 1e9,
                plan.peak_bytes / 1e9,
                plan.capacity_bytes / 1e9
            );
            println!(
                "unchunked baseline {:.2} GB -> saves {:.1}% (paper §IV: \
                 >80%); modeled latency x{:.2}",
                plan.unchunked_peak_bytes / 1e9,
                100.0 * plan.savings_frac(),
                plan.latency_factor
            );
        }
        // sim-OOM is a *verdict* worth explaining; any other error (bad
        // headroom, unknown gpu) is a usage error and propagates
        Err(e @ fastfold::Error::SimOom { .. }) => {
            // the min-DAP suggestion uses the same headroom as the verdict
            let min_dap = autochunk::min_dap_degree(&cfg, &mem, &gpu, 64, headroom);
            if flags.contains_key("json") {
                let mut o = std::collections::BTreeMap::new();
                o.insert("verdict".to_string(), fastfold::json::Json::Str("oom".into()));
                o.insert("n_res".to_string(), fastfold::json::Json::Num(len as f64));
                o.insert("dap".to_string(), fastfold::json::Json::Num(dap as f64));
                o.insert("error".to_string(), fastfold::json::Json::Str(e.to_string()));
                o.insert(
                    "min_dap".to_string(),
                    match &min_dap {
                        Some((need, _)) => fastfold::json::Json::Num(*need as f64),
                        None => fastfold::json::Json::Null,
                    },
                );
                println!("{}", fastfold::json::Json::Obj(o).to_string());
                return Ok(());
            }
            println!("AutoChunk verdict at {len} residues, dap={dap}: {e}");
            match min_dap {
                Some((need, plan)) => println!(
                    "smallest DAP degree that fits: {need} \
                     (peak {:.1} GB, latency x{:.2})",
                    plan.peak_bytes / 1e9,
                    plan.latency_factor
                ),
                None => println!("does not fit any DAP degree up to 64"),
            }
        }
        Err(e) => return Err(e),
    }
    Ok(())
}

// ---------------------------------------------------------------- chaos

/// `fastfold chaos` — synthesize a deterministic fault schedule from a
/// seed (the file `train`/`daemon`/`loadgen` consume via `--faults`),
/// print it, and project the modeled wall-clock inflation of the paper's
/// 67-hour run across a fleet-MTBF sweep at Young's optimal checkpoint
/// interval.
fn cmd_chaos(flags: &BTreeMap<String, String>) -> Result<()> {
    use fastfold::perfmodel::mtbf;
    let seed: u64 = num_flag(flags, "seed", 17)?;
    let steps: usize = num_flag(flags, "steps", 8)?;
    let dp: usize = num_flag(flags, "dp", 4)?;
    let transients: usize = num_flag(flags, "transients", 3)?;
    let serve_events: usize = num_flag(flags, "serve-events", 2)?;
    let schedule =
        FaultSchedule::synthesize(seed, steps, dp, transients, serve_events);
    schedule.validate(dp)?;
    println!(
        "fastfold chaos — seed {seed}: {} train event(s), {} serve \
         event(s) (steps={steps}, dp={dp})\n",
        schedule.train.len(),
        schedule.serve.len()
    );
    let mut t = Table::new(&["plane", "at", "kind", "rank", "count"]);
    for e in &schedule.train {
        t.row(&[
            "train".into(),
            format!("step {}", e.step),
            e.kind.name().into(),
            e.rank.to_string(),
            e.count.to_string(),
        ]);
    }
    for e in &schedule.serve {
        t.row(&[
            "serve".into(),
            format!("dispatch {}", e.at),
            "backend_fail".into(),
            "-".into(),
            e.count.to_string(),
        ]);
    }
    t.print();
    if let Some(out) = flags.get("out") {
        std::fs::write(out, schedule.to_jsonl())?;
        eprintln!("[fastfold] wrote {out}");
    }

    // the fleet question behind the headline: what a finite MTBF does to
    // the 67-hour two-stage run
    let base: f64 = num_flag(flags, "base-hours", 67.0)?;
    println!(
        "\nmodeled wall-clock for a {base:.0} h fault-free run \
         (Young-optimal checkpoint interval):"
    );
    let mut t = Table::new(&["fleet MTBF (h)", "expected wall (h)", "inflation"]);
    for (m, wall, infl) in
        mtbf::inflation_sweep(base, &[4.0, 8.0, 24.0, 72.0, 168.0])
    {
        t.row(&[
            format!("{m:.0}"),
            format!("{wall:.1}"),
            format!("x{infl:.3}"),
        ]);
    }
    t.print();
    Ok(())
}

// ---------------------------------------------------------------- bench

/// `fastfold bench` — the host perf harness: measures the zero-copy data
/// plane (shard moves, ring all-reduce) and the native fused kernels
/// (softmax / LayerNorm / Adam vs their naive op chains), the
/// scalar-vs-simd backend ratios and thread-scaling curves, plus the
/// synthetic train steps/s and the modeled serve makespan. `--json`
/// writes the ledger to `BENCH_host.json` in the current directory by
/// default (`--out` overrides the path — test runs point it at
/// `target/` so the repo root stays clean); `--quick` runs the reduced
/// sizes the tier-1 smoke uses. No artifacts, no network, no device.
fn cmd_bench(flags: &BTreeMap<String, String>) -> Result<()> {
    let mut run_cfg = RunConfig::default();
    apply_device_backend(&mut run_cfg, flags)?;
    let opts = fastfold::bench::BenchOptions { quick: flags.contains_key("quick") };
    if flags.contains_key("train") {
        let doc = fastfold::bench::run_train_bench(opts)?;
        let out = flags
            .get("train-out")
            .cloned()
            .unwrap_or_else(|| "BENCH_train.json".to_string());
        std::fs::write(&out, format!("{doc}\n"))?;
        if flags.contains_key("json") {
            println!("{doc}");
        } else {
            println!(
                "fastfold bench --train — DP overlap + mixed precision \
                 (quick={})\n",
                opts.quick
            );
            fastfold::bench::render_train_table(&doc).print();
        }
        eprintln!("[fastfold] wrote {out}");
        return Ok(());
    }
    let doc = fastfold::bench::run_host_bench(opts)?;
    if flags.contains_key("json") || flags.contains_key("out") {
        let out = flags
            .get("out")
            .cloned()
            .unwrap_or_else(|| "BENCH_host.json".to_string());
        std::fs::write(&out, format!("{doc}\n"))?;
        println!("{doc}");
        eprintln!("[fastfold] wrote {out}");
    } else {
        println!(
            "fastfold bench — host data plane + native fused kernels \
             (quick={})\n",
            opts.quick
        );
        fastfold::bench::render_table(&doc).print();
        println!("\n(use --json to emit the BENCH_host.json ledger)");
    }
    Ok(())
}

// ------------------------------------------------------- verify / lint

/// `fastfold verify` — the static schedule verifier on the CLI: lift the
/// canonical DAP program into the effect IR and prove (or refute, with
/// structured diagnostics) every hazard class, forward and backward.
/// `--all` sweeps every preset × dap ∈ {1,2,4,8} geometry the benches and
/// smoke jobs use; `--json FILE` writes the diagnostics artifact CI
/// uploads. Exits nonzero on any hazard — the same verdict the planner,
/// trainer, and daemon admission gates enforce.
fn cmd_verify(flags: &BTreeMap<String, String>) -> Result<()> {
    use fastfold::analysis;
    let all = flags.contains_key("all");
    let presets: Vec<String> = if all {
        ["tiny", "small", "initial_training", "finetune"]
            .iter()
            .map(|s| s.to_string())
            .collect()
    } else {
        vec![flags.get("preset").cloned().unwrap_or_else(|| "tiny".into())]
    };
    let daps: Vec<usize> = if all || !flags.contains_key("dap") {
        vec![1, 2, 4, 8]
    } else {
        vec![num_flag(flags, "dap", 2)?]
    };

    let mut reports = Vec::new();
    let mut t = Table::new(&["program", "dap", "steps", "hazards", "verify (us)"]);
    for preset in &presets {
        let cfg = ModelConfig::preset(preset)?;
        for &n in &daps {
            if cfg.n_seq % n != 0 || cfg.n_res % n != 0 {
                println!(
                    "[fastfold] skipping {preset} at dap={n}: does not divide \
                     (n_seq={}, n_res={})",
                    cfg.n_seq, cfg.n_res
                );
                continue;
            }
            let (fwd, bwd) = analysis::verify_canonical(preset, &cfg, n);
            for r in [fwd, bwd] {
                t.row(&[
                    r.program.clone(),
                    r.n.to_string(),
                    r.steps.to_string(),
                    r.diagnostics.len().to_string(),
                    r.elapsed_micros.to_string(),
                ]);
                reports.push(r);
            }
        }
    }
    t.print();
    for r in &reports {
        for d in &r.diagnostics {
            println!(
                "  {} [step {} rank {} {}] '{}': {} — fix: {}",
                r.program,
                d.step,
                d.rank,
                d.hazard.name(),
                d.buffer,
                d.detail,
                d.fix
            );
        }
    }

    if let Some(path) = flags.get("json") {
        // bare `--json` (no value) falls back to the default artifact name
        let path =
            if path == "true" { "VERIFY_report.json" } else { path.as_str() };
        let doc = fastfold::json::Json::Arr(
            reports.iter().map(|r| r.to_json()).collect(),
        );
        std::fs::write(path, format!("{doc}\n"))?;
        eprintln!("[fastfold] wrote {path}");
    }

    let hazards: usize = reports.iter().map(|r| r.diagnostics.len()).sum();
    let total_us: u128 = reports.iter().map(|r| r.elapsed_micros).sum();
    println!(
        "\n[fastfold] verified {} programs in {total_us} us total: {}",
        reports.len(),
        if hazards == 0 {
            "all hazard-free".to_string()
        } else {
            format!("{hazards} hazard(s) refuted")
        }
    );
    if hazards > 0 {
        return Err(fastfold::Error::Schedule(format!(
            "verify: {hazards} hazard(s) refuted (see diagnostics above)"
        )));
    }
    Ok(())
}

/// `fastfold lint` — determinism lint over the Rust source tree: flag
/// unordered hash containers (iteration order one refactor away from a
/// nondeterministic ledger), wall-clock reads outside files annotated
/// as measurement planes, kernel calls that bypass the device dispatch
/// plane, and panics inside the fault-recovery planes. Exits nonzero on
/// any violation.
fn cmd_lint(flags: &BTreeMap<String, String>) -> Result<()> {
    use std::path::Path;
    let default = if Path::new("rust/src").is_dir() { "rust/src" } else { "src" };
    let root = flags.get("src").cloned().unwrap_or_else(|| default.to_string());
    let violations = fastfold::analysis::lint::lint_dir(Path::new(&root))?;
    if violations.is_empty() {
        println!(
            "[fastfold] lint: {root}: clean (rules: unordered-container, \
             wallclock, backend-bypass, panic-in-recovery)"
        );
        return Ok(());
    }
    for v in &violations {
        println!("{v}");
    }
    Err(fastfold::Error::msg(format!(
        "lint: {} violation(s) in {root}",
        violations.len()
    )))
}

// ---------------------------------------------------------------- info

fn cmd_info(flags: &BTreeMap<String, String>) -> Result<()> {
    let rt = Runtime::new(&artifacts_dir(flags))?;
    println!("platform: {}", rt.platform());
    println!("device backend: {}", rt.device_backend());
    println!("artifacts: {}", rt.manifest.artifacts.len());
    for (preset, ps) in &rt.manifest.params {
        println!(
            "  preset '{preset}': {} params in {} leaves",
            ps.count,
            ps.leaves.len()
        );
    }
    Ok(())
}

// ---------------------------------------------------------------- reports

fn cmd_report(pos: &[String], flags: &BTreeMap<String, String>) -> Result<()> {
    match pos.get(1).map(|s| s.as_str()) {
        Some("table2") => report_table2(),
        Some("table3") => report_table3(flags),
        Some("table4") => report_table4(),
        Some("table5") => report_table5(),
        Some("fig10") => report_fig10(),
        Some("fig11") => report_fig11(),
        Some("fig13") => report_fig13(),
        Some("validate") => report_validate(flags),
        _ => {
            println!("report: table2 table3 table4 table5 fig10 fig11 fig13 validate");
            Ok(())
        }
    }
}

/// Table II — Evoformer vs ViT/GPT settings (from the config system).
fn report_table2() -> Result<()> {
    let cfg = ModelConfig::initial_training();
    let per_block = (cfg.param_count()
        - ModelConfig { n_blocks: 0, ..cfg.clone() }.param_count())
        / cfg.n_blocks;
    let mut t = Table::new(&["", "AlphaFold (ours)", "ViT-B/16", "GPT-2", "paper"]);
    t.row(&[
        "Sequence Shape".into(),
        format!("({}, {}) / ({}, {})", cfg.n_seq, cfg.n_res, cfg.n_res, cfg.n_res),
        "196".into(),
        "512".into(),
        "(Ns,Nr)/(Nr,Nr)".into(),
    ]);
    t.row(&["Layers".into(), cfg.n_blocks.to_string(), "12".into(), "48".into(), "48".into()]);
    t.row(&[
        "Hidden Dim".into(),
        format!("{} or {}", cfg.d_pair, cfg.d_msa),
        "768".into(),
        "1600".into(),
        "128 or 256".into(),
    ]);
    t.row(&[
        "Heads".into(),
        format!("{} or {}", cfg.n_heads_msa, cfg.n_heads_pair),
        "12".into(),
        "25".into(),
        "8 or 4".into(),
    ]);
    t.row(&[
        "Params per Layer".into(),
        format!("{:.2} M", per_block as f64 / 1e6),
        "7.1 M".into(),
        "30.7 M".into(),
        "1.8 M".into(),
    ]);
    t.row(&[
        "Total Params".into(),
        format!("{:.1} M", cfg.param_count() as f64 / 1e6),
        "86 M".into(),
        "1500 M".into(),
        "93 M".into(),
    ]);
    println!("Table II — model settings (measured from this repo's config)");
    t.print();
    Ok(())
}

/// Table III — communication per Evoformer block: measured collective
/// counts + volumes from both coordinators.
fn report_table3(flags: &BTreeMap<String, String>) -> Result<()> {
    let preset = flags.get("preset").cloned().unwrap_or_else(|| "tiny".into());
    let rt = Runtime::new(&artifacts_dir(flags))?;
    let n = 2usize;

    // DAP: run one real block forward and read the comm log
    let co = DapCoordinator::new(&rt, &preset, n, true)?;
    let cfg = co.cfg.clone();
    let params = rt.manifest.load_params(&preset)?;
    let idx = rt.manifest.block_leaf_indices(&preset, 0)?;
    let bp: Vec<_> = idx.iter().map(|&i| params[i].clone()).collect();
    let m = fastfold::HostTensor::zeros(&[cfg.n_seq, cfg.n_res, cfg.d_msa]);
    let z = fastfold::HostTensor::zeros(&[cfg.n_res, cfg.n_res, cfg.d_pair]);
    let mut state = co.shard_inputs(&m, &z)?;
    co.block_forward(&bp, &mut state)?;

    println!("Table III — communication per Evoformer block (DAP measured on");
    println!("a real block forward at N={n}, preset '{preset}'; TP simulated):\n");
    println!("DAP forward (paper: 3 AllGather + 6 All_to_All; delta from the");
    println!("bias-projection gathers the paper folds into 'no comm' — DESIGN.md §3):");
    for line in co.comm.log.lock().unwrap().summary() {
        println!("  {line}");
    }

    let tp = TpCoordinator::new(cfg, n.min(2))?;
    tp.block_forward_comm()?;
    tp.block_backward_comm()?;
    println!("\nTP fwd+bwd (paper: 12 × AllReduce):");
    for line in tp.comm.log.lock().unwrap().summary() {
        println!("  {line}");
    }
    Ok(())
}

/// Table IV — training resource/time comparison (calibrated model).
fn report_table4() -> Result<()> {
    let m = ScalingModel::default();
    println!("Table IV — resource and time cost (scaling-model reproduction)\n");
    let mut t = Table::new(&[
        "Implementation", "Training", "Hardware", "Step (s)", "Days", "kGPU-h",
        "paper step (s)",
    ]);

    // samples: 10M initial @ global batch 128 + 1.5M finetune @ 128
    let init_steps = 10.0e6 / 128.0;
    let ft_steps = 1.5e6 / 128.0;

    struct Row {
        name: &'static str,
        profile: ImplProfile,
        dap_init: usize,
        dap_ft: usize,
        gpus_init: f64,
        gpus_ft: f64,
        dp_init: usize,
        dp_ft: usize,
        paper_step: (&'static str, &'static str),
    }
    let rows = [
        Row {
            name: "OpenFold",
            profile: ImplProfile::openfold(),
            dap_init: 1,
            dap_ft: 1,
            gpus_init: 128.0,
            gpus_ft: 128.0,
            dp_init: 128,
            dp_ft: 128,
            paper_step: ("6.19", "20.66"),
        },
        Row {
            name: "FastFold",
            profile: ImplProfile::fastfold(),
            dap_init: 2,
            dap_ft: 4,
            gpus_init: 256.0,
            gpus_ft: 512.0,
            dp_init: 128,
            dp_ft: 128,
            paper_step: ("2.49", "4.15"),
        },
    ];

    for r in rows {
        let step_init = {
            let mp = m
                .train_step(&ModelConfig::initial_training(), &r.profile, MpMethod::Dap, r.dap_init, true)
                .total();
            m.dp_step(&ModelConfig::initial_training(), mp, r.dp_init)
        };
        let step_ft = {
            let mp = m
                .train_step(&ModelConfig::finetune(), &r.profile, MpMethod::Dap, r.dap_ft, true)
                .total();
            m.dp_step(&ModelConfig::finetune(), mp, r.dp_ft)
        };
        let days_init = step_init * init_steps / 86400.0;
        let days_ft = step_ft * ft_steps / 86400.0;
        let gpu_hours = (days_init * 24.0 * r.gpus_init) + (days_ft * 24.0 * r.gpus_ft);
        t.row(&[
            r.name.into(),
            "initial".into(),
            format!("{} x A100", r.gpus_init as usize),
            format!("{step_init:.2}"),
            format!("{:.2}", days_init + days_ft),
            format!("{:.1}", gpu_hours / 1000.0),
            r.paper_step.0.into(),
        ]);
        t.row(&[
            "".into(),
            "finetune".into(),
            format!("{} x A100", r.gpus_ft as usize),
            format!("{step_ft:.2}"),
            "".into(),
            "".into(),
            r.paper_step.1.into(),
        ]);
    }
    t.print();

    // headline: aggregate PFLOPs at 512 GPUs fine-tuning
    let cfg = ModelConfig::finetune();
    let p = ImplProfile::fastfold();
    let mp = m.train_step(&cfg, &p, MpMethod::Dap, 4, true).total();
    let step = m.dp_step(&cfg, mp, 128);
    let flops = fastfold::perfmodel::flops::train_step_flops(&cfg, 2.5) * 128.0;
    println!(
        "\nAggregate at 512 x A100 (model): {:.2} PFLOPs (paper: 6.02), \
         step {:.2}s, DP efficiency {:.1}% (paper: 90.1%)",
        flops / step / 1e15,
        step,
        100.0 * mp / step
    );
    Ok(())
}

/// Table V — extreme-sequence inference latency & OOM boundary.
fn report_table5() -> Result<()> {
    let m = ScalingModel::default();
    let mem = MemoryModel::default();
    let gpu = GpuSpec::a100_40g();
    println!("Table V — extremely long sequences (memory model + scaling model)\n");
    let mut t = Table::new(&[
        "Length", "AlphaFold", "OpenFold", "FastFold (8 GPU)", "FastFold (4 GPU)",
        "AutoChunk (1 GPU)", "paper FF8/FF4 (s)",
    ]);
    let paper: BTreeMap<usize, (&str, &str)> = [
        (2560usize, ("133", "154")),
        (3072, ("202", "239")),
        (3584, ("389", "414")),
        (4096, ("548", "OOM")),
    ]
    .into();
    for &len in &[2560usize, 3072, 3584, 4096] {
        let base = |p: ImplProfile| -> String {
            match chunking::plan_chunks(&ModelConfig::inference(len), &mem, &gpu) {
                Some(plan) => {
                    let lat = m.inference_latency(len, &p, MpMethod::Dap, 1, plan.chunks > 1);
                    format!("{:.0} s", lat)
                }
                None => "OOM".into(),
            }
        };
        let ff = |n: usize| -> String {
            match mem.check(&ModelConfig::inference(len), n, 1, gpu.memory) {
                Ok(_) => format!(
                    "{:.0} s",
                    m.inference_latency(len, &ImplProfile::fastfold(), MpMethod::Dap, n, false)
                ),
                Err(_) => "OOM".into(),
            }
        };
        // the planner's single-device verdict: peak when a strategy fits,
        // OOM when even per-module chunking cannot (3072+)
        let auto = match autochunk::plan(&ModelConfig::inference(len), &mem, &gpu, 1) {
            Ok(plan) => format!("{:.1} GB pk", plan.peak_bytes / 1e9),
            Err(_) => "OOM".into(),
        };
        let (p8, p4) = paper[&len];
        t.row(&[
            len.to_string(),
            base(ImplProfile::alphafold_jax_gpu()),
            base(ImplProfile::openfold()),
            ff(8),
            ff(4),
            auto,
            format!("{p8} / {p4}"),
        ]);
    }
    t.print();
    Ok(())
}

/// Fig 10 — model-parallel scaling intra-node (TP vs DAP).
fn report_fig10() -> Result<()> {
    let m = ScalingModel::default();
    println!("Fig 10 — model-parallel scaling efficiency intra-node (model)\n");
    for (label, cfg) in [
        ("Initial Training", ModelConfig::initial_training()),
        ("Fine-tuning", ModelConfig::finetune()),
    ] {
        println!("{label}:");
        let mut t = Table::new(&["GPUs", "DAP step (s)", "DAP eff", "TP step (s)", "TP eff"]);
        let p = ImplProfile::fastfold();
        let t1 = m.train_step(&cfg, &p, MpMethod::Dap, 1, true).total();
        for n in [1usize, 2, 4] {
            let d = m.train_step(&cfg, &p, MpMethod::Dap, n, true).total();
            let tp = m.train_step(&cfg, &p, MpMethod::TensorParallel, n, true).total();
            t.row(&[
                n.to_string(),
                format!("{d:.3}"),
                format!("{:.1}%", 100.0 * t1 / (n as f64 * d)),
                format!("{tp:.3}"),
                format!("{:.1}%", 100.0 * t1 / (n as f64 * tp)),
            ]);
        }
        t.print();
        println!();
    }
    println!("(paper: DAP clearly above TP at every point; Fine-tuning scales");
    println!(" better than Initial Training — both shapes hold above.)");
    Ok(())
}

/// Fig 11 — data-parallel scaling inter-node.
fn report_fig11() -> Result<()> {
    let m = ScalingModel::default();
    println!("Fig 11 — data-parallel scaling (model)\n");
    for (label, cfg, dap, max_nodes) in [
        ("Initial Training (DAP=2)", ModelConfig::initial_training(), 2usize, 64usize),
        ("Fine-tuning (DAP=4)", ModelConfig::finetune(), 4, 128),
    ] {
        println!("{label}:");
        let p = ImplProfile::fastfold();
        let mp = m.train_step(&cfg, &p, MpMethod::Dap, dap, true).total();
        let mut t = Table::new(&["DP ranks", "step (s)", "samples/s", "efficiency"]);
        let mut n = 1usize;
        while n <= max_nodes {
            let step = m.dp_step(&cfg, mp, n);
            t.row(&[
                n.to_string(),
                format!("{step:.3}"),
                format!("{:.2}", n as f64 / step),
                format!("{:.1}%", 100.0 * mp / step),
            ]);
            n *= 4;
        }
        if max_nodes == 128 {
            let step = m.dp_step(&cfg, mp, 128);
            t.row(&[
                "128".into(),
                format!("{step:.3}"),
                format!("{:.2}", 128.0 / step),
                format!("{:.1}%", 100.0 * mp / step),
            ]);
        }
        t.print();
        println!();
    }
    println!("(paper: near-linear, 90.1% at 128-node fine-tuning.)");
    Ok(())
}

/// Fig 13 — long-sequence inference: FastFold distributed vs baselines.
fn report_fig13() -> Result<()> {
    let m = ScalingModel::default();
    println!("Fig 13 — long-sequence inference latency (model)\n");
    let mut t = Table::new(&[
        "Length", "AlphaFold (s)", "OpenFold (s)", "FF 2 GPU", "FF 4 GPU", "FF 8 GPU",
        "FF8 speedup vs OF",
    ]);
    for &len in &[1024usize, 1536, 2048, 2560] {
        let af =
            m.inference_latency(len, &ImplProfile::alphafold_jax_gpu(), MpMethod::Dap, 1, true);
        let of = m.inference_latency(len, &ImplProfile::openfold(), MpMethod::Dap, 1, true);
        let f = |n| m.inference_latency(len, &ImplProfile::fastfold(), MpMethod::Dap, n, false);
        t.row(&[
            len.to_string(),
            format!("{af:.0}"),
            format!("{of:.0}"),
            format!("{:.0}", f(2)),
            format!("{:.0}", f(4)),
            format!("{:.0}", f(8)),
            format!("{:.1}x", of / f(8)),
        ]);
    }
    t.print();
    println!("\n(paper: 7.5–9.5x vs OpenFold, 9.3–11.6x vs AlphaFold at 8 GPUs.)");
    println!("Recycling fixed at {INFER_RECYCLES} passes, as at inference.");
    Ok(())
}

/// Fig 14-style validation: numerics of every execution path vs reference.
fn report_validate(flags: &BTreeMap<String, String>) -> Result<()> {
    let preset = flags.get("preset").cloned().unwrap_or_else(|| "tiny".into());
    let rt = Runtime::new(&artifacts_dir(flags))?;
    let params = rt.manifest.load_params(&preset)?;
    let model_cfg = ModelConfig::preset(&preset)?;
    let mut gen = DataGen::new(model_cfg, 11);
    let batch = gen.next_batch();

    println!("Validation (paper §V.D): max |Δ| of every path vs single-device fused\n");
    let (m_ref, z_ref) = fastfold::inference::single_device_forward(
        &rt, &preset, &params, &batch.msa_tokens, false,
    )?;
    let mut t = Table::new(&["path", "max|Δ msa_logits|", "max|Δ dist_logits|"]);
    let (m_n, z_n) = fastfold::inference::single_device_forward(
        &rt, &preset, &params, &batch.msa_tokens, true,
    )?;
    t.row(&[
        "naive kernels".into(),
        format!("{:.2e}", m_ref.max_abs_diff(&m_n)),
        format!("{:.2e}", z_ref.max_abs_diff(&z_n)),
    ]);
    for n in [2usize, 4] {
        if let Ok(co) = DapCoordinator::new(&rt, &preset, n, true) {
            let (m_d, z_d) = co.model_forward(&params, &batch.msa_tokens)?;
            t.row(&[
                format!("DAP n={n}"),
                format!("{:.2e}", m_ref.max_abs_diff(&m_d)),
                format!("{:.2e}", z_ref.max_abs_diff(&z_d)),
            ]);
        }
    }
    t.print();
    Ok(())
}
