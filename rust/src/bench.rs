//! Host perf bench harness — the measurable half of the zero-copy data
//! plane and the native fused kernels.
//!
//! `fastfold bench --json` (and the tier-1 `tests/bench_host.rs` smoke,
//! which runs the same harness in quick mode) measures the repo's
//! host-side hot paths and emits a machine-readable `BENCH_host.json`
//! ledger so perf changes are tracked per PR instead of asserted:
//!
//! * **shard_move** — DAP shard split + unshard reassembly throughput,
//!   view-based ([`HostTensor::split_axis`] O(1) views +
//!   adjacency-aware [`HostTensor::concat`]) vs the copying reference
//!   ([`HostTensor::slice_axis_copy`] / [`HostTensor::concat_copy`]).
//! * **ring_all_reduce** — the DP gradient reduction's host GB/s with
//!   its per-step snapshots in the reused scratch buffer.
//! * **fused_softmax / fused_layernorm / fused_adam** — the paper's
//!   Fig 8/9 fused-vs-naive deltas, on host ([`crate::kernels`]), plus
//!   the per-backend `scalar_us` / `simd_us` / `simd_speedup` ratio
//!   (ScalarHost oracle vs the f32x8 SimdHost pinned to one thread, so
//!   the ratio isolates lanes from threading).
//! * **thread_scaling** — SimdHost within-op scaling curves for softmax
//!   and LayerNorm at 1/2/4/8 worker threads on large-row shapes
//!   (`scaling_1_to_N` = t1/tN; ≈1.0 on a 1-core box, gated in CI).
//! * **synthetic_train** — artifact-free hybrid trainer steps/s (the CI
//!   train smoke's layout: dp=2 × dap=2 on the synthetic backend).
//! * **serve_makespan** — the serving planner's modeled makespan and
//!   aggregate PFLOP/s over a mixed request fleet (deterministic — a
//!   schedule regression, not a wall-clock one).
//!
//! Every metric is median-of-N wall time on plain host code: no
//! artifacts, no network, no device.

use crate::comm::ring::ring_all_reduce;
use crate::config::{ModelConfig, RunConfig, TrainConfig};
use crate::device::{simd_backend_with_threads, DeviceBackend, ScalarHost};
use crate::error::Result;
use crate::inference::engine::{plan_batch, InferRequest, PlacementPlanner, SchedPolicy};
use crate::json::Json;
// lint:allow(backend) — the bench times raw kernels as the baseline side
use crate::kernels::{adam, layernorm, softmax, ScratchPool};
use crate::metrics::{median, Table};
use crate::rng::Rng;
use crate::tensor::HostTensor;
use crate::train::{ParallelPlan, SyntheticBackend, TrainBackend, Trainer};
use std::collections::BTreeMap;
use std::hint::black_box;
use std::time::Instant; // lint:allow(wallclock) — the bench harness measures wall time by definition

/// Harness knobs.
#[derive(Clone, Copy, Debug, Default)]
pub struct BenchOptions {
    /// Quick mode: smaller tensors and fewer iterations, sized to run
    /// inside the tier-1 test suite (seconds, not minutes).
    pub quick: bool,
}

/// Median wall seconds of `f` over `iters` runs after `warmup` runs —
/// the one timing loop every host bench (this harness and the fig8/fig9
/// benches' native mode) shares, so aggregation can never drift between
/// them.
pub fn bench_med<F: FnMut()>(warmup: usize, iters: usize, mut f: F) -> f64 {
    for _ in 0..warmup {
        f();
    }
    let times: Vec<f64> = (0..iters)
        .map(|_| {
            let t0 = Instant::now();
            f();
            t0.elapsed().as_secs_f64()
        })
        .collect();
    median(times)
}

/// Build a JSON object from `(key, value)` pairs — shared with the
/// serve-daemon bench ledger so both harnesses shape JSON identically.
pub(crate) fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

/// Wrap a number as JSON (deterministic rendering lives in [`Json`]).
pub(crate) fn num(v: f64) -> Json {
    Json::Num(v)
}

// ------------------------------------------------------------ shard moves

fn bench_shard_move(o: &BenchOptions, rng: &mut Rng) -> Json {
    let (rows, cols, dap) = if o.quick { (256usize, 2048usize, 8usize) } else { (512, 8192, 8) };
    let iters = if o.quick { 20 } else { 40 };
    let x = HostTensor::new(vec![rows, cols], rng.normal_vec(rows * cols, 1.0))
        .expect("static shape");
    let part = rows / dap;
    // bytes conceptually moved per roundtrip: every element leaves as a
    // shard and comes back through the unshard
    let bytes = 2.0 * x.size_bytes() as f64;

    let view = bench_med(3, iters, || {
        let parts = x.split_axis(0, dap).expect("divisible");
        let back = HostTensor::concat(&parts, 0).expect("same shapes");
        black_box(back.len());
    });
    let copy = bench_med(3, iters, || {
        let parts: Vec<HostTensor> = (0..dap)
            .map(|i| x.slice_axis_copy(0, i * part, part).expect("in range"))
            .collect();
        let back = HostTensor::concat_copy(&parts, 0).expect("same shapes");
        black_box(back.len());
    });
    let view = view.max(1e-9);
    obj(vec![
        ("elems", num((rows * cols) as f64)),
        ("dap", num(dap as f64)),
        ("view_us", num(view * 1e6)),
        ("copy_us", num(copy * 1e6)),
        ("view_gbps", num(bytes / view / 1e9)),
        ("copy_gbps", num(bytes / copy.max(1e-9) / 1e9)),
        ("speedup", num(copy / view)),
    ])
}

// ---------------------------------------------------------------- ring

fn bench_ring(o: &BenchOptions, rng: &mut Rng) -> Json {
    let (n, len) = if o.quick { (8usize, 1usize << 16) } else { (8, 1 << 20) };
    let iters = if o.quick { 10 } else { 20 };
    let base: Vec<Vec<f32>> = (0..n).map(|_| rng.normal_vec(len, 1.0)).collect();
    let mut wire_total = 0usize;
    let mut times = Vec::with_capacity(iters);
    for it in 0..iters + 2 {
        let ranks = base.clone();
        let t0 = Instant::now();
        let (out, wire) = ring_all_reduce(ranks).expect("uniform shards");
        let dt = t0.elapsed().as_secs_f64();
        black_box(out.len());
        if it >= 2 {
            times.push(dt);
            wire_total = wire.iter().sum();
        }
    }
    let med = median(times).max(1e-9);
    obj(vec![
        ("ranks", num(n as f64)),
        ("elems_per_rank", num(len as f64)),
        ("wire_bytes", num(wire_total as f64)),
        ("time_ms", num(med * 1e3)),
        ("gbps", num(wire_total as f64 / med / 1e9)),
    ])
}

// -------------------------------------------------------------- kernels

fn bench_softmax(o: &BenchOptions, rng: &mut Rng) -> Json {
    let (rows, cols) = if o.quick { (1024usize, 128usize) } else { (4096, 128) };
    let iters = if o.quick { 15 } else { 30 };
    let x = rng.normal_vec(rows * cols, 2.0);
    let scale = 1.0 / (cols as f32).sqrt();
    let mut out = vec![0.0f32; x.len()];
    let pool = ScratchPool::new();
    let fused = bench_med(3, iters, || {
        softmax::softmax_rows(&x, cols, scale, &mut out);
        black_box(out[0]);
    });
    let naive = bench_med(3, iters, || {
        softmax::softmax_rows_naive(&x, cols, scale, &pool, &mut out);
        black_box(out[0]);
    });
    // backend ratio: scalar oracle vs single-threaded f32x8 lanes, so
    // the speedup isolates vectorization from within-op threading
    let simd1 = simd_backend_with_threads(1);
    let scalar = bench_med(3, iters, || {
        ScalarHost.softmax_rows(&x, cols, scale, &mut out);
        black_box(out[0]);
    });
    let simd = bench_med(3, iters, || {
        simd1.softmax_rows(&x, cols, scale, &mut out);
        black_box(out[0]);
    });
    obj(vec![
        ("rows", num(rows as f64)),
        ("cols", num(cols as f64)),
        ("naive_us", num(naive * 1e6)),
        ("fused_us", num(fused * 1e6)),
        ("speedup", num(naive / fused.max(1e-9))),
        ("scalar_us", num(scalar * 1e6)),
        ("simd_us", num(simd * 1e6)),
        ("simd_speedup", num(scalar / simd.max(1e-9))),
    ])
}

fn bench_layernorm(o: &BenchOptions, rng: &mut Rng) -> Json {
    let (rows, cols) = if o.quick { (1024usize, 128usize) } else { (4096, 128) };
    let iters = if o.quick { 15 } else { 30 };
    let x = rng.normal_vec(rows * cols, 2.0);
    let g = rng.normal_vec(cols, 1.0);
    let b = rng.normal_vec(cols, 1.0);
    let mut out = vec![0.0f32; x.len()];
    let pool = ScratchPool::new();
    let fused = bench_med(3, iters, || {
        layernorm::layernorm_rows(&x, cols, &g, &b, 1e-5, &mut out);
        black_box(out[0]);
    });
    let apex = bench_med(3, iters, || {
        layernorm::layernorm_rows_apex(&x, cols, &g, &b, 1e-5, &mut out);
        black_box(out[0]);
    });
    let naive = bench_med(3, iters, || {
        layernorm::layernorm_rows_naive(&x, cols, &g, &b, 1e-5, &pool, &mut out);
        black_box(out[0]);
    });
    let simd1 = simd_backend_with_threads(1);
    let scalar = bench_med(3, iters, || {
        ScalarHost.layernorm_rows(&x, cols, &g, &b, 1e-5, &mut out);
        black_box(out[0]);
    });
    let simd = bench_med(3, iters, || {
        simd1.layernorm_rows(&x, cols, &g, &b, 1e-5, &mut out);
        black_box(out[0]);
    });
    obj(vec![
        ("rows", num(rows as f64)),
        ("cols", num(cols as f64)),
        ("naive_us", num(naive * 1e6)),
        ("apex_us", num(apex * 1e6)),
        ("fused_us", num(fused * 1e6)),
        ("speedup", num(naive / fused.max(1e-9))),
        ("speedup_vs_apex", num(apex / fused.max(1e-9))),
        ("scalar_us", num(scalar * 1e6)),
        ("simd_us", num(simd * 1e6)),
        ("simd_speedup", num(scalar / simd.max(1e-9))),
    ])
}

fn bench_adam(o: &BenchOptions, rng: &mut Rng) -> Json {
    let n = if o.quick { 1usize << 16 } else { 1 << 20 };
    let iters = if o.quick { 10 } else { 20 };
    let p0 = rng.normal_vec(n, 1.0);
    let g = rng.normal_vec(n, 0.5);
    let m0 = rng.normal_vec(n, 0.1);
    let v0: Vec<f32> = rng.normal_vec(n, 0.1).iter().map(|x| x * x).collect();
    let pool = ScratchPool::new();
    // state clones happen OUTSIDE the timed region: only the update
    // traversal itself is measured, so the ratio isolates pass count
    // instead of being diluted by identical memcpy costs on both sides
    let timed = |naive: bool| -> f64 {
        let mut times = Vec::with_capacity(iters);
        for it in 0..iters + 2 {
            let (mut p, mut m, mut v) = (p0.clone(), m0.clone(), v0.clone());
            let t0 = Instant::now();
            if naive {
                adam::adam_step_naive(3, 1e-3, &mut p, &g, &mut m, &mut v, &pool);
            } else {
                adam::adam_step(3, 1e-3, &mut p, &g, &mut m, &mut v);
            }
            let dt = t0.elapsed().as_secs_f64();
            black_box(p[0]);
            if it >= 2 {
                times.push(dt);
            }
        }
        median(times)
    };
    let fused = timed(false);
    let naive = timed(true);
    let timed_backend = |be: &dyn DeviceBackend| -> f64 {
        let mut times = Vec::with_capacity(iters);
        for it in 0..iters + 2 {
            let (mut p, mut m, mut v) = (p0.clone(), m0.clone(), v0.clone());
            let t0 = Instant::now();
            be.adam_step(3, 1e-3, &mut p, &g, &mut m, &mut v);
            let dt = t0.elapsed().as_secs_f64();
            black_box(p[0]);
            if it >= 2 {
                times.push(dt);
            }
        }
        median(times)
    };
    let simd1 = simd_backend_with_threads(1);
    let scalar = timed_backend(&ScalarHost);
    let simd = timed_backend(simd1.as_ref());
    obj(vec![
        ("elems", num(n as f64)),
        ("naive_us", num(naive * 1e6)),
        ("fused_us", num(fused * 1e6)),
        ("speedup", num(naive / fused.max(1e-9))),
        ("scalar_us", num(scalar * 1e6)),
        ("simd_us", num(simd * 1e6)),
        ("simd_speedup", num(scalar / simd.max(1e-9))),
    ])
}

fn bench_thread_scaling(o: &BenchOptions, rng: &mut Rng) -> Json {
    // large-row shapes so the within-op banding has enough rows per
    // worker to engage at every thread count (8 workers need >=512 rows
    // at the 64-row admission floor)
    let (rows, cols) = if o.quick { (2048usize, 128usize) } else { (8192, 256) };
    let iters = if o.quick { 8 } else { 16 };
    let x = rng.normal_vec(rows * cols, 2.0);
    let scale = 1.0 / (cols as f32).sqrt();
    let g = rng.normal_vec(cols, 1.0);
    let b = rng.normal_vec(cols, 1.0);
    let mut out = vec![0.0f32; x.len()];
    let threads = [1usize, 2, 4, 8];
    let mut run_kernel = |which: &str| -> Json {
        let us: Vec<f64> = threads
            .iter()
            .map(|&t| {
                let be = simd_backend_with_threads(t);
                let med = bench_med(2, iters, || {
                    if which == "softmax" {
                        be.softmax_rows(&x, cols, scale, &mut out);
                    } else {
                        be.layernorm_rows(&x, cols, &g, &b, 1e-5, &mut out);
                    }
                    black_box(out[0]);
                });
                med * 1e6
            })
            .collect();
        obj(vec![
            ("rows", num(rows as f64)),
            ("cols", num(cols as f64)),
            ("t1_us", num(us[0])),
            ("t2_us", num(us[1])),
            ("t4_us", num(us[2])),
            ("t8_us", num(us[3])),
            ("scaling_1_to_2", num(us[0] / us[1].max(1e-3))),
            ("scaling_1_to_4", num(us[0] / us[2].max(1e-3))),
            ("scaling_1_to_8", num(us[0] / us[3].max(1e-3))),
        ])
    };
    let softmax_curve = run_kernel("softmax");
    let layernorm_curve = run_kernel("layernorm");
    obj(vec![
        ("softmax", softmax_curve),
        ("layernorm", layernorm_curve),
    ])
}

// ------------------------------------------------------ train and serve

fn bench_synthetic_train(o: &BenchOptions) -> Result<Json> {
    let steps = if o.quick { 2usize } else { 8 };
    let model_cfg = ModelConfig::tiny();
    let plan = ParallelPlan::new(2, 2, 1);
    let params = SyntheticBackend::init_params(&model_cfg);
    let backend: Box<dyn TrainBackend> = Box::new(SyntheticBackend::new(plan.dap));
    let cfg = TrainConfig { steps, log_every: usize::MAX, ..TrainConfig::default() };
    let mut trainer =
        Trainer::with_backend("tiny", model_cfg, params, backend, plan, cfg)?;
    let report = trainer.run()?;
    Ok(obj(vec![
        ("steps", num(report.steps as f64)),
        ("steps_per_sec", num(report.steps_per_sec)),
        ("dp_wire_bytes", num(report.wire_bytes as f64)),
        ("final_loss", num(report.final_loss as f64)),
    ]))
}

fn bench_serve_makespan() -> Result<Json> {
    let run_cfg = RunConfig::default();
    let planner = PlacementPlanner::from_run_config(&run_cfg)?;
    let lens = [None, Some(512), Some(1024), Some(2048), Some(2560), Some(3072)];
    let requests: Vec<InferRequest> = lens
        .iter()
        .enumerate()
        .map(|(i, len)| {
            let mut r = InferRequest::new(&format!("bench{i}"), "tiny");
            r.model_len = *len;
            r
        })
        .collect();
    let lanes = 4usize;
    let plan = plan_batch(
        &planner,
        SchedPolicy::Sjf,
        run_cfg.serve.max_bypass,
        lanes,
        &requests,
    );
    let stats = plan.stats(&requests);
    let admitted = plan.order.len();
    Ok(obj(vec![
        ("requests", num(requests.len() as f64)),
        ("admitted", num(admitted as f64)),
        ("lanes", num(lanes as f64)),
        ("modeled_makespan_s", num(plan.modeled_makespan)),
        ("aggregate_pflops", num(stats.aggregate_pflops(plan.modeled_makespan))),
    ]))
}

// ---------------------------------------------------------------- driver

/// Run the full host bench suite; returns the `BENCH_host.json` document.
pub fn run_host_bench(opts: BenchOptions) -> Result<Json> {
    let mut rng = Rng::new(2024);
    let mut top = BTreeMap::new();
    top.insert("bench".to_string(), Json::Str("host".into()));
    // version 2.0: per-backend simd_speedup ratios + thread_scaling curves
    top.insert("version".to_string(), Json::Num(2.0));
    top.insert("quick".to_string(), Json::Bool(opts.quick));
    top.insert(
        "device_backend".to_string(),
        Json::Str(crate::device::current().name().into()),
    );
    top.insert("shard_move".to_string(), bench_shard_move(&opts, &mut rng));
    top.insert("ring_all_reduce".to_string(), bench_ring(&opts, &mut rng));
    top.insert("fused_softmax".to_string(), bench_softmax(&opts, &mut rng));
    top.insert("fused_layernorm".to_string(), bench_layernorm(&opts, &mut rng));
    top.insert("fused_adam".to_string(), bench_adam(&opts, &mut rng));
    top.insert("thread_scaling".to_string(), bench_thread_scaling(&opts, &mut rng));
    top.insert("synthetic_train".to_string(), bench_synthetic_train(&opts)?);
    top.insert("serve_makespan".to_string(), bench_serve_makespan()?);
    Ok(Json::Obj(top))
}

/// Console rendering of a [`run_host_bench`] document.
pub fn render_table(doc: &Json) -> Table {
    let mut t = Table::new(&["metric", "baseline", "optimized", "speedup / rate"]);
    let f = |j: &Json, key: &str| -> f64 {
        j.get(key).and_then(|v| v.as_f64()).unwrap_or(f64::NAN)
    };
    if let Ok(s) = doc.get("shard_move") {
        t.row(&[
            format!("shard move ({}x dap{})", f(s, "elems"), f(s, "dap")),
            format!("{:.1} µs copy", f(s, "copy_us")),
            format!("{:.2} µs view", f(s, "view_us")),
            format!("{:.0}x", f(s, "speedup")),
        ]);
    }
    if let Ok(s) = doc.get("ring_all_reduce") {
        t.row(&[
            format!("ring all-reduce ({} ranks)", f(s, "ranks")),
            format!("{:.0} B wire", f(s, "wire_bytes")),
            format!("{:.2} ms", f(s, "time_ms")),
            format!("{:.2} GB/s", f(s, "gbps")),
        ]);
    }
    for (key, label) in [
        ("fused_softmax", "softmax"),
        ("fused_layernorm", "layernorm"),
        ("fused_adam", "adam"),
    ] {
        if let Ok(s) = doc.get(key) {
            t.row(&[
                format!("fused {label}"),
                format!("{:.1} µs naive", f(s, "naive_us")),
                format!("{:.1} µs fused", f(s, "fused_us")),
                format!("{:.2}x", f(s, "speedup")),
            ]);
            t.row(&[
                format!("simd {label} (1 thread)"),
                format!("{:.1} µs scalar", f(s, "scalar_us")),
                format!("{:.1} µs simd", f(s, "simd_us")),
                format!("{:.2}x", f(s, "simd_speedup")),
            ]);
        }
    }
    if let Ok(ts) = doc.get("thread_scaling") {
        for (key, label) in [("softmax", "softmax"), ("layernorm", "layernorm")] {
            if let Ok(s) = ts.get(key) {
                t.row(&[
                    format!("simd {label} threads 1→4"),
                    format!("{:.1} µs t1", f(s, "t1_us")),
                    format!("{:.1} µs t4", f(s, "t4_us")),
                    format!("{:.2}x", f(s, "scaling_1_to_4")),
                ]);
            }
        }
    }
    if let Ok(s) = doc.get("synthetic_train") {
        t.row(&[
            "synthetic train (dp2 x dap2)".into(),
            format!("{} steps", f(s, "steps")),
            String::new(),
            format!("{:.1} steps/s", f(s, "steps_per_sec")),
        ]);
    }
    if let Ok(s) = doc.get("serve_makespan") {
        t.row(&[
            "serve schedule (modeled)".into(),
            format!("{} reqs / {} lanes", f(s, "requests"), f(s, "lanes")),
            format!("{:.1} s makespan", f(s, "modeled_makespan_s")),
            format!("{:.2} PFLOP/s", f(s, "aggregate_pflops")),
        ]);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn serve_makespan_is_deterministic() {
        let a = bench_serve_makespan().unwrap();
        let b = bench_serve_makespan().unwrap();
        assert_eq!(a, b);
        let mk = a.get("modeled_makespan_s").unwrap().as_f64().unwrap();
        assert!(mk > 0.0);
        let adm = a.get("admitted").unwrap().as_f64().unwrap();
        assert!(adm >= 1.0);
    }

    #[test]
    fn synthetic_train_reports_steps() {
        let j = bench_synthetic_train(&BenchOptions { quick: true }).unwrap();
        assert_eq!(j.get("steps").unwrap().as_f64().unwrap(), 2.0);
        assert!(j.get("steps_per_sec").unwrap().as_f64().unwrap() > 0.0);
    }
}
