//! Host perf bench harness — the measurable half of the zero-copy data
//! plane and the native fused kernels.
//!
//! `fastfold bench --json` (and the tier-1 `tests/bench_host.rs` smoke,
//! which runs the same harness in quick mode) measures the repo's
//! host-side hot paths and emits a machine-readable `BENCH_host.json`
//! ledger so perf changes are tracked per PR instead of asserted:
//!
//! * **shard_move** — DAP shard split + unshard reassembly throughput,
//!   view-based ([`HostTensor::split_axis`] O(1) views +
//!   adjacency-aware [`HostTensor::concat`]) vs the copying reference
//!   ([`HostTensor::slice_axis_copy`] / [`HostTensor::concat_copy`]).
//! * **ring_all_reduce** — the DP gradient reduction's host GB/s with
//!   its per-step snapshots in the reused scratch buffer.
//! * **fused_softmax / fused_layernorm / fused_adam** — the paper's
//!   Fig 8/9 fused-vs-naive deltas, on host ([`crate::kernels`]), plus
//!   the per-backend `scalar_us` / `simd_us` / `simd_speedup` ratio
//!   (ScalarHost oracle vs the f32x8 SimdHost pinned to one thread, so
//!   the ratio isolates lanes from threading).
//! * **thread_scaling** — SimdHost within-op scaling curves for softmax
//!   and LayerNorm at 1/2/4/8 worker threads on large-row shapes
//!   (`scaling_1_to_N` = t1/tN; ≈1.0 on a 1-core box, gated in CI).
//! * **synthetic_train** — artifact-free hybrid trainer steps/s (the CI
//!   train smoke's layout: dp=2 × dap=2 on the synthetic backend).
//! * **serve_makespan** — the serving planner's modeled makespan and
//!   aggregate PFLOP/s over a mixed request fleet (deterministic — a
//!   schedule regression, not a wall-clock one).
//!
//! Every metric is median-of-N wall time on plain host code: no
//! artifacts, no network, no device.

use crate::comm::ring::ring_all_reduce;
use crate::config::{ModelConfig, Precision, RunConfig, TrainConfig};
use crate::device::{simd_backend_with_threads, DeviceBackend, ScalarHost};
use crate::error::Result;
use crate::inference::engine::{plan_batch, InferRequest, PlacementPlanner, SchedPolicy};
use crate::json::Json;
// lint:allow(backend) — the bench times raw kernels as the baseline side
use crate::kernels::{adam, layernorm, softmax, ScratchPool};
use crate::metrics::{median, Table};
use crate::perfmodel::gpu::ImplProfile;
use crate::perfmodel::scaling::MpMethod;
use crate::perfmodel::{DpOverlap, ScalingModel};
use crate::rng::Rng;
use crate::tensor::HostTensor;
use crate::train::{ParallelPlan, SyntheticBackend, TrainBackend, TrainReport, Trainer};
use std::collections::BTreeMap;
use std::hint::black_box;
use std::time::Instant; // lint:allow(wallclock) — the bench harness measures wall time by definition

/// Harness knobs.
#[derive(Clone, Copy, Debug, Default)]
pub struct BenchOptions {
    /// Quick mode: smaller tensors and fewer iterations, sized to run
    /// inside the tier-1 test suite (seconds, not minutes).
    pub quick: bool,
}

/// Median wall seconds of `f` over `iters` runs after `warmup` runs —
/// the one timing loop every host bench (this harness and the fig8/fig9
/// benches' native mode) shares, so aggregation can never drift between
/// them.
pub fn bench_med<F: FnMut()>(warmup: usize, iters: usize, mut f: F) -> f64 {
    for _ in 0..warmup {
        f();
    }
    let times: Vec<f64> = (0..iters)
        .map(|_| {
            let t0 = Instant::now();
            f();
            t0.elapsed().as_secs_f64()
        })
        .collect();
    median(times)
}

/// Build a JSON object from `(key, value)` pairs — shared with the
/// serve-daemon bench ledger so both harnesses shape JSON identically.
pub(crate) fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

/// Wrap a number as JSON (deterministic rendering lives in [`Json`]).
pub(crate) fn num(v: f64) -> Json {
    Json::Num(v)
}

// ------------------------------------------------------------ shard moves

fn bench_shard_move(o: &BenchOptions, rng: &mut Rng) -> Json {
    let (rows, cols, dap) = if o.quick { (256usize, 2048usize, 8usize) } else { (512, 8192, 8) };
    let iters = if o.quick { 20 } else { 40 };
    let x = HostTensor::new(vec![rows, cols], rng.normal_vec(rows * cols, 1.0))
        .expect("static shape");
    let part = rows / dap;
    // bytes conceptually moved per roundtrip: every element leaves as a
    // shard and comes back through the unshard
    let bytes = 2.0 * x.size_bytes() as f64;

    let view = bench_med(3, iters, || {
        let parts = x.split_axis(0, dap).expect("divisible");
        let back = HostTensor::concat(&parts, 0).expect("same shapes");
        black_box(back.len());
    });
    let copy = bench_med(3, iters, || {
        let parts: Vec<HostTensor> = (0..dap)
            .map(|i| x.slice_axis_copy(0, i * part, part).expect("in range"))
            .collect();
        let back = HostTensor::concat_copy(&parts, 0).expect("same shapes");
        black_box(back.len());
    });
    let view = view.max(1e-9);
    obj(vec![
        ("elems", num((rows * cols) as f64)),
        ("dap", num(dap as f64)),
        ("view_us", num(view * 1e6)),
        ("copy_us", num(copy * 1e6)),
        ("view_gbps", num(bytes / view / 1e9)),
        ("copy_gbps", num(bytes / copy.max(1e-9) / 1e9)),
        ("speedup", num(copy / view)),
    ])
}

// ---------------------------------------------------------------- ring

fn bench_ring(o: &BenchOptions, rng: &mut Rng) -> Json {
    let (n, len) = if o.quick { (8usize, 1usize << 16) } else { (8, 1 << 20) };
    let iters = if o.quick { 10 } else { 20 };
    let base: Vec<Vec<f32>> = (0..n).map(|_| rng.normal_vec(len, 1.0)).collect();
    let mut wire_total = 0usize;
    let mut times = Vec::with_capacity(iters);
    for it in 0..iters + 2 {
        let ranks = base.clone();
        let t0 = Instant::now();
        let (out, wire) = ring_all_reduce(ranks).expect("uniform shards");
        let dt = t0.elapsed().as_secs_f64();
        black_box(out.len());
        if it >= 2 {
            times.push(dt);
            wire_total = wire.iter().sum();
        }
    }
    let med = median(times).max(1e-9);
    obj(vec![
        ("ranks", num(n as f64)),
        ("elems_per_rank", num(len as f64)),
        ("wire_bytes", num(wire_total as f64)),
        ("time_ms", num(med * 1e3)),
        ("gbps", num(wire_total as f64 / med / 1e9)),
    ])
}

// -------------------------------------------------------------- kernels

fn bench_softmax(o: &BenchOptions, rng: &mut Rng) -> Json {
    let (rows, cols) = if o.quick { (1024usize, 128usize) } else { (4096, 128) };
    let iters = if o.quick { 15 } else { 30 };
    let x = rng.normal_vec(rows * cols, 2.0);
    let scale = 1.0 / (cols as f32).sqrt();
    let mut out = vec![0.0f32; x.len()];
    let pool = ScratchPool::new();
    let fused = bench_med(3, iters, || {
        softmax::softmax_rows(&x, cols, scale, &mut out);
        black_box(out[0]);
    });
    let naive = bench_med(3, iters, || {
        softmax::softmax_rows_naive(&x, cols, scale, &pool, &mut out);
        black_box(out[0]);
    });
    // backend ratio: scalar oracle vs single-threaded f32x8 lanes, so
    // the speedup isolates vectorization from within-op threading
    let simd1 = simd_backend_with_threads(1);
    let scalar = bench_med(3, iters, || {
        ScalarHost.softmax_rows(&x, cols, scale, &mut out);
        black_box(out[0]);
    });
    let simd = bench_med(3, iters, || {
        simd1.softmax_rows(&x, cols, scale, &mut out);
        black_box(out[0]);
    });
    obj(vec![
        ("rows", num(rows as f64)),
        ("cols", num(cols as f64)),
        ("naive_us", num(naive * 1e6)),
        ("fused_us", num(fused * 1e6)),
        ("speedup", num(naive / fused.max(1e-9))),
        ("scalar_us", num(scalar * 1e6)),
        ("simd_us", num(simd * 1e6)),
        ("simd_speedup", num(scalar / simd.max(1e-9))),
    ])
}

fn bench_layernorm(o: &BenchOptions, rng: &mut Rng) -> Json {
    let (rows, cols) = if o.quick { (1024usize, 128usize) } else { (4096, 128) };
    let iters = if o.quick { 15 } else { 30 };
    let x = rng.normal_vec(rows * cols, 2.0);
    let g = rng.normal_vec(cols, 1.0);
    let b = rng.normal_vec(cols, 1.0);
    let mut out = vec![0.0f32; x.len()];
    let pool = ScratchPool::new();
    let fused = bench_med(3, iters, || {
        layernorm::layernorm_rows(&x, cols, &g, &b, 1e-5, &mut out);
        black_box(out[0]);
    });
    let apex = bench_med(3, iters, || {
        layernorm::layernorm_rows_apex(&x, cols, &g, &b, 1e-5, &mut out);
        black_box(out[0]);
    });
    let naive = bench_med(3, iters, || {
        layernorm::layernorm_rows_naive(&x, cols, &g, &b, 1e-5, &pool, &mut out);
        black_box(out[0]);
    });
    let simd1 = simd_backend_with_threads(1);
    let scalar = bench_med(3, iters, || {
        ScalarHost.layernorm_rows(&x, cols, &g, &b, 1e-5, &mut out);
        black_box(out[0]);
    });
    let simd = bench_med(3, iters, || {
        simd1.layernorm_rows(&x, cols, &g, &b, 1e-5, &mut out);
        black_box(out[0]);
    });
    obj(vec![
        ("rows", num(rows as f64)),
        ("cols", num(cols as f64)),
        ("naive_us", num(naive * 1e6)),
        ("apex_us", num(apex * 1e6)),
        ("fused_us", num(fused * 1e6)),
        ("speedup", num(naive / fused.max(1e-9))),
        ("speedup_vs_apex", num(apex / fused.max(1e-9))),
        ("scalar_us", num(scalar * 1e6)),
        ("simd_us", num(simd * 1e6)),
        ("simd_speedup", num(scalar / simd.max(1e-9))),
    ])
}

fn bench_adam(o: &BenchOptions, rng: &mut Rng) -> Json {
    let n = if o.quick { 1usize << 16 } else { 1 << 20 };
    let iters = if o.quick { 10 } else { 20 };
    let p0 = rng.normal_vec(n, 1.0);
    let g = rng.normal_vec(n, 0.5);
    let m0 = rng.normal_vec(n, 0.1);
    let v0: Vec<f32> = rng.normal_vec(n, 0.1).iter().map(|x| x * x).collect();
    let pool = ScratchPool::new();
    // state clones happen OUTSIDE the timed region: only the update
    // traversal itself is measured, so the ratio isolates pass count
    // instead of being diluted by identical memcpy costs on both sides
    let timed = |naive: bool| -> f64 {
        let mut times = Vec::with_capacity(iters);
        for it in 0..iters + 2 {
            let (mut p, mut m, mut v) = (p0.clone(), m0.clone(), v0.clone());
            let t0 = Instant::now();
            if naive {
                adam::adam_step_naive(3, 1e-3, &mut p, &g, &mut m, &mut v, &pool);
            } else {
                adam::adam_step(3, 1e-3, &mut p, &g, &mut m, &mut v);
            }
            let dt = t0.elapsed().as_secs_f64();
            black_box(p[0]);
            if it >= 2 {
                times.push(dt);
            }
        }
        median(times)
    };
    let fused = timed(false);
    let naive = timed(true);
    let timed_backend = |be: &dyn DeviceBackend| -> f64 {
        let mut times = Vec::with_capacity(iters);
        for it in 0..iters + 2 {
            let (mut p, mut m, mut v) = (p0.clone(), m0.clone(), v0.clone());
            let t0 = Instant::now();
            be.adam_step(3, 1e-3, &mut p, &g, &mut m, &mut v);
            let dt = t0.elapsed().as_secs_f64();
            black_box(p[0]);
            if it >= 2 {
                times.push(dt);
            }
        }
        median(times)
    };
    let simd1 = simd_backend_with_threads(1);
    let scalar = timed_backend(&ScalarHost);
    let simd = timed_backend(simd1.as_ref());
    obj(vec![
        ("elems", num(n as f64)),
        ("naive_us", num(naive * 1e6)),
        ("fused_us", num(fused * 1e6)),
        ("speedup", num(naive / fused.max(1e-9))),
        ("scalar_us", num(scalar * 1e6)),
        ("simd_us", num(simd * 1e6)),
        ("simd_speedup", num(scalar / simd.max(1e-9))),
    ])
}

fn bench_thread_scaling(o: &BenchOptions, rng: &mut Rng) -> Json {
    // large-row shapes so the within-op banding has enough rows per
    // worker to engage at every thread count (8 workers need >=512 rows
    // at the 64-row admission floor)
    let (rows, cols) = if o.quick { (2048usize, 128usize) } else { (8192, 256) };
    let iters = if o.quick { 8 } else { 16 };
    let x = rng.normal_vec(rows * cols, 2.0);
    let scale = 1.0 / (cols as f32).sqrt();
    let g = rng.normal_vec(cols, 1.0);
    let b = rng.normal_vec(cols, 1.0);
    let mut out = vec![0.0f32; x.len()];
    let threads = [1usize, 2, 4, 8];
    let mut run_kernel = |which: &str| -> Json {
        let us: Vec<f64> = threads
            .iter()
            .map(|&t| {
                let be = simd_backend_with_threads(t);
                let med = bench_med(2, iters, || {
                    if which == "softmax" {
                        be.softmax_rows(&x, cols, scale, &mut out);
                    } else {
                        be.layernorm_rows(&x, cols, &g, &b, 1e-5, &mut out);
                    }
                    black_box(out[0]);
                });
                med * 1e6
            })
            .collect();
        obj(vec![
            ("rows", num(rows as f64)),
            ("cols", num(cols as f64)),
            ("t1_us", num(us[0])),
            ("t2_us", num(us[1])),
            ("t4_us", num(us[2])),
            ("t8_us", num(us[3])),
            ("scaling_1_to_2", num(us[0] / us[1].max(1e-3))),
            ("scaling_1_to_4", num(us[0] / us[2].max(1e-3))),
            ("scaling_1_to_8", num(us[0] / us[3].max(1e-3))),
        ])
    };
    let softmax_curve = run_kernel("softmax");
    let layernorm_curve = run_kernel("layernorm");
    obj(vec![
        ("softmax", softmax_curve),
        ("layernorm", layernorm_curve),
    ])
}

// ------------------------------------------------------ train and serve

fn bench_synthetic_train(o: &BenchOptions) -> Result<Json> {
    let steps = if o.quick { 2usize } else { 8 };
    let model_cfg = ModelConfig::tiny();
    let plan = ParallelPlan::new(2, 2, 1);
    let params = SyntheticBackend::init_params(&model_cfg);
    let backend: Box<dyn TrainBackend> = Box::new(SyntheticBackend::new(plan.dap));
    let cfg = TrainConfig { steps, log_every: usize::MAX, ..TrainConfig::default() };
    let mut trainer =
        Trainer::with_backend("tiny", model_cfg, params, backend, plan, cfg)?;
    let report = trainer.run()?;
    Ok(obj(vec![
        ("steps", num(report.steps as f64)),
        ("steps_per_sec", num(report.steps_per_sec)),
        ("dp_wire_bytes", num(report.wire_bytes as f64)),
        ("final_loss", num(report.final_loss as f64)),
    ]))
}

fn bench_serve_makespan() -> Result<Json> {
    let run_cfg = RunConfig::default();
    let planner = PlacementPlanner::from_run_config(&run_cfg)?;
    let lens = [None, Some(512), Some(1024), Some(2048), Some(2560), Some(3072)];
    let requests: Vec<InferRequest> = lens
        .iter()
        .enumerate()
        .map(|(i, len)| {
            let mut r = InferRequest::new(&format!("bench{i}"), "tiny");
            r.model_len = *len;
            r
        })
        .collect();
    let lanes = 4usize;
    let plan = plan_batch(
        &planner,
        SchedPolicy::Sjf,
        run_cfg.serve.max_bypass,
        lanes,
        &requests,
    );
    let stats = plan.stats(&requests);
    let admitted = plan.order.len();
    Ok(obj(vec![
        ("requests", num(requests.len() as f64)),
        ("admitted", num(admitted as f64)),
        ("lanes", num(lanes as f64)),
        ("modeled_makespan_s", num(plan.modeled_makespan)),
        ("aggregate_pflops", num(stats.aggregate_pflops(plan.modeled_makespan))),
    ]))
}

// ----------------------------------------------------- training overlap

/// Synthetic geometry for the training bench: the same six parameter
/// leaves the trainer always carries, but fattened until the DP gradient
/// ring is a first-class share of the step (the regime the bucketed
/// overlap plane exists for), over tiny activations so the suite stays
/// in bench time. `n_seq`/`n_res` stay small — the synthetic backward
/// cost scales with `params × n_seq`, so this keeps compute and comm the
/// same order of magnitude.
fn train_bench_config(quick: bool) -> ModelConfig {
    let mut cfg = ModelConfig::tiny();
    cfg.name = "bench_train".into();
    cfg.n_seq = 2;
    cfg.n_res = 8;
    if quick {
        cfg.d_msa = 16_384;
        cfg.d_pair = 8_192;
        cfg.n_heads_msa = 16;
        cfg.d_head = 64;
        cfg.d_opm = 2_048;
        cfg.n_dist_bins = 16_384;
    } else {
        cfg.d_msa = 65_536;
        cfg.d_pair = 32_768;
        cfg.n_heads_msa = 64;
        cfg.d_head = 64;
        cfg.d_opm = 8_192;
        cfg.n_dist_bins = 65_536;
    }
    cfg
}

/// One measured trainer configuration for the train bench (dp=4 ×
/// accum=2 on the shared geometry, 4 compute threads so replicas
/// genuinely run concurrently under the reducer).
fn train_bench_run(
    cfg: &ModelConfig,
    steps: usize,
    precision: Precision,
    prefetch: bool,
    bucket_mb: Option<f64>,
) -> Result<TrainReport> {
    let plan = ParallelPlan { dp: 4, dap: 1, accum: 2, threads: 4 };
    let params = SyntheticBackend::init_params(cfg);
    let backend: Box<dyn TrainBackend> = Box::new(SyntheticBackend::new(plan.dap));
    let tcfg = TrainConfig {
        steps,
        log_every: usize::MAX,
        precision,
        prefetch,
        bucket_mb,
        ..TrainConfig::default()
    };
    let mut trainer =
        Trainer::with_backend("bench_train", cfg.clone(), params, backend, plan, tcfg)?;
    trainer.run()
}

fn train_report_json(r: &TrainReport) -> Json {
    obj(vec![
        ("precision", Json::Str(r.precision.to_string())),
        ("steps", num(r.steps as f64)),
        ("steps_per_sec", num(r.steps_per_sec)),
        ("comm_us", num(r.comm_seconds * 1e6)),
        ("exposed_comm_us", num(r.exposed_comm_seconds * 1e6)),
        ("overlap_fraction", num(r.overlap_fraction)),
        ("prefetch_stall_us", num(r.prefetch_stall_seconds * 1e6)),
        ("dp_wire_bytes", num(r.wire_bytes as f64)),
        ("skipped_steps", num(r.skipped_steps as f64)),
        ("final_loss", num(r.final_loss as f64)),
    ])
}

/// Run the training-overlap bench; returns the `BENCH_train.json`
/// document. Three measured configurations on one comm-heavy geometry —
/// the f32 synchronous baseline (monolithic post-backward all-reduce,
/// inline data), f32 with the bucketed overlap + prefetch planes, and
/// the full bf16 stack — next to the modeled timeline
/// ([`ScalingModel::dp_step_overlapped`] at the paper's A100 finetune
/// point and the ScaleFold H100 calibration), so measured overlap can
/// be read against what the α–β model predicts.
pub fn run_train_bench(opts: BenchOptions) -> Result<Json> {
    let cfg = train_bench_config(opts.quick);
    let steps = if opts.quick { 3usize } else { 6 };
    // sized to split the six leaves into ~5 buckets (largest leaves ride
    // alone; small ones pack) so reductions start mid-backward
    let bucket_mb = Some(if opts.quick { 0.0625 } else { 0.25 });
    let param_elems: usize = SyntheticBackend::init_params(&cfg)
        .iter()
        .map(|p| p.data().len())
        .sum();

    let f32_sync = train_bench_run(&cfg, steps, Precision::F32, false, None)?;
    let f32_overlap = train_bench_run(&cfg, steps, Precision::F32, true, bucket_mb)?;
    let bf16_overlap = train_bench_run(&cfg, steps, Precision::Bf16, true, bucket_mb)?;

    // modeled twin: the paper-scale point the host measurement mirrors
    let m = ScalingModel::default();
    let ft = ModelConfig::finetune();
    let p = ImplProfile::fastfold();
    let mp = m.train_step(&ft, &p, MpMethod::Dap, 4, true).total();
    let mono = m.dp_step_overlapped(&ft, mp, 128, DpOverlap::f32_monolithic());
    let bucketed = m.dp_step_overlapped(&ft, mp, 128, DpOverlap::bf16_bucketed());
    let (sf_init, sf_ft) = ScalingModel::scalefold_hours();
    let modeled = obj(vec![
        ("a100_ft_dp128_mono_exposed_ms", num(mono.exposed_secs * 1e3)),
        ("a100_ft_dp128_bucketed_exposed_ms", num(bucketed.exposed_secs * 1e3)),
        ("a100_ft_dp128_bucketed_overlap_fraction", num(bucketed.overlap_fraction)),
        ("scalefold_h100_initial_hours", num(sf_init)),
        ("scalefold_h100_finetune_hours", num(sf_ft)),
        ("scalefold_h100_total_hours", num(sf_init + sf_ft)),
    ]);

    let mut top = BTreeMap::new();
    top.insert("bench".to_string(), Json::Str("train".into()));
    top.insert("version".to_string(), Json::Num(1.0));
    top.insert("quick".to_string(), Json::Bool(opts.quick));
    top.insert(
        "device_backend".to_string(),
        Json::Str(crate::device::current().name().into()),
    );
    top.insert(
        "geometry".to_string(),
        obj(vec![
            ("dp", num(4.0)),
            ("accum", num(2.0)),
            ("threads", num(4.0)),
            ("param_elems", num(param_elems as f64)),
            ("steps", num(steps as f64)),
            ("bucket_mb", num(bucket_mb.unwrap_or(0.0))),
        ]),
    );
    top.insert("f32_sync".to_string(), train_report_json(&f32_sync));
    top.insert("f32_overlap".to_string(), train_report_json(&f32_overlap));
    top.insert("bf16_overlap".to_string(), train_report_json(&bf16_overlap));
    top.insert(
        "bf16_speedup_vs_f32_sync".to_string(),
        num(bf16_overlap.steps_per_sec / f32_sync.steps_per_sec.max(1e-9)),
    );
    top.insert("modeled".to_string(), modeled);
    Ok(Json::Obj(top))
}

/// Console rendering of a [`run_train_bench`] document.
pub fn render_train_table(doc: &Json) -> Table {
    let mut t = Table::new(&["config", "steps/s", "comm exposed", "overlap"]);
    let f = |j: &Json, key: &str| -> f64 {
        j.get(key).and_then(|v| v.as_f64()).unwrap_or(f64::NAN)
    };
    for key in ["f32_sync", "f32_overlap", "bf16_overlap"] {
        if let Ok(s) = doc.get(key) {
            t.row(&[
                key.into(),
                format!("{:.2}", f(s, "steps_per_sec")),
                format!(
                    "{:.0} / {:.0} µs",
                    f(s, "exposed_comm_us"),
                    f(s, "comm_us")
                ),
                format!("{:.1}%", 100.0 * f(s, "overlap_fraction")),
            ]);
        }
    }
    if let Ok(v) = doc.get("bf16_speedup_vs_f32_sync") {
        t.row(&[
            "bf16 stack vs f32 sync".into(),
            format!("{:.2}x", v.as_f64().unwrap_or(f64::NAN)),
            String::new(),
            String::new(),
        ]);
    }
    if let Ok(m) = doc.get("modeled") {
        t.row(&[
            "modeled scalefold (H100)".into(),
            format!("{:.1} h total", f(m, "scalefold_h100_total_hours")),
            format!(
                "{:.1} ms mono / {:.2} ms bucketed",
                f(m, "a100_ft_dp128_mono_exposed_ms"),
                f(m, "a100_ft_dp128_bucketed_exposed_ms")
            ),
            format!(
                "{:.1}%",
                100.0 * f(m, "a100_ft_dp128_bucketed_overlap_fraction")
            ),
        ]);
    }
    t
}

// ---------------------------------------------------------------- driver

/// Run the full host bench suite; returns the `BENCH_host.json` document.
pub fn run_host_bench(opts: BenchOptions) -> Result<Json> {
    let mut rng = Rng::new(2024);
    let mut top = BTreeMap::new();
    top.insert("bench".to_string(), Json::Str("host".into()));
    // version 2.0: per-backend simd_speedup ratios + thread_scaling curves
    top.insert("version".to_string(), Json::Num(2.0));
    top.insert("quick".to_string(), Json::Bool(opts.quick));
    top.insert(
        "device_backend".to_string(),
        Json::Str(crate::device::current().name().into()),
    );
    top.insert("shard_move".to_string(), bench_shard_move(&opts, &mut rng));
    top.insert("ring_all_reduce".to_string(), bench_ring(&opts, &mut rng));
    top.insert("fused_softmax".to_string(), bench_softmax(&opts, &mut rng));
    top.insert("fused_layernorm".to_string(), bench_layernorm(&opts, &mut rng));
    top.insert("fused_adam".to_string(), bench_adam(&opts, &mut rng));
    top.insert("thread_scaling".to_string(), bench_thread_scaling(&opts, &mut rng));
    top.insert("synthetic_train".to_string(), bench_synthetic_train(&opts)?);
    top.insert("serve_makespan".to_string(), bench_serve_makespan()?);
    Ok(Json::Obj(top))
}

/// Console rendering of a [`run_host_bench`] document.
pub fn render_table(doc: &Json) -> Table {
    let mut t = Table::new(&["metric", "baseline", "optimized", "speedup / rate"]);
    let f = |j: &Json, key: &str| -> f64 {
        j.get(key).and_then(|v| v.as_f64()).unwrap_or(f64::NAN)
    };
    if let Ok(s) = doc.get("shard_move") {
        t.row(&[
            format!("shard move ({}x dap{})", f(s, "elems"), f(s, "dap")),
            format!("{:.1} µs copy", f(s, "copy_us")),
            format!("{:.2} µs view", f(s, "view_us")),
            format!("{:.0}x", f(s, "speedup")),
        ]);
    }
    if let Ok(s) = doc.get("ring_all_reduce") {
        t.row(&[
            format!("ring all-reduce ({} ranks)", f(s, "ranks")),
            format!("{:.0} B wire", f(s, "wire_bytes")),
            format!("{:.2} ms", f(s, "time_ms")),
            format!("{:.2} GB/s", f(s, "gbps")),
        ]);
    }
    for (key, label) in [
        ("fused_softmax", "softmax"),
        ("fused_layernorm", "layernorm"),
        ("fused_adam", "adam"),
    ] {
        if let Ok(s) = doc.get(key) {
            t.row(&[
                format!("fused {label}"),
                format!("{:.1} µs naive", f(s, "naive_us")),
                format!("{:.1} µs fused", f(s, "fused_us")),
                format!("{:.2}x", f(s, "speedup")),
            ]);
            t.row(&[
                format!("simd {label} (1 thread)"),
                format!("{:.1} µs scalar", f(s, "scalar_us")),
                format!("{:.1} µs simd", f(s, "simd_us")),
                format!("{:.2}x", f(s, "simd_speedup")),
            ]);
        }
    }
    if let Ok(ts) = doc.get("thread_scaling") {
        for (key, label) in [("softmax", "softmax"), ("layernorm", "layernorm")] {
            if let Ok(s) = ts.get(key) {
                t.row(&[
                    format!("simd {label} threads 1→4"),
                    format!("{:.1} µs t1", f(s, "t1_us")),
                    format!("{:.1} µs t4", f(s, "t4_us")),
                    format!("{:.2}x", f(s, "scaling_1_to_4")),
                ]);
            }
        }
    }
    if let Ok(s) = doc.get("synthetic_train") {
        t.row(&[
            "synthetic train (dp2 x dap2)".into(),
            format!("{} steps", f(s, "steps")),
            String::new(),
            format!("{:.1} steps/s", f(s, "steps_per_sec")),
        ]);
    }
    if let Ok(s) = doc.get("serve_makespan") {
        t.row(&[
            "serve schedule (modeled)".into(),
            format!("{} reqs / {} lanes", f(s, "requests"), f(s, "lanes")),
            format!("{:.1} s makespan", f(s, "modeled_makespan_s")),
            format!("{:.2} PFLOP/s", f(s, "aggregate_pflops")),
        ]);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn serve_makespan_is_deterministic() {
        let a = bench_serve_makespan().unwrap();
        let b = bench_serve_makespan().unwrap();
        assert_eq!(a, b);
        let mk = a.get("modeled_makespan_s").unwrap().as_f64().unwrap();
        assert!(mk > 0.0);
        let adm = a.get("admitted").unwrap().as_f64().unwrap();
        assert!(adm >= 1.0);
    }

    #[test]
    fn synthetic_train_reports_steps() {
        let j = bench_synthetic_train(&BenchOptions { quick: true }).unwrap();
        assert_eq!(j.get("steps").unwrap().as_f64().unwrap(), 2.0);
        assert!(j.get("steps_per_sec").unwrap().as_f64().unwrap() > 0.0);
    }

    #[test]
    fn train_bench_ledger_has_gate_metrics() {
        let doc = run_train_bench(BenchOptions { quick: true }).unwrap();
        let f = |path: &[&str]| -> f64 {
            let mut j = &doc;
            for k in path {
                j = j.get(k).unwrap();
            }
            j.as_f64().unwrap()
        };
        // every measured configuration ran and made progress
        for key in ["f32_sync", "f32_overlap", "bf16_overlap"] {
            assert!(f(&[key, "steps_per_sec"]) > 0.0, "{key} steps/s");
            assert!(f(&[key, "comm_us"]) > 0.0, "{key} comm");
            let ov = f(&[key, "overlap_fraction"]);
            assert!((0.0..=1.0).contains(&ov), "{key} overlap {ov}");
            assert!(
                f(&[key, "exposed_comm_us"]) <= f(&[key, "comm_us"]) + 1e-9,
                "{key} exposed <= comm"
            );
        }
        // the synchronous baseline by construction hides nothing: its
        // monolithic all-reduce sits entirely on the critical path
        assert_eq!(f(&["f32_sync", "overlap_fraction"]), 0.0);
        assert_eq!(f(&["f32_sync", "prefetch_stall_us"]), 0.0);
        // the bf16 wire is exactly half the f32 wire: same elements,
        // 2 B each instead of 4, and no steps were skipped
        assert_eq!(f(&["bf16_overlap", "skipped_steps"]), 0.0);
        assert_eq!(
            2.0 * f(&["bf16_overlap", "dp_wire_bytes"]),
            f(&["f32_overlap", "dp_wire_bytes"])
        );
        // the speedup ratio and the modeled twin are present and finite
        assert!(f(&["bf16_speedup_vs_f32_sync"]).is_finite());
        let sf = f(&["modeled", "scalefold_h100_total_hours"]);
        assert!((sf - 10.3).abs() / 10.3 < 0.10, "scalefold hours {sf}");
        assert!(
            f(&["modeled", "a100_ft_dp128_bucketed_exposed_ms"])
                < f(&["modeled", "a100_ft_dp128_mono_exposed_ms"])
        );
        // rendering never panics on a fresh ledger: three measured
        // configs + the speedup row + the modeled twin
        let table = render_train_table(&doc);
        assert_eq!(table.rows.len(), 5);
    }
}
