//! `artifacts/manifest.json` — the contract between the python compile path
//! and the rust runtime: artifact files with typed I/O specs, the canonical
//! parameter flatten order + initial-params binary, batch specs, and the
//! DAP schedule.

use crate::error::{Error, Result};
use crate::json::Json;
use crate::tensor::HostTensor;
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

#[derive(Clone, Debug, PartialEq)]
pub enum DType {
    F32,
    I32,
}

impl DType {
    fn parse(s: &str) -> Result<Self> {
        match s {
            "float32" => Ok(DType::F32),
            "int32" => Ok(DType::I32),
            other => Err(Error::Manifest(format!("unsupported dtype '{other}'"))),
        }
    }
}

#[derive(Clone, Debug)]
pub struct TensorSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: DType,
}

impl TensorSpec {
    fn from_json(j: &Json) -> Result<Self> {
        Ok(TensorSpec {
            name: j.get("name")?.as_str()?.to_string(),
            shape: j
                .get("shape")?
                .as_arr()?
                .iter()
                .map(|v| v.as_usize())
                .collect::<Result<_>>()?,
            dtype: DType::parse(j.get("dtype")?.as_str()?)?,
        })
    }

    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
}

#[derive(Clone, Debug)]
pub struct ArtifactSpec {
    pub name: String,
    pub file: String,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

#[derive(Clone, Debug)]
pub struct ParamLeaf {
    pub name: String,
    pub shape: Vec<usize>,
    pub offset: usize,
}

#[derive(Clone, Debug)]
pub struct ParamSet {
    pub file: String,
    pub total: usize,
    pub count: usize,
    pub leaves: Vec<ParamLeaf>,
}

/// One op of the DAP schedule (mirrors python/compile/dap.py SCHEDULE).
#[derive(Clone, Debug, PartialEq)]
pub enum ScheduleOp {
    Exec { seg: String, inputs: Vec<String>, outputs: Vec<String> },
    Gather { input: String, output: String, axis: usize, id: Option<String> },
    Scatter { input: String, output: String, axis: usize, id: Option<String> },
    AllToAll {
        input: String,
        output: String,
        split: usize,
        concat: usize,
        id: Option<String>,
    },
    Wait { id: String },
}

#[derive(Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub artifacts: BTreeMap<String, ArtifactSpec>,
    pub params: BTreeMap<String, ParamSet>,
    pub schedule: Vec<ScheduleOp>,
    pub configs: BTreeMap<String, Json>,
}

impl Manifest {
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let src = std::fs::read_to_string(dir.join("manifest.json")).map_err(|e| {
            Error::Manifest(format!(
                "cannot read {}/manifest.json (run `make artifacts`): {e}",
                dir.display()
            ))
        })?;
        let j = Json::parse(&src)?;

        let mut artifacts = BTreeMap::new();
        for (name, spec) in j.get("artifacts")?.as_obj()? {
            let inputs = spec
                .get("inputs")?
                .as_arr()?
                .iter()
                .map(TensorSpec::from_json)
                .collect::<Result<Vec<_>>>()?;
            let outputs = spec
                .get("outputs")?
                .as_arr()?
                .iter()
                .map(TensorSpec::from_json)
                .collect::<Result<Vec<_>>>()?;
            artifacts.insert(
                name.clone(),
                ArtifactSpec {
                    name: name.clone(),
                    file: spec.get("file")?.as_str()?.to_string(),
                    inputs,
                    outputs,
                },
            );
        }

        let mut params = BTreeMap::new();
        for (cfg, p) in j.get("params")?.as_obj()? {
            let leaves = p
                .get("leaves")?
                .as_arr()?
                .iter()
                .map(|l| {
                    Ok(ParamLeaf {
                        name: l.get("name")?.as_str()?.to_string(),
                        shape: l
                            .get("shape")?
                            .as_arr()?
                            .iter()
                            .map(|v| v.as_usize())
                            .collect::<Result<_>>()?,
                        offset: l.get("offset")?.as_usize()?,
                    })
                })
                .collect::<Result<Vec<_>>>()?;
            params.insert(
                cfg.clone(),
                ParamSet {
                    file: p.get("file")?.as_str()?.to_string(),
                    total: p.get("total")?.as_usize()?,
                    count: p.get("count")?.as_usize()?,
                    leaves,
                },
            );
        }

        let schedule = parse_schedule(j.get("dap_schedule")?)?;
        let configs = j.get("configs")?.as_obj()?.clone();

        Ok(Manifest { dir, artifacts, params, schedule, configs })
    }

    pub fn artifact(&self, name: &str) -> Result<&ArtifactSpec> {
        self.artifacts
            .get(name)
            .ok_or_else(|| Error::Manifest(format!("no artifact '{name}'")))
    }

    pub fn artifact_path(&self, name: &str) -> Result<PathBuf> {
        Ok(self.dir.join(&self.artifact(name)?.file))
    }

    /// Load the initial parameter leaves for a config preset, in canonical
    /// flatten order, as host tensors.
    pub fn load_params(&self, preset: &str) -> Result<Vec<HostTensor>> {
        let ps = self
            .params
            .get(preset)
            .ok_or_else(|| Error::Manifest(format!("no params for '{preset}'")))?;
        let bytes = std::fs::read(self.dir.join(&ps.file))?;
        if bytes.len() != ps.total * 4 {
            return Err(Error::Manifest(format!(
                "params file {} is {} bytes, expected {}",
                ps.file,
                bytes.len(),
                ps.total * 4
            )));
        }
        ps.leaves
            .iter()
            .map(|leaf| {
                let n: usize = leaf.shape.iter().product();
                let start = leaf.offset * 4;
                let data: Vec<f32> = bytes[start..start + n * 4]
                    .chunks_exact(4)
                    .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
                    .collect();
                HostTensor::new(leaf.shape.clone(), data)
            })
            .collect()
    }

    /// Indices of the parameter leaves of `preset` whose names start with
    /// `prefix`, in canonical flatten order — the one prefix-filtered
    /// selection every execution path shares (embed/heads/block picks).
    pub fn leaf_indices_with_prefix(&self, preset: &str, prefix: &str) -> Result<Vec<usize>> {
        let ps = self
            .params
            .get(preset)
            .ok_or_else(|| Error::Manifest(format!("no params for '{preset}'")))?;
        Ok(ps
            .leaves
            .iter()
            .enumerate()
            .filter(|(_, l)| l.name.starts_with(prefix))
            .map(|(i, _)| i)
            .collect())
    }

    /// The subset of `params` (the full canonical leaf list of `preset`)
    /// whose leaf names start with `prefix`, cloned in canonical order.
    /// This replaces the hand-rolled `pick` closures the single-device and
    /// DAP inference paths used to duplicate.
    pub fn pick_params(
        &self,
        preset: &str,
        prefix: &str,
        params: &[HostTensor],
    ) -> Result<Vec<HostTensor>> {
        self.leaf_indices_with_prefix(preset, prefix)?
            .into_iter()
            .map(|i| {
                params.get(i).cloned().ok_or_else(|| {
                    Error::Manifest(format!(
                        "param list has {} leaves, canonical leaf index {i} \
                         out of range for '{preset}'",
                        params.len()
                    ))
                })
            })
            .collect()
    }

    /// Names of the parameter leaves belonging to block `i` of a preset,
    /// in canonical order (prefix `blocks/<i>/`).
    pub fn block_leaf_indices(&self, preset: &str, block: usize) -> Result<Vec<usize>> {
        let idx = self.leaf_indices_with_prefix(preset, &format!("blocks/{block}/"))?;
        if idx.is_empty() {
            return Err(Error::Manifest(format!(
                "no leaves for block {block} of '{preset}'"
            )));
        }
        Ok(idx)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn manifest_with_leaves(names: &[&str]) -> Manifest {
        let leaves: Vec<ParamLeaf> = names
            .iter()
            .enumerate()
            .map(|(i, n)| ParamLeaf { name: n.to_string(), shape: vec![1], offset: i })
            .collect();
        let mut params = BTreeMap::new();
        params.insert(
            "tiny".to_string(),
            ParamSet {
                file: "params.bin".into(),
                total: leaves.len(),
                count: leaves.len(),
                leaves,
            },
        );
        Manifest {
            dir: PathBuf::from("."),
            artifacts: BTreeMap::new(),
            params,
            schedule: Vec::new(),
            configs: BTreeMap::new(),
        }
    }

    #[test]
    fn prefix_indices_preserve_canonical_order() {
        let m = manifest_with_leaves(&[
            "embedder/a", "blocks/0/x", "heads/y", "blocks/0/z", "blocks/1/w",
        ]);
        assert_eq!(m.leaf_indices_with_prefix("tiny", "embedder/").unwrap(), vec![0]);
        assert_eq!(m.leaf_indices_with_prefix("tiny", "blocks/0/").unwrap(), vec![1, 3]);
        assert_eq!(m.leaf_indices_with_prefix("tiny", "heads/").unwrap(), vec![2]);
        assert_eq!(m.block_leaf_indices("tiny", 1).unwrap(), vec![4]);
        assert!(m.leaf_indices_with_prefix("nope", "x").is_err());
        assert!(m.block_leaf_indices("tiny", 7).is_err());
    }

    #[test]
    fn pick_params_clones_prefix_subset() {
        let m = manifest_with_leaves(&["embedder/a", "blocks/0/x", "heads/y"]);
        let params: Vec<HostTensor> = (0..3)
            .map(|i| HostTensor::full(&[1], i as f32))
            .collect();
        let picked = m.pick_params("tiny", "heads/", &params).unwrap();
        assert_eq!(picked.len(), 1);
        assert_eq!(picked[0].data(), &[2.0][..]);
        // a short param list (caller passed the wrong leaf vector) errors
        // instead of silently truncating the pick
        assert!(m.pick_params("tiny", "heads/", &params[..2]).is_err());
    }
}

fn parse_schedule(j: &Json) -> Result<Vec<ScheduleOp>> {
    j.as_arr()?
        .iter()
        .map(|op| {
            let kind = op.get("op")?.as_str()?;
            let id = op.opt("id").map(|v| v.as_str().map(String::from)).transpose()?;
            match kind {
                "exec" => Ok(ScheduleOp::Exec {
                    seg: op.get("seg")?.as_str()?.to_string(),
                    inputs: op
                        .get("in")?
                        .as_arr()?
                        .iter()
                        .map(|v| v.as_str().map(String::from))
                        .collect::<Result<_>>()?,
                    outputs: op
                        .get("out")?
                        .as_arr()?
                        .iter()
                        .map(|v| v.as_str().map(String::from))
                        .collect::<Result<_>>()?,
                }),
                "gather" => Ok(ScheduleOp::Gather {
                    input: op.get("in")?.as_str()?.to_string(),
                    output: op.get("out")?.as_str()?.to_string(),
                    axis: op.get("axis")?.as_usize()?,
                    id,
                }),
                "scatter" => Ok(ScheduleOp::Scatter {
                    input: op.get("in")?.as_str()?.to_string(),
                    output: op.get("out")?.as_str()?.to_string(),
                    axis: op.get("axis")?.as_usize()?,
                    id,
                }),
                "a2a" => Ok(ScheduleOp::AllToAll {
                    input: op.get("in")?.as_str()?.to_string(),
                    output: op.get("out")?.as_str()?.to_string(),
                    split: op.get("split")?.as_usize()?,
                    concat: op.get("concat")?.as_usize()?,
                    id,
                }),
                "wait" => Ok(ScheduleOp::Wait {
                    id: id.ok_or_else(|| Error::Manifest("wait without id".into()))?,
                }),
                other => Err(Error::Manifest(format!("unknown schedule op '{other}'"))),
            }
        })
        .collect()
}
