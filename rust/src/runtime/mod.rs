//! PJRT runtime: load AOT HLO-text artifacts, compile once, execute from
//! the coordinator hot path. Python never runs here.

mod client;
pub mod executable;

pub use client::Runtime;
pub use executable::{Executable, Value};
