//! [`Executable`]: a compiled PJRT executable plus its manifest I/O spec.
//! Validates shapes on the way in, decomposes the result tuple on the way
//! out, and keeps per-executable run statistics for the perf pass.

use crate::error::{Error, Result};
use crate::manifest::{ArtifactSpec, DType, TensorSpec};
use crate::tensor::{HostTensor, IntTensor};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::time::Instant; // lint:allow(wallclock) — executable run-time measurement

/// An input value: f32 tensor, i32 tensor, or f32 scalar.
#[derive(Clone, Debug)]
pub enum Value {
    F32(HostTensor),
    I32(IntTensor),
}

impl Value {
    pub fn shape(&self) -> &[usize] {
        match self {
            Value::F32(t) => &t.shape,
            Value::I32(t) => &t.shape,
        }
    }

    pub fn as_f32(&self) -> Result<&HostTensor> {
        match self {
            Value::F32(t) => Ok(t),
            _ => Err(Error::Shape("expected f32 tensor".into())),
        }
    }

    fn to_literal(&self) -> Result<xla::Literal> {
        match self {
            Value::F32(t) => t.to_literal(),
            Value::I32(t) => t.to_literal(),
        }
    }

    fn matches(&self, spec: &TensorSpec) -> bool {
        let dt_ok = matches!(
            (self, &spec.dtype),
            (Value::F32(_), DType::F32) | (Value::I32(_), DType::I32)
        );
        dt_ok && self.shape() == spec.shape.as_slice()
    }
}

impl From<HostTensor> for Value {
    fn from(t: HostTensor) -> Self {
        Value::F32(t)
    }
}

impl From<IntTensor> for Value {
    fn from(t: IntTensor) -> Self {
        Value::I32(t)
    }
}

/// Lock-free per-executable run statistics. The old `Mutex<ExecStats>`
/// serialized every rank worker on the ledger after each segment run;
/// relaxed atomic counters record without contention, and integer
/// nanosecond accumulation keeps the totals *exact* (addition of u64
/// nanos is associative — the sum is independent of thread interleaving,
/// unlike a float accumulator).
#[derive(Default, Debug)]
pub struct ExecStats {
    runs: AtomicUsize,
    total_nanos: AtomicU64,
}

impl ExecStats {
    /// Record one run of `seconds` wall time.
    pub fn record(&self, seconds: f64) {
        self.runs.fetch_add(1, Ordering::Relaxed);
        self.total_nanos
            .fetch_add((seconds * 1e9).round().max(0.0) as u64, Ordering::Relaxed);
    }

    /// Number of recorded runs.
    pub fn runs(&self) -> usize {
        self.runs.load(Ordering::Relaxed)
    }

    /// Total recorded wall seconds.
    pub fn total_seconds(&self) -> f64 {
        self.total_nanos.load(Ordering::Relaxed) as f64 / 1e9
    }
}

/// `Executable` is `Sync`: rank worker threads share one compiled
/// executable (`Arc<Executable>`) and record into the lock-free
/// [`ExecStats`] ledger without serializing on a mutex.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    pub spec: ArtifactSpec,
    pub stats: ExecStats,
}

impl Executable {
    pub(crate) fn new(exe: xla::PjRtLoadedExecutable, spec: ArtifactSpec) -> Self {
        Executable { exe, spec, stats: ExecStats::default() }
    }

    /// Execute with typed host values; returns the decomposed output tuple
    /// as f32 host tensors (all our artifact outputs are f32).
    pub fn run(&self, inputs: &[Value]) -> Result<Vec<HostTensor>> {
        if inputs.len() != self.spec.inputs.len() {
            return Err(Error::Shape(format!(
                "{}: {} inputs supplied, spec wants {}",
                self.spec.name,
                inputs.len(),
                self.spec.inputs.len()
            )));
        }
        for (i, (v, s)) in inputs.iter().zip(self.spec.inputs.iter()).enumerate() {
            if !v.matches(s) {
                return Err(Error::Shape(format!(
                    "{}: input {} ('{}') got shape {:?}, spec wants {:?} {:?}",
                    self.spec.name, i, s.name, v.shape(), s.shape, s.dtype
                )));
            }
        }
        let lits: Vec<xla::Literal> =
            inputs.iter().map(|v| v.to_literal()).collect::<Result<_>>()?;
        let t0 = Instant::now();
        let result = self.exe.execute::<xla::Literal>(&lits)?;
        let out_lit = result[0][0].to_literal_sync()?;
        self.stats.record(t0.elapsed().as_secs_f64());
        // lowered with return_tuple=True: always a tuple, even for 1 output
        let parts = out_lit.to_tuple()?;
        if parts.len() != self.spec.outputs.len() {
            return Err(Error::Shape(format!(
                "{}: got {} outputs, spec wants {}",
                self.spec.name,
                parts.len(),
                self.spec.outputs.len()
            )));
        }
        parts.iter().map(HostTensor::from_literal).collect()
    }

    /// Convenience: all-f32 inputs.
    pub fn run_f32(&self, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
        let vals: Vec<Value> = inputs.iter().cloned().map(Value::F32).collect();
        self.run(&vals)
    }

    /// Hot-path variant: leading inputs are pre-converted literals (e.g.
    /// cached block parameters — converted once per block, not once per
    /// segment execution); the trailing `rest` tensors are converted here.
    /// Shape validation for the literal prefix happened at cache build.
    pub fn run_with_params(
        &self,
        params: &[xla::Literal],
        rest: &[HostTensor],
    ) -> Result<Vec<HostTensor>> {
        let want = self.spec.inputs.len();
        if params.len() + rest.len() != want {
            return Err(Error::Shape(format!(
                "{}: {}+{} inputs supplied, spec wants {}",
                self.spec.name,
                params.len(),
                rest.len(),
                want
            )));
        }
        for (i, t) in rest.iter().enumerate() {
            let s = &self.spec.inputs[params.len() + i];
            if t.shape != s.shape {
                return Err(Error::Shape(format!(
                    "{}: input '{}' got {:?}, wants {:?}",
                    self.spec.name, s.name, t.shape, s.shape
                )));
            }
        }
        let rest_lits: Vec<xla::Literal> =
            rest.iter().map(|t| t.to_literal()).collect::<Result<_>>()?;
        let mut refs: Vec<&xla::Literal> = params.iter().collect();
        refs.extend(rest_lits.iter());
        let t0 = Instant::now();
        let result = self.exe.execute::<&xla::Literal>(&refs)?;
        let out_lit = result[0][0].to_literal_sync()?;
        self.stats.record(t0.elapsed().as_secs_f64());
        let parts = out_lit.to_tuple()?;
        if parts.len() != self.spec.outputs.len() {
            return Err(Error::Shape(format!(
                "{}: got {} outputs, spec wants {}",
                self.spec.name,
                parts.len(),
                self.spec.outputs.len()
            )));
        }
        parts.iter().map(HostTensor::from_literal).collect()
    }

    pub fn mean_run_seconds(&self) -> f64 {
        let runs = self.stats.runs();
        if runs == 0 {
            0.0
        } else {
            self.stats.total_seconds() / runs as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_are_exact_under_concurrency() {
        // the atomic ledger must lose nothing however threads interleave:
        // 8 workers × 1000 records of exactly 1 ms each (1 ms = 10^6
        // nanos, exactly representable) must total exactly 8 s / 8000 runs
        let st = ExecStats::default();
        std::thread::scope(|s| {
            for _ in 0..8 {
                s.spawn(|| {
                    for _ in 0..1000 {
                        st.record(0.001);
                    }
                });
            }
        });
        assert_eq!(st.runs(), 8000);
        assert!((st.total_seconds() - 8.0).abs() < 1e-12, "{}", st.total_seconds());
    }

    #[test]
    fn stats_empty_and_negative_guard() {
        let st = ExecStats::default();
        assert_eq!(st.runs(), 0);
        assert_eq!(st.total_seconds(), 0.0);
        // a (clock-skew) negative duration must not wrap the counter
        st.record(-1.0);
        assert_eq!(st.runs(), 1);
        assert_eq!(st.total_seconds(), 0.0);
    }
}
