//! [`Executable`]: a compiled PJRT executable plus its manifest I/O spec.
//! Validates shapes on the way in, decomposes the result tuple on the way
//! out, and keeps per-executable run statistics for the perf pass.

use crate::error::{Error, Result};
use crate::manifest::{ArtifactSpec, DType, TensorSpec};
use crate::tensor::{HostTensor, IntTensor};
use std::sync::Mutex;
use std::time::Instant;

/// An input value: f32 tensor, i32 tensor, or f32 scalar.
#[derive(Clone, Debug)]
pub enum Value {
    F32(HostTensor),
    I32(IntTensor),
}

impl Value {
    pub fn shape(&self) -> &[usize] {
        match self {
            Value::F32(t) => &t.shape,
            Value::I32(t) => &t.shape,
        }
    }

    pub fn as_f32(&self) -> Result<&HostTensor> {
        match self {
            Value::F32(t) => Ok(t),
            _ => Err(Error::Shape("expected f32 tensor".into())),
        }
    }

    fn to_literal(&self) -> Result<xla::Literal> {
        match self {
            Value::F32(t) => t.to_literal(),
            Value::I32(t) => t.to_literal(),
        }
    }

    fn matches(&self, spec: &TensorSpec) -> bool {
        let dt_ok = matches!(
            (self, &spec.dtype),
            (Value::F32(_), DType::F32) | (Value::I32(_), DType::I32)
        );
        dt_ok && self.shape() == spec.shape.as_slice()
    }
}

impl From<HostTensor> for Value {
    fn from(t: HostTensor) -> Self {
        Value::F32(t)
    }
}

impl From<IntTensor> for Value {
    fn from(t: IntTensor) -> Self {
        Value::I32(t)
    }
}

#[derive(Default, Clone, Debug)]
pub struct ExecStats {
    pub runs: usize,
    pub total_seconds: f64,
}

/// `Executable` is `Sync`: rank worker threads share one compiled
/// executable (`Arc<Executable>`) and race only on the stats ledger,
/// which sits behind a mutex.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    pub spec: ArtifactSpec,
    pub stats: Mutex<ExecStats>,
}

impl Executable {
    pub(crate) fn new(exe: xla::PjRtLoadedExecutable, spec: ArtifactSpec) -> Self {
        Executable { exe, spec, stats: Mutex::new(ExecStats::default()) }
    }

    /// Execute with typed host values; returns the decomposed output tuple
    /// as f32 host tensors (all our artifact outputs are f32).
    pub fn run(&self, inputs: &[Value]) -> Result<Vec<HostTensor>> {
        if inputs.len() != self.spec.inputs.len() {
            return Err(Error::Shape(format!(
                "{}: {} inputs supplied, spec wants {}",
                self.spec.name,
                inputs.len(),
                self.spec.inputs.len()
            )));
        }
        for (i, (v, s)) in inputs.iter().zip(self.spec.inputs.iter()).enumerate() {
            if !v.matches(s) {
                return Err(Error::Shape(format!(
                    "{}: input {} ('{}') got shape {:?}, spec wants {:?} {:?}",
                    self.spec.name, i, s.name, v.shape(), s.shape, s.dtype
                )));
            }
        }
        let lits: Vec<xla::Literal> =
            inputs.iter().map(|v| v.to_literal()).collect::<Result<_>>()?;
        let t0 = Instant::now();
        let result = self.exe.execute::<xla::Literal>(&lits)?;
        let out_lit = result[0][0].to_literal_sync()?;
        {
            let mut st = self.stats.lock().unwrap();
            st.runs += 1;
            st.total_seconds += t0.elapsed().as_secs_f64();
        }
        // lowered with return_tuple=True: always a tuple, even for 1 output
        let parts = out_lit.to_tuple()?;
        if parts.len() != self.spec.outputs.len() {
            return Err(Error::Shape(format!(
                "{}: got {} outputs, spec wants {}",
                self.spec.name,
                parts.len(),
                self.spec.outputs.len()
            )));
        }
        parts.iter().map(HostTensor::from_literal).collect()
    }

    /// Convenience: all-f32 inputs.
    pub fn run_f32(&self, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
        let vals: Vec<Value> = inputs.iter().cloned().map(Value::F32).collect();
        self.run(&vals)
    }

    /// Hot-path variant: leading inputs are pre-converted literals (e.g.
    /// cached block parameters — converted once per block, not once per
    /// segment execution); the trailing `rest` tensors are converted here.
    /// Shape validation for the literal prefix happened at cache build.
    pub fn run_with_params(
        &self,
        params: &[xla::Literal],
        rest: &[HostTensor],
    ) -> Result<Vec<HostTensor>> {
        let want = self.spec.inputs.len();
        if params.len() + rest.len() != want {
            return Err(Error::Shape(format!(
                "{}: {}+{} inputs supplied, spec wants {}",
                self.spec.name,
                params.len(),
                rest.len(),
                want
            )));
        }
        for (i, t) in rest.iter().enumerate() {
            let s = &self.spec.inputs[params.len() + i];
            if t.shape != s.shape {
                return Err(Error::Shape(format!(
                    "{}: input '{}' got {:?}, wants {:?}",
                    self.spec.name, s.name, t.shape, s.shape
                )));
            }
        }
        let rest_lits: Vec<xla::Literal> =
            rest.iter().map(|t| t.to_literal()).collect::<Result<_>>()?;
        let mut refs: Vec<&xla::Literal> = params.iter().collect();
        refs.extend(rest_lits.iter());
        let t0 = Instant::now();
        let result = self.exe.execute::<&xla::Literal>(&refs)?;
        let out_lit = result[0][0].to_literal_sync()?;
        {
            let mut st = self.stats.lock().unwrap();
            st.runs += 1;
            st.total_seconds += t0.elapsed().as_secs_f64();
        }
        let parts = out_lit.to_tuple()?;
        if parts.len() != self.spec.outputs.len() {
            return Err(Error::Shape(format!(
                "{}: got {} outputs, spec wants {}",
                self.spec.name,
                parts.len(),
                self.spec.outputs.len()
            )));
        }
        parts.iter().map(HostTensor::from_literal).collect()
    }

    pub fn mean_run_seconds(&self) -> f64 {
        let st = self.stats.lock().unwrap();
        if st.runs == 0 {
            0.0
        } else {
            st.total_seconds / st.runs as f64
        }
    }
}
