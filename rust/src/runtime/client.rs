//! [`Runtime`]: one PJRT CPU client + an executable cache keyed by artifact
//! name. Compilation happens once per artifact (`HloModuleProto::from_text_file`
//! → `XlaComputation` → `client.compile`); subsequent loads hit the cache.

use super::executable::Executable;
use crate::error::{Error, Result};
use crate::manifest::Manifest;
use std::collections::BTreeMap;
use std::sync::{Arc, Mutex};
use std::time::Instant; // lint:allow(wallclock) — PJRT load-time measurement

pub struct Runtime {
    client: xla::PjRtClient,
    pub manifest: Manifest,
    cache: Mutex<BTreeMap<String, Arc<Executable>>>,
    /// cumulative compile time, for the perf log
    pub compile_seconds: Mutex<f64>,
}

impl Runtime {
    /// Create a CPU PJRT client and read the artifact manifest.
    pub fn new(artifacts_dir: &str) -> Result<Self> {
        let manifest = Manifest::load(artifacts_dir)?;
        let client = xla::PjRtClient::cpu()?;
        Ok(Runtime {
            client,
            manifest,
            cache: Mutex::new(BTreeMap::new()),
            compile_seconds: Mutex::new(0.0),
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Name of the host device backend the kernel plane dispatches
    /// through ([`crate::device::current`]) — the runtime never names a
    /// concrete backend itself, it only reports the active selection.
    pub fn device_backend(&self) -> &'static str {
        crate::device::current().name()
    }

    /// Load (compile-once, cached) an artifact by manifest name. The
    /// returned `Arc` is sharable across rank worker threads.
    pub fn load(&self, name: &str) -> Result<Arc<Executable>> {
        if let Some(e) = self.cache.lock().unwrap().get(name) {
            return Ok(e.clone());
        }
        let spec = self.manifest.artifact(name)?.clone();
        let path = self.manifest.artifact_path(name)?;
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(&path).map_err(|e| {
            Error::Manifest(format!("loading {}: {e}", path.display()))
        })?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp)?;
        *self.compile_seconds.lock().unwrap() += t0.elapsed().as_secs_f64();
        let exec = Arc::new(Executable::new(exe, spec));
        self.cache.lock().unwrap().insert(name.to_string(), exec.clone());
        Ok(exec)
    }

    /// Number of compiled executables currently cached.
    pub fn cached(&self) -> usize {
        self.cache.lock().unwrap().len()
    }
}
