//! Checkpointing.
//!
//! **V2 (current)**: the *full* training state — parameters, Adam moments
//! (m, v), optimizer step, schedule position (stage, step-in-stage), and
//! per-rank data-generator cursors + RNG states — so a resumed run is
//! bit-for-bit identical to an uninterrupted one. The V1 format persisted
//! only parameters, which silently restarted Adam moments, the step
//! count, warmup, and the data stream on resume.
//!
//! Layout: one raw little-endian f32 blob (`params | m | v`, canonical
//! leaf order — the same layout as the exported `*_params.bin`, three
//! times over) plus a JSON sidecar with `version`, shapes, a CRC-32 of
//! the blob, and the schedule/data cursors. V1 checkpoints (no `version`
//! key, params-only blob) remain loadable through [`load`].
//!
//! Writes are **atomic** (temp file + fsync + rename), so a crash
//! mid-checkpoint leaves either the previous file or none — never a
//! truncated blob. Readers verify the sidecar CRC before deserializing;
//! [`load_latest_full`] skips corrupt entries and falls back to the
//! newest checkpoint that still verifies, which is what the trainer's
//! elastic-recovery rollback uses.

use crate::error::{Error, Result};
use crate::json::Json;
use crate::tensor::HostTensor;
use std::collections::BTreeMap;
use std::path::Path;

/// Current checkpoint format version.
pub const FORMAT_VERSION: usize = 2;

/// Everything a resumed run needs to continue bit-for-bit.
#[derive(Clone, Debug)]
pub struct TrainState {
    /// model preset the state belongs to
    pub preset: String,
    /// global optimizer step
    pub step: usize,
    /// schedule stage index
    pub stage: usize,
    /// optimizer steps taken inside the current stage
    pub steps_in_stage: usize,
    /// gradient-accumulation factor the run used — the per-rank cursor
    /// stride is `dp × accum`, so resuming under a different accum would
    /// silently misalign the data streams; restore() rejects a mismatch
    pub accum: usize,
    /// parameters (canonical leaf order)
    pub params: Vec<HostTensor>,
    /// Adam first moments
    pub m: Vec<HostTensor>,
    /// Adam second moments
    pub v: Vec<HostTensor>,
    /// per-DP-rank data-generator cursors (batches drawn incl. skips)
    pub cursors: Vec<u64>,
    /// per-DP-rank data-generator RNG states
    pub rng_states: Vec<(u64, u64)>,
}

fn stem(preset: &str, step: usize) -> String {
    format!("{preset}_step{step:06}")
}

/// Write `bytes` to `dir/name` atomically: a `.tmp` sibling is written
/// and fsynced first, then renamed over the target, so readers only ever
/// observe complete files.
fn write_atomic(dir: &Path, name: &str, bytes: &[u8]) -> Result<()> {
    let tmp = dir.join(format!("{name}.tmp"));
    {
        use std::io::Write as _;
        let mut f = std::fs::File::create(&tmp)?;
        f.write_all(bytes)?;
        f.sync_all()?;
    }
    std::fs::rename(&tmp, dir.join(name))?;
    Ok(())
}

/// Verify a blob against the sidecar's `crc` key (absent on checkpoints
/// written by older builds — those load unverified).
fn verify_crc(stem: &str, meta: &Json, bytes: &[u8]) -> Result<()> {
    if let Some(c) = meta.opt("crc") {
        let want = c.as_usize()? as u32;
        let got = crate::faults::crc32(bytes);
        if got != want {
            return Err(Error::msg(format!(
                "checkpoint {stem}: blob crc32 {got:#010x} does not match \
                 header {want:#010x} (corrupt or tampered checkpoint)"
            )));
        }
    }
    Ok(())
}

fn write_tensors(bytes: &mut Vec<u8>, ts: &[HostTensor]) {
    for t in ts {
        for v in t.data() {
            bytes.extend_from_slice(&v.to_le_bytes());
        }
    }
}

/// Save a full V2 checkpoint; returns the blob path.
pub fn save_full(dir: &str, state: &TrainState) -> Result<String> {
    std::fs::create_dir_all(dir)?;
    let stem = stem(&state.preset, state.step);
    if state.m.len() != state.params.len() || state.v.len() != state.params.len() {
        return Err(Error::msg(format!(
            "checkpoint {stem}: params/m/v leaf counts differ ({}/{}/{})",
            state.params.len(),
            state.m.len(),
            state.v.len()
        )));
    }
    if state.cursors.len() != state.rng_states.len() {
        return Err(Error::msg(format!(
            "checkpoint {stem}: {} cursors but {} rng states",
            state.cursors.len(),
            state.rng_states.len()
        )));
    }
    let bin_path = Path::new(dir).join(format!("{stem}.bin"));
    let mut bytes = Vec::new();
    write_tensors(&mut bytes, &state.params);
    write_tensors(&mut bytes, &state.m);
    write_tensors(&mut bytes, &state.v);
    write_atomic(Path::new(dir), &format!("{stem}.bin"), &bytes)?;

    let mut meta = BTreeMap::new();
    meta.insert("version".to_string(), Json::Num(FORMAT_VERSION as f64));
    meta.insert(
        "crc".to_string(),
        Json::Num(crate::faults::crc32(&bytes) as f64),
    );
    meta.insert("preset".to_string(), Json::Str(state.preset.clone()));
    meta.insert("step".to_string(), Json::Num(state.step as f64));
    meta.insert("stage".to_string(), Json::Num(state.stage as f64));
    meta.insert(
        "steps_in_stage".to_string(),
        Json::Num(state.steps_in_stage as f64),
    );
    meta.insert("accum".to_string(), Json::Num(state.accum as f64));
    meta.insert(
        "shapes".to_string(),
        Json::Arr(
            state
                .params
                .iter()
                .map(|p| {
                    Json::Arr(p.shape.iter().map(|&d| Json::Num(d as f64)).collect())
                })
                .collect(),
        ),
    );
    meta.insert(
        "cursors".to_string(),
        Json::Arr(state.cursors.iter().map(|&c| Json::Num(c as f64)).collect()),
    );
    // RNG states are full u64s — hex strings, since Json::Num is an f64
    meta.insert(
        "rng".to_string(),
        Json::Arr(
            state
                .rng_states
                .iter()
                .map(|(s0, s1)| Json::Str(format!("{s0:016x}:{s1:016x}")))
                .collect(),
        ),
    );
    write_atomic(
        Path::new(dir),
        &format!("{stem}.json"),
        Json::Obj(meta).to_string().as_bytes(),
    )?;
    Ok(bin_path.display().to_string())
}

fn parse_shapes(meta: &Json) -> Result<Vec<Vec<usize>>> {
    meta.get("shapes")?
        .as_arr()?
        .iter()
        .map(|s| s.as_arr()?.iter().map(|d| d.as_usize()).collect())
        .collect::<Result<_>>()
}

fn read_tensors(
    bytes: &[u8],
    shapes: &[Vec<usize>],
    offset_elems: usize,
) -> Result<Vec<HostTensor>> {
    let mut out = Vec::with_capacity(shapes.len());
    let mut off = offset_elems;
    for shape in shapes {
        let n: usize = shape.iter().product();
        let data: Vec<f32> = bytes[off * 4..(off + n) * 4]
            .chunks_exact(4)
            .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
            .collect();
        out.push(HostTensor::new(shape.clone(), data)?);
        off += n;
    }
    Ok(out)
}

fn parse_rng(s: &str) -> Result<(u64, u64)> {
    let (a, b) = s
        .split_once(':')
        .ok_or_else(|| Error::Json(format!("bad rng state '{s}'")))?;
    let p = |h: &str| {
        u64::from_str_radix(h, 16)
            .map_err(|_| Error::Json(format!("bad rng state '{s}'")))
    };
    Ok((p(a)?, p(b)?))
}

/// Load a full V2 checkpoint (errors on V1 — params-only checkpoints
/// cannot resume the optimizer; use [`load`] to read just parameters).
pub fn load_full(dir: &str, preset: &str, step: usize) -> Result<TrainState> {
    let stem = stem(preset, step);
    let meta_src = std::fs::read_to_string(Path::new(dir).join(format!("{stem}.json")))?;
    let meta = Json::parse(&meta_src)?;
    let version = match meta.opt("version") {
        Some(v) => v.as_usize()?,
        None => 1,
    };
    if version != FORMAT_VERSION {
        return Err(Error::msg(format!(
            "checkpoint {stem} is format v{version}: params-only, cannot \
             resume optimizer state (re-checkpoint with this build for \
             full-state resume)"
        )));
    }
    let shapes = parse_shapes(&meta)?;
    let total: usize = shapes.iter().map(|s| s.iter().product::<usize>()).sum();
    let bytes = std::fs::read(Path::new(dir).join(format!("{stem}.bin")))?;
    if bytes.len() != 3 * total * 4 {
        return Err(Error::msg(format!(
            "checkpoint {stem}: {} bytes, expected {} (params+m+v)",
            bytes.len(),
            3 * total * 4
        )));
    }
    verify_crc(&stem, &meta, &bytes)?;
    let params = read_tensors(&bytes, &shapes, 0)?;
    let m = read_tensors(&bytes, &shapes, total)?;
    let v = read_tensors(&bytes, &shapes, 2 * total)?;
    let cursors: Vec<u64> = meta
        .get("cursors")?
        .as_arr()?
        .iter()
        .map(|c| c.as_usize().map(|u| u as u64))
        .collect::<Result<_>>()?;
    let rng_states: Vec<(u64, u64)> = meta
        .get("rng")?
        .as_arr()?
        .iter()
        .map(|s| parse_rng(s.as_str()?))
        .collect::<Result<_>>()?;
    if cursors.len() != rng_states.len() {
        return Err(Error::msg(format!(
            "checkpoint {stem}: {} cursors but {} rng states",
            cursors.len(),
            rng_states.len()
        )));
    }
    Ok(TrainState {
        preset: meta.get("preset")?.as_str()?.to_string(),
        step: meta.get("step")?.as_usize()?,
        stage: meta.get("stage")?.as_usize()?,
        steps_in_stage: meta.get("steps_in_stage")?.as_usize()?,
        accum: meta.get("accum")?.as_usize()?,
        params,
        m,
        v,
        cursors,
        rng_states,
    })
}

/// All checkpointed steps for `preset` in `dir`, ascending (empty when
/// the directory does not exist).
fn scan_steps(dir: &str, preset: &str) -> Result<Vec<usize>> {
    let prefix = format!("{preset}_step");
    let mut steps = Vec::new();
    let entries = match std::fs::read_dir(dir) {
        Ok(e) => e,
        Err(_) => return Ok(steps),
    };
    for entry in entries {
        let name = entry?.file_name();
        let name = name.to_string_lossy();
        if let Some(rest) = name.strip_prefix(&prefix) {
            if let Some(digits) = rest.strip_suffix(".json") {
                if let Ok(step) = digits.parse::<usize>() {
                    steps.push(step);
                }
            }
        }
    }
    steps.sort_unstable();
    Ok(steps)
}

/// Highest checkpointed step for `preset` in `dir` (None when no
/// checkpoint exists) — what `fastfold train --resume` picks up.
pub fn latest_step(dir: &str, preset: &str) -> Result<Option<usize>> {
    Ok(scan_steps(dir, preset)?.pop())
}

/// Load the newest checkpoint for `preset` that still *verifies*: scan
/// candidate steps highest-first and skip entries whose blob is missing,
/// truncated, or fails the header CRC. This is the rollback target the
/// trainer's elastic recovery uses — a crash mid-write (or a corrupted
/// file) silently falls back to the previous good checkpoint.
pub fn load_latest_full(
    dir: &str,
    preset: &str,
) -> Result<Option<(usize, TrainState)>> {
    for &step in scan_steps(dir, preset)?.iter().rev() {
        if let Ok(state) = load_full(dir, preset, step) {
            return Ok(Some((step, state)));
        }
    }
    Ok(None)
}

/// Save a params-only V1 checkpoint (kept for export/interop; training
/// uses [`save_full`]).
pub fn save(dir: &str, preset: &str, step: usize, params: &[HostTensor]) -> Result<String> {
    std::fs::create_dir_all(dir)?;
    let stem = stem(preset, step);
    let bin_path = Path::new(dir).join(format!("{stem}.bin"));
    let mut bytes = Vec::new();
    write_tensors(&mut bytes, params);
    write_atomic(Path::new(dir), &format!("{stem}.bin"), &bytes)?;

    let mut meta = BTreeMap::new();
    meta.insert(
        "crc".to_string(),
        Json::Num(crate::faults::crc32(&bytes) as f64),
    );
    meta.insert("preset".to_string(), Json::Str(preset.to_string()));
    meta.insert("step".to_string(), Json::Num(step as f64));
    meta.insert(
        "shapes".to_string(),
        Json::Arr(
            params
                .iter()
                .map(|p| {
                    Json::Arr(p.shape.iter().map(|&d| Json::Num(d as f64)).collect())
                })
                .collect(),
        ),
    );
    write_atomic(
        Path::new(dir),
        &format!("{stem}.json"),
        Json::Obj(meta).to_string().as_bytes(),
    )?;
    Ok(bin_path.display().to_string())
}

/// Load only the parameters (reads both V1 and V2 blobs).
pub fn load(dir: &str, preset: &str, step: usize) -> Result<(usize, Vec<HostTensor>)> {
    let stem = stem(preset, step);
    let meta_src = std::fs::read_to_string(Path::new(dir).join(format!("{stem}.json")))?;
    let meta = Json::parse(&meta_src)?;
    let got_step = meta.get("step")?.as_usize()?;
    let version = match meta.opt("version") {
        Some(v) => v.as_usize()?,
        None => 1,
    };
    let shapes = parse_shapes(&meta)?;
    let bytes = std::fs::read(Path::new(dir).join(format!("{stem}.bin")))?;
    let total: usize = shapes.iter().map(|s| s.iter().product::<usize>()).sum();
    let expect = if version >= 2 { 3 * total * 4 } else { total * 4 };
    if bytes.len() != expect {
        return Err(Error::msg(format!(
            "checkpoint {stem}: {} bytes, expected {expect}",
            bytes.len()
        )));
    }
    verify_crc(&stem, &meta, &bytes)?;
    let params = read_tensors(&bytes, &shapes, 0)?;
    Ok((got_step, params))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> String {
        let dir = std::env::temp_dir().join(name);
        std::fs::remove_dir_all(&dir).ok();
        dir.to_str().unwrap().to_string()
    }

    fn leaves(seed: f32) -> Vec<HostTensor> {
        vec![
            HostTensor::new(vec![2, 3], (0..6).map(|i| seed + i as f32).collect())
                .unwrap(),
            HostTensor::scalar(seed * 7.5),
        ]
    }

    #[test]
    fn v1_roundtrip() {
        let dir = tmp("ff_ckpt_v1");
        let params = leaves(1.0);
        save(&dir, "tiny", 42, &params).unwrap();
        let (step, loaded) = load(&dir, "tiny", 42).unwrap();
        assert_eq!(step, 42);
        assert_eq!(loaded, params);
        // V1 cannot resume optimizer state — loud error, not silent zeros
        let err = load_full(&dir, "tiny", 42).unwrap_err();
        assert!(err.to_string().contains("v1"), "{err}");
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn v2_full_roundtrip() {
        let dir = tmp("ff_ckpt_v2");
        let state = TrainState {
            preset: "tiny".into(),
            step: 7,
            stage: 1,
            steps_in_stage: 3,
            accum: 2,
            params: leaves(1.0),
            m: leaves(0.25),
            v: leaves(0.5),
            cursors: vec![12, 12],
            rng_states: vec![(u64::MAX, 1), (0x1234_5678_9abc_def0, 42)],
        };
        save_full(&dir, &state).unwrap();
        let got = load_full(&dir, "tiny", 7).unwrap();
        assert_eq!(got.step, 7);
        assert_eq!(got.stage, 1);
        assert_eq!(got.steps_in_stage, 3);
        assert_eq!(got.accum, 2);
        assert_eq!(got.params, state.params);
        assert_eq!(got.m, state.m);
        assert_eq!(got.v, state.v);
        assert_eq!(got.cursors, state.cursors);
        assert_eq!(got.rng_states, state.rng_states);
        // params-only reader sees just the parameter section
        let (step, params) = load(&dir, "tiny", 7).unwrap();
        assert_eq!(step, 7);
        assert_eq!(params, state.params);
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn latest_step_scans_dir() {
        let dir = tmp("ff_ckpt_latest");
        assert_eq!(latest_step(&dir, "tiny").unwrap(), None);
        for step in [2usize, 10, 6] {
            let state = TrainState {
                preset: "tiny".into(),
                step,
                stage: 0,
                steps_in_stage: step,
                accum: 1,
                params: leaves(1.0),
                m: leaves(0.0),
                v: leaves(0.0),
                cursors: vec![step as u64],
                rng_states: vec![(1, 2)],
            };
            save_full(&dir, &state).unwrap();
        }
        assert_eq!(latest_step(&dir, "tiny").unwrap(), Some(10));
        assert_eq!(latest_step(&dir, "small").unwrap(), None);
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn missing_checkpoint_errors() {
        assert!(load("/nonexistent_dir_xyz", "tiny", 1).is_err());
        assert!(load_full("/nonexistent_dir_xyz", "tiny", 1).is_err());
        assert_eq!(
            load_latest_full("/nonexistent_dir_xyz", "tiny").unwrap().map(|x| x.0),
            None
        );
    }

    fn state_at(step: usize, seed: f32) -> TrainState {
        TrainState {
            preset: "tiny".into(),
            step,
            stage: 0,
            steps_in_stage: step,
            accum: 1,
            params: leaves(seed),
            m: leaves(0.0),
            v: leaves(0.0),
            cursors: vec![step as u64],
            rng_states: vec![(1, 2)],
        }
    }

    #[test]
    fn partially_written_checkpoint_is_detected_and_previous_used() {
        let dir = tmp("ff_ckpt_corrupt");
        save_full(&dir, &state_at(2, 1.0)).unwrap();
        save_full(&dir, &state_at(4, 9.0)).unwrap();
        // sanity: the newest checkpoint wins while both verify
        assert_eq!(load_latest_full(&dir, "tiny").unwrap().unwrap().0, 4);
        // simulate a crash mid-write: truncate the step-4 blob
        let blob = Path::new(&dir).join("tiny_step000004.bin");
        let bytes = std::fs::read(&blob).unwrap();
        std::fs::write(&blob, &bytes[..bytes.len() / 2]).unwrap();
        assert!(load_full(&dir, "tiny", 4).is_err());
        let (step, state) = load_latest_full(&dir, "tiny").unwrap().unwrap();
        assert_eq!(step, 2);
        assert_eq!(state.params, leaves(1.0));
        // a same-length bit flip slips past the size check but trips CRC
        let mut flipped = bytes.clone();
        flipped[3] ^= 0x40;
        std::fs::write(&blob, &flipped).unwrap();
        let err = load_full(&dir, "tiny", 4).unwrap_err();
        assert!(err.to_string().contains("crc32"), "{err}");
        // restoring the pristine bytes makes step 4 the target again
        std::fs::write(&blob, &bytes).unwrap();
        assert_eq!(load_latest_full(&dir, "tiny").unwrap().unwrap().0, 4);
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn atomic_writes_leave_no_tmp_files() {
        let dir = tmp("ff_ckpt_atomic");
        save_full(&dir, &state_at(3, 1.5)).unwrap();
        save(&dir, "tiny", 8, &leaves(2.0)).unwrap();
        let leftovers: Vec<String> = std::fs::read_dir(&dir)
            .unwrap()
            .map(|e| e.unwrap().file_name().to_string_lossy().into_owned())
            .filter(|n| n.ends_with(".tmp"))
            .collect();
        assert!(leftovers.is_empty(), "stray temp files: {leftovers:?}");
        std::fs::remove_dir_all(dir).ok();
    }
}
