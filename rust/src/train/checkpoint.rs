//! Checkpointing: parameters as raw little-endian f32 in canonical leaf
//! order (the same layout as the exported `*_params.bin`), plus a small
//! JSON sidecar with step + shapes for integrity checking on load.

use crate::error::{Error, Result};
use crate::json::Json;
use crate::tensor::HostTensor;
use std::collections::BTreeMap;
use std::path::Path;

pub fn save(dir: &str, preset: &str, step: usize, params: &[HostTensor]) -> Result<String> {
    std::fs::create_dir_all(dir)?;
    let stem = format!("{preset}_step{step:06}");
    let bin_path = Path::new(dir).join(format!("{stem}.bin"));
    let mut bytes = Vec::new();
    for p in params {
        for v in &p.data {
            bytes.extend_from_slice(&v.to_le_bytes());
        }
    }
    std::fs::write(&bin_path, &bytes)?;

    let mut meta = BTreeMap::new();
    meta.insert("preset".to_string(), Json::Str(preset.to_string()));
    meta.insert("step".to_string(), Json::Num(step as f64));
    meta.insert(
        "shapes".to_string(),
        Json::Arr(
            params
                .iter()
                .map(|p| {
                    Json::Arr(p.shape.iter().map(|&d| Json::Num(d as f64)).collect())
                })
                .collect(),
        ),
    );
    let meta_path = Path::new(dir).join(format!("{stem}.json"));
    std::fs::write(&meta_path, Json::Obj(meta).to_string())?;
    Ok(bin_path.display().to_string())
}

pub fn load(dir: &str, preset: &str, step: usize) -> Result<(usize, Vec<HostTensor>)> {
    let stem = format!("{preset}_step{step:06}");
    let meta_src = std::fs::read_to_string(Path::new(dir).join(format!("{stem}.json")))?;
    let meta = Json::parse(&meta_src)?;
    let got_step = meta.get("step")?.as_usize()?;
    let shapes: Vec<Vec<usize>> = meta
        .get("shapes")?
        .as_arr()?
        .iter()
        .map(|s| s.as_arr()?.iter().map(|d| d.as_usize()).collect())
        .collect::<Result<_>>()?;
    let bytes = std::fs::read(Path::new(dir).join(format!("{stem}.bin")))?;
    let total: usize = shapes.iter().map(|s| s.iter().product::<usize>()).sum();
    if bytes.len() != total * 4 {
        return Err(Error::msg(format!(
            "checkpoint {stem}: {} bytes, expected {}",
            bytes.len(),
            total * 4
        )));
    }
    let mut params = Vec::with_capacity(shapes.len());
    let mut off = 0usize;
    for shape in shapes {
        let n: usize = shape.iter().product();
        let data: Vec<f32> = bytes[off * 4..(off + n) * 4]
            .chunks_exact(4)
            .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
            .collect();
        params.push(HostTensor::new(shape, data)?);
        off += n;
    }
    Ok((got_step, params))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let dir = std::env::temp_dir().join("ff_ckpt_test");
        let dir = dir.to_str().unwrap();
        let params = vec![
            HostTensor::new(vec![2, 3], vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]).unwrap(),
            HostTensor::scalar(7.5),
        ];
        save(dir, "tiny", 42, &params).unwrap();
        let (step, loaded) = load(dir, "tiny", 42).unwrap();
        assert_eq!(step, 42);
        assert_eq!(loaded, params);
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn missing_checkpoint_errors() {
        assert!(load("/nonexistent_dir_xyz", "tiny", 1).is_err());
    }
}
