//! The two-stage AlphaFold training recipe (paper Table I / §V.B) and the
//! full learning-rate shape.
//!
//! AlphaFold trains in two stages: **initial training** at crop
//! (N_r=256, N_s=128) for ~10M samples, then **fine-tuning** at
//! (N_r=384, N_s=512) for ~1.5M samples at a lower LR. Within a stage the
//! LR shape is linear-warmup → constant → a multiplicative stage decay
//! ([`LrSchedule`]); the old `lr_at` warmup-only helper is the degenerate
//! case with no decay.

use crate::config::{ModelConfig, TrainConfig};
use crate::error::{Error, Result};

/// Warmup → constant → stage-decay learning-rate shape.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct LrSchedule {
    /// plateau LR after warmup
    pub base_lr: f32,
    /// linear warmup length in steps (0 = start at `base_lr`)
    pub warmup_steps: usize,
    /// step at which the stage decay kicks in (None = never)
    pub decay_after: Option<usize>,
    /// multiplicative factor applied from `decay_after` on (AlphaFold
    /// drops to 0.95× for the tail of initial training)
    pub decay_factor: f32,
}

impl LrSchedule {
    /// Warmup-only schedule — exactly the repo's original `lr_at` shape.
    pub fn warmup_only(base_lr: f32, warmup_steps: usize) -> Self {
        LrSchedule { base_lr, warmup_steps, decay_after: None, decay_factor: 1.0 }
    }

    /// Schedule described by a [`TrainConfig`] (its `lr_decay_after` /
    /// `lr_decay_factor` knobs; `None` decay when unset).
    pub fn from_train_config(cfg: &TrainConfig) -> Self {
        LrSchedule {
            base_lr: cfg.lr,
            warmup_steps: cfg.warmup_steps,
            decay_after: cfg.lr_decay_after,
            decay_factor: cfg.lr_decay_factor,
        }
    }

    /// LR applied at (0-indexed) `step` within the stage.
    pub fn at(&self, step: usize) -> f32 {
        let lr = if self.warmup_steps == 0 || step >= self.warmup_steps {
            self.base_lr
        } else {
            self.base_lr * (step + 1) as f32 / self.warmup_steps as f32
        };
        match self.decay_after {
            Some(d) if step >= d => lr * self.decay_factor,
            _ => lr,
        }
    }
}

/// One stage of the recipe: a model preset (crop geometry) trained for a
/// fixed number of optimizer steps under its own LR schedule.
#[derive(Clone, Debug)]
pub struct Stage {
    /// stage label ("initial" / "finetune")
    pub name: String,
    /// model preset the stage runs (`ModelConfig::preset` name)
    pub preset: String,
    /// optimizer steps in this stage
    pub steps: usize,
    /// LR shape within the stage
    pub lr: LrSchedule,
}

/// An ordered list of stages — the unit `Trainer::run_schedule` executes
/// and the V2 checkpoint indexes into (`stage`, `steps_in_stage`).
#[derive(Clone, Debug)]
pub struct TrainSchedule {
    /// stages in execution order (never empty)
    pub stages: Vec<Stage>,
}

impl TrainSchedule {
    /// A single-stage schedule over `preset` with the config's LR knobs —
    /// what plain `fastfold train` runs.
    pub fn single(preset: &str, cfg: &TrainConfig) -> Self {
        TrainSchedule {
            stages: vec![Stage {
                name: "train".into(),
                preset: preset.to_string(),
                steps: cfg.steps,
                lr: LrSchedule::from_train_config(cfg),
            }],
        }
    }

    /// The paper's two-stage recipe at a given global batch size:
    /// initial training (10M samples, LR 1e-3, 1k-step warmup, 0.95×
    /// stage decay over the final 7.5%) then fine-tuning (1.5M samples,
    /// LR 5e-4, no warmup).
    pub fn alphafold(global_batch: usize) -> Self {
        let gb = global_batch.max(1);
        let init_steps = 10_000_000 / gb;
        let ft_steps = 1_500_000 / gb;
        TrainSchedule {
            stages: vec![
                Stage {
                    name: "initial".into(),
                    preset: "initial_training".into(),
                    steps: init_steps,
                    lr: LrSchedule {
                        base_lr: 1e-3,
                        warmup_steps: 1000.min(init_steps),
                        decay_after: Some(init_steps - init_steps / 13),
                        decay_factor: 0.95,
                    },
                },
                Stage {
                    name: "finetune".into(),
                    preset: "finetune".into(),
                    steps: ft_steps,
                    lr: LrSchedule::warmup_only(5e-4, 0),
                },
            ],
        }
    }

    /// Total optimizer steps across all stages.
    pub fn total_steps(&self) -> usize {
        self.stages.iter().map(|s| s.steps).sum()
    }

    /// Model configs of every stage, in order (for plan validation).
    pub fn stage_configs(&self) -> Result<Vec<ModelConfig>> {
        self.stages.iter().map(|s| ModelConfig::preset(&s.preset)).collect()
    }

    /// Locate a global step: (stage index, step within that stage).
    /// `global_step == total_steps()` maps past the final stage end.
    pub fn stage_of(&self, global_step: usize) -> Result<(usize, usize)> {
        let mut rem = global_step;
        for (i, s) in self.stages.iter().enumerate() {
            if rem < s.steps {
                return Ok((i, rem));
            }
            rem -= s.steps;
        }
        Err(Error::Config(format!(
            "global step {global_step} is past the schedule's {} total steps",
            self.total_steps()
        )))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn warmup_only_matches_legacy_lr_at() {
        let s = LrSchedule::warmup_only(1.0, 10);
        for step in 0..25 {
            assert_eq!(s.at(step), super::super::lr_at(step, 1.0, 10), "step {step}");
        }
        // warmup = 0 is flat from step 0
        assert_eq!(LrSchedule::warmup_only(0.5, 0).at(0), 0.5);
    }

    #[test]
    fn full_shape_warmup_constant_decay() {
        let s = LrSchedule {
            base_lr: 1.0,
            warmup_steps: 4,
            decay_after: Some(10),
            decay_factor: 0.5,
        };
        assert!((s.at(0) - 0.25).abs() < 1e-6);
        assert!((s.at(3) - 1.0).abs() < 1e-6);
        assert_eq!(s.at(4), 1.0);
        assert_eq!(s.at(9), 1.0);
        assert_eq!(s.at(10), 0.5);
        assert_eq!(s.at(100), 0.5);
    }

    #[test]
    fn alphafold_recipe_shape() {
        let sched = TrainSchedule::alphafold(128);
        assert_eq!(sched.stages.len(), 2);
        assert_eq!(sched.stages[0].preset, "initial_training");
        assert_eq!(sched.stages[1].preset, "finetune");
        assert_eq!(sched.stages[0].steps, 78_125);
        assert_eq!(sched.stages[1].steps, 11_718);
        assert!(sched.stages[1].lr.base_lr < sched.stages[0].lr.base_lr);
        // decay applies only in the initial stage's tail
        let lr = &sched.stages[0].lr;
        assert_eq!(lr.at(50_000), 1e-3);
        assert!(lr.at(78_000) < 1e-3);
        sched.stage_configs().unwrap();
    }

    #[test]
    fn stage_of_walks_boundaries() {
        let sched = TrainSchedule {
            stages: vec![
                Stage {
                    name: "a".into(),
                    preset: "tiny".into(),
                    steps: 3,
                    lr: LrSchedule::warmup_only(1.0, 0),
                },
                Stage {
                    name: "b".into(),
                    preset: "tiny".into(),
                    steps: 2,
                    lr: LrSchedule::warmup_only(0.5, 0),
                },
            ],
        };
        assert_eq!(sched.total_steps(), 5);
        assert_eq!(sched.stage_of(0).unwrap(), (0, 0));
        assert_eq!(sched.stage_of(2).unwrap(), (0, 2));
        assert_eq!(sched.stage_of(3).unwrap(), (1, 0));
        assert_eq!(sched.stage_of(4).unwrap(), (1, 1));
        assert!(sched.stage_of(5).is_err());
    }
}
