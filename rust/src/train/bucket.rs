//! Bucketed, overlapped DP gradient all-reduce (paper §V.C / Fig 11).
//!
//! The monolithic trainer reduces the *entire* flattened gradient once,
//! after the whole backward finishes — every byte of DP wire sits on the
//! critical path. FastFold (and every production DDP stack) instead
//! packs leaves into fixed-size **buckets in backward-completion order**
//! and launches each bucket's ring all-reduce the moment its last
//! gradient lands, so cross-replica communication overlaps the rest of
//! the reverse pass. This module is that machinery:
//!
//! * [`BucketPlan`] — greedy fixed-capacity packing of the leaves along
//!   the backend's [`TrainBackend::backward_leaf_order`], plus
//!   [`BucketPlan::as_schedule`]: the plan lowered to `ScheduleOp`s
//!   (per-bucket backward segment → async gather → wait → Adam) so the
//!   PR 7 effect-IR verifier proves the overlapped schedule hazard-free
//!   *statically* before a step runs ([`BucketPlan::admit`]). Dropping a
//!   `Wait` is a stale-read/unjoined refutation, not a silent corruption.
//! * [`BucketSink`] — the [`GradSink`] the streamed backward feeds:
//!   micro-grads fold per (replica, leaf) in micro order (bit-for-bit
//!   the monolithic accumulation), and a bucket whose `dp × leaves`
//!   replica sums are all in is posted to the reducer channel.
//! * [`bucketed_step`] — drives one optimizer step's gradient phase: a
//!   scoped reducer thread rings each ready bucket (f32 or bf16 wire,
//!   one shared [`RingScratch`] across all buckets) while the backward
//!   keeps producing, with a `MeasuredComm`-style wall-clock ledger of
//!   comm busy seconds vs the part that actually blocked the step.
//!
//! Equivalence: the per-(replica, leaf) fold order and the ring
//! reduction math are unchanged; on the exact (dyadic) synthetic
//! gradient grid the bucketed step is bit-for-bit the monolithic one at
//! any bucket size — the equivalence matrix in `tests/train_overlap.rs`
//! pins this across (dap, dp, accum, bucket-size) products.

use super::backend::{GradSink, TrainBackend};
use super::data::Batch;
use crate::analysis::{verify, Program, VerifyReport};
use crate::comm::ring::{
    ring_all_reduce_bf16_with_scratch, ring_all_reduce_with_scratch, RingScratch,
};
use crate::config::Precision;
use crate::error::{Error, Result};
use crate::manifest::ScheduleOp;
use crate::tensor::HostTensor;
use std::sync::mpsc::{sync_channel, Receiver, SyncSender};
use std::sync::Mutex;
use std::time::Instant; // lint:allow(wallclock) — measured comm/exposed overlap ledger

/// One gradient bucket: the leaves it carries (in backward-completion
/// order) and their total element count.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Bucket {
    /// Canonical leaf indices, in the order the backward finishes them.
    pub leaves: Vec<usize>,
    /// Total f32 elements across the bucket's leaves.
    pub elems: usize,
}

/// Greedy fixed-capacity packing of the model's leaves into reduction
/// buckets along the backward-completion order.
#[derive(Clone, Debug)]
pub struct BucketPlan {
    buckets: Vec<Bucket>,
    leaf_to_bucket: Vec<usize>,
}

impl BucketPlan {
    /// Pack `leaf_sizes` (elements per canonical leaf) into buckets of at
    /// most `bucket_bytes` (4 bytes per element — the f32 wire basis, so
    /// the schedule is identical across `--precision` and bucketed-vs-
    /// monolithic comparisons hold the partition fixed), walking `order`
    /// (a permutation of the leaf indices, backward-completion order).
    /// A single leaf larger than the capacity gets a bucket of its own.
    pub fn new(leaf_sizes: &[usize], order: &[usize], bucket_bytes: usize) -> Result<Self> {
        let n = leaf_sizes.len();
        if order.len() != n {
            return Err(Error::Config(format!(
                "bucket order lists {} leaves, model has {n}",
                order.len()
            )));
        }
        let mut seen = vec![false; n];
        for &leaf in order {
            if leaf >= n || seen[leaf] {
                return Err(Error::Config(format!(
                    "bucket order is not a permutation of 0..{n} (leaf {leaf})"
                )));
            }
            seen[leaf] = true;
        }
        let cap_elems = (bucket_bytes / 4).max(1);
        let mut buckets: Vec<Bucket> = Vec::new();
        let mut cur = Bucket { leaves: Vec::new(), elems: 0 };
        for &leaf in order {
            let sz = leaf_sizes[leaf];
            if !cur.leaves.is_empty() && cur.elems + sz > cap_elems {
                buckets.push(std::mem::replace(
                    &mut cur,
                    Bucket { leaves: Vec::new(), elems: 0 },
                ));
            }
            cur.leaves.push(leaf);
            cur.elems += sz;
        }
        if !cur.leaves.is_empty() {
            buckets.push(cur);
        }
        let mut leaf_to_bucket = vec![0usize; n];
        for (b, bucket) in buckets.iter().enumerate() {
            for &leaf in &bucket.leaves {
                leaf_to_bucket[leaf] = b;
            }
        }
        Ok(BucketPlan { buckets, leaf_to_bucket })
    }

    /// One bucket holding every leaf — the monolithic reduction expressed
    /// in bucket form (used when `--bucket-mb` is not set but the
    /// overlapped path runs anyway).
    pub fn single(leaf_sizes: &[usize], order: &[usize]) -> Result<Self> {
        Self::new(leaf_sizes, order, usize::MAX)
    }

    /// The packed buckets, in launch order.
    pub fn buckets(&self) -> &[Bucket] {
        &self.buckets
    }

    /// Number of buckets.
    pub fn n_buckets(&self) -> usize {
        self.buckets.len()
    }

    /// Which bucket carries `leaf`.
    pub fn bucket_of(&self, leaf: usize) -> usize {
        self.leaf_to_bucket[leaf]
    }

    /// Lower the overlapped step to the effect-IR schedule the PR 7
    /// verifier checks: per bucket, a backward segment producing the
    /// bucket's gradient, then an *async* collective on it; all buckets
    /// joined before the Adam segment reads the reduced values. The
    /// hazards this construction is exposed to (reading a bucket the
    /// reduction has not joined, finishing with in-flight collectives)
    /// are exactly the verifier's stale-read/unjoined classes.
    pub fn as_schedule(&self) -> Vec<ScheduleOp> {
        let nb = self.buckets.len();
        let mut ops = Vec::with_capacity(3 * nb + 1);
        for b in 0..nb {
            ops.push(ScheduleOp::Exec {
                seg: format!("bwd{b}"),
                inputs: vec!["acts".to_string()],
                outputs: vec![format!("grad{b}")],
            });
            ops.push(ScheduleOp::Gather {
                input: format!("grad{b}"),
                output: format!("red{b}"),
                axis: 0,
                id: Some(format!("ar{b}")),
            });
        }
        for b in 0..nb {
            ops.push(ScheduleOp::Wait { id: format!("ar{b}") });
        }
        ops.push(ScheduleOp::Exec {
            seg: "adam".to_string(),
            inputs: (0..nb).map(|b| format!("red{b}")).collect(),
            outputs: vec!["params".to_string()],
        });
        ops
    }

    /// Statically verify the overlapped schedule and gate on hazards
    /// (the trainer's admission path, mirroring
    /// [`crate::train::ParallelPlan::admit_schedule`]). Verified at
    /// `max(dp, 2)` ranks — the schedule is SPMD, and degree 1 would
    /// let a broken schedule through unexercised.
    pub fn admit(&self, origin: &str, dp: usize) -> Result<u128> {
        let report = self.verify_at(origin, dp);
        report.gate()?;
        Ok(report.elapsed_micros)
    }

    /// The raw verifier report for the overlapped schedule (admission
    /// uses [`BucketPlan::admit`]; this is the introspection seam).
    pub fn verify_at(&self, origin: &str, dp: usize) -> VerifyReport {
        let ops = self.as_schedule();
        let program = Program::from_schedule(
            &format!("{origin}:dp-bucket-allreduce"),
            &ops,
            dp.max(2),
            &[("acts", None)],
        );
        verify(&program)
    }
}

/// Everything one bucketed gradient phase produced.
#[derive(Clone, Debug)]
pub struct BucketOutcome {
    /// Per micro-batch losses in global (replica-major) batch order.
    pub losses: Vec<f32>,
    /// Reduced gradient leaves in canonical order — the *sum* over the
    /// effective batch (the caller applies the mean, clip, Adam).
    pub grads: Vec<HostTensor>,
    /// Critical-path (max over ranks) ring wire bytes, summed over
    /// buckets.
    pub wire_bytes: usize,
    /// Wall seconds the reducer spent inside ring reductions (busy time,
    /// overlapped or not).
    pub comm_seconds: f64,
    /// Wall seconds the compute path actually blocked waiting for the
    /// last reductions after the backward finished — the *exposed* part
    /// of `comm_seconds`.
    pub exposed_seconds: f64,
}

struct SinkState {
    /// per (replica·n_leaves + leaf): micro-grads awaiting the fold
    micro: Vec<Vec<Option<HostTensor>>>,
    /// arrivals per (replica, leaf)
    filled: Vec<usize>,
    /// folded replica sums, taken by the reducer
    summed: Vec<Option<HostTensor>>,
    /// per bucket: (replica, leaf) sums still outstanding
    remaining: Vec<usize>,
    /// per micro-batch losses
    losses: Vec<Option<f32>>,
    /// ready-bucket channel; dropped on close/error to stop the reducer
    tx: Option<SyncSender<usize>>,
    /// first failure observed inside an emit callback
    error: Option<String>,
}

/// The [`GradSink`] the bucketed step hands to the streamed backward:
/// folds micro-grads per (replica, leaf) in micro order and posts each
/// bucket to the reducer the moment its last replica sum completes.
pub struct BucketSink<'a> {
    plan: &'a BucketPlan,
    accum: usize,
    n_leaves: usize,
    state: Mutex<SinkState>,
}

impl<'a> BucketSink<'a> {
    fn new(plan: &'a BucketPlan, dp: usize, accum: usize, n_leaves: usize) -> (Self, Receiver<usize>) {
        let nb = plan.n_buckets();
        // capacity = bucket count: at most one post per bucket, so the
        // collector never blocks on a busy reducer while holding its lock
        let (tx, rx) = sync_channel::<usize>(nb.max(1));
        let remaining: Vec<usize> =
            plan.buckets().iter().map(|b| dp * b.leaves.len()).collect();
        let sink = BucketSink {
            plan,
            accum,
            n_leaves,
            state: Mutex::new(SinkState {
                micro: vec![Vec::new(); dp * n_leaves],
                filled: vec![0; dp * n_leaves],
                summed: (0..dp * n_leaves).map(|_| None).collect(),
                remaining,
                losses: vec![None; dp * accum],
                tx: Some(tx),
                error: None,
            }),
        };
        (sink, rx)
    }

    /// Drop the ready-bucket sender so the reducer drains and exits.
    fn close(&self) {
        self.state.lock().unwrap().tx = None;
    }

    fn fail(st: &mut SinkState, msg: String) {
        if st.error.is_none() {
            st.error = Some(msg);
        }
        st.tx = None;
    }
}

impl GradSink for BucketSink<'_> {
    fn emit_loss(&self, batch_idx: usize, loss: f32) {
        let mut st = self.state.lock().unwrap();
        if batch_idx >= st.losses.len() {
            let n = st.losses.len();
            Self::fail(&mut st, format!("loss for batch {batch_idx}, step has {n}"));
            return;
        }
        st.losses[batch_idx] = Some(loss);
    }

    fn emit_grad(&self, batch_idx: usize, leaf: usize, grad: HostTensor) {
        let mut st = self.state.lock().unwrap();
        if st.error.is_some() {
            return;
        }
        if leaf >= self.n_leaves || batch_idx >= st.losses.len() {
            Self::fail(
                &mut st,
                format!("grad for batch {batch_idx} leaf {leaf} out of range"),
            );
            return;
        }
        let (r, a) = (batch_idx / self.accum, batch_idx % self.accum);
        let slot = r * self.n_leaves + leaf;
        if st.micro[slot].is_empty() {
            st.micro[slot] = (0..self.accum).map(|_| None).collect();
        }
        if st.micro[slot][a].is_some() {
            Self::fail(
                &mut st,
                format!("duplicate grad for batch {batch_idx} leaf {leaf}"),
            );
            return;
        }
        st.micro[slot][a] = Some(grad);
        st.filled[slot] += 1;
        if st.filled[slot] < self.accum {
            return;
        }
        // all micro-grads in: fold in micro order — element-for-element
        // the monolithic replica accumulation
        let micro = std::mem::take(&mut st.micro[slot]);
        let mut it = micro.into_iter();
        let mut acc = match it.next().flatten() {
            Some(g) => g,
            None => {
                Self::fail(&mut st, format!("leaf {leaf} lost its first micro-grad"));
                return;
            }
        };
        for g in it {
            let g = match g {
                Some(g) => g,
                None => {
                    Self::fail(&mut st, format!("leaf {leaf} lost a micro-grad"));
                    return;
                }
            };
            if let Err(e) = acc.add_assign(&g) {
                Self::fail(&mut st, format!("leaf {leaf} micro fold: {e}"));
                return;
            }
        }
        st.summed[slot] = Some(acc);
        let b = self.plan.bucket_of(leaf);
        st.remaining[b] -= 1;
        if st.remaining[b] == 0 {
            if let Some(tx) = &st.tx {
                // capacity ≥ n_buckets: this send never blocks
                let _ = tx.send(b);
            }
        }
    }
}

struct ReducerOut {
    grads: Vec<Option<HostTensor>>,
    wire_bytes: usize,
    comm_seconds: f64,
}

#[allow(clippy::too_many_arguments)] // one step's full gradient phase
fn reduce_buckets(
    rx: Receiver<usize>,
    sink: &BucketSink<'_>,
    plan: &BucketPlan,
    leaf_shapes: &[Vec<usize>],
    dp: usize,
    precision: Precision,
    wire_scale: f32,
    scratch: &mut RingScratch,
) -> Result<ReducerOut> {
    let n_leaves = leaf_shapes.len();
    let mut grads: Vec<Option<HostTensor>> = (0..n_leaves).map(|_| None).collect();
    let mut wire_bytes = 0usize;
    let mut comm_seconds = 0.0f64;
    for b in rx {
        let bucket = &plan.buckets()[b];
        // pull the bucket's replica sums out of the collector
        let mut per_rank: Vec<Vec<f32>> = Vec::with_capacity(dp);
        {
            let mut st = sink.state.lock().unwrap();
            for r in 0..dp {
                let mut flat = Vec::with_capacity(bucket.elems);
                for &leaf in &bucket.leaves {
                    let g = st.summed[r * n_leaves + leaf].take().ok_or_else(|| {
                        Error::msg(format!(
                            "bucket {b}: replica {r} leaf {leaf} sum missing"
                        ))
                    })?;
                    if g.shape != leaf_shapes[leaf] {
                        return Err(Error::Shape(format!(
                            "bucket {b} leaf {leaf}: grad {:?} vs param {:?}",
                            g.shape, leaf_shapes[leaf]
                        )));
                    }
                    flat.extend_from_slice(g.data());
                }
                if wire_scale != 1.0 {
                    // dynamic loss scale: an exact power-of-two boost
                    // applied before the precision-lossy wire; the
                    // caller divides it back out after the reduction
                    crate::device::current().scale(&mut flat, wire_scale);
                }
                per_rank.push(flat);
            }
        }
        let t = Instant::now();
        let (mut reduced, wire) = match precision {
            Precision::F32 => ring_all_reduce_with_scratch(per_rank, scratch)?,
            Precision::Bf16 => ring_all_reduce_bf16_with_scratch(per_rank, scratch)?,
        };
        comm_seconds += t.elapsed().as_secs_f64();
        wire_bytes += wire.iter().copied().max().unwrap_or(0);
        // every rank holds the identical reduced bucket; unpack rank 0
        let flat = reduced.swap_remove(0);
        let mut off = 0usize;
        for &leaf in &bucket.leaves {
            let n: usize = leaf_shapes[leaf].iter().product();
            grads[leaf] =
                Some(HostTensor::new(leaf_shapes[leaf].clone(), flat[off..off + n].to_vec())?);
            off += n;
        }
    }
    Ok(ReducerOut { grads, wire_bytes, comm_seconds })
}

/// One optimizer step's gradient phase, bucketed and overlapped: stream
/// the backward into a [`BucketSink`] while a scoped reducer thread
/// rings each bucket as it completes. Returns the per-batch losses, the
/// effective-batch gradient *sums* (caller applies the inverse
/// `wire_scale`, the mean, clip, Adam), the critical-path wire bytes,
/// and the measured comm/exposed seconds. `wire_scale` (a power of two;
/// 1.0 = off) is multiplied into each rank's bucket before the
/// precision-lossy wire — the bf16 dynamic-loss-scale hook. `batches`
/// is the replica-major effective batch (`dp × accum` entries); `dp = 1`
/// degenerates gracefully (the ring is a no-op in f32, a
/// round-to-storage in bf16 — matching the multi-rank grid semantics).
#[allow(clippy::too_many_arguments)] // the step's full gradient-phase contract
pub fn bucketed_step(
    backend: &dyn TrainBackend,
    params: &[HostTensor],
    batches: &[Batch],
    dp: usize,
    accum: usize,
    threads: usize,
    plan: &BucketPlan,
    precision: Precision,
    wire_scale: f32,
    scratch: &mut RingScratch,
) -> Result<BucketOutcome> {
    let n_leaves = params.len();
    let e = dp * accum;
    if batches.len() != e {
        return Err(Error::msg(format!(
            "bucketed step wants {e} micro-batches (dp {dp} × accum {accum}), got {}",
            batches.len()
        )));
    }
    let leaf_shapes: Vec<Vec<usize>> = params.iter().map(|p| p.shape.clone()).collect();
    let (sink, rx) = BucketSink::new(plan, dp, accum, n_leaves);
    let sink_ref = &sink;
    let shapes_ref = &leaf_shapes;
    let scratch_ref = &mut *scratch;

    let mut reducer_out: Option<Result<ReducerOut>> = None;
    let mut exposed_seconds = 0.0f64;
    let stream_res = std::thread::scope(|s| {
        let handle = s.spawn(move || {
            reduce_buckets(
                rx, sink_ref, plan, shapes_ref, dp, precision, wire_scale, scratch_ref,
            )
        });
        let res = backend.grad_many_streamed(params, batches, threads, sink_ref);
        // backward done (or failed): close the channel so the reducer
        // drains and exits, then measure how long the join blocks — the
        // exposed (non-overlapped) share of the comm time
        sink.close();
        let t = Instant::now();
        reducer_out = Some(handle.join().expect("bucket reducer thread panicked"));
        exposed_seconds = t.elapsed().as_secs_f64();
        res
    });
    stream_res?;
    if let Some(msg) = sink.state.lock().unwrap().error.take() {
        return Err(Error::msg(format!("bucketed gradient fold: {msg}")));
    }
    let red = reducer_out.expect("reducer joined above")?;

    let mut grads = Vec::with_capacity(n_leaves);
    for (leaf, g) in red.grads.into_iter().enumerate() {
        grads.push(g.ok_or_else(|| {
            Error::msg(format!("leaf {leaf} never completed its bucket reduction"))
        })?);
    }
    let mut losses = Vec::with_capacity(e);
    for (i, l) in sink.state.lock().unwrap().losses.iter().enumerate() {
        losses.push(l.ok_or_else(|| Error::msg(format!("batch {i} reported no loss")))?);
    }
    Ok(BucketOutcome {
        losses,
        grads,
        wire_bytes: red.wire_bytes,
        comm_seconds: red.comm_seconds,
        exposed_seconds,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::Hazard;
    use crate::comm::ring::ring_all_reduce;
    use crate::config::ModelConfig;
    use crate::train::{DataGen, SyntheticBackend};

    #[test]
    fn plan_packs_greedily_in_backward_order() {
        // capacity 8 elems = 32 bytes; order 2,1,0 → [2,1] then [0]
        let plan = BucketPlan::new(&[4, 4, 4], &[2, 1, 0], 32).unwrap();
        assert_eq!(plan.n_buckets(), 2);
        assert_eq!(plan.buckets()[0], Bucket { leaves: vec![2, 1], elems: 8 });
        assert_eq!(plan.buckets()[1], Bucket { leaves: vec![0], elems: 4 });
        assert_eq!(plan.bucket_of(2), 0);
        assert_eq!(plan.bucket_of(1), 0);
        assert_eq!(plan.bucket_of(0), 1);
    }

    #[test]
    fn oversized_leaf_gets_its_own_bucket() {
        let plan = BucketPlan::new(&[100, 1, 1], &[0, 1, 2], 16).unwrap();
        assert_eq!(plan.n_buckets(), 2);
        assert_eq!(plan.buckets()[0].leaves, vec![0]);
        assert_eq!(plan.buckets()[1].leaves, vec![1, 2]);
    }

    #[test]
    fn single_puts_everything_in_one_bucket() {
        let plan = BucketPlan::single(&[5, 7, 3], &[2, 1, 0]).unwrap();
        assert_eq!(plan.n_buckets(), 1);
        assert_eq!(plan.buckets()[0].leaves, vec![2, 1, 0]);
        assert_eq!(plan.buckets()[0].elems, 15);
    }

    #[test]
    fn non_permutation_orders_rejected() {
        assert!(BucketPlan::new(&[4, 4], &[0], 64).is_err());
        assert!(BucketPlan::new(&[4, 4], &[0, 0], 64).is_err());
        assert!(BucketPlan::new(&[4, 4], &[0, 2], 64).is_err());
    }

    #[test]
    fn overlapped_schedule_admits_at_all_dp() {
        let plan = BucketPlan::new(&[16, 8, 8, 4], &[3, 2, 1, 0], 48).unwrap();
        for dp in [1usize, 2, 4, 8] {
            plan.admit("test", dp).unwrap_or_else(|e| {
                panic!("bucketed schedule must admit at dp={dp}: {e}")
            });
        }
    }

    #[test]
    fn dropping_a_wait_is_refuted_statically() {
        let plan = BucketPlan::new(&[16, 8], &[1, 0], 16).unwrap();
        assert!(plan.n_buckets() >= 2);
        let mut ops = plan.as_schedule();
        let wait_at = ops
            .iter()
            .position(|op| matches!(op, ScheduleOp::Wait { .. }))
            .expect("schedule has waits");
        ops.remove(wait_at);
        let program =
            Program::from_schedule("test:missing-wait", &ops, 2, &[("acts", None)]);
        let report = verify(&program);
        assert!(report.gate().is_err(), "missing Wait must refuse admission");
        let hazards: Vec<Hazard> =
            report.diagnostics.iter().map(|d| d.hazard).collect();
        assert!(
            hazards.iter().any(|h| matches!(
                h,
                Hazard::StaleRead | Hazard::UnsetSlot | Hazard::UnjoinedAtEnd
            )),
            "expected a stale-read/unset/unjoined refutation, got {hazards:?}"
        );
    }

    /// The monolithic gradient phase, hand-rolled exactly as the trainer
    /// used to run it: per-replica micro folds, one full-vector ring.
    fn monolithic(
        backend: &SyntheticBackend,
        params: &[HostTensor],
        batches: &[Batch],
        dp: usize,
        accum: usize,
    ) -> (Vec<f32>, Vec<HostTensor>) {
        let results = backend.grad_many(params, batches, 1).unwrap();
        let losses: Vec<f32> = results.iter().map(|(l, _)| *l).collect();
        let mut it = results.into_iter();
        let mut per_replica: Vec<Vec<HostTensor>> = Vec::with_capacity(dp);
        for _ in 0..dp {
            let (_, mut acc) = it.next().unwrap();
            for _ in 1..accum {
                let (_, g) = it.next().unwrap();
                for (a, b) in acc.iter_mut().zip(g.iter()) {
                    a.add_assign(b).unwrap();
                }
            }
            per_replica.push(acc);
        }
        if dp == 1 {
            return (losses, per_replica.pop().unwrap());
        }
        let per_rank: Vec<Vec<f32>> = per_replica
            .iter()
            .map(|gs| gs.iter().flat_map(|g| g.data().iter().copied()).collect())
            .collect();
        let (reduced, _) = ring_all_reduce(per_rank).unwrap();
        let flat = reduced.into_iter().next().unwrap();
        let mut out = Vec::with_capacity(params.len());
        let mut off = 0usize;
        for p in params {
            let n = p.data().len();
            out.push(HostTensor::new(p.shape.clone(), flat[off..off + n].to_vec()).unwrap());
            off += n;
        }
        (losses, out)
    }

    #[test]
    fn bucketed_step_matches_monolithic_bitwise() {
        let cfg = ModelConfig::tiny();
        let params = SyntheticBackend::init_params(&cfg);
        let leaf_sizes: Vec<usize> = params.iter().map(|p| p.data().len()).collect();
        let backend = SyntheticBackend::new(1);
        let order = backend.backward_leaf_order(params.len());
        for (dp, accum) in [(1usize, 2usize), (2, 1), (2, 2), (4, 2)] {
            let mut gen = DataGen::new(cfg.clone(), 17);
            let batches: Vec<Batch> =
                (0..dp * accum).map(|_| gen.next_batch()).collect();
            let (ref_losses, ref_grads) =
                monolithic(&backend, &params, &batches, dp, accum);
            for bucket_bytes in [64usize, 1 << 20] {
                let plan =
                    BucketPlan::new(&leaf_sizes, &order, bucket_bytes).unwrap();
                let mut scratch = RingScratch::new();
                let out = bucketed_step(
                    &backend,
                    &params,
                    &batches,
                    dp,
                    accum,
                    2,
                    &plan,
                    Precision::F32,
                    1.0,
                    &mut scratch,
                )
                .unwrap();
                assert_eq!(out.losses.len(), ref_losses.len());
                for (a, b) in out.losses.iter().zip(ref_losses.iter()) {
                    assert_eq!(a.to_bits(), b.to_bits());
                }
                assert_eq!(
                    out.grads, ref_grads,
                    "dp={dp} accum={accum} bytes={bucket_bytes}"
                );
                if dp > 1 {
                    assert!(out.wire_bytes > 0);
                }
            }
        }
    }

    #[test]
    fn bf16_bucketed_step_is_deterministic_and_close_to_f32() {
        let cfg = ModelConfig::tiny();
        let params = SyntheticBackend::init_params(&cfg);
        let leaf_sizes: Vec<usize> = params.iter().map(|p| p.data().len()).collect();
        let backend = SyntheticBackend::new(1);
        let order = backend.backward_leaf_order(params.len());
        let plan = BucketPlan::new(&leaf_sizes, &order, 256).unwrap();
        let (dp, accum) = (2usize, 2usize);
        let mut gen = DataGen::new(cfg.clone(), 23);
        let batches: Vec<Batch> = (0..dp * accum).map(|_| gen.next_batch()).collect();
        let run = |precision: Precision| {
            let mut scratch = RingScratch::new();
            bucketed_step(
                &backend, &params, &batches, dp, accum, 1, &plan, precision, 1.0,
                &mut scratch,
            )
            .unwrap()
        };
        let a = run(Precision::Bf16);
        let b = run(Precision::Bf16);
        for (x, y) in a.grads.iter().zip(b.grads.iter()) {
            assert_eq!(x, y, "bf16 bucketed step must be run-to-run deterministic");
        }
        // a power-of-two wire scale is mantissa-preserving: dividing it
        // back out reproduces the unscaled bf16 reduction bit-for-bit
        let mut scratch = RingScratch::new();
        let scaled = bucketed_step(
            &backend,
            &params,
            &batches,
            dp,
            accum,
            1,
            &plan,
            Precision::Bf16,
            1024.0,
            &mut scratch,
        )
        .unwrap();
        for (x, y) in scaled.grads.iter().zip(a.grads.iter()) {
            let mut x = x.clone();
            x.scale(1.0 / 1024.0);
            assert_eq!(&x, y, "2^k wire scale must be exactly invertible");
        }

        let f = run(Precision::F32);
        // bf16 wire is half the f32 wire for the same schedule
        assert_eq!(a.wire_bytes * 2, f.wire_bytes);
        for (x, y) in a.grads.iter().zip(f.grads.iter()) {
            for (xa, ya) in x.data().iter().zip(y.data().iter()) {
                let tol = 0.02 * ya.abs().max(1.0);
                assert!(
                    (xa - ya).abs() <= tol,
                    "bf16 grad {xa} too far from f32 {ya}"
                );
            }
        }
    }
}
