//! `ParallelPlan` — the hybrid DP×DAP training layout (paper §V.B).
//!
//! The paper's 67-hour headline composes data parallelism *across*
//! replicas with Dynamic Axial Parallelism *inside* each replica: a job on
//! `dp × dap` GPUs runs `dp` model replicas, each sharded over a `dap`-way
//! DAP group, with gradient accumulation giving an effective batch of
//! `dp × accum` samples per optimizer step. The plan is resolved from
//! CLI / TOML / env ([`crate::config::ParallelConfig`]) and validated
//! against the model geometry and the [`crate::perfmodel`] memory model
//! before any executable is loaded.

use crate::config::{ModelConfig, ParallelConfig};
use crate::error::{Error, Result};
use crate::perfmodel::{GpuSpec, MemoryModel};

/// Activation multiplier for a training step vs the inference working set:
/// forward activations + backward cotangents + segment-checkpoint
/// rematerialization headroom (the DAP tape rematerializes forward inside
/// each segment VJP, so the multiplier is small and flat rather than
/// `O(n_blocks)` — the §III.B bound this repo's backward avoids).
pub const TRAIN_ACT_MULT: f64 = 3.0;

/// How a training job is laid out across ranks.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ParallelPlan {
    /// data-parallel replicas (each holds a full model copy)
    pub dp: usize,
    /// DAP degree inside each replica (1 = dense single-device replica)
    pub dap: usize,
    /// gradient-accumulation micro-batches per replica per optimizer step
    pub accum: usize,
    /// host rank-executor thread budget (resolved; >= 1)
    pub threads: usize,
}

impl Default for ParallelPlan {
    fn default() -> Self {
        ParallelPlan { dp: 1, dap: 1, accum: 1, threads: 1 }
    }
}

impl std::fmt::Display for ParallelPlan {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "dp={} x dap={} ({} GPUs), accum={} (effective batch {}), threads={}",
            self.dp,
            self.dap,
            self.gpus(),
            self.accum,
            self.effective_batch(),
            self.threads
        )
    }
}

impl ParallelPlan {
    /// A plan with explicit degrees and a sequential thread budget.
    pub fn new(dp: usize, dap: usize, accum: usize) -> Self {
        ParallelPlan { dp, dap, accum, threads: 1 }
    }

    /// Resolve a plan from the run config's `[parallel]` section (which
    /// itself merges TOML, CLI flags, and the `FASTFOLD_THREADS` env).
    pub fn from_config(p: &ParallelConfig) -> Self {
        ParallelPlan {
            dp: p.dp_size,
            dap: p.dap_size,
            accum: p.accum,
            threads: p.resolve_threads(),
        }
    }

    /// Builder-style thread override (0 = auto).
    pub fn with_threads(mut self, threads: usize) -> Self {
        self.threads =
            if threads == 0 { crate::dap::default_threads() } else { threads };
        self
    }

    /// Total rank budget the plan occupies.
    pub fn gpus(&self) -> usize {
        self.dp * self.dap
    }

    /// Samples consumed per optimizer step.
    pub fn effective_batch(&self) -> usize {
        self.dp * self.accum
    }

    /// Structural validation against the model geometry: every degree
    /// >= 1, and `dap` must divide both axial dimensions (the DAP schedule
    /// shards `n_seq` and `n_res` along axis 0).
    pub fn validate(&self, cfg: &ModelConfig) -> Result<()> {
        if self.dp == 0 || self.dap == 0 || self.accum == 0 || self.threads == 0 {
            return Err(Error::Config(format!(
                "parallel plan degrees must be >= 1 (got dp={}, dap={}, \
                 accum={}, threads={})",
                self.dp, self.dap, self.accum, self.threads
            )));
        }
        if cfg.n_seq % self.dap != 0 || cfg.n_res % self.dap != 0 {
            return Err(Error::Config(format!(
                "dap={} does not divide (n_seq={}, n_res={}) of preset '{}'",
                self.dap, cfg.n_seq, cfg.n_res, cfg.name
            )));
        }
        Ok(())
    }

    /// Static schedule admission for this plan's DAP degree: prove the
    /// canonical per-block program (forward **and** backward — training
    /// runs both) hazard-free before any executable is loaded. Returns
    /// the verifier's own cost in microseconds; `Err` refuses the run
    /// with the leading diagnostics. `fastfold train` calls this right
    /// after [`Self::validate`] unless `--unsafe-skip-verify` is given.
    pub fn admit_schedule(&self, cfg: &ModelConfig) -> Result<u128> {
        crate::analysis::admit("train", cfg, self.dap)
    }

    /// Per-device training memory this plan needs (bytes): framework
    /// overhead + [`TRAIN_ACT_MULT`] × the DAP-sharded activation working
    /// set + the optimizer state (params, grads, Adam m/v — replicated on
    /// every rank; DAP shards activations, not parameters).
    pub fn train_bytes_per_device(&self, cfg: &ModelConfig, mem: &MemoryModel) -> f64 {
        let act = mem.inference_peak(cfg, self.dap, 1) - mem.fixed_overhead;
        let opt_state = 4.0 * 4.0 * cfg.param_count() as f64; // p+g+m+v, f32
        mem.fixed_overhead + TRAIN_ACT_MULT * act + opt_state
    }

    /// Memory-fit check for one training stage: Ok(per-device bytes) when
    /// the stage fits `gpu`, `Err(SimOom)` otherwise — the same verdict
    /// type the Table V inference boundary uses.
    pub fn check_memory(
        &self,
        cfg: &ModelConfig,
        mem: &MemoryModel,
        gpu: &GpuSpec,
    ) -> Result<f64> {
        let need = self.train_bytes_per_device(cfg, mem);
        if need > gpu.memory {
            return Err(Error::SimOom { need_gb: need / 1e9, cap_gb: gpu.memory / 1e9 });
        }
        Ok(need)
    }

    /// Full resolution: structure + rank budget + memory fit for every
    /// stage config. This is what `fastfold train` / `fastfold scale` run
    /// before touching artifacts.
    pub fn validate_for(
        &self,
        stages: &[ModelConfig],
        mem: &MemoryModel,
        gpu: &GpuSpec,
        max_gpus: usize,
    ) -> Result<()> {
        if self.gpus() > max_gpus {
            return Err(Error::Config(format!(
                "plan needs {} ranks (dp={} x dap={}), budget is {max_gpus}",
                self.gpus(),
                self.dp,
                self.dap
            )));
        }
        for cfg in stages {
            self.validate(cfg)?;
            self.check_memory(cfg, mem, gpu)?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn arithmetic() {
        let p = ParallelPlan::new(128, 4, 2);
        assert_eq!(p.gpus(), 512);
        assert_eq!(p.effective_batch(), 256);
        assert!(p.to_string().contains("512 GPUs"));
    }

    #[test]
    fn rejects_zero_and_nondividing_dap() {
        let cfg = ModelConfig::tiny(); // n_seq=8, n_res=16
        assert!(ParallelPlan::new(0, 1, 1).validate(&cfg).is_err());
        assert!(ParallelPlan::new(1, 1, 0).validate(&cfg).is_err());
        assert!(ParallelPlan::new(1, 3, 1).validate(&cfg).is_err());
        assert!(ParallelPlan::new(2, 4, 2).validate(&cfg).is_ok());
    }

    #[test]
    fn schedule_admission_accepts_shipping_plans() {
        let cfg = ModelConfig::tiny();
        for dap in [1usize, 2, 4, 8] {
            ParallelPlan::new(2, dap, 1).admit_schedule(&cfg).unwrap();
        }
    }

    #[test]
    fn memory_need_shrinks_with_dap() {
        let mem = MemoryModel::default();
        let cfg = ModelConfig::finetune();
        let n1 = ParallelPlan::new(1, 1, 1).train_bytes_per_device(&cfg, &mem);
        let n4 = ParallelPlan::new(1, 4, 1).train_bytes_per_device(&cfg, &mem);
        assert!(n4 < n1, "dap sharding must shrink the working set: {n4} vs {n1}");
    }

    #[test]
    fn oom_verdict_on_small_device() {
        let mem = MemoryModel::default();
        let cfg = ModelConfig::finetune();
        let mut small = GpuSpec::a100_40g();
        small.memory = 4.0e9;
        let err = ParallelPlan::new(1, 1, 1).check_memory(&cfg, &mem, &small);
        assert!(matches!(err, Err(Error::SimOom { .. })), "{err:?}");
        // the paper's fix: shard with DAP until the stage fits a real A100
        let a100 = GpuSpec::a100_40g();
        assert!(ParallelPlan::new(1, 4, 1).check_memory(&cfg, &mem, &a100).is_ok());
    }

    #[test]
    fn rank_budget_enforced() {
        let mem = MemoryModel::default();
        let gpu = GpuSpec::a100_40g();
        let stages = [ModelConfig::initial_training(), ModelConfig::finetune()];
        let plan = ParallelPlan::new(128, 4, 1);
        assert!(plan.validate_for(&stages, &mem, &gpu, 512).is_ok());
        assert!(plan.validate_for(&stages, &mem, &gpu, 256).is_err());
    }
}
