//! Data-parallel trainer.
//!
//! Per step, per DP rank: `grad_step` executable (loss + grads) on that
//! rank's batch → host ring all-reduce of the gradient leaves (the exact
//! algorithm the Fig 11 cost model prices) → gradient clip → `adam_update`
//! executable. Parameters and optimizer state live as host tensors between
//! steps (the coordinator owns state; PJRT owns math).
//!
//! The per-rank forward/backward fans out over `threads` host worker
//! threads ([`crate::dap::executor::parallel_ranks`]); batches are drawn
//! sequentially first and losses/gradients are folded back in rank order,
//! so the threaded step is bit-for-bit identical to `threads = 1`.

use super::data::{Batch, DataGen};
use super::lr_at;
use crate::comm::ring::ring_all_reduce;
use crate::config::TrainConfig;
use crate::dap::executor::{default_threads, parallel_ranks};
use crate::error::{Error, Result};
use crate::runtime::{Runtime, Value};
use crate::tensor::HostTensor;
use std::sync::Arc;
use std::time::Instant;

pub struct Trainer<'rt> {
    rt: &'rt Runtime,
    preset: String,
    pub dp: usize,
    /// rank-executor thread budget (1 = sequential; default:
    /// [`default_threads`])
    pub threads: usize,
    pub params: Vec<HostTensor>,
    pub m: Vec<HostTensor>,
    pub v: Vec<HostTensor>,
    pub step: usize,
    pub cfg: TrainConfig,
    grad_exe: Arc<crate::runtime::Executable>,
    adam_exe: Arc<crate::runtime::Executable>,
    gens: Vec<DataGen>,
    pub history: Vec<(usize, f32)>,
    pub wire_bytes: usize,
}

#[derive(Clone, Debug)]
pub struct TrainReport {
    pub steps: usize,
    pub final_loss: f32,
    pub initial_loss: f32,
    pub seconds: f64,
    pub steps_per_sec: f64,
    pub wire_bytes: usize,
    /// rank-executor threads the run used (1 = sequential)
    pub threads: usize,
}

impl<'rt> Trainer<'rt> {
    pub fn new(rt: &'rt Runtime, preset: &str, dp: usize, cfg: TrainConfig) -> Result<Self> {
        if dp == 0 {
            return Err(Error::Config("dp must be >= 1".into()));
        }
        let params = rt.manifest.load_params(preset)?;
        let zeros: Vec<HostTensor> =
            params.iter().map(|p| HostTensor::zeros(&p.shape)).collect();
        let grad_exe = rt.load(&format!("{preset}/grad_step"))?;
        let adam_exe = rt.load(&format!("{preset}/adam_update"))?;
        let model_cfg = crate::config::ModelConfig::preset(preset)?;
        let gens = (0..dp)
            .map(|r| DataGen::new(model_cfg.clone(), cfg.seed.wrapping_add(1000 * r as u64)))
            .collect();
        Ok(Trainer {
            rt,
            preset: preset.to_string(),
            dp,
            threads: default_threads(),
            m: zeros.clone(),
            v: zeros,
            params,
            step: 0,
            cfg,
            grad_exe,
            adam_exe,
            gens,
            history: Vec::new(),
            wire_bytes: 0,
        })
    }

    /// Builder-style override of the rank-executor thread budget
    /// (`--threads` on the CLI): 1 restores the sequential path, 0 means
    /// auto ([`default_threads`]), consistent with the CLI/TOML/env knobs.
    pub fn with_threads(mut self, threads: usize) -> Self {
        self.threads = if threads == 0 { default_threads() } else { threads };
        self
    }

    fn batch_values(b: &Batch) -> Vec<Value> {
        // canonical batch flatten order: dict keys sorted by jax =
        // dist_bins, msa_labels, msa_mask, msa_tokens
        vec![
            b.dist_bins.clone().into(),
            b.msa_labels.clone().into(),
            b.msa_mask.clone().into(),
            b.msa_tokens.clone().into(),
        ]
    }

    /// One optimizer step over `dp` rank-local batches. Returns mean loss.
    pub fn train_step(&mut self) -> Result<f32> {
        let n_leaves = self.params.len();

        // draw every rank's batch sequentially (the data stream is the
        // same whatever the thread budget), then fan the per-rank
        // forward/backward out over worker threads
        let batches: Vec<Batch> =
            (0..self.dp).map(|r| self.gens[r].next_batch()).collect();
        let params = &self.params;
        let grad_exe = &self.grad_exe;
        let per_rank: Vec<(f32, Vec<HostTensor>)> =
            parallel_ranks(self.threads, self.dp, |r| {
                let mut args: Vec<Value> =
                    params.iter().cloned().map(Value::F32).collect();
                args.extend(Self::batch_values(&batches[r]));
                let out = grad_exe.run(&args)?;
                // outputs: loss scalar, then grads in canonical order
                Ok((out[0].data[0], out[1..].to_vec()))
            })?;
        // fold losses in rank order (bit-for-bit vs the sequential loop)
        let mut loss_acc = 0.0f32;
        for (loss, _) in &per_rank {
            loss_acc += *loss;
        }
        let leaf_shapes: Vec<Vec<usize>> =
            per_rank[0].1.iter().map(|g| g.shape.clone()).collect();

        // ring all-reduce + average
        let grads: Vec<HostTensor> = if self.dp == 1 {
            per_rank.into_iter().next().map(|(_, g)| g).ok_or_else(|| Error::msg("no grads"))?
        } else {
            // flatten for the ring
            let per_rank_grads: Vec<Vec<f32>> = per_rank
                .iter()
                .map(|(_, grads)| {
                    grads.iter().flat_map(|g| g.data.iter().copied()).collect()
                })
                .collect();
            let (reduced, wire) = ring_all_reduce(per_rank_grads)?;
            // account the critical-path rank (exact per-rank volumes can
            // differ at non-divisible lengths; see comm::ring)
            self.wire_bytes += wire.iter().copied().max().unwrap_or(0);
            let mut flat = reduced.into_iter().next().unwrap();
            let inv = 1.0 / self.dp as f32;
            for x in flat.iter_mut() {
                *x *= inv;
            }
            let mut out = Vec::with_capacity(n_leaves);
            let mut off = 0usize;
            for shape in &leaf_shapes {
                let n: usize = shape.iter().product();
                out.push(HostTensor::new(shape.clone(), flat[off..off + n].to_vec())?);
                off += n;
            }
            out
        };

        // global-norm gradient clip (host-side; tiny vs step cost)
        let grads = match self.cfg.grad_clip {
            Some(clip) => clip_by_global_norm(grads, clip),
            None => grads,
        };

        // adam update via HLO
        self.step += 1;
        let lr = lr_at(self.step - 1, self.cfg.lr, self.cfg.warmup_steps);
        let mut args: Vec<Value> = Vec::with_capacity(4 * n_leaves + 2);
        args.extend(self.params.iter().cloned().map(Value::F32));
        args.extend(grads.into_iter().map(Value::F32));
        args.extend(self.m.iter().cloned().map(Value::F32));
        args.extend(self.v.iter().cloned().map(Value::F32));
        args.push(Value::F32(HostTensor::scalar(self.step as f32)));
        args.push(Value::F32(HostTensor::scalar(lr)));
        let out = self.adam_exe.run(&args)?;
        let (p2, rest) = out.split_at(n_leaves);
        let (m2, v2) = rest.split_at(n_leaves);
        self.params = p2.to_vec();
        self.m = m2.to_vec();
        self.v = v2.to_vec();

        let loss = loss_acc / self.dp as f32;
        self.history.push((self.step, loss));
        Ok(loss)
    }

    /// Run the configured number of steps; log + checkpoint per config.
    pub fn run(&mut self) -> Result<TrainReport> {
        let t0 = Instant::now();
        let mut first = None;
        let mut last = 0.0;
        for _ in 0..self.cfg.steps {
            let loss = self.train_step()?;
            if first.is_none() {
                first = Some(loss);
            }
            last = loss;
            if self.step % self.cfg.log_every.max(1) == 0 {
                println!(
                    "step {:>5}  loss {:.4}  lr {:.2e}",
                    self.step,
                    loss,
                    lr_at(self.step - 1, self.cfg.lr, self.cfg.warmup_steps)
                );
            }
            if let Some(dir) = &self.cfg.checkpoint_dir {
                if self.step % self.cfg.checkpoint_every.max(1) == 0 {
                    super::checkpoint::save(dir, &self.preset, self.step, &self.params)?;
                }
            }
        }
        let seconds = t0.elapsed().as_secs_f64();
        Ok(TrainReport {
            steps: self.cfg.steps,
            final_loss: last,
            initial_loss: first.unwrap_or(f32::NAN),
            seconds,
            steps_per_sec: self.cfg.steps as f64 / seconds.max(1e-9),
            wire_bytes: self.wire_bytes,
            threads: self.threads,
        })
    }
}

fn clip_by_global_norm(mut grads: Vec<HostTensor>, clip: f32) -> Vec<HostTensor> {
    let sq: f64 = grads
        .iter()
        .flat_map(|g| g.data.iter())
        .map(|&x| (x as f64) * (x as f64))
        .sum();
    let norm = sq.sqrt() as f32;
    if norm > clip && norm > 0.0 {
        let s = clip / norm;
        for g in grads.iter_mut() {
            g.scale(s);
        }
    }
    grads
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn clip_scales_down_only() {
        let big = vec![HostTensor::full(&[4], 10.0)];
        let out = clip_by_global_norm(big, 1.0);
        let norm: f32 = out[0].data.iter().map(|x| x * x).sum::<f32>().sqrt();
        assert!((norm - 1.0).abs() < 1e-5);
        let small = vec![HostTensor::full(&[4], 0.01)];
        let out = clip_by_global_norm(small.clone(), 1.0);
        assert_eq!(out[0].data, small[0].data);
    }
}
