//! Hybrid DP×DAP trainer (paper §V.B).
//!
//! One optimizer step under a [`ParallelPlan`]:
//!
//! 1. **Data routing** — one logical global batch stream, assigned
//!    replica-major: at step `s`, replica `r`'s micro-batch `a` is global
//!    index `s·E + r·accum + a` (E = dp·accum). Every replica's generator
//!    shares the seed and skips the other replicas' draws, so the stream
//!    a run consumes is a pure function of the *effective* batch — the
//!    foundation of the hybrid ≡ sequential equivalence suite.
//! 2. **Replica forward/backward** — through the [`TrainBackend`]: the
//!    monolithic `grad_step` executable at `dap = 1`, the DAP
//!    coordinator + tape VJP at `dap > 1` (sharded activations, per-leaf
//!    grads summed over the DAP group). Dense micro-batches fan out over
//!    the rank-executor threads; results fold in batch order
//!    (bit-for-bit vs `threads = 1`).
//! 3. **Accumulation + DP reduction** — micro-grads accumulate per
//!    replica in micro order, cross replicas via the host ring
//!    all-reduce (the Fig 11 algorithm; critical-path rank accounted in
//!    `wire_dp_bytes`, DAP collectives separately in `wire_dap_bytes`),
//!    then mean over the effective batch, global-norm clip, and the Adam
//!    executable.
//!
//! [`Trainer::run_schedule`] drives the two-stage AlphaFold recipe
//! ([`TrainSchedule`]); V2 checkpoints persist params + Adam moments +
//! step + schedule position + per-rank data cursors, so
//! [`Trainer::restore`] resumes bit-for-bit.

use super::backend::{build_backend, TrainBackend};
use super::checkpoint;
use super::data::{Batch, DataGen};
use super::plan::ParallelPlan;
use super::schedule::{LrSchedule, Stage, TrainSchedule};
use crate::comm::ring::ring_all_reduce;
use crate::config::{ModelConfig, TrainConfig};
use crate::dap::executor::default_threads;
use crate::error::{Error, Result};
use crate::runtime::Runtime;
use crate::tensor::HostTensor;
use std::time::Instant; // lint:allow(wallclock) — steps/s wall measurement

/// The training coordinator: owns parameters, optimizer state, the data
/// generators, and a [`TrainBackend`].
pub struct Trainer<'rt> {
    rt: Option<&'rt Runtime>,
    preset: String,
    model_cfg: ModelConfig,
    /// the hybrid layout this trainer executes
    pub plan: ParallelPlan,
    /// Duality-Async overlap for the DAP backend
    pub overlap: bool,
    /// model parameters (canonical leaf order)
    pub params: Vec<HostTensor>,
    /// Adam first moments
    pub m: Vec<HostTensor>,
    /// Adam second moments
    pub v: Vec<HostTensor>,
    /// global optimizer step (1-based after the first step)
    pub step: usize,
    /// current schedule stage index
    pub stage: usize,
    /// optimizer steps taken inside the current stage
    pub steps_in_stage: usize,
    /// run configuration (steps, LR knobs, checkpointing, seed)
    pub cfg: TrainConfig,
    /// LR shape of the current stage
    pub lr_sched: LrSchedule,
    /// LR actually applied by the most recent step
    pub last_lr: f32,
    backend: Box<dyn TrainBackend + 'rt>,
    gens: Vec<DataGen>,
    /// (step, loss) pairs
    pub history: Vec<(usize, f32)>,
    /// DP ring all-reduce wire bytes (critical-path rank), cumulative
    pub wire_dp_bytes: usize,
    /// DAP (model-parallel) collective wire bytes, cumulative
    pub wire_dap_bytes: usize,
}

/// What one `run`/`run_schedule` call did.
#[derive(Clone, Debug)]
pub struct TrainReport {
    /// optimizer steps actually executed by this call (not `cfg.steps` —
    /// a resumed or staged run executes the remainder)
    pub steps: usize,
    /// loss at the last executed step
    pub final_loss: f32,
    /// loss at the first executed step
    pub initial_loss: f32,
    /// wall seconds
    pub seconds: f64,
    /// executed steps per wall second
    pub steps_per_sec: f64,
    /// DP ring wire bytes moved by this call
    pub wire_bytes: usize,
    /// DAP collective wire bytes moved by this call
    pub wire_dap_bytes: usize,
    /// rank-executor threads the run used (1 = sequential)
    pub threads: usize,
    /// LR applied at the last executed step
    pub final_lr: f32,
}

/// Same-seed generators on one global stream: rank r starts offset by
/// `r · accum` draws (its slice of step 0's effective batch).
fn make_gens(cfg: &ModelConfig, seed: u64, dp: usize, accum: usize) -> Vec<DataGen> {
    (0..dp)
        .map(|r| {
            let mut g = DataGen::new(cfg.clone(), seed);
            g.fast_forward(r * accum);
            g
        })
        .collect()
}

impl<'rt> Trainer<'rt> {
    /// Data-parallel trainer (dap = 1, no accumulation) — the legacy
    /// constructor, kept as the `ParallelPlan { dp, 1, 1 }` special case.
    pub fn new(rt: &'rt Runtime, preset: &str, dp: usize, cfg: TrainConfig) -> Result<Self> {
        let plan = ParallelPlan { dp, dap: 1, accum: 1, threads: default_threads() };
        Self::hybrid(rt, preset, plan, true, cfg)
    }

    /// Hybrid DP×DAP trainer under an explicit [`ParallelPlan`].
    /// `overlap` enables Duality-Async comm deferral in the DAP backend.
    pub fn hybrid(
        rt: &'rt Runtime,
        preset: &str,
        plan: ParallelPlan,
        overlap: bool,
        cfg: TrainConfig,
    ) -> Result<Self> {
        let model_cfg = ModelConfig::preset(preset)?;
        plan.validate(&model_cfg)?;
        let params = rt.manifest.load_params(preset)?;
        let backend = build_backend(rt, preset, &plan, overlap)?;
        Ok(Self::assemble(Some(rt), preset, model_cfg, params, backend, plan, overlap, cfg))
    }

    /// Construction seam for artifact-free runs: an explicit backend and
    /// initial parameters (the hybrid equivalence suite and the CLI
    /// `--backend synthetic` smoke path). No runtime: stages cannot
    /// switch presets.
    pub fn with_backend(
        preset: &str,
        model_cfg: ModelConfig,
        params: Vec<HostTensor>,
        backend: Box<dyn TrainBackend + 'rt>,
        plan: ParallelPlan,
        cfg: TrainConfig,
    ) -> Result<Self> {
        plan.validate(&model_cfg)?;
        Ok(Self::assemble(None, preset, model_cfg, params, backend, plan, true, cfg))
    }

    #[allow(clippy::too_many_arguments)] // private assembly point
    fn assemble(
        rt: Option<&'rt Runtime>,
        preset: &str,
        model_cfg: ModelConfig,
        params: Vec<HostTensor>,
        backend: Box<dyn TrainBackend + 'rt>,
        plan: ParallelPlan,
        overlap: bool,
        cfg: TrainConfig,
    ) -> Self {
        let zeros: Vec<HostTensor> =
            params.iter().map(|p| HostTensor::zeros(&p.shape)).collect();
        let gens = make_gens(&model_cfg, cfg.seed, plan.dp, plan.accum);
        let lr_sched = LrSchedule::from_train_config(&cfg);
        Trainer {
            rt,
            preset: preset.to_string(),
            model_cfg,
            plan,
            overlap,
            m: zeros.clone(),
            v: zeros,
            params,
            step: 0,
            stage: 0,
            steps_in_stage: 0,
            cfg,
            lr_sched,
            last_lr: 0.0,
            backend,
            gens,
            history: Vec::new(),
            wire_dp_bytes: 0,
            wire_dap_bytes: 0,
        }
    }

    /// Builder-style override of the rank-executor thread budget
    /// (`--threads` on the CLI): 1 restores the sequential path, 0 means
    /// auto ([`default_threads`]). For `dap > 1` set the budget on the
    /// plan *before* construction — the coordinator binds it then.
    pub fn with_threads(mut self, threads: usize) -> Self {
        self.plan = self.plan.with_threads(threads);
        self
    }

    /// The preset this trainer currently runs.
    pub fn preset(&self) -> &str {
        &self.preset
    }

    /// The backend's display name ("dense", "dap4", "synthetic").
    pub fn backend_name(&self) -> String {
        self.backend.name()
    }

    /// Per-rank data cursors (batches drawn incl. skips).
    pub fn cursors(&self) -> Vec<u64> {
        self.gens.iter().map(|g| g.cursor()).collect()
    }

    /// One optimizer step over the effective batch (dp × accum
    /// micro-batches). Returns the mean micro-loss.
    pub fn train_step(&mut self) -> Result<f32> {
        let (dp, accum) = (self.plan.dp, self.plan.accum);
        let e = dp * accum;
        let n_leaves = self.params.len();

        // draw the step's effective batch, replica-major on the global
        // stream; each rank then skips the other ranks' next-step slice.
        // The skip regenerates (dp-1)·accum discarded batches per rank —
        // accepted: it is what a real per-rank loader does (each rank owns
        // an independent, individually-resumable stream, which is what the
        // checkpoint's per-rank cursors capture), and synthetic data gen
        // is noise next to a PJRT forward/backward at any dp this
        // single-process simulator runs.
        let mut batches: Vec<Batch> = Vec::with_capacity(e);
        for gen in self.gens.iter_mut() {
            for _ in 0..accum {
                batches.push(gen.next_batch());
            }
            gen.fast_forward((dp - 1) * accum);
        }

        let results =
            self.backend.grad_many(&self.params, &batches, self.plan.threads)?;
        if results.len() != e {
            return Err(Error::msg(format!(
                "backend returned {} micro-grads for {e} micro-batches",
                results.len()
            )));
        }
        self.wire_dap_bytes += self.backend.take_mp_wire_bytes();

        // fold losses in global micro order (replica-major = stream order)
        let mut loss_acc = 0.0f32;
        for (l, _) in &results {
            loss_acc += *l;
        }
        let leaf_shapes: Vec<Vec<usize>> =
            results[0].1.iter().map(|g| g.shape.clone()).collect();

        // replica-local accumulation in micro order
        let mut it = results.into_iter();
        let mut per_replica: Vec<Vec<HostTensor>> = Vec::with_capacity(dp);
        for _r in 0..dp {
            let (_, mut acc) = it.next().ok_or_else(|| Error::msg("no grads"))?;
            for _a in 1..accum {
                let (_, g) = it.next().ok_or_else(|| Error::msg("no grads"))?;
                for (a, b) in acc.iter_mut().zip(g.iter()) {
                    a.add_assign(b)?;
                }
            }
            per_replica.push(acc);
        }

        // DP reduction: the host ring all-reduce (the exact algorithm the
        // Fig 11 cost model prices), critical-path rank accounted
        let mut grads: Vec<HostTensor> = if dp == 1 {
            per_replica.pop().ok_or_else(|| Error::msg("no grads"))?
        } else {
            let per_rank_flat: Vec<Vec<f32>> = per_replica
                .iter()
                .map(|gs| gs.iter().flat_map(|g| g.data().iter().copied()).collect())
                .collect();
            let (reduced, wire) = ring_all_reduce(per_rank_flat)?;
            self.wire_dp_bytes += wire.iter().copied().max().unwrap_or(0);
            let flat = reduced
                .into_iter()
                .next()
                .ok_or_else(|| Error::msg("empty ring result"))?;
            let mut out = Vec::with_capacity(n_leaves);
            let mut off = 0usize;
            for shape in &leaf_shapes {
                let n: usize = shape.iter().product();
                out.push(HostTensor::new(shape.clone(), flat[off..off + n].to_vec())?);
                off += n;
            }
            out
        };

        // mean over the effective batch
        let inv = 1.0 / e as f32;
        if e > 1 {
            for g in grads.iter_mut() {
                g.scale(inv);
            }
        }

        // global-norm gradient clip (host-side; tiny vs step cost)
        let grads = match self.cfg.grad_clip {
            Some(clip) => clip_by_global_norm(grads, clip),
            None => grads,
        };

        // the LR actually applied this step (stage-local schedule)
        let lr = self.lr_sched.at(self.steps_in_stage);
        self.step += 1;
        self.steps_in_stage += 1;
        let (p2, m2, v2) =
            self.backend
                .adam(self.step, lr, &self.params, &grads, &self.m, &self.v)?;
        self.params = p2;
        self.m = m2;
        self.v = v2;
        self.last_lr = lr;

        let loss = loss_acc / e as f32;
        self.history.push((self.step, loss));
        Ok(loss)
    }

    /// Snapshot the full training state (V2 checkpoint payload).
    pub fn state(&self) -> checkpoint::TrainState {
        checkpoint::TrainState {
            preset: self.preset.clone(),
            step: self.step,
            stage: self.stage,
            steps_in_stage: self.steps_in_stage,
            accum: self.plan.accum,
            params: self.params.clone(),
            m: self.m.clone(),
            v: self.v.clone(),
            cursors: self.cursors(),
            rng_states: self.gens.iter().map(|g| g.rng_state()).collect(),
        }
    }

    /// Restore a V2 checkpoint into this trainer: params, Adam moments,
    /// step, schedule position, and the per-rank data generators — the
    /// next step is bit-for-bit the one an uninterrupted run would take.
    pub fn restore(&mut self, state: checkpoint::TrainState) -> Result<()> {
        if state.preset != self.preset {
            return Err(Error::Config(format!(
                "checkpoint is for preset '{}', trainer runs '{}'",
                state.preset, self.preset
            )));
        }
        if state.params.len() != self.params.len() {
            return Err(Error::Config(format!(
                "checkpoint has {} leaves, model has {}",
                state.params.len(),
                self.params.len()
            )));
        }
        for (a, b) in state.params.iter().zip(self.params.iter()) {
            if a.shape != b.shape {
                return Err(Error::Config(format!(
                    "checkpoint leaf shape {:?} != model {:?}",
                    a.shape, b.shape
                )));
            }
        }
        if state.cursors.len() != self.plan.dp {
            return Err(Error::Config(format!(
                "checkpoint has {} data-rank cursors, plan has dp={}",
                state.cursors.len(),
                self.plan.dp
            )));
        }
        if state.accum != self.plan.accum {
            return Err(Error::Config(format!(
                "checkpoint was written at accum={}, plan has accum={} — \
                 the per-rank cursor stride (dp × accum) would misalign \
                 the data streams",
                state.accum, self.plan.accum
            )));
        }
        self.gens = state
            .rng_states
            .iter()
            .zip(state.cursors.iter())
            .map(|(rs, &c)| DataGen::from_state(self.model_cfg.clone(), *rs, c))
            .collect();
        self.params = state.params;
        self.m = state.m;
        self.v = state.v;
        self.step = state.step;
        self.stage = state.stage;
        self.steps_in_stage = state.steps_in_stage;
        Ok(())
    }

    fn save_checkpoint(&self, dir: &str) -> Result<()> {
        checkpoint::save_full(dir, &self.state())?;
        Ok(())
    }

    /// Enter a schedule stage: bind its LR shape and, when the stage runs
    /// a different preset (initial-training crop → finetune crop),
    /// rebuild the backend and data generators for the new geometry
    /// (parameters carry over — AlphaFold's leaves are crop-independent).
    fn enter_stage(&mut self, index: usize, stage: &Stage) -> Result<()> {
        self.lr_sched = stage.lr;
        if stage.preset == self.preset {
            return Ok(());
        }
        let rt = self.rt.ok_or_else(|| {
            Error::Config(format!(
                "stage '{}' switches preset '{}' -> '{}', but this trainer \
                 was built without a runtime (with_backend seam)",
                stage.name, self.preset, stage.preset
            ))
        })?;
        let model_cfg = ModelConfig::preset(&stage.preset)?;
        self.plan.validate(&model_cfg)?;
        let expect = rt.manifest.load_params(&stage.preset)?;
        if expect.len() != self.params.len() {
            return Err(Error::Config(format!(
                "preset '{}' has {} leaves, carried params have {} — stages \
                 must share parameter shapes",
                stage.preset,
                expect.len(),
                self.params.len()
            )));
        }
        for (a, b) in expect.iter().zip(self.params.iter()) {
            if a.shape != b.shape {
                return Err(Error::Config(format!(
                    "stage '{}' leaf shape {:?} != carried {:?}",
                    stage.name, a.shape, b.shape
                )));
            }
        }
        self.backend = build_backend(rt, &stage.preset, &self.plan, self.overlap)?;
        self.preset = stage.preset.clone();
        self.model_cfg = model_cfg;
        // a new crop geometry is a new data stream: deterministic
        // stage-derived seed, fresh replica offsets
        let seed = self.cfg.seed.wrapping_add(1_000_003u64.wrapping_mul(index as u64));
        self.gens = make_gens(&self.model_cfg, seed, self.plan.dp, self.plan.accum);
        Ok(())
    }

    /// Run the single-stage schedule implied by `cfg` (`cfg.steps` total;
    /// a restored trainer executes only the remainder).
    pub fn run(&mut self) -> Result<TrainReport> {
        let sched = TrainSchedule::single(&self.preset, &self.cfg);
        self.run_schedule(&sched)
    }

    /// Drive a (possibly multi-stage) [`TrainSchedule`] from the current
    /// position to the end; log and checkpoint per config.
    pub fn run_schedule(&mut self, sched: &TrainSchedule) -> Result<TrainReport> {
        let t0 = Instant::now();
        let wire_dp0 = self.wire_dp_bytes;
        let wire_dap0 = self.wire_dap_bytes;
        let mut first = None;
        let mut last = 0.0;
        let mut executed = 0usize;
        while self.stage < sched.stages.len() {
            let stage = sched.stages[self.stage].clone();
            self.enter_stage(self.stage, &stage)?;
            while self.steps_in_stage < stage.steps {
                let loss = self.train_step()?;
                executed += 1;
                if first.is_none() {
                    first = Some(loss);
                }
                last = loss;
                if self.step % self.cfg.log_every.max(1) == 0 {
                    println!(
                        "step {:>5}  stage {}  loss {:.4}  lr {:.2e}",
                        self.step, stage.name, loss, self.last_lr
                    );
                }
                if let Some(dir) = &self.cfg.checkpoint_dir {
                    if self.step % self.cfg.checkpoint_every.max(1) == 0 {
                        self.save_checkpoint(dir)?;
                    }
                }
            }
            self.stage += 1;
            self.steps_in_stage = 0;
        }
        let seconds = t0.elapsed().as_secs_f64();
        Ok(TrainReport {
            steps: executed,
            final_loss: last,
            initial_loss: first.unwrap_or(f32::NAN),
            seconds,
            steps_per_sec: executed as f64 / seconds.max(1e-9),
            wire_bytes: self.wire_dp_bytes - wire_dp0,
            wire_dap_bytes: self.wire_dap_bytes - wire_dap0,
            threads: self.backend.effective_threads(self.plan.threads),
            final_lr: self.last_lr,
        })
    }
}

fn clip_by_global_norm(mut grads: Vec<HostTensor>, clip: f32) -> Vec<HostTensor> {
    let sq: f64 = grads
        .iter()
        .flat_map(|g| g.data().iter())
        .map(|&x| (x as f64) * (x as f64))
        .sum();
    let norm = sq.sqrt() as f32;
    if norm > clip && norm > 0.0 {
        let s = clip / norm;
        for g in grads.iter_mut() {
            g.scale(s);
        }
    }
    grads
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn clip_scales_down_only() {
        let big = vec![HostTensor::full(&[4], 10.0)];
        let out = clip_by_global_norm(big, 1.0);
        let norm: f32 = out[0].data().iter().map(|x| x * x).sum::<f32>().sqrt();
        assert!((norm - 1.0).abs() < 1e-5);
        let small = vec![HostTensor::full(&[4], 0.01)];
        let out = clip_by_global_norm(small.clone(), 1.0);
        assert_eq!(out[0].data(), small[0].data());
    }

    #[test]
    fn make_gens_offsets_the_global_stream() {
        let cfg = ModelConfig::tiny();
        // dp=2, accum=2: rank 1 starts at global index 2
        let gens = make_gens(&cfg, 11, 2, 2);
        assert_eq!(gens[0].cursor(), 0);
        assert_eq!(gens[1].cursor(), 2);
        let mut reference = DataGen::new(cfg, 11);
        reference.fast_forward(2);
        let mut g1 = gens.into_iter().nth(1).unwrap();
        assert_eq!(
            g1.next_batch().msa_tokens.data,
            reference.next_batch().msa_tokens.data
        );
    }
}
