//! Hybrid DP×DAP trainer (paper §V.B).
//!
//! One optimizer step under a [`ParallelPlan`]:
//!
//! 1. **Data routing** — one logical global batch stream, assigned
//!    replica-major: at step `s`, replica `r`'s micro-batch `a` is global
//!    index `s·E + r·accum + a` (E = dp·accum). Every replica's generator
//!    shares the seed and skips the other replicas' draws, so the stream
//!    a run consumes is a pure function of the *effective* batch — the
//!    foundation of the hybrid ≡ sequential equivalence suite.
//! 2. **Replica forward/backward** — through the [`TrainBackend`]: the
//!    monolithic `grad_step` executable at `dap = 1`, the DAP
//!    coordinator + tape VJP at `dap > 1` (sharded activations, per-leaf
//!    grads summed over the DAP group). Dense micro-batches fan out over
//!    the rank-executor threads; results fold in batch order
//!    (bit-for-bit vs `threads = 1`).
//! 3. **Accumulation + DP reduction** — micro-grads accumulate per
//!    replica in micro order, cross replicas via the host ring
//!    all-reduce (the Fig 11 algorithm; critical-path rank accounted in
//!    `wire_dp_bytes`, DAP collectives separately in `wire_dap_bytes`),
//!    then mean over the effective batch, global-norm clip, and the Adam
//!    executable.
//!
//! [`Trainer::run_schedule`] drives the two-stage AlphaFold recipe
//! ([`TrainSchedule`]); V2 checkpoints persist params + Adam moments +
//! step + schedule position + per-rank data cursors, so
//! [`Trainer::restore`] resumes bit-for-bit.
//!
//! A seeded [`FaultSchedule`] installed via [`Trainer::with_faults`]
//! exercises the recovery planes: transient faults (simulated OOM, comm
//! stall) retry the grad phase with exponential backoff over the *same*
//! drawn batches; corrupted wire payloads are caught by the CRC guard
//! and ledgered as retransmits; a permanent rank crash surfaces as
//! [`Error::RankLost`] from the heartbeat sweep, and the schedule driver
//! rolls back to the latest V2 checkpoint and re-plans with shrunk `dp`
//! at constant effective batch — the stream is a pure function of the
//! effective batch, so the recovered run converges bit-for-bit to the
//! fault-free one. All recovery seconds are modeled (virtual clock), so
//! the [`RecoveryLedger`] is deterministic.

use super::backend::{build_backend, TrainBackend};
use super::bucket::{bucketed_step, BucketPlan};
use super::checkpoint;
use super::data::{Batch, DataGen};
use super::plan::ParallelPlan;
use super::prefetch::Prefetcher;
use super::schedule::{LrSchedule, Stage, TrainSchedule};
use crate::comm::ring::{
    ring_all_reduce_bf16_with_scratch, ring_all_reduce_with_scratch, RingScratch,
};
use crate::config::{ModelConfig, Precision, TrainConfig};
use crate::dap::executor::default_threads;
use crate::error::{Error, Result};
use crate::faults::{FaultKind, FaultSchedule, Heartbeats, Injector, RecoveryLedger};
use crate::runtime::Runtime;
use crate::tensor::HostTensor;
use std::time::Instant; // lint:allow(wallclock) — steps/s + comm/stall wall measurement

/// The training coordinator: owns parameters, optimizer state, the data
/// generators, and a [`TrainBackend`].
pub struct Trainer<'rt> {
    rt: Option<&'rt Runtime>,
    preset: String,
    model_cfg: ModelConfig,
    /// the hybrid layout this trainer executes
    pub plan: ParallelPlan,
    /// Duality-Async overlap for the DAP backend
    pub overlap: bool,
    /// model parameters (canonical leaf order)
    pub params: Vec<HostTensor>,
    /// Adam first moments
    pub m: Vec<HostTensor>,
    /// Adam second moments
    pub v: Vec<HostTensor>,
    /// global optimizer step (1-based after the first step)
    pub step: usize,
    /// current schedule stage index
    pub stage: usize,
    /// optimizer steps taken inside the current stage
    pub steps_in_stage: usize,
    /// run configuration (steps, LR knobs, checkpointing, seed)
    pub cfg: TrainConfig,
    /// LR shape of the current stage
    pub lr_sched: LrSchedule,
    /// LR actually applied by the most recent step
    pub last_lr: f32,
    backend: Box<dyn TrainBackend + 'rt>,
    gens: Vec<DataGen>,
    /// (step, loss) pairs
    pub history: Vec<(usize, f32)>,
    /// DP ring all-reduce wire bytes (critical-path rank), cumulative
    pub wire_dp_bytes: usize,
    /// DAP (model-parallel) collective wire bytes, cumulative
    pub wire_dap_bytes: usize,
    /// double-buffered input producer, live while `cfg.prefetch` is on
    prefetcher: Option<Prefetcher>,
    /// ring-reduce scratch shared across every bucket and step
    ring_scratch: RingScratch,
    /// bucket partition, built on first bucketed step (invalidated when
    /// the backend — and hence the backward order — changes)
    bucket_plan: Option<BucketPlan>,
    /// dynamic loss scale applied to the gradient wire (power of two;
    /// 1.0 in f32 mode)
    pub loss_scale: f32,
    /// optimizer updates skipped by the bf16 non-finite guard, cumulative
    pub skipped_steps: usize,
    consecutive_skips: usize,
    scale_growth_counter: usize,
    /// measured wall seconds spent inside DP ring reductions, cumulative
    pub comm_seconds: f64,
    /// the part of `comm_seconds` that blocked the compute path
    /// (monolithic reductions are fully exposed; bucketed ones only
    /// their post-backward tail), cumulative
    pub exposed_comm_seconds: f64,
    /// wall seconds the step blocked waiting on the prefetch producer,
    /// cumulative
    pub prefetch_stall_seconds: f64,
    /// fault-injection plane, installed by [`Trainer::with_faults`]
    injector: Option<Injector>,
    /// per-rank liveness plane (rebuilt on elastic dp-shrink)
    heartbeats: Heartbeats,
    /// recovery-cost ledger for faulted runs, cumulative
    pub recovery: RecoveryLedger,
}

/// Initial dynamic loss scale in bf16 mode (2^15 — exact in binary FP,
/// so scaling is mantissa-preserving and exactly invertible).
const LOSS_SCALE_INIT: f32 = 32768.0;
/// Dynamic loss scale ceiling (2^24).
const LOSS_SCALE_MAX: f32 = 16_777_216.0;
/// Clean steps between loss-scale doublings.
const LOSS_SCALE_GROWTH_INTERVAL: usize = 2000;
/// Consecutive guard skips before the run is declared diverged.
const MAX_CONSECUTIVE_SKIPS: usize = 50;

/// Grad-phase attempts per step before a transient fault is permanent.
const MAX_GRAD_ATTEMPTS: usize = 4;
/// Modeled base backoff before a grad-phase retry, seconds.
const RETRY_BACKOFF_BASE_SECS: f64 = 0.05;
/// Modeled cost of one straggler slowdown, seconds.
const STRAGGLER_SECS: f64 = 0.25;
/// Modeled cost of one corrupt-payload retransmit, seconds.
const RETRANSMIT_SECS: f64 = 0.01;
/// Modeled cost of one rollback + dp-shrink recovery, seconds.
const ROLLBACK_SECS: f64 = 2.0;

/// What one `run`/`run_schedule` call did.
#[derive(Clone, Debug)]
pub struct TrainReport {
    /// optimizer steps actually executed by this call (not `cfg.steps` —
    /// a resumed or staged run executes the remainder)
    pub steps: usize,
    /// loss at the last executed step
    pub final_loss: f32,
    /// loss at the first executed step
    pub initial_loss: f32,
    /// wall seconds
    pub seconds: f64,
    /// executed steps per wall second
    pub steps_per_sec: f64,
    /// DP ring wire bytes moved by this call
    pub wire_bytes: usize,
    /// DAP collective wire bytes moved by this call
    pub wire_dap_bytes: usize,
    /// rank-executor threads the run used (1 = sequential)
    pub threads: usize,
    /// LR applied at the last executed step
    pub final_lr: f32,
    /// gradient-wire precision the run used ("f32" or "bf16")
    pub precision: &'static str,
    /// measured wall seconds inside DP ring reductions for this call
    pub comm_seconds: f64,
    /// the part of `comm_seconds` that blocked the compute path
    pub exposed_comm_seconds: f64,
    /// fraction of comm time hidden behind the backward
    /// (`1 − exposed/comm`; 1.0 when no comm was measured)
    pub overlap_fraction: f64,
    /// wall seconds blocked waiting on the prefetch producer
    pub prefetch_stall_seconds: f64,
    /// optimizer updates skipped by the bf16 non-finite guard
    pub skipped_steps: usize,
    /// recovery cost absorbed by this call (all zero on clean runs)
    pub recovery: RecoveryLedger,
}

/// Same-seed generators on one global stream: rank r starts offset by
/// `r · accum` draws (its slice of step 0's effective batch).
fn make_gens(cfg: &ModelConfig, seed: u64, dp: usize, accum: usize) -> Vec<DataGen> {
    (0..dp)
        .map(|r| {
            let mut g = DataGen::new(cfg.clone(), seed);
            g.fast_forward(r * accum);
            g
        })
        .collect()
}

impl<'rt> Trainer<'rt> {
    /// Data-parallel trainer (dap = 1, no accumulation) — the legacy
    /// constructor, kept as the `ParallelPlan { dp, 1, 1 }` special case.
    pub fn new(rt: &'rt Runtime, preset: &str, dp: usize, cfg: TrainConfig) -> Result<Self> {
        let plan = ParallelPlan { dp, dap: 1, accum: 1, threads: default_threads() };
        Self::hybrid(rt, preset, plan, true, cfg)
    }

    /// Hybrid DP×DAP trainer under an explicit [`ParallelPlan`].
    /// `overlap` enables Duality-Async comm deferral in the DAP backend.
    pub fn hybrid(
        rt: &'rt Runtime,
        preset: &str,
        plan: ParallelPlan,
        overlap: bool,
        cfg: TrainConfig,
    ) -> Result<Self> {
        let model_cfg = ModelConfig::preset(preset)?;
        plan.validate(&model_cfg)?;
        let params = rt.manifest.load_params(preset)?;
        let backend = build_backend(rt, preset, &plan, overlap)?;
        Ok(Self::assemble(Some(rt), preset, model_cfg, params, backend, plan, overlap, cfg))
    }

    /// Construction seam for artifact-free runs: an explicit backend and
    /// initial parameters (the hybrid equivalence suite and the CLI
    /// `--backend synthetic` smoke path). No runtime: stages cannot
    /// switch presets.
    pub fn with_backend(
        preset: &str,
        model_cfg: ModelConfig,
        params: Vec<HostTensor>,
        backend: Box<dyn TrainBackend + 'rt>,
        plan: ParallelPlan,
        cfg: TrainConfig,
    ) -> Result<Self> {
        plan.validate(&model_cfg)?;
        Ok(Self::assemble(None, preset, model_cfg, params, backend, plan, true, cfg))
    }

    #[allow(clippy::too_many_arguments)] // private assembly point
    fn assemble(
        rt: Option<&'rt Runtime>,
        preset: &str,
        model_cfg: ModelConfig,
        params: Vec<HostTensor>,
        backend: Box<dyn TrainBackend + 'rt>,
        plan: ParallelPlan,
        overlap: bool,
        cfg: TrainConfig,
    ) -> Self {
        let zeros: Vec<HostTensor> =
            params.iter().map(|p| HostTensor::zeros(&p.shape)).collect();
        let gens = make_gens(&model_cfg, cfg.seed, plan.dp, plan.accum);
        let heartbeats = Heartbeats::new(plan.dp);
        let lr_sched = LrSchedule::from_train_config(&cfg);
        let cfg_precision = cfg.precision;
        Trainer {
            rt,
            preset: preset.to_string(),
            model_cfg,
            plan,
            overlap,
            m: zeros.clone(),
            v: zeros,
            params,
            step: 0,
            stage: 0,
            steps_in_stage: 0,
            cfg,
            lr_sched,
            last_lr: 0.0,
            backend,
            gens,
            history: Vec::new(),
            wire_dp_bytes: 0,
            wire_dap_bytes: 0,
            prefetcher: None,
            ring_scratch: RingScratch::new(),
            bucket_plan: None,
            loss_scale: match cfg_precision {
                Precision::F32 => 1.0,
                Precision::Bf16 => LOSS_SCALE_INIT,
            },
            skipped_steps: 0,
            consecutive_skips: 0,
            scale_growth_counter: 0,
            comm_seconds: 0.0,
            exposed_comm_seconds: 0.0,
            prefetch_stall_seconds: 0.0,
            injector: None,
            heartbeats,
            recovery: RecoveryLedger::default(),
        }
    }

    /// Builder-style override of the rank-executor thread budget
    /// (`--threads` on the CLI): 1 restores the sequential path, 0 means
    /// auto ([`default_threads`]). For `dap > 1` set the budget on the
    /// plan *before* construction — the coordinator binds it then.
    pub fn with_threads(mut self, threads: usize) -> Self {
        self.plan = self.plan.with_threads(threads);
        self
    }

    /// Install a deterministic fault schedule (validated against the
    /// current plan) to be injected at the step seams: transients fail
    /// grad-phase attempts, crashes flip the heartbeat plane. Resets the
    /// recovery ledger and liveness state.
    pub fn with_faults(&mut self, schedule: FaultSchedule) -> Result<()> {
        schedule.validate(self.plan.dp)?;
        self.heartbeats = Heartbeats::new(self.plan.dp);
        self.recovery = RecoveryLedger::default();
        self.injector = Some(Injector::new(schedule));
        Ok(())
    }

    /// The preset this trainer currently runs.
    pub fn preset(&self) -> &str {
        &self.preset
    }

    /// The backend's display name ("dense", "dap4", "synthetic").
    pub fn backend_name(&self) -> String {
        self.backend.name()
    }

    /// Per-rank data cursors (batches drawn incl. skips).
    pub fn cursors(&self) -> Vec<u64> {
        self.gens.iter().map(|g| g.cursor()).collect()
    }

    /// CRC-32 fingerprint of every parameter leaf's little-endian bytes
    /// in canonical order — what the chaos CI job compares between the
    /// faulted-and-recovered run and the fault-free control.
    pub fn params_crc32(&self) -> u32 {
        let flat: Vec<f32> = self
            .params
            .iter()
            .flat_map(|p| p.data().iter().copied())
            .collect();
        crate::faults::crc32_f32(&flat)
    }

    /// Draw the step's effective batch, replica-major on the global
    /// stream — inline, or consumed from the double-buffered prefetcher
    /// when `cfg.prefetch` is on (bit-for-bit the same stream either
    /// way; the trainer adopts the producer's post-draw generator state
    /// so checkpoints cannot tell the difference).
    fn draw_step_batches(&mut self) -> Result<Vec<Batch>> {
        let (dp, accum) = (self.plan.dp, self.plan.accum);
        if self.cfg.prefetch {
            if self.prefetcher.is_none() {
                self.prefetcher =
                    Some(Prefetcher::start(&self.model_cfg, &self.gens, accum));
            }
            let pf = self.prefetcher.as_mut().expect("started above");
            let step = pf.next_step()?;
            self.prefetch_stall_seconds += pf.take_stall_seconds();
            self.gens = step
                .rng_states
                .iter()
                .zip(step.cursors.iter())
                .map(|(rs, &c)| DataGen::from_state(self.model_cfg.clone(), *rs, c))
                .collect();
            return Ok(step.batches);
        }
        // inline path: each rank skips the other ranks' next-step slice.
        // The skip is an O(1) cursor bump on the counter-keyed stream —
        // each rank owns an independent, individually-resumable stream,
        // which is what the checkpoint's per-rank cursors capture.
        let mut batches: Vec<Batch> = Vec::with_capacity(dp * accum);
        for gen in self.gens.iter_mut() {
            for _ in 0..accum {
                batches.push(gen.next_batch());
            }
            gen.fast_forward((dp - 1) * accum);
        }
        Ok(batches)
    }

    /// The legacy gradient phase: materialize every micro-grad, fold per
    /// replica in micro order, then one monolithic (fully exposed) ring
    /// all-reduce over the whole flattened gradient. Returns per-batch
    /// losses and the effective-batch gradient *sums* (still carrying
    /// the loss scale in bf16 mode).
    fn monolithic_grad_phase(
        &mut self,
        batches: &[Batch],
    ) -> Result<(Vec<f32>, Vec<HostTensor>)> {
        let (dp, accum) = (self.plan.dp, self.plan.accum);
        let e = dp * accum;
        let n_leaves = self.params.len();
        let results =
            self.backend.grad_many(&self.params, batches, self.plan.threads)?;
        if results.len() != e {
            return Err(Error::msg(format!(
                "backend returned {} micro-grads for {e} micro-batches",
                results.len()
            )));
        }
        let losses: Vec<f32> = results.iter().map(|(l, _)| *l).collect();
        let leaf_shapes: Vec<Vec<usize>> =
            results[0].1.iter().map(|g| g.shape.clone()).collect();

        // replica-local accumulation in micro order
        let mut it = results.into_iter();
        let mut per_replica: Vec<Vec<HostTensor>> = Vec::with_capacity(dp);
        for _r in 0..dp {
            let (_, mut acc) = it.next().ok_or_else(|| Error::msg("no grads"))?;
            for _a in 1..accum {
                let (_, g) = it.next().ok_or_else(|| Error::msg("no grads"))?;
                for (a, b) in acc.iter_mut().zip(g.iter()) {
                    a.add_assign(b)?;
                }
            }
            per_replica.push(acc);
        }

        let bf16 = self.cfg.precision == Precision::Bf16;
        let grads: Vec<HostTensor> = if dp == 1 {
            let mut grads =
                per_replica.pop().ok_or_else(|| Error::msg("no grads"))?;
            if bf16 {
                // match the dp > 1 wire semantics: scale, round to the
                // bf16 grid (what a stored bf16 gradient would hold)
                for g in grads.iter_mut() {
                    g.scale(self.loss_scale);
                    crate::device::bf16_round_tensor(g);
                }
            }
            grads
        } else {
            // DP reduction: the host ring all-reduce (the exact algorithm
            // the Fig 11 cost model prices), critical-path rank accounted
            let mut per_rank_flat: Vec<Vec<f32>> = per_replica
                .iter()
                .map(|gs| gs.iter().flat_map(|g| g.data().iter().copied()).collect())
                .collect();
            if bf16 && self.loss_scale != 1.0 {
                for f in per_rank_flat.iter_mut() {
                    crate::device::current().scale(f, self.loss_scale);
                }
            }
            let t = Instant::now();
            let (reduced, wire) = if bf16 {
                ring_all_reduce_bf16_with_scratch(per_rank_flat, &mut self.ring_scratch)?
            } else {
                ring_all_reduce_with_scratch(per_rank_flat, &mut self.ring_scratch)?
            };
            // the monolithic reduction sits entirely on the critical path
            let dt = t.elapsed().as_secs_f64();
            self.comm_seconds += dt;
            self.exposed_comm_seconds += dt;
            self.wire_dp_bytes += wire.iter().copied().max().unwrap_or(0);
            let flat = reduced
                .into_iter()
                .next()
                .ok_or_else(|| Error::msg("empty ring result"))?;
            let mut out = Vec::with_capacity(n_leaves);
            let mut off = 0usize;
            for shape in &leaf_shapes {
                let n: usize = shape.iter().product();
                out.push(HostTensor::new(shape.clone(), flat[off..off + n].to_vec())?);
                off += n;
            }
            out
        };
        Ok((losses, grads))
    }

    /// The overlapped gradient phase: stream the backward into per-block
    /// buckets, each ring-reduced the moment it completes. The bucket
    /// partition is verified hazard-free by the effect-IR schedule
    /// verifier before its first use.
    fn bucketed_grad_phase(
        &mut self,
        batches: &[Batch],
    ) -> Result<(Vec<f32>, Vec<HostTensor>)> {
        let (dp, accum) = (self.plan.dp, self.plan.accum);
        let n_leaves = self.params.len();
        if self.bucket_plan.is_none() {
            let leaf_sizes: Vec<usize> =
                self.params.iter().map(|p| p.data().len()).collect();
            let order = self.backend.backward_leaf_order(n_leaves);
            let mb = self.cfg.bucket_mb.expect("bucketed path gated on bucket_mb");
            let bytes = ((mb * (1u64 << 20) as f64) as usize).max(4);
            let plan = BucketPlan::new(&leaf_sizes, &order, bytes)?;
            plan.admit("train", dp)?;
            self.bucket_plan = Some(plan);
        }
        let plan = self.bucket_plan.as_ref().expect("built above");
        let wire_scale = if self.cfg.precision == Precision::Bf16 {
            self.loss_scale
        } else {
            1.0
        };
        let out = bucketed_step(
            self.backend.as_ref(),
            &self.params,
            batches,
            dp,
            accum,
            self.plan.threads,
            plan,
            self.cfg.precision,
            wire_scale,
            &mut self.ring_scratch,
        )?;
        self.wire_dp_bytes += out.wire_bytes;
        self.comm_seconds += out.comm_seconds;
        self.exposed_comm_seconds += out.exposed_seconds;
        Ok((out.losses, out.grads))
    }

    /// Consume this step's scheduled non-retryable events: stragglers
    /// are absorbed as modeled slowdown; a rank crash flips the target's
    /// liveness bit for the heartbeat sweep to surface.
    fn consume_step_faults(&mut self, step: usize) {
        let dp = self.plan.dp;
        let Some(inj) = self.injector.as_mut() else {
            return;
        };
        while inj.take(step, FaultKind::Straggler).is_some() {
            self.recovery.stragglers += 1;
            self.recovery.recovery_seconds += STRAGGLER_SECS;
        }
        while let Some(rank) = inj.take(step, FaultKind::RankCrash) {
            // events scheduled before a shrink may name a retired rank
            self.heartbeats.mark_dead(rank % dp);
        }
    }

    /// Tick live ranks and surface the lowest dead one as
    /// [`Error::RankLost`]. Detection sits at the step boundary, before
    /// any batch is drawn on behalf of a rank that will never compute.
    fn sweep_heartbeats(&mut self, step: usize) -> Result<()> {
        if self.injector.is_none() {
            return Ok(());
        }
        for r in 0..self.plan.dp {
            if !self.heartbeats.is_dead(r) {
                self.heartbeats.tick(r);
            }
        }
        match self.heartbeats.first_dead() {
            Some(rank) => Err(Error::RankLost { rank, step }),
            None => Ok(()),
        }
    }

    /// Corrupt-payload events: flip a bit on a wire copy of the reduced
    /// gradient, confirm the CRC guard catches it, and ledger the
    /// retransmit. The pristine payload proceeds — detect-and-retransmit
    /// leaves the reduced result bitwise unchanged.
    fn guard_wire_payload(&mut self, step: usize, grads: &[HostTensor]) {
        let Some(inj) = self.injector.as_mut() else {
            return;
        };
        while inj.take(step, FaultKind::CorruptPayload).is_some() {
            let Some(leaf) = grads.first() else {
                continue;
            };
            let want = crate::comm::ring::payload_crc32(leaf.data());
            let mut wire = leaf.data().to_vec();
            if let Some(x) = wire.first_mut() {
                *x = f32::from_bits(x.to_bits() ^ 1);
            }
            if crate::comm::ring::payload_crc32(&wire) != want {
                self.recovery.retransmits += 1;
                self.recovery.recovery_seconds += RETRANSMIT_SECS;
            }
        }
    }

    /// One grad-phase attempt under the fault plane: scheduled
    /// transients for this step fail the attempt before any compute; a
    /// clean pass then runs the wire-payload CRC guard.
    fn faulted_grad_phase(
        &mut self,
        batches: &[Batch],
        step: usize,
    ) -> Result<(Vec<f32>, Vec<HostTensor>)> {
        if let Some(inj) = self.injector.as_mut() {
            if inj.take(step, FaultKind::TransientOom).is_some() {
                return Err(Error::SimOom { need_gb: 48.0, cap_gb: 40.0 });
            }
            if let Some(rank) = inj.take(step, FaultKind::CommStall) {
                return Err(Error::CommTimeout {
                    op: "ring_all_reduce".into(),
                    rank,
                    waited_ms: crate::comm::worker::wait_timeout_ms(),
                });
            }
        }
        let out = if self.cfg.bucket_mb.is_some() {
            self.bucketed_grad_phase(batches)?
        } else {
            self.monolithic_grad_phase(batches)?
        };
        self.guard_wire_payload(step, &out.1);
        Ok(out)
    }

    /// The gradient phase with bounded retry: injected transients back
    /// off exponentially (modeled seconds — deterministic) and re-run
    /// over the *same* drawn batches, so a retried step is bitwise the
    /// step a clean run would have taken.
    fn grad_phase_with_retry(
        &mut self,
        batches: &[Batch],
        step: usize,
    ) -> Result<(Vec<f32>, Vec<HostTensor>)> {
        let mut attempt = 0usize;
        loop {
            attempt += 1;
            match self.faulted_grad_phase(batches, step) {
                Err(e)
                    if self.injector.is_some()
                        && attempt < MAX_GRAD_ATTEMPTS
                        && is_transient(&e) =>
                {
                    if matches!(e, Error::CommTimeout { .. }) {
                        self.recovery.comm_timeouts += 1;
                    }
                    self.recovery.retries += 1;
                    self.recovery.recovery_seconds +=
                        crate::faults::backoff_secs(RETRY_BACKOFF_BASE_SECS, attempt);
                }
                other => return other,
            }
        }
    }

    /// One optimizer step over the effective batch (dp × accum
    /// micro-batches). Returns the mean micro-loss.
    pub fn train_step(&mut self) -> Result<f32> {
        let (dp, accum) = (self.plan.dp, self.plan.accum);
        let e = dp * accum;
        let step = self.step + 1;
        self.consume_step_faults(step);
        self.sweep_heartbeats(step)?;
        let batches = self.draw_step_batches()?;

        let (losses, mut grads) = self.grad_phase_with_retry(&batches, step)?;
        self.wire_dap_bytes += self.backend.take_mp_wire_bytes();

        // fold losses in global micro order (replica-major = stream order)
        let mut loss_acc = 0.0f32;
        for l in &losses {
            loss_acc += *l;
        }

        // bf16 guard: a non-finite reduced gradient skips the update and
        // shrinks the loss scale (data is consumed either way — standard
        // dynamic-loss-scaling semantics); a clean step pays the scale
        // back out (exact: the scale is a power of two) and periodically
        // grows it
        if self.cfg.precision == Precision::Bf16 {
            let non_finite = grads
                .iter()
                .any(|g| g.data().iter().any(|x| !x.is_finite()));
            if non_finite {
                self.skipped_steps += 1;
                self.consecutive_skips += 1;
                if self.consecutive_skips > MAX_CONSECUTIVE_SKIPS {
                    return Err(Error::msg(format!(
                        "bf16 loss-scale guard: {} consecutive non-finite \
                         gradient steps (loss scale now {})",
                        self.consecutive_skips, self.loss_scale
                    )));
                }
                self.loss_scale = (self.loss_scale * 0.5).max(1.0);
                self.scale_growth_counter = 0;
                return Ok(loss_acc / e as f32);
            }
            let inv = 1.0 / self.loss_scale;
            if inv != 1.0 {
                for g in grads.iter_mut() {
                    g.scale(inv);
                }
            }
            self.consecutive_skips = 0;
            self.scale_growth_counter += 1;
            if self.scale_growth_counter >= LOSS_SCALE_GROWTH_INTERVAL
                && self.loss_scale < LOSS_SCALE_MAX
            {
                self.loss_scale *= 2.0;
                self.scale_growth_counter = 0;
            }
        }

        // mean over the effective batch
        let inv = 1.0 / e as f32;
        if e > 1 {
            for g in grads.iter_mut() {
                g.scale(inv);
            }
        }

        // global-norm gradient clip (host-side; tiny vs step cost)
        let grads = match self.cfg.grad_clip {
            Some(clip) => clip_by_global_norm(grads, clip),
            None => grads,
        };

        // the LR actually applied this step (stage-local schedule)
        let lr = self.lr_sched.at(self.steps_in_stage);
        self.step += 1;
        self.steps_in_stage += 1;
        let (p2, m2, v2) =
            self.backend
                .adam(self.step, lr, &self.params, &grads, &self.m, &self.v)?;
        self.params = p2;
        self.m = m2;
        self.v = v2;
        self.last_lr = lr;

        let loss = loss_acc / e as f32;
        self.history.push((self.step, loss));
        Ok(loss)
    }

    /// Snapshot the full training state (V2 checkpoint payload).
    pub fn state(&self) -> checkpoint::TrainState {
        checkpoint::TrainState {
            preset: self.preset.clone(),
            step: self.step,
            stage: self.stage,
            steps_in_stage: self.steps_in_stage,
            accum: self.plan.accum,
            params: self.params.clone(),
            m: self.m.clone(),
            v: self.v.clone(),
            cursors: self.cursors(),
            rng_states: self.gens.iter().map(|g| g.rng_state()).collect(),
        }
    }

    /// Preset + leaf-count + leaf-shape compatibility of a checkpoint
    /// against this trainer (shared by [`Self::restore`] and the elastic
    /// recovery path).
    fn check_state_shapes(&self, state: &checkpoint::TrainState) -> Result<()> {
        if state.preset != self.preset {
            return Err(Error::Config(format!(
                "checkpoint is for preset '{}', trainer runs '{}'",
                state.preset, self.preset
            )));
        }
        if state.params.len() != self.params.len() {
            return Err(Error::Config(format!(
                "checkpoint has {} leaves, model has {}",
                state.params.len(),
                self.params.len()
            )));
        }
        for (a, b) in state.params.iter().zip(self.params.iter()) {
            if a.shape != b.shape {
                return Err(Error::Config(format!(
                    "checkpoint leaf shape {:?} != model {:?}",
                    a.shape, b.shape
                )));
            }
        }
        Ok(())
    }

    /// Restore a V2 checkpoint into this trainer: params, Adam moments,
    /// step, schedule position, and the per-rank data generators — the
    /// next step is bit-for-bit the one an uninterrupted run would take.
    pub fn restore(&mut self, state: checkpoint::TrainState) -> Result<()> {
        self.check_state_shapes(&state)?;
        if state.cursors.len() != self.plan.dp {
            return Err(Error::Config(format!(
                "checkpoint has {} data-rank cursors, plan has dp={}",
                state.cursors.len(),
                self.plan.dp
            )));
        }
        if state.accum != self.plan.accum {
            return Err(Error::Config(format!(
                "checkpoint was written at accum={}, plan has accum={} — \
                 the per-rank cursor stride (dp × accum) would misalign \
                 the data streams",
                state.accum, self.plan.accum
            )));
        }
        // the restored stream position invalidates any in-flight
        // prefetched batches; a fresh producer restarts on demand
        self.prefetcher = None;
        self.gens = state
            .rng_states
            .iter()
            .zip(state.cursors.iter())
            .map(|(rs, &c)| DataGen::from_state(self.model_cfg.clone(), *rs, c))
            .collect();
        self.params = state.params;
        self.m = state.m;
        self.v = state.v;
        self.step = state.step;
        self.stage = state.stage;
        self.steps_in_stage = state.steps_in_stage;
        Ok(())
    }

    /// Restore a checkpoint into a *different* dp×accum layout with the
    /// same effective batch. Per-rank generators are re-derived from the
    /// checkpoint's rank-0 stream position: the stream is counter-keyed,
    /// so new rank `r` resumes at `pos + r·accum'` — the exact draws the
    /// old layout would have handed out.
    fn restore_elastic(&mut self, state: checkpoint::TrainState) -> Result<()> {
        self.check_state_shapes(&state)?;
        let old_e = state.cursors.len() * state.accum;
        let new_e = self.plan.dp * self.plan.accum;
        if old_e != new_e {
            return Err(Error::Config(format!(
                "elastic restore changes the effective batch: checkpoint \
                 has {old_e}, new plan has {new_e}"
            )));
        }
        let (seed, pos) = match (state.rng_states.first(), state.cursors.first())
        {
            (Some(rs), Some(&c)) => (rs.0, c),
            _ => {
                return Err(Error::Config(
                    "checkpoint carries no data-rank state".into(),
                ))
            }
        };
        self.prefetcher = None;
        let accum = self.plan.accum as u64;
        self.gens = (0..self.plan.dp as u64)
            .map(|r| {
                let c = pos + r * accum;
                DataGen::from_state(self.model_cfg.clone(), (seed, c), c)
            })
            .collect();
        self.params = state.params;
        self.m = state.m;
        self.v = state.v;
        self.step = state.step;
        self.stage = state.stage;
        self.steps_in_stage = state.steps_in_stage;
        Ok(())
    }

    /// Elastic recovery from a permanent rank loss: roll back to the
    /// latest readable V2 checkpoint, re-plan with the largest surviving
    /// `dp` that divides the effective batch (accum grows to match), and
    /// resume. The data stream is a pure function of the effective
    /// batch, so the recovered run converges bit-for-bit to fault-free.
    fn recover_from_rank_loss(&mut self, rank: usize, step: usize) -> Result<()> {
        let dir = self.cfg.checkpoint_dir.clone().ok_or_else(|| {
            Error::Config(format!(
                "rank {rank} lost at step {step} with no checkpoint_dir — \
                 elastic recovery rolls back to the latest V2 checkpoint"
            ))
        })?;
        let (ckpt_step, state) = checkpoint::load_latest_full(&dir, &self.preset)?
            .ok_or_else(|| {
                Error::Config(format!(
                    "rank {rank} lost at step {step} before any checkpoint \
                     was written — nothing to roll back to"
                ))
            })?;
        let e = self.plan.dp * self.plan.accum;
        let new_dp =
            (1..self.plan.dp).rev().find(|d| e % d == 0).ok_or_else(|| {
                Error::Config(format!(
                    "rank {rank} lost at dp={} — no smaller layout divides \
                     the effective batch {e}",
                    self.plan.dp
                ))
            })?;
        println!(
            "rank {rank} lost at step {step}: rolling back to step \
             {ckpt_step}, re-planning dp {} -> {new_dp} (accum {} -> {})",
            self.plan.dp,
            self.plan.accum,
            e / new_dp
        );
        self.recovery.rank_crashes += 1;
        self.recovery.lost_steps += self.step - ckpt_step;
        self.recovery.recovery_seconds += ROLLBACK_SECS;
        self.plan.dp = new_dp;
        self.plan.accum = e / new_dp;
        self.heartbeats = Heartbeats::new(new_dp);
        // the bucket partition was admitted at the old dp; re-admit lazily
        self.bucket_plan = None;
        self.restore_elastic(state)
    }

    fn save_checkpoint(&self, dir: &str) -> Result<()> {
        checkpoint::save_full(dir, &self.state())?;
        Ok(())
    }

    /// Enter a schedule stage: bind its LR shape and, when the stage runs
    /// a different preset (initial-training crop → finetune crop),
    /// rebuild the backend and data generators for the new geometry
    /// (parameters carry over — AlphaFold's leaves are crop-independent).
    fn enter_stage(&mut self, index: usize, stage: &Stage) -> Result<()> {
        self.lr_sched = stage.lr;
        if stage.preset == self.preset {
            return Ok(());
        }
        let rt = self.rt.ok_or_else(|| {
            Error::Config(format!(
                "stage '{}' switches preset '{}' -> '{}', but this trainer \
                 was built without a runtime (with_backend seam)",
                stage.name, self.preset, stage.preset
            ))
        })?;
        let model_cfg = ModelConfig::preset(&stage.preset)?;
        self.plan.validate(&model_cfg)?;
        let expect = rt.manifest.load_params(&stage.preset)?;
        if expect.len() != self.params.len() {
            return Err(Error::Config(format!(
                "preset '{}' has {} leaves, carried params have {} — stages \
                 must share parameter shapes",
                stage.preset,
                expect.len(),
                self.params.len()
            )));
        }
        for (a, b) in expect.iter().zip(self.params.iter()) {
            if a.shape != b.shape {
                return Err(Error::Config(format!(
                    "stage '{}' leaf shape {:?} != carried {:?}",
                    stage.name, a.shape, b.shape
                )));
            }
        }
        self.backend = build_backend(rt, &stage.preset, &self.plan, self.overlap)?;
        self.preset = stage.preset.clone();
        self.model_cfg = model_cfg;
        // the new backend may complete its backward in a different leaf
        // order; the new geometry is a new data stream — rebuild both
        // the bucket partition and the prefetch producer on demand
        self.bucket_plan = None;
        self.prefetcher = None;
        // a new crop geometry is a new data stream: deterministic
        // stage-derived seed, fresh replica offsets
        let seed = self.cfg.seed.wrapping_add(1_000_003u64.wrapping_mul(index as u64));
        self.gens = make_gens(&self.model_cfg, seed, self.plan.dp, self.plan.accum);
        Ok(())
    }

    /// Run the single-stage schedule implied by `cfg` (`cfg.steps` total;
    /// a restored trainer executes only the remainder).
    pub fn run(&mut self) -> Result<TrainReport> {
        let sched = TrainSchedule::single(&self.preset, &self.cfg);
        self.run_schedule(&sched)
    }

    /// Drive a (possibly multi-stage) [`TrainSchedule`] from the current
    /// position to the end; log and checkpoint per config.
    pub fn run_schedule(&mut self, sched: &TrainSchedule) -> Result<TrainReport> {
        let t0 = Instant::now();
        let wire_dp0 = self.wire_dp_bytes;
        let wire_dap0 = self.wire_dap_bytes;
        let comm0 = self.comm_seconds;
        let exposed0 = self.exposed_comm_seconds;
        let stall0 = self.prefetch_stall_seconds;
        let skipped0 = self.skipped_steps;
        let rec0 = self.recovery;
        let mut first = None;
        let mut last = 0.0;
        let mut executed = 0usize;
        'stages: while self.stage < sched.stages.len() {
            let stage = sched.stages[self.stage].clone();
            self.enter_stage(self.stage, &stage)?;
            while self.steps_in_stage < stage.steps {
                let loss = match self.train_step() {
                    Ok(loss) => loss,
                    Err(Error::RankLost { rank, step }) => {
                        // rollback may land in an earlier stage — rebind
                        // the stage from the restored schedule position
                        self.recover_from_rank_loss(rank, step)?;
                        continue 'stages;
                    }
                    Err(e) => return Err(e),
                };
                executed += 1;
                if first.is_none() {
                    first = Some(loss);
                }
                last = loss;
                if self.step % self.cfg.log_every.max(1) == 0 {
                    println!(
                        "step {:>5}  stage {}  loss {:.4}  lr {:.2e}",
                        self.step, stage.name, loss, self.last_lr
                    );
                }
                if let Some(dir) = &self.cfg.checkpoint_dir {
                    if self.step % self.cfg.checkpoint_every.max(1) == 0 {
                        self.save_checkpoint(dir)?;
                    }
                }
            }
            self.stage += 1;
            self.steps_in_stage = 0;
        }
        let seconds = t0.elapsed().as_secs_f64();
        let comm = self.comm_seconds - comm0;
        let exposed = self.exposed_comm_seconds - exposed0;
        let overlap_fraction = if comm > 0.0 {
            (1.0 - exposed / comm).clamp(0.0, 1.0)
        } else {
            1.0
        };
        Ok(TrainReport {
            steps: executed,
            final_loss: last,
            initial_loss: first.unwrap_or(f32::NAN),
            seconds,
            steps_per_sec: executed as f64 / seconds.max(1e-9),
            wire_bytes: self.wire_dp_bytes - wire_dp0,
            wire_dap_bytes: self.wire_dap_bytes - wire_dap0,
            threads: self.backend.effective_threads(self.plan.threads),
            final_lr: self.last_lr,
            precision: self.cfg.precision.name(),
            comm_seconds: comm,
            exposed_comm_seconds: exposed,
            overlap_fraction,
            prefetch_stall_seconds: self.prefetch_stall_seconds - stall0,
            skipped_steps: self.skipped_steps - skipped0,
            recovery: self.recovery.since(&rec0),
        })
    }
}

/// Whether a grad-phase failure is worth retrying: transient device
/// pressure or a timed-out collective — never a lost rank, a diverged
/// run, or a logic bug.
fn is_transient(e: &Error) -> bool {
    matches!(e, Error::SimOom { .. } | Error::CommTimeout { .. } | Error::Comm(_))
}

fn clip_by_global_norm(mut grads: Vec<HostTensor>, clip: f32) -> Vec<HostTensor> {
    let sq: f64 = grads
        .iter()
        .flat_map(|g| g.data().iter())
        .map(|&x| (x as f64) * (x as f64))
        .sum();
    let norm = sq.sqrt() as f32;
    if norm > clip && norm > 0.0 {
        let s = clip / norm;
        for g in grads.iter_mut() {
            g.scale(s);
        }
    }
    grads
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn clip_scales_down_only() {
        let big = vec![HostTensor::full(&[4], 10.0)];
        let out = clip_by_global_norm(big, 1.0);
        let norm: f32 = out[0].data().iter().map(|x| x * x).sum::<f32>().sqrt();
        assert!((norm - 1.0).abs() < 1e-5);
        let small = vec![HostTensor::full(&[4], 0.01)];
        let out = clip_by_global_norm(small.clone(), 1.0);
        assert_eq!(out[0].data(), small[0].data());
    }

    #[test]
    fn make_gens_offsets_the_global_stream() {
        let cfg = ModelConfig::tiny();
        // dp=2, accum=2: rank 1 starts at global index 2
        let gens = make_gens(&cfg, 11, 2, 2);
        assert_eq!(gens[0].cursor(), 0);
        assert_eq!(gens[1].cursor(), 2);
        let mut reference = DataGen::new(cfg, 11);
        reference.fast_forward(2);
        let mut g1 = gens.into_iter().nth(1).unwrap();
        assert_eq!(
            g1.next_batch().msa_tokens.data,
            reference.next_batch().msa_tokens.data
        );
    }
}
