//! Synthetic co-evolution data generator — the substitution for the
//! paper's proprietary training corpus (DESIGN.md §2).
//!
//! Recipe (mirrors python/compile/model.py::make_synthetic_batch): a random
//! ancestral sequence; MSA rows are mutated copies (15% substitution), so
//! column statistics carry real co-evolution-like signal for the
//! masked-MSA objective; a toy helix fold gives distance bins correlated
//! with |i−j| for the distogram objective. BERT-style masking at 15%.

use crate::config::ModelConfig;
use crate::rng::Rng;
use crate::tensor::{HostTensor, IntTensor};

pub struct Batch {
    pub msa_tokens: IntTensor,
    pub msa_labels: IntTensor,
    pub msa_mask: HostTensor,
    pub dist_bins: IntTensor,
}

/// Derive the RNG key for one batch of a stream: a splitmix64-style
/// finalizer over (stream seed, batch index), so batch `c` is a pure
/// function of `(seed, c)` — the property that makes
/// [`DataGen::fast_forward`] a counter bump instead of a replay.
fn batch_seed(seed: u64, cursor: u64) -> u64 {
    let mut z = seed ^ cursor.wrapping_mul(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

pub struct DataGen {
    pub cfg: ModelConfig,
    /// base stream seed — batch `cursor` draws from a fresh RNG keyed
    /// `(seed, cursor)`, never from carried sequential state
    seed: u64,
    pub mask_frac: f64,
    pub mutation_rate: f64,
    /// batches drawn so far (including [`DataGen::fast_forward`] skips) —
    /// the per-rank cursor the V2 checkpoint records
    cursor: u64,
}

impl DataGen {
    pub fn new(cfg: ModelConfig, seed: u64) -> Self {
        DataGen { cfg, seed, mask_frac: 0.15, mutation_rate: 0.15, cursor: 0 }
    }

    /// Rebuild a generator at an exact saved position (V2 checkpoint
    /// resume): the stream is counter-keyed, so restoring (seed, cursor)
    /// O(1) makes the next batch bit-for-bit the one an uninterrupted run
    /// would have drawn.
    pub fn from_state(cfg: ModelConfig, rng_state: (u64, u64), cursor: u64) -> Self {
        DataGen {
            cfg,
            seed: rng_state.0,
            mask_frac: 0.15,
            mutation_rate: 0.15,
            cursor,
        }
    }

    /// Batches drawn so far (including skips).
    pub fn cursor(&self) -> u64 {
        self.cursor
    }

    /// Snapshot the stream state (paired with [`DataGen::cursor`] in the
    /// V2 checkpoint): the base seed plus the cursor echoed into the
    /// second slot — the counter-keyed stream has no other RNG state.
    pub fn rng_state(&self) -> (u64, u64) {
        (self.seed, self.cursor)
    }

    /// Skip `k` batches in O(1). The hybrid trainer assigns one global
    /// batch stream replica-major — rank r consumes global indices
    /// `step·E + r·accum + a` (E = dp·accum) — so each rank skips the
    /// other ranks' draws every step; with the counter-keyed stream the
    /// skip is a cursor bump, not `(dp−1)·accum` regenerated batches.
    pub fn fast_forward(&mut self, k: usize) {
        self.cursor += k as u64;
    }

    pub fn next_batch(&mut self) -> Batch {
        let mut rng = Rng::new(batch_seed(self.seed, self.cursor));
        self.cursor += 1;
        let s = self.cfg.n_seq;
        let r = self.cfg.n_res;
        let aa = 20usize;
        let rng = &mut rng;

        let ancestor: Vec<i32> = (0..r).map(|_| rng.below(aa) as i32).collect();
        let mut msa = vec![0i32; s * r];
        msa[..r].copy_from_slice(&ancestor); // row 0 = target
        for row in 1..s {
            for i in 0..r {
                msa[row * r + i] = if rng.bernoulli(self.mutation_rate) {
                    rng.below(aa) as i32
                } else {
                    ancestor[i]
                };
            }
        }

        // toy fold: noisy helix; distance -> bins
        let mut coords = Vec::with_capacity(r);
        for i in 0..r {
            let t = i as f64;
            coords.push([
                (t * 0.6).cos() * 4.0 + rng.normal() * 0.3,
                (t * 0.6).sin() * 4.0 + rng.normal() * 0.3,
                t * 1.5 + rng.normal() * 0.3,
            ]);
        }
        let mut dmax: f64 = 1e-9;
        let mut dist = vec![0f64; r * r];
        for i in 0..r {
            for j in 0..r {
                let d: f64 = (0..3)
                    .map(|k| (coords[i][k] - coords[j][k]).powi(2))
                    .sum::<f64>()
                    .sqrt();
                dist[i * r + j] = d;
                dmax = dmax.max(d);
            }
        }
        let bins = self.cfg.n_dist_bins;
        let dist_bins: Vec<i32> = dist
            .iter()
            .map(|&d| ((d / (dmax / bins as f64)) as usize).min(bins - 1) as i32)
            .collect();

        // BERT masking
        let mask_tok = self.cfg.msa_vocab as i32 - 1;
        let mut tokens = msa.clone();
        let mut mask = vec![0f32; s * r];
        for i in 0..s * r {
            if rng.bernoulli(self.mask_frac) {
                tokens[i] = mask_tok;
                mask[i] = 1.0;
            }
        }

        Batch {
            msa_tokens: IntTensor::new(vec![s, r], tokens).unwrap(),
            msa_labels: IntTensor::new(vec![s, r], msa).unwrap(),
            msa_mask: HostTensor::new(vec![s, r], mask).unwrap(),
            dist_bins: IntTensor::new(vec![r, r], dist_bins).unwrap(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn batch_shapes_and_ranges() {
        let cfg = ModelConfig::tiny();
        let mut g = DataGen::new(cfg.clone(), 1);
        let b = g.next_batch();
        assert_eq!(b.msa_tokens.shape, vec![cfg.n_seq, cfg.n_res]);
        assert_eq!(b.dist_bins.shape, vec![cfg.n_res, cfg.n_res]);
        assert!(b.msa_tokens.data.iter().all(|&t| t >= 0 && t < cfg.msa_vocab as i32));
        assert!(b
            .dist_bins
            .data
            .iter()
            .all(|&t| t >= 0 && t < cfg.n_dist_bins as i32));
    }

    #[test]
    fn masking_consistent() {
        let mut g = DataGen::new(ModelConfig::tiny(), 2);
        let b = g.next_batch();
        let mask_tok = g.cfg.msa_vocab as i32 - 1;
        let mask = b.msa_mask.data();
        for (i, &mv) in mask.iter().enumerate() {
            if mv > 0.5 {
                assert_eq!(b.msa_tokens.data[i], mask_tok);
            } else {
                assert_eq!(b.msa_tokens.data[i], b.msa_labels.data[i]);
            }
        }
        let frac = mask.iter().sum::<f32>() / mask.len() as f32;
        assert!(frac > 0.05 && frac < 0.3, "mask frac {frac}");
    }

    #[test]
    fn coevolution_signal_present() {
        // columns should mostly agree with the target row (85% identity)
        let mut g = DataGen::new(ModelConfig::tiny(), 3);
        let b = g.next_batch();
        let (s, r) = (g.cfg.n_seq, g.cfg.n_res);
        let mut agree = 0usize;
        for row in 1..s {
            for i in 0..r {
                if b.msa_labels.data[row * r + i] == b.msa_labels.data[i] {
                    agree += 1;
                }
            }
        }
        let frac = agree as f64 / ((s - 1) * r) as f64;
        assert!(frac > 0.7, "identity {frac}");
    }

    #[test]
    fn deterministic_given_seed() {
        let mut a = DataGen::new(ModelConfig::tiny(), 7);
        let mut b = DataGen::new(ModelConfig::tiny(), 7);
        assert_eq!(a.next_batch().msa_tokens.data, b.next_batch().msa_tokens.data);
    }

    #[test]
    fn fast_forward_matches_discarded_draws() {
        let mut a = DataGen::new(ModelConfig::tiny(), 8);
        let mut b = DataGen::new(ModelConfig::tiny(), 8);
        for _ in 0..3 {
            a.next_batch();
        }
        b.fast_forward(3);
        assert_eq!(a.cursor(), b.cursor());
        assert_eq!(a.next_batch().msa_tokens.data, b.next_batch().msa_tokens.data);
    }

    #[test]
    fn fast_forward_is_constant_time_for_astronomical_skips() {
        // the counter-keyed stream makes a skip a cursor bump: a skip no
        // replaying implementation could ever finish must complete
        // instantly and leave the stream consistent with from_state
        let mut g = DataGen::new(ModelConfig::tiny(), 8);
        g.fast_forward(1 << 40);
        assert_eq!(g.cursor(), 1 << 40);
        let mut h =
            DataGen::from_state(ModelConfig::tiny(), g.rng_state(), g.cursor());
        assert_eq!(g.next_batch().msa_tokens.data, h.next_batch().msa_tokens.data);
    }

    #[test]
    fn interleaved_skips_match_contiguous_draws() {
        // cursor/state equivalence pin for the O(1) fast_forward: any mix
        // of draws and skips lands on the same per-batch streams
        let mut a = DataGen::new(ModelConfig::tiny(), 13);
        let mut b = DataGen::new(ModelConfig::tiny(), 13);
        // a: draw 0, skip 1-2, draw 3; b: draw 0-3 discarding 1-2
        let a0 = a.next_batch();
        a.fast_forward(2);
        let a3 = a.next_batch();
        let b0 = b.next_batch();
        b.next_batch();
        b.next_batch();
        let b3 = b.next_batch();
        assert_eq!(a0.msa_tokens.data, b0.msa_tokens.data);
        assert_eq!(a3.msa_tokens.data, b3.msa_tokens.data);
        assert_eq!(a3.dist_bins.data, b3.dist_bins.data);
        assert_eq!(a.cursor(), b.cursor());
        assert_eq!(a.rng_state(), b.rng_state());
    }

    #[test]
    fn state_restore_resumes_stream_bitwise() {
        let mut a = DataGen::new(ModelConfig::tiny(), 9);
        for _ in 0..5 {
            a.next_batch();
        }
        let mut b = DataGen::from_state(ModelConfig::tiny(), a.rng_state(), a.cursor());
        let (ba, bb) = (a.next_batch(), b.next_batch());
        assert_eq!(ba.msa_tokens.data, bb.msa_tokens.data);
        assert_eq!(ba.dist_bins.data, bb.dist_bins.data);
        assert_eq!(ba.msa_mask.data(), bb.msa_mask.data());
        assert_eq!(a.cursor(), b.cursor());
    }

    #[test]
    fn distogram_correlates_with_chain_distance() {
        let mut g = DataGen::new(ModelConfig::tiny(), 9);
        let b = g.next_batch();
        let r = g.cfg.n_res;
        // near-diagonal bins should be smaller than far-pair bins on average
        let mut near = 0f64;
        let mut far = 0f64;
        let (mut nn, mut nf) = (0, 0);
        for i in 0..r {
            for j in 0..r {
                let d = (i as i64 - j as i64).unsigned_abs() as usize;
                if d == 1 {
                    near += b.dist_bins.data[i * r + j] as f64;
                    nn += 1;
                } else if d > r / 2 {
                    far += b.dist_bins.data[i * r + j] as f64;
                    nf += 1;
                }
            }
        }
        assert!(near / nn as f64 <= far / nf as f64);
    }
}
