//! Per-replica step backends — how one micro-batch's (loss, gradients)
//! and the Adam update are actually computed.
//!
//! The trainer orchestrates the hybrid DP×DAP layout (data routing,
//! gradient accumulation, the DP ring all-reduce, checkpoints); *what* a
//! replica executes is behind [`TrainBackend`]:
//!
//! * [`DenseBackend`] — the `dap = 1` path: the monolithic PJRT
//!   `grad_step` executable, replicas fanned over the rank-executor
//!   threads exactly like the pre-hybrid trainer.
//! * [`HybridDapBackend`] — the `dap > 1` path: embed → DAP block
//!   forwards through the coordinator (tape recording on) → heads+loss
//!   VJP → reverse block replay through [`crate::dap::Tape`] (sharded
//!   grads summed over the DAP group per replica) → embed VJP. Model-
//!   parallel collective volume is read off the coordinator's comm log so
//!   the trainer can account DAP wire separately from DP wire.
//! * [`SyntheticBackend`] — a pure-host stand-in (no artifacts, no PJRT):
//!   integer-grid gradients derived from the batch plus a host Adam.
//!   This is the construction seam the hybrid equivalence suite and the
//!   CI train smoke use, mirroring `SegmentRunner` / `BackendFactory`.

use super::data::Batch;
use super::plan::ParallelPlan;
use crate::dap::executor::parallel_ranks;
use crate::dap::DapCoordinator;
use crate::error::{Error, Result};
use crate::runtime::{Executable, Runtime, Value};
use crate::tensor::HostTensor;
use std::cell::Cell;
use std::sync::Arc;

/// (loss, full-model gradient leaves in canonical order).
pub type GradOut = (f32, Vec<HostTensor>);

/// Updated (params, m, v) after one Adam step.
pub type AdamOut = (Vec<HostTensor>, Vec<HostTensor>, Vec<HostTensor>);

/// Where a streamed backward delivers results as they are produced —
/// the seam the bucketed, overlapped DP all-reduce hangs off: each leaf
/// gradient is handed over the moment the backward computes it, so a
/// bucket's cross-replica reduction can launch while the rest of the
/// reverse pass is still running. Implementations are shared across
/// backend worker threads, so methods take `&self` and must be
/// internally synchronized.
pub trait GradSink: Sync {
    /// This micro-batch's scalar loss.
    fn emit_loss(&self, batch_idx: usize, loss: f32);
    /// One leaf gradient for micro-batch `batch_idx`, delivered in the
    /// backend's [`TrainBackend::backward_leaf_order`] within the batch.
    fn emit_grad(&self, batch_idx: usize, leaf: usize, grad: HostTensor);
}

/// Emit one micro-batch's materialized gradients into `sink` following
/// `order` (any leaf `order` misses is still delivered, at the end).
fn emit_in_order(
    sink: &dyn GradSink,
    batch_idx: usize,
    grads: Vec<HostTensor>,
    order: &[usize],
) {
    let mut slots: Vec<Option<HostTensor>> = grads.into_iter().map(Some).collect();
    for &leaf in order {
        if let Some(g) = slots.get_mut(leaf).and_then(|s| s.take()) {
            sink.emit_grad(batch_idx, leaf, g);
        }
    }
    for (leaf, s) in slots.iter_mut().enumerate() {
        if let Some(g) = s.take() {
            sink.emit_grad(batch_idx, leaf, g);
        }
    }
}

/// Computes a replica's forward/backward and the optimizer update.
pub trait TrainBackend {
    /// Short name for logs/reports ("dense", "dap4", "synthetic").
    fn name(&self) -> String;

    /// Loss + full-model gradients for one micro-batch.
    fn grad(&self, params: &[HostTensor], batch: &Batch) -> Result<GradOut>;

    /// Map [`TrainBackend::grad`] over independent micro-batches. The
    /// default runs sequentially; backends that are `Sync` may fan out
    /// over `threads` (results MUST come back in batch order — the
    /// trainer's gradient fold depends on it).
    fn grad_many(
        &self,
        params: &[HostTensor],
        batches: &[Batch],
        threads: usize,
    ) -> Result<Vec<GradOut>> {
        let _ = threads;
        batches.iter().map(|b| self.grad(params, b)).collect()
    }

    /// Leaf indices in the order the backward pass finishes computing
    /// them — the order a streamed backward hands gradients to a
    /// [`GradSink`], and the order the bucketed DP all-reduce packs its
    /// buckets so each bucket closes (and its ring reduction launches)
    /// as early as possible. The default is plain reverse canonical
    /// order; backends with structure (heads → blocks reversed → embed)
    /// override with their true completion order. Must be a permutation
    /// of `0..n_leaves`.
    fn backward_leaf_order(&self, n_leaves: usize) -> Vec<usize> {
        (0..n_leaves).rev().collect()
    }

    /// Stream each micro-batch's loss and per-leaf gradients into `sink`
    /// as they become available, instead of materializing a full
    /// `Vec<GradOut>` first. Within one micro-batch gradients arrive in
    /// [`TrainBackend::backward_leaf_order`]; micro-batches may
    /// interleave arbitrarily (the sink keys on `batch_idx`). The
    /// default computes each micro-batch with [`TrainBackend::grad`] and
    /// emits it before starting the next, so overlap-aware callers see
    /// per-batch streaming on any backend.
    fn grad_many_streamed(
        &self,
        params: &[HostTensor],
        batches: &[Batch],
        threads: usize,
        sink: &dyn GradSink,
    ) -> Result<()> {
        let _ = threads;
        let order = self.backward_leaf_order(params.len());
        for (i, b) in batches.iter().enumerate() {
            let (loss, grads) = self.grad(params, b)?;
            sink.emit_loss(i, loss);
            emit_in_order(sink, i, grads, &order);
        }
        Ok(())
    }

    /// One Adam update at (1-based) `step` with learning rate `lr`.
    fn adam(
        &self,
        step: usize,
        lr: f32,
        params: &[HostTensor],
        grads: &[HostTensor],
        m: &[HostTensor],
        v: &[HostTensor],
    ) -> Result<AdamOut>;

    /// Model-parallel (DAP) wire bytes moved since the last call
    /// (0 for backends without model parallelism).
    fn take_mp_wire_bytes(&self) -> usize {
        0
    }

    /// The thread budget this backend actually runs with when the trainer
    /// requests `requested` threads. The DAP backend bound its budget to
    /// the coordinator at construction, so a later `with_threads`
    /// override does not reach it — reports stay honest by asking.
    fn effective_threads(&self, requested: usize) -> usize {
        requested
    }
}

/// Canonical batch flatten order: dict keys sorted by jax =
/// dist_bins, msa_labels, msa_mask, msa_tokens.
pub(crate) fn batch_values(b: &Batch) -> Vec<Value> {
    vec![
        b.dist_bins.clone().into(),
        b.msa_labels.clone().into(),
        b.msa_mask.clone().into(),
        b.msa_tokens.clone().into(),
    ]
}

fn adam_via_exe(
    exe: &Executable,
    step: usize,
    lr: f32,
    params: &[HostTensor],
    grads: &[HostTensor],
    m: &[HostTensor],
    v: &[HostTensor],
) -> Result<AdamOut> {
    let n = params.len();
    let mut args: Vec<Value> = Vec::with_capacity(4 * n + 2);
    args.extend(params.iter().cloned().map(Value::F32));
    args.extend(grads.iter().cloned().map(Value::F32));
    args.extend(m.iter().cloned().map(Value::F32));
    args.extend(v.iter().cloned().map(Value::F32));
    args.push(Value::F32(HostTensor::scalar(step as f32)));
    args.push(Value::F32(HostTensor::scalar(lr)));
    let out = exe.run(&args)?;
    let (p2, rest) = out.split_at(n);
    let (m2, v2) = rest.split_at(n);
    Ok((p2.to_vec(), m2.to_vec(), v2.to_vec()))
}

/// Build the backend a [`ParallelPlan`] calls for: dense at `dap = 1`,
/// the DAP coordinator path at `dap > 1`.
pub fn build_backend<'rt>(
    rt: &'rt Runtime,
    preset: &str,
    plan: &ParallelPlan,
    overlap: bool,
) -> Result<Box<dyn TrainBackend + 'rt>> {
    if plan.dap > 1 {
        Ok(Box::new(HybridDapBackend::new(
            rt,
            preset,
            plan.dap,
            overlap,
            plan.threads,
        )?))
    } else {
        Ok(Box::new(DenseBackend::new(rt, preset)?))
    }
}

// ------------------------------------------------------------------ dense

/// `dap = 1`: the monolithic `grad_step` + `adam_update` executables.
pub struct DenseBackend {
    grad_exe: Arc<Executable>,
    adam_exe: Arc<Executable>,
}

impl DenseBackend {
    /// Load the preset's training executables.
    pub fn new(rt: &Runtime, preset: &str) -> Result<Self> {
        Ok(DenseBackend {
            grad_exe: rt.load(&format!("{preset}/grad_step"))?,
            adam_exe: rt.load(&format!("{preset}/adam_update"))?,
        })
    }
}

impl TrainBackend for DenseBackend {
    fn name(&self) -> String {
        "dense".into()
    }

    fn grad(&self, params: &[HostTensor], batch: &Batch) -> Result<GradOut> {
        let mut args: Vec<Value> =
            params.iter().cloned().map(Value::F32).collect();
        args.extend(batch_values(batch));
        let out = self.grad_exe.run(&args)?;
        // outputs: loss scalar, then grads in canonical order
        Ok((out[0].data()[0], out[1..].to_vec()))
    }

    fn grad_many(
        &self,
        params: &[HostTensor],
        batches: &[Batch],
        threads: usize,
    ) -> Result<Vec<GradOut>> {
        // independent micro-batches fan out over the rank-executor
        // threads; results join in batch order (bit-for-bit vs threads=1)
        parallel_ranks(threads, batches.len(), |i| self.grad(params, &batches[i]))
    }

    fn grad_many_streamed(
        &self,
        params: &[HostTensor],
        batches: &[Batch],
        threads: usize,
        sink: &dyn GradSink,
    ) -> Result<()> {
        // same fan-out as grad_many, but each worker hands its batch to
        // the sink the moment it finishes instead of joining first
        let order = self.backward_leaf_order(params.len());
        parallel_ranks(threads, batches.len(), |i| {
            let (loss, grads) = self.grad(params, &batches[i])?;
            sink.emit_loss(i, loss);
            emit_in_order(sink, i, grads, &order);
            Ok(())
        })
        .map(|_| ())
    }

    fn adam(
        &self,
        step: usize,
        lr: f32,
        params: &[HostTensor],
        grads: &[HostTensor],
        m: &[HostTensor],
        v: &[HostTensor],
    ) -> Result<AdamOut> {
        adam_via_exe(&self.adam_exe, step, lr, params, grads, m, v)
    }
}

// ----------------------------------------------------------------- hybrid

/// `dap > 1`: the replica's forward/backward runs through the DAP
/// coordinator and tape; parameters stay replicated, activations are
/// sharded, per-leaf gradients are summed over the DAP group.
pub struct HybridDapBackend<'rt> {
    co: DapCoordinator<'rt>,
    embed_exe: Arc<Executable>,
    loss_head_grad_exe: Arc<Executable>,
    embed_bwd_exe: Arc<Executable>,
    adam_exe: Arc<Executable>,
    embed_idx: Vec<usize>,
    head_idx: Vec<usize>,
    block_idx: Vec<Vec<usize>>,
    wire_mark: Cell<usize>,
}

fn load_or_hint(rt: &Runtime, key: &str) -> Result<Arc<Executable>> {
    if !rt.manifest.artifacts.contains_key(key) {
        return Err(Error::Manifest(format!(
            "hybrid training needs the '{key}' executable — regenerate \
             artifacts (`make artifacts`) with the current exporter, which \
             emits the heads/loss and embed VJPs"
        )));
    }
    rt.load(key)
}

impl<'rt> HybridDapBackend<'rt> {
    /// Load the coordinator plus the trunk-boundary VJP executables for
    /// `preset` at DAP degree `dap`.
    pub fn new(
        rt: &'rt Runtime,
        preset: &str,
        dap: usize,
        overlap: bool,
        threads: usize,
    ) -> Result<Self> {
        let co = DapCoordinator::new(rt, preset, dap, overlap)?.with_threads(threads);
        if !co.has_backward() {
            return Err(Error::Manifest(format!(
                "preset '{preset}' has no dap{dap} backward (VJP) segment \
                 executables — export with backward enabled for hybrid \
                 training"
            )));
        }
        let embed_exe = rt.load(&format!("{preset}/embed"))?;
        let loss_head_grad_exe = load_or_hint(rt, &format!("{preset}/loss_head_grad"))?;
        let embed_bwd_exe = load_or_hint(rt, &format!("{preset}/embed_bwd"))?;
        let adam_exe = rt.load(&format!("{preset}/adam_update"))?;
        let man = &rt.manifest;
        let embed_idx = man.leaf_indices_with_prefix(preset, "embedder/")?;
        let head_idx = man.leaf_indices_with_prefix(preset, "heads/")?;
        let block_idx: Vec<Vec<usize>> = (0..co.cfg.n_blocks)
            .map(|b| man.block_leaf_indices(preset, b))
            .collect::<Result<_>>()?;
        Ok(HybridDapBackend {
            co,
            embed_exe,
            loss_head_grad_exe,
            embed_bwd_exe,
            adam_exe,
            embed_idx,
            head_idx,
            block_idx,
            wire_mark: Cell::new(0),
        })
    }

    /// The coordinator's DAP degree.
    pub fn dap(&self) -> usize {
        self.co.n
    }

    /// Shared forward/backward body: run the replica and hand each leaf
    /// gradient to `emit` at the point the backward produces it — head
    /// leaves right after the loss/head VJP, each block's leaves as its
    /// reverse tape replay completes (deepest block first), embedder
    /// leaves last. Returns the loss and how many leaves were emitted.
    fn grad_emit(
        &self,
        params: &[HostTensor],
        batch: &Batch,
        emit: &mut dyn FnMut(usize, HostTensor),
    ) -> Result<(f32, usize)> {
        let co = &self.co;
        let mut emitted = 0usize;

        // embed (replicated)
        let mut args: Vec<Value> = self
            .embed_idx
            .iter()
            .map(|&i| params[i].clone().into())
            .collect();
        args.push(batch.msa_tokens.clone().into());
        let out = self.embed_exe.run(&args)?;
        let (m0, z0) = (out[0].clone(), out[1].clone());

        // trunk forward under DAP, recording one tape per block
        *co.record.borrow_mut() = true;
        let mut state = co.shard_inputs(&m0, &z0)?;
        let mut tapes = Vec::with_capacity(co.cfg.n_blocks);
        let mut block_params = Vec::with_capacity(co.cfg.n_blocks);
        for idx in &self.block_idx {
            let bp: Vec<HostTensor> = idx.iter().map(|&i| params[i].clone()).collect();
            if let Err(e) = co.block_forward(&bp, &mut state) {
                *co.record.borrow_mut() = false;
                return Err(e);
            }
            tapes.push(std::mem::take(&mut *co.tape.borrow_mut()));
            block_params.push(bp);
        }
        *co.record.borrow_mut() = false;
        let (m, z) = co.unshard(&state)?;

        // heads + trunk losses, with cotangents w.r.t. (head params, m, z)
        let mut args: Vec<Value> = self
            .head_idx
            .iter()
            .map(|&i| params[i].clone().into())
            .collect();
        args.push(m.into());
        args.push(z.into());
        args.extend(batch_values(batch));
        let out = self.loss_head_grad_exe.run(&args)?;
        let nh = self.head_idx.len();
        let loss = out[0].data()[0];
        for (k, &i) in self.head_idx.iter().enumerate() {
            emit(i, out[1 + k].clone());
            emitted += 1;
        }
        let d_m = out[1 + nh].clone();
        let d_z = out[2 + nh].clone();

        // reverse block replay: shard the cotangents like the activations,
        // walk blocks backward, summing each leaf over the DAP group —
        // each block's grads stream out the moment its replay completes
        // (the bucketed DP all-reduce launch points)
        let mut d_state = co.shard_inputs(&d_m, &d_z)?;
        for b in (0..self.block_idx.len()).rev() {
            let bg = co.block_backward_with(
                std::mem::take(&mut tapes[b]),
                &block_params[b],
                &mut d_state,
            )?;
            if bg.len() != self.block_idx[b].len() {
                return Err(Error::Schedule(format!(
                    "block {b} backward returned {} grads, expected {}",
                    bg.len(),
                    self.block_idx[b].len()
                )));
            }
            for (g, &i) in bg.into_iter().zip(self.block_idx[b].iter()) {
                emit(i, g);
                emitted += 1;
            }
        }
        let (d_m0, d_z0) = co.unshard(&d_state)?;

        // embed VJP
        let mut args: Vec<Value> = self
            .embed_idx
            .iter()
            .map(|&i| params[i].clone().into())
            .collect();
        args.push(batch.msa_tokens.clone().into());
        args.push(d_m0.into());
        args.push(d_z0.into());
        let out = self.embed_bwd_exe.run(&args)?;
        for (k, &i) in self.embed_idx.iter().enumerate() {
            emit(i, out[k].clone());
            emitted += 1;
        }
        Ok((loss, emitted))
    }
}

impl TrainBackend for HybridDapBackend<'_> {
    fn name(&self) -> String {
        format!("dap{}", self.co.n)
    }

    fn grad(&self, params: &[HostTensor], batch: &Batch) -> Result<GradOut> {
        let mut grads: Vec<Option<HostTensor>> = vec![None; params.len()];
        let (loss, _emitted) =
            self.grad_emit(params, batch, &mut |i, g| grads[i] = Some(g))?;
        let grads: Vec<HostTensor> = grads
            .into_iter()
            .enumerate()
            .map(|(i, g)| {
                g.ok_or_else(|| {
                    Error::Manifest(format!(
                        "leaf {i} received no gradient (not an embedder/ \
                         blocks/ heads/ leaf?)"
                    ))
                })
            })
            .collect::<Result<_>>()?;
        Ok((loss, grads))
    }

    fn backward_leaf_order(&self, n_leaves: usize) -> Vec<usize> {
        // the true completion order of grad_emit: heads, blocks deepest
        // block first, embedder last
        let mut order = Vec::with_capacity(n_leaves);
        order.extend(self.head_idx.iter().copied());
        for idx in self.block_idx.iter().rev() {
            order.extend(idx.iter().copied());
        }
        order.extend(self.embed_idx.iter().copied());
        order
    }

    fn grad_many_streamed(
        &self,
        params: &[HostTensor],
        batches: &[Batch],
        _threads: usize,
        sink: &dyn GradSink,
    ) -> Result<()> {
        // replicas run sequentially (the coordinator owns the thread
        // budget inside each block); gradients still stream per block,
        // so bucket reductions overlap the remaining reverse replay
        for (i, b) in batches.iter().enumerate() {
            let (loss, emitted) =
                self.grad_emit(params, b, &mut |leaf, g| sink.emit_grad(i, leaf, g))?;
            if emitted != params.len() {
                return Err(Error::Manifest(format!(
                    "streamed backward emitted {emitted} leaf grads, model \
                     has {}",
                    params.len()
                )));
            }
            sink.emit_loss(i, loss);
        }
        Ok(())
    }

    fn adam(
        &self,
        step: usize,
        lr: f32,
        params: &[HostTensor],
        grads: &[HostTensor],
        m: &[HostTensor],
        v: &[HostTensor],
    ) -> Result<AdamOut> {
        adam_via_exe(&self.adam_exe, step, lr, params, grads, m, v)
    }

    fn take_mp_wire_bytes(&self) -> usize {
        let total = self.co.comm.log.lock().unwrap().total_bytes();
        let prev = self.wire_mark.replace(total);
        total.saturating_sub(prev)
    }

    fn effective_threads(&self, _requested: usize) -> usize {
        // the coordinator's budget was fixed at construction; replicas
        // run sequentially with the rank fan-out inside each block
        self.co.threads
    }
}

// -------------------------------------------------------------- synthetic

/// Host Adam, element-for-element the formula of the exported
/// `adam_update` executable (`python/compile/aot.py`), executed per leaf
/// through the active [`crate::device`] backend's fused
/// single-traversal kernel — bit-for-bit the old three-clone loop on
/// every backend (the Adam update is purely elementwise), one
/// copy-on-write per state tensor instead of three eager clones plus an
/// index loop.
pub fn host_adam(
    step: usize,
    lr: f32,
    params: &[HostTensor],
    grads: &[HostTensor],
    m: &[HostTensor],
    v: &[HostTensor],
) -> Result<AdamOut> {
    let mut p2 = Vec::with_capacity(params.len());
    let mut m2 = Vec::with_capacity(params.len());
    let mut v2 = Vec::with_capacity(params.len());
    for (((p, g), mm), vv) in params.iter().zip(grads).zip(m).zip(v) {
        if p.shape != g.shape {
            return Err(Error::Shape(format!(
                "adam: param {:?} vs grad {:?}",
                p.shape, g.shape
            )));
        }
        let mut pn = p.clone();
        let mut mn = mm.clone();
        let mut vn = vv.clone();
        crate::device::adam_update_tensors(step, lr, &mut pn, g, &mut mn, &mut vn);
        p2.push(pn);
        m2.push(mn);
        v2.push(vn);
    }
    Ok((p2, m2, v2))
}

/// Pure-host backend: no artifacts, no PJRT. Gradients are **integer-grid**
/// functions of the batch alone (token sums scaled by a power of two), so
/// every partition of the same micro-batch stream — any `(dp, dap, accum)`
/// split — folds to bit-for-bit identical global gradients; the loss is
/// `⟨params, grads⟩`, so parameters still enter the reported loss. `dap`
/// is *simulated* here: each leaf gradient is computed as per-shard
/// partial sums over contiguous MSA-row blocks folded in rank order,
/// exercising the same shard-then-sum contract as the real DAP tape.
pub struct SyntheticBackend {
    dap: usize,
    /// power-of-two gradient scale (keeps grads exactly representable)
    scale: f32,
}

impl SyntheticBackend {
    /// A synthetic backend simulating DAP degree `dap` (>= 1).
    pub fn new(dap: usize) -> Self {
        SyntheticBackend { dap: dap.max(1), scale: 1.0 / 256.0 }
    }

    /// Deterministic parameter leaves for a preset — integer-grid values,
    /// shapes derived from the model dims (a stand-in for the exported
    /// `*_params.bin` when running artifact-free).
    pub fn init_params(cfg: &crate::config::ModelConfig) -> Vec<HostTensor> {
        let shapes: Vec<Vec<usize>> = vec![
            vec![cfg.d_msa],
            vec![cfg.d_pair, 4],
            vec![cfg.n_heads_msa, cfg.d_head],
            vec![cfg.d_opm, 2],
            vec![cfg.n_dist_bins],
            vec![1],
        ];
        shapes
            .into_iter()
            .enumerate()
            .map(|(j, shape)| {
                let n: usize = shape.iter().product();
                let data: Vec<f32> = (0..n)
                    .map(|i| ((i * 7 + j * 3) % 13) as f32 / 8.0 - 0.75)
                    .collect();
                HostTensor::new(shape, data).expect("static shapes")
            })
            .collect()
    }
}

impl TrainBackend for SyntheticBackend {
    fn name(&self) -> String {
        if self.dap > 1 {
            format!("synthetic-dap{}", self.dap)
        } else {
            "synthetic".into()
        }
    }

    fn grad(&self, params: &[HostTensor], batch: &Batch) -> Result<GradOut> {
        let toks = &batch.msa_tokens.data;
        let rows = batch.msa_tokens.shape[0];
        let cols = batch.msa_tokens.shape[1];
        // contiguous row shards (remainder on the last shard)
        let dap = self.dap.min(rows.max(1));
        let base = rows / dap;
        let mut grads = Vec::with_capacity(params.len());
        let mut loss_acc = 0.0f64;
        for (j, p) in params.iter().enumerate() {
            let pd = p.data();
            let n = pd.len();
            let mut g = Vec::with_capacity(n);
            for i in 0..n {
                let col = (i + j) % cols;
                // per-shard integer partial sums, folded in rank order —
                // exact in f32, so the fold order (and hence `dap`) never
                // changes the bits
                let mut total = 0.0f32;
                for k in 0..dap {
                    let lo = k * base;
                    let hi = if k == dap - 1 { rows } else { (k + 1) * base };
                    let mut part = 0.0f32;
                    for row in lo..hi {
                        part += (toks[row * cols + col] - 11) as f32;
                    }
                    total += part;
                }
                let gi = total * self.scale;
                loss_acc += pd[i] as f64 * gi as f64;
                g.push(gi);
            }
            grads.push(HostTensor::new(p.shape.clone(), g)?);
        }
        Ok((loss_acc as f32, grads))
    }

    fn grad_many(
        &self,
        params: &[HostTensor],
        batches: &[Batch],
        threads: usize,
    ) -> Result<Vec<GradOut>> {
        parallel_ranks(threads, batches.len(), |i| self.grad(params, &batches[i]))
    }

    fn grad_many_streamed(
        &self,
        params: &[HostTensor],
        batches: &[Batch],
        threads: usize,
        sink: &dyn GradSink,
    ) -> Result<()> {
        let order = self.backward_leaf_order(params.len());
        parallel_ranks(threads, batches.len(), |i| {
            let (loss, grads) = self.grad(params, &batches[i])?;
            sink.emit_loss(i, loss);
            emit_in_order(sink, i, grads, &order);
            Ok(())
        })
        .map(|_| ())
    }

    fn adam(
        &self,
        step: usize,
        lr: f32,
        params: &[HostTensor],
        grads: &[HostTensor],
        m: &[HostTensor],
        v: &[HostTensor],
    ) -> Result<AdamOut> {
        host_adam(step, lr, params, grads, m, v)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelConfig;
    use crate::train::DataGen;

    #[test]
    fn synthetic_grads_are_dap_invariant_bitwise() {
        let cfg = ModelConfig::tiny();
        let params = SyntheticBackend::init_params(&cfg);
        let batch = DataGen::new(cfg.clone(), 3).next_batch();
        let (l1, g1) = SyntheticBackend::new(1).grad(&params, &batch).unwrap();
        for dap in [2usize, 4, 8] {
            let (l, g) = SyntheticBackend::new(dap).grad(&params, &batch).unwrap();
            assert_eq!(l.to_bits(), l1.to_bits(), "dap={dap} loss");
            assert_eq!(g, g1, "dap={dap} grads");
        }
    }

    #[test]
    fn synthetic_grad_many_is_thread_invariant() {
        let cfg = ModelConfig::tiny();
        let params = SyntheticBackend::init_params(&cfg);
        let mut gen = DataGen::new(cfg.clone(), 4);
        let batches: Vec<_> = (0..5).map(|_| gen.next_batch()).collect();
        let be = SyntheticBackend::new(2);
        let seq = be.grad_many(&params, &batches, 1).unwrap();
        let thr = be.grad_many(&params, &batches, 4).unwrap();
        assert_eq!(seq.len(), thr.len());
        for ((ls, gs), (lt, gt)) in seq.iter().zip(thr.iter()) {
            assert_eq!(ls.to_bits(), lt.to_bits());
            assert_eq!(gs, gt);
        }
    }

    struct RecordSink {
        state: std::sync::Mutex<RecordInner>,
    }

    struct RecordInner {
        losses: Vec<Option<f32>>,
        grads: Vec<Vec<Option<HostTensor>>>,
        arrival: Vec<Vec<usize>>,
    }

    impl RecordSink {
        fn new(batches: usize, leaves: usize) -> Self {
            RecordSink {
                state: std::sync::Mutex::new(RecordInner {
                    losses: vec![None; batches],
                    grads: vec![vec![None; leaves]; batches],
                    arrival: vec![Vec::new(); batches],
                }),
            }
        }
    }

    impl GradSink for RecordSink {
        fn emit_loss(&self, batch_idx: usize, loss: f32) {
            self.state.lock().unwrap().losses[batch_idx] = Some(loss);
        }
        fn emit_grad(&self, batch_idx: usize, leaf: usize, grad: HostTensor) {
            let mut st = self.state.lock().unwrap();
            assert!(st.grads[batch_idx][leaf].is_none(), "duplicate leaf emit");
            st.grads[batch_idx][leaf] = Some(grad);
            st.arrival[batch_idx].push(leaf);
        }
    }

    #[test]
    fn streamed_grads_match_grad_many_bitwise() {
        let cfg = ModelConfig::tiny();
        let params = SyntheticBackend::init_params(&cfg);
        let mut gen = DataGen::new(cfg.clone(), 9);
        let batches: Vec<_> = (0..4).map(|_| gen.next_batch()).collect();
        let be = SyntheticBackend::new(2);
        let reference = be.grad_many(&params, &batches, 1).unwrap();
        let order = be.backward_leaf_order(params.len());
        for threads in [1usize, 4] {
            let sink = RecordSink::new(batches.len(), params.len());
            be.grad_many_streamed(&params, &batches, threads, &sink).unwrap();
            let st = sink.state.into_inner().unwrap();
            for (i, (l, gs)) in reference.iter().enumerate() {
                assert_eq!(st.losses[i].unwrap().to_bits(), l.to_bits());
                for (j, g) in gs.iter().enumerate() {
                    assert_eq!(st.grads[i][j].as_ref().unwrap(), g);
                }
                // within a batch, leaves arrive in backward order
                assert_eq!(st.arrival[i], order, "threads={threads} batch {i}");
            }
        }
    }

    #[test]
    fn host_adam_moves_against_gradient() {
        let p = vec![HostTensor::full(&[4], 1.0)];
        let g = vec![HostTensor::full(&[4], 0.5)];
        let m = vec![HostTensor::zeros(&[4])];
        let v = vec![HostTensor::zeros(&[4])];
        let (p2, m2, v2) = host_adam(1, 0.1, &p, &g, &m, &v).unwrap();
        assert!(p2[0].data()[0] < 1.0);
        assert!(m2[0].data()[0] > 0.0);
        assert!(v2[0].data()[0] > 0.0);
        // deterministic
        let (p3, _, _) = host_adam(1, 0.1, &p, &g, &m, &v).unwrap();
        assert_eq!(p2, p3);
    }

    #[test]
    fn host_adam_shape_mismatch_rejected() {
        let p = vec![HostTensor::full(&[4], 1.0)];
        let g = vec![HostTensor::full(&[2], 0.5)];
        let m = vec![HostTensor::zeros(&[4])];
        let v = vec![HostTensor::zeros(&[4])];
        assert!(host_adam(1, 0.1, &p, &g, &m, &v).is_err());
    }
}
