//! Double-buffered data prefetch: generate step N+1's micro-batches
//! while step N computes (paper §V.A — the input pipeline must never be
//! the reason an accelerator idles).
//!
//! A producer thread owns a private copy of the per-rank [`DataGen`]s
//! (rebuilt from the trainer's exact `(rng_state, cursor)` snapshots —
//! the counter-keyed stream makes that a pure O(1) restore) and runs the
//! *same* replica-major draw loop the trainer runs inline, pushing one
//! [`StepBatches`] per optimizer step through a capacity-1 channel: one
//! step buffered, one being generated — classic double buffering. Each
//! payload carries the post-draw cursors and RNG states, and the trainer
//! adopts them into its own generators on receipt, so `Trainer::state()`
//! (the V2 checkpoint) is bit-for-bit identical with prefetch on or off,
//! and a resume under prefetch replays the exact uninterrupted stream.
//!
//! The consumer side records how long each `recv` blocked — the
//! **prefetch stall** ledger surfaced in `BENCH_train.json`: with the
//! pipeline keeping up, stalls are ~0 and the input path is fully
//! hidden behind compute.

use super::data::{Batch, DataGen};
use crate::config::ModelConfig;
use crate::error::{Error, Result};
use std::sync::mpsc::{sync_channel, Receiver};
use std::thread::JoinHandle;
use std::time::Instant; // lint:allow(wallclock) — prefetch stall ledger

/// One optimizer step's worth of input, plus the generator state after
/// drawing it (what the trainer's checkpoint must record).
pub struct StepBatches {
    /// The replica-major effective batch (`dp × accum` micro-batches).
    pub batches: Vec<Batch>,
    /// Per-rank cursors *after* this step's draws and skips.
    pub cursors: Vec<u64>,
    /// Per-rank RNG states *after* this step's draws and skips.
    pub rng_states: Vec<(u64, u64)>,
}

/// The double-buffered producer handle the trainer owns while
/// `--prefetch` is on. Dropping it tears the producer thread down
/// (the in-flight step is discarded; the trainer's own generators are
/// the source of truth for where the stream is).
pub struct Prefetcher {
    rx: Option<Receiver<StepBatches>>,
    handle: Option<JoinHandle<()>>,
    stall_seconds: f64,
    steps: usize,
}

impl Prefetcher {
    /// Start a producer at the exact stream position of `gens`, drawing
    /// `accum` micro-batches per rank per step (the replica-major loop,
    /// including each rank's skip over the other ranks' slice).
    pub fn start(cfg: &ModelConfig, gens: &[DataGen], accum: usize) -> Self {
        let dp = gens.len().max(1);
        let accum = accum.max(1);
        let snaps: Vec<((u64, u64), u64)> =
            gens.iter().map(|g| (g.rng_state(), g.cursor())).collect();
        let cfg = cfg.clone();
        let (tx, rx) = sync_channel::<StepBatches>(1);
        let handle = std::thread::spawn(move || {
            let mut gens: Vec<DataGen> = snaps
                .into_iter()
                .map(|(rs, c)| DataGen::from_state(cfg.clone(), rs, c))
                .collect();
            loop {
                let mut batches = Vec::with_capacity(dp * accum);
                for g in gens.iter_mut() {
                    for _ in 0..accum {
                        batches.push(g.next_batch());
                    }
                    g.fast_forward((dp - 1) * accum);
                }
                let step = StepBatches {
                    batches,
                    cursors: gens.iter().map(|g| g.cursor()).collect(),
                    rng_states: gens.iter().map(|g| g.rng_state()).collect(),
                };
                // consumer gone (trainer dropped the prefetcher): exit
                if tx.send(step).is_err() {
                    break;
                }
            }
        });
        Prefetcher { rx: Some(rx), handle: Some(handle), stall_seconds: 0.0, steps: 0 }
    }

    /// The next step's effective batch, blocking if the producer is
    /// behind; the blocked time lands in the stall ledger.
    pub fn next_step(&mut self) -> Result<StepBatches> {
        let rx = self
            .rx
            .as_ref()
            .ok_or_else(|| Error::msg("prefetcher already shut down"))?;
        let t = Instant::now();
        let step = rx
            .recv()
            .map_err(|_| Error::msg("prefetch producer thread exited"))?;
        self.stall_seconds += t.elapsed().as_secs_f64();
        self.steps += 1;
        Ok(step)
    }

    /// Cumulative wall seconds `next_step` spent blocked on the producer.
    pub fn stall_seconds(&self) -> f64 {
        self.stall_seconds
    }

    /// Drain the stall ledger (the trainer folds it into its cumulative
    /// counter after every step, so nothing is lost when a stage switch
    /// replaces the prefetcher).
    pub fn take_stall_seconds(&mut self) -> f64 {
        std::mem::replace(&mut self.stall_seconds, 0.0)
    }

    /// Steps consumed through this prefetcher.
    pub fn steps(&self) -> usize {
        self.steps
    }
}

impl Drop for Prefetcher {
    fn drop(&mut self) {
        // drop the receiver first so a producer blocked in `send` errors
        // out instead of deadlocking the join
        self.rx.take();
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mk_gens(cfg: &ModelConfig, seed: u64, dp: usize, accum: usize) -> Vec<DataGen> {
        (0..dp)
            .map(|r| {
                let mut g = DataGen::new(cfg.clone(), seed);
                g.fast_forward(r * accum);
                g
            })
            .collect()
    }

    fn inline_step(gens: &mut [DataGen], accum: usize) -> Vec<Batch> {
        let dp = gens.len();
        let mut batches = Vec::with_capacity(dp * accum);
        for g in gens.iter_mut() {
            for _ in 0..accum {
                batches.push(g.next_batch());
            }
            g.fast_forward((dp - 1) * accum);
        }
        batches
    }

    #[test]
    fn prefetched_stream_matches_inline_bit_for_bit() {
        let cfg = ModelConfig::tiny();
        let (dp, accum) = (2usize, 2usize);
        let mut inline = mk_gens(&cfg, 41, dp, accum);
        let mut pf = Prefetcher::start(&cfg, &inline, accum);
        for step in 0..3 {
            let got = pf.next_step().unwrap();
            let want = inline_step(&mut inline, accum);
            assert_eq!(got.batches.len(), want.len());
            for (a, b) in got.batches.iter().zip(want.iter()) {
                assert_eq!(a.msa_tokens.data, b.msa_tokens.data, "step {step}");
                assert_eq!(a.msa_labels.data, b.msa_labels.data);
                assert_eq!(a.dist_bins.data, b.dist_bins.data);
                assert_eq!(a.msa_mask, b.msa_mask);
            }
            let want_cursors: Vec<u64> = inline.iter().map(|g| g.cursor()).collect();
            let want_rng: Vec<(u64, u64)> =
                inline.iter().map(|g| g.rng_state()).collect();
            assert_eq!(got.cursors, want_cursors, "step {step}");
            assert_eq!(got.rng_states, want_rng, "step {step}");
        }
    }

    #[test]
    fn restart_from_snapshot_resumes_the_stream() {
        let cfg = ModelConfig::tiny();
        let (dp, accum) = (2usize, 1usize);
        let gens = mk_gens(&cfg, 7, dp, accum);
        let mut pf = Prefetcher::start(&cfg, &gens, accum);
        let s1 = pf.next_step().unwrap();
        let s2 = pf.next_step().unwrap();
        drop(pf);
        // restore generators at s2's recorded position (what the trainer
        // adopts on receipt) and restart: the next step must be exactly
        // what the uninterrupted producer would have sent third
        let restored: Vec<DataGen> = s2
            .rng_states
            .iter()
            .zip(s2.cursors.iter())
            .map(|(rs, &c)| DataGen::from_state(cfg.clone(), *rs, c))
            .collect();
        let mut pf2 = Prefetcher::start(&cfg, &restored, accum);
        let s3 = pf2.next_step().unwrap();

        let mut inline = mk_gens(&cfg, 7, dp, accum);
        let _ = inline_step(&mut inline, accum);
        let _ = inline_step(&mut inline, accum);
        let want = inline_step(&mut inline, accum);
        for (a, b) in s3.batches.iter().zip(want.iter()) {
            assert_eq!(a.msa_tokens.data, b.msa_tokens.data);
        }
        // and the first two steps came through unchanged
        assert_eq!(s1.cursors.len(), dp);
        assert!(s2.cursors.iter().zip(s1.cursors.iter()).all(|(b, a)| b > a));
    }

    #[test]
    fn dropping_mid_stream_joins_cleanly() {
        let cfg = ModelConfig::tiny();
        let gens = mk_gens(&cfg, 3, 1, 1);
        let mut pf = Prefetcher::start(&cfg, &gens, 1);
        let _ = pf.next_step().unwrap();
        drop(pf); // must not hang on the producer's blocked send
    }

    #[test]
    fn stall_ledger_accumulates() {
        let cfg = ModelConfig::tiny();
        let gens = mk_gens(&cfg, 5, 1, 1);
        let mut pf = Prefetcher::start(&cfg, &gens, 1);
        assert_eq!(pf.stall_seconds(), 0.0);
        let _ = pf.next_step().unwrap();
        let _ = pf.next_step().unwrap();
        assert_eq!(pf.steps(), 2);
        assert!(pf.stall_seconds() >= 0.0);
    }
}
