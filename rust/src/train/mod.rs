//! Training runtime: synthetic co-evolution data, the hybrid DP×DAP
//! trainer (micro-batch grads → accumulation → ring all-reduce →
//! adam_update), the [`ParallelPlan`] layout, the two-stage AlphaFold
//! recipe + full LR schedule, resumable full-state (V2) checkpointing,
//! and the overlapped training plane: bucketed DP all-reduce launched
//! from the streamed backward ([`bucket`]), double-buffered input
//! prefetch ([`prefetch`]), and bf16 mixed-precision gradient wire.

pub mod backend;
pub mod bucket;
pub mod checkpoint;
pub mod data;
pub mod plan;
pub mod prefetch;
pub mod schedule;
pub mod trainer;

pub use backend::{GradSink, SyntheticBackend, TrainBackend};
pub use bucket::{bucketed_step, Bucket, BucketOutcome, BucketPlan};
pub use data::DataGen;
pub use plan::ParallelPlan;
pub use prefetch::{Prefetcher, StepBatches};
pub use schedule::{LrSchedule, Stage, TrainSchedule};
pub use trainer::{TrainReport, Trainer};

/// Linear-warmup → constant LR — the degenerate (no stage-decay) case of
/// [`LrSchedule`]; `LrSchedule::warmup_only(base_lr, warmup).at(step)`
/// reproduces it exactly (cross-checked in `schedule::tests`).
pub fn lr_at(step: usize, base_lr: f32, warmup: usize) -> f32 {
    if warmup == 0 || step >= warmup {
        base_lr
    } else {
        base_lr * (step + 1) as f32 / warmup as f32
    }
}

#[cfg(test)]
mod tests {
    #[test]
    fn warmup_ramps() {
        assert!(super::lr_at(0, 1.0, 10) < 0.2);
        assert_eq!(super::lr_at(10, 1.0, 10), 1.0);
        assert_eq!(super::lr_at(5, 1.0, 0), 1.0);
    }
}
