//! Training runtime: synthetic co-evolution data, the data-parallel
//! trainer (grad_step executable → ring all-reduce → adam_update
//! executable), LR schedule, gradient clipping, checkpointing.

pub mod checkpoint;
pub mod data;
pub mod trainer;

pub use data::DataGen;
pub use trainer::{TrainReport, Trainer};

/// Linear-warmup → constant LR schedule (AlphaFold's training recipe shape).
pub fn lr_at(step: usize, base_lr: f32, warmup: usize) -> f32 {
    if warmup == 0 || step >= warmup {
        base_lr
    } else {
        base_lr * (step + 1) as f32 / warmup as f32
    }
}

#[cfg(test)]
mod tests {
    #[test]
    fn warmup_ramps() {
        assert!(super::lr_at(0, 1.0, 10) < 0.2);
        assert_eq!(super::lr_at(10, 1.0, 10), 1.0);
        assert_eq!(super::lr_at(5, 1.0, 0), 1.0);
    }
}
