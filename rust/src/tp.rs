//! Tensor Parallelism baseline (paper §IV.B.1, Megatron-style), used for
//! the Table III communication comparison and the Fig 10/13 baselines.
//!
//! The schedule mirrors the paper's description: column-parallel QKV(+gate)
//! projections, row-parallel output projections with AllReduce; transition
//! = column→row parallel pair with AllReduce; triangle-mult and OPM do not
//! parallelize under TP (parameters replicated, compute duplicated). Six
//! AllReduces per block forward, six more in backward. TP degree is capped
//! by the pair-stack head count (4) — the limitation the paper calls out.
//!
//! This module *simulates the coordination* (issuing the collectives on
//! real-sized tensors so volumes are measured, pricing compute via the
//! FLOPs model): DAP is the paper's contribution and runs the full
//! executable path; TP is its baseline and needs faithful comm/compute
//! accounting, not a second sharded-artifact pipeline (DESIGN.md §4).

use crate::comm::{Collectives, CommKind};
use crate::config::ModelConfig;
use crate::error::{Error, Result};
use crate::tensor::HostTensor;

pub struct TpCoordinator {
    pub cfg: ModelConfig,
    pub n: usize,
    pub comm: Collectives,
}

/// One AllReduce site in the TP block schedule.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TpSite {
    RowAttnOut,
    ColAttnOut,
    MsaTransition,
    TriStartAttnOut,
    TriEndAttnOut,
    PairTransition,
}

pub const TP_SITES: [TpSite; 6] = [
    TpSite::RowAttnOut,
    TpSite::ColAttnOut,
    TpSite::MsaTransition,
    TpSite::TriStartAttnOut,
    TpSite::TriEndAttnOut,
    TpSite::PairTransition,
];

impl TpCoordinator {
    pub fn new(cfg: ModelConfig, n: usize) -> Result<Self> {
        if n > cfg.n_heads_pair {
            return Err(Error::Schedule(format!(
                "TP degree {n} exceeds pair-stack head count {} (paper §IV.B.1)",
                cfg.n_heads_pair
            )));
        }
        if cfg.n_heads_msa % n != 0 || cfg.n_heads_pair % n != 0 {
            return Err(Error::Schedule(format!(
                "TP degree {n} must divide head counts ({}, {})",
                cfg.n_heads_msa, cfg.n_heads_pair
            )));
        }
        Ok(TpCoordinator { cfg, n, comm: Collectives::new(n) })
    }

    fn site_tensor(&self, site: TpSite) -> HostTensor {
        let s = self.cfg.n_seq;
        let r = self.cfg.n_res;
        match site {
            TpSite::RowAttnOut | TpSite::ColAttnOut | TpSite::MsaTransition => {
                HostTensor::zeros(&[s, r, self.cfg.d_msa])
            }
            TpSite::TriStartAttnOut | TpSite::TriEndAttnOut | TpSite::PairTransition => {
                HostTensor::zeros(&[r, r, self.cfg.d_pair])
            }
        }
    }

    /// Issue one block's forward collectives (partial-sum AllReduce at each
    /// row-parallel output). Returns per-rank wire bytes this block moved.
    pub fn block_forward_comm(&self) -> Result<usize> {
        let before = self.comm.log.lock().unwrap().total_bytes();
        for site in TP_SITES {
            let t = self.site_tensor(site);
            let parts: Vec<HostTensor> = (0..self.n).map(|_| t.clone()).collect();
            self.comm.all_reduce(&parts)?;
        }
        Ok(self.comm.log.lock().unwrap().total_bytes() - before)
    }

    /// Backward mirrors forward: 6 more AllReduces (paper Table III: 12
    /// per block for Attention+FF).
    pub fn block_backward_comm(&self) -> Result<usize> {
        self.block_forward_comm()
    }

    /// AllReduce count after `blocks` forward(+backward) blocks.
    pub fn expected_allreduces(blocks: usize, training: bool) -> usize {
        blocks * if training { 12 } else { 6 }
    }

    pub fn allreduce_count(&self) -> usize {
        self.comm.log.lock().unwrap().count(CommKind::AllReduce)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn twelve_allreduce_per_training_block() {
        // paper Table III: TP = 12 × AllReduce per block (6 fwd + 6 bwd)
        let tp = TpCoordinator::new(ModelConfig::tiny(), 2).unwrap();
        tp.block_forward_comm().unwrap();
        tp.block_backward_comm().unwrap();
        assert_eq!(tp.allreduce_count(), 12);
        assert_eq!(TpCoordinator::expected_allreduces(1, true), 12);
    }

    #[test]
    fn degree_capped_by_pair_heads() {
        // paper: TP scales to at most 4 devices (pair stack has 4 heads)
        assert!(TpCoordinator::new(ModelConfig::initial_training(), 8).is_err());
        assert!(TpCoordinator::new(ModelConfig::initial_training(), 4).is_ok());
    }

    #[test]
    fn tp_moves_more_bytes_than_dap() {
        // the paper's core Table III claim: TP volume ≫ DAP volume
        use crate::perfmodel::ScalingModel;
        let cfg = ModelConfig::finetune();
        let tp = TpCoordinator::new(cfg.clone(), 4).unwrap();
        let tp_bytes = tp.block_forward_comm().unwrap();
        let m = ScalingModel::default();
        let dap_bytes: f64 = m
            .dap_comm_bytes(&cfg, 4, 4.0) // f32 here to match host tensors
            .iter()
            .map(|(b, _)| b)
            .sum();
        assert!(
            tp_bytes as f64 > 2.0 * dap_bytes,
            "tp {tp_bytes} vs dap {dap_bytes}"
        );
    }
}
