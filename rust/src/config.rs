//! Configuration system: model presets mirroring `python/compile/configs.py`
//! 1:1, parallelism/training/inference settings, and a minimal TOML-subset
//! loader so deployments can override presets from a file
//! (`fastfold train --config path.toml`).
//!
//! Supported TOML subset: `[section]` headers, `key = value` with string /
//! int / float / bool values and `#` comments — exactly what launcher
//! configs need, nothing more (offline build: no toml crate).

use crate::error::{Error, Result};
use std::collections::BTreeMap;

// ------------------------------------------------------------- model config

#[derive(Clone, Debug, PartialEq)]
pub struct ModelConfig {
    pub name: String,
    pub n_res: usize,
    pub n_seq: usize,
    pub d_msa: usize,
    pub d_pair: usize,
    pub n_heads_msa: usize,
    pub n_heads_pair: usize,
    pub d_head: usize,
    pub d_opm: usize,
    pub n_blocks: usize,
    pub transition_factor: usize,
    pub msa_vocab: usize,
    pub n_dist_bins: usize,
    pub relpos_clip: usize,
}

impl ModelConfig {
    fn base(name: &str, n_res: usize, n_seq: usize) -> Self {
        ModelConfig {
            name: name.into(),
            n_res,
            n_seq,
            d_msa: 256,
            d_pair: 128,
            n_heads_msa: 8,
            n_heads_pair: 4,
            d_head: 32,
            d_opm: 32,
            n_blocks: 48,
            transition_factor: 4,
            msa_vocab: 23,
            n_dist_bins: 64,
            relpos_clip: 32,
        }
    }

    pub fn tiny() -> Self {
        ModelConfig {
            d_msa: 32,
            d_pair: 16,
            n_heads_msa: 4,
            n_heads_pair: 2,
            d_head: 8,
            d_opm: 8,
            n_blocks: 2,
            transition_factor: 2,
            n_dist_bins: 16,
            relpos_clip: 8,
            ..Self::base("tiny", 16, 8)
        }
    }

    pub fn small() -> Self {
        ModelConfig {
            d_msa: 64,
            d_pair: 32,
            n_heads_msa: 4,
            n_heads_pair: 4,
            d_head: 16,
            d_opm: 16,
            n_blocks: 4,
            transition_factor: 4,
            n_dist_bins: 32,
            relpos_clip: 16,
            ..Self::base("small", 64, 16)
        }
    }

    /// Paper Table I — Initial Training (N_r=256, N_s=128).
    pub fn initial_training() -> Self {
        Self::base("initial_training", 256, 128)
    }

    /// Paper Table I — Fine-tuning (N_r=384, N_s=512).
    pub fn finetune() -> Self {
        Self::base("finetune", 384, 512)
    }

    /// An inference-shaped config for a given sequence length (paper §V.C
    /// long-sequence scenarios; N_s = 256 MSA clusters, the AlphaFold
    /// inference default scale).
    pub fn inference(n_res: usize) -> Self {
        Self::base(&format!("infer_{n_res}"), n_res, 256)
    }

    pub fn preset(name: &str) -> Result<Self> {
        match name {
            "tiny" => Ok(Self::tiny()),
            "small" => Ok(Self::small()),
            "initial_training" => Ok(Self::initial_training()),
            "finetune" => Ok(Self::finetune()),
            _ => Err(Error::Config(format!("unknown preset '{name}'"))),
        }
    }

    /// Exact parameter count (closed form, mirrors model.py init).
    /// Verified against the manifest's recorded count in tests.
    pub fn param_count(&self) -> usize {
        let (dm, dz) = (self.d_msa, self.d_pair);
        let (hm, hp, dh) = (self.n_heads_msa, self.n_heads_pair, self.d_head);
        let t = self.transition_factor;
        let ln = |d: usize| 2 * d;
        let lin = |i: usize, o: usize| i * o + o;
        let lin_nb = |i: usize, o: usize| i * o;
        let attn = |d: usize, h: usize| ln(d) + lin_nb(d, 4 * h * dh) + lin(h * dh, d);
        let bias = |h: usize| ln(dz) + lin_nb(dz, h);
        let trans = |d: usize| ln(d) + lin(d, t * d) + lin(t * d, d);
        let tri_mult = ln(dz) + lin_nb(dz, 4 * dz) + ln(dz) + lin_nb(dz, dz) + lin(dz, dz);
        let opm = ln(dm) + lin_nb(dm, 2 * self.d_opm)
            + lin(self.d_opm * self.d_opm, dz);
        let block = bias(hm)
            + attn(dm, hm)          // row
            + attn(dm, hm)          // col
            + trans(dm)
            + opm
            + 2 * tri_mult
            + 2 * bias(hp)
            + 2 * attn(dz, hp)
            + trans(dz);
        let v = self.msa_vocab;
        let nrel = 2 * self.relpos_clip + 1;
        let embed = lin(v, dm) + lin(v, dm) + 2 * lin(v, dz) + lin(nrel, dz);
        let heads = ln(dm) + lin(dm, v) + ln(dz) + lin(dz, self.n_dist_bins);
        embed + self.n_blocks * block + heads
    }
}

// -------------------------------------------------------- parallel / train

#[derive(Clone, Debug, PartialEq)]
pub struct ParallelConfig {
    /// Dynamic Axial Parallelism degree (simulated ranks).
    pub dap_size: usize,
    /// Tensor Parallelism degree (baseline; ≤ n_heads_pair per the paper).
    pub tp_size: usize,
    /// Data-parallel replicas.
    pub dp_size: usize,
    /// Gradient-accumulation micro-batches per replica per optimizer step
    /// (effective batch = dp_size × accum).
    pub accum: usize,
    /// Duality Async Operation (computation–communication overlap) on/off.
    pub overlap: bool,
    /// Rank-executor host threads: 0 = auto (env `FASTFOLD_THREADS` or
    /// available parallelism), 1 = sequential, N = explicit budget.
    pub threads: usize,
}

impl Default for ParallelConfig {
    fn default() -> Self {
        ParallelConfig {
            dap_size: 1,
            tp_size: 1,
            dp_size: 1,
            accum: 1,
            overlap: true,
            threads: 0,
        }
    }
}

impl ParallelConfig {
    /// Resolve the configured thread budget: explicit value, or the
    /// [`crate::dap::default_threads`] policy when 0 (auto).
    pub fn resolve_threads(&self) -> usize {
        if self.threads >= 1 {
            self.threads
        } else {
            crate::dap::default_threads()
        }
    }
}

/// Gradient storage/wire precision for training (the `--precision` flag /
/// `[train] precision` key). `Bf16` emulates mixed-precision training on
/// the host device plane: micro-gradients are rounded to the bf16 grid at
/// emission, the DP ring all-reduce moves 2-byte bf16 halves (half the
/// f32 wire), and a dynamic loss-scale guard skips non-finite steps —
/// while parameters and Adam moments stay f32 **master weights**.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum Precision {
    /// Full f32 everywhere (the default; bit-for-bit the legacy path).
    #[default]
    F32,
    /// bf16 gradient storage + wire emulation over f32 master weights.
    Bf16,
}

impl Precision {
    /// Parse a `--precision` / `[train] precision` value.
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "f32" => Ok(Precision::F32),
            "bf16" => Ok(Precision::Bf16),
            _ => Err(Error::Config(format!(
                "unknown precision '{s}' (expected f32 or bf16)"
            ))),
        }
    }

    /// Canonical name ("f32" / "bf16").
    pub fn name(self) -> &'static str {
        match self {
            Precision::F32 => "f32",
            Precision::Bf16 => "bf16",
        }
    }
}

#[derive(Clone, Debug)]
pub struct TrainConfig {
    pub steps: usize,
    pub lr: f32,
    pub warmup_steps: usize,
    /// step at which the stage decay multiplies the LR (None = never) —
    /// the AlphaFold warmup → constant → stage-decay shape
    pub lr_decay_after: Option<usize>,
    /// multiplicative LR factor applied from `lr_decay_after` on
    pub lr_decay_factor: f32,
    pub log_every: usize,
    pub checkpoint_every: usize,
    pub checkpoint_dir: Option<String>,
    pub seed: u64,
    pub grad_clip: Option<f32>,
    /// gradient storage/wire precision (`--precision {f32,bf16}`)
    pub precision: Precision,
    /// double-buffered data prefetch: a producer thread generates step
    /// N+1's micro-batches while step N computes (`--prefetch`)
    pub prefetch: bool,
    /// DP all-reduce bucket size in MiB: `Some(mb)` overlaps per-bucket
    /// ring reductions with the remaining backward, `None` keeps the
    /// monolithic post-backward reduce (`--bucket-mb`)
    pub bucket_mb: Option<f64>,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            steps: 200,
            lr: 1e-3,
            warmup_steps: 20,
            lr_decay_after: None,
            lr_decay_factor: 1.0,
            log_every: 10,
            checkpoint_every: 100,
            checkpoint_dir: None,
            seed: 42,
            grad_clip: Some(1.0),
            precision: Precision::F32,
            prefetch: false,
            bucket_mb: None,
        }
    }
}

/// AutoChunk planner settings (paper §IV), settable from the `[autochunk]`
/// TOML section so deployments can retarget the planner per fleet.
#[derive(Clone, Debug, PartialEq)]
pub struct AutoChunkConfig {
    /// Consult the planner (memory guard) before long-sequence inference.
    pub enabled: bool,
    /// Device the planner budgets against (a `GpuSpec::by_name` name).
    pub gpu: String,
    /// Fraction of the transient budget left free when choosing chunk
    /// counts (allocator fragmentation / workspace reservation). Defaults
    /// to [`crate::inference::autochunk::CHUNK_HEADROOM`].
    pub headroom: f64,
}

impl Default for AutoChunkConfig {
    fn default() -> Self {
        AutoChunkConfig {
            enabled: true,
            gpu: "a100_40g".into(),
            headroom: crate::inference::autochunk::CHUNK_HEADROOM,
        }
    }
}

/// Serving-layer settings (`[serve]` section): the queue discipline and
/// admission bounds `fastfold serve` hands the inference engine.
#[derive(Clone, Debug, PartialEq)]
pub struct ServeConfig {
    /// Queue discipline: FIFO or shortest-job-first by modeled latency.
    pub policy: crate::inference::engine::SchedPolicy,
    /// Largest DAP degree the placement planner may assign (the fleet's
    /// biggest model-parallel group; Table V serves up to 8).
    pub max_dap: usize,
    /// SJF starvation guard: a waiting request runs next once this many
    /// later arrivals have overtaken it (0 = strict arrival order).
    pub max_bypass: usize,
    /// Daemon backpressure bound: a request arriving while this many
    /// requests already wait is shed instead of queued (0 = unbounded;
    /// only the `fastfold daemon` continuous loop enforces it — a batch
    /// drain has no arrival process to push back on).
    pub queue_cap: usize,
    /// Result-cache byte budget in decimal GB (0 disables the cache).
    /// Entries are priced at the modeled output size of the request
    /// shape, so one 4096-residue distogram costs real gigabytes.
    pub cache_gb: f64,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            policy: crate::inference::engine::SchedPolicy::Fifo,
            max_dap: 8,
            max_bypass: 4,
            queue_cap: 512,
            cache_gb: 8.0,
        }
    }
}

/// Collective-communication settings (`[comm]` section): the bounded
/// wait the comm worker's join path enforces so a stalled collective
/// surfaces a structured [`crate::Error::CommTimeout`] instead of
/// hanging the process forever.
#[derive(Clone, Debug, PartialEq)]
pub struct CommConfig {
    /// Milliseconds a schedule `Wait` blocks on an in-flight collective
    /// before timing out (0 = unbounded, the legacy block-forever join).
    pub wait_timeout_ms: u64,
}

impl Default for CommConfig {
    fn default() -> Self {
        CommConfig {
            wait_timeout_ms: crate::comm::worker::DEFAULT_WAIT_TIMEOUT_MS,
        }
    }
}

/// Host device-backend settings (`[device]` section): which
/// [`crate::device::DeviceBackend`] implementation the kernel plane
/// dispatches through. The CLI resolves the final choice with
/// `--device-backend` / `FASTFOLD_BACKEND` taking precedence over this
/// field (see [`crate::device::resolve_kind`]) and writes the canonical
/// name back here so downstream consumers (planner, perf model) price
/// the backend that actually runs.
#[derive(Clone, Debug, PartialEq)]
pub struct DeviceConfig {
    /// Backend name: `"scalar"`, `"simd"`, or `"xla-stub"`.
    pub backend: String,
}

impl Default for DeviceConfig {
    fn default() -> Self {
        DeviceConfig { backend: "simd".into() }
    }
}

#[derive(Clone, Debug)]
pub struct RunConfig {
    pub preset: String,
    pub artifacts_dir: String,
    pub parallel: ParallelConfig,
    pub train: TrainConfig,
    pub autochunk: AutoChunkConfig,
    pub serve: ServeConfig,
    pub comm: CommConfig,
    pub device: DeviceConfig,
}

impl Default for RunConfig {
    fn default() -> Self {
        RunConfig {
            preset: "tiny".into(),
            artifacts_dir: "artifacts".into(),
            parallel: ParallelConfig::default(),
            train: TrainConfig::default(),
            autochunk: AutoChunkConfig::default(),
            serve: ServeConfig::default(),
            comm: CommConfig::default(),
            device: DeviceConfig::default(),
        }
    }
}

// ------------------------------------------------------------- TOML subset

#[derive(Clone, Debug, PartialEq)]
pub enum TomlValue {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
}

impl TomlValue {
    pub fn as_usize(&self) -> Result<usize> {
        match self {
            TomlValue::Int(i) if *i >= 0 => Ok(*i as usize),
            _ => Err(Error::Config(format!("expected non-negative int, got {self:?}"))),
        }
    }

    pub fn as_f32(&self) -> Result<f32> {
        Ok(self.as_f64()? as f32)
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            TomlValue::Float(f) => Ok(*f),
            TomlValue::Int(i) => Ok(*i as f64),
            _ => Err(Error::Config(format!("expected float, got {self:?}"))),
        }
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            TomlValue::Bool(b) => Ok(*b),
            _ => Err(Error::Config(format!("expected bool, got {self:?}"))),
        }
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            TomlValue::Str(s) => Ok(s),
            _ => Err(Error::Config(format!("expected string, got {self:?}"))),
        }
    }
}

/// section -> key -> value
pub type TomlDoc = BTreeMap<String, BTreeMap<String, TomlValue>>;

pub fn parse_toml(src: &str) -> Result<TomlDoc> {
    let mut doc: TomlDoc = BTreeMap::new();
    let mut section = String::new();
    doc.insert(String::new(), BTreeMap::new());
    for (lineno, raw) in src.lines().enumerate() {
        let line = match raw.find('#') {
            // naive comment strip is fine: our strings never contain '#'
            Some(i) if !raw[..i].contains('"') || raw[..i].matches('"').count() % 2 == 0 => {
                &raw[..i]
            }
            _ => raw,
        }
        .trim();
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix('[') {
            let name = rest
                .strip_suffix(']')
                .ok_or_else(|| Error::Config(format!("line {}: bad section", lineno + 1)))?;
            section = name.trim().to_string();
            doc.entry(section.clone()).or_default();
            continue;
        }
        let eq = line
            .find('=')
            .ok_or_else(|| Error::Config(format!("line {}: expected key = value", lineno + 1)))?;
        let key = line[..eq].trim().to_string();
        let val_src = line[eq + 1..].trim();
        let val = parse_toml_value(val_src)
            .ok_or_else(|| Error::Config(format!("line {}: bad value '{val_src}'", lineno + 1)))?;
        doc.entry(section.clone()).or_default().insert(key, val);
    }
    Ok(doc)
}

fn parse_toml_value(s: &str) -> Option<TomlValue> {
    if let Some(body) = s.strip_prefix('"').and_then(|t| t.strip_suffix('"')) {
        return Some(TomlValue::Str(body.to_string()));
    }
    match s {
        "true" => return Some(TomlValue::Bool(true)),
        "false" => return Some(TomlValue::Bool(false)),
        _ => {}
    }
    if let Ok(i) = s.parse::<i64>() {
        return Some(TomlValue::Int(i));
    }
    if let Ok(f) = s.parse::<f64>() {
        return Some(TomlValue::Float(f));
    }
    None
}

impl RunConfig {
    /// Load a RunConfig from a TOML file, starting from defaults.
    pub fn from_toml_file(path: &str) -> Result<Self> {
        let src = std::fs::read_to_string(path)?;
        Self::from_toml(&src)
    }

    pub fn from_toml(src: &str) -> Result<Self> {
        let doc = parse_toml(src)?;
        let mut cfg = RunConfig::default();
        if let Some(root) = doc.get("") {
            if let Some(v) = root.get("preset") {
                cfg.preset = v.as_str()?.to_string();
            }
            if let Some(v) = root.get("artifacts_dir") {
                cfg.artifacts_dir = v.as_str()?.to_string();
            }
        }
        if let Some(p) = doc.get("parallel") {
            if let Some(v) = p.get("dap_size") {
                cfg.parallel.dap_size = v.as_usize()?;
            }
            if let Some(v) = p.get("tp_size") {
                cfg.parallel.tp_size = v.as_usize()?;
            }
            if let Some(v) = p.get("dp_size") {
                cfg.parallel.dp_size = v.as_usize()?;
            }
            if let Some(v) = p.get("accum") {
                let n = v.as_usize()?;
                if n == 0 {
                    return Err(Error::Config("parallel accum must be >= 1".into()));
                }
                cfg.parallel.accum = n;
            }
            if let Some(v) = p.get("overlap") {
                cfg.parallel.overlap = v.as_bool()?;
            }
            if let Some(v) = p.get("threads") {
                cfg.parallel.threads = v.as_usize()?;
            }
        }
        if let Some(t) = doc.get("train") {
            if let Some(v) = t.get("steps") {
                cfg.train.steps = v.as_usize()?;
            }
            if let Some(v) = t.get("lr") {
                cfg.train.lr = v.as_f32()?;
            }
            if let Some(v) = t.get("warmup_steps") {
                cfg.train.warmup_steps = v.as_usize()?;
            }
            if let Some(v) = t.get("lr_decay_after") {
                cfg.train.lr_decay_after = Some(v.as_usize()?);
            }
            if let Some(v) = t.get("lr_decay_factor") {
                cfg.train.lr_decay_factor = v.as_f32()?;
            }
            if let Some(v) = t.get("log_every") {
                cfg.train.log_every = v.as_usize()?;
            }
            if let Some(v) = t.get("checkpoint_every") {
                cfg.train.checkpoint_every = v.as_usize()?;
            }
            if let Some(v) = t.get("checkpoint_dir") {
                cfg.train.checkpoint_dir = Some(v.as_str()?.to_string());
            }
            if let Some(v) = t.get("seed") {
                cfg.train.seed = v.as_usize()? as u64;
            }
            if let Some(v) = t.get("grad_clip") {
                cfg.train.grad_clip = Some(v.as_f32()?);
            }
            if let Some(v) = t.get("precision") {
                cfg.train.precision = Precision::parse(v.as_str()?)?;
            }
            if let Some(v) = t.get("prefetch") {
                cfg.train.prefetch = v.as_bool()?;
            }
            if let Some(v) = t.get("bucket_mb") {
                let mb = v.as_f64()?;
                if !(mb > 0.0 && mb.is_finite()) {
                    return Err(Error::Config(format!(
                        "train bucket_mb must be a positive number, got {mb}"
                    )));
                }
                cfg.train.bucket_mb = Some(mb);
            }
        }
        if let Some(a) = doc.get("autochunk") {
            if let Some(v) = a.get("enabled") {
                cfg.autochunk.enabled = v.as_bool()?;
            }
            if let Some(v) = a.get("gpu") {
                cfg.autochunk.gpu = v.as_str()?.to_string();
            }
            if let Some(v) = a.get("headroom") {
                let h = v.as_f64()?;
                crate::inference::autochunk::validate_headroom(h)?;
                cfg.autochunk.headroom = h;
            }
        }
        if let Some(s) = doc.get("serve") {
            if let Some(v) = s.get("policy") {
                cfg.serve.policy =
                    crate::inference::engine::SchedPolicy::parse(v.as_str()?)?;
            }
            if let Some(v) = s.get("max_dap") {
                let n = v.as_usize()?;
                if n == 0 {
                    return Err(Error::Config("serve max_dap must be >= 1".into()));
                }
                cfg.serve.max_dap = n;
            }
            if let Some(v) = s.get("max_bypass") {
                cfg.serve.max_bypass = v.as_usize()?;
            }
            if let Some(v) = s.get("queue_cap") {
                cfg.serve.queue_cap = v.as_usize()?;
            }
            if let Some(v) = s.get("cache_gb") {
                let g = v.as_f64()?;
                if !(0.0..=1024.0).contains(&g) {
                    return Err(Error::Config(format!(
                        "serve cache_gb must be in [0, 1024], got {g}"
                    )));
                }
                cfg.serve.cache_gb = g;
            }
        }
        if let Some(c) = doc.get("comm") {
            if let Some(v) = c.get("wait_timeout_ms") {
                cfg.comm.wait_timeout_ms = v.as_usize()? as u64;
            }
        }
        if let Some(d) = doc.get("device") {
            if let Some(v) = d.get("backend") {
                let name = v.as_str()?;
                // validate eagerly so a typo fails at config load, not at
                // first dispatch; store the canonical spelling
                let kind = crate::device::DeviceKind::parse(name)?;
                cfg.device.backend = kind.name().to_string();
            }
        }
        Ok(cfg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_match_paper_table1() {
        let it = ModelConfig::initial_training();
        assert_eq!((it.n_res, it.n_seq), (256, 128));
        let ft = ModelConfig::finetune();
        assert_eq!((ft.n_res, ft.n_seq), (384, 512));
        assert_eq!(it.n_blocks, 48);
        assert_eq!(it.d_msa, 256);
        assert_eq!(it.d_pair, 128);
    }

    #[test]
    fn param_count_matches_paper_table2() {
        // paper Table II: ~1.8M params per Evoformer layer
        let cfg = ModelConfig::initial_training();
        let per_block = (cfg.param_count()
            - ModelConfig { n_blocks: 0, ..cfg.clone() }.param_count())
            / cfg.n_blocks;
        assert!(
            (1_700_000..1_950_000).contains(&per_block),
            "per-block {per_block}"
        );
    }

    #[test]
    fn toml_roundtrip() {
        let src = r#"
# launcher config
preset = "small"
artifacts_dir = "artifacts"

[parallel]
dap_size = 4
accum = 2
overlap = false
threads = 2

[train]
steps = 50
lr = 0.0005
lr_decay_after = 40
lr_decay_factor = 0.95

[autochunk]
enabled = true
gpu = "tpu_v3"
headroom = 0.25
"#;
        let cfg = RunConfig::from_toml(src).unwrap();
        assert_eq!(cfg.preset, "small");
        assert_eq!(cfg.parallel.dap_size, 4);
        assert_eq!(cfg.parallel.accum, 2);
        assert!(!cfg.parallel.overlap);
        assert_eq!(cfg.parallel.threads, 2);
        assert_eq!(cfg.parallel.resolve_threads(), 2);
        assert!(ParallelConfig::default().resolve_threads() >= 1);
        assert_eq!(cfg.train.steps, 50);
        assert!((cfg.train.lr - 5e-4).abs() < 1e-9);
        assert_eq!(cfg.train.lr_decay_after, Some(40));
        assert!((cfg.train.lr_decay_factor - 0.95).abs() < 1e-6);
        assert!(RunConfig::from_toml("[parallel]\naccum = 0").is_err());
        assert!(cfg.autochunk.enabled);
        assert_eq!(cfg.autochunk.gpu, "tpu_v3");
        assert!((cfg.autochunk.headroom - 0.25).abs() < 1e-9);
    }

    #[test]
    fn autochunk_defaults_and_validation() {
        let cfg = RunConfig::from_toml("").unwrap();
        assert_eq!(cfg.autochunk, AutoChunkConfig::default());
        assert!(RunConfig::from_toml("[autochunk]\nheadroom = 1.5").is_err());
    }

    #[test]
    fn serve_section_parses_and_validates() {
        use crate::inference::engine::SchedPolicy;
        let cfg = RunConfig::from_toml("").unwrap();
        assert_eq!(cfg.serve, ServeConfig::default());
        assert_eq!(cfg.serve.policy, SchedPolicy::Fifo);
        let cfg = RunConfig::from_toml(
            "[serve]\npolicy = \"sjf\"\nmax_dap = 16\nmax_bypass = 2\n\
             queue_cap = 64\ncache_gb = 2.5",
        )
        .unwrap();
        assert_eq!(cfg.serve.policy, SchedPolicy::Sjf);
        assert_eq!(cfg.serve.max_dap, 16);
        assert_eq!(cfg.serve.max_bypass, 2);
        assert_eq!(cfg.serve.queue_cap, 64);
        assert!((cfg.serve.cache_gb - 2.5).abs() < 1e-12);
        assert!(RunConfig::from_toml("[serve]\npolicy = \"lifo\"").is_err());
        assert!(RunConfig::from_toml("[serve]\nmax_dap = 0").is_err());
        assert!(RunConfig::from_toml("[serve]\ncache_gb = -1.0").is_err());
        assert!(RunConfig::from_toml("[serve]\ncache_gb = 99999").is_err());
    }

    #[test]
    fn device_section_parses_and_validates() {
        let cfg = RunConfig::from_toml("").unwrap();
        assert_eq!(cfg.device, DeviceConfig::default());
        assert_eq!(cfg.device.backend, "simd");
        let cfg =
            RunConfig::from_toml("[device]\nbackend = \"scalar\"").unwrap();
        assert_eq!(cfg.device.backend, "scalar");
        let cfg =
            RunConfig::from_toml("[device]\nbackend = \"xla-stub\"").unwrap();
        assert_eq!(cfg.device.backend, "xla-stub");
        assert!(RunConfig::from_toml("[device]\nbackend = \"cuda\"").is_err());
        assert!(RunConfig::from_toml("[device]\nbackend = 3").is_err());
    }

    #[test]
    fn train_overlap_keys_parse_and_validate() {
        let cfg = RunConfig::from_toml("").unwrap();
        assert_eq!(cfg.train.precision, Precision::F32);
        assert!(!cfg.train.prefetch);
        assert_eq!(cfg.train.bucket_mb, None);
        let cfg = RunConfig::from_toml(
            "[train]\nprecision = \"bf16\"\nprefetch = true\nbucket_mb = 0.5",
        )
        .unwrap();
        assert_eq!(cfg.train.precision, Precision::Bf16);
        assert!(cfg.train.prefetch);
        assert_eq!(cfg.train.bucket_mb, Some(0.5));
        assert!(RunConfig::from_toml("[train]\nprecision = \"fp8\"").is_err());
        assert!(RunConfig::from_toml("[train]\nbucket_mb = 0").is_err());
        assert!(RunConfig::from_toml("[train]\nbucket_mb = -1.0").is_err());
        assert_eq!(Precision::parse("f32").unwrap().name(), "f32");
        assert_eq!(Precision::parse("bf16").unwrap().name(), "bf16");
    }

    #[test]
    fn comm_section_parses() {
        let cfg = RunConfig::from_toml("").unwrap();
        assert_eq!(cfg.comm, CommConfig::default());
        assert_eq!(
            cfg.comm.wait_timeout_ms,
            crate::comm::worker::DEFAULT_WAIT_TIMEOUT_MS
        );
        let cfg =
            RunConfig::from_toml("[comm]\nwait_timeout_ms = 250").unwrap();
        assert_eq!(cfg.comm.wait_timeout_ms, 250);
        let cfg = RunConfig::from_toml("[comm]\nwait_timeout_ms = 0").unwrap();
        assert_eq!(cfg.comm.wait_timeout_ms, 0); // 0 = unbounded
        assert!(RunConfig::from_toml("[comm]\nwait_timeout_ms = -5").is_err());
    }

    #[test]
    fn toml_errors() {
        assert!(parse_toml("[unclosed").is_err());
        assert!(parse_toml("novalue").is_err());
        assert!(RunConfig::from_toml("preset = 5").is_err());
    }

    #[test]
    fn unknown_preset_rejected() {
        assert!(ModelConfig::preset("nope").is_err());
    }
}
