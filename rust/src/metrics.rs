//! Metrics & reporting: wall timers, throughput/FLOPs accounting,
//! parallel-efficiency math, per-request serving ledgers
//! ([`ServeStats`]), and simple aligned-table printing shared by the CLI
//! `report`/`serve` subcommands and the bench harnesses.

use std::collections::BTreeMap;
use std::time::Instant; // lint:allow(wallclock) — Stopwatch wall measurement

/// Measure a closure's wall time over `iters` runs after `warmup` runs;
/// returns (mean, min, max) seconds.
pub fn time_it<F: FnMut()>(warmup: usize, iters: usize, mut f: F) -> (f64, f64, f64) {
    for _ in 0..warmup {
        f();
    }
    let mut times = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        times.push(t0.elapsed().as_secs_f64());
    }
    let sum: f64 = times.iter().sum();
    let min = times.iter().cloned().fold(f64::INFINITY, f64::min);
    let max = times.iter().cloned().fold(0.0, f64::max);
    (sum / iters as f64, min, max)
}

/// Parallel efficiency: speedup(N) / N.
pub fn parallel_efficiency(t1: f64, tn: f64, n: usize) -> f64 {
    if tn <= 0.0 || n == 0 {
        return 0.0;
    }
    (t1 / tn) / n as f64
}

/// Median of a sample (consumes and sorts).
pub fn median(mut xs: Vec<f64>) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = xs.len();
    if n % 2 == 1 {
        xs[n / 2]
    } else {
        0.5 * (xs[n / 2 - 1] + xs[n / 2])
    }
}

/// Nearest-rank percentile of a sample (consumes and sorts): `p` in
/// [0, 100]. `percentile(xs, 50)` is the lower-median convention the
/// serve-latency ledger uses (p50/p90/p99 of modeled sojourn times).
pub fn percentile(mut xs: Vec<f64>, p: f64) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let p = p.clamp(0.0, 100.0);
    // nearest-rank: ceil(p/100 * n), 1-indexed
    let rank = ((p / 100.0) * xs.len() as f64).ceil() as usize;
    xs[rank.max(1) - 1]
}

/// Fixed-width table printer (console reproduction of the paper's tables).
pub struct Table {
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Self {
        Table {
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        self.rows.push(cells.to_vec());
    }

    pub fn print(&self) {
        let ncol = self.headers.len();
        let mut width = vec![0usize; ncol];
        for (i, h) in self.headers.iter().enumerate() {
            width[i] = h.chars().count();
        }
        for row in &self.rows {
            for (i, c) in row.iter().enumerate().take(ncol) {
                width[i] = width[i].max(c.chars().count());
            }
        }
        let line = |cells: &[String]| {
            let mut s = String::from("| ");
            for (i, c) in cells.iter().enumerate().take(ncol) {
                s.push_str(c);
                for _ in c.chars().count()..width[i] {
                    s.push(' ');
                }
                s.push_str(" | ");
            }
            println!("{}", s.trim_end());
        };
        line(&self.headers);
        let sep: Vec<String> =
            width.iter().map(|w| "-".repeat(*w)).collect();
        line(&sep);
        for row in &self.rows {
            line(row);
        }
    }
}

/// One served request's ledger entry — what the inference engine records
/// per drained request (plain data so this module stays a leaf).
#[derive(Clone, Debug)]
pub struct ServeRecord {
    /// Request id.
    pub id: String,
    /// Backend the placement chose (`single`, `chunked`, `dap<N>`,
    /// `rejected`).
    pub backend: String,
    /// Modeled end-to-end latency (seconds, paper scale).
    pub modeled_latency: f64,
    /// Modeled FLOPs for the whole request.
    pub modeled_flops: f64,
    /// Measured wall seconds of the execution.
    pub wall_seconds: f64,
    /// Whether the request produced output.
    pub ok: bool,
    /// Whether the output came from the result cache instead of a
    /// backend execution (cached results do no compute, so they are
    /// excluded from the aggregate-FLOP numerator).
    pub cached: bool,
}

/// Degraded-mode counters the serve daemon reports alongside its
/// per-request ledger — all zero on a fault-free run.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct DegradedStats {
    /// Dispatch attempts retried after a backend failure.
    pub retries: usize,
    /// Retries that fell back to a smaller placement.
    pub fallbacks: usize,
    /// Arrivals shed by the open circuit breaker.
    pub breaker_shed: usize,
    /// Requests that exhausted their dispatch retries.
    pub failed: usize,
}

impl DegradedStats {
    /// Whether any degraded-mode event was recorded.
    pub fn any(&self) -> bool {
        self.retries + self.fallbacks + self.breaker_shed + self.failed > 0
    }
}

/// Aggregate serving metrics over a drained request batch.
#[derive(Clone, Debug, Default)]
pub struct ServeStats {
    /// Per-request records in submission order.
    pub records: Vec<ServeRecord>,
    /// Fault-plane counters (zero unless faults were injected).
    pub degraded: DegradedStats,
}

impl ServeStats {
    /// Append one request's record.
    pub fn push(&mut self, r: ServeRecord) {
        self.records.push(r);
    }

    /// Requests that produced output.
    pub fn completed(&self) -> usize {
        self.records.iter().filter(|r| r.ok).count()
    }

    /// Total modeled FLOPs of *executed* requests (rejected records
    /// carry 0; cache hits served stored bits, so their modeled FLOPs
    /// are excluded — counting them would overstate throughput).
    pub fn total_modeled_flops(&self) -> f64 {
        self.records
            .iter()
            .filter(|r| !r.cached)
            .map(|r| r.modeled_flops)
            .sum()
    }

    /// Requests answered from the result cache.
    pub fn cache_hits(&self) -> usize {
        self.records.iter().filter(|r| r.cached).count()
    }

    /// Aggregate modeled throughput: total modeled FLOPs over a modeled
    /// makespan — the paper's "6.02 PetaFLOP/s aggregate" framing.
    pub fn aggregate_pflops(&self, makespan_seconds: f64) -> f64 {
        if makespan_seconds > 0.0 {
            self.total_modeled_flops() / makespan_seconds / 1e15
        } else {
            0.0
        }
    }

    /// Mean measured wall seconds over completed requests.
    pub fn mean_wall_seconds(&self) -> f64 {
        let done: Vec<f64> = self
            .records
            .iter()
            .filter(|r| r.ok)
            .map(|r| r.wall_seconds)
            .collect();
        if done.is_empty() {
            0.0
        } else {
            done.iter().sum::<f64>() / done.len() as f64
        }
    }

    /// Backend mix, e.g. `chunked x2 dap8 x1 single x3`.
    pub fn backend_mix(&self) -> String {
        let mut counts: BTreeMap<&str, usize> = BTreeMap::new();
        for r in &self.records {
            *counts.entry(r.backend.as_str()).or_default() += 1;
        }
        if counts.is_empty() {
            return "none".into();
        }
        counts
            .iter()
            .map(|(b, c)| format!("{b} x{c}"))
            .collect::<Vec<_>>()
            .join(" ")
    }
}

/// Human byte volume (binary units — wire/checkpoint accounting).
pub fn fmt_bytes(b: usize) -> String {
    let b = b as f64;
    if b < 1024.0 {
        format!("{b:.0} B")
    } else if b < 1024.0 * 1024.0 {
        format!("{:.1} KiB", b / 1024.0)
    } else if b < 1024.0 * 1024.0 * 1024.0 {
        format!("{:.1} MiB", b / (1024.0 * 1024.0))
    } else {
        format!("{:.2} GiB", b / (1024.0 * 1024.0 * 1024.0))
    }
}

/// Human duration.
pub fn fmt_secs(s: f64) -> String {
    if s < 1e-3 {
        format!("{:.1} µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2} ms", s * 1e3)
    } else if s < 120.0 {
        format!("{:.2} s", s)
    } else if s < 7200.0 {
        format!("{:.1} min", s / 60.0)
    } else if s < 172800.0 {
        format!("{:.1} h", s / 3600.0)
    } else {
        format!("{:.2} days", s / 86400.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn efficiency_math() {
        assert!((parallel_efficiency(8.0, 1.0, 8) - 1.0).abs() < 1e-12);
        assert!((parallel_efficiency(8.0, 2.0, 8) - 0.5).abs() < 1e-12);
        assert_eq!(parallel_efficiency(1.0, 0.0, 4), 0.0);
    }

    #[test]
    fn median_odd_even() {
        assert_eq!(median(vec![3.0, 1.0, 2.0]), 2.0);
        assert_eq!(median(vec![4.0, 1.0, 2.0, 3.0]), 2.5);
        assert!(median(vec![]).is_nan());
    }

    #[test]
    fn fmt_bytes_ranges() {
        assert_eq!(fmt_bytes(512), "512 B");
        assert!(fmt_bytes(2048).contains("KiB"));
        assert!(fmt_bytes(3 * 1024 * 1024).contains("MiB"));
        assert!(fmt_bytes(2 * 1024 * 1024 * 1024).contains("GiB"));
    }

    #[test]
    fn fmt_ranges() {
        assert!(fmt_secs(5e-4).contains("µs") || fmt_secs(5e-4).contains("ms"));
        assert!(fmt_secs(0.5).contains("ms"));
        assert!(fmt_secs(30.0).contains("s"));
        assert!(fmt_secs(3600.0).contains("min"));
        assert!(fmt_secs(86400.0 * 3.0).contains("days"));
    }

    #[test]
    fn serve_stats_aggregate() {
        let mut s = ServeStats::default();
        let rec = |id: &str, backend: &str, flops: f64, ok: bool| ServeRecord {
            id: id.into(),
            backend: backend.into(),
            modeled_latency: 1.0,
            modeled_flops: flops,
            wall_seconds: 0.5,
            ok,
            cached: false,
        };
        s.push(rec("a", "single", 2e15, true));
        s.push(rec("b", "dap4", 6e15, true));
        s.push(rec("c", "rejected", 0.0, false));
        assert_eq!(s.completed(), 2);
        assert!((s.total_modeled_flops() - 8e15).abs() < 1.0);
        // 8e15 FLOPs over a 4 s modeled makespan = 2 PFLOP/s aggregate
        assert!((s.aggregate_pflops(4.0) - 2.0).abs() < 1e-9);
        assert_eq!(s.aggregate_pflops(0.0), 0.0);
        assert!((s.mean_wall_seconds() - 0.5).abs() < 1e-12);
        let mix = s.backend_mix();
        assert!(mix.contains("single x1") && mix.contains("dap4 x1"), "{mix}");
        assert_eq!(ServeStats::default().backend_mix(), "none");
    }

    #[test]
    fn cache_hits_excluded_from_flop_numerator() {
        // regression: a cache hit carries the modeled FLOPs of the work
        // it *avoided* — counting it would inflate aggregate PFLOP/s
        let mut s = ServeStats::default();
        let rec = |id: &str, cached: bool| ServeRecord {
            id: id.into(),
            backend: "single".into(),
            modeled_latency: 1.0,
            modeled_flops: 4e15,
            wall_seconds: 0.0,
            ok: true,
            cached,
        };
        s.push(rec("miss", false));
        s.push(rec("hit", true));
        assert_eq!(s.completed(), 2, "a hit still completes");
        assert_eq!(s.cache_hits(), 1);
        // only the executed request's 4e15 FLOPs count
        assert!((s.total_modeled_flops() - 4e15).abs() < 1.0);
        assert!((s.aggregate_pflops(2.0) - 2.0).abs() < 1e-9);
    }

    #[test]
    fn percentile_nearest_rank() {
        let xs: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        assert_eq!(percentile(xs.clone(), 50.0), 50.0);
        assert_eq!(percentile(xs.clone(), 99.0), 99.0);
        assert_eq!(percentile(xs.clone(), 100.0), 100.0);
        assert_eq!(percentile(xs.clone(), 0.0), 1.0);
        assert_eq!(percentile(vec![7.0], 99.0), 7.0);
        assert!(percentile(vec![], 50.0).is_nan());
        // p50 <= p99 on any sample
        let sample = vec![3.0, 1.0, 4.0, 1.0, 5.0, 9.0, 2.0, 6.0];
        assert!(percentile(sample.clone(), 50.0) <= percentile(sample, 99.0));
    }

    #[test]
    fn timer_runs() {
        let (mean, min, max) = time_it(1, 3, || {
            std::hint::black_box((0..1000).sum::<usize>());
        });
        assert!(mean >= 0.0 && min <= mean && mean <= max + 1e-12);
    }
}
